"""Benchmark package: paper tables, kernel microbench, roofline, perf CI.

Canonical invocation (from the repo root, any extra PYTHONPATH optional):

    python -m benchmarks.run [--json [PATH]] [--fast] [--skip-resnet]

Importing this package makes ``src/repro`` importable on its own, so the
``PYTHONPATH=src`` prefix the test suite uses is not required for the
benchmark entry points; from outside the repo root, put the repo root on
``PYTHONPATH`` so ``-m benchmarks.run`` resolves.
"""
import os
import sys

_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), os.pardir, "src"))
try:
    import repro  # noqa: F401
except ImportError:  # pragma: no cover - depends on caller's PYTHONPATH
    if _SRC not in sys.path:
        sys.path.insert(0, _SRC)
