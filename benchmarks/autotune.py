"""Autotune driver: measure the kernel block-size candidate grids on
THIS host and persist the winners as a versioned tuning artifact.

    python -m benchmarks.autotune [--out PATH] [--fast] [--iters N]
                                  [--kernels matmul,ssd] [--backends ...]
                                  [--buckets small,medium]

Per ``(kernel, backend, shape bucket)`` the sweep times every candidate
block through ``benchmarks.harness.measure`` (warmup excluded, every
iteration synced, median-of-k — the same contract as every other
benchmark number) on a representative problem of that bucket, and
writes the winners to ``kernels/TUNE_<device_kind>.json`` (schema
``repro-tune/1``, atomic write).  ``dispatch`` consults the artifact
once activated — via ``--tune`` on any benchmark entry point,
``Session(tune=...)``, or the ``REPRO_TUNE_FILE`` env var — and falls
back to the static tables otherwise.  Tuning NEVER runs implicitly
inside a jitted hot path; this driver is the only place measurements
happen.

Backends are swept only where they can run (``pallas`` needs a TPU
host; CPU artifacts cover ``interpret`` + ``xla``).  ``--fast`` trims
buckets, problem sizes, and iteration counts for the CI leg; the
artifact records the mode so ``tools/check_bench.py`` never diffs a
fast sweep against a full one unnoticed.
"""
from __future__ import annotations

import argparse
import os
import sys

if __package__ in (None, ""):  # executed as a script
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.harness import environment_meta, measure  # noqa: E402
from repro.kernels import autotune, dispatch  # noqa: E402

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: Representative max extent per bucket (the measured problem's size).
#: ``fast`` uses each bucket's low end so the CI sweep stays cheap while
#: every measurement is still genuinely inside its bucket; ``interpret``
#: problems are scaled down further below (the interpreter simulates the
#: kernel body, so absolute cost is orders of magnitude above xla).
BUCKET_SIZES = {"small": 256, "medium": 512, "large": 1536}
FAST_BUCKET_SIZES = {"small": 128, "medium": 288, "large": 1056}
INTERPRET_SIZES = {"small": 64, "medium": 288, "large": 1056}


def _available_backends():
    import jax

    return ("pallas", "interpret", "xla") if jax.default_backend() == "tpu" \
        else ("interpret", "xla")


def make_measure_fn(*, iters: int, warmup: int = 1, sizes=None,
                    interpret_sizes=None, seed: int = 0):
    """The ``autotune.sweep`` measure hook: builds one representative
    problem per (kernel, backend, bucket) and times a jitted call of the
    dispatch entry point with the candidate block forced."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.afpm import AFPMConfig

    rng = np.random.default_rng(seed)
    sizes = dict(sizes or BUCKET_SIZES)
    interpret_sizes = dict(interpret_sizes or INTERPRET_SIZES)
    cache = {}

    def problem(kernel, backend, bucket):
        size = (interpret_sizes if backend == "interpret" else sizes)[bucket]
        key = (kernel, backend, bucket)
        if key in cache:
            return cache[key]
        if kernel == "matmul":
            ops = (jnp.asarray(rng.standard_normal((size, size)), jnp.float32),
                   jnp.asarray(rng.standard_normal((size, size)), jnp.float32))
        elif kernel == "bitwise":
            n = size * size
            ops = (jnp.asarray(rng.standard_normal(n), jnp.float32),
                   jnp.asarray(rng.standard_normal(n), jnp.float32))
        else:  # ssd: (L, H, P) scan, small state so the chunk dominates
            L, H, P, N = size, 2, 16, 8
            ops = (jnp.asarray(rng.standard_normal((L, H, P)), jnp.float32),
                   jnp.asarray(rng.uniform(0.01, 0.2, (L, H)), jnp.float32),
                   jnp.asarray(-rng.uniform(0.5, 2.0, (H,)), jnp.float32),
                   jnp.asarray(rng.standard_normal((L, N)), jnp.float32),
                   jnp.asarray(rng.standard_normal((L, N)), jnp.float32))
        cache[key] = (size, ops)
        return cache[key]

    def measure_fn(kernel, backend, bucket, block, size_hint):
        del size_hint  # the sweep passes the clip extent; we sized above
        size, operands = problem(kernel, backend, bucket)
        if kernel == "matmul":
            fn = jax.jit(lambda a, b: dispatch.matmul(
                a, b, 3, backend=backend, block_sizes=tuple(block)))
        elif kernel == "bitwise":
            cfg = AFPMConfig(n=5)
            fn = jax.jit(lambda a, b: dispatch.multiply(
                a, b, cfg, backend=backend, block=tuple(block)))
        else:
            fn = jax.jit(lambda *a: dispatch.ssd(
                *a, chunk=int(block), backend=backend))
        return measure(fn, *operands, iters=iters, warmup=warmup).median_us

    return measure_fn


def clip_sizes(fast: bool):
    """(bucket -> clip extent) handed to the sweep so candidates larger
    than the measured problem are dropped, per backend handled inside
    the measure hook."""
    return dict(FAST_BUCKET_SIZES if fast else BUCKET_SIZES)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="measure kernel block-size candidates on this host "
                    "and write the TUNE_<device>.json artifact")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="artifact path (default: "
                         "kernels/TUNE_<device_kind>.json in the repo)")
    ap.add_argument("--fast", action="store_true",
                    help="CI sweep: small/medium buckets only, low-end "
                         "problem sizes, fewer timing iterations")
    ap.add_argument("--iters", type=int, default=None,
                    help="timing iterations per candidate (default: 3, "
                         "2 with --fast)")
    ap.add_argument("--kernels", default=",".join(autotune.KERNELS),
                    help="comma list of kernels to sweep")
    ap.add_argument("--backends", default=None,
                    help="comma list of backends (default: every backend "
                         "this host can run)")
    ap.add_argument("--buckets", default=None,
                    help="comma list of shape buckets (default: "
                         "small,medium with --fast, all three otherwise)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    kernels = tuple(k for k in args.kernels.split(",") if k)
    backends = (tuple(b for b in args.backends.split(",") if b)
                if args.backends else _available_backends())
    if args.buckets:
        buckets = tuple(b for b in args.buckets.split(",") if b)
    else:
        buckets = ("small", "medium") if args.fast else autotune.BUCKETS
    iters = args.iters if args.iters is not None else (2 if args.fast else 3)

    sizes = clip_sizes(args.fast)
    interp = ({"small": 64, "medium": 160, "large": 1056} if args.fast
              else INTERPRET_SIZES)
    meta = environment_meta()
    meta["fast"] = args.fast
    meta["iters"] = iters
    meta["sizes"] = {b: sizes[b] for b in buckets}

    measure_fn = make_measure_fn(iters=iters, sizes=sizes,
                                 interpret_sizes=interp, seed=args.seed)
    try:
        table = autotune.sweep(measure_fn, kernels=kernels, backends=backends,
                               buckets=buckets, sizes=sizes, meta=meta,
                               verbose=True)
    except autotune.TuneError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    out = args.out or os.path.join(
        REPO, "kernels", autotune.artifact_name(table.device))
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    table.save(out)
    print(f"[autotune] wrote {out} ({len(table.entries)} entries, device "
          f"{table.device}, schema {autotune.SCHEMA}); activate with "
          f"--tune {out} on any benchmark entry point, Session(tune=...), "
          f"or {autotune.ENV_VAR}={out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
