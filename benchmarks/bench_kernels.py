"""Kernel micro-benchmarks: segmented matmul (XLA path timed on CPU; the
Pallas path is the TPU target, validated in interpret mode) + bit-level
multiplier throughput + SSD scan.

All timing goes through ``benchmarks.harness`` (warmup excluded, every
iteration synced, median-of-k).  The ``seg_matmul_pN_vs_exact`` ratios are
the hardware-portable gate metrics of the perf trajectory; absolute µs are
informational (see docs/benchmarks.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

try:
    from .harness import BenchReport, module_main
except ImportError:  # run as a script: python benchmarks/<module>.py
    import os
    import sys

    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    from benchmarks.harness import BenchReport, module_main
from repro.core.afpm import AFPMConfig
from repro.core.numerics import segmented_matmul_xla
from repro.kernels import autotune, dispatch, ops



def run(report: BenchReport | None = None):
    report = report if report is not None else BenchReport()
    print("\n== kernel micro-benchmarks (CPU host; Pallas = TPU target) ==")
    rng = np.random.default_rng(0)
    M = K = N = 256 if report.fast else 512
    x = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
    dims = f"{M}x{K}x{N}"

    exact = jax.jit(lambda a, b: a @ b)
    us_exact = report.record("kern_exact_matmul", exact, x, w,
                             derived={"dims": dims}).median_us
    print(f"{'exact fp32 ' + dims:28s} {us_exact:10.1f} us")

    for p in (1, 2, 3):
        f = jax.jit(lambda a, b, p=p: segmented_matmul_xla(a, b, p))
        us = report.record(f"kern_seg_matmul_p{p}", f, x, w,
                           derived={"dims": dims}).median_us
        ratio = us / us_exact
        print(f"{'segmented matmul passes=' + str(p):28s} {us:10.1f} us "
              f"({ratio:.2f}x exact)")
        # the stable, hardware-portable gate metric: overhead vs the exact
        # matmul measured in the same process on the same operands
        report.add(f"kern_seg_matmul_p{p}_vs_exact", ratio, "ratio",
                   derived={"dims": dims})

    n_elems = 1 << (14 if report.fast else 16)
    xe = jnp.asarray(rng.standard_normal(n_elems), jnp.float32)
    ye = jnp.asarray(rng.standard_normal(n_elems), jnp.float32)
    for label, cfg in [("AC5-5", AFPMConfig(n=5)), ("ACL5", AFPMConfig(n=5, mode="acl"))]:
        f = jax.jit(lambda a, b, c=cfg: ops.afpm_multiply(a, b, c, backend="xla"))
        us = report.record(f"kern_bitlevel_{label}", f, xe, ye,
                           derived={"n_elems": n_elems}).median_us
        rate = n_elems / (us / 1e6) / 1e6
        print(f"{'bitlevel ' + label + f' {n_elems} elems':28s} {us:10.1f} us "
              f"({rate:.0f} Mmul/s)")

    L, H, P, Nst = (512 if report.fast else 1024), 4, 32, 16
    xs = jnp.asarray(rng.standard_normal((L, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (L, H)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2, (H,)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((L, Nst)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((L, Nst)), jnp.float32)
    f = jax.jit(lambda *a: ops.ssd_scan(*a, backend="xla"))
    us = report.record("kern_ssd_scan", f, xs, dt, A, B, C,
                       derived={"L": L, "H": H, "P": P}).median_us
    print(f"{'ssd_scan %dx%dx%d (chunked)' % (L, H, P):28s} {us:10.1f} us")

    # autotuner probe: the default-chunk path (tuned table first, static
    # fallback — what production callers get) vs the static-table chunk
    # forced explicitly.  With a tuned artifact active the ratio asserts
    # the measured winner is no slower than the guessed tile; with none,
    # both sides are the same chunk and the ratio pins near 1.
    chunk_tuned = dispatch.scan_chunk("xla", L)
    chunk_static = dispatch.SCAN_CHUNKS[("xla", dispatch.shape_bucket(L))]
    f_static = jax.jit(
        lambda *a: ops.ssd_scan(*a, chunk=chunk_static, backend="xla"))
    us_static = report.record(
        "kern_ssd_scan_static_chunk", f_static, xs, dt, A, B, C,
        derived={"chunk": chunk_static}).median_us
    ratio = us / us_static
    report.add("autotuned_vs_static", ratio, "ratio",
               derived={"kernel": "ssd", "backend": "xla",
                        "chunk_tuned": chunk_tuned,
                        "chunk_static": chunk_static,
                        "tune": autotune.active_source()})
    print(f"{'autotuned vs static (ssd)':28s} {ratio:10.2f} x "
          f"(chunk {chunk_tuned} vs {chunk_static})")
    return report


if __name__ == "__main__":
    module_main(run)
