"""Kernel micro-benchmarks: segmented matmul (XLA path timed on CPU; the
Pallas path is the TPU target, validated in interpret mode) + bit-level
multiplier throughput + SSD scan."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.afpm import AFPMConfig
from repro.core.numerics import segmented_matmul_xla
from repro.kernels import ops


def _time(fn, *args, iters=5):
    jax.block_until_ready(fn(*args))  # one warmup call (compile excluded)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run(csv_rows=None):
    print("\n== kernel micro-benchmarks (CPU host; Pallas = TPU target) ==")
    rng = np.random.default_rng(0)
    M = K = N = 512
    x = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)

    exact = jax.jit(lambda a, b: a @ b)
    us_exact = _time(exact, x, w)
    print(f"{'exact fp32 512^3':28s} {us_exact:10.1f} us")
    if csv_rows is not None:
        csv_rows.append(("kern_exact_matmul", us_exact, "512x512x512"))

    for p in (1, 2, 3):
        f = jax.jit(lambda a, b, p=p: segmented_matmul_xla(a, b, p))
        us = _time(f, x, w)
        print(f"{'segmented matmul passes=' + str(p):28s} {us:10.1f} us "
              f"({us / us_exact:.2f}x exact)")
        if csv_rows is not None:
            csv_rows.append((f"kern_seg_matmul_p{p}", us, f"ratio={us/us_exact:.2f}"))

    xe = jnp.asarray(rng.standard_normal(1 << 16), jnp.float32)
    ye = jnp.asarray(rng.standard_normal(1 << 16), jnp.float32)
    for label, cfg in [("AC5-5", AFPMConfig(n=5)), ("ACL5", AFPMConfig(n=5, mode="acl"))]:
        f = jax.jit(lambda a, b, c=cfg: ops.afpm_multiply(a, b, c, backend="xla"))
        us = _time(f, xe, ye)
        rate = (1 << 16) / (us / 1e6) / 1e6
        print(f"{'bitlevel ' + label + ' 65536 elems':28s} {us:10.1f} us "
              f"({rate:.0f} Mmul/s)")
        if csv_rows is not None:
            csv_rows.append((f"kern_bitlevel_{label}", us, f"Mmul_s={rate:.0f}"))

    L, H, P, Nst = 1024, 4, 32, 16
    xs = jnp.asarray(rng.standard_normal((L, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (L, H)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2, (H,)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((L, Nst)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((L, Nst)), jnp.float32)
    f = jax.jit(lambda *a: ops.ssd_scan(*a, backend="xla"))
    us = _time(f, xs, dt, A, B, C)
    print(f"{'ssd_scan 1024x4x32 (chunked)':28s} {us:10.1f} us")
    if csv_rows is not None:
        csv_rows.append(("kern_ssd_scan", us, f"L={L}"))


if __name__ == "__main__":
    run()
