"""Serving-engine benchmark: continuous batching vs solo generation.

Times one identical workload (N requests, same prompts/lengths) two ways
on the same resident weights in the same process:

- **solo** — sequential per-request ``Session.generate`` (batch 1), the
  no-batching baseline every request's bits are defined by;
- **serving** — the continuous-batching :class:`repro.serving.Engine`
  (one tier, N requests over fewer KV slots, mid-decode joins and
  per-step retirement).

The gate metric is their co-measured ratio ``serving_vs_solo_generate``
(engine time / solo time, < 1 means batching wins) — hardware-portable,
so it rides in ``GATED_UNITS`` like the kernel ratios.  Per-tier
throughput of the SLA ladder (exact premium vs segmented bulk) is
informational (``tok/s`` varies with the host) and carries each tier's
modeled area/power (``Session.ppa_report``) in ``derived``, tying the
serving artifact back to the paper's PPA tables.

The paged-KV accounting metrics gate too, but they are deterministic
scheduling outputs (page counts under a fixed workload), not timings:

- ``serving_pages_per_request`` — mean KV pages reserved per retired
  request on a mixed short/long workload;
- ``serving_kv_reservation_vs_maxlen`` — that reservation as a fraction
  of the whole-``max_len`` slot the pre-paging pool would have pinned
  (the acceptance bar is a >= 4x shrink, i.e. a value <= 0.25);
- ``serving_longprompt_decode_stall`` — decode steps starved while a
  longer-than-``prefill_chunk`` prompt prefilled in pieces, per decode
  step (chunked prefill interleaves, so this must stay 0).
"""
from __future__ import annotations

import numpy as np

try:
    from .harness import BenchReport, measure, module_main
except ImportError:  # run as a script: python benchmarks/<module>.py
    import os
    import sys

    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    from benchmarks.harness import BenchReport, measure, module_main
from repro.session import Session
from repro.serving import TierSpec


def _workload(session, n_requests: int, prompt_len: int):
    rng = np.random.default_rng(0)
    return [rng.integers(0, session.config.vocab, prompt_len)
            for _ in range(n_requests)]


def run(report: BenchReport | None = None):
    report = report if report is not None else BenchReport()
    print("\n== serving engine (continuous batching, accuracy tiers) ==")
    n_requests = 4 if report.fast else 8
    prompt_len = 8
    gen_len = 8 if report.fast else 16
    slots = 2 if report.fast else 4
    sess = Session("qwen3-4b")
    prompts = _workload(sess, n_requests, prompt_len)
    wl = {"n_requests": n_requests, "prompt_len": prompt_len,
          "gen_len": gen_len, "slots": slots}
    n_tokens = n_requests * gen_len

    def solo():
        return [sess.generate(prompts=p[None], gen_len=gen_len).tokens
                for p in prompts]

    eng = sess.serving_engine((TierSpec("serve", "exact"),), slots=slots,
                              max_len=prompt_len + gen_len)

    def serving():
        reqs = [eng.submit(p, tier="serve", max_new_tokens=gen_len)
                for p in prompts]
        eng.run()
        return [r.result() for r in reqs]

    m_solo = measure(solo, iters=report.default_iters)
    m_srv = measure(serving, iters=report.default_iters)
    report.add("serving_solo_generate", m_solo.median_us, "us",
               derived=dict(wl), meta=m_solo.stats())
    report.add("serving_engine_run", m_srv.median_us, "us",
               derived=dict(wl), meta=m_srv.stats())
    ratio = m_srv.median_us / m_solo.median_us
    # the stable, hardware-portable gate metric: both sides timed in the
    # same process on the same weights and prompts
    report.add("serving_vs_solo_generate", ratio, "ratio", derived=dict(wl))
    print(f"{'solo generate x' + str(n_requests):28s} "
          f"{m_solo.median_us:10.1f} us")
    print(f"{'continuous batching':28s} {m_srv.median_us:10.1f} us "
          f"({ratio:.2f}x solo, {n_tokens / m_srv.median_us * 1e6:.1f} "
          f"tok/s)")

    # per-tier throughput of the SLA ladder: informational tok/s, with the
    # tier's modeled PPA in derived (never gated — see docs/benchmarks.md)
    for tier, policy in (("premium", "exact"), ("bulk", "segmented1")):
        teng = sess.serving_engine((TierSpec(tier, policy),), slots=slots,
                                   max_len=prompt_len + gen_len)

        def tier_run(te=teng, name=tier):
            reqs = [te.submit(p, tier=name, max_new_tokens=gen_len)
                    for p in prompts]
            te.run()
            return [r.result() for r in reqs]

        m = measure(tier_run, iters=report.default_iters)
        tok_s = n_tokens / m.median_us * 1e6
        ppa = sess.replace(policy=policy).ppa_report()
        report.add(f"serving_{tier}_tok_s", tok_s, "tok/s",
                   derived=dict(wl, policy=policy,
                                area_um2=round(ppa["area_um2"], 1),
                                power_w=round(ppa["power_w"], 4),
                                area_reduction=round(ppa["area_reduction"],
                                                     4)),
                   meta=m.stats())
        print(f"{'tier ' + tier + ' (' + policy + ')':28s} "
              f"{tok_s:10.1f} tok/s (area {ppa['area_um2']:,.0f} um^2, "
              f"{ppa['power_w']:.3f} W modeled)")

    # paged-KV accounting: deterministic scheduling metrics on a mixed
    # short/long workload against a deliberately large max_len tier —
    # exactly the regime where whole-slot pooling wasted KV.  The long
    # prompt exceeds prefill_chunk, so its prefill runs in pieces
    # interleaved with the short requests' decode.
    big_len, page_size, chunk = 128, 16, 8
    prng = np.random.default_rng(1)
    paged_prompts = [prng.integers(0, sess.config.vocab, 5)
                     for _ in range(4)]
    paged_prompts.append(prng.integers(0, sess.config.vocab, 24))
    peng = sess.serving_engine((TierSpec("paged", "exact"),), slots=slots,
                               max_len=big_len, page_size=page_size,
                               prefill_chunk=chunk)
    for p in paged_prompts:
        peng.submit(p, tier="paged", max_new_tokens=8)
    peng.run()
    s = peng.lane_stats()["paged"]
    ppr = s.pages_per_request
    reservation = ppr * page_size / big_len
    stall = s.n_decode_stall_steps / max(1, s.n_decode_steps)
    pwl = dict(n_requests=len(paged_prompts), short_len=5, long_len=24,
               gen_len=8, max_len=big_len, page_size=page_size,
               prefill_chunk=chunk, slots=slots,
               n_prefill_chunks=s.n_prefill_chunks,
               n_interleave_steps=s.n_interleave_steps)
    report.add("serving_pages_per_request", ppr, "ratio", derived=dict(pwl))
    report.add("serving_kv_reservation_vs_maxlen", reservation, "ratio",
               derived=dict(pwl))
    report.add("serving_longprompt_decode_stall", stall, "ratio",
               derived=dict(pwl))
    print(f"{'paged KV (mixed workload)':28s} {ppr:10.2f} pages/request "
          f"({reservation:.3f} of a max_len={big_len} slot, "
          f"{s.n_decode_stall_steps} decode stalls)")
    return report


if __name__ == "__main__":
    module_main(run)
