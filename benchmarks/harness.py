"""Shared timing core for every benchmark module (the perf trajectory).

One measurement contract, used by all of ``bench_kernels`` /
``table2_ppa`` / ``table3_image`` / ``table4_resnet`` / ``roofline``
instead of five hand-rolled ``time.perf_counter()`` loops:

- warmup calls (compilation) run first and are excluded from timing;
- every timed iteration is synced with ``jax.block_until_ready`` on the
  result pytree, so async dispatch can never be timed as "done";
- the reported value is the median of k iterations, with dispersion
  (IQR, min, max) kept alongside so noisy runs are visible in the
  artifact instead of silently averaged away.

:class:`BenchReport` collects named metrics as ``{value, unit, derived,
meta}`` entries plus device/backend/jax-version metadata and serializes
them to the versioned ``BENCH_*.json`` schema that
``tools/check_bench.py`` diffs against the committed trajectory (see
``docs/benchmarks.md`` for the schema and tolerance-band policy).
"""
from __future__ import annotations

import dataclasses
import json
import os
import platform
import socket
import statistics
import time

import jax

#: Versioned schema tag written into every artifact; ``tools/check_bench.py``
#: refuses to compare artifacts whose tag does not match its own.
SCHEMA = "repro-bench/1"

#: Units whose values are stable across hosts (ratios of co-measured
#: timings, deterministic model outputs, accuracy metrics) — these gate
#: the perf trajectory.  Everything else ("us", "Mmul/s", ...) is
#: informational: recorded, diffed, but never a CI failure on shared CPU
#: runners.  Tolerances are relative bands; per-metric overrides live in
#: ``tools/check_bench.py``.
GATED_UNITS = {
    "ratio": 0.50,     # timing ratios (e.g. seg_matmul_pN / exact)
    "dB": 0.05,        # PSNR accuracy metrics
    "um2": 0.005,      # analytical PPA model outputs (deterministic)
    "W": 0.005,
    "percent": 0.25,   # model-vs-paper deviation summaries
}


@dataclasses.dataclass(frozen=True)
class Measurement:
    """Median-of-k wall-clock timing with dispersion."""

    median_us: float
    iqr_us: float
    min_us: float
    max_us: float
    iters: int
    warmup: int

    @property
    def rel_iqr(self) -> float:
        return self.iqr_us / self.median_us if self.median_us else 0.0

    def stats(self) -> dict:
        return dataclasses.asdict(self)


def measure(fn, *args, iters: int = 5, warmup: int = 1) -> Measurement:
    """Time ``fn(*args)``: ``warmup`` untimed calls, then ``iters`` timed
    iterations, each synced through ``jax.block_until_ready`` (which walks
    the result pytree and passes non-array leaves through untouched)."""
    if iters < 1:
        raise ValueError(f"iters must be >= 1, got {iters}")
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append((time.perf_counter() - t0) * 1e6)
    samples.sort()
    if len(samples) >= 2:
        q = statistics.quantiles(samples, n=4)
        iqr = q[2] - q[0]
    else:
        iqr = 0.0
    return Measurement(median_us=statistics.median(samples), iqr_us=iqr,
                       min_us=samples[0], max_us=samples[-1],
                       iters=iters, warmup=warmup)


def environment_meta() -> dict:
    """Host/device/version context stamped into every artifact."""
    devices = jax.devices()
    return {
        "host": socket.gethostname(),
        "platform": platform.platform(),
        "python": platform.python_version(),
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "device": devices[0].device_kind if devices else "none",
        "device_count": len(devices),
        "cpu_count": os.cpu_count(),
    }


class BenchReport:
    """Collector every benchmark module writes into.

    ``fast`` trims iteration counts (and lets modules trim problem sizes)
    for the CI subset; the artifact records which mode produced it so a
    fast run is never diffed against a full baseline unnoticed.
    """

    def __init__(self, *, fast: bool = False, iters: int | None = None):
        from repro.kernels import autotune

        self.fast = fast
        self.default_iters = iters if iters is not None else (3 if fast else 5)
        self.meta = environment_meta()
        self.meta["fast"] = fast
        # which measured tuning artifact (if any) shaped the kernel block
        # sizes behind these numbers — None means the static tables
        self.meta["tune"] = autotune.active_source()
        self.metrics: dict[str, dict] = {}

    def add(self, name: str, value: float, unit: str, *,
            derived: dict | None = None, meta: dict | None = None) -> None:
        if name in self.metrics:
            raise ValueError(f"duplicate metric {name!r}")
        self.metrics[name] = {
            "value": float(value),
            "unit": unit,
            "derived": derived or {},
            "meta": meta or {},
        }

    def record(self, name: str, fn, *args, derived: dict | None = None,
               iters: int | None = None, warmup: int = 1) -> Measurement:
        """Measure ``fn(*args)`` and add it as a ``us`` metric."""
        m = measure(fn, *args, iters=iters or self.default_iters,
                    warmup=warmup)
        self.add(name, m.median_us, "us", derived=derived, meta=m.stats())
        return m

    def to_dict(self) -> dict:
        return {"schema": SCHEMA, "meta": self.meta, "metrics": self.metrics}

    def write(self, path: str) -> None:
        """Atomic artifact write (temp file + ``os.replace``): an
        interrupted run must never leave a truncated ``BENCH_*.json``
        behind for ``check_bench`` to trip over."""
        path = os.fspath(path)
        tmp = f"{path}.tmp.{os.getpid()}"
        try:
            with open(tmp, "w") as f:
                json.dump(self.to_dict(), f, indent=1, sort_keys=True)
                f.write("\n")
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)

    def csv_rows(self):
        """Legacy ``name,value,derived`` summary rows (stdout contract)."""
        for name, m in self.metrics.items():
            derived = ";".join(f"{k}={v}" for k, v in m["derived"].items())
            yield name, m["value"], m["unit"], derived


def activate_tuning(path: str | None = None):
    """Activate a measured kernel-tuning artifact for this process (the
    shared ``--tune`` knob of every benchmark entry point).  ``None``
    falls back to the ``REPRO_TUNE_FILE`` env var; with neither, the
    static dispatch tables stay in effect.  Returns the active table (or
    None) so callers can report what they run under."""
    from repro.kernels import autotune

    return autotune.activate(path)


def module_main(run_fn, argv=None, **fixed_kwargs):
    """Shared standalone entry point for the benchmark modules: every
    ``python -m benchmarks.<module>`` accepts the same ``--fast`` /
    ``--iters`` / ``--tune`` defaults as the full driver, so a single
    section can be re-measured under exactly the conditions CI uses."""
    import argparse

    ap = argparse.ArgumentParser(description=run_fn.__module__)
    ap.add_argument("--fast", action="store_true",
                    help="CI subset: fewer timing iterations / smaller sizes")
    ap.add_argument("--iters", type=int, default=None,
                    help="override the per-metric timing iteration count")
    ap.add_argument("--tune", default=None, metavar="TUNE_JSON",
                    help="measured kernel-tuning artifact to activate "
                         "(default: REPRO_TUNE_FILE env var, else the "
                         "static tables)")
    args = ap.parse_args(argv)
    activate_tuning(args.tune)
    report = BenchReport(fast=args.fast, iters=args.iters)
    run_fn(report, **fixed_kwargs)
    return report
