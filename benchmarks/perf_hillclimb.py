"""§Perf hillclimbing harness: lower a cell under a modified config, record
the roofline deltas, and append the iteration to the experiment log.

Each experiment = (cell, hypothesis, config transform).  Results land in
benchmarks/artifacts/perf/<cell>__<tag>.json so EXPERIMENTS.md §Perf can
show the full hypothesis -> change -> before/after chain.

Run single experiments (each is a fresh process — 512 fake devices):
  PYTHONPATH=src python -m benchmarks.perf_hillclimb --exp qwen3_zero_dp

``--policy policy.json`` additionally applies an auto-configured
per-layer NumericsPolicy (``python -m repro.session auto-configure
--out policy.json``, or ``benchmarks/table4_resnet.py --auto``) on top
of the experiment's config transform — the plumbing that lets a
budget-fitted policy's roofline be hillclimbed like any other config
change.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse     # noqa: E402
import dataclasses  # noqa: E402
import json         # noqa: E402

import jax          # noqa: E402

ART = os.path.join(os.path.dirname(__file__), "artifacts", "perf")


def _zero_dp(cfg):
    """Pure ZeRO-DP: batch over ALL 256/512 chips, weights ZeRO-sharded over
    the full mesh, no TP/SP — the right regime for multi-B-param models
    where activation volume >> weight volume."""
    full = ("pod", "data", "model")
    return dataclasses.replace(
        cfg,
        seq_shard_activations=False,
        fsdp=False,
        loss_batch_chunks=1,  # chunking breaks batch-sharding over 256 chips
        sharding_overrides=(
            ("batch", full), ("embed", full), ("embed_table", None),
            ("mlp", None), ("heads", None), ("q_dim", None), ("kv_dim", None),
            ("seq", None), ("vocab", "model"), ("kv_seq", None),
        ),
    )


def _zero_dp_vocab_full(cfg):
    """zero_dp + unembed table sharded over the full mesh on d_model."""
    full = ("pod", "data", "model")
    return dataclasses.replace(
        _zero_dp(cfg),
        sharding_overrides=_zero_dp(cfg).sharding_overrides[:-2]
        + (("vocab", "model"), ("kv_seq", None), ("embed_table", full)),
    )


def _bf16_numerics(cfg):
    """Paper numerics at scale: segmented split-float matmuls (3 MXU passes,
    BD term dropped) instead of exact fp32-accum bf16 dots."""
    from repro.core.numerics import NumericsConfig

    return dataclasses.replace(
        cfg, numerics=NumericsConfig(mode="segmented", seg_passes=3,
                                     backend="xla"))


def _moe_ep_data(cfg):
    """Experts sharded over 'data' instead of 'model' for train (toward
    cluster-wide EP), keeping TP for attention."""
    return dataclasses.replace(
        cfg, sharding_overrides=(("experts", ("pod", "data")),))


def _accum16(cfg):
    return dataclasses.replace(cfg, grad_accum=16)


def _no_sp(cfg):
    return dataclasses.replace(cfg, seq_shard_activations=False)


def _decode_batch_full(cfg):
    """Decode: shard batch over the full mesh, replicate kv heads; cache
    stays unsharded on seq (no LSE-combine collectives)."""
    full = ("pod", "data", "model")
    return dataclasses.replace(
        cfg, sharding_overrides=(("batch", full), ("kv_seq", None),
                                 ("heads", None)))


EXPERIMENTS = {
    # -- pair 1: qwen3-4b train_4k (paper-representative dense LM train) ----
    "qwen3_base": ("qwen3-4b", "train_4k", None,
                   "BASELINE (paper-faithful): TP over model + SP on residual"),
    "qwen3_zero_dp": ("qwen3-4b", "train_4k", _zero_dp,
                      "H1: activation gather/scatter churn from TP+SP dominates a "
                      "4B model; ZeRO-DP over all 256 chips cuts collective bytes "
                      "~20x (weights 8GB vs activations 300GB moved per step)"),
    "qwen3_zero_dp_seg": ("qwen3-4b", "train_4k",
                          lambda c: _bf16_numerics(_zero_dp(c)),
                          "H2 (beyond-paper): + segmented 3-pass numerics drops "
                          "the BD term -> ~0.9x dot flops vs exact-fp32-accum"),
    "qwen3_no_sp": ("qwen3-4b", "train_4k", _no_sp,
                    "H3 (ablation): TP without SP — fewer reshards but "
                    "activations unsharded on seq (memory regression expected)"),
    # -- pair 2: deepseek-v3 train_4k (most collective-bound) ---------------
    "ds_base": ("deepseek-v3-671b", "train_4k", None,
                "BASELINE: TP+EP(model)+fsdp(data)+SP"),
    "ds_accum16": ("deepseek-v3-671b", "train_4k", _accum16,
                   "H1: halving microbatch halves MoE dispatch slab peak and "
                   "its replicated-gather traffic"),
    "ds_ep_data": ("deepseek-v3-671b", "train_4k", _moe_ep_data,
                   "H2: experts over 'data' (16-way EP on the other axis) — "
                   "dispatch all-to-all crosses data instead of colliding with "
                   "TP collectives on 'model'"),
    "ds_shardmap_accum2": ("deepseek-v3-671b", "train_4k",
                           lambda c: dataclasses.replace(c, grad_accum=2),
                           "H4: with shard_map EP the dispatch slab no longer "
                           "replicates, so fewer microbatches (8->2) cut the "
                           "per-micro ZeRO weight re-gathers 4x at ~3 GiB "
                           "activation cost"),
    # -- pair 3: qwen2-vl-72b decode_32k (worst meaningful roofline) --------
    "vl_decode_base": ("qwen2-vl-72b", "decode_32k", None,
                       "BASELINE: batch over data, kv cache seq-sharded over "
                       "model (flash-decode LSE combine)"),
    "vl_decode_batch_full": ("qwen2-vl-72b", "decode_32k", _decode_batch_full,
                             "H1: decode is HBM-bound on cache reads; sharding "
                             "batch over all chips (128 B over 256) fails "
                             "divisibility -> expect fallback/regression (test "
                             "the divisibility-fallback honesty)"),
}


def run_experiment(tag: str, policy_path: str | None = None):
    from repro.configs import get_arch
    from repro.launch import dryrun
    from repro.session import Session, load_policy

    arch, shape, transform, hypothesis = EXPERIMENTS[tag]
    cfg = get_arch(arch)
    if transform is not None:
        cfg = transform(cfg)
    if policy_path is not None:
        # serve an auto-configured per-layer policy in this cell (the
        # sweep's output plugged straight into the roofline harness)
        cfg = dataclasses.replace(cfg, numerics=load_policy(policy_path))
        hypothesis += f" [+ per-layer policy {policy_path}]"
    # a Session over the transformed full-size config IS the experiment
    # spec — no get_arch monkeypatching needed
    rec = dryrun.lower_session_cell(Session(cfg), shape, multi_pod=False)
    rec["tag"] = tag
    rec["hypothesis"] = hypothesis
    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, f"{tag}.json"), "w") as f:
        json.dump(rec, f, indent=1)
    r = rec.get("roofline", {})
    print(f"[perf] {tag}: {rec['status']} "
          f"t_c={r.get('t_compute_s', 0):.2f} t_m={r.get('t_memory_s', 0):.2f} "
          f"t_x={r.get('t_collective_s', 0):.2f} dom={r.get('dominant')} "
          f"frac={r.get('roofline_fraction', 0):.4f} "
          f"mem={rec.get('memory', {}).get('peak_estimate_bytes', 0)/2**30:.1f}GiB")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--exp", required=True, choices=sorted(EXPERIMENTS))
    ap.add_argument("--policy", default=None, metavar="POLICY_JSON",
                    help="apply an auto-configured NumericsPolicy on top of "
                         "the experiment's config transform")
    args = ap.parse_args()
    run_experiment(args.exp, policy_path=args.policy)


if __name__ == "__main__":
    main()
