"""Real-weights accuracy: loaded checkpoints -> measured error per policy.

The compat loop-closer: instead of seeded random init, every session here
comes out of ``Session.from_pretrained`` on an actual safetensors
checkpoint (the committed golden fixtures under ``tests/golden/compat/``
— tiny but real files, sharded for qwen3 — so CI is hermetic; point
``REPRO_REAL_CHECKPOINT_<FAMILY>`` at a downloaded checkpoint to run the
same loop full-size).  For each family the benchmark measures, on the
*loaded* weights:

- task-level degradation per accuracy preset versus the exact baseline —
  perplexity ratio for qwen3, teacher-forced greedy token disagreement
  for whisper, top-1 label flips for ResNet — alongside the raw logits
  MRED the paper's error model speaks in;
- the ``auto_configure`` loop end to end: the proxy model's
  ``predicted_error`` versus the *measured* MRED of the adopted policy on
  the same calibration batch, plus the modeled area reduction it bought.

All values are deterministic model outputs (no wall clock), so every
metric gates the trajectory via ``tools/check_bench.py`` ("percent" /
"ratio" units — see ``benchmarks.harness.GATED_UNITS``).
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np

try:
    from .harness import BenchReport, module_main
except ImportError:  # run as a script: python benchmarks/<module>.py
    import sys

    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    from benchmarks.harness import BenchReport, module_main

#: The committed tiny-but-real fixture checkpoints (tests/golden/compat/
#: README-less by design: regenerate with tests/golden/gen_compat_golden.py).
GOLDEN = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                      "tests", "golden", "compat")

#: Accuracy ladder measured against the exact baseline, worst-first so the
#: printed table reads as a degradation curve.
POLICIES = ("segmented1", "segmented2", "segmented3")

#: Per-family proxy error budget for the auto_configure loop (MRED of the
#: calibration logits; same scale Session.auto_configure optimizes).
BUDGETS = {"qwen3-4b": 0.05, "whisper-tiny": 0.05, "resnet18": 0.05}


def _policy_cfg(name):
    from repro.session import _PRESETS

    return _PRESETS[name]


def _lm_eval(sess, seq_len: int):
    """Teacher-forced eval closure for a loaded LM session: returns
    ``(logits_fn(policy), targets)`` on a seeded token batch (plus seeded
    encoder embeddings when the arch has an encoder)."""
    import jax.numpy as jnp

    from repro.models import transformer

    cfg = sess.config
    rng = np.random.default_rng(0)
    tokens = rng.integers(0, cfg.vocab, (2, seq_len))
    batch = {"tokens": jnp.asarray(tokens, jnp.int32)}
    if cfg.encoder_layers:
        enc_len = min(cfg.enc_len, seq_len)
        batch["enc_embeds"] = jnp.asarray(
            rng.standard_normal((2, enc_len, cfg.d_model)), jnp.float32)

    def logits(numerics):
        pcfg = dataclasses.replace(cfg, numerics=numerics) \
            if numerics is not None else cfg
        h, _, _ = transformer.backbone(sess.params, pcfg, batch, mode="train")
        return np.asarray(transformer.logits_fn(sess.params, pcfg, h),
                          np.float64)

    targets = tokens[:, 1:]  # next-token teacher forcing
    return logits, targets


def _xent(logits: np.ndarray, targets: np.ndarray) -> float:
    """Mean next-token cross-entropy (nats) of ``logits[:, :-1]`` against
    ``targets`` — the perplexity exponent."""
    lp = logits[:, :-1] - logits[:, :-1].max(-1, keepdims=True)
    lse = np.log(np.exp(lp).sum(-1))
    picked = np.take_along_axis(lp, targets[..., None], axis=-1)[..., 0]
    return float((lse - picked).mean())


def _autoconf_metrics(report, tag: str, sess, family: str, measured_fn,
                      calib=None):
    """Run the proxy auto_configure loop on the loaded weights and report
    predicted vs measured error for the adopted policy."""
    res = sess.auto_configure(BUDGETS[family], calib=calib)
    measured = measured_fn(res.policy)
    predicted = res.predicted_error if res.predicted_error else res.error
    report.add(f"real_{tag}_autoconf_predicted_mred", 100.0 * predicted,
               "percent", derived={"budget": BUDGETS[family],
                                   "n_evals": res.n_evals})
    report.add(f"real_{tag}_autoconf_measured_mred", 100.0 * measured,
               "percent", derived={"method": res.method})
    # the proxy's promise: predictions upper-bound (approximately) the
    # measured error — the ratio is the trajectory's drift detector
    report.add(f"real_{tag}_autoconf_measured_vs_predicted",
               measured / predicted if predicted else 0.0, "ratio")
    report.add(f"real_{tag}_autoconf_area_reduction",
               100.0 * res.area_reduction, "percent",
               derived={"assignments": len(res.assignments)})
    print(f"  auto_configure: predicted {predicted:.3e} measured "
          f"{measured:.3e} mred, area -{100 * res.area_reduction:.1f}% "
          f"({res.n_evals} evals)")
    return res


def _run_lm(report, family: str, tag: str, seq_len: int):
    from repro.core.metrics import mred
    from repro.session import Session

    sess = Session.from_pretrained(family, os.path.join(GOLDEN, family))
    logits, targets = _lm_eval(sess, seq_len)
    ref = logits(None)
    ref_xent = _xent(ref, targets)
    ref_tok = ref.argmax(-1)
    print(f"\n-- {family} (loaded from fixture checkpoint) --")
    for pol in POLICIES:
        got = logits(_policy_cfg(pol))
        m = mred(got, ref)
        ppl_ratio = float(np.exp(_xent(got, targets) - ref_xent))
        disagree = 100.0 * float((got.argmax(-1) != ref_tok).mean())
        report.add(f"real_{tag}_{pol}_mred", 100.0 * m, "percent",
                   derived={"seq_len": seq_len})
        if tag == "qwen3":
            report.add(f"real_{tag}_{pol}_ppl_ratio", ppl_ratio, "ratio")
        else:
            report.add(f"real_{tag}_{pol}_tok_disagree", disagree, "percent")
        print(f"  {pol}: mred {m:.3e}  ppl-ratio {ppl_ratio:.4f}  "
              f"greedy-disagree {disagree:.2f}%")
    _autoconf_metrics(report, tag, sess, family,
                      lambda policy: mred(logits(policy), ref))


def _run_resnet(report, size: int):
    import jax.numpy as jnp

    from repro.core.metrics import mred
    from repro.models import resnet
    from repro.session import Session

    sess = Session.from_pretrained("resnet18", os.path.join(GOLDEN, "resnet18"))
    cfg = sess.config
    rng = np.random.default_rng(0)
    images = jnp.asarray(rng.standard_normal((4, size, size, 3)), jnp.float32)

    def logits(numerics):
        acfg = dataclasses.replace(cfg, numerics=numerics) \
            if numerics is not None else cfg
        out, _ = resnet.apply(sess.params, sess._state, images, acfg,
                              train=False)
        return np.asarray(out, np.float64)

    ref = logits(None)
    ref_top1 = ref.argmax(-1)
    print("\n-- resnet18 (loaded from fixture checkpoint) --")
    for pol in POLICIES:
        got = logits(_policy_cfg(pol))
        m = mred(got, ref)
        flips = 100.0 * float((got.argmax(-1) != ref_top1).mean())
        report.add(f"real_resnet_{pol}_mred", 100.0 * m, "percent",
                   derived={"size": size})
        report.add(f"real_resnet_{pol}_top1_mismatch", flips, "percent")
        print(f"  {pol}: mred {m:.3e}  top1-mismatch {flips:.1f}%")
    _autoconf_metrics(report, "resnet", sess, "resnet18",
                      lambda policy: mred(logits(policy), ref),
                      calib=np.asarray(images))


def run(report: BenchReport | None = None):
    report = report if report is not None else BenchReport()
    seq_len = 8 if report.fast else 16
    size = 16 if report.fast else 32
    print("\n== Real-weights accuracy: fixture checkpoints, measured vs "
          "predicted error per policy ==")
    _run_lm(report, "qwen3-4b", "qwen3", seq_len)
    _run_lm(report, "whisper-tiny", "whisper", seq_len)
    _run_resnet(report, size)


if __name__ == "__main__":
    module_main(run)
