"""Roofline report: aggregates the dry-run artifacts into the §Roofline table.

Reads benchmarks/artifacts/dryrun/*.json (produced by repro.launch.dryrun)
and prints, per (arch x shape x mesh): the three roofline terms, the
dominant bottleneck, MODEL_FLOPS/HLO_FLOPS, and the roofline fraction.
Also emits the markdown table used by EXPERIMENTS.md.
"""
from __future__ import annotations

import glob
import json
import os

try:
    from .harness import BenchReport, module_main
except ImportError:  # run as a script: python benchmarks/<module>.py
    import os
    import sys

    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    from benchmarks.harness import BenchReport, module_main

ART = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")


def load_records(mesh: str | None = None):
    recs = []
    for path in sorted(glob.glob(os.path.join(ART, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if mesh and r.get("mesh") != mesh:
            continue
        recs.append(r)
    return recs


def _row(r):
    rf = r.get("roofline", {})
    mem = r.get("memory", {})
    return dict(
        arch=r["arch"], shape=r["shape"], mesh=r["mesh"], status=r["status"],
        t_c=rf.get("t_compute_s", 0.0), t_m=rf.get("t_memory_s", 0.0),
        t_x=rf.get("t_collective_s", 0.0), dom=rf.get("dominant", "-"),
        useful=rf.get("useful_flops_ratio", 0.0),
        frac=rf.get("roofline_fraction", 0.0),
        gib=mem.get("peak_estimate_bytes", 0) / 2 ** 30,
        fits=mem.get("peak_estimate_bytes", 0) <= 16 * 2 ** 30,
    )


def run(report: BenchReport | None = None, mesh: str = "16x16"):
    report = report if report is not None else BenchReport()
    recs = load_records(mesh)
    print(f"\n== Roofline ({mesh}; v5e: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s ICI) ==")
    hdr = (f"{'arch':26s} {'shape':12s} {'stat':6s} {'t_comp':>8s} {'t_mem':>8s} "
           f"{'t_coll':>8s} {'dom':>6s} {'useful':>7s} {'frac':>6s} {'GiB':>6s}")
    print(hdr)
    for r in recs:
        row = _row(r)
        if row["status"] != "ok":
            print(f"{row['arch']:26s} {row['shape']:12s} {row['status'][:20]}")
            continue
        print(f"{row['arch']:26s} {row['shape']:12s} {'ok':6s} "
              f"{row['t_c']:8.3f} {row['t_m']:8.3f} {row['t_x']:8.3f} "
              f"{row['dom'][:6]:>6s} {row['useful']:7.3f} {row['frac']:6.3f} "
              f"{row['gib']:6.1f}")
        # modeled step time from the dry-run artifacts (no live timing
        # here, so the dispersion fields do not apply): informational us,
        # with the dimensionless roofline fraction in derived
        report.add(f"roofline_{mesh}_{row['arch']}_{row['shape']}",
                   max(row['t_c'], row['t_m'], row['t_x']) * 1e6, "us",
                   derived={"dom": row["dom"], "frac": round(row["frac"], 3)})
    return report


def markdown_table(mesh: str = "16x16") -> str:
    recs = load_records(mesh)
    lines = [
        "| arch | shape | status | t_compute (s) | t_memory (s) | t_collective (s) "
        "| dominant | useful FLOPs | roofline frac | GiB/chip | fits 16G |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        row = _row(r)
        if row["status"] != "ok":
            lines.append(f"| {row['arch']} | {row['shape']} | {row['status']} "
                         "| – | – | – | – | – | – | – | – |")
            continue
        lines.append(
            f"| {row['arch']} | {row['shape']} | ok | {row['t_c']:.3f} "
            f"| {row['t_m']:.3f} | {row['t_x']:.3f} | {row['dom']} "
            f"| {row['useful']:.3f} | {row['frac']:.3f} | {row['gib']:.1f} "
            f"| {'yes' if row['fits'] else 'NO'} |")
    return "\n".join(lines)


if __name__ == "__main__":
    rep = module_main(run)  # single-pod mesh, shared --fast/--iters/--tune
    print()
    run(BenchReport(fast=rep.fast, iters=rep.default_iters), mesh="2x16x16")
