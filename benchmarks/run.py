"""Benchmark driver — one section per paper table + kernels + roofline.

Canonical invocation (from the repo root; ``benchmarks/__init__.py`` makes
``src/repro`` importable on its own):

    python -m benchmarks.run [--json [PATH]] [--fast] [--skip-resnet]

``--json`` writes the versioned ``BENCH_*.json`` perf-trajectory artifact
(default path ``BENCH_<host>.json``); ``tools/check_bench.py`` diffs it
against the committed baseline.  A ``name,value,unit,derived`` CSV summary
is printed at the end (legacy stdout contract).
"""
import argparse
import os
import socket
import sys

if __package__ in (None, ""):  # executed as a script: python benchmarks/run.py
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="run the benchmark suite and (optionally) emit the "
                    "BENCH_*.json perf-trajectory artifact")
    ap.add_argument("--skip-resnet", action="store_true",
                    help="skip the (slow) Table IV ResNet benchmark")
    ap.add_argument("--resnet-steps", type=int, default=120)
    ap.add_argument("--fast", action="store_true",
                    help="CI subset: fewer timing iterations and smaller "
                         "problem sizes (recorded in the artifact meta)")
    ap.add_argument("--iters", type=int, default=None,
                    help="override the per-metric timing iteration count")
    ap.add_argument("--json", nargs="?", const="", default=None,
                    metavar="PATH",
                    help="write the BENCH_*.json artifact here "
                         "(default: BENCH_<host>.json in the cwd)")
    ap.add_argument("--tune", default=None, metavar="TUNE_JSON",
                    help="measured kernel-tuning artifact to activate for "
                         "the whole run (kernels/TUNE_<device>.json; "
                         "generate with python -m benchmarks.autotune). "
                         "Default: the REPRO_TUNE_FILE env var if set, "
                         "else the static tuning tables")
    args = ap.parse_args(argv)

    from benchmarks import (bench_kernels, bench_serving, real_accuracy,
                            roofline, table2_ppa, table3_image)
    from benchmarks.harness import BenchReport, activate_tuning

    table = activate_tuning(args.tune)
    if table is not None:
        from repro.kernels import autotune

        print(f"[bench] tuned kernel table active: "
              f"{autotune.active_source()} ({len(table.entries)} entries, "
              f"device {table.device})")
    report = BenchReport(fast=args.fast, iters=args.iters)
    table2_ppa.run(report)
    table3_image.run(report)
    real_accuracy.run(report)
    bench_kernels.run(report)
    roofline.run(report)
    bench_serving.run(report)
    if not args.skip_resnet:
        from benchmarks import table4_resnet

        table4_resnet.run(report, train_steps=args.resnet_steps)

    print("\nname,value,unit,derived")
    for name, value, unit, derived in report.csv_rows():
        print(f"{name},{value:.1f},{unit},{derived}")

    if args.json is not None:
        path = args.json or f"BENCH_{socket.gethostname()}.json"
        report.write(path)
        print(f"\n[bench] wrote {path} ({len(report.metrics)} metrics, "
              f"schema {report.to_dict()['schema']}); gate with: "
              f"python tools/check_bench.py --baseline "
              f"benchmarks/BENCH_cpu_ci.json {path}")


if __name__ == "__main__":
    main()
