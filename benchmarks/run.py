"""Benchmark driver — one section per paper table + kernels + roofline.

Prints ``name,us_per_call,derived`` CSV at the end (harness contract).
"""
import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-resnet", action="store_true",
                    help="skip the (slow) Table IV ResNet benchmark")
    ap.add_argument("--resnet-steps", type=int, default=120)
    args = ap.parse_args()

    csv_rows = []
    from benchmarks import bench_kernels, roofline, table2_ppa, table3_image

    table2_ppa.run(csv_rows)
    table3_image.run(csv_rows)
    bench_kernels.run(csv_rows)
    roofline.run(csv_rows)
    if not args.skip_resnet:
        from benchmarks import table4_resnet

        table4_resnet.run(csv_rows, train_steps=args.resnet_steps)

    print("\nname,us_per_call,derived")
    for name, us, derived in csv_rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
