"""Table II reproduction: post-layout PPA via the calibrated analytical model.

Prints predicted logic area / power / delay per multiplier configuration
next to the paper's published values, with per-row deviation.  The model is
calibrated on TWO rows only (Exact and AC5-5); every other row is a
prediction (see repro/core/ppa.py).
"""
from __future__ import annotations

import time

from repro.core import ppa


def run(csv_rows=None):
    print("\n== Table II: post-layout PPA (64x32 SRAM, analytical model) ==")
    print(f"{'design':8s} {'area um2':>9s} {'paper':>7s} {'err%':>6s} "
          f"{'power W':>9s} {'paper':>9s} {'err%':>6s} {'delay ns':>8s}")
    errs_a, errs_p = [], []
    for name, (kind, kw) in ppa.TABLE2_SPECS.items():
        t0 = time.perf_counter()
        est = ppa.estimate(kind, name=name, **kw)
        dt = (time.perf_counter() - t0) * 1e6
        pa, pp_ = ppa.PAPER_TABLE2_64x32[name]
        ea = 100 * (est.logic_area_um2 - pa) / pa
        ep = 100 * (est.power_w - pp_) / pp_
        errs_a.append(abs(ea))
        errs_p.append(abs(ep))
        print(f"{name:8s} {est.logic_area_um2:9.0f} {pa:7.0f} {ea:6.1f} "
              f"{est.power_w:9.2e} {pp_:9.2e} {ep:6.1f} {est.delay_ns:8.2f}")
        if csv_rows is not None:
            csv_rows.append((f"table2_{name}", dt,
                             f"area={est.logic_area_um2:.0f};power={est.power_w:.3e}"))
    print(f"mean |err|: area {sum(errs_a)/len(errs_a):.1f}%  "
          f"power {sum(errs_p)/len(errs_p):.1f}%")
    # headline claims
    e = ppa.estimate("exact")
    ac44 = ppa.estimate("ac", n=4)
    acl5 = ppa.estimate("acl", n=5)
    print(f"AC4-4 vs exact: area -{100*(1-ac44.logic_area_um2/e.logic_area_um2):.0f}% "
          f"power -{100*(1-ac44.power_w/e.power_w):.0f}%  (paper headline: 69%/72%)")
    print(f"ACL5  vs exact: area -{100*(1-acl5.logic_area_um2/e.logic_area_um2):.0f}% "
          f"power -{100*(1-acl5.power_w/e.power_w):.0f}%  (paper: 78.4%/82.1%)")
    da, dp = ppa.bd_omission_savings(5)
    print(f"BD omission (n=5): area -{100*da:.1f}% power -{100*dp:.1f}% "
          f"(paper: 6.8%/12.6%)")


if __name__ == "__main__":
    run()
