"""Table II reproduction: post-layout PPA via the calibrated analytical model.

Prints predicted logic area / power / delay per multiplier configuration
next to the paper's published values, with per-row deviation.  The model is
calibrated on TWO rows only (Exact and AC5-5); every other row is a
prediction (see repro/core/ppa.py).

Metrics: the model outputs (area/power savings, mean deviation vs paper)
are deterministic and gate the trajectory; the model-evaluation wall-clock
is informational (see docs/benchmarks.md).
"""
from __future__ import annotations

try:
    from .harness import BenchReport, module_main
except ImportError:  # run as a script: python benchmarks/<module>.py
    import os
    import sys

    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    from benchmarks.harness import BenchReport, module_main
from repro.core import ppa



def run(report: BenchReport | None = None):
    report = report if report is not None else BenchReport()
    print("\n== Table II: post-layout PPA (64x32 SRAM, analytical model) ==")
    print(f"{'design':8s} {'area um2':>9s} {'paper':>7s} {'err%':>6s} "
          f"{'power W':>9s} {'paper':>9s} {'err%':>6s} {'delay ns':>8s}")
    errs_a, errs_p = [], []
    for name, (kind, kw) in ppa.TABLE2_SPECS.items():
        est = ppa.estimate(kind, name=name, **kw)
        pa, pp_ = ppa.PAPER_TABLE2_64x32[name]
        ea = 100 * (est.logic_area_um2 - pa) / pa
        ep = 100 * (est.power_w - pp_) / pp_
        errs_a.append(abs(ea))
        errs_p.append(abs(ep))
        print(f"{name:8s} {est.logic_area_um2:9.0f} {pa:7.0f} {ea:6.1f} "
              f"{est.power_w:9.2e} {pp_:9.2e} {ep:6.1f} {est.delay_ns:8.2f}")
        report.add(f"table2_{name}_area", est.logic_area_um2, "um2",
                   derived={"paper_um2": pa, "err_pct": round(ea, 2)})
        report.add(f"table2_{name}_power", est.power_w, "W",
                   derived={"paper_w": pp_, "err_pct": round(ep, 2)})
    mean_a = sum(errs_a) / len(errs_a)
    mean_p = sum(errs_p) / len(errs_p)
    print(f"mean |err|: area {mean_a:.1f}%  power {mean_p:.1f}%")
    report.add("table2_mean_abs_err_area", mean_a, "percent")
    report.add("table2_mean_abs_err_power", mean_p, "percent")
    # model-evaluation wall clock (informational; one representative design)
    report.record("table2_estimate_call", lambda: ppa.estimate("ac", n=4),
                  derived={"design": "AC4-4"}, warmup=1)
    # headline claims
    e = ppa.estimate("exact")
    ac44 = ppa.estimate("ac", n=4)
    acl5 = ppa.estimate("acl", n=5)
    ac44_a = 1 - ac44.logic_area_um2 / e.logic_area_um2
    ac44_p = 1 - ac44.power_w / e.power_w
    acl5_a = 1 - acl5.logic_area_um2 / e.logic_area_um2
    acl5_p = 1 - acl5.power_w / e.power_w
    print(f"AC4-4 vs exact: area -{100*ac44_a:.0f}% power -{100*ac44_p:.0f}%  "
          f"(paper headline: 69%/72%)")
    print(f"ACL5  vs exact: area -{100*acl5_a:.0f}% power -{100*acl5_p:.0f}%  "
          f"(paper: 78.4%/82.1%)")
    report.add("table2_ac44_area_saving", ac44_a, "ratio",
               derived={"paper": 0.69})
    report.add("table2_ac44_power_saving", ac44_p, "ratio",
               derived={"paper": 0.72})
    report.add("table2_acl5_area_saving", acl5_a, "ratio",
               derived={"paper": 0.784})
    report.add("table2_acl5_power_saving", acl5_p, "ratio",
               derived={"paper": 0.821})
    da, dp = ppa.bd_omission_savings(5)
    print(f"BD omission (n=5): area -{100*da:.1f}% power -{100*dp:.1f}% "
          f"(paper: 6.8%/12.6%)")
    report.add("table2_bd_omission_area_saving", da, "ratio",
               derived={"paper": 0.068})
    return report


if __name__ == "__main__":
    module_main(run)
