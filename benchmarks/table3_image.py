"""Table III reproduction: image blending + edge detection PSNR per multiplier.

Every scalar multiplication in the two kernels goes through the selected
bit-level multiplier (the CiM array does the multiplies; additions are the
macro's exact adder tree).  PSNR is computed against the exact-fp32 result,
on deterministic synthetic grayscale images (stand-ins for the paper's
Lake/Mandril/Cameraman set — see DESIGN.md).

Metrics: per-design blend/edge PSNR (dB, deterministic — gates the
trajectory) plus one informational wall-clock per design via the shared
harness (see docs/benchmarks.md).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

try:
    from .harness import BenchReport, module_main
except ImportError:  # run as a script: python benchmarks/<module>.py
    import os
    import sys

    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    from benchmarks.harness import BenchReport, module_main
from repro.core.metrics import psnr
from repro.core.registry import get_multiplier
from repro.data.synthetic import gray_images


MULTS = ["AC4-4", "AC5-5", "AC6-6", "ACL5", "MMBS5", "MMBS6", "MMBS7",
         "CSS12", "CSS16", "NC", "LPC", "HPC"]

SOBEL_X = np.array([[-1, 0, 1], [-2, 0, 2], [-1, 0, 1]], np.float32)
SOBEL_Y = SOBEL_X.T.copy()


def blend(a, b, alpha, mult):
    """alpha-blend: every product through the multiplier under test."""
    return mult(a, jnp.float32(alpha)) + mult(b, jnp.float32(1.0 - alpha))


def conv3x3(img, kernel, mult):
    """3x3 correlation with multiplier-under-test products, exact adds."""
    H, W = img.shape
    pad = jnp.pad(img, 1)
    out = jnp.zeros((H, W), jnp.float32)
    for i in range(3):
        for j in range(3):
            k = float(kernel[i, j])
            if k == 0.0:
                continue
            out = out + mult(pad[i:i + H, j:j + W], jnp.float32(k))
    return out


def edge_detect(img, mult):
    gx = conv3x3(img, SOBEL_X, mult)
    gy = conv3x3(img, SOBEL_Y, mult)
    # magnitude: squares also go through the multiplier under test
    return jnp.sqrt(mult(gx, gx) + mult(gy, gy))


def run(report: BenchReport | None = None, n_images: int = 3, size: int = 128):
    report = report if report is not None else BenchReport()
    if report.fast:
        n_images, size = min(n_images, 2), min(size, 96)
    imgs = gray_images(seed=42, n=2 * n_images, size=size)
    exact = get_multiplier("exact")
    print("\n== Table III: image-processing PSNR (dB) vs exact fp32 ==")
    print(f"{'design':8s} " + " ".join(f"{'blend'+str(i+1):>8s}" for i in range(n_images))
          + " " + " ".join(f"{'edge'+str(i+1):>8s}" for i in range(n_images)))
    results = {}
    for name in MULTS:
        mult = get_multiplier(name)
        row = []
        for i in range(n_images):
            a = jnp.asarray(imgs[2 * i])
            b = jnp.asarray(imgs[2 * i + 1])
            ref = np.asarray(blend(a, b, 0.6, exact))
            got = np.asarray(blend(a, b, 0.6, mult))
            row.append(psnr(got, ref, peak=255.0))
        for i in range(n_images):
            a = jnp.asarray(imgs[i])
            ref = np.asarray(edge_detect(a, exact))
            got = np.asarray(edge_detect(a, mult))
            row.append(psnr(got, ref, peak=float(np.max(np.abs(ref)))))
        results[name] = row
        print(f"{name:8s} " + " ".join(f"{v:8.2f}" for v in row))
        report.add(f"table3_{name}_psnr_blend", row[0], "dB",
                   derived={"size": size})
        report.add(f"table3_{name}_psnr_edge", row[n_images], "dB",
                   derived={"size": size})
    # informational wall-clock of one representative pipeline (the blend is
    # eager bit-level emulation; warmup still excluded for symmetry)
    a0, b0 = jnp.asarray(imgs[0]), jnp.asarray(imgs[1])
    report.record("table3_blend_AC5-5", blend, a0, b0, 0.6,
                  get_multiplier("AC5-5"), derived={"size": size},
                  iters=min(3, report.default_iters))
    # paper-claim checks (Table III rankings)
    ac55_blend = results["AC5-5"][0]
    mmbs5_blend = results["MMBS5"][0]
    hpc_blend = results["HPC"][0]
    ok1 = results["AC4-4"][0] < results["AC5-5"][0] < results["AC6-6"][0]
    ok2 = ac55_blend > mmbs5_blend and ac55_blend > hpc_blend
    print(f"paper-claim check: PSNR increases with n: {ok1}; "
          f"AC5-5 beats MMBS5 & HPC: {ok2}")
    return results


if __name__ == "__main__":
    module_main(run)
