"""Table III reproduction: image blending + edge detection PSNR per multiplier.

Every scalar multiplication in the two kernels goes through the selected
bit-level multiplier (the CiM array does the multiplies; additions are the
macro's exact adder tree).  PSNR is computed against the exact-fp32 result,
on deterministic synthetic grayscale images (stand-ins for the paper's
Lake/Mandril/Cameraman set — see DESIGN.md).
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core.metrics import psnr
from repro.core.registry import get_multiplier
from repro.data.synthetic import gray_images

MULTS = ["AC4-4", "AC5-5", "AC6-6", "ACL5", "MMBS5", "MMBS6", "MMBS7",
         "CSS12", "CSS16", "NC", "LPC", "HPC"]

SOBEL_X = np.array([[-1, 0, 1], [-2, 0, 2], [-1, 0, 1]], np.float32)
SOBEL_Y = SOBEL_X.T.copy()


def blend(a, b, alpha, mult):
    """alpha-blend: every product through the multiplier under test."""
    return mult(a, jnp.float32(alpha)) + mult(b, jnp.float32(1.0 - alpha))


def conv3x3(img, kernel, mult):
    """3x3 correlation with multiplier-under-test products, exact adds."""
    H, W = img.shape
    pad = jnp.pad(img, 1)
    out = jnp.zeros((H, W), jnp.float32)
    for i in range(3):
        for j in range(3):
            k = float(kernel[i, j])
            if k == 0.0:
                continue
            out = out + mult(pad[i:i + H, j:j + W], jnp.float32(k))
    return out


def edge_detect(img, mult):
    gx = conv3x3(img, SOBEL_X, mult)
    gy = conv3x3(img, SOBEL_Y, mult)
    # magnitude: squares also go through the multiplier under test
    return jnp.sqrt(mult(gx, gx) + mult(gy, gy))


def run(csv_rows=None, n_images: int = 3, size: int = 128):
    imgs = gray_images(seed=42, n=2 * n_images, size=size)
    exact = get_multiplier("exact")
    print("\n== Table III: image-processing PSNR (dB) vs exact fp32 ==")
    print(f"{'design':8s} " + " ".join(f"{'blend'+str(i+1):>8s}" for i in range(n_images))
          + " " + " ".join(f"{'edge'+str(i+1):>8s}" for i in range(n_images)))
    results = {}
    for name in MULTS:
        mult = get_multiplier(name)
        row = []
        t0 = time.perf_counter()
        for i in range(n_images):
            a = jnp.asarray(imgs[2 * i])
            b = jnp.asarray(imgs[2 * i + 1])
            ref = np.asarray(blend(a, b, 0.6, exact))
            got = np.asarray(blend(a, b, 0.6, mult))
            row.append(psnr(got, ref, peak=255.0))
        for i in range(n_images):
            a = jnp.asarray(imgs[i])
            ref = np.asarray(edge_detect(a, exact))
            got = np.asarray(edge_detect(a, mult))
            row.append(psnr(got, ref, peak=float(np.max(np.abs(ref)))))
        dt = (time.perf_counter() - t0) * 1e6 / (2 * n_images)
        results[name] = row
        print(f"{name:8s} " + " ".join(f"{v:8.2f}" for v in row))
        if csv_rows is not None:
            csv_rows.append((f"table3_{name}", dt,
                             f"psnr_blend={row[0]:.1f};psnr_edge={row[n_images]:.1f}"))
    # paper-claim checks (Table III rankings)
    ac55_blend = results["AC5-5"][0]
    mmbs5_blend = results["MMBS5"][0]
    hpc_blend = results["HPC"][0]
    ok1 = results["AC4-4"][0] < results["AC5-5"][0] < results["AC6-6"][0]
    ok2 = ac55_blend > mmbs5_blend and ac55_blend > hpc_blend
    print(f"paper-claim check: PSNR increases with n: {ok1}; "
          f"AC5-5 beats MMBS5 & HPC: {ok2}")
    return results


if __name__ == "__main__":
    run()
