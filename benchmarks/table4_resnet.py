"""Table IV reproduction: ResNet-18 inference under approximate multipliers.

Methodology mirrors §IV-C: the network is trained with exact fp32
arithmetic (here: on the deterministic synthetic CIFAR-like set, a few
hundred steps — this container is a single CPU core), then inference runs
with every conv/fc product routed through the approximate multiplier
(bit-level emulation, im2col + afpm_matmul_emulated).  Reported: MRED/NMED
of the multiplier itself plus Top-1 accuracy vs the exact baseline.

All inference routes through :class:`repro.session.Session`.  ``--auto
BUDGET`` additionally runs the per-layer auto-configurer
(``Session.auto_configure`` -> ``repro.core.sweep.auto_configure``)
against a calibration batch and
emits a NumericsPolicy meeting the logits-MRED budget at minimum modeled
area (``--out`` saves it as JSON for ``repro.launch.serve --policy``).
``--method proxy`` (default) spends one instrumented calibration pass on
the composed-error sensitivity model (``repro.core.sensitivity``);
``--method greedy`` keeps the original measured-error sweep.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

try:
    from .harness import BenchReport
except ImportError:  # run as a script: python benchmarks/<module>.py
    import os
    import sys

    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    from benchmarks.harness import BenchReport
from repro.core.metrics import mred, nmed, top_k_accuracy
from repro.core.numerics import NumericsConfig
from repro.core.registry import get_multiplier
from repro.data.synthetic import DataConfig, cifar_like
from repro.models import resnet
from repro.models.layers import unzip
from repro.optim import adamw
from repro.session import Session


# paper Table IV values for side-by-side printing
PAPER = {
    "Exact": (None, None, 0.8715),
    "ACL5": (4.16e-2, 1.58e-4, 0.8569),
    "AC4-4": (1.38e-3, 5.35e-6, 0.8715),
    "AC5-5": (3.36e-4, 1.30e-6, 0.8717),
    "AC6-6": (8.29e-5, 3.55e-7, 0.8715),
    "MMBS5": (2.92e-3, 1.13e-5, 0.8714),
    "CSS16": (3.48e-4, 1.37e-6, 0.8717),
    "NC": (4.37e-2, 1.55e-4, 0.8253),
    "HPC": (7.06e-3, 2.59e-5, 0.8717),
}

MULTS = ["AC4-4", "AC5-5", "AC6-6", "ACL5", "MMBS5", "CSS16", "NC", "HPC"]


def train_resnet(steps=120, batch=64, seed=0, width_mult=0.5):
    widths = tuple(int(w * width_mult) for w in (64, 128, 256, 512))
    cfg = resnet.ResNetConfig(widths=widths)
    pp, state = resnet.init(cfg, jax.random.PRNGKey(seed))
    params, _ = unzip(pp)
    opt_cfg = adamw.AdamWConfig(lr=3e-3, schedule="cosine", warmup_steps=20,
                                total_steps=steps, weight_decay=1e-4)
    opt = adamw.init(params, opt_cfg)
    dcfg = DataConfig(global_batch=batch, seed=seed)

    @jax.jit
    def step(params, state, opt, batch_):
        (loss, new_state), grads = jax.value_and_grad(
            resnet.loss_fn, has_aux=True)(params, state, batch_, cfg)
        params, opt, m = adamw.apply_updates(params, grads, opt, opt_cfg)
        return params, new_state, opt, loss

    for s in range(steps):
        hb = cifar_like(dcfg, s)
        b = {k: jnp.asarray(v) for k, v in hb.items()}
        params, state, opt, loss = step(params, state, opt, b)
        if s % 40 == 0 or s == steps - 1:
            print(f"  [resnet-train] step {s:4d} loss {float(loss):.4f}")
    return cfg, params, state


def run(report: BenchReport | None = None, train_steps=120, eval_n=48):
    report = report if report is not None else BenchReport()
    print("\n== Table IV: ResNet-18 inference with approximate multipliers ==")
    cfg, params, state = train_resnet(steps=train_steps)
    dcfg = DataConfig(global_batch=eval_n, seed=999)
    eval_b = cifar_like(dcfg, 10_000, n=eval_n)
    images = jnp.asarray(eval_b["images"])
    labels = jnp.asarray(eval_b["labels"])

    # multiplier-level error metrics on a broad operand distribution
    rng = np.random.default_rng(0)
    xs = rng.uniform(-4, 4, 100_000).astype(np.float32)
    ys = rng.uniform(-4, 4, 100_000).astype(np.float32)
    exact_prod = xs.astype(np.float64) * ys.astype(np.float64)

    sess = Session.from_resnet(cfg, params, state)
    logits_exact = sess.apply(images)
    top1_exact = top_k_accuracy(logits_exact, labels, 1)
    print(f"{'design':8s} {'MRED':>9s} {'paperM':>9s} {'NMED':>9s} "
          f"{'top1':>6s} {'d_top1':>7s} {'agree%':>7s}")
    print(f"{'Exact':8s} {'-':>9s} {'-':>9s} {'-':>9s} {top1_exact:6.3f} "
          f"{'-':>7s} {'-':>7s}")
    pred_exact = np.argmax(np.asarray(logits_exact), -1)

    for name in MULTS:
        mult = get_multiplier(name)
        ap = np.asarray(mult(jnp.asarray(xs), jnp.asarray(ys)))
        m, n = mred(ap, exact_prod), nmed(ap, exact_prod)
        ncfg = NumericsConfig(mode="emulated", multiplier=name,
                              seg_n=int(name[2]) if name.startswith("AC") and
                              name[2].isdigit() else 5)
        approx = sess.replace(policy=ncfg)
        # emulated inference is minutes-scale on one CPU core: a single
        # synced iteration through the shared harness, no warmup, and the
        # timed call's logits are reused for the accuracy metrics
        captured = {}

        def _eval(approx=approx):
            captured["logits"] = approx.apply(images)
            return captured["logits"]

        meas = report.record(f"table4_{name}", _eval, iters=1, warmup=0,
                             derived={"eval_n": eval_n})
        logits = captured["logits"]
        top1 = top_k_accuracy(logits, labels, 1)
        agree = float(np.mean(np.argmax(np.asarray(logits), -1) == pred_exact))
        report.add(f"table4_{name}_top1_delta", float(top1 - top1_exact),
                   "top1", derived={"mred": float(m), "agree": agree})
        pm = PAPER.get(name, (None,))[0]
        print(f"{name:8s} {m:9.2e} {pm if pm else 0:9.2e} {n:9.2e} "
              f"{float(top1):6.3f} {float(top1 - top1_exact):+7.3f} "
              f"{agree*100:6.1f}%  [{meas.median_us/1e6:.1f}s eval]")
    print("paper-claim check: AC4-4/5-5/6-6 should show ~zero top-1 drop; "
          "NC the largest drop (Table IV).")
    return report


def run_auto(budget=1e-2, train_steps=120, calib_n=32, candidates="segmented",
             out=None, method="proxy"):
    """Budget-driven per-layer configuration of the Table IV network.

    ``candidates='segmented'`` uses the fast split-float ladder (CPU-cheap
    calibration); ``'emulated'`` uses the bit-level Pareto-frontier designs
    (paper-faithful, hours on one core).  ``method='proxy'`` (default) fits
    the composed-error sensitivity model in ONE calibration pass and solves
    the assignment from the model; ``'greedy'`` re-measures the network per
    candidate assignment (the original O(L x C) full-eval schedule).
    Prints the chosen per-layer assignment and the modeled-area saving vs
    the all-exact baseline; for the proxy, also the measured error of the
    emitted policy (one verification eval, outside the configurator).
    """
    print(f"\n== auto-configure[{method}]: per-layer numerics under "
          f"MRED <= {budget:g} ==")
    cfg, params, state = train_resnet(steps=train_steps)
    dcfg = DataConfig(global_batch=calib_n, seed=123)
    calib = cifar_like(dcfg, 20_000, n=calib_n)
    images = jnp.asarray(calib["images"])

    sess = Session.from_resnet(cfg, params, state)
    # exact reference before the session adopts the emitted policy (only
    # the proxy needs it, for the one verification eval outside the
    # configurator)
    ref = (np.asarray(sess.apply(images), np.float64)
           if method == "proxy" else None)
    res = sess.auto_configure(budget, calib=images, candidates=candidates,
                              method=method, verbose=True)
    err_kind = "composed" if res.method == "proxy" else "measured"
    print(f"[auto] {err_kind} error={res.error:.3e} (budget {budget:g})  "
          f"area {res.area_um2:,.0f} um^2 vs exact {res.baseline_area_um2:,.0f} "
          f"(-{res.area_reduction:.1%})  [{res.n_evals} calibration evals]")
    if res.method == "proxy":
        measured = mred(np.asarray(sess.apply(images)), ref)
        health = measured / max(res.error, 1e-30)
        print(f"[auto] measured error of emitted policy: {measured:.3e} "
              f"(measured/composed {health:.2f}x — the gain-aware model "
              f"should bracket this near 1; see docs/sensitivity.md)")
    for path, name in res.assignments:
        print(f"  {path:16s} -> {name}")
    if out:
        sess.save_policy(out)
        print(f"[auto] policy written to {out} (rule paths are this ResNet's "
              f"layers; schema + LM-serving policies: docs/numerics_policy.md)")
    return res


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--auto", type=float, default=None, metavar="BUDGET",
                    help="run the per-layer auto-configurer at this MRED budget "
                         "instead of the fixed Table IV grid")
    ap.add_argument("--candidates", choices=["segmented", "emulated"],
                    default="segmented")
    ap.add_argument("--method", choices=["proxy", "greedy"], default="proxy",
                    help="proxy: one calibration pass + composed-error model; "
                         "greedy: full-network eval per candidate assignment")
    ap.add_argument("--out", default=None, help="write the policy JSON here")
    ap.add_argument("--train-steps", type=int, default=120)
    ap.add_argument("--fast", action="store_true",
                    help="CI subset: fewer timing iterations")
    ap.add_argument("--iters", type=int, default=None,
                    help="override the per-metric timing iteration count")
    ap.add_argument("--tune", default=None, metavar="TUNE_JSON",
                    help="measured kernel-tuning artifact to activate "
                         "(default: REPRO_TUNE_FILE env var, else the "
                         "static tables)")
    args = ap.parse_args()
    from benchmarks.harness import activate_tuning

    activate_tuning(args.tune)
    if args.auto is not None:
        run_auto(budget=args.auto, candidates=args.candidates, out=args.out,
                 train_steps=args.train_steps, method=args.method)
    else:
        run(BenchReport(fast=args.fast, iters=args.iters),
            train_steps=args.train_steps)
