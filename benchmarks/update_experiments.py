"""Inject the dry-run/roofline/perf tables into EXPERIMENTS.md markers."""
import glob
import json
import os
import re

HERE = os.path.dirname(__file__)
EXP = os.path.join(HERE, "..", "EXPERIMENTS.md")
PERF = os.path.join(HERE, "artifacts", "perf")


def dryrun_summary():
    from benchmarks.roofline import load_records

    out = []
    for mesh in ("16x16", "2x16x16"):
        recs = load_records(mesh)
        ok = sum(1 for r in recs if r["status"] == "ok")
        skip = sum(1 for r in recs if str(r["status"]).startswith("skipped"))
        err = [r for r in recs
               if r["status"] not in ("ok",) and not str(r["status"]).startswith("skipped")]
        out.append(f"- mesh {mesh}: {ok} ok, {skip} skipped (long_500k "
                   f"full-attention policy), {len(err)} failed"
                   + (f" ({[ (e['arch'], e['shape']) for e in err ]})" if err else ""))
    # memory fit summary
    recs = load_records("16x16")
    over = [(r["arch"], r["shape"],
             round(r["memory"]["peak_estimate_bytes"] / 2**30, 1))
            for r in recs if r["status"] == "ok"
            and r["memory"]["peak_estimate_bytes"] > 16 * 2**30]
    if over:
        out.append(f"- cells over the 16 GiB v5e HBM budget at 16x16 "
                   f"(see §Perf for the fixes): {over}")
    return "\n".join(out)


def perf_log():
    rows = []
    for path in sorted(glob.glob(os.path.join(PERF, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        rf = r.get("roofline", {})
        rows.append(
            f"### {r['tag']}\n"
            f"*{r.get('hypothesis', '')}*\n\n"
            f"- status: {r['status']}; t_compute {rf.get('t_compute_s', 0):.2f}s, "
            f"t_memory {rf.get('t_memory_s', 0):.2f}s, "
            f"t_collective {rf.get('t_collective_s', 0):.2f}s "
            f"-> dominant {rf.get('dominant')}, "
            f"roofline fraction {rf.get('roofline_fraction', 0):.4f}, "
            f"mem {r.get('memory', {}).get('peak_estimate_bytes', 0)/2**30:.1f} GiB\n")
    return "\n".join(rows)


def main():
    from benchmarks.roofline import markdown_table

    with open(EXP) as f:
        text = f.read()
    text = re.sub(
        r"<!-- DRYRUN_TABLE -->.*?(?=\n## |$)",
        "<!-- DRYRUN_TABLE -->\n" + dryrun_summary() + "\n\n"
        "Full per-cell records: `benchmarks/artifacts/dryrun/*.json`; "
        "regenerate tables with `python -m benchmarks.roofline`.\n",
        text, flags=re.S)
    table16 = markdown_table("16x16")
    table512 = markdown_table("2x16x16")
    text = re.sub(
        r"<!-- ROOFLINE_TABLE -->.*?(?=\n## |$)",
        "<!-- ROOFLINE_TABLE -->\n### Single-pod 16x16 (256 chips)\n\n"
        + table16 + "\n\n### Multi-pod 2x16x16 (512 chips)\n\n" + table512 + "\n",
        text, flags=re.S)
    if "<!-- PERF_LOG -->" in text:
        text = re.sub(r"<!-- PERF_LOG -->.*$",
                      "<!-- PERF_LOG -->\n" + perf_log() + "\n", text, flags=re.S)
    with open(EXP, "w") as f:
        f.write(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
