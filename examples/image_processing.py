"""Image processing under approximate FP multiplication (paper §IV-B).

Alpha-blending and Sobel edge detection where every multiply goes through
the configurable multiplier; prints PSNR vs the exact pipeline for a sweep
of configurations — the paper's Table III experiment, runnable standalone.

Run:  PYTHONPATH=src python examples/image_processing.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np

from benchmarks.table3_image import blend, edge_detect, run


if __name__ == "__main__":
    results = run(n_images=2, size=96)
    best = max(results, key=lambda k: results[k][0])
    print(f"\nhighest-fidelity design on blending: {best} "
          f"({results[best][0]:.1f} dB)")
    print("Interpretation: >50 dB is visually indistinguishable; the AC-n-n "
          "family spans 60-100+ dB at 2.9-2.5x lower area than exact "
          "(see benchmarks/table2_ppa.py).")
