"""Quickstart: the paper's accuracy-configurable FP multiplier in 2 minutes.

Shows: (1) exact vs approximate multiply at bit level, (2) the error/cost
trade-off across configs, (3) the numerics knob on a matmul, (4) the PPA
model — everything the compiler flow exposes.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import ppa
from repro.core.afpm import AFPMConfig, afpm_mult_f32
from repro.core.metrics import mred
from repro.core.registry import available, get_multiplier
from repro.numerics import NumericsConfig, nmatmul, numerics_scope

print("== 1. one multiply, many multipliers ==")
x, y = jnp.float32(3.14159), jnp.float32(-2.71828)
print(f"   exact: {float(x * y):+.6f}")
for name in ["AC4-4", "AC5-5", "AC6-6", "ACL5", "MMBS5", "CSS16", "NC", "HPC"]:
    got = float(get_multiplier(name)(x, y))
    print(f"   {name:6s}: {got:+.6f}  (rel err {abs(got - float(x*y))/abs(float(x*y)):.2e})")

print("\n== 2. accuracy-PPA trade-off (the paper's design space) ==")
rng = np.random.default_rng(0)
a = rng.uniform(-4, 4, 50_000).astype(np.float32)
b = rng.uniform(-4, 4, 50_000).astype(np.float32)
exact = a.astype(np.float64) * b.astype(np.float64)
for n in (4, 5, 6):
    approx = np.asarray(afpm_mult_f32(a, b, AFPMConfig(n=n)))
    est = ppa.estimate("ac", n=n)
    print(f"   AC{n}-{n}: MRED {mred(approx, exact):.2e}  "
          f"area {est.logic_area_um2:.0f} um2  power {est.power_w:.2e} W")

print("\n== 3. the numerics knob on a matmul (compiler integration) ==")
X = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)
W = jnp.asarray(rng.standard_normal((64, 8)), jnp.float32)
with numerics_scope(NumericsConfig(mode="exact", compute_dtype="float32")):
    ref = np.asarray(nmatmul(X, W))
for cfg in [NumericsConfig(mode="emulated", multiplier="AC5-5", seg_n=5),
            NumericsConfig(mode="segmented", seg_passes=3, backend="xla"),
            NumericsConfig(mode="segmented", seg_passes=1, backend="xla")]:
    with numerics_scope(cfg):           # precision is ambient, not an argument
        got = np.asarray(nmatmul(X, W))
    err = np.abs(got - ref).mean() / np.abs(ref).mean()
    label = cfg.multiplier if cfg.mode == "emulated" else f"segmented-{cfg.seg_passes}"
    print(f"   {cfg.mode:9s} {label:12s}: mean rel err {err:.2e}")

print(f"\n== 4. registry has {len(available())} multipliers: {available()[:8]} ... ==")
print("done.")
