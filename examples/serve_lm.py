"""Serve a small LM with accuracy-tiered SLAs in ONE engine.

The paper's accuracy knob as a *traffic* knob: premium requests decode
exact, standard under the 3-pass segmented multiplier (AC-like), bulk
under 1 pass (ACL-like) — all three tiers continuously batched over the
SAME resident weights, each tier on its own KV-slot pool and resident
compiled decode.  Continuous batching is bit-transparent: every request's
tokens equal a solo ``Session.generate`` under its tier's numerics, so
the only accuracy trade-off is the one you configured.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import numpy as np

from repro.session import Session, print_ppa_report
from repro.serving import DEFAULT_TIERS


def main():
    print("== accuracy-tiered continuous batching ==")
    sess = Session("qwen3-4b", seed=7)
    eng = sess.serving_engine(DEFAULT_TIERS, slots=2, max_len=48)
    rng = np.random.default_rng(7)
    reqs = []
    for i in range(6):  # 2 requests per tier, staggered lengths
        tier = DEFAULT_TIERS[i % len(DEFAULT_TIERS)]
        prompt = rng.integers(0, sess.config.vocab, 8 + 3 * (i // 3))
        reqs.append(eng.submit(prompt, tier=tier.name, max_new_tokens=12))
    stats = eng.run()

    for spec in DEFAULT_TIERS:
        s = stats[spec.name]
        print(f"   {spec.name:8s} ({spec.policy}): {s.n_finished} requests, "
              f"{s.n_tokens} tokens over {s.n_decode_steps} decode steps "
              f"(mean batch {s.mean_occupancy:.2f})")
        print_ppa_report(sess.replace(policy=spec.policy).ppa_report(),
                         tag=f"tier:{spec.name}")

    # the bit-transparency claim, checked live: each request matches its
    # solo generate under the same tier policy
    policy = {t.name: t.policy for t in DEFAULT_TIERS}
    for r in reqs:
        solo = sess.replace(policy=policy[r.tier]).generate(
            prompts=r.prompt[None], gen_len=r.max_new_tokens)
        assert np.array_equal(r.result(), solo.tokens[0]), r.id
    print("\nall requests bit-identical to solo generation under their "
          "tier's numerics; the SLA ladder spends area/power only where "
          "the traffic class paid for it.")


if __name__ == "__main__":
    main()
