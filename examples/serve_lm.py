"""Serve a small LM with batched requests under the paper's numerics knob.

Compares exact / segmented-3 (AC-like) / segmented-1 (ACL-like) serving on
the same weights: latency and greedy-token agreement — the system-level
face of the accuracy-PPA trade-off.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import numpy as np

from repro.launch.serve import serve


def main():
    print("== batched serving under configurable numerics ==")
    ref = serve("qwen3-4b", batch=4, prompt_len=32, gen_len=12,
                numerics="exact", seed=7)
    for mode in ("segmented3", "segmented2", "segmented1"):
        got = serve("qwen3-4b", batch=4, prompt_len=32, gen_len=12,
                    numerics=mode, seed=7)
        agree = float(np.mean(got == ref))
        print(f"   {mode}: greedy-token agreement vs exact = {agree*100:.0f}%")
    print("\n3 passes (AC-like, BD dropped) preserves decoding; 1 pass "
          "(ACL-like) trades tokens for 3x fewer MXU passes.")


if __name__ == "__main__":
    main()
