"""End-to-end driver (paper's workload): train ResNet-18 exactly, deploy
approximately — the full §IV-C loop.

1. trains ResNet-18 on the synthetic CIFAR set for a few hundred steps
   (exact fp32 arithmetic),
2. checkpoints it (fault-tolerant: rerunning resumes),
3. evaluates inference under exact vs AC5-5 vs ACL5 multipliers,
4. prints the accuracy deltas next to the PPA savings — the actual
   deployment decision the paper's compiler flow automates.

Run:  PYTHONPATH=src python examples/train_resnet.py [--steps 300]
"""
import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ppa
from repro.core.metrics import top_k_accuracy
from repro.core.numerics import NumericsConfig
from repro.data.synthetic import DataConfig, cifar_like
from repro.models import resnet


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--eval-n", type=int, default=64)
    args = ap.parse_args()

    from benchmarks.table4_resnet import train_resnet

    cfg, params, state = train_resnet(steps=args.steps, batch=64)

    # checkpoint (restart-safe)
    from repro.checkpoint import io as ckpt_io

    ckpt_dir = "/tmp/repro_resnet_ckpt"
    ckpt_io.save(ckpt_dir, args.steps, (params, state))
    print(f"checkpointed to {ckpt_dir} (step {ckpt_io.latest_step(ckpt_dir)})")

    dcfg = DataConfig(global_batch=args.eval_n, seed=123)
    b = cifar_like(dcfg, 77_000, n=args.eval_n)
    images, labels = jnp.asarray(b["images"]), jnp.asarray(b["labels"])

    print(f"\n{'numerics':14s} {'top-1':>6s} {'area um2':>9s} {'power W':>9s}")
    for label, ncfg, est in [
        ("exact", NumericsConfig(mode="exact", compute_dtype="float32"),
         ppa.estimate("exact")),
        ("AC5-5", NumericsConfig(mode="emulated", multiplier="AC5-5", seg_n=5),
         ppa.estimate("ac", n=5)),
        ("ACL5", NumericsConfig(mode="emulated", multiplier="ACL5", seg_n=5),
         ppa.estimate("acl", n=5)),
    ]:
        acfg = dataclasses.replace(cfg, numerics=ncfg)
        logits, _ = resnet.apply(params, state, images, acfg, train=False)
        t1 = top_k_accuracy(logits, labels, 1)
        print(f"{label:14s} {float(t1):6.3f} {est.logic_area_um2:9.0f} "
              f"{est.power_w:9.2e}")
    print("\nThe deployment story: AC5-5 keeps accuracy at ~1/3 the multiplier "
          "area/power; ACL5 trades a few points for ~1/5.")


if __name__ == "__main__":
    main()
