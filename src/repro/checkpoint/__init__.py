"""Sharded checkpointing (msgpack+zstd), atomic commit, elastic re-sharding."""
from . import io
