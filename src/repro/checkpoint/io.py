"""Fault-tolerant checkpointing: sharded msgpack+zstd, atomic commit, restart.

Layout:  <dir>/step_<N>/shard_<k>.msgpack.zst  + MANIFEST.json (written last
— its presence marks the checkpoint committed; partial writes are ignored
at restore, which is the crash-consistency story).

Compression uses ``zstandard`` when installed and falls back to stdlib
``zlib`` otherwise (the shard filename is codec-independent; restore
detects the codec from the blob's magic bytes, so checkpoints written
with either codec restore in either environment — a zstd checkpoint in a
zlib-only environment raises a clear error).

Elastic re-sharding: arrays are stored UNsharded per-leaf (host gathers its
addressable shards; in multi-host each host writes its own shard file and
restore re-slices), so a checkpoint written under mesh A restores under
mesh B — ``restore`` just device_puts with the new shardings.
"""
from __future__ import annotations

import json
import os
import shutil
import time
import zlib

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard
except ImportError:  # optional dependency; zlib fallback below
    zstandard = None

_CODEC_VERSION = 1
_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"


def _compress(raw: bytes) -> bytes:
    if zstandard is not None:
        return zstandard.ZstdCompressor(level=3).compress(raw)
    return zlib.compress(raw, 6)


def _decompress(blob: bytes) -> bytes:
    if blob[:4] == _ZSTD_MAGIC:
        if zstandard is None:
            raise ModuleNotFoundError(
                "checkpoint shard is zstd-compressed but the 'zstandard' "
                "package is not installed; pip install zstandard to restore it"
            )
        return zstandard.ZstdDecompressor().decompress(blob)
    return zlib.decompress(blob)


def _encode_leaf(x):
    x = np.asarray(x)
    if x.dtype == jnp.bfloat16:
        return {"dtype": "bfloat16", "shape": list(x.shape),
                "data": x.view(np.uint16).tobytes()}
    return {"dtype": str(x.dtype), "shape": list(x.shape), "data": x.tobytes()}


def _decode_leaf(d):
    if d["dtype"] == "bfloat16":
        arr = np.frombuffer(d["data"], np.uint16).reshape(d["shape"])
        return jnp.asarray(arr.view(jnp.bfloat16))
    return np.frombuffer(d["data"], np.dtype(d["dtype"])).reshape(d["shape"])


def save(ckpt_dir: str, step: int, tree, extra: dict | None = None,
         keep: int = 3, process_index: int | None = None):
    """Atomically write a checkpoint for ``step``; prunes old ones."""
    pidx = jax.process_index() if process_index is None else process_index
    step_dir = os.path.join(ckpt_dir, f"step_{step:09d}")
    tmp_dir = step_dir + ".tmp"
    os.makedirs(tmp_dir, exist_ok=True)

    leaves, treedef = jax.tree.flatten(tree)
    payload = {
        "version": _CODEC_VERSION,
        "leaves": [_encode_leaf(jax.device_get(l)) for l in leaves],
    }
    blob = _compress(msgpack.packb(payload, use_bin_type=True))
    with open(os.path.join(tmp_dir, f"shard_{pidx}.msgpack.zst"), "wb") as f:
        f.write(blob)

    if pidx == 0:
        manifest = {
            "step": step,
            "time": time.time(),
            "nshards": jax.process_count(),
            "extra": extra or {},
        }
        with open(os.path.join(tmp_dir, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f)
    os.replace(tmp_dir, step_dir)  # atomic commit

    # prune
    steps = sorted(all_steps(ckpt_dir))
    for old in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{old:09d}"), ignore_errors=True)
    return step_dir


def all_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "MANIFEST.json")):
                out.append(int(name[5:]))
    return sorted(out)


def latest_step(ckpt_dir: str):
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, tree_like, step: int | None = None,
            shardings=None, process_index: int | None = None):
    """Restore into the structure of ``tree_like``; optionally device_put with
    ``shardings`` (a matching tree) — this is the elastic re-shard path.
    Returns (tree, manifest)."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no committed checkpoints under {ckpt_dir}")
    pidx = jax.process_index() if process_index is None else process_index
    step_dir = os.path.join(ckpt_dir, f"step_{step:09d}")
    with open(os.path.join(step_dir, "MANIFEST.json")) as f:
        manifest = json.load(f)
    with open(os.path.join(step_dir, f"shard_{pidx}.msgpack.zst"), "rb") as f:
        payload = msgpack.unpackb(_decompress(f.read()), raw=False)
    if payload["version"] != _CODEC_VERSION:
        raise ValueError(f"codec version mismatch: {payload['version']}")
    leaves = [_decode_leaf(d) for d in payload["leaves"]]
    treedef = jax.tree.structure(tree_like)
    tree = jax.tree.unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree, manifest


# ---------------------------------------------------------------------------
# safetensors interchange (repro.compat)
# ---------------------------------------------------------------------------

def save_safetensors(path, tree, metadata=None):
    """Export a params tree as ONE safetensors file through the compat
    state-dict model (``repro.compat``): dotted native leaf paths, plain
    host arrays.  Unlike :func:`save` this is the *interchange* format —
    readable by any safetensors implementation — not the sharded
    fault-tolerant training format.  Reload with :func:`load_safetensors`;
    the round trip is bit-exact."""
    from repro.compat import flatten_tree, write_safetensors

    write_safetensors(path, flatten_tree(tree), metadata)


def load_safetensors(path, tree_like=None, *, cast=False):
    """Load a safetensors checkpoint -> ``(tree, metadata)``.

    With ``tree_like`` the flat state dict is rebuilt into that tree's
    structure, every leaf validated against its shape/dtype (one-line
    ``CompatError`` on mismatch; ``cast=True`` converts dtypes).  Without
    it the raw flat ``{path: array}`` state dict comes back."""
    from repro.compat import load_checkpoint, unflatten_tree

    sd, meta = load_checkpoint(path)
    if tree_like is None:
        return sd, meta
    return unflatten_tree(tree_like, sd, cast=cast), meta
