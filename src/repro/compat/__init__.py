"""Pretrained-checkpoint interop: state-dict model, safetensors IO,
per-family converters (``docs/compat.md``).

The layer between the outside world and the numerics core: real
qwen3-4b / whisper-tiny / ResNet-18 weights load into our model
families (``Session.from_pretrained``) so ``auto_configure`` and the
paper's Table 3/4 accuracy claims can be validated against trained
weights instead of random init (``benchmarks/real_accuracy.py``).
"""
from .state_dict import (CompatError, MapRule, Mapping, flatten_tree,
                         tree_paths, unflatten_tree)
from .safetensors_io import (INDEX_SUFFIX, load_checkpoint, read_safetensors,
                             read_torch_checkpoint, write_safetensors,
                             write_sharded_checkpoint)
from .converters import (Converter, LoadedCheckpoint, converter_for,
                         export_pretrained, families, load_pretrained,
                         register_converter)

__all__ = [
    "CompatError", "Converter", "INDEX_SUFFIX", "LoadedCheckpoint",
    "MapRule", "Mapping", "converter_for", "export_pretrained", "families",
    "flatten_tree", "load_checkpoint", "load_pretrained", "read_safetensors",
    "read_torch_checkpoint", "register_converter", "tree_paths",
    "unflatten_tree", "write_safetensors", "write_sharded_checkpoint",
]
