"""Per-family pretrained-checkpoint converters.

A :class:`Converter` binds one checkpoint *family* (the foreign naming
scheme — HF qwen3, HF whisper, torchvision resnet) to one of our model
families via a :class:`~repro.compat.state_dict.Mapping` built from the
arch config.  Registered converters:

=============  =========================================  ==============
family         foreign layout                             native model
=============  =========================================  ==============
``qwen3-4b``   HF ``Qwen3ForCausalLM`` (``model.layers.   decoder LM,
               {i}.self_attn.q_proj...``, tied lm_head)   ``seg{s}_p{p}``
``whisper-tiny`` HF ``WhisperForConditionalGeneration``   enc-dec LM
               (``model.encoder/decoder.layers.{i}...``)  + ``encoder.*``
``resnet18``   torchvision ``resnet18`` state dict        CIFAR ResNet
               (``layer{1..4}.{b}``, OIHW convs)          + bn state
=============  =========================================  ==============

:func:`load_pretrained` is the one entry point
(``Session.from_pretrained`` wraps it): read the checkpoint
(safetensors single/sharded, or torch pickle by extension), build the
family mapping for the resolved config, rename/adapt into the native
state dict, and validate every leaf against a ``jax.eval_shape``
template of the model's own ``init`` — so a loaded tree is
shape/dtype-identical to a freshly initialized one.
:func:`export_pretrained` is the exact inverse.

Known divergences from the real checkpoints (documented in
``docs/compat.md``): our backbone MLP is gated, real Whisper's is not —
the whisper mapping consumes an extension key
(``...layers.{i}.fc_gate.weight``) for the gate; and real HF whisper
LayerNorm/attention biases have no native counterpart (load with
``unknown="ignore"`` to drop them).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional

import numpy as np

from .safetensors_io import load_checkpoint, read_torch_checkpoint
from .state_dict import (CompatError, MapRule, Mapping, flatten_tree,
                         unflatten_tree)

__all__ = ["Converter", "LoadedCheckpoint", "converter_for", "families",
           "load_pretrained", "export_pretrained", "register_converter"]

FORMAT_TAG = "repro-compat/1"

_TORCH_SUFFIXES = (".pt", ".pth", ".bin")


@dataclasses.dataclass(frozen=True)
class LoadedCheckpoint:
    """The result of :func:`load_pretrained`, ready for a Session."""

    family: str
    kind: str                 # "lm" | "resnet"
    cfg: object               # ArchConfig | ResNetConfig
    params: dict
    state: Optional[dict]     # resnet batchnorm running stats
    metadata: Dict[str, str]


# ---------------------------------------------------------------------------
# transformer block rule builders
# ---------------------------------------------------------------------------

# foreign key templates, per naming scheme, relative to the layer prefix
_QWEN_NAMES = {
    "ln1": "input_layernorm.weight",
    "ln2": "post_attention_layernorm.weight",
    "attn.wq": "self_attn.q_proj.weight",
    "attn.wk": "self_attn.k_proj.weight",
    "attn.wv": "self_attn.v_proj.weight",
    "attn.wo": "self_attn.o_proj.weight",
    "attn.q_norm": "self_attn.q_norm.weight",
    "attn.k_norm": "self_attn.k_norm.weight",
    "mlp.wi": "mlp.up_proj.weight",
    "mlp.wg": "mlp.gate_proj.weight",
    "mlp.wo": "mlp.down_proj.weight",
}

_WHISPER_NAMES = {
    "ln1": "self_attn_layer_norm.weight",
    "ln2": "final_layer_norm.weight",
    "attn.wq": "self_attn.q_proj.weight",
    "attn.wk": "self_attn.k_proj.weight",
    "attn.wv": "self_attn.v_proj.weight",
    "attn.wo": "self_attn.out_proj.weight",
    "mlp.wi": "fc1.weight",
    "mlp.wg": "fc_gate.weight",      # extension: our MLP is gated
    "mlp.wo": "fc2.weight",
    "cross.wq": "encoder_attn.q_proj.weight",
    "cross.wk": "encoder_attn.k_proj.weight",
    "cross.wv": "encoder_attn.v_proj.weight",
    "cross.wo": "encoder_attn.out_proj.weight",
    "ln_cross": "encoder_attn_layer_norm.weight",
}

# norms store HF's raw weight as our ``1 + scale`` -> import shift
_NORM_SHIFT = -1.0


def _block_rules(prefix, dst_prefix, names, stack_kw, *, qk_norm=False,
                 cross=False):
    """MapRules for one (stacked) transformer block position."""
    def mk(slot, dst, **kw):
        return MapRule(prefix + names[slot], dst_prefix + dst,
                       **stack_kw, **kw)

    rules = [
        mk("ln1", "ln1.scale", shift=_NORM_SHIFT),
        mk("ln2", "ln2.scale", shift=_NORM_SHIFT),
        mk("attn.wq", "attn.wq", transpose=True),
        mk("attn.wk", "attn.wk", transpose=True),
        mk("attn.wv", "attn.wv", transpose=True),
        mk("attn.wo", "attn.wo", transpose=True),
    ]
    if qk_norm:
        rules += [mk("attn.q_norm", "attn.q_norm.scale", shift=_NORM_SHIFT),
                  mk("attn.k_norm", "attn.k_norm.scale", shift=_NORM_SHIFT)]
    if cross:
        rules += [mk("cross.wq", "cross.wq", transpose=True),
                  mk("cross.wk", "cross.wk", transpose=True),
                  mk("cross.wv", "cross.wv", transpose=True),
                  mk("cross.wo", "cross.wo", transpose=True),
                  mk("ln_cross", "ln_cross.scale", shift=_NORM_SHIFT)]
    rules += [
        mk("mlp.wi", "mlp.wi", transpose=True),
        mk("mlp.wg", "mlp.wg", transpose=True),
        mk("mlp.wo", "mlp.wo", transpose=True),
    ]
    return rules


def _decoder_stack_rules(cfg, layer_tpl, names, *, cross):
    """Rules for every ``seg{s}_p{p}`` against global HF layer indices."""
    rules = []
    base = 0
    for si, (repeats, pattern) in enumerate(cfg.segments):
        period = len(pattern)
        for pi, spec in enumerate(pattern):
            if spec.kind != "dense" or spec.attn not in ("global", "local"):
                raise CompatError(
                    f"no pretrained converter for layer kind="
                    f"{spec.kind!r} attn={spec.attn!r} "
                    f"(seg{si}_p{pi} of {cfg.arch_id})")
            if spec.shared:
                raise CompatError(f"no pretrained converter for shared "
                                  f"blocks (seg{si}_p{pi} of {cfg.arch_id})")
            stack_kw = dict(stack=repeats, start=base + pi, stride=period)
            rules += _block_rules(layer_tpl, f"seg{si}_p{pi}.", names,
                                  stack_kw, qk_norm=cfg.qk_norm, cross=cross)
        base += repeats * period
    return rules


# ---------------------------------------------------------------------------
# converters
# ---------------------------------------------------------------------------

class Converter:
    """One checkpoint family.  Subclasses provide the mapping + config
    resolution; the base class owns template building and load/export."""

    family: str
    kind: str  # "lm" | "resnet"

    # -- family-specific ----------------------------------------------------

    def mapping(self, cfg) -> Mapping:
        raise NotImplementedError

    def default_config(self, reduced: bool):
        raise NotImplementedError

    def config_json(self, cfg) -> str:
        raise NotImplementedError

    def config_from_json(self, text: str):
        raise NotImplementedError

    def templates(self, cfg):
        """(params_template, state_template|None) via ``jax.eval_shape``."""
        raise NotImplementedError

    # -- shared machinery ---------------------------------------------------

    def resolve_config(self, cfg, metadata: Dict[str, str], reduced: bool):
        if cfg is not None:
            return cfg
        meta_fam = metadata.get("repro.family")
        if meta_fam is not None and meta_fam != self.family:
            raise CompatError(f"checkpoint metadata says family "
                              f"{meta_fam!r}, loader asked for "
                              f"{self.family!r}")
        blob = metadata.get("repro.config")
        if blob is not None:
            try:
                return self.config_from_json(blob)
            except (json.JSONDecodeError, TypeError, ValueError) as e:
                raise CompatError(f"bad repro.config metadata for "
                                  f"{self.family}: {e}") from None
        return self.default_config(reduced)

    def export_metadata(self, cfg) -> Dict[str, str]:
        return {"format": FORMAT_TAG, "repro.family": self.family,
                "repro.config": self.config_json(cfg)}

    def build(self, cfg, native: Dict[str, np.ndarray],
              metadata: Dict[str, str], *, cast: bool) -> LoadedCheckpoint:
        params_tpl, state_tpl = self.templates(cfg)
        params = unflatten_tree(params_tpl, native, cast=cast)
        state = (unflatten_tree(state_tpl, native, cast=cast)
                 if state_tpl is not None else None)
        return LoadedCheckpoint(self.family, self.kind, cfg, params, state,
                                metadata)


class DecoderLMConverter(Converter):
    """HF decoder-only causal LM (qwen/llama naming scheme)."""

    kind = "lm"

    def __init__(self, family: str):
        self.family = family

    def default_config(self, reduced: bool):
        from repro.configs import get_arch
        base = get_arch(self.family)
        return base.reduced() if reduced else base

    def config_json(self, cfg) -> str:
        return json.dumps({"arch_id": cfg.arch_id,
                           "reduced": cfg.d_model == 64})

    def config_from_json(self, text: str):
        spec = json.loads(text)
        from repro.configs import get_arch
        base = get_arch(spec["arch_id"])
        return base.reduced() if spec.get("reduced") else base

    def templates(self, cfg):
        import jax
        from repro.models import transformer
        from repro.models.layers import unzip

        pp = jax.eval_shape(
            lambda k: transformer.init(cfg, k), jax.random.PRNGKey(0))
        params, _ = unzip(pp)
        return params, None

    def mapping(self, cfg) -> Mapping:
        rules = [MapRule("model.embed_tokens.weight", "embed")]
        rules += _decoder_stack_rules(cfg, "model.layers.{i}.", _QWEN_NAMES,
                                      cross=False)
        rules.append(MapRule("model.norm.weight", "final_norm.scale",
                             shift=_NORM_SHIFT))
        if not cfg.tie_embeddings:
            rules.append(MapRule("lm_head.weight", "unembed",
                                 transpose=True))
        return Mapping(rules)


class WhisperConverter(DecoderLMConverter):
    """HF whisper enc-dec (``model.encoder/decoder.layers.{i}`` split)."""

    def mapping(self, cfg) -> Mapping:
        if not cfg.encoder_layers:
            raise CompatError(f"{self.family}: whisper converter needs an "
                              f"encoder (encoder_layers=0 in config)")
        rules = [MapRule("model.decoder.embed_tokens.weight", "embed")]
        rules += _decoder_stack_rules(cfg, "model.decoder.layers.{i}.",
                                      _WHISPER_NAMES, cross=True)
        rules.append(MapRule("model.decoder.layer_norm.weight",
                             "final_norm.scale", shift=_NORM_SHIFT))
        if not cfg.tie_embeddings:
            rules.append(MapRule("proj_out.weight", "unembed",
                                 transpose=True))
        # the encoder scans all its layers in ONE stacked block set
        enc_stack = dict(stack=cfg.encoder_layers, start=0, stride=1)
        rules += _block_rules("model.encoder.layers.{i}.", "encoder.blocks.",
                              _WHISPER_NAMES, enc_stack,
                              qk_norm=cfg.qk_norm, cross=False)
        rules.append(MapRule("model.encoder.layer_norm.weight",
                             "encoder.norm.scale", shift=_NORM_SHIFT))
        return Mapping(rules)


class ResNet18Converter(Converter):
    """torchvision ``resnet18`` naming onto the CIFAR ResNet family."""

    kind = "resnet"

    def __init__(self, family: str = "resnet18"):
        self.family = family

    def default_config(self, reduced: bool):
        from repro.models.resnet import ResNetConfig
        return ResNetConfig()

    def config_json(self, cfg) -> str:
        return json.dumps({"num_classes": cfg.num_classes,
                           "widths": list(cfg.widths),
                           "blocks": list(cfg.blocks)})

    def config_from_json(self, text: str):
        from repro.models.resnet import ResNetConfig
        spec = json.loads(text)
        return ResNetConfig(num_classes=spec["num_classes"],
                            widths=tuple(spec["widths"]),
                            blocks=tuple(spec["blocks"]))

    def templates(self, cfg):
        import jax
        from repro.models import resnet

        params, state = jax.eval_shape(
            lambda k: resnet.init(cfg, k), jax.random.PRNGKey(0))
        return params, state

    def mapping(self, cfg) -> Mapping:
        conv = dict(permute=(2, 3, 1, 0))  # torch OIHW -> our HWIO
        rules = [MapRule("conv1.weight", "stem", **conv)]
        rules += self._bn_rules("bn1.", "bn_stem.")
        cin = cfg.widths[0]
        for si, (w, n) in enumerate(zip(cfg.widths, cfg.blocks)):
            for bi in range(n):
                src = f"layer{si + 1}.{bi}."
                dst = f"s{si}b{bi}."
                stride = 2 if (si > 0 and bi == 0) else 1
                rules += [MapRule(src + "conv1.weight", dst + "conv1",
                                  **conv),
                          MapRule(src + "conv2.weight", dst + "conv2",
                                  **conv)]
                rules += self._bn_rules(src + "bn1.", dst + "bn1.")
                rules += self._bn_rules(src + "bn2.", dst + "bn2.")
                if stride != 1 or cin != w:
                    rules.append(MapRule(src + "downsample.0.weight",
                                         dst + "proj", **conv))
                    rules += self._bn_rules(src + "downsample.1.",
                                            dst + "bn_proj.")
                cin = w
        rules += [MapRule("fc.weight", "fc", transpose=True),
                  MapRule("fc.bias", "fc_b")]
        return Mapping(rules)

    @staticmethod
    def _bn_rules(src, dst):
        # weight/bias live in params; running stats in the state tree —
        # one flat native namespace, split apart by the two templates
        return [MapRule(src + "weight", dst + "scale"),
                MapRule(src + "bias", dst + "bias"),
                MapRule(src + "running_mean", dst + "mean"),
                MapRule(src + "running_var", dst + "var")]


# ---------------------------------------------------------------------------
# registry + entry points
# ---------------------------------------------------------------------------

_CONVERTERS: Dict[str, Converter] = {}


def register_converter(conv: Converter) -> Converter:
    _CONVERTERS[conv.family] = conv
    return conv


def converter_for(family: str) -> Converter:
    try:
        return _CONVERTERS[family]
    except KeyError:
        raise CompatError(f"no checkpoint converter registered for "
                          f"{family!r} (have: "
                          f"{', '.join(sorted(_CONVERTERS))})") from None


def families() -> list:
    return sorted(_CONVERTERS)


register_converter(DecoderLMConverter("qwen3-4b"))
register_converter(WhisperConverter("whisper-tiny"))
register_converter(ResNet18Converter("resnet18"))


def _read_foreign(path):
    import os
    p = os.fspath(path)
    if p.endswith(_TORCH_SUFFIXES):
        return read_torch_checkpoint(p), {}
    return load_checkpoint(p)


def load_pretrained(family: str, path, *, cfg=None, reduced: bool = True,
                    unknown: str = "error", cast: bool = True
                    ) -> LoadedCheckpoint:
    """Load a pretrained checkpoint into native model trees.

    ``path``: a ``.safetensors`` file, sharded ``*.safetensors.index.json``
    (or a directory holding either), or a torch pickle (by extension).
    ``cfg`` overrides the architecture; otherwise it comes from the
    checkpoint's ``repro.config`` metadata when present, else the
    registered arch (``reduced`` selecting the CPU-sized variant).
    ``unknown`` is the strict-vs-ignore mode for unmapped foreign keys;
    ``cast=True`` converts leaf dtypes to the native template's.
    """
    conv = converter_for(family)
    foreign, metadata = _read_foreign(path)
    cfg = conv.resolve_config(cfg, metadata, reduced)
    native = conv.mapping(cfg).to_native(foreign, unknown=unknown)
    return conv.build(cfg, native, metadata, cast=cast)


def export_pretrained(family: str, cfg, params, state=None):
    """Native trees -> ``(foreign_state_dict, metadata)`` for this family
    (the exact inverse of :func:`load_pretrained`)."""
    conv = converter_for(family)
    native = flatten_tree(params)
    if state is not None:
        native.update(flatten_tree(state))
    return conv.mapping(cfg).to_foreign(native), conv.export_metadata(cfg)
