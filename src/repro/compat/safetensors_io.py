"""Dependency-free safetensors reader/writer + guarded torch-pickle reader.

The safetensors container is simple enough to implement directly (and
doing so keeps the compat layer importable in the bare CI environment):

    [8-byte little-endian u64: N][N bytes of JSON header][raw data]

where the header maps ``name -> {"dtype", "shape", "data_offsets"}``
(offsets relative to the start of the data section) plus an optional
``"__metadata__"`` string->string dict.  Reading is zero-copy:
tensors are ``np.frombuffer`` views into one ``bytes`` object.

Sharded checkpoints follow the HF convention — a
``*.safetensors.index.json`` with ``{"weight_map": {name: shard_file}}``
next to the shard files; :func:`load_checkpoint` accepts a single
``.safetensors`` file, an index file, or a directory holding either.

``bfloat16`` uses ``ml_dtypes`` when available (it ships with jax); in
its absence BF16 tensors raise a :class:`CompatError` instead of
silently mis-decoding.  All malformed-input paths raise one-line
:class:`CompatError`\\ s naming the file.

:func:`read_torch_checkpoint` wraps ``torch.load`` behind an in-function
import so environments without torch fail with a skippable one-liner
(tests use ``pytest.importorskip``).
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Dict, Mapping, Optional, Tuple

import numpy as np

from .state_dict import CompatError

try:  # ml_dtypes is a jax dependency, but don't hard-require it here
    import ml_dtypes as _ml_dtypes
except ImportError:  # pragma: no cover - exercised only without jax
    _ml_dtypes = None

__all__ = ["read_safetensors", "write_safetensors", "load_checkpoint",
           "write_sharded_checkpoint", "read_torch_checkpoint",
           "INDEX_SUFFIX"]

INDEX_SUFFIX = ".safetensors.index.json"

_FIXED_DTYPES = {
    "F64": np.dtype(np.float64), "F32": np.dtype(np.float32),
    "F16": np.dtype(np.float16),
    "I64": np.dtype(np.int64), "I32": np.dtype(np.int32),
    "I16": np.dtype(np.int16), "I8": np.dtype(np.int8),
    "U64": np.dtype(np.uint64), "U32": np.dtype(np.uint32),
    "U16": np.dtype(np.uint16), "U8": np.dtype(np.uint8),
    "BOOL": np.dtype(np.bool_),
}


def _dtype_from_tag(tag: str, path: str) -> np.dtype:
    if tag in _FIXED_DTYPES:
        return _FIXED_DTYPES[tag]
    if tag == "BF16":
        if _ml_dtypes is None:
            raise CompatError(f"{path}: BF16 tensor needs ml_dtypes, which "
                              f"is not installed")
        return np.dtype(_ml_dtypes.bfloat16)
    raise CompatError(f"{path}: unsupported safetensors dtype {tag!r}")


def _tag_from_dtype(dtype: np.dtype, name: str) -> str:
    for tag, dt in _FIXED_DTYPES.items():
        if dtype == dt:
            return tag
    if _ml_dtypes is not None and dtype == np.dtype(_ml_dtypes.bfloat16):
        return "BF16"
    raise CompatError(f"tensor {name!r}: dtype {dtype} has no safetensors "
                      f"encoding")


# ---------------------------------------------------------------------------
# single-file read/write
# ---------------------------------------------------------------------------

def read_safetensors(path) -> Tuple[Dict[str, np.ndarray], Dict[str, str]]:
    """Read one ``.safetensors`` file -> ``(state_dict, metadata)``.

    Tensors are zero-copy read-only views into the file buffer.
    """
    path = os.fspath(path)
    try:
        with open(path, "rb") as f:
            raw = f.read()
    except OSError as e:
        raise CompatError(f"{path}: cannot read ({e})") from None
    if len(raw) < 8:
        raise CompatError(f"{path}: truncated ({len(raw)} bytes, need at "
                          f"least an 8-byte header length)")
    hlen = int.from_bytes(raw[:8], "little")
    if 8 + hlen > len(raw):
        raise CompatError(f"{path}: header length {hlen} overruns the "
                          f"{len(raw)}-byte file")
    try:
        header = json.loads(raw[8:8 + hlen].decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise CompatError(f"{path}: bad JSON header ({e})") from None
    data = memoryview(raw)[8 + hlen:]

    meta = header.pop("__metadata__", {}) or {}
    sd: Dict[str, np.ndarray] = {}
    for name, spec in header.items():
        try:
            dtag, shape = spec["dtype"], tuple(spec["shape"])
            beg, end = spec["data_offsets"]
        except (TypeError, KeyError) as e:
            raise CompatError(f"{path}: tensor {name!r} has a malformed "
                              f"header entry (missing {e})") from None
        dtype = _dtype_from_tag(dtag, path)
        nbytes = int(np.prod(shape, dtype=np.int64)) * dtype.itemsize
        if not (0 <= beg <= end <= len(data)) or end - beg != nbytes:
            raise CompatError(f"{path}: tensor {name!r} offsets "
                              f"[{beg}, {end}) do not match dtype {dtag} "
                              f"shape {shape} ({nbytes} bytes)")
        sd[name] = np.frombuffer(data[beg:end], dtype=dtype).reshape(shape)
    return sd, dict(meta)


def write_safetensors(path, sd: Mapping[str, np.ndarray],
                      metadata: Optional[Mapping[str, str]] = None) -> None:
    """Write a flat state dict as one ``.safetensors`` file (atomic)."""
    path = os.fspath(path)
    header: Dict[str, object] = {}
    if metadata:
        header["__metadata__"] = {str(k): str(v)
                                  for k, v in metadata.items()}
    chunks = []
    offset = 0
    for name in sd:
        arr = np.ascontiguousarray(sd[name])
        tag = _tag_from_dtype(arr.dtype, name)
        buf = arr.tobytes()
        header[name] = {"dtype": tag, "shape": list(arr.shape),
                        "data_offsets": [offset, offset + len(buf)]}
        chunks.append(buf)
        offset += len(buf)
    hjson = json.dumps(header, separators=(",", ":")).encode("utf-8")
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               prefix=".st_tmp_")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(len(hjson).to_bytes(8, "little"))
            f.write(hjson)
            for buf in chunks:
                f.write(buf)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


# ---------------------------------------------------------------------------
# sharded checkpoints (HF *.safetensors.index.json convention)
# ---------------------------------------------------------------------------

def write_sharded_checkpoint(directory, sd: Mapping[str, np.ndarray],
                             metadata: Optional[Mapping[str, str]] = None,
                             *, basename: str = "model",
                             max_shard_bytes: int = 1 << 30) -> str:
    """Write ``sd`` as N shard files + an index; returns the index path.

    Shards split greedily at ``max_shard_bytes`` (a tensor never spans
    shards).  Metadata is duplicated into every shard, so any single
    shard — and the whole — is self-describing.
    """
    directory = os.fspath(directory)
    os.makedirs(directory, exist_ok=True)
    groups, cur, cur_bytes = [], [], 0
    for name in sd:
        nbytes = np.asarray(sd[name]).nbytes
        if cur and cur_bytes + nbytes > max_shard_bytes:
            groups.append(cur)
            cur, cur_bytes = [], 0
        cur.append(name)
        cur_bytes += nbytes
    if cur or not groups:
        groups.append(cur)

    n = len(groups)
    weight_map: Dict[str, str] = {}
    total = 0
    for gi, names in enumerate(groups):
        fname = f"{basename}-{gi + 1:05d}-of-{n:05d}.safetensors"
        write_safetensors(os.path.join(directory, fname),
                          {k: sd[k] for k in names}, metadata)
        for k in names:
            weight_map[k] = fname
            total += np.asarray(sd[k]).nbytes
    index = {"metadata": {"total_size": total},
             "weight_map": weight_map}
    index_path = os.path.join(directory, basename + INDEX_SUFFIX)
    with open(index_path, "w") as f:
        json.dump(index, f, indent=1, sort_keys=True)
    return index_path


def _load_index(index_path) -> Tuple[Dict[str, np.ndarray], Dict[str, str]]:
    index_path = os.fspath(index_path)
    try:
        with open(index_path) as f:
            index = json.load(f)
        weight_map = index["weight_map"]
    except (OSError, json.JSONDecodeError, KeyError) as e:
        raise CompatError(f"{index_path}: bad shard index ({e})") from None
    base = os.path.dirname(index_path)
    sd: Dict[str, np.ndarray] = {}
    meta: Dict[str, str] = {}
    for fname in sorted(set(weight_map.values())):
        shard, smeta = read_safetensors(os.path.join(base, fname))
        sd.update(shard)
        meta.update(smeta)
    missing = [k for k in weight_map if k not in sd]
    if missing:
        raise CompatError(f"{index_path}: shard index names "
                          f"{len(missing)} tensor(s) absent from shards, "
                          f"first {missing[0]!r}")
    return sd, meta


def load_checkpoint(path) -> Tuple[Dict[str, np.ndarray], Dict[str, str]]:
    """Load a safetensors checkpoint -> ``(state_dict, metadata)``.

    ``path`` may be a single ``.safetensors`` file, a
    ``*.safetensors.index.json`` shard index, or a directory containing
    exactly one of either.
    """
    path = os.fspath(path)
    if os.path.isdir(path):
        entries = sorted(os.listdir(path))
        indexes = [e for e in entries if e.endswith(INDEX_SUFFIX)]
        if len(indexes) == 1:
            return _load_index(os.path.join(path, indexes[0]))
        if len(indexes) > 1:
            raise CompatError(f"{path}: {len(indexes)} shard indexes found "
                              f"({indexes[0]}, ...); pass one explicitly")
        singles = [e for e in entries if e.endswith(".safetensors")]
        if len(singles) == 1:
            return read_safetensors(os.path.join(path, singles[0]))
        raise CompatError(f"{path}: expected one .safetensors file or one "
                          f"{INDEX_SUFFIX} index, found {len(singles)} "
                          f"file(s)")
    if path.endswith(INDEX_SUFFIX):
        return _load_index(path)
    return read_safetensors(path)


# ---------------------------------------------------------------------------
# torch pickle (guarded)
# ---------------------------------------------------------------------------

def read_torch_checkpoint(path) -> Dict[str, np.ndarray]:
    """Read a torch-pickle weights file -> flat numpy state dict.

    Imports torch lazily; raises :class:`CompatError` when torch is not
    installed (callers/tests guard with ``pytest.importorskip``).
    """
    path = os.fspath(path)
    try:
        import torch
    except ImportError:
        raise CompatError(f"{path}: reading torch-pickle checkpoints "
                          f"requires torch, which is not installed") from None
    try:
        obj = torch.load(path, map_location="cpu", weights_only=True)
    except Exception as e:  # torch raises a zoo of types here
        raise CompatError(f"{path}: torch.load failed ({e})") from None
    if hasattr(obj, "state_dict"):
        obj = obj.state_dict()
    if not isinstance(obj, dict):
        raise CompatError(f"{path}: expected a state dict, got "
                          f"{type(obj).__name__}")
    sd: Dict[str, np.ndarray] = {}
    for name, t in obj.items():
        if not torch.is_tensor(t):
            continue  # optimizer counters etc.
        t = t.detach().cpu()
        if t.dtype == torch.bfloat16:
            if _ml_dtypes is None:
                raise CompatError(f"{path}: BF16 tensor {name!r} needs "
                                  f"ml_dtypes, which is not installed")
            arr = t.view(torch.uint16).numpy().view(_ml_dtypes.bfloat16)
        else:
            arr = t.numpy()
        sd[str(name)] = arr
    return sd
