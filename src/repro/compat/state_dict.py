"""Framework-neutral state-dict model + declarative path-mapping DSL.

A *state dict* is a flat ``{dotted.path: np.ndarray}`` mapping — the
lingua franca between our nested param trees and foreign checkpoint
layouts (HF/torch name schemes).  Two layers live here:

1. **tree <-> state dict** — :func:`flatten_tree` walks a nested params
   tree (dicts, lists/tuples, ``PP`` leaves) into dotted keys;
   :func:`unflatten_tree` rebuilds arrays into the *shape of a template
   tree*, validating every leaf's shape/dtype with a one-line
   :class:`CompatError` (the template is typically a
   ``jax.eval_shape`` of the model's ``init``, so no real init compute
   is spent).

2. **the mapping DSL** — a :class:`Mapping` is an ordered tuple of
   :class:`MapRule`; each rule renames one foreign key (or one stacked
   *family* of per-layer keys) onto one native key and applies an
   invertible adapter chain: axis permutation (``transpose`` /
   ``permute``), ``reshape``, and an additive ``shift`` (our rmsnorm
   stores ``scale`` with ``y = x * (1 + scale)`` while HF stores the
   raw weight, so ``shift=-1``).  ``stack=N`` rules gather
   ``src.format(i=...)`` for ``N`` layers onto the native leading
   ``layers`` axis (the scanned ``seg{s}_p{p}.*`` layout) — the
   levanter ``stack_state_dict``/``unstack_state_dict`` idea expressed
   as data.  Every rule inverts exactly, so one rule table serves both
   :meth:`Mapping.to_native` (import) and :meth:`Mapping.to_foreign`
   (export) and a round trip is bit-exact.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Mapping as TMapping, Optional, Tuple

import numpy as np

__all__ = ["CompatError", "MapRule", "Mapping", "flatten_tree",
           "tree_paths", "unflatten_tree"]


class CompatError(RuntimeError):
    """A checkpoint-interop error with a one-line structured message."""


# ---------------------------------------------------------------------------
# tree <-> flat state dict
# ---------------------------------------------------------------------------

def _is_pp(x) -> bool:
    # duck-typed so this module stays importable without jax/models
    return type(x).__name__ == "PP" and hasattr(x, "value") \
        and hasattr(x, "axes")


def _join(prefix: str, key: str) -> str:
    return f"{prefix}.{key}" if prefix else key


def flatten_tree(tree, prefix: str = "") -> Dict[str, np.ndarray]:
    """Nested params tree -> flat ``{dotted.path: array}`` state dict.

    Dict keys join with ``.``; list/tuple entries use their index as the
    path segment; ``PP`` leaves contribute their ``.value``.  Arrays are
    converted with ``np.asarray`` (device arrays come back to host).
    """
    out: Dict[str, np.ndarray] = {}

    def walk(node, path):
        if _is_pp(node):
            node = node.value
        if isinstance(node, dict):
            for k in node:
                walk(node[k], _join(path, str(k)))
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(v, _join(path, str(i)))
        else:
            out[path] = np.asarray(node)

    walk(tree, prefix)
    return out


def tree_paths(tree, prefix: str = "") -> list:
    """The dotted leaf paths of a tree, in :func:`flatten_tree` order."""
    return list(flatten_tree(tree, prefix))


def _leaf_spec(leaf) -> Tuple[tuple, np.dtype]:
    """(shape, dtype) of a template leaf (array or ShapeDtypeStruct)."""
    return tuple(leaf.shape), np.dtype(leaf.dtype)


def unflatten_tree(template, sd: TMapping[str, np.ndarray], prefix: str = "",
                   *, cast: bool = False):
    """Rebuild a tree shaped like ``template`` from a flat state dict.

    ``template`` leaves only need ``.shape``/``.dtype`` (real arrays or
    ``jax.ShapeDtypeStruct`` both work; ``PP`` leaves are unwrapped — the
    result carries plain arrays).  Each leaf is validated: a missing key,
    wrong shape, or wrong dtype raises a one-line :class:`CompatError`
    naming the offending path (``cast=True`` converts dtype mismatches
    with ``astype`` instead of failing).
    """
    def walk(node, path):
        if _is_pp(node):
            node = node.value
        if isinstance(node, dict):
            return {k: walk(node[k], _join(path, str(k))) for k in node}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v, _join(path, str(i)))
                              for i, v in enumerate(node))
        if path not in sd:
            raise CompatError(f"missing key {path!r} in state dict "
                              f"({len(sd)} keys present)")
        arr = np.asarray(sd[path])
        shape, dtype = _leaf_spec(node)
        if tuple(arr.shape) != shape:
            raise CompatError(f"{path}: shape {tuple(arr.shape)} does not "
                              f"match expected {shape}")
        if arr.dtype != dtype:
            if not cast:
                raise CompatError(f"{path}: dtype {arr.dtype} does not match "
                                  f"expected {dtype} (pass cast=True to "
                                  f"convert)")
            arr = arr.astype(dtype)
        return arr

    return walk(template, prefix)


# ---------------------------------------------------------------------------
# the mapping DSL
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MapRule:
    """One foreign-key -> native-key mapping with an invertible adapter
    chain (applied in import order: permute -> reshape -> ``+ shift``).

    ``transpose`` is shorthand for swapping the last two axes (the torch
    ``Linear`` (out, in) vs our (in, out) convention); ``permute`` is a
    full axes permutation (e.g. torch conv OIHW -> our HWIO is
    ``(2, 3, 1, 0)``).  ``reshape`` reshapes to the given *native* shape
    after the permutation; exporting back then needs ``src_shape`` (the
    foreign shape) to invert it.

    ``stack=N`` makes this a *stacked* rule: ``src`` must contain an
    ``{i}`` placeholder, and import gathers the adapter-applied slices
    for ``i = start, start+stride, ...`` (``N`` of them) onto a new
    leading axis of the single native key ``dst`` — our scanned
    ``seg{s}_p{p}.*`` layers layout.
    """

    src: str
    dst: str
    transpose: bool = False
    permute: Optional[Tuple[int, ...]] = None
    reshape: Optional[Tuple[int, ...]] = None
    src_shape: Optional[Tuple[int, ...]] = None
    shift: float = 0.0
    stack: int = 0
    start: int = 0
    stride: int = 1

    def __post_init__(self):
        if self.transpose and self.permute is not None:
            raise CompatError(f"rule {self.src!r}: transpose and permute "
                              f"are mutually exclusive")
        if self.stack and "{i}" not in self.src:
            raise CompatError(f"rule {self.src!r}: stack={self.stack} "
                              f"requires an {{i}} placeholder in src")

    # -- adapter chain ------------------------------------------------------

    def _perm(self, ndim: int) -> Optional[Tuple[int, ...]]:
        if self.permute is not None:
            return self.permute
        if self.transpose:
            return tuple(range(ndim - 2)) + (ndim - 1, ndim - 2)
        return None

    def adapt(self, arr: np.ndarray) -> np.ndarray:
        """Foreign array -> native array (import direction)."""
        perm = self._perm(arr.ndim)
        if perm is not None:
            arr = np.transpose(arr, perm)
        if self.reshape is not None:
            arr = np.reshape(arr, self.reshape)
        if self.shift:
            arr = arr + np.asarray(self.shift, arr.dtype)
        return arr

    def unadapt(self, arr: np.ndarray) -> np.ndarray:
        """Native array -> foreign array (export direction)."""
        if self.shift:
            arr = arr - np.asarray(self.shift, arr.dtype)
        if self.reshape is not None:
            if self.src_shape is None:
                raise CompatError(
                    f"rule {self.src!r}: exporting a reshape rule needs "
                    f"src_shape (the foreign shape) to invert it")
            perm = self._perm(len(self.src_shape))
            mid = (tuple(self.src_shape[a] for a in perm)
                   if perm is not None else tuple(self.src_shape))
            arr = np.reshape(arr, mid)
        perm = self._perm(arr.ndim)
        if perm is not None:
            arr = np.transpose(arr, tuple(np.argsort(perm)))
        return arr

    def src_keys(self) -> list:
        """The foreign key(s) this rule consumes."""
        if not self.stack:
            return [self.src]
        return [self.src.format(i=self.start + r * self.stride)
                for r in range(self.stack)]


class Mapping:
    """An ordered rule table mapping one foreign checkpoint layout onto
    one native param-tree layout (see :class:`MapRule`)."""

    def __init__(self, rules: Iterable[MapRule]):
        self.rules = tuple(rules)
        dsts = [r.dst for r in self.rules]
        if len(set(dsts)) != len(dsts):
            dup = sorted({d for d in dsts if dsts.count(d) > 1})
            raise CompatError(f"mapping has duplicate native keys: {dup}")

    def to_native(self, foreign: TMapping[str, np.ndarray], *,
                  unknown: str = "error") -> Dict[str, np.ndarray]:
        """Foreign state dict -> native state dict.

        Every rule's source key(s) must be present (one-line
        :class:`CompatError` otherwise).  Foreign keys no rule consumes
        are an error under ``unknown="error"`` (strict — catches layout
        drift) and dropped under ``unknown="ignore"`` (HF checkpoints
        carry buffers like rotary ``inv_freq`` that have no native
        counterpart).
        """
        if unknown not in ("error", "ignore"):
            raise CompatError(f"unknown= must be 'error' or 'ignore', "
                              f"got {unknown!r}")
        native: Dict[str, np.ndarray] = {}
        consumed = set()
        for rule in self.rules:
            keys = rule.src_keys()
            missing = [k for k in keys if k not in foreign]
            if missing:
                shown = ", ".join(repr(k) for k in missing[:3])
                more = f" (+{len(missing) - 3} more)" if len(missing) > 3 \
                    else ""
                raise CompatError(f"checkpoint is missing {shown}{more} "
                                  f"for native key {rule.dst!r}")
            consumed.update(keys)
            if rule.stack:
                native[rule.dst] = np.stack(
                    [rule.adapt(np.asarray(foreign[k])) for k in keys])
            else:
                native[rule.dst] = rule.adapt(np.asarray(foreign[keys[0]]))
        leftover = sorted(set(foreign) - consumed)
        if leftover and unknown == "error":
            shown = ", ".join(repr(k) for k in leftover[:3])
            more = f" (+{len(leftover) - 3} more)" if len(leftover) > 3 else ""
            raise CompatError(f"checkpoint has {len(leftover)} unmapped "
                              f"key(s): {shown}{more} (pass "
                              f"unknown='ignore' to drop them)")
        return native

    def to_foreign(self, native: TMapping[str, np.ndarray]
                   ) -> Dict[str, np.ndarray]:
        """Native state dict -> foreign state dict (the export path;
        exact inverse of :meth:`to_native`)."""
        foreign: Dict[str, np.ndarray] = {}
        for rule in self.rules:
            if rule.dst not in native:
                raise CompatError(f"native state dict is missing "
                                  f"{rule.dst!r} (cannot export "
                                  f"{rule.src!r})")
            arr = np.asarray(native[rule.dst])
            if rule.stack:
                if arr.shape[0] != rule.stack:
                    raise CompatError(
                        f"{rule.dst}: leading (layers) axis is "
                        f"{arr.shape[0]}, rule stacks {rule.stack}")
                for r, key in enumerate(rule.src_keys()):
                    foreign[key] = rule.unadapt(arr[r])
            else:
                foreign[rule.src] = rule.unadapt(arr)
        return foreign

    def native_keys(self) -> list:
        return [r.dst for r in self.rules]
