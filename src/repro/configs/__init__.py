"""Architecture configs: the 10 assigned archs + the paper's ResNet workload.

Importing this package registers every arch; use
``repro.configs.base.get_arch(arch_id)`` / ``list_archs()``.
"""
from . import (base, deepseek_v3_671b, gemma2_9b, gemma3_12b,
               llama4_maverick_400b_a17b, mamba2_130m, minitron_8b,
               qwen2_vl_72b, qwen3_4b, whisper_tiny, zamba2_7b)
from .base import ArchConfig, get_arch, list_archs

__all__ = ["ArchConfig", "base", "get_arch", "list_archs"]
