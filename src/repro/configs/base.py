"""Architecture configuration system.

Every assigned architecture is a :class:`ArchConfig` built from
:class:`LayerSpec` patterns; the paper's numerics (multiplier choice /
segmented passes) is a first-class field (``numerics``) — the
"compiler-integrated accuracy knob" at system level.

Layer patterns are expressed as ``segments``: a list of
``(repeats, [LayerSpec, ...])``.  Each segment is executed as a
scan-over-repeats with params stacked on a leading ``layers`` axis, which
keeps compile time flat in depth.  ``shared=True`` specs reuse one weight
set across all repeats (zamba2's shared attention block).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

from repro.core.numerics import NumericsConfig
from repro.core.policy import Numerics


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: str = "dense"          # dense | moe | ssm
    attn: str = "global"         # global | local | mla | none
    window: int = 4096           # local-attention window
    shared: bool = False         # reuse one weight set across repeats


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 1
    n_shared: int = 0            # always-on shared experts (deepseek style)
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_size: int = 128
    head_dim: int = 64           # P
    expansion: int = 2           # d_inner = expansion * d_model
    conv_width: int = 4
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    arch_id: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    segments: Tuple[Tuple[int, Tuple[LayerSpec, ...]], ...]
    head_dim: Optional[int] = None
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # attention details
    qk_norm: bool = False
    logit_softcap: Optional[float] = None      # gemma2 style final softcap
    attn_softcap: Optional[float] = None       # gemma2 attention softcap
    rope_theta: float = 10000.0
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl M-RoPE
    # enc-dec (whisper)
    encoder_layers: int = 0
    decoder_len: int = 256        # fixed decoder length for enc-dec shapes
    enc_len: int = 1500           # encoder output length kept in serving state
    frontend: str = "none"        # none | audio_stub | vision_stub
    dense_d_ff: Optional[int] = None  # dense-layer ff when it differs from d_ff (deepseek)
    # numerics (the paper's knob): one global NumericsConfig, or a
    # NumericsPolicy mapping layer paths to configs (repro.core.policy)
    numerics: Numerics = NumericsConfig(mode="exact")
    # training/serving details
    dtype: str = "bfloat16"
    param_dtype: str = "float32"  # bfloat16 for the memory-constrained giants
    optimizer: str = "adamw"      # adamw | adafactor (giants)
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    grad_accum: int = 1
    loss_batch_chunks: int = 8    # CE loss chunking (1 = off; keep chunk rows
                                  # divisible by the batch-sharding degree)
    remat: str = "full"           # full | dots | none
    # sharding behaviour (see repro/distributed/sharding.py)
    fsdp: bool = False            # shard weight 'embed' axis over data
    seq_shard_activations: bool = True  # sequence parallelism on residual
    sharding_overrides: Optional[Tuple[Tuple[str, object], ...]] = None  # rule overrides
    moment_dtype: str = "float32" # optimizer moments (bf16 for the giants)
    # long-context capability: sub-quadratic archs run long_500k
    subquadratic: bool = False

    @property
    def n_layers(self) -> int:
        return sum(r * len(p) for r, p in self.segments)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def dense_ff(self) -> int:
        return self.dense_d_ff or self.d_ff

    def layer_specs(self):
        """Flat list of LayerSpec in execution order (for reference/counting)."""
        out = []
        for repeats, pattern in self.segments:
            for _ in range(repeats):
                out.extend(pattern)
        return out

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab
        hd = self.resolved_head_dim
        total = v * d * (1 if self.tie_embeddings else 2)
        for repeats, pattern in self.segments:
            seg = 0
            for spec in pattern:
                if spec.kind == "ssm":
                    s = self.ssm
                    din = s.expansion * d
                    nheads = din // s.head_dim
                    seg_p = d * (2 * din + 2 * s.state_size + nheads) + din * d
                    seg_p += s.conv_width * din + 2 * nheads
                elif spec.kind in ("dense", "moe"):
                    if spec.attn == "mla":
                        m = self.mla
                        qd = self.n_heads * (m.nope_head_dim + m.rope_head_dim)
                        seg_p = d * m.q_lora_rank + m.q_lora_rank * qd
                        seg_p += d * (m.kv_lora_rank + m.rope_head_dim)
                        seg_p += m.kv_lora_rank * self.n_heads * (m.nope_head_dim + m.v_head_dim)
                        seg_p += self.n_heads * m.v_head_dim * d
                    elif spec.attn == "none":
                        seg_p = 0
                    else:
                        seg_p = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
                    if spec.kind == "moe":
                        e = self.moe
                        seg_p += d * e.n_experts  # router
                        seg_p += 3 * d * ff * (e.n_experts + e.n_shared)
                    else:
                        seg_p += 3 * d * ff
                else:
                    raise ValueError(spec.kind)
                seg += seg_p
            total += seg * (repeats if not all(s.shared for s in pattern) else 1)
        if self.encoder_layers:
            # whisper-style encoder blocks + cross-attention in decoder
            enc = self.encoder_layers * (4 * d * d + 3 * d * ff)
            cross = self.n_layers * 4 * d * d
            total += enc + cross
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k + shared experts only)."""
        if not self.moe:
            return self.param_count()
        e = self.moe
        active = dataclasses.replace(
            self, moe=dataclasses.replace(e, n_experts=e.top_k))
        # param_count counts (n_experts + n_shared) expert MLPs + router;
        # replacing n_experts with top_k yields the active set. Router cost
        # (d*E) is negligible either way.
        return active.param_count()

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        def cut_pattern(pattern):
            return tuple(
                dataclasses.replace(s, window=min(s.window, 64)) for s in pattern
            )

        segs = tuple((min(r, 2), cut_pattern(p)) for r, p in self.segments)
        small_heads = max(2, min(4, self.n_heads))
        kv = max(1, min(self.n_kv_heads, small_heads))
        return dataclasses.replace(
            self,
            d_model=64,
            n_heads=small_heads,
            n_kv_heads=kv,
            head_dim=16,
            d_ff=128,
            vocab=256,
            segments=segs,
            # generous capacity: smoke tests check cache/step consistency,
            # which capacity drops would (legitimately) perturb
            moe=dataclasses.replace(self.moe, n_experts=4,
                                    top_k=min(2, self.moe.top_k),
                                    capacity_factor=4.0)
            if self.moe
            else None,
            mla=MLAConfig(kv_lora_rank=32, q_lora_rank=48, rope_head_dim=8,
                          nope_head_dim=16, v_head_dim=16)
            if self.mla
            else None,
            ssm=dataclasses.replace(self.ssm, state_size=16, head_dim=8, chunk=16)
            if self.ssm
            else None,
            mrope_sections=(2, 3, 3) if self.mrope_sections else None,  # half=8
            encoder_layers=min(self.encoder_layers, 2),
            decoder_len=32,
            enc_len=64,
            grad_accum=1,
            fsdp=False,
            seq_shard_activations=False,
            dtype="float32",   # tight numerics for CPU smoke assertions
            dense_d_ff=128 if self.dense_d_ff else None,
            remat="none",
        )


_REGISTRY: dict[str, ArchConfig] = {}


def register_arch(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.arch_id] = cfg
    return cfg


def get_arch(arch_id: str) -> ArchConfig:
    # import the config modules lazily so registration happens on first use
    from repro import configs as _c  # noqa: F401

    if arch_id not in _REGISTRY:
        raise ValueError(f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]


def list_archs() -> list[str]:
    from repro import configs as _c  # noqa: F401

    return sorted(_REGISTRY)
