"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8 [arXiv:2412.19437].

First 3 layers dense (d_ff 18432 per the DSv3 paper), remaining 58 MoE with
2048-wide experts.  MTP (multi-token prediction) head is not reproduced
(noted in DESIGN.md §Arch-applicability).
"""
from repro.configs.base import (ArchConfig, LayerSpec, MLAConfig, MoEConfig,
                                register_arch)

CONFIG = register_arch(ArchConfig(
    arch_id="deepseek-v3-671b",
    family="moe",
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,
    d_ff=2048,            # expert intermediate size
    dense_d_ff=18432,     # the 3 dense layers
    vocab=129280,
    segments=(
        (3, (LayerSpec(kind="dense", attn="mla"),)),
        (58, (LayerSpec(kind="moe", attn="mla"),)),
    ),
    moe=MoEConfig(n_experts=256, top_k=8, n_shared=1, capacity_factor=1.25),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536, rope_head_dim=64,
                  nope_head_dim=128, v_head_dim=128),
    rope_theta=10000.0,
    fsdp=True,
    optimizer="adafactor",
    param_dtype="bfloat16",
    grad_accum=8,
))
