"""gemma2-9b [dense] — 1:1 local:global alternating, logit softcaps
[arXiv:2408.00118]."""
from repro.configs.base import ArchConfig, LayerSpec, register_arch

CONFIG = register_arch(ArchConfig(
    arch_id="gemma2-9b",
    family="dense",
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab=256000,
    segments=((21, (LayerSpec(kind="dense", attn="local", window=4096),
                    LayerSpec(kind="dense", attn="global"))),),
    logit_softcap=30.0,
    attn_softcap=50.0,
    tie_embeddings=True,
))
