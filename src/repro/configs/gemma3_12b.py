"""gemma3-12b [dense] — 5:1 local:global, 128k context, qk-norm
[hf:google/gemma-3 family]."""
from repro.configs.base import ArchConfig, LayerSpec, register_arch

CONFIG = register_arch(ArchConfig(
    arch_id="gemma3-12b",
    family="dense",
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab=262144,
    segments=((8, (LayerSpec(kind="dense", attn="local", window=1024),) * 5
                  + (LayerSpec(kind="dense", attn="global"),)),),
    qk_norm=True,
    rope_theta=1000000.0,
    tie_embeddings=True,
))
