"""llama4-maverick-400b-a17b [moe] — 128e top-1 + shared expert, early fusion
[hf:meta-llama/Llama-4 family]."""
from repro.configs.base import ArchConfig, LayerSpec, MoEConfig, register_arch

CONFIG = register_arch(ArchConfig(
    arch_id="llama4-maverick-400b-a17b",
    family="moe",
    d_model=5120,
    n_heads=40,          # not divisible by model=16 -> heads replicate; fsdp
    n_kv_heads=8,        # covers the attention weights instead (DESIGN.md §5)
    head_dim=128,
    d_ff=8192,
    dense_d_ff=16384,     # the interleaved dense layers
    vocab=202048,
    # MoE every 2nd layer (interleave step 2) — this is what makes the model
    # 400B total / 17B active; 48 layers = 24 x (moe, dense)
    segments=((24, (LayerSpec(kind="moe", attn="global"),
                    LayerSpec(kind="dense", attn="global"))),),
    moe=MoEConfig(n_experts=128, top_k=1, n_shared=1, capacity_factor=1.25),
    rope_theta=500000.0,
    fsdp=True,
    optimizer="adafactor",
    param_dtype="bfloat16",
    grad_accum=8,
))
