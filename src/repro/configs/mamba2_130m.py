"""mamba2-130m [ssm] — SSD, attention-free [arXiv:2405.21060]."""
from repro.configs.base import ArchConfig, LayerSpec, SSMConfig, register_arch

CONFIG = register_arch(ArchConfig(
    arch_id="mamba2-130m",
    family="ssm",
    d_model=768,
    n_heads=12,          # unused (attention-free); kept for API uniformity
    n_kv_heads=12,
    d_ff=0,              # no MLP blocks — SSD blocks only
    vocab=50280,
    segments=((24, (LayerSpec(kind="ssm", attn="none"),)),),
    ssm=SSMConfig(state_size=128, head_dim=64, expansion=2, conv_width=4, chunk=128),
    tie_embeddings=True,
    subquadratic=True,
))
