"""minitron-8b [dense] — width/depth-pruned nemotron [arXiv:2407.14679]."""
from repro.configs.base import ArchConfig, LayerSpec, register_arch

CONFIG = register_arch(ArchConfig(
    arch_id="minitron-8b",
    family="dense",
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=256000,
    segments=((32, (LayerSpec(kind="dense", attn="global"),)),),
))
