"""qwen2-vl-72b [vlm] — M-RoPE, dynamic resolution; the vision tower is a
STUB (input_specs feeds precomputed patch embeddings + 3D positions)
[arXiv:2409.12191]."""
from repro.configs.base import ArchConfig, LayerSpec, register_arch

CONFIG = register_arch(ArchConfig(
    arch_id="qwen2-vl-72b",
    family="vlm",
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab=152064,
    segments=((80, (LayerSpec(kind="dense", attn="global"),)),),
    mrope_sections=(16, 24, 24),   # (t, h, w) frequency bands of half=64
    rope_theta=1000000.0,
    frontend="vision_stub",
    fsdp=True,
    optimizer="adafactor",
    param_dtype="bfloat16",
    grad_accum=4,
))
