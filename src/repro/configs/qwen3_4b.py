"""qwen3-4b [dense] — GQA with qk-norm [hf:Qwen/Qwen3 family]."""
from repro.configs.base import ArchConfig, LayerSpec, register_arch

CONFIG = register_arch(ArchConfig(
    arch_id="qwen3-4b",
    family="dense",
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9728,
    vocab=151936,
    segments=((36, (LayerSpec(kind="dense", attn="global"),)),),
    qk_norm=True,
    rope_theta=1000000.0,
    tie_embeddings=True,
))
