"""whisper-tiny [audio] — enc-dec transformer backbone; the conv/mel frontend
is a STUB (input_specs feeds precomputed frame embeddings) [arXiv:2212.04356]."""
from repro.configs.base import ArchConfig, LayerSpec, register_arch

CONFIG = register_arch(ArchConfig(
    arch_id="whisper-tiny",
    family="audio",
    d_model=384,
    n_heads=6,           # 6 heads -> replicated under model=16 (divisibility rule)
    n_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab=51865,
    segments=((4, (LayerSpec(kind="dense", attn="global"),)),),  # decoder
    encoder_layers=4,
    decoder_len=256,
    frontend="audio_stub",
    seq_shard_activations=False,   # tiny model; collective overhead dominates
))
