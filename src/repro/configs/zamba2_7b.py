"""zamba2-7b [hybrid] — Mamba2 backbone + SHARED attention block applied
periodically (weights reused, caches per application) [arXiv:2411.15242].

81 blocks = 13 x (5 mamba + 1 shared-attn) + 3 mamba.
"""
from repro.configs.base import ArchConfig, LayerSpec, SSMConfig, register_arch

CONFIG = register_arch(ArchConfig(
    arch_id="zamba2-7b",
    family="hybrid",
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab=32000,
    segments=(
        (13, (LayerSpec(kind="ssm", attn="none"),) * 5
             + (LayerSpec(kind="dense", attn="global", shared=True),)),
        (3, (LayerSpec(kind="ssm", attn="none"),)),
    ),
    ssm=SSMConfig(state_size=64, head_dim=64, expansion=2, conv_width=4, chunk=128),
    subquadratic=True,
))
