"""Core paper contribution: accuracy-configurable FP multiplication for CiM.

Public surface:
  formats      — FloatFormat descriptions + bit-level helpers
  exact_mult   — IEEE 754 exact multiplier (oracle + device)
  afpm         — mantissa-segmentation AFPM (AC-n-n) + ACL low-precision mode
  baselines    — MMBS / CSS / NC-LPC-HPC comparison designs
  registry     — named multiplier library (the OpenACM operator library role)
  numerics     — NumericsConfig + nmatmul dispatch (compiler integration)
  scope        — thread-local numerics_scope/layer_scope stacks (the
                 ambient-configuration machinery behind repro.numerics)
  policy       — per-layer NumericsPolicy (glob rules over layer paths)
  sweep        — accuracy-PPA sweep + budget-driven auto-configuration
  metrics      — MRED / NMED / PSNR / top-k
  ppa          — analytical gate-equivalent PPA model (Table II stand-in)
"""
from . import (afpm, baselines, exact_mult, formats, metrics, numerics,
               policy, ppa, registry, scope)
from .afpm import AFPMConfig, afpm_matmul_emulated, afpm_mult_f32
from .numerics import EXACT, NumericsConfig, nmatmul, segmented_matmul_xla
from .policy import NumericsPolicy, PolicyRule
from .registry import available, get_multiplier

__all__ = [
    "AFPMConfig",
    "EXACT",
    "NumericsConfig",
    "afpm",
    "afpm_matmul_emulated",
    "afpm_mult_f32",
    "available",
    "baselines",
    "exact_mult",
    "formats",
    "get_multiplier",
    "metrics",
    "NumericsPolicy",
    "PolicyRule",
    "nmatmul",
    "numerics",
    "policy",
    "ppa",
    "registry",
    "scope",
    "segmented_matmul_xla",
]
