"""Mantissa-segmentation approximate floating-point multiplier (paper §III-B).

Implements the paper's AC-n-n design bit-faithfully, vectorized in JAX
(uint32 arithmetic only, so it runs identically on CPU and on the TPU VPU):

* the explicit mantissa is segmented into a high part ``A`` (top ``n``
  bits) and a low part ``B`` (next ``n`` bits); lower bits are truncated
  (Eq. 5);
* partial products: ``AC`` always exact; ``AD``/``BC`` conditionally
  executed — bypassed when the low-segment operand (``D`` resp. ``B``)
  has its upper ``n-2`` bits all zero, with a shift-based compensation
  ``A<<1`` / ``C<<1`` when the bypassed operand is non-zero;
* special cases: ``A==0 & B,C!=0`` forces ``BC``; ``C==0 & A,D!=0``
  forces ``AD``;
* the ``BD`` partial product is always omitted (Eq. 6);
* shift-and-add accumulation into a ``3n``-fractional-bit accumulator;
  the linear terms ``1 + Mx + My`` use the mantissas truncated to their
  upper ``3n`` bits (Fig. 3);
* normalization decided by the two integer bits of the accumulator
  (product in ``[1, 4)``), mantissa zero-padded back to the format width.

The ``ACL-n`` low-precision mode replaces the whole mantissa-product term
with the paper's bitwise-AND first-order approximation: the partial sum is
``A_x + A_y + (A_x & A_y)`` at weight ``2^-n`` with an ``n``-bit
accumulator (§III-B last paragraph).

Approximate modes flush subnormal inputs/outputs to zero (underflow is
"typically set to ±0" in the paper) and propagate inf/nan IEEE-style.

Everything here is elementwise and differentiable-opt-out (a
straight-through ``custom_jvp`` is provided so the emulated numerics can
sit inside a training graph for finetuning studies).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from .formats import FP32, FloatFormat, get_format

_U1 = jnp.uint32(1)


def _decode(x, fmt: FloatFormat):
    """float32 -> (sign, biased exp field, mantissa field aligned to fmt.man_bits)."""
    bits = jax.lax.bitcast_convert_type(jnp.asarray(x, jnp.float32), jnp.uint32)
    man32 = bits & jnp.uint32((1 << 23) - 1)
    exp32 = (bits >> 23) & jnp.uint32(0xFF)
    sign = bits >> 31
    if fmt.man_bits == 23 and fmt.exp_bits == 8:
        return sign, exp32, man32
    # operate in the narrower storage format: truncate mantissa, rebias exp
    man = man32 >> (23 - fmt.man_bits)
    e_unb = exp32.astype(jnp.int32) - 127
    exp = jnp.clip(e_unb + fmt.bias, 0, fmt.max_exp_field).astype(jnp.uint32)
    # flush values outside fmt's normal range (approx path flushes subnormals)
    man = jnp.where((exp == 0) | (exp == fmt.max_exp_field), jnp.uint32(0), man)
    # preserve inf/nan class from fp32
    exp = jnp.where(exp32 == 255, jnp.uint32(fmt.max_exp_field), exp)
    man = jnp.where((exp32 == 255) & (man32 != 0), _U1, man)
    return sign, exp, man


def _encode_f32(sign, e_unb, man_fmt, fmt: FloatFormat):
    """(sign, unbiased exp, fmt-width mantissa) -> float32 value."""
    man32 = jnp.asarray(man_fmt, jnp.uint32) << (23 - fmt.man_bits)
    exp32 = jnp.asarray(e_unb + 127, jnp.uint32)
    bits = (jnp.asarray(sign, jnp.uint32) << 31) | (exp32 << 23) | man32
    return jax.lax.bitcast_convert_type(bits, jnp.float32)


@dataclasses.dataclass(frozen=True)
class AFPMConfig:
    """Configuration knob exposed to the compiler flow (paper §III-B)."""

    n: int = 5                 # segment width
    mode: str = "ac"           # "ac" (AC-n-n) or "acl" (low-precision mode)
    fmt: str = "fp32"          # storage format name (fp32/bf16/fp16/afp24/...)
    skip_bd: bool = True       # paper: BD always omitted (kept as a knob for ablation)
    conditional: bool = True   # conditional execution of AD/BC
    compensation: bool = True  # shift-based compensation of bypassed terms

    @property
    def label(self) -> str:
        if self.mode == "acl":
            return f"ACL{self.n}"
        return f"AC{self.n}-{self.n}"

    def format(self) -> FloatFormat:
        return get_format(self.fmt)


def _ac_mantissa_product(mx, my, n: int, M: int, cfg: AFPMConfig):
    """Approximate cross term ``Mx*My`` in units of ``2^-3n`` (uint32).

    ``mx``/``my`` are the explicit mantissa fields (width ``M``).
    Returns an integer ``cross`` such that ``Mx*My ~= cross * 2^-3n``.
    """
    # segments (Eq. 5): A/C = top n bits, B/D = next n bits
    A = (mx >> (M - n)).astype(jnp.uint32)
    B = ((mx >> max(M - 2 * n, 0)) & jnp.uint32((1 << n) - 1)).astype(jnp.uint32)
    C = (my >> (M - n)).astype(jnp.uint32)
    D = ((my >> max(M - 2 * n, 0)) & jnp.uint32((1 << n) - 1)).astype(jnp.uint32)

    AC = A * C
    AD = A * D
    BC = B * C
    BD = B * D

    if cfg.conditional:
        # bypass when the upper (n-2) bits of the low operand are all zero
        d_small = (D >> 2) == 0
        b_small = (B >> 2) == 0
        # special-case forcing (paper): A==0 & B,C!=0 -> force BC;
        #                               C==0 & A,D!=0 -> force AD
        force_ad = (C == 0) & (A != 0) & (D != 0)
        force_bc = (A == 0) & (C != 0) & (B != 0)
        exec_ad = (~d_small) | force_ad
        exec_bc = (~b_small) | force_bc
        if cfg.compensation:
            # bypassed multiply ~ operand approximated by the constant 2 -> A<<1
            comp_ad = jnp.where((A != 0) & (D != 0), A << 1, jnp.uint32(0))
            comp_bc = jnp.where((C != 0) & (B != 0), C << 1, jnp.uint32(0))
        else:
            comp_ad = jnp.uint32(0)
            comp_bc = jnp.uint32(0)
        ad_term = jnp.where(exec_ad, AD, comp_ad)
        bc_term = jnp.where(exec_bc, BC, comp_bc)
    else:
        ad_term, bc_term = AD, BC

    cross = (AC << n) + ad_term + bc_term
    if not cfg.skip_bd:
        cross = cross + (BD >> n)  # BD sits n bits below the accumulator lsb
    return cross


def afpm_mult_f32(x, y, cfg: AFPMConfig):
    """Elementwise approximate multiply, bit-faithful to the paper's datapath.

    Operates on float32 carriers; if ``cfg.fmt`` is narrower the operands
    are first truncated into that storage format (the CiM array stores
    them at that width).
    """
    fmt = cfg.format()
    n, M = cfg.n, fmt.man_bits
    if cfg.mode not in ("ac", "acl"):
        raise ValueError(f"unknown AFPM mode {cfg.mode!r}")
    if cfg.mode == "ac" and M < 2 * n:
        raise ValueError(f"mantissa of {fmt.name} too narrow for 2 segments of n={n}")
    if cfg.mode == "acl" and M < n:
        raise ValueError(f"mantissa of {fmt.name} too narrow for n={n}")

    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    sx, ex, mx = _decode(x, fmt)
    sy, ey, my = _decode(y, fmt)
    s_res = sx ^ sy

    if cfg.mode == "ac":
        T = min(3 * n, M)  # accumulator fractional width (3n, clipped to mantissa)
        U = jnp.uint32(1 << T)
        cross = _ac_mantissa_product(mx, my, n, M, cfg)
        cross_t = cross >> (3 * n - T) if 3 * n > T else cross << (T - 3 * n)
        # linear terms use mantissas truncated to their upper 3n bits (Fig. 3)
        mx_t = (mx >> (M - T)).astype(jnp.uint32)
        my_t = (my >> (M - T)).astype(jnp.uint32)
        acc = U + mx_t + my_t + cross_t  # (1 + Mx)(1 + My) approx, in 2^-T units
    else:  # ACL-n: partial sum = A_x + A_y + (A_x & A_y), n-bit accumulator
        T = n
        U = jnp.uint32(1 << T)
        A = (mx >> (M - n)).astype(jnp.uint32)
        Cseg = (my >> (M - n)).astype(jnp.uint32)
        acc = U + A + Cseg + (A & Cseg)

    # normalization from the two integer bits of the accumulator (prod in [1,4))
    ge2 = acc >= (U << 1)
    acc_n = jnp.where(ge2, acc >> 1, acc)  # in [U, 2U)
    man_acc = acc_n - U  # T fractional bits
    # zero-padded back to the format mantissa width (T <= M always here)
    man_res = (man_acc << (M - T)).astype(jnp.uint32)

    e_unb = (
        ex.astype(jnp.int32)
        - fmt.bias
        + ey.astype(jnp.int32)
        - fmt.bias
        + ge2.astype(jnp.int32)
    )

    res = _encode_f32(s_res, e_unb, man_res, fmt)

    # exception handling (overflow -> inf, underflow -> 0; paper §III-A rules)
    e_min = 1 - fmt.bias
    e_max = fmt.max_exp_field - 1 - fmt.bias
    sgn = jnp.where(s_res == 1, -1.0, 1.0).astype(jnp.float32)
    res = jnp.where(e_unb > e_max, sgn * jnp.inf, res)
    res = jnp.where(e_unb < e_min, sgn * 0.0, res)

    # special operands: zero/subnormal-flush, inf, nan
    x_fin = jnp.isfinite(x)
    y_fin = jnp.isfinite(y)
    x_zero = (ex == 0)  # true zero or flushed subnormal
    y_zero = (ey == 0)
    res = jnp.where((x_zero | y_zero) & x_fin & y_fin, sgn * 0.0, res)
    inf_in = jnp.isinf(x) | jnp.isinf(y)
    res = jnp.where(inf_in, sgn * jnp.inf, res)
    res = jnp.where(
        jnp.isnan(x) | jnp.isnan(y) | (inf_in & (x_zero | y_zero)), jnp.nan, res
    )
    return res


# -- straight-through estimator wrapper (lets emulated numerics live in -----
# -- a training graph: forward = AFPM, backward = exact product rule) -------

@partial(jax.custom_jvp, nondiff_argnums=(2,))
def afpm_mult_ste(x, y, cfg: AFPMConfig):
    return afpm_mult_f32(x, y, cfg)


@afpm_mult_ste.defjvp
def _afpm_mult_jvp(cfg, primals, tangents):
    x, y = primals
    dx, dy = tangents
    return afpm_mult_f32(x, y, cfg), x * dy + y * dx


def afpm_matmul_emulated(x, w, cfg: AFPMConfig, k_chunk: int = 64):
    """Matmul where every scalar product goes through the bit-level AFPM.

    Memory-bounded by chunking the contraction axis: per chunk the
    elementwise products ``x[..., k] * w[k, :]`` are materialized as a
    ``(..., k_chunk, N)`` block and summed in fp32.  This is the
    paper-faithful semantics for Tables III/IV (accumulation in the CiM
    macro is exact; only the multipliers are approximate).
    """
    x = jnp.asarray(x, jnp.float32)
    w = jnp.asarray(w, jnp.float32)
    K = x.shape[-1]
    assert w.shape[0] == K, (x.shape, w.shape)
    pad = (-K) % k_chunk
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
        w = jnp.pad(w, [(0, pad), (0, 0)])
    nchunks = (K + pad) // k_chunk
    xs = x.reshape(x.shape[:-1] + (nchunks, k_chunk))
    ws = w.reshape(nchunks, k_chunk, w.shape[-1])

    def body(carry, kc):
        xk, wk = kc  # (..., k_chunk), (k_chunk, N)
        prods = afpm_mult_ste(xk[..., :, None], wk, cfg)
        return carry + jnp.sum(prods, axis=-2), None

    init = jnp.zeros(x.shape[:-1] + (w.shape[-1],), jnp.float32)
    xs_m = jnp.moveaxis(xs, -2, 0)  # (nchunks, ..., k_chunk)
    out, _ = jax.lax.scan(body, init, (xs_m, ws))
    return out
