"""Baseline approximate FP multipliers the paper compares against (§II, Tables II-IV).

Re-implemented from their cited descriptions:

* **MMBS-k** (Li et al., TENCON 2020 [7]) — mantissa-bit-segmentation:
  both explicit mantissas are cut to their top ``k`` bits (with a
  half-ULP compensation constant so truncation bias becomes zero-mean);
  the mantissa cross product is computed exactly on the k-bit segments and
  the linear terms stay exact.  Runtime-configurable ``k``.
* **CSS-m** (Di Meo et al., Electronics 2022 [6]) — static segmentation:
  the significand product is restructured into multiply-and-accumulate on
  two balanced static segments of ``m/2`` bits per operand (the published
  parameterization counts total segment bits ``m``), with an LSB ``1``
  steering/compensation term.
* **NC / LPC / HPC** (Li et al., TCAS-II 2024 [5]) — Mitchell logarithmic
  multiplier (``log2(1+x) ~ x``) with no / low-precision / high-precision
  error compensation.  LPC adds the optimal constant compensation; HPC
  adds an AND-based first-order term plus the constant refinement, which
  reproduces the published error hierarchy (NC ~4e-2, LPC ~3e-2,
  HPC ~7e-3 MRED).

All of them share the exact sign/exponent path and the paper's exception
rules (overflow to inf, underflow/subnormal flush to zero).  Like
``repro.core.afpm`` they are uint32-only and vectorized.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .formats import FP32

_U1 = jnp.uint32(1)


def _decode_f32(x):
    bits = jax.lax.bitcast_convert_type(jnp.asarray(x, jnp.float32), jnp.uint32)
    return bits >> 31, (bits >> 23) & jnp.uint32(0xFF), bits & jnp.uint32((1 << 23) - 1)


def _assemble(sign, e_unb, man23, x, y, ex, ey):
    """Shared exception handling + assembly for all baselines (fp32)."""
    exp32 = jnp.asarray(e_unb + 127, jnp.uint32)
    bits = (jnp.asarray(sign, jnp.uint32) << 31) | (exp32 << 23) | jnp.asarray(man23, jnp.uint32)
    res = jax.lax.bitcast_convert_type(bits, jnp.float32)
    sgn = jnp.where(sign == 1, -1.0, 1.0).astype(jnp.float32)
    res = jnp.where(e_unb > 127, sgn * jnp.inf, res)
    res = jnp.where(e_unb < -126, sgn * 0.0, res)
    x_fin = jnp.isfinite(x)
    y_fin = jnp.isfinite(y)
    zero_in = (ex == 0) | (ey == 0)
    res = jnp.where(zero_in & x_fin & y_fin, sgn * 0.0, res)
    inf_in = jnp.isinf(x) | jnp.isinf(y)
    res = jnp.where(inf_in, sgn * jnp.inf, res)
    res = jnp.where(jnp.isnan(x) | jnp.isnan(y) | (inf_in & zero_in), jnp.nan, res)
    return res


def _norm_from_frac(frac_num, frac_den_log2):
    """Normalize ``1+Mx+My+P`` style sums: value = frac_num * 2^-frac_den_log2 in [1,4)."""
    U = jnp.uint32(1 << frac_den_log2)
    ge2 = frac_num >= (U << 1)
    acc = jnp.where(ge2, frac_num >> 1, frac_num) - U
    return ge2.astype(jnp.int32), acc


# ---------------------------------------------------------------------------
# MMBS-k
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MMBSConfig:
    k: int = 6

    @property
    def label(self) -> str:
        return f"MMBS{self.k}"


def mmbs_mult_f32(x, y, cfg: MMBSConfig):
    k = cfg.k
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    sx, ex, mx = _decode_f32(x)
    sy, ey, my = _decode_f32(y)
    s_res = sx ^ sy

    # top-k segments with half-ULP (in segment units: +0.5 -> fixed-point x2)
    A = (mx >> (23 - k)).astype(jnp.uint32)
    C = (my >> (23 - k)).astype(jnp.uint32)
    # cross product on compensated segments: (A+0.5)(C+0.5) in 2^-2k units
    # = AC + (A+C)/2 + 0.25  -> scale x4 to stay integral: 4AC + 2(A+C) + 1
    cross4 = (A * C << 2) + ((A + C) << 1) + jnp.uint32(1)  # units 2^-(2k+2)
    T = min(2 * k + 2, 23)
    mx_t = (mx >> (23 - T)).astype(jnp.uint32)
    my_t = (my >> (23 - T)).astype(jnp.uint32)
    acc = jnp.uint32(1 << T) + mx_t + my_t + (cross4 >> (2 * k + 2 - T))
    inc, man_acc = _norm_from_frac(acc, T)
    man_res = man_acc << (23 - T)
    e_unb = ex.astype(jnp.int32) - 127 + ey.astype(jnp.int32) - 127 + inc
    return _assemble(s_res, e_unb, man_res, x, y, ex, ey)


# ---------------------------------------------------------------------------
# CSS-m
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CSSConfig:
    m: int = 16  # total static-segment bits (m/2 per operand)

    @property
    def label(self) -> str:
        return f"CSS{self.m}"


def css_mult_f32(x, y, cfg: CSSConfig):
    # Calibration note (DESIGN.md §7): per-operand static segment width is
    # m//2 + 2 significand bits (hidden bit included) with a half-ULP
    # compensation term — this reproduces the published MRED curve
    # (CSS12..CSS18) within ~1.4x with the correct ranking.
    s = cfg.m // 2 + 2
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    sx, ex, mx = _decode_f32(x)
    sy, ey, my = _decode_f32(y)
    s_res = sx ^ sy

    sig_x = mx | jnp.uint32(1 << 23)  # 24-bit significand 1.M
    sig_y = my | jnp.uint32(1 << 23)
    A = (sig_x >> (24 - s)).astype(jnp.uint32)  # top s bits, MSB=1 (static segment)
    C = (sig_y >> (24 - s)).astype(jnp.uint32)
    # half-ULP compensated product: (A+.5)(C+.5) -> (2A+1)(2C+1) / 2^(2s)
    prod = ((A << 1) + _U1) * ((C << 1) + _U1)  # in [2^2s, 2^(2s+2)), units 2^-2s
    inc, man_acc = _norm_from_frac(prod, 2 * s)
    T = min(2 * s, 23)
    man_res = (man_acc >> max(2 * s - T, 0)) << (23 - T)
    e_unb = ex.astype(jnp.int32) - 127 + ey.astype(jnp.int32) - 127 + inc
    return _assemble(s_res, e_unb, man_res, x, y, ex, ey)


# ---------------------------------------------------------------------------
# NC / LPC / HPC (logarithmic, Mitchell-based)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LogConfig:
    comp: str = "nc"  # "nc" | "lpc" | "hpc"

    @property
    def label(self) -> str:
        return self.comp.upper()


def log_mult_f32(x, y, cfg: LogConfig):
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    sx, ex, mx = _decode_f32(x)
    sy, ey, my = _decode_f32(y)
    s_res = sx ^ sy

    # Mitchell antilog: value = 2^(ex+ey) * (1 + L) for L < 1,
    #                   value = 2^(ex+ey+1) * (1 + (L-1)) for L >= 1
    # (the fraction is NOT halved in the carry case — that is what makes
    # Mitchell's error one-sided in [-11.1%, 0]).
    U = jnp.uint32(1 << 23)
    L = mx.astype(jnp.uint32) + my.astype(jnp.uint32)  # units 2^-23, in [0, 2)
    carry = L >= U
    # exact error of Mitchell: mx*my (no carry) / (1-mx)(1-my) (carry) — the
    # compensation levels of [5] approximate this region-wise term.
    if cfg.comp == "nc":
        comp = jnp.uint32(0)
    elif cfg.comp == "lpc":
        # low-precision: the optimal constant E[err] = 1/12 in both regions
        comp = jnp.uint32((1 << 23) // 12)
    elif cfg.comp == "hpc":
        # high-precision: half-ULP-compensated 3x3 product of the top
        # mantissa bits (complemented in the carry region): err ~ (hx+.5)(hy+.5)/64
        hx = jnp.where(carry, (~mx & (U - _U1)) >> 20, mx >> 20)
        hy = jnp.where(carry, (~my & (U - _U1)) >> 20, my >> 20)
        comp = (((hx << 1) + _U1) * ((hy << 1) + _U1)) << 15  # units 2^-23
    else:
        raise ValueError(cfg.comp)
    # in the carry region the result is renormalized by 2^1, so the error
    # (1-mx)(1-my) appears halved at the output mantissa scale
    comp = jnp.where(carry, comp >> 1, comp)
    acc = jnp.where(carry, L - U, L) + comp
    # compensation may push the fraction past 1.0 — this is a true significand
    # overflow (unlike Mitchell's antilog carry), so the fraction halves
    acc_ovf = acc >= U
    man_acc = jnp.where(acc_ovf, (acc - U) >> 1, acc)
    inc = carry.astype(jnp.int32) + acc_ovf.astype(jnp.int32)
    e_unb = ex.astype(jnp.int32) - 127 + ey.astype(jnp.int32) - 127 + inc
    return _assemble(s_res, e_unb, man_acc, x, y, ex, ey)
