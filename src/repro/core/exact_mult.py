"""Exact IEEE 754-compliant floating-point multiplier (paper §III-A).

This is the correctness-preserving baseline of the paper: the full
five-stage pipeline — sign XOR, exponent accumulation with bias
correction, full significand product, normalization, and round-to-nearest
ties-to-even with overflow/underflow handling.

Two implementations:

* :func:`np_exact_mult_bits` — bit-level numpy oracle, generic over
  :class:`~repro.core.formats.FloatFormat` (int64 headroom covers the
  48-bit single-precision significand product).  For ``fp32`` it is
  bit-identical to the host multiplier (verified by tests, including
  subnormals, signed zeros, inf/nan).
* :func:`exact_mult_f32` — device-side exact multiply.  On any IEEE
  hardware (CPU/TPU fp32) the native multiply *is* the exact multiplier,
  so this is simply ``x * y`` — documented here so that the numerics
  dispatch table has an explicit "exact" entry mirroring the paper's
  baseline row.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .formats import FP32, FloatFormat, np_decode, np_encode


def _normalize_subnormal(exp: np.ndarray, man: np.ndarray, fmt: FloatFormat):
    """Return (unbiased_exp, significand) for possibly-subnormal operands."""
    man = man.astype(np.int64)
    is_sub = exp == 0
    # normal: sig = 1.man, unbiased e = exp - bias
    sig_n = man | (1 << fmt.man_bits)
    e_n = exp.astype(np.int64) - fmt.bias
    # subnormal: 0.man * 2^(1-bias): renormalize by shifting the leading one
    # up to the hidden-bit position (shift = man_bits + 1 - bit_length(man)).
    blen = np.vectorize(lambda v: int(v).bit_length(), otypes=[np.int64])(man)
    shift = fmt.man_bits + 1 - blen
    sig_s = np.where(man > 0, man << np.maximum(shift, 0), 0)
    e_s = (1 - fmt.bias) - shift
    sig = np.where(is_sub, sig_s, sig_n)
    e = np.where(is_sub, e_s, e_n)
    return e, sig


def np_exact_mult_bits(xb: np.ndarray, yb: np.ndarray, fmt: FloatFormat = FP32) -> np.ndarray:
    """Multiply two ``fmt``-encoded integer arrays; return ``fmt``-encoded bits."""
    xb = np.asarray(xb, np.int64)
    yb = np.asarray(yb, np.int64)
    sx, ex, mx = np_decode(xb, fmt)
    sy, ey, my = np_decode(yb, fmt)
    s_res = sx ^ sy  # Eq. (2)

    x_zero = (ex == 0) & (mx == 0)
    y_zero = (ey == 0) & (my == 0)
    x_inf = (ex == fmt.max_exp_field) & (mx == 0)
    y_inf = (ey == fmt.max_exp_field) & (my == 0)
    x_nan = (ex == fmt.max_exp_field) & (mx != 0)
    y_nan = (ey == fmt.max_exp_field) & (my != 0)

    e_x, sig_x = _normalize_subnormal(ex, mx, fmt)
    e_y, sig_y = _normalize_subnormal(ey, my, fmt)

    # significand product: [2^(2m), 2^(2m+2)) for normal inputs  -- Eq. (4)
    prod = sig_x * sig_y  # fits int64 for man_bits <= 23 (48 bits)
    m = fmt.man_bits
    carry = prod >= (1 << (2 * m + 1))
    e_res = e_x + e_y + carry.astype(np.int64)  # Eq. (3) done in unbiased space
    # align so the hidden bit sits at position 2m (after optional carry shift)
    prod_n = np.where(carry, prod, prod << 1)  # hidden bit now at 2m+1
    # prod_n in [2^(2m+1), 2^(2m+2)); significand value = prod_n * 2^-(2m+1)

    ebiased = e_res + fmt.bias

    # gradual underflow: if ebiased < 1, shift right extra (1 - ebiased) bits
    extra = np.clip(1 - ebiased, 0, 2 * m + 3)
    shift_total = (m + 1) + extra  # bits to drop from prod_n to keep man_bits+1
    kept = prod_n >> shift_total
    # round to nearest, ties to even
    round_bit = (prod_n >> (shift_total - 1)) & 1
    sticky = (prod_n & ((1 << (shift_total - 1)) - 1)) != 0
    round_up = (round_bit == 1) & (sticky | ((kept & 1) == 1))
    kept = kept + round_up.astype(np.int64)
    # post-round renormalization
    re_carry = kept >= (1 << (m + 1))
    kept = np.where(re_carry, kept >> 1, kept)
    ebiased = np.where((extra == 0) & re_carry, ebiased + 1, ebiased)

    is_sub_res = extra > 0
    # subnormal result that rounded up into the normal range
    sub_to_norm = is_sub_res & (kept >= (1 << m))
    man_res = np.where(is_sub_res & ~sub_to_norm, kept, kept & ((1 << m) - 1))
    exp_res = np.where(is_sub_res, np.where(sub_to_norm, 1, 0), ebiased)

    # overflow to inf
    ovf = exp_res >= fmt.max_exp_field
    exp_res = np.where(ovf, fmt.max_exp_field, exp_res)
    man_res = np.where(ovf, 0, man_res)
    # total underflow to zero
    uvf = (is_sub_res & (kept == 0)) | (extra >= 2 * m + 3)
    exp_res = np.where(uvf, 0, exp_res)
    man_res = np.where(uvf, 0, man_res)

    out = np_encode(s_res, exp_res, man_res, fmt)

    # special values
    zero_out = np_encode(s_res, 0, 0, fmt)
    inf_out = np_encode(s_res, fmt.max_exp_field, 0, fmt)
    nan_out = np_encode(0, fmt.max_exp_field, 1 << (m - 1), fmt)
    out = np.where(x_zero | y_zero, zero_out, out)
    out = np.where(x_inf | y_inf, inf_out, out)
    out = np.where((x_inf & y_zero) | (y_inf & x_zero), nan_out, out)
    out = np.where(x_nan | y_nan, nan_out, out)
    return out


def np_exact_mult_f32(x: np.ndarray, y: np.ndarray) -> np.ndarray:
    """Bit-exact fp32 multiply through the oracle datapath (returns float32)."""
    from .formats import np_bits_to_f32, np_f32_to_bits

    return np_bits_to_f32(np_exact_mult_bits(np_f32_to_bits(x), np_f32_to_bits(y), FP32))


def exact_mult_f32(x, y):
    """Device-side exact IEEE754 fp32 multiply = the hardware multiplier."""
    return jnp.asarray(x, jnp.float32) * jnp.asarray(y, jnp.float32)
