"""Floating-point format descriptions and bit-level encode/decode helpers.

The paper supports "floating-point formats with bit widths ranging from 16
to 32 bits" plus FP8 variants (Table I: FP8-32 / AFP16-32).  A format is a
(sign, exponent, mantissa) triple; all of the multiplier implementations in
``repro.core`` are generic over :class:`FloatFormat`.

Two families of helpers live here:

* numpy (``np_*``) — used by the bit-exact oracles and hypothesis tests,
  where int64 headroom makes the 48-bit significand product trivial;
* jax (``jnp_*``) — used by the on-device emulated numerics (uint32 only,
  safe without ``jax_enable_x64``).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class FloatFormat:
    """An IEEE-754-style binary format: 1 sign, ``exp_bits``, ``man_bits``."""

    name: str
    exp_bits: int
    man_bits: int

    @property
    def bias(self) -> int:
        return (1 << (self.exp_bits - 1)) - 1

    @property
    def total_bits(self) -> int:
        return 1 + self.exp_bits + self.man_bits

    @property
    def max_exp_field(self) -> int:
        """All-ones exponent field (inf/nan encoding)."""
        return (1 << self.exp_bits) - 1

    @property
    def sig_bits(self) -> int:
        """Significand width including the hidden bit."""
        return self.man_bits + 1

    @property
    def max_finite(self) -> float:
        return float(
            (2.0 - 2.0 ** (-self.man_bits)) * 2.0 ** (self.max_exp_field - 1 - self.bias)
        )

    @property
    def min_normal(self) -> float:
        return float(2.0 ** (1 - self.bias))


FP32 = FloatFormat("fp32", 8, 23)
BF16 = FloatFormat("bf16", 8, 7)
FP16 = FloatFormat("fp16", 5, 10)
FP8_E4M3 = FloatFormat("fp8_e4m3", 4, 3)
FP8_E5M2 = FloatFormat("fp8_e5m2", 5, 2)
# The paper's AFP16-32 family: arbitrary widths between 16 and 32 bits.
AFP24 = FloatFormat("afp24_e8m15", 8, 15)
AFP20 = FloatFormat("afp20_e8m11", 8, 11)

FORMATS = {f.name: f for f in [FP32, BF16, FP16, FP8_E4M3, FP8_E5M2, AFP24, AFP20]}
FORMATS["afp24"] = AFP24  # short aliases for the paper's AFP16-32 family
FORMATS["afp20"] = AFP20


def get_format(name: str) -> FloatFormat:
    try:
        return FORMATS[name]
    except KeyError as e:
        raise ValueError(f"unknown float format {name!r}; known: {sorted(FORMATS)}") from e


# ---------------------------------------------------------------------------
# numpy bit-level helpers (int64 headroom; oracle-side)
# ---------------------------------------------------------------------------

def np_f32_to_bits(x: np.ndarray) -> np.ndarray:
    return np.asarray(x, np.float32).view(np.uint32).astype(np.int64)


def np_bits_to_f32(bits: np.ndarray) -> np.ndarray:
    return (np.asarray(bits, np.int64).astype(np.uint32)).view(np.float32)


def np_decode(bits: np.ndarray, fmt: FloatFormat):
    """Split encoded integers into (sign, exp_field, mantissa_field)."""
    bits = np.asarray(bits, np.int64)
    man = bits & ((1 << fmt.man_bits) - 1)
    exp = (bits >> fmt.man_bits) & fmt.max_exp_field
    sign = (bits >> (fmt.man_bits + fmt.exp_bits)) & 1
    return sign, exp, man


def np_encode(sign: np.ndarray, exp: np.ndarray, man: np.ndarray, fmt: FloatFormat) -> np.ndarray:
    return (
        (np.asarray(sign, np.int64) << (fmt.man_bits + fmt.exp_bits))
        | (np.asarray(exp, np.int64) << fmt.man_bits)
        | np.asarray(man, np.int64)
    )


def np_decode_to_value(bits: np.ndarray, fmt: FloatFormat) -> np.ndarray:
    """Decode format-encoded integers to float64 real values (exact for <=52-bit sig)."""
    sign, exp, man = np_decode(bits, fmt)
    val = np.where(
        exp == 0,
        # subnormal: 0.man * 2^(1-bias)
        man.astype(np.float64) * 2.0 ** (1 - fmt.bias - fmt.man_bits),
        (man.astype(np.float64) * 2.0 ** -fmt.man_bits + 1.0)
        * 2.0 ** (exp.astype(np.float64) - fmt.bias),
    )
    val = np.where(exp == fmt.max_exp_field, np.where(man == 0, np.inf, np.nan), val)
    return np.where(sign == 1, -val, val)


def np_encode_from_value(x: np.ndarray, fmt: FloatFormat) -> np.ndarray:
    """Round float64 values to the nearest (ties-even) representable encoding."""
    x = np.asarray(x, np.float64)
    sign = (np.signbit(x)).astype(np.int64)
    ax = np.abs(x)
    out = np.zeros(x.shape, np.int64)

    nan = np.isnan(x)
    inf = np.isinf(x)
    # overflow threshold: midpoint between max finite and next step
    max_f = fmt.max_finite
    step = 2.0 ** (fmt.max_exp_field - 1 - fmt.bias - fmt.man_bits)
    ovf = ax >= max_f + step / 2

    # normal/subnormal path
    with np.errstate(invalid="ignore", over="ignore", under="ignore"):
        m, e = np.frexp(ax)  # ax = m * 2^e, m in [0.5, 1)
    # normalized exponent field = e - 1 + bias
    efield = e - 1 + fmt.bias
    # subnormal if efield < 1
    sub = efield < 1
    # quantize significand
    # normal: sig = m * 2^(man_bits+1)  (in [2^man_bits, 2^(man_bits+1)))
    shift = np.where(sub, 1 - efield, 0)
    scale = np.ldexp(np.ones_like(ax), fmt.man_bits + 1 - shift)
    sig = m * scale
    sig_r = np.rint(sig)  # ties-to-even
    # renormalize if rounding overflowed the significand (normal path only;
    # subnormal encodings are linear in the significand, incl. the promotion
    # to min-normal, so no shift is needed there)
    carry = ~sub & (sig_r >= np.ldexp(np.ones_like(ax), fmt.man_bits + 1))
    sig_r = np.where(carry, sig_r / 2.0, sig_r)
    efield = np.where(carry, efield + 1, efield)
    # subnormal that rounded up to min normal
    sub_to_norm = sub & (sig_r >= (1 << fmt.man_bits))
    efield = np.where(sub, np.where(sub_to_norm, 1, 0), efield)
    sig_r = np.nan_to_num(sig_r, nan=0.0, posinf=0.0, neginf=0.0)
    man = np.where(
        efield > 0,
        sig_r.astype(np.int64) - (1 << fmt.man_bits),
        sig_r.astype(np.int64),
    )
    man = np.clip(man, 0, (1 << fmt.man_bits) - 1)
    efield = np.clip(efield, 0, fmt.max_exp_field - 1)
    out = np_encode(sign, efield, man, fmt)
    out = np.where(ax == 0, np_encode(sign, 0, 0, fmt), out)
    out = np.where(ovf | inf, np_encode(sign, fmt.max_exp_field, 0, fmt), out)
    out = np.where(nan, np_encode(sign, fmt.max_exp_field, 1 << (fmt.man_bits - 1), fmt), out)
    return out


# ---------------------------------------------------------------------------
# jax bit-level helpers (uint32-safe; device-side)
# ---------------------------------------------------------------------------

def jnp_f32_to_bits(x: jax.Array) -> jax.Array:
    return jax.lax.bitcast_convert_type(jnp.asarray(x, jnp.float32), jnp.uint32)


def jnp_bits_to_f32(bits: jax.Array) -> jax.Array:
    return jax.lax.bitcast_convert_type(jnp.asarray(bits, jnp.uint32), jnp.float32)


def jnp_decode_f32(x: jax.Array):
    """Decode float32 arrays to (sign, exp_field, mantissa_field) uint32."""
    bits = jnp_f32_to_bits(x)
    man = bits & jnp.uint32((1 << 23) - 1)
    exp = (bits >> 23) & jnp.uint32(0xFF)
    sign = bits >> 31
    return sign, exp, man


def jnp_encode_f32(sign: jax.Array, exp: jax.Array, man: jax.Array) -> jax.Array:
    bits = (
        (jnp.asarray(sign, jnp.uint32) << 31)
        | (jnp.asarray(exp, jnp.uint32) << 23)
        | jnp.asarray(man, jnp.uint32)
    )
    return jnp_bits_to_f32(bits)


def jnp_quantize_to_format(x: jax.Array, fmt: FloatFormat) -> jax.Array:
    """Round-to-nearest-even quantization of float32 to ``fmt``, returned as float32.

    Used to model storage in narrower CiM formats.  Subnormals of the target
    format are flushed to zero (matching the approximate datapath).
    """
    if fmt.name == "fp32":
        return jnp.asarray(x, jnp.float32)
    bits = jnp_f32_to_bits(x)
    drop = 23 - fmt.man_bits
    # RNE on the mantissa field (works across the exponent boundary because
    # the exponent field is contiguous above the mantissa in IEEE-754).
    lsb = (bits >> drop) & jnp.uint32(1)
    rnd = jnp.uint32((1 << (drop - 1)) - 1) + lsb
    rbits = (bits + rnd) & ~jnp.uint32((1 << drop) - 1)
    y = jnp_bits_to_f32(rbits)
    # clamp exponent range of the target format
    y = jnp.where(jnp.abs(y) > fmt.max_finite, jnp.sign(y) * jnp.inf, y)
    y = jnp.where(jnp.abs(y) < fmt.min_normal, jnp.zeros_like(y), y)
    # preserve nan/inf of input
    y = jnp.where(jnp.isfinite(x), y, x)
    return y


def truncate_mantissa(x: jax.Array, keep_bits: int) -> jax.Array:
    """Truncate (toward zero) a float32 mantissa to its top ``keep_bits`` bits."""
    if keep_bits >= 23:
        return jnp.asarray(x, jnp.float32)
    mask = ~jnp.uint32((1 << (23 - keep_bits)) - 1)
    return jnp_bits_to_f32(jnp_f32_to_bits(x) & mask)


@partial(jax.jit, static_argnames=("fmt_name",))
def quantize(x: jax.Array, fmt_name: str) -> jax.Array:
    return jnp_quantize_to_format(x, get_format(fmt_name))
