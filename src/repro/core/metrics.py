"""Error and quality metrics used by the paper (MRED, NMED, PSNR)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def mred(approx, exact) -> float:
    """Mean relative error distance: E[|a-e| / |e|], over nonzero exact values."""
    approx = np.asarray(approx, np.float64).ravel()
    exact = np.asarray(exact, np.float64).ravel()
    mask = np.isfinite(exact) & np.isfinite(approx) & (exact != 0)
    if not mask.any():
        return 0.0
    return float(np.mean(np.abs(approx[mask] - exact[mask]) / np.abs(exact[mask])))


def nmed(approx, exact) -> float:
    """Normalized mean error distance: E[|a-e|] / max|e|."""
    approx = np.asarray(approx, np.float64).ravel()
    exact = np.asarray(exact, np.float64).ravel()
    mask = np.isfinite(exact) & np.isfinite(approx)
    if not mask.any():
        return 0.0
    denom = np.max(np.abs(exact[mask]))
    if denom == 0:
        return 0.0
    return float(np.mean(np.abs(approx[mask] - exact[mask])) / denom)


def psnr(test, ref, peak: float | None = None) -> float:
    """Peak signal-to-noise ratio in dB (paper Table III's metric)."""
    test = np.asarray(test, np.float64)
    ref = np.asarray(ref, np.float64)
    if peak is None:
        peak = float(np.max(np.abs(ref))) or 1.0
    mse = float(np.mean((test - ref) ** 2))
    if mse == 0:
        return float("inf")
    return float(10.0 * np.log10(peak * peak / mse))


def max_red(approx, exact) -> float:
    """Worst-case relative error distance (useful for error-bound tests)."""
    approx = np.asarray(approx, np.float64).ravel()
    exact = np.asarray(exact, np.float64).ravel()
    mask = np.isfinite(exact) & np.isfinite(approx) & (exact != 0)
    if not mask.any():
        return 0.0
    return float(np.max(np.abs(approx[mask] - exact[mask]) / np.abs(exact[mask])))


def top_k_accuracy(logits, labels, k: int = 1) -> float:
    logits = jnp.asarray(logits)
    labels = jnp.asarray(labels)
    topk = jnp.argsort(logits, axis=-1)[..., -k:]
    hit = jnp.any(topk == labels[..., None], axis=-1)
    return float(jnp.mean(hit))
