"""Numerics configuration + matmul dispatch — the "compiler integration" layer.

This is the system-level face of the paper: floating-point precision and
multiplier architecture are exposed as first-class configuration, and every
matmul in the model zoo routes through :func:`nmatmul`.

Modes
-----
``exact``
    Native IEEE fp32 (or bf16) matmul — the exact-baseline row.
``emulated``
    Every scalar product goes through the bit-level multiplier selected by
    ``multiplier`` (AC-n-n / ACL-n / MMBS / CSS / NC-LPC-HPC).  Bit-faithful
    to the RTL; used for the paper's accuracy studies (Tables III/IV).
    O(M*N*K) elementwise work — small models only.
``segmented``
    TPU-native analogue: split-float (hi/lo bf16) matmul with term
    skipping; ``seg_passes`` = 1 (ACL-like), 2, or 3 (AC-n-n-like) MXU
    passes, exact = 6-pass HIGHEST.  Scales to the full model zoo and is
    what the multi-pod dry-run/roofline paths use.  Backed by the kernel
    substrate (``repro.kernels.dispatch``), selected by ``backend``:

    ``auto``       Pallas on TPU, XLA reference elsewhere (default)
    ``pallas``     force the native Pallas lowering (TPU)
    ``interpret``  Pallas kernel body in interpreter mode (any backend;
                   what tests use to validate the kernels on CPU)
    ``xla``        force the pure-jnp reference implementation

Configuration is *ambient*: ``repro.core.scope`` provides the
``numerics_scope`` / ``layer_scope`` context managers (public surface:
``repro.numerics``), and :func:`nmatmul` with no extra arguments resolves
its config from the innermost scope and its full layer path from the
scope stack.  Per-layer policies (``repro.core.policy``: glob rules over
layer paths) plug in as the scoped value.  The legacy explicit form
``nmatmul(x, w, cfg, path=...)`` still works for one release behind a
:class:`DeprecationWarning`.
"""
from __future__ import annotations

import dataclasses
import sys
import warnings
from typing import Optional

import jax
import jax.numpy as jnp

from . import scope as _scope
from .afpm import AFPMConfig, afpm_matmul_emulated
from .registry import get_elementwise, get_multiplier

# single source of truth for kernel backends; kernels/dispatch.py imports
# this (that direction is cycle-safe, the reverse is not: EXACT below is
# constructed while this module loads)
BACKENDS = ("auto", "pallas", "interpret", "xla")


@dataclasses.dataclass(frozen=True)
class NumericsConfig:
    mode: str = "exact"             # exact | emulated | segmented
    multiplier: str = "AC5-5"       # registry name, for emulated mode
    seg_passes: int = 3             # segmented mode: 1=ACL-like, 3=AC-like
    seg_n: int = 5                  # segment width for emulated AC modes
    backend: str = "auto"           # kernel backend: auto|pallas|interpret|xla
    compute_dtype: str = "bfloat16" # exact-mode matmul dtype for big models
    accum_dtype: str = "float32"

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(
                f"unknown backend {self.backend!r}; expected one of {BACKENDS}")

    def afpm(self) -> AFPMConfig:
        mode = "acl" if self.multiplier.lower().startswith("acl") else "ac"
        return AFPMConfig(n=self.seg_n, mode=mode)


EXACT = NumericsConfig(mode="exact")


# ---------------------------------------------------------------------------
# calibration tap — the instrumented-pass hook for repro.core.sensitivity
# ---------------------------------------------------------------------------
# When a tap is installed, nmatmul reports (full layer path, x, w) for every
# call site it executes with concrete (non-traced) operands; sites inside
# jax.lax.scan / jit traces see tracers and are skipped, which is why the
# sensitivity calibration pass forces policy-driven unrolling
# (NumericsPolicy.force_unroll) and runs eagerly.
_OPERAND_TAP = None


def set_operand_tap(tap):
    """Install (``tap(path, x, w)``) or clear (``tap=None``) the call-site
    operand recorder; returns the previously installed tap so callers can
    restore it (see ``repro.core.sensitivity.record_operands``)."""
    global _OPERAND_TAP
    prev = _OPERAND_TAP
    _OPERAND_TAP = tap
    return prev


def operand_tap_active() -> bool:
    """True while a calibration tap is installed — call sites that normally
    bypass nmatmul for exact numerics (native convs, the fused routed-expert
    einsum) must route through it so the pass records their operands."""
    return _OPERAND_TAP is not None


def segmented_matmul_xla(x, w, passes: int = 3):
    """Split-float approximate matmul (XLA reference; oracle for the kernel).

    passes=3: hi*hi + hi*lo + lo*hi  (AC + AD + BC; BD omitted, paper Eq. 6)
    passes=2: hi*hi + hi*lo          (asymmetric: activations low bits kept)
    passes=1: hi*hi                  (ACL-like single high-segment product)

    Thin alias of ``repro.kernels.ref.afpm_matmul_ref`` — the single XLA
    reference implementation, also what the substrate's xla backend runs.
    """
    from repro.kernels import ref  # lazy: kernels import core

    return ref.afpm_matmul_ref(x, w, passes)


# call sites (by code location) that already emitted the one-per-site
# nmatmul deprecation warning; repro.numerics.reset_deprecation_registry
# clears it (tests)
_DEPRECATED_SITES: set = set()


def _warn_deprecated_nmatmul():
    frame = sys._getframe(2)  # the nmatmul caller
    site = (frame.f_code.co_filename, frame.f_lineno)
    if site in _DEPRECATED_SITES:
        return
    _DEPRECATED_SITES.add(site)
    warnings.warn(
        "nmatmul(x, w, cfg, path=...) is deprecated; wrap the call in "
        "repro.numerics.numerics_scope(cfg) / layer_scope(name) and call "
        "nmatmul(x, w) — the explicit form will be removed next release",
        DeprecationWarning, stacklevel=3)


def nmatmul(x: jax.Array, w: jax.Array, cfg: Optional[NumericsConfig] = None,
            path: Optional[str] = None):
    """Numerics-aware matmul: ``x @ w`` under the ambient numerics scope.

    The config comes from the innermost ``repro.numerics.numerics_scope``
    (EXACT outside any scope); for policies it is resolved per call site
    against the full layer path of the active ``layer_scope`` stack — this
    is what lets one forward pass run different numerics in different
    layers without threading arguments.

    Deprecated form: ``cfg`` (config or policy/scoped-policy) and ``path``
    may still be passed explicitly; an explicit ``cfg`` shadows any
    ambient scope, while ``path`` alone resolves the ambient scope at that
    leaf (like an inline ``layer_scope``).  Both warn once per call site
    and will be removed one release after 2026-07.
    """
    if cfg is None and path is None:
        amb = _scope.current_numerics()
        rel = _scope.current_path()
        # a scoped-policy ambient (e.g. block_apply(ncfg=policy.scope(...)))
        # carries a prefix: the tap must see the absolute path even though
        # resolution below stays relative (ScopedPolicy.lookup joins it)
        full = amb.full_path(rel) if hasattr(amb, "full_path") else rel
        if amb is None:
            resolved = EXACT
        elif isinstance(amb, NumericsConfig):
            resolved = amb
        else:
            resolved = amb.lookup(rel)
    else:
        _warn_deprecated_nmatmul()
        path = path or ""
        if cfg is None:
            # path-only call (half-migrated site): treat the path as an
            # inline layer_scope leaf and resolve the ambient scope there —
            # silently dropping an active policy would skew results
            amb = _scope.current_numerics()
            rel = _scope.current_path(path)
            full = amb.full_path(rel) if hasattr(amb, "full_path") else rel
            if amb is None:
                resolved = EXACT
            elif isinstance(amb, NumericsConfig):
                resolved = amb
            else:
                resolved = amb.lookup(rel)
        else:
            # full path: a scoped policy knows its prefix; plain configs
            # report the caller-supplied (relative) path verbatim
            full = cfg.full_path(path) if hasattr(cfg, "full_path") else path
            if isinstance(cfg, NumericsConfig):
                resolved = cfg
            else:
                resolved = cfg.lookup(path)  # NumericsPolicy / ScopedPolicy
                # (duck-typed to keep core.numerics import-cycle-free)
    if _OPERAND_TAP is not None and not (
            isinstance(x, jax.core.Tracer) or isinstance(w, jax.core.Tracer)):
        _OPERAND_TAP(full, x, w)
    cfg = resolved
    if cfg.mode == "exact":
        dt = jnp.dtype(cfg.compute_dtype)
        return jax.lax.dot_general(
            x.astype(dt), w.astype(dt), (((x.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.dtype(cfg.accum_dtype),
        )
    if cfg.mode == "emulated":
        name = cfg.multiplier.lower()
        if name.startswith(("ac", "acl")) and not name.startswith("ac-"):
            return afpm_matmul_emulated(x, w, cfg.afpm())
        # generic registry multiplier: chunked elementwise matmul
        mult = get_multiplier(cfg.multiplier)
        return _generic_emulated_matmul(x, w, mult)
    if cfg.mode == "segmented":
        from repro.kernels import dispatch  # lazy: kernels import core

        return dispatch.matmul(x, w, cfg.seg_passes, backend=cfg.backend)
    raise ValueError(f"unknown numerics mode {cfg.mode!r}")


def _generic_emulated_matmul(x, w, mult, k_chunk: int = 64):
    x = jnp.asarray(x, jnp.float32)
    w = jnp.asarray(w, jnp.float32)
    K = x.shape[-1]
    pad = (-K) % k_chunk
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
        w = jnp.pad(w, [(0, pad), (0, 0)])
    nchunks = x.shape[-1] // k_chunk
    xs = jnp.moveaxis(x.reshape(x.shape[:-1] + (nchunks, k_chunk)), -2, 0)
    ws = w.reshape(nchunks, k_chunk, w.shape[-1])

    def body(carry, kc):
        xk, wk = kc
        return carry + jnp.sum(mult(xk[..., :, None], wk), axis=-2), None

    init = jnp.zeros(x.shape[:-1] + (w.shape[-1],), jnp.float32)
    out, _ = jax.lax.scan(body, init, (xs, ws))
    return out


def apply_elementwise(x, y, multiplier: str, backend: str = "auto"):
    """Elementwise product under a named multiplier (image-processing path).

    AFPM-family multipliers route through the kernel substrate (Pallas on
    TPU); everything else runs the registered pure-jnp function.
    """
    return get_elementwise(multiplier, backend=backend)(x, y)
