"""Per-layer numerics policies — the compiler's per-layer configuration map.

The paper's framework picks a (multiplier, segmentation) configuration per
error budget; OpenACMv2 extends the selection to *per layer* of a network
(accuracy-constrained co-optimization), and the hybrid-domain FP-CiM line
shows DNN layers differ sharply in how much multiplier precision they
need.  A :class:`NumericsPolicy` is the system-level expression of that:
an ordered list of ``(glob pattern, NumericsConfig)`` rules over *layer
paths* plus a default, so a single forward pass can run exact attention,
segmented-1 MLPs and an exact ``lm_head`` at the same time.

Layer paths
-----------
Every ``nmatmul`` call site in the model zoo has a stable dotted path:

=====================  ====================================================
model                  paths
=====================  ====================================================
transformer (LM zoo)   ``blocks.{i}.attn.{wq,wk,wv,wo}`` (GQA/local),
                       ``blocks.{i}.attn.{wq_a,wq_b,wkv_a,wo}`` (MLA),
                       ``blocks.{i}.mlp.{wi,wg,wo}`` (dense MLP),
                       ``blocks.{i}.mlp.shared.{wi,wg,wo}`` (MoE shared),
                       ``blocks.{i}.ssm.{in_proj,out_proj,scan}``,
                       ``blocks.{i}.cross.{wq,wk,wv,wo}`` (enc-dec),
                       ``encoder.blocks.*`` (whisper encoder, unindexed),
                       ``lm_head``
resnet (Table IV)      ``stem``, ``s{stage}b{block}.{conv1,conv2,proj}``,
                       ``fc``
=====================  ====================================================

``{i}`` is the global layer index (0-based, execution order).  The
``ssm.scan`` path carries only its ``backend`` field (the selective scan
is not a multiplier datapath; its kernel backend is still selectable).

Matching and precedence
-----------------------
Rules are matched with :func:`fnmatch.fnmatchcase` (shell globs: ``*``
matches any run of characters including dots, ``?`` one character,
``[seq]`` a set).  Rules are evaluated **in order; the first matching
rule wins**; if no rule matches, ``default`` applies.  Put specific rules
(``blocks.0.attn.wq``) before broad ones (``blocks.*``).

Scan homogeneity
----------------
Transformer depth runs as ``jax.lax.scan`` over layer repeats, which
requires every repeat to trace identically.  ``transformer.stack_apply``
checks each scanned segment against the policy: if all repeats resolve to
the same configs the segment stays scanned; otherwise it is transparently
unrolled (per-repeat trace, compile time grows with depth — intended for
serving, where the policy is fixed).

Serialization
-------------
``to_json`` / ``from_json`` round-trip the policy (see
``docs/numerics_policy.md`` for the schema), so an auto-configured policy
(``repro.core.sweep.auto_configure``) can be saved and served with
``python -m repro.launch.serve --policy policy.json``.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import json
from typing import Iterable, Mapping, Sequence, Tuple, Union

from .numerics import EXACT, NumericsConfig


@dataclasses.dataclass(frozen=True)
class PolicyRule:
    """One ``pattern -> config`` entry; ``pattern`` is a shell glob."""

    pattern: str
    config: NumericsConfig

    def matches(self, path: str) -> bool:
        return fnmatch.fnmatchcase(path, self.pattern)


@dataclasses.dataclass(frozen=True)
class NumericsPolicy:
    """Ordered glob rules over layer paths; first match wins, else default.

    ``force_unroll`` (class attribute, False) is an escape hatch for the
    sensitivity calibration pass: a policy subclass setting it True makes
    ``transformer.stack_apply`` unroll every scanned segment so call sites
    execute eagerly with concrete operands (the operand tap in
    ``repro.core.numerics`` cannot record tracers).
    """

    rules: Tuple[PolicyRule, ...] = ()
    default: NumericsConfig = EXACT

    force_unroll = False

    def __post_init__(self):
        # accept any iterable of rules / (pattern, config) pairs
        norm = tuple(
            r if isinstance(r, PolicyRule) else PolicyRule(*r)
            for r in self.rules
        )
        object.__setattr__(self, "rules", norm)

    # -- resolution ---------------------------------------------------------

    def lookup(self, path: str) -> NumericsConfig:
        """Resolve one layer path to its NumericsConfig."""
        for rule in self.rules:
            if rule.matches(path):
                return rule.config
        return self.default

    def scope(self, prefix: str) -> "ScopedPolicy":
        """View of this policy with ``prefix.`` prepended to every lookup."""
        return ScopedPolicy(self, prefix)

    def full_path(self, path: str = "") -> str:
        """The absolute layer path a relative ``path`` resolves under (the
        root policy is unscoped, so this is the identity)."""
        return path

    # -- construction helpers ----------------------------------------------

    @classmethod
    def from_assignments(cls, assignments: Mapping[str, NumericsConfig],
                         default: NumericsConfig = EXACT) -> "NumericsPolicy":
        """Exact-path rules from a {path: config} map (auto-configurer output)."""
        return cls(tuple(PolicyRule(p, c) for p, c in assignments.items()),
                   default)

    # -- serialization ------------------------------------------------------

    def to_dict(self) -> dict:
        return {
            "default": _config_to_dict(self.default),
            "rules": [
                {"pattern": r.pattern, "config": _config_to_dict(r.config)}
                for r in self.rules
            ],
        }

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_dict(cls, d: Mapping) -> "NumericsPolicy":
        default = _config_from_dict(d.get("default", {}))
        rules = tuple(
            PolicyRule(r["pattern"], _config_from_dict(r.get("config", {})))
            for r in d.get("rules", ())
        )
        return cls(rules, default)

    @classmethod
    def from_json(cls, text: str) -> "NumericsPolicy":
        return cls.from_dict(json.loads(text))


@dataclasses.dataclass(frozen=True)
class ScopedPolicy:
    """A policy view rooted at a path prefix (cheap, created per layer)."""

    policy: NumericsPolicy
    prefix: str

    def lookup(self, path: str = "") -> NumericsConfig:
        return self.policy.lookup(_join(self.prefix, path))

    def scope(self, prefix: str) -> "ScopedPolicy":
        return ScopedPolicy(self.policy, _join(self.prefix, prefix))

    def full_path(self, path: str = "") -> str:
        return _join(self.prefix, path)

    @property
    def force_unroll(self) -> bool:
        return self.policy.force_unroll


Numerics = Union[NumericsConfig, NumericsPolicy, ScopedPolicy]


def _join(prefix: str, path: str) -> str:
    if not prefix:
        return path
    if not path:
        return prefix
    return f"{prefix}.{path}"


def _config_to_dict(cfg: NumericsConfig) -> dict:
    return dataclasses.asdict(cfg)


_CONFIG_FIELDS = {f.name for f in dataclasses.fields(NumericsConfig)}


def _config_from_dict(d: Mapping) -> NumericsConfig:
    unknown = set(d) - _CONFIG_FIELDS
    if unknown:
        raise ValueError(
            f"unknown NumericsConfig fields {sorted(unknown)}; "
            f"expected a subset of {sorted(_CONFIG_FIELDS)}")
    return NumericsConfig(**d)


# ---------------------------------------------------------------------------
# duck-typed helpers used at every model call site — a plain NumericsConfig
# passes through untouched, so all pre-policy code keeps working
# ---------------------------------------------------------------------------

def is_policy(ncfg) -> bool:
    return isinstance(ncfg, (NumericsPolicy, ScopedPolicy))


def resolve(ncfg: Numerics | None, path: str = "") -> NumericsConfig:
    """Resolve a config-or-policy to the concrete config for ``path``."""
    if ncfg is None:
        return EXACT
    if isinstance(ncfg, NumericsConfig):
        return ncfg
    return ncfg.lookup(path)


def scoped(ncfg: Numerics, *parts: str) -> Numerics:
    """Scope a policy under ``parts`` (no-op for a plain NumericsConfig)."""
    if is_policy(ncfg):
        for p in parts:
            ncfg = ncfg.scope(p)
    return ncfg


def expert_paths(n_experts: int, names: Sequence[str] = ("wi", "wg", "wo"),
                 prefix: str = "") -> Tuple[str, ...]:
    """Per-expert MoE call-site paths: ``expert{k}.{name}`` under ``prefix``.

    Each routed expert is a separate weight slab — a separate multiplier
    array instance in the CiM deployment model — so the PPA roll-up
    (``repro.core.sweep.policy_area``) and the auto-configurer enumerate
    every expert path individually rather than one path per MoE layer.
    """
    return tuple(_join(prefix, f"expert{k}.{name}")
                 for k in range(n_experts) for name in names)
