"""Analytical PPA (power/performance/area) model for the multiplier designs.

The paper's Table II is post-layout (OpenROAD + FreePDK45).  This container
has no EDA flow, so we replace layout with a gate-equivalent (GE) cost
model of each datapath — partial-product arrays, compressor trees, adders,
zero-detectors, steering muxes — and calibrate two scalar constants per
metric (slope and intercept of ``metric = a*GE + b``) on two anchor rows
of the published table (the exact FP32 multiplier and AC5-5, 64x32 SRAM
block).  The benchmark (`benchmarks/table2_ppa.py`) then *predicts* every
other row and reports the deviation from the paper, making the model
falsifiable.  Area uses the full datapath GE; power uses the *active* GE
(runtime-reconfigurable designs clock-gate the unused portion of their
arrays, which is why e.g. MMBS has large area but moderate power).

GE unit convention (standard-cell folklore, NAND2 = 1 GE):
  AND2 1.5 | XOR2 2.5 | full adder 4.5 | half adder 2.5 | 2:1 mux 2.5 |
  register bit 6.0 | OR-tree per input 1.0
"""
from __future__ import annotations

import dataclasses

GE_AND = 1.5
GE_XOR = 2.5
GE_FA = 4.5
GE_HA = 2.5
GE_MUX = 2.5
GE_REG = 6.0
GE_OR = 1.0

# paper constants (Table II): SRAM area and flat (SRAM-dominated) delay
SRAM_AREA = {"16x8": 7052.0, "32x16": 16910.0, "64x32": 48642.0}
SRAM_DELAY_NS = {"16x8": 5.22, "32x16": 5.24, "64x32": 5.24}


def _array_mult_ge(n: int, m: int) -> float:
    """n x m unsigned array multiplier: AND plane + Wallace compressors + CPA."""
    if n <= 0 or m <= 0:
        return 0.0
    if n == 1 or m == 1:
        return n * m * GE_AND
    and_plane = n * m * GE_AND
    compressors = max(n * m - n - m, 0) * GE_FA  # classic n*m-n-m FA count
    cpa = (n + m) * GE_FA  # final carry-propagate adder
    return and_plane + compressors + cpa


def _adder_ge(width: int) -> float:
    return width * GE_FA


def _zero_detect_ge(width: int) -> float:
    return max(width, 0) * GE_OR


@dataclasses.dataclass(frozen=True)
class PPAEstimate:
    name: str
    ge_area: float
    ge_power: float
    logic_area_um2: float
    power_w: float
    delay_ns: float
    sram_area_um2: float

    @property
    def total_area_um2(self) -> float:
        return self.logic_area_um2 + self.sram_area_um2


def multiplier_ge(kind: str, **kw) -> tuple[float, float]:
    """(area GE, active/power GE) of one FP multiplier datapath."""
    man = kw.get("man_bits", 23)
    exp = kw.get("exp_bits", 8)
    sig = man + 1
    # shared FP front/back-end: sign xor, exponent adders, special detect,
    # overflow/underflow logic
    shared = GE_XOR + 2 * _adder_ge(exp + 1) + 2 * _zero_detect_ge(exp + man) + 8 * GE_MUX

    if kind == "exact":
        core = _array_mult_ge(sig, sig)
        core += _adder_ge(2 * sig)  # rounding (RNE) increment + renorm
        core += _adder_ge(sig)      # sticky/guard collection
        active = core
    elif kind == "ac":
        n = kw["n"]
        # AC always; AD/BC arrays present but conditionally fired;
        # BD array REMOVED (paper: ~6.8% area, ~12.6% power saved)
        core = 3 * _array_mult_ge(n, n)
        core += 2 * _zero_detect_ge(n - 2)      # conditional-execution detectors
        core += 2 * (n * GE_MUX)                # comp/bypass steering
        core += _adder_ge(3 * n + 2) * 3        # shift-and-add accumulator (3n)
        core += (3 * n) * GE_MUX                # normalization shifter (1 pos)
        active = core
    elif kind == "acl":
        n = kw["n"]
        core = n * GE_AND                       # bitwise AND row
        core += 2 * _adder_ge(n + 2)            # two n-bit additions
        core += n * GE_MUX
        active = core
    elif kind == "mmbs":
        k = kw["k"]
        kmax = kw.get("k_max", 12)              # runtime-reconfigurable datapath
        T = 2 * k + 2
        core = _array_mult_ge(kmax, kmax)       # array sized for max precision
        core += 3 * _adder_ge(T)                # linear-term shift-and-add
        core += T * GE_MUX
        core += 24 * GE_REG                     # precision/frequency config regs
        # only the k x k portion of the array switches at precision k
        active = core - (_array_mult_ge(kmax, kmax) - _array_mult_ge(k, k))
    elif kind == "css":
        s = kw["m"] // 2 + 2                    # matches baselines.css_mult_f32
        core = _array_mult_ge(s, s)
        core += 2 * _adder_ge(2 * s + 2)        # MAC restructuring adders
        core += 2 * 24 * GE_MUX                 # static segment steering (24b in)
        core += 2 * _zero_detect_ge(24)         # segment-select detection
        active = core
    elif kind == "log":
        comp = kw.get("comp", "nc")
        core = _adder_ge(man + 1)               # Mitchell mantissa add
        if comp == "lpc":
            core += _adder_ge(man) * 0.5 + 4 * GE_MUX
        elif comp == "hpc":
            core += _array_mult_ge(4, 4) + _adder_ge(man)
        active = core
    else:
        raise ValueError(kind)
    return shared + core, shared + active


# Calibration anchors (paper Table II, 64x32 rows): exact and AC5-5
_ANCHOR_EXACT = {"area": 6268.0, "power": 2.32e-3}
_ANCHOR_AC55 = {"area": 2156.0, "power": 7.72e-4}


def _calibration():
    ge_exact, gp_exact = multiplier_ge("exact")
    ge_ac55, gp_ac55 = multiplier_ge("ac", n=5)
    a_area = (_ANCHOR_EXACT["area"] - _ANCHOR_AC55["area"]) / (ge_exact - ge_ac55)
    b_area = _ANCHOR_EXACT["area"] - a_area * ge_exact
    a_pow = (_ANCHOR_EXACT["power"] - _ANCHOR_AC55["power"]) / (gp_exact - gp_ac55)
    b_pow = _ANCHOR_EXACT["power"] - a_pow * gp_exact
    return a_area, b_area, a_pow, b_pow


def estimate(kind: str, name: str | None = None, sram: str = "64x32", **kw) -> PPAEstimate:
    a_area, b_area, a_pow, b_pow = _calibration()
    ge_area, ge_power = multiplier_ge(kind, **kw)
    return PPAEstimate(
        name=name or kind,
        ge_area=ge_area,
        ge_power=ge_power,
        logic_area_um2=a_area * ge_area + b_area,
        power_w=a_pow * ge_power + b_pow,
        delay_ns=SRAM_DELAY_NS[sram],  # SRAM access dominates the critical path
        sram_area_um2=SRAM_AREA[sram],
    )


# Published Table II (64x32) for validation in the benchmark.
PAPER_TABLE2_64x32 = {
    "Exact": (6268.0, 2.32e-3),
    "ACL5": (1351.0, 4.16e-4),
    "AC4-4": (1945.0, 6.42e-4),
    "AC5-5": (2156.0, 7.72e-4),
    "AC6-6": (2568.0, 9.22e-4),
    "MMBS5": (3134.0, 7.07e-4),
    "MMBS6": (3171.0, 7.56e-4),
    "MMBS7": (3329.0, 8.61e-4),
    "CSS12": (2136.0, 6.42e-4),
    "CSS14": (2312.0, 7.18e-4),
    "CSS16": (2572.0, 8.01e-4),
    "CSS18": (2846.0, 9.12e-4),
    "NC": (1360.0, 4.22e-4),
    "LPC": (1384.0, 4.33e-4),
    "HPC": (1658.0, 5.19e-4),
}

# Specs for every Table II row: name -> (kind, kwargs)
TABLE2_SPECS = {
    "Exact": ("exact", {}),
    "ACL5": ("acl", {"n": 5}),
    "AC4-4": ("ac", {"n": 4}),
    "AC5-5": ("ac", {"n": 5}),
    "AC6-6": ("ac", {"n": 6}),
    "MMBS5": ("mmbs", {"k": 5}),
    "MMBS6": ("mmbs", {"k": 6}),
    "MMBS7": ("mmbs", {"k": 7}),
    "CSS12": ("css", {"m": 12}),
    "CSS14": ("css", {"m": 14}),
    "CSS16": ("css", {"m": 16}),
    "CSS18": ("css", {"m": 18}),
    "NC": ("log", {"comp": "nc"}),
    "LPC": ("log", {"comp": "lpc"}),
    "HPC": ("log", {"comp": "hpc"}),
}

# Paper headline claims (abstract / §IV-A) used as validation targets.
PAPER_CLAIMS = {
    "headline_area_reduction": 0.69,   # "up to 69% logic area reduction"
    "headline_power_reduction": 0.72,  # "72% power savings"
    "acl5_area_reduction": 0.784,      # ACL5 vs exact
    "acl5_power_reduction": 0.821,
    "bd_omission_area": 0.068,         # omitting BD: ~6.8% area
    "bd_omission_power": 0.126,        # ~12.6% power
}


def bd_omission_savings(n: int = 5) -> tuple[float, float]:
    """Area/power saved by omitting the BD array (validates the 6.8%/12.6% claim)."""
    a_area, b_area, a_pow, b_pow = _calibration()
    ge_a, gp_a = multiplier_ge("ac", n=n)
    # with BD: a 4th n x n array + wider (4n) accumulator
    ge_bd = ge_a + _array_mult_ge(n, n) + (_adder_ge(4 * n) - _adder_ge(3 * n + 2)) * 3
    gp_bd = ge_bd
    area_with = a_area * ge_bd + b_area
    area_without = a_area * ge_a + b_area
    pow_with = a_pow * gp_bd + b_pow
    pow_without = a_pow * gp_a + b_pow
    return (
        (area_with - area_without) / area_with,
        (pow_with - pow_without) / pow_with,
    )
