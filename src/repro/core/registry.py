"""Multiplier registry — the "operator library" of the compiler flow.

OpenACM exposes approximate operators as named library entries that the
compiler instantiates per layer.  We mirror that: every multiplier design
(exact, AC-n-n, ACL-n, MMBS-k, CSS-m, NC/LPC/HPC) is registered under the
paper's label and resolvable by name from model/benchmark configs.

AFPM-family entries additionally record their :class:`AFPMConfig`, so
:func:`get_elementwise` can route them through the kernel substrate
(``repro.kernels.dispatch``) — one audited entry point whether the caller
wants the pure-jnp datapath, the Pallas VPU kernel, or its interpreter.
"""
from __future__ import annotations

from typing import Callable, Dict

import jax

from . import afpm, baselines
from .exact_mult import exact_mult_f32

MultFn = Callable[[jax.Array, jax.Array], jax.Array]

_REGISTRY: Dict[str, MultFn] = {}
_AFPM_CONFIGS: Dict[str, afpm.AFPMConfig] = {}


def register(name: str, fn: MultFn,
             afpm_cfg: afpm.AFPMConfig | None = None) -> None:
    _REGISTRY[name.lower()] = fn
    if afpm_cfg is not None:
        _AFPM_CONFIGS[name.lower()] = afpm_cfg


def get_multiplier(name: str) -> MultFn:
    try:
        return _REGISTRY[name.lower()]
    except KeyError as e:
        raise ValueError(
            f"unknown multiplier {name!r}; available: {sorted(_REGISTRY)}"
        ) from e


def available() -> list[str]:
    return sorted(_REGISTRY)


def get_elementwise(name: str, backend: str = "auto") -> MultFn:
    """Backend-aware elementwise multiplier.

    AFPM-family names (AC-n-n / ACL-n / AC-<fmt>) dispatch through the
    kernel substrate under ``backend``; other designs have no kernel and
    fall back to their registered pure-jnp implementation.
    """
    cfg = _AFPM_CONFIGS.get(name.lower())
    if cfg is None:
        return get_multiplier(name)
    from repro.kernels import dispatch  # lazy: kernels import core

    return lambda x, y: dispatch.multiply(x, y, cfg, backend=backend)


def _register_defaults() -> None:
    register("exact", exact_mult_f32)
    for n in (3, 4, 5, 6, 7):
        cfg = afpm.AFPMConfig(n=n, mode="ac")
        register(f"AC{n}-{n}", lambda x, y, c=cfg: afpm.afpm_mult_f32(x, y, c), cfg)
    for n in (4, 5, 6, 8):
        cfg = afpm.AFPMConfig(n=n, mode="acl")
        register(f"ACL{n}", lambda x, y, c=cfg: afpm.afpm_mult_f32(x, y, c), cfg)
    # narrower storage formats (paper: FP16..FP32 supported by the framework)
    for fmtname, nmax in (("fp16", 5), ("afp24", 7), ("bf16", 3)):
        cfg = afpm.AFPMConfig(n=min(nmax, 5), mode="ac", fmt=fmtname)
        register(f"AC-{fmtname}", lambda x, y, c=cfg: afpm.afpm_mult_f32(x, y, c), cfg)
    for k in (5, 6, 7):
        cfg = baselines.MMBSConfig(k=k)
        register(f"MMBS{k}", lambda x, y, c=cfg: baselines.mmbs_mult_f32(x, y, c))
    for m in (12, 14, 16, 18):
        cfg = baselines.CSSConfig(m=m)
        register(f"CSS{m}", lambda x, y, c=cfg: baselines.css_mult_f32(x, y, c))
    for comp in ("nc", "lpc", "hpc"):
        cfg = baselines.LogConfig(comp=comp)
        register(comp.upper(), lambda x, y, c=cfg: baselines.log_mult_f32(x, y, c))


_register_defaults()
