"""Dynamic numerics scoping — precision as an ambient property of a region.

The paper's framing (and OpenACM/OpenACMv2's) is that accuracy
configuration is *compiler* state: a region of the program runs under a
multiplier configuration, not every multiply carrying its own argument.
This module is that region mechanism: a thread-local stack of ambient
:class:`~repro.core.policy.Numerics` values plus a thread-local *path
stack* of layer-name segments.  ``nmatmul(x, w)`` with no arguments
resolves its config from the innermost :func:`numerics_scope` and its
full layer path from the joined :func:`layer_scope` stack.

Transform safety
----------------
Scopes are ordinary Python context managers, and resolution happens at
**trace time**: when ``jax.jit`` / ``jax.lax.scan`` / ``jax.vmap`` traces
a function, the ``with`` blocks execute during the trace and every
``nmatmul`` bakes its resolved config into the jaxpr.  Nothing dynamic
survives into the compiled computation, so scoped code jits, scans and
vmaps exactly like explicitly-configured code (see
``tests/test_scopes.py``).  ``jax.checkpoint`` traces its body once at
call time (the backward pass replays the jaxpr, not the Python), so
remat'ed blocks resolve consistently too.

The flip side of trace-time resolution: the ambient scope is **not part
of a jit cache key**.  A function jitted once and re-invoked under a
*different* ``numerics_scope`` hits the compiled cache and keeps the
first trace's numerics.  Enter the scope *inside* the jitted function
from a value the jit re-traces on (the model zoo's pattern: entry points
build a fresh ``jax.jit`` closure per config — ``Session.generate``,
``transformer.backbone`` closing over ``cfg.numerics``), or jit per
scope.  Never hoist one jitted callable across scopes expecting it to
re-resolve.

The stacks are ``threading.local``: concurrent sessions in different
threads cannot observe each other's scopes.
"""
from __future__ import annotations

import contextlib
import threading

__all__ = [
    "ambient_view",
    "current_numerics",
    "current_path",
    "force_unroll_active",
    "layer_scope",
    "maybe_numerics_scope",
    "numerics_scope",
    "resolve_here",
]


class _ScopeState(threading.local):
    def __init__(self):
        self.numerics = []   # stack of ambient Numerics (config or policy)
        self.path = []       # stack of layer-path segments


_STATE = _ScopeState()


@contextlib.contextmanager
def numerics_scope(numerics):
    """Make ``numerics`` (a NumericsConfig or NumericsPolicy) ambient.

    Every ``nmatmul(x, w)`` inside the block resolves against it; nested
    scopes shadow outer ones (innermost wins), so a resolved plain config
    can locally override an outer policy — e.g. the uniform-config expert
    body inside a shard_map under a per-layer policy.
    """
    _STATE.numerics.append(numerics)
    try:
        yield numerics
    finally:
        _STATE.numerics.pop()


def maybe_numerics_scope(numerics):
    """``numerics_scope(numerics)``, or a no-op when ``numerics`` is None —
    the plumbing helper for entry points with an optional override."""
    if numerics is None:
        return contextlib.nullcontext()
    return numerics_scope(numerics)


@contextlib.contextmanager
def layer_scope(name):
    """Push one layer-path segment (dotted names allowed: ``blocks.3``).

    The full path of a call site is the dot-join of every active
    ``layer_scope`` — ``blocks.3`` → ``attn`` → ``wq`` resolves as
    ``blocks.3.attn.wq`` against the ambient policy.
    """
    _STATE.path.append(str(name))
    try:
        yield
    finally:
        _STATE.path.pop()


def current_numerics():
    """The innermost ambient Numerics, or None outside any scope."""
    return _STATE.numerics[-1] if _STATE.numerics else None


def force_unroll_active() -> bool:
    """True when the ambient numerics is a calibration policy
    (``NumericsPolicy.force_unroll``): scanned structure — decoder segment
    repeats and the whisper-style encoder stack — must execute eagerly and
    un-remat'ed so the sensitivity operand tap (``repro.core.sensitivity``)
    sees concrete arrays at every call site."""
    return bool(getattr(current_numerics(), "force_unroll", False))


def current_path(leaf: str = "") -> str:
    """Dot-joined layer path of the active ``layer_scope`` stack
    (+ ``leaf`` appended when given)."""
    parts = [p for p in _STATE.path if p]
    if leaf:
        parts.append(leaf)
    return ".".join(parts)


def resolve_here(leaf: str = ""):
    """Concrete NumericsConfig at the current scope (+ optional ``leaf``).

    Equivalent to ``policy.resolve(current_numerics(), current_path(leaf))``
    — EXACT when no scope is active.
    """
    from .policy import resolve  # deferred: policy imports core.numerics

    return resolve(current_numerics(), current_path(leaf))


def ambient_view():
    """The ambient numerics as a view rooted at the current path: a
    ScopedPolicy for policies (so relative lookups like ``expert3.wi``
    resolve under the full path), the config itself for plain configs,
    None outside any scope."""
    from .policy import scoped  # deferred: policy imports core.numerics

    amb = current_numerics()
    if amb is None:
        return None
    prefix = current_path()
    return scoped(amb, prefix) if prefix else amb
