"""Gain-aware composed-error sensitivity model — one calibration pass.

The greedy auto-configurer (``repro.core.sweep.auto_configure``,
``method="greedy"``) re-evaluates the whole network once per candidate
assignment: fine for ResNet-18-class calibration, intractable for the LM
zoo.  This module replaces those full-network evaluations with a
first-order error-composition model built from a **single instrumented
calibration pass**:

1. ``record_operands`` installs the operand tap in ``repro.core.numerics``;
   one forward under the (default-only) calibration policy records, per
   ``nmatmul`` call site, a bounded sample of its operand distribution,
   the rms magnitudes of its input and its exact product, and a
   **gain coefficient** (below).  Scanned segments — decoder repeats *and*
   the whisper-style encoder stack — are transparently unrolled for the
   pass (``NumericsPolicy.force_unroll``) so every site executes eagerly
   with concrete operands; without the unroll, scanned sites see tracers
   and are invisible to the tap.
2. Per site, the **local error** of a candidate design is measured by
   pushing the recorded operand sample through that design — no network
   in the loop, just a tiny matmul per (site, candidate).  Two flavours:
   :meth:`SensitivityModel.local_error` (MRED against the float64 exact
   product — the paper's per-multiplier metric, diagnostic) and
   :meth:`SensitivityModel.local_rms_error` (rms relative error **against
   the calibration default design's own output** on the same sample, what
   the composition model propagates).  The reference matters: the network
   error ``eval_fn`` measures is against the *default-numerics* baseline,
   so a candidate that rounds exactly like the default (segmented-1 under
   a bf16-exact default is bitwise the same dot) must read as zero local
   error, not as the default's own rounding.
3. Per site, a **gain coefficient** ``g_i`` estimates the rms
   amplification of the site's linear map on a *random* tangent — a
   Jacobian-norm estimate from a JVP probe on the recorded operand sample
   (``jax.jvp`` of ``t -> t @ w`` at the recorded ``x``), with a
   finite-difference output-perturbation fallback when the JVP cannot be
   taken.  The probe direction matters: recorded activations concentrate
   on the map's loud singular directions, while an injected *error* is an
   arbitrary direction — ``g_i`` measures what the map does to the
   latter.
4. The **composed error** of an assignment is a first-order sum: an error
   injected at site ``i`` (rms relative size ``delta_i``, absolute rms
   ``delta_i * out_rms_i``) reaches the network head scaled by the
   **downstream gain** ``G_i`` — the product of the gain coefficients of
   the sites it subsequently flows *through*.  The model multiplies gains
   only along observed dataflow **chains** (site ``j``'s recorded input
   equals site ``j-1``'s recorded output); across residual/branching
   structure, where the perturbation rides the identity stream rather
   than the branch matmuls, the unit-gain residual-stream assumption
   stands (``G`` contribution 1).  At the head, the absolute rms error is
   converted to the *measured* metric (MRED, a mean of per-element
   relative errors) through the **tail factor** ``sqrt(2/pi) *
   mean(1/|y|) * rms(y)`` computed on the recorded head sample — MRED's
   small-|y| denominators make it systematically larger than the rms
   ratio, and ignoring that was the dominant source of the old flat
   model's ~2x under-prediction on deep stacks.

   Putting it together::

       predict(assign) = baseline
                       + sum_i calls_i * tail * alpha_i * G_i * delta_rms_i
       alpha_i = out_rms_i / out_rms_head          (flat first-order term)
       G_i     = prod_{j in downstream chain of i} g_j
       tail    = sqrt(2/pi) * mean(1/|y_head|) * rms(y_head)
       calls_i = executions of the site during the pass (1 everywhere
                 except the unindexed scanned-encoder sites, where one
                 path stands for ``encoder_layers`` injections)

   The composition stays deliberately linear (no RSS cancellation
   credit), so the prediction upper-bounds the typical measured error
   while the gain and tail terms remove the systematic under-prediction.

Model assumptions, explicitly: (a) first-order — per-site errors are
small enough that their images at the head superpose linearly; (b) linear
composition over sites — no cancellation credit between sites; (c) gain
enters per call site as a random-direction Jacobian-norm estimate of that
site's own map, composed multiplicatively only along recorded
input-equals-previous-output chains, with unit gain elsewhere (the
residual-stream assumption); (d) the head's recorded sample is
representative of the output magnitude distribution the measured MRED is
taken over.  See ``docs/sensitivity.md`` for the worked derivation and
the trade-off against the greedy baseline.

The cross-validation tests (``tests/test_sensitivity.py``) pin the proxy
against the greedy baseline on the ResNet-18 calibration setup, and the
property tests (``tests/test_hypothesis_properties.py``) assert the
composed prediction brackets measured network error within pinned factors
on random layer stacks and a 2-block transformer stack.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .metrics import mred
from .numerics import EXACT, NumericsConfig, nmatmul, set_operand_tap
from .policy import NumericsPolicy
from .scope import numerics_scope

# bounded per-site operand sample: rows of x, columns of w (strided —
# deterministic, so calibration and its golden fixtures are reproducible)
MAX_ROWS = 64
MAX_COLS = 64

# the gain probe: a fixed-seed random tangent (deterministic, so the
# recorded coefficients are reproducible and golden-pinnable)
PROBE_SEED = 20260730
# chain detection: site j is "chained" to site j-1 when its recorded input
# sample equals site j-1's recorded exact output within this tolerance.
# The comparison is between the eager pass's actual output (computed under
# the calibration default — bf16 operand rounding for the LM zoo's
# exact-bf16 default, ~4e-3 per element with cancellation spikes) and the
# tap's float64 reference product, so the tolerance must swallow the
# default design's own rounding; unrelated tensors differ at O(1) per
# element, so a loose tolerance cannot false-positive a 64x64 allclose.
CHAIN_RTOL = 5e-2
CHAIN_ATOL = 2e-2  # x rms(prev output)


@dataclasses.dataclass(frozen=True)
class SiteRecord:
    """One call site's recorded operand distribution + gain coefficient."""

    path: str
    x: np.ndarray          # (<=MAX_ROWS, K) float32 operand rows
    w: np.ndarray          # (K, <=MAX_COLS) float32 weight columns
    out_rms: float         # rms of the exact (float64) sample product
    order: int             # execution order of the site's first call
    calls: int = 1         # times the site was hit during the pass
    in_rms: float = 0.0    # rms of the recorded x sample
    gain: float = 1.0      # random-tangent rms gain of t -> t @ w (JVP probe)
    chained: bool = False  # input sample == previous site's output sample


def _strided(n: int, limit: int) -> np.ndarray:
    if n <= limit:
        return np.arange(n)
    return np.unique(np.linspace(0, n - 1, limit).astype(np.int64))


def _rms(a: np.ndarray) -> float:
    a = np.asarray(a, np.float64)
    return float(np.sqrt(np.mean(a * a))) if a.size else 0.0


def probe_gain(x: np.ndarray, w: np.ndarray, method: str = "jvp") -> float:
    """Jacobian-norm estimate of the site's map on a random tangent.

    ``rms(J v) / rms(v)`` for a fixed-seed tangent ``v`` shaped like the
    recorded operand sample ``x`` — via ``jax.jvp`` of ``t -> t @ w`` at
    ``x`` (``method="jvp"``), or the finite-difference output
    perturbation ``(f(x + eps*v) - f(x)) / eps`` (``method="fd"``, the
    fallback when the JVP cannot be taken).  The map is linear in ``x``,
    so both estimates agree to rounding; what matters is the *random*
    tangent: data directions concentrate on the loud singular vectors,
    an injected error does not.
    """
    v = np.random.default_rng(PROBE_SEED).standard_normal(
        x.shape).astype(np.float32)
    v_rms = _rms(v)
    if v_rms == 0.0:
        return 1.0
    if method == "jvp":
        _, jv = jax.jvp(lambda t: jnp.matmul(t, jnp.asarray(w)),
                        (jnp.asarray(x),), (jnp.asarray(v),))
        jv = np.asarray(jv)
    elif method == "fd":
        eps = 1e-2
        x64, w64 = x.astype(np.float64), w.astype(np.float64)
        jv = ((x64 + eps * v.astype(np.float64)) @ w64 - x64 @ w64) / eps
    else:
        raise ValueError(f"unknown probe method {method!r}")
    return _rms(jv) / v_rms


def _site_gain(x: np.ndarray, w: np.ndarray) -> float:
    """JVP probe with the finite-difference fallback (see :func:`probe_gain`)."""
    try:
        g = probe_gain(x, w, method="jvp")
    except Exception:  # non-differentiable dtype / probe failure
        g = probe_gain(x, w, method="fd")
    return g if np.isfinite(g) and g > 0.0 else 1.0


@contextlib.contextmanager
def record_operands(max_rows: int = MAX_ROWS, max_cols: int = MAX_COLS):
    """Context manager: install the nmatmul operand tap, yield the store.

    The store maps full layer path -> :class:`SiteRecord`.  Repeat calls
    to the same path keep the first sample (one forward over a calibration
    batch visits each site once; scanned encoder layers and serving loops
    revisit) and bump ``calls``.  Sites reached with traced operands
    (inside scan/jit) are invisible — run the pass eagerly with
    ``force_unroll`` (both the decoder segments and the whisper-style
    encoder honour it).
    """
    store: Dict[str, SiteRecord] = {}
    order = [0]
    # chain probe: the previous site's exact sample product, the column
    # indices it was sampled at, and its FULL output width — the next
    # site's input is compared in the previous site's sampled column
    # space, so chains are detected even when the intermediate width
    # exceeds max_cols
    prev_probe = [None]  # (exact_sample, col_idx, full_out_cols)

    def tap(path, x, w):
        if getattr(w, "ndim", 0) != 2:
            return
        if path in store:
            r = store[path]
            store[path] = dataclasses.replace(r, calls=r.calls + 1)
            return
        x2 = np.asarray(x, np.float32).reshape(-1, x.shape[-1])
        w2 = np.asarray(w, np.float32)
        x2 = x2[_strided(x2.shape[0], max_rows)]
        cols = _strided(w2.shape[1], max_cols)
        full_out_cols = w2.shape[1]
        w2 = w2[:, cols]
        exact = x2.astype(np.float64) @ w2.astype(np.float64)
        chained = False
        if prev_probe[0] is not None:
            p_exact, p_cols, p_full = prev_probe[0]
            if (x2.shape[0] == p_exact.shape[0]
                    and x2.shape[1] == p_full):
                x_sub = x2[:, p_cols]
                # atol scales with the signal: a fixed floor would let
                # unrelated quiet tensors (rms << 1) false-positive
                chained = bool(np.allclose(
                    x_sub, p_exact, rtol=CHAIN_RTOL,
                    atol=CHAIN_ATOL * _rms(p_exact)))
        store[path] = SiteRecord(
            path=path, x=x2, w=w2,
            out_rms=_rms(exact),
            order=order[0],
            in_rms=_rms(x2),
            gain=_site_gain(x2, w2),
            chained=chained)
        order[0] += 1
        prev_probe[0] = (exact, cols, full_out_cols)

    prev = set_operand_tap(tap)
    try:
        yield store
    finally:
        set_operand_tap(prev)


def propagation_coefficients(store: Mapping[str, SiteRecord]) -> Dict[str, float]:
    """Flat first-order alpha per site: ``out_rms / out_rms(last site)``.

    The last-executed site is the network head (``fc`` / ``lm_head``), so
    its coefficient is exactly 1; upstream sites scale by how loud their
    output is relative to the head's.  This is the *data-magnitude* term
    of the composition — the gain and tail terms (:class:`SensitivityModel`)
    multiply on top of it.
    """
    if not store:
        return {}
    last = max(store.values(), key=lambda r: r.order)
    net_rms = max(last.out_rms, 1e-30)
    return {p: r.out_rms / net_rms for p, r in store.items()}


def downstream_gains(store: Mapping[str, SiteRecord]) -> Dict[str, float]:
    """Per site, the product of gain coefficients along its downstream
    *chain*: starting from the next-executed site, multiply ``gain`` while
    each successive site is ``chained`` to its predecessor; the first
    unchained site ends the run (the perturbation rides the residual /
    branching stream from there, unit gain).  The head's own coefficient
    is 1."""
    ordered = sorted(store.values(), key=lambda r: r.order)
    out: Dict[str, float] = {}
    # suffix pass: G_i = gain_{i+1} * G_{i+1} while site i+1 is chained
    for i in range(len(ordered) - 1, -1, -1):
        if i + 1 < len(ordered) and ordered[i + 1].chained:
            out[ordered[i].path] = (ordered[i + 1].gain
                                    * out[ordered[i + 1].path])
        else:
            out[ordered[i].path] = 1.0
    return out


def mred_tail_factor(store: Mapping[str, SiteRecord]) -> float:
    """MRED-vs-rms conversion at the head: ``sqrt(2/pi) * mean(1/|y|) *
    rms(y)`` over the head site's recorded exact sample (zero elements
    masked, like :func:`repro.core.metrics.mred`).

    For a centered error ``e`` independent of the output ``y``,
    ``E[|e|/|y|] = E[|e|] * E[1/|y|] = sqrt(2/pi) * rms(e) * E[1/|y|]`` —
    so predicted-MRED = tail * (rms-relative error).  Heavy small-``|y|``
    tails (logits near decision boundaries) push this well above 1; the
    flat model's implicit ``tail = 1`` was the dominant source of its ~2x
    composed-error under-prediction on deep stacks.
    """
    if not store:
        return 1.0
    last = max(store.values(), key=lambda r: r.order)
    y = (last.x.astype(np.float64) @ last.w.astype(np.float64)).ravel()
    y = y[y != 0.0]
    if y.size == 0:
        return 1.0
    return float(np.sqrt(2.0 / np.pi) * np.mean(1.0 / np.abs(y)) * _rms(y))


@dataclasses.dataclass
class SensitivityModel:
    """Per-site records + propagation/gain coefficients + error caches.

    ``alpha`` is the flat data-magnitude coefficient, ``gain`` the per-site
    downstream-chain gain product ``G_i``, ``tail`` the head's MRED
    conversion factor; :meth:`contribution` composes all three with the
    site's local rms error (see the module docstring for the formula and
    its assumptions).
    """

    sites: Dict[str, SiteRecord]
    alpha: Dict[str, float]
    baseline_error: float = 0.0    # eval_fn under the default-only policy
    gain: Dict[str, float] = dataclasses.field(default_factory=dict)
    tail: float = 1.0
    # the design local rms errors are measured against: the calibration
    # default (what eval_fn's reference ran), or None for the float64
    # exact product
    reference: Optional[NumericsConfig] = None

    def __post_init__(self):
        self._local: Dict[Tuple[str, NumericsConfig], float] = {}
        self._local_rms: Dict[Tuple[str, NumericsConfig], float] = {}
        self._ref: Dict[str, np.ndarray] = {}  # per-path reference output
        if not self.gain:
            self.gain = downstream_gains(self.sites)

    @classmethod
    def from_store(cls, store: Mapping[str, SiteRecord],
                   baseline_error: float = 0.0,
                   reference: Optional[NumericsConfig] = None,
                   ) -> "SensitivityModel":
        return cls(dict(store), propagation_coefficients(store),
                   baseline_error, downstream_gains(store),
                   mred_tail_factor(store), reference)

    def _approx(self, path: str, cfg: NumericsConfig) -> np.ndarray:
        r = self.sites[path]
        with numerics_scope(cfg):
            return np.asarray(
                nmatmul(jnp.asarray(r.x), jnp.asarray(r.w)), np.float64)

    def _reference(self, path: str) -> np.ndarray:
        if path not in self._ref:  # cached: one reference per path, not
            r = self.sites[path]   # one per (path, candidate) pair
            self._ref[path] = (
                r.x.astype(np.float64) @ r.w.astype(np.float64)
                if self.reference is None
                else self._approx(path, self.reference))
        return self._ref[path]

    def local_error(self, path: str, cfg: NumericsConfig) -> float:
        """MRED the design induces at ``path`` on its recorded operands,
        against the float64 exact product (the paper's per-multiplier
        metric; diagnostic, not what the composition propagates)."""
        key = (path, cfg)
        if key not in self._local:
            r = self.sites[path]
            exact = r.x.astype(np.float64) @ r.w.astype(np.float64)
            self._local[key] = mred(self._approx(path, cfg), exact)
        return self._local[key]

    def local_rms_error(self, path: str, cfg: NumericsConfig) -> float:
        """rms relative error the design induces at ``path`` on its
        recorded operands — ``rms(approx - ref) / rms(ref)`` where ``ref``
        is the calibration default's own output (:attr:`reference`; the
        float64 exact product when None).  This is the quantity linear
        maps transport, i.e. what :meth:`contribution` propagates."""
        key = (path, cfg)
        if key not in self._local_rms:
            ref = self._reference(path)
            err = self._approx(path, cfg) - ref
            self._local_rms[key] = _rms(err) / max(_rms(ref), 1e-30)
        return self._local_rms[key]

    def contribution(self, path: str, cfg: NumericsConfig) -> float:
        """Predicted network-output MRED contribution of one assignment:
        ``calls * tail * alpha * G * local_rms_error`` (gain-aware
        composition).  ``calls`` weights execution multiplicity: an
        unindexed ``encoder.blocks.*`` site runs once per scanned encoder
        layer during the (unrolled) calibration pass, and each execution
        injects the design's error independently — the linear composition
        must count every injection, not just the first recorded sample."""
        return (self.sites[path].calls * self.tail * self.alpha[path]
                * self.gain.get(path, 1.0)
                * self.local_rms_error(path, cfg))

    def predict(self, assignments: Mapping[str, NumericsConfig]) -> float:
        """Composed network error of a per-site assignment (first-order,
        linear over the assigned sites, on top of the baseline)."""
        return self.baseline_error + sum(
            self.contribution(p, c) for p, c in assignments.items()
            if p in self.sites)


class _CalibrationPolicy(NumericsPolicy):
    """Default-only policy that forces scanned segments — decoder repeats
    and the whisper-style encoder stack — to unroll so the operand tap
    sees concrete arrays at every call site."""

    force_unroll = True


def calibration_policy(default: Optional[NumericsConfig] = None) -> NumericsPolicy:
    return _CalibrationPolicy((), default=default or EXACT)


def calibrate(eval_fn, default: Optional[NumericsConfig] = None,
              max_rows: int = MAX_ROWS, max_cols: int = MAX_COLS) -> SensitivityModel:
    """One instrumented pass: run ``eval_fn`` under the default-only
    calibration policy with the operand tap installed; returns the fitted
    :class:`SensitivityModel` (``eval_fn`` is invoked exactly once)."""
    with record_operands(max_rows, max_cols) as store:
        base = float(eval_fn(calibration_policy(default)))
    return SensitivityModel.from_store(store, baseline_error=base,
                                       reference=default or EXACT)
