"""Composed-error sensitivity model — one calibration pass, O(L) configuration.

The greedy auto-configurer (``repro.core.sweep.auto_configure``,
``method="greedy"``) re-evaluates the whole network once per candidate
assignment: fine for ResNet-18-class calibration, intractable for the LM
zoo.  This module replaces those full-network evaluations with a
first-order error-composition model built from a **single instrumented
calibration pass**:

1. ``record_operands`` installs the operand tap in ``repro.core.numerics``;
   one forward under the (default-only) calibration policy records, per
   ``nmatmul`` call site, a bounded sample of its operand distribution and
   the rms magnitude of its exact product.  Scanned transformer segments
   are transparently unrolled for the pass (``NumericsPolicy.force_unroll``)
   so every site executes eagerly with concrete operands.
2. Per site, the **local error** of a candidate design is the MRED of the
   recorded operand sample pushed through that design — no network in the
   loop, just a tiny matmul per (site, candidate).
3. Per site, a first-order **error-propagation coefficient** ``alpha``
   maps call-site MRED into network-output error: under the unit-gain
   residual-stream assumption, a relative perturbation of magnitude
   ``delta`` injected at a site whose output rms is ``r`` arrives at the
   network output (the last executed site: ``fc`` / ``lm_head``) as an
   absolute perturbation ``delta * r``, i.e. a relative output error
   ``delta * r / r_last`` — so ``alpha = out_rms / out_rms_last``.
4. The **composed error** of an assignment is the linear first-order sum
   ``sum_l alpha_l * delta_l`` — deliberately conservative versus an RSS
   composition (independent per-site errors partially cancel), so the
   prediction upper-bounds the typical measured error.

The cross-validation tests (``tests/test_sensitivity.py``) pin the proxy
against the greedy baseline on the ResNet-18 calibration setup, and the
property tests (``tests/test_hypothesis_properties.py``) assert the
composed prediction brackets measured network error within a stated
factor on random layer stacks.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Dict, Mapping, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from .metrics import mred
from .numerics import EXACT, NumericsConfig, nmatmul, set_operand_tap
from .policy import NumericsPolicy
from .scope import numerics_scope

# bounded per-site operand sample: rows of x, columns of w (strided —
# deterministic, so calibration and its golden fixtures are reproducible)
MAX_ROWS = 64
MAX_COLS = 64


@dataclasses.dataclass(frozen=True)
class SiteRecord:
    """One call site's recorded operand distribution."""

    path: str
    x: np.ndarray          # (<=MAX_ROWS, K) float32 operand rows
    w: np.ndarray          # (K, <=MAX_COLS) float32 weight columns
    out_rms: float         # rms of the exact (float64) sample product
    order: int             # execution order of the site's first call
    calls: int = 1         # times the site was hit during the pass


def _strided(n: int, limit: int) -> np.ndarray:
    if n <= limit:
        return np.arange(n)
    return np.unique(np.linspace(0, n - 1, limit).astype(np.int64))


@contextlib.contextmanager
def record_operands(max_rows: int = MAX_ROWS, max_cols: int = MAX_COLS):
    """Context manager: install the nmatmul operand tap, yield the store.

    The store maps full layer path -> :class:`SiteRecord`.  Repeat calls
    to the same path keep the first sample (one forward over a calibration
    batch visits each site once; serving loops would revisit) and bump
    ``calls``.  Sites reached with traced operands (inside scan/jit) are
    invisible — run the pass eagerly with ``force_unroll``.
    """
    store: Dict[str, SiteRecord] = {}
    order = [0]

    def tap(path, x, w):
        if getattr(w, "ndim", 0) != 2:
            return
        if path in store:
            r = store[path]
            store[path] = dataclasses.replace(r, calls=r.calls + 1)
            return
        x2 = np.asarray(x, np.float32).reshape(-1, x.shape[-1])
        w2 = np.asarray(w, np.float32)
        x2 = x2[_strided(x2.shape[0], max_rows)]
        w2 = w2[:, _strided(w2.shape[1], max_cols)]
        exact = x2.astype(np.float64) @ w2.astype(np.float64)
        store[path] = SiteRecord(
            path=path, x=x2, w=w2,
            out_rms=float(np.sqrt(np.mean(exact * exact))),
            order=order[0])
        order[0] += 1

    prev = set_operand_tap(tap)
    try:
        yield store
    finally:
        set_operand_tap(prev)


def propagation_coefficients(store: Mapping[str, SiteRecord]) -> Dict[str, float]:
    """First-order alpha per site: ``out_rms / out_rms(last site)``.

    The last-executed site is the network head (``fc`` / ``lm_head``), so
    its coefficient is exactly 1; upstream sites scale by how loud their
    output is relative to the head's.
    """
    if not store:
        return {}
    last = max(store.values(), key=lambda r: r.order)
    net_rms = max(last.out_rms, 1e-30)
    return {p: r.out_rms / net_rms for p, r in store.items()}


@dataclasses.dataclass
class SensitivityModel:
    """Per-site operand records + propagation coefficients + error cache."""

    sites: Dict[str, SiteRecord]
    alpha: Dict[str, float]
    baseline_error: float = 0.0    # eval_fn under the default-only policy

    def __post_init__(self):
        self._local: Dict[Tuple[str, NumericsConfig], float] = {}

    @classmethod
    def from_store(cls, store: Mapping[str, SiteRecord],
                   baseline_error: float = 0.0) -> "SensitivityModel":
        return cls(dict(store), propagation_coefficients(store),
                   baseline_error)

    def local_error(self, path: str, cfg: NumericsConfig) -> float:
        """MRED the design induces at ``path`` on its recorded operands."""
        key = (path, cfg)
        if key not in self._local:
            r = self.sites[path]
            exact = r.x.astype(np.float64) @ r.w.astype(np.float64)
            with numerics_scope(cfg):
                approx = np.asarray(
                    nmatmul(jnp.asarray(r.x), jnp.asarray(r.w)), np.float64)
            self._local[key] = mred(approx, exact)
        return self._local[key]

    def contribution(self, path: str, cfg: NumericsConfig) -> float:
        """Predicted network-output error contribution of one assignment."""
        return self.alpha[path] * self.local_error(path, cfg)

    def predict(self, assignments: Mapping[str, NumericsConfig]) -> float:
        """Composed network error of a per-site assignment (first-order sum
        over the assigned sites, on top of the baseline)."""
        return self.baseline_error + sum(
            self.contribution(p, c) for p, c in assignments.items()
            if p in self.sites)


class _CalibrationPolicy(NumericsPolicy):
    """Default-only policy that forces scanned segments to unroll so the
    operand tap sees concrete arrays at every call site."""

    force_unroll = True


def calibration_policy(default: Optional[NumericsConfig] = None) -> NumericsPolicy:
    return _CalibrationPolicy((), default=default or EXACT)


def calibrate(eval_fn, default: Optional[NumericsConfig] = None,
              max_rows: int = MAX_ROWS, max_cols: int = MAX_COLS) -> SensitivityModel:
    """One instrumented pass: run ``eval_fn`` under the default-only
    calibration policy with the operand tap installed; returns the fitted
    :class:`SensitivityModel` (``eval_fn`` is invoked exactly once)."""
    with record_operands(max_rows, max_cols) as store:
        base = float(eval_fn(calibration_policy(default)))
    return SensitivityModel.from_store(store, baseline_error=base)
