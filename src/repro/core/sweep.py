"""Design-space exploration: the OpenACM-style accuracy-PPA sweep.

The paper's point is that the compiler can explore (multiplier, n, format)
configurations systematically.  This module produces the Pareto frontier
over the registered designs — error (MRED on a caller-supplied operand
distribution) vs area/power from the analytical model — and can recommend
a configuration for an error budget.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from . import ppa
from .metrics import mred
from .registry import get_multiplier

SWEEPABLE = {
    # name -> (ppa kind, ppa kwargs)
    "AC3-3": ("ac", {"n": 3}), "AC4-4": ("ac", {"n": 4}),
    "AC5-5": ("ac", {"n": 5}), "AC6-6": ("ac", {"n": 6}),
    "AC7-7": ("ac", {"n": 7}),
    "ACL4": ("acl", {"n": 4}), "ACL5": ("acl", {"n": 5}),
    "ACL6": ("acl", {"n": 6}),
    "MMBS5": ("mmbs", {"k": 5}), "MMBS6": ("mmbs", {"k": 6}),
    "MMBS7": ("mmbs", {"k": 7}),
    "CSS12": ("css", {"m": 12}), "CSS14": ("css", {"m": 14}),
    "CSS16": ("css", {"m": 16}), "CSS18": ("css", {"m": 18}),
    "NC": ("log", {"comp": "nc"}), "LPC": ("log", {"comp": "lpc"}),
    "HPC": ("log", {"comp": "hpc"}),
}


@dataclasses.dataclass(frozen=True)
class SweepPoint:
    name: str
    mred: float
    area_um2: float
    power_w: float
    pareto: bool = False


def sweep(x=None, y=None, seed: int = 0, n_samples: int = 50_000):
    """Evaluate every design; returns SweepPoints with Pareto flags."""
    if x is None:
        rng = np.random.default_rng(seed)
        x = rng.uniform(-4, 4, n_samples).astype(np.float32)
        y = rng.uniform(-4, 4, n_samples).astype(np.float32)
    exact = np.asarray(x, np.float64) * np.asarray(y, np.float64)
    points = []
    for name, (kind, kw) in SWEEPABLE.items():
        approx = np.asarray(get_multiplier(name)(jnp.asarray(x), jnp.asarray(y)))
        est = ppa.estimate(kind, name=name, **kw)
        points.append(SweepPoint(name, mred(approx, exact),
                                 est.logic_area_um2, est.power_w))
    # Pareto: no other point has both lower error and lower area
    out = []
    for p in points:
        dominated = any(q.mred <= p.mred and q.area_um2 < p.area_um2
                        for q in points if q is not p)
        out.append(dataclasses.replace(p, pareto=not dominated))
    return sorted(out, key=lambda p: p.mred)


def recommend(error_budget: float, metric: str = "area_um2", **kw) -> SweepPoint:
    """Cheapest design meeting the MRED budget (the compiler's selection)."""
    candidates = [p for p in sweep(**kw) if p.mred <= error_budget]
    if not candidates:
        raise ValueError(f"no design meets MRED <= {error_budget}")
    return min(candidates, key=lambda p: getattr(p, metric))
