"""Design-space exploration: the OpenACM-style accuracy-PPA sweep.

The paper's point is that the compiler can explore (multiplier, n, format)
configurations systematically.  This module produces the Pareto frontier
over the registered designs — error (MRED on a caller-supplied operand
distribution) vs area/power from the analytical model — and can recommend
a configuration for an error budget.

:func:`auto_configure` lifts the selection from one multiplier to a whole
network (the OpenACMv2 accuracy-constrained co-optimization role): given a
network-level error budget and an evaluation callback over a calibration
batch, it assigns each layer the cheapest design (by the same PPA model)
whose composed network error stays within budget, and emits a serializable
:class:`~repro.core.policy.NumericsPolicy`.  Two methods:

``method="proxy"`` (default)
    One instrumented calibration pass fits the gain-aware composed-error
    sensitivity model (``repro.core.sensitivity``): per-site operand
    samples, flat propagation coefficients ``alpha``, JVP-probe gain
    coefficients composed along observed dataflow chains, and the head's
    MRED tail factor.  The assignment is then solved as a knapsack-style
    exchange over the modeled per-site contributions ``tail * alpha * G *
    local_rms_error`` — O(layers x designs) local matmuls, exactly
    **one** ``eval_fn`` invocation.  Scales to the LM zoo.  The model is
    first-order and composes linearly over sites (no cancellation
    credit): predictions upper-bound the typical measured error (see
    ``docs/sensitivity.md`` and the brackets pinned in
    ``tests/test_hypothesis_properties.py``).
``method="greedy"``
    The original schedule: probe each layer, then re-evaluate the whole
    network per candidate assignment — O(layers x designs) *full-network*
    evals.  Measured (not modeled) error; use it to cross-validate the
    proxy on calibration-sized networks.
"""
from __future__ import annotations

import dataclasses
import heapq
import re
from typing import Callable, Mapping, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from . import ppa
from .metrics import mred
from .numerics import NumericsConfig
from .policy import NumericsPolicy
from .registry import get_multiplier

SWEEPABLE = {
    # name -> (ppa kind, ppa kwargs)
    "AC3-3": ("ac", {"n": 3}), "AC4-4": ("ac", {"n": 4}),
    "AC5-5": ("ac", {"n": 5}), "AC6-6": ("ac", {"n": 6}),
    "AC7-7": ("ac", {"n": 7}),
    "ACL4": ("acl", {"n": 4}), "ACL5": ("acl", {"n": 5}),
    "ACL6": ("acl", {"n": 6}),
    "MMBS5": ("mmbs", {"k": 5}), "MMBS6": ("mmbs", {"k": 6}),
    "MMBS7": ("mmbs", {"k": 7}),
    "CSS12": ("css", {"m": 12}), "CSS14": ("css", {"m": 14}),
    "CSS16": ("css", {"m": 16}), "CSS18": ("css", {"m": 18}),
    "NC": ("log", {"comp": "nc"}), "LPC": ("log", {"comp": "lpc"}),
    "HPC": ("log", {"comp": "hpc"}),
}


@dataclasses.dataclass(frozen=True)
class SweepPoint:
    name: str
    mred: float
    area_um2: float
    power_w: float
    pareto: bool = False


def sweep(x=None, y=None, seed: int = 0, n_samples: int = 50_000):
    """Evaluate every design; returns SweepPoints with Pareto flags."""
    if x is None:
        rng = np.random.default_rng(seed)
        x = rng.uniform(-4, 4, n_samples).astype(np.float32)
        y = rng.uniform(-4, 4, n_samples).astype(np.float32)
    exact = np.asarray(x, np.float64) * np.asarray(y, np.float64)
    points = []
    for name, (kind, kw) in SWEEPABLE.items():
        approx = np.asarray(get_multiplier(name)(jnp.asarray(x), jnp.asarray(y)))
        est = ppa.estimate(kind, name=name, **kw)
        points.append(SweepPoint(name, mred(approx, exact),
                                 est.logic_area_um2, est.power_w))
    # Pareto: no other point has both lower error and lower area
    out = []
    for p in points:
        dominated = any(q.mred <= p.mred and q.area_um2 < p.area_um2
                        for q in points if q is not p)
        out.append(dataclasses.replace(p, pareto=not dominated))
    return sorted(out, key=lambda p: p.mred)


def recommend(error_budget: float, metric: str = "area_um2", **kw) -> SweepPoint:
    """Cheapest design meeting the MRED budget (the compiler's selection)."""
    candidates = [p for p in sweep(**kw) if p.mred <= error_budget]
    if not candidates:
        raise ValueError(f"no design meets MRED <= {error_budget}")
    return min(candidates, key=lambda p: getattr(p, metric))


# ---------------------------------------------------------------------------
# per-layer auto-configuration (network-level budget -> NumericsPolicy)
# ---------------------------------------------------------------------------

def config_ppa(cfg: NumericsConfig) -> ppa.PPAEstimate:
    """PPA estimate of the multiplier a NumericsConfig instantiates.

    ``segmented`` mode (the TPU split-float analogue) is modeled by its
    hardware counterpart: 1 pass ≈ ACL-n (single high-segment product),
    2-3 passes ≈ AC-n-n (conditional multi-pass) — a proxy, but the same
    one the paper's Table II rows describe.
    """
    if cfg.mode == "exact":
        return ppa.estimate("exact", name="Exact")
    if cfg.mode == "emulated":
        spec = SWEEPABLE.get(cfg.multiplier) or SWEEPABLE.get(cfg.multiplier.upper())
        if spec is None:  # AFPM family outside the sweep table (e.g. AC-fp16)
            low = cfg.multiplier.lower()
            kind = "acl" if low.startswith("acl") else "ac"
            return ppa.estimate(kind, name=cfg.multiplier, n=cfg.seg_n)
        kind, kw = spec
        return ppa.estimate(kind, name=cfg.multiplier, **kw)
    if cfg.mode == "segmented":
        kind = "acl" if cfg.seg_passes == 1 else "ac"
        return ppa.estimate(kind, name=f"segmented-{cfg.seg_passes}", n=cfg.seg_n)
    raise ValueError(f"unknown numerics mode {cfg.mode!r}")


def policy_area(policy: NumericsPolicy, layer_paths: Sequence[str],
                counts: Optional[Mapping[str, int]] = None) -> float:
    """Modeled logic area (um^2) of one multiplier instance per layer path.

    ``counts`` weights paths by instance multiplicity (e.g. a path standing
    for all experts of a MoE layer); per-expert path enumerations
    (``repro.core.policy.expert_paths``, ``transformer.layer_paths``) carry
    multiplicity in the path list itself and need no counts.
    """
    counts = counts or {}
    return sum(config_ppa(policy.lookup(p)).logic_area_um2 * counts.get(p, 1)
               for p in layer_paths)


def policy_ppa(policy: NumericsPolicy, layer_paths: Sequence[str],
               counts: Optional[Mapping[str, int]] = None) -> dict:
    """Table II roll-up of a policy over a network's call sites: total
    modeled logic area and power, one multiplier instance per path (scaled
    by ``counts`` multiplicity), plus the all-exact baseline for deltas."""
    counts = counts or {}
    area = power = 0.0
    for p in layer_paths:
        est = config_ppa(policy.lookup(p))
        k = counts.get(p, 1)
        area += est.logic_area_um2 * k
        power += est.power_w * k
    n = sum(counts.get(p, 1) for p in layer_paths)
    exact = ppa.estimate("exact", name="Exact")
    return {
        "area_um2": area,
        "power_w": power,
        "baseline_area_um2": exact.logic_area_um2 * n,
        "baseline_power_w": exact.power_w * n,
        "n_sites": n,
    }


def _emulated_config(name: str) -> NumericsConfig:
    m = re.match(r"ACL?(\d)", name)
    return NumericsConfig(mode="emulated", multiplier=name,
                          seg_n=int(m.group(1)) if m else 5)


def pareto_candidates(**kw) -> list:
    """(name, NumericsConfig) per Pareto-frontier design — the default
    per-layer candidate set for :func:`auto_configure`."""
    return [(p.name, _emulated_config(p.name)) for p in sweep(**kw) if p.pareto]


@dataclasses.dataclass(frozen=True)
class AutoConfigResult:
    policy: NumericsPolicy                    # serializable (policy.to_json())
    error: float                              # network error: measured (greedy)
    #                                           or composed-model (proxy)
    area_um2: float                           # modeled logic area, all layers
    baseline_area_um2: float                  # all layers on the default design
    assignments: Tuple[Tuple[str, str], ...]  # (layer path, design name)
    n_evals: int                              # eval_fn invocations spent
    method: str = "greedy"
    predicted_error: Optional[float] = None   # proxy only: == error

    @property
    def area_reduction(self) -> float:
        return 1.0 - self.area_um2 / self.baseline_area_um2


def auto_configure(eval_fn: Callable[[NumericsPolicy], float],
                   layer_paths: Sequence[str],
                   error_budget: float,
                   candidates: Optional[Sequence[Tuple[str, NumericsConfig]]] = None,
                   default: Optional[NumericsConfig] = None,
                   verbose: bool = False,
                   method: str = "proxy") -> AutoConfigResult:
    """Per-layer design selection under a network error budget.

    ``eval_fn(policy)`` runs the network on a calibration batch under
    ``policy`` and returns its error versus the exact baseline (e.g. MRED
    of the logits — any monotone scalar works).  ``layer_paths`` names the
    layers to configure (e.g. ``repro.models.resnet.layer_paths(cfg)`` or
    ``repro.models.transformer.layer_paths(cfg)``); ``candidates`` is a
    ``(name, NumericsConfig)`` list (default: the emulated Pareto-frontier
    designs from :func:`pareto_candidates`); ``default`` is the config of
    unassigned layers (default exact fp32).

    ``method="proxy"`` (default) spends exactly one ``eval_fn`` call: the
    instrumented calibration pass of ``repro.core.sensitivity`` records
    per-site operand distributions, propagation coefficients and gain
    coefficients (the gain-aware composed-error model), then a
    knapsack-style exchange assigns each site the cheapest design whose
    composed (modeled) error stays within budget — the proxy pass must run
    the network eagerly (no surrounding jit) so the operand tap sees
    concrete arrays; scanned segments and the whisper-style encoder are
    unrolled automatically for the pass.  ``method="greedy"`` keeps the
    original measured-error schedule: ``O(L)`` probe evals plus up to
    ``O(L * C)`` assignment evals, each a full-network run.
    """
    if method not in ("proxy", "greedy"):
        raise ValueError(f"unknown method {method!r}; expected 'proxy' or 'greedy'")
    default = default or NumericsConfig(mode="exact", compute_dtype="float32")
    cand = list(candidates) if candidates is not None else pareto_candidates()
    cand.sort(key=lambda nc: config_ppa(nc[1]).logic_area_um2)
    exact_area = config_ppa(default).logic_area_um2
    cand = [(n, c) for n, c in cand
            if config_ppa(c).logic_area_um2 < exact_area]
    if not cand:
        raise ValueError("no candidate is cheaper than the default design")
    if method == "proxy":
        return _proxy_configure(eval_fn, layer_paths, error_budget, cand,
                                default, exact_area, verbose)
    n_evals = 0

    def evaluate(assign) -> float:
        nonlocal n_evals
        n_evals += 1
        return float(eval_fn(NumericsPolicy.from_assignments(
            {p: c for p, (_, c) in assign.items()}, default=default)))

    sens = {p: evaluate({p: cand[0]}) for p in layer_paths}
    assign: dict = {}
    err = evaluate(assign)  # default-only policy (0 when default == baseline)
    for p in sorted(layer_paths, key=lambda q: sens[q]):
        for name, c in cand:
            trial = dict(assign)
            trial[p] = (name, c)
            e = evaluate(trial)
            if e <= error_budget:
                assign, err = trial, e
                if verbose:
                    print(f"[auto_configure] {p:16s} -> {name:7s} "
                          f"err={e:.3e} (budget {error_budget:.3e})")
                break
        else:
            if verbose:
                print(f"[auto_configure] {p:16s} -> default (no candidate fits)")

    policy = NumericsPolicy.from_assignments(
        {p: c for p, (_, c) in assign.items()}, default=default)
    return AutoConfigResult(
        policy=policy,
        error=err,
        area_um2=policy_area(policy, layer_paths),
        baseline_area_um2=exact_area * len(layer_paths),
        assignments=tuple((p, assign[p][0]) for p in layer_paths if p in assign),
        n_evals=n_evals,
        method="greedy",
    )


def _proxy_configure(eval_fn, layer_paths, error_budget, cand, default,
                     exact_area, verbose) -> AutoConfigResult:
    """Knapsack-style assignment over the composed-error model.

    Start every recorded site on its cheapest candidate; while the composed
    prediction exceeds budget, take the exchange (site -> lower-error
    option, the default included as the zero-error anchor) with the best
    error-reduction-per-area ratio.  Terminates within budget because the
    all-default assignment contributes zero composed error.

    Site areas are weighted by the execution multiplicity the calibration
    pass observed (``SiteRecord.calls``): an unindexed ``encoder.blocks.*``
    site stands for ``encoder_layers`` physical multiplier instances, and
    its contribution is already ``calls``-weighted — both sides of the
    error-per-area exchange ratio (and the reported area roll-up) must
    count the same instances or encoder sites look ``calls``-times more
    error-efficient per um^2 than they are.
    """
    from . import sensitivity as sens_mod  # deferred: keeps sweep importable alone

    model = sens_mod.calibrate(eval_fn, default=default)
    areas = [(name, c, config_ppa(c).logic_area_um2) for name, c in cand]
    # physical multiplier instances per path (1 unless the pass executed
    # the site multiple times — the unrolled scanned encoder)
    mult = {p: (model.sites[p].calls if p in model.sites else 1)
            for p in layer_paths}

    opts = {}       # path -> [(name or None, cfg, area, contribution)]
    for p in layer_paths:
        if p not in model.sites:
            continue  # never executed on the calibration batch: stays default
        o = [(name, c, a * mult[p], model.contribution(p, c))
             for name, c, a in areas]
        o.append((None, default, exact_area * mult[p], 0.0))
        opts[p] = o
    if layer_paths and not opts:
        raise ValueError(
            "proxy calibration recorded no operand samples for any of the "
            f"{len(layer_paths)} layer paths — eval_fn must execute the "
            "network EAGERLY (no surrounding jax.jit; scanned segments are "
            "unrolled automatically) and route its matmuls through nmatmul "
            "with the passed policy; use method='greedy' if eager execution "
            "is not possible")
    choice = {p: min(range(len(o)), key=lambda i: o[i][2])
              for p, o in opts.items()}
    total = model.baseline_error + sum(
        opts[p][i][3] for p, i in choice.items())

    # best exchange per site, served from a max-heap with lazy (versioned)
    # invalidation: O((L*C) log(L*C)) overall instead of rescanning every
    # (site, option) pair per exchange — L is tens of thousands of sites on
    # the per-expert LM-zoo enumerations this method exists for.  The
    # globally best exchange is always some site's best exchange, so the
    # schedule is identical to the full rescan.
    def best_move(p):
        cur = opts[p][choice[p]]
        best = None
        for j, alt in enumerate(opts[p]):
            gain = cur[3] - alt[3]
            if gain <= 0.0:
                continue
            score = gain / max(alt[2] - cur[2], 1e-9)
            if best is None or score > best[0]:
                best = (score, gain, j)
        return best

    version = dict.fromkeys(opts, 0)
    heap = []
    for p in opts:
        bm = best_move(p)
        if bm is not None:
            heapq.heappush(heap, (-bm[0], version[p], p, bm[2], bm[1]))
    while total > error_budget and heap:
        _, ver, p, j, gain = heapq.heappop(heap)
        if ver != version[p]:
            continue  # stale: this site was exchanged since the push
        choice[p] = j
        total -= gain
        version[p] += 1
        bm = best_move(p)
        if bm is not None:
            heapq.heappush(heap, (-bm[0], version[p], p, bm[2], bm[1]))

    assign = {p: opts[p][i] for p, i in choice.items()
              if opts[p][i][0] is not None}
    if verbose:
        for p in layer_paths:
            if p in assign:
                name, _, _, contrib = assign[p]
                print(f"[auto_configure/proxy] {p:24s} -> {name:12s} "
                      f"alpha={model.alpha[p]:.3f} "
                      f"G={model.gain.get(p, 1.0):.3f} "
                      f"contrib={contrib:.3e}")
            elif p in opts:
                print(f"[auto_configure/proxy] {p:24s} -> default")
        print(f"[auto_configure/proxy] composed error {total:.3e} "
              f"(budget {error_budget:.3e}, baseline "
              f"{model.baseline_error:.3e}, tail x{model.tail:.2f})")
    policy = NumericsPolicy.from_assignments(
        {p: c for p, (_, c, _, _) in assign.items()}, default=default)
    return AutoConfigResult(
        policy=policy,
        error=total,
        area_um2=policy_area(policy, layer_paths, counts=mult),
        baseline_area_um2=exact_area * sum(mult[p] for p in layer_paths),
        assignments=tuple((p, assign[p][0]) for p in layer_paths if p in assign),
        n_evals=1,
        method="proxy",
        predicted_error=total,
    )
