"""Deterministic synthetic data pipelines (host-shardable, restart-exact)."""
from . import pipeline, synthetic
from .synthetic import DataConfig
