"""Host data pipeline: per-shard iterators with prefetch + device put.

On a real multi-host pod each process feeds its addressable shard of the
``batch`` axis (``jax.make_array_from_process_local_data``); in this
container there is one process, so the pipeline degenerates to device_put
with the global sharding — the code path is identical either way.
"""
from __future__ import annotations

import collections
import threading
from typing import Callable, Iterator

import jax
import numpy as np


class Prefetcher:
    """Background-thread prefetch of host batches (depth-bounded)."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._it = it
        self._q = collections.deque()
        self._depth = depth
        self._lock = threading.Condition()
        self._done = False
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        try:
            for item in self._it:
                with self._lock:
                    while len(self._q) >= self._depth:
                        self._lock.wait(0.1)
                    self._q.append(item)
                    self._lock.notify_all()
        finally:
            with self._lock:
                self._done = True
                self._lock.notify_all()

    def __iter__(self):
        return self

    def __next__(self):
        with self._lock:
            while not self._q and not self._done:
                self._lock.wait(0.1)
            if self._q:
                item = self._q.popleft()
                self._lock.notify_all()
                return item
        raise StopIteration


def sharded_batches(make_batch: Callable[[int], dict], start_step: int = 0,
                    sharding=None, prefetch: int = 2):
    """Iterator of device batches from a (step -> host batch) function."""
    def gen():
        step = start_step
        while True:
            host = make_batch(step)
            if sharding is not None:
                dev = {k: jax.device_put(v, sharding[k] if isinstance(sharding, dict)
                                         else sharding) for k, v in host.items()}
            else:
                dev = host
            yield step, dev
            step += 1

    return Prefetcher(gen(), depth=prefetch)
