"""Deterministic synthetic data: token streams, images, host-shardable.

Every generator is a pure function of (seed, step, shard) so any worker can
reproduce any batch — this is what makes checkpoint/restart and elastic
re-sharding exact: no data-loader state needs to be saved beyond the step
counter.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int = 32000
    seq_len: int = 1024
    global_batch: int = 8
    seed: int = 0
    kind: str = "lm"  # lm | markov | images


def _keys(seed, step, shard):
    return np.random.default_rng(np.uint64(seed) * 1_000_003 + np.uint64(step) * 97 + np.uint64(shard))


def lm_batch(cfg: DataConfig, step: int, shard: int = 0, nshards: int = 1):
    """Markov-chain token stream — learnable structure (loss actually drops),
    unlike uniform noise.  Returns host numpy arrays (tokens, targets)."""
    rng = _keys(cfg.seed, step, shard)
    b = cfg.global_batch // nshards
    S = cfg.seq_len
    # degree-2 markov: next = (a*prev + b*prev2 + noise) mod vocab
    toks = np.empty((b, S + 1), np.int64)
    toks[:, 0] = rng.integers(0, cfg.vocab, b)
    toks[:, 1] = rng.integers(0, cfg.vocab, b)
    noise = rng.integers(0, 17, (b, S + 1))
    for t in range(2, S + 1):
        toks[:, t] = (31 * toks[:, t - 1] + 7 * toks[:, t - 2] + noise[:, t]) % cfg.vocab
    return {
        "tokens": toks[:, :-1].astype(np.int32),
        "targets": toks[:, 1:].astype(np.int32),
    }


def cifar_like(cfg: DataConfig, step: int, n: int = None, classes: int = 10):
    """Synthetic 32x32 images with class-dependent structure (frequency +
    color statistics per class), so a CNN genuinely learns to separate them.
    Deterministic in (seed, step)."""
    rng = _keys(cfg.seed, step, 0)
    n = n or cfg.global_batch
    labels = rng.integers(0, classes, n)
    xx, yy = np.meshgrid(np.arange(32), np.arange(32))
    images = np.empty((n, 32, 32, 3), np.float32)
    for i in range(n):
        c = labels[i]
        fx, fy = 1 + (c % 5), 1 + (c // 5) * 2
        phase = rng.uniform(0, 2 * np.pi)
        base = np.sin(2 * np.pi * (fx * xx + fy * yy) / 32 + phase)
        color = np.array([np.cos(c), np.sin(2 * c), np.cos(3 * c)]) * 0.5
        img = base[..., None] * (0.5 + color) + rng.normal(0, 0.35, (32, 32, 3))
        images[i] = img
    mean, std = images.mean(), images.std() + 1e-6
    return {"images": ((images - mean) / std).astype(np.float32),
            "labels": labels.astype(np.int32)}


def gray_images(seed: int, n: int, size: int = 128):
    """Natural-ish grayscale test images for the image-processing benchmark
    (sums of oriented gratings + smooth blobs; stands in for Lake/Mandril/
    Cameraman/etc. which we cannot ship)."""
    rng = np.random.default_rng(seed)
    xx, yy = np.meshgrid(np.linspace(0, 1, size), np.linspace(0, 1, size))
    out = np.empty((n, size, size), np.float32)
    for i in range(n):
        img = np.zeros((size, size))
        for _ in range(6):
            fx, fy = rng.uniform(1, 12, 2)
            img += rng.uniform(0.2, 1.0) * np.sin(
                2 * np.pi * (fx * xx + fy * yy) + rng.uniform(0, 2 * np.pi))
        for _ in range(3):
            cx, cy, s = rng.uniform(0.2, 0.8, 2).tolist() + [rng.uniform(0.01, 0.08)]
            img += rng.uniform(0.5, 1.5) * np.exp(-((xx - cx) ** 2 + (yy - cy) ** 2) / s)
        img = (img - img.min()) / (img.max() - img.min() + 1e-9)
        out[i] = img * 255.0
    return out
