"""Distribution: logical sharding rules, collectives, pipeline parallelism, fault tolerance."""
from . import sharding
