"""Fault tolerance: straggler watchdog, heartbeat registry, restart policy.

On a real pod these hooks attach to the coordination service; the logic
(EWMA step timing, deviation flags, restart decisions, elastic re-mesh
planning) is host-side and identical, so it is implemented and tested here.

Components:
  StepWatchdog      — per-step wall-time EWMA; flags stragglers (> k*median)
  HeartbeatRegistry — worker liveness with timeout -> dead-set
  RestartPolicy     — bounded restarts with exponential backoff
  plan_elastic_mesh — choose the largest (data', model) mesh that fits the
                      surviving device count (model kept — weights reshard
                      over data only, so no weight redistribution)
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict, deque
from typing import Dict, List, Optional, Tuple


class StepWatchdog:
    """Tracks per-worker step durations; flags stragglers."""

    def __init__(self, threshold: float = 2.0, window: int = 16):
        self.threshold = threshold
        self.durations: Dict[int, deque] = defaultdict(lambda: deque(maxlen=window))

    def record(self, worker: int, duration_s: float):
        self.durations[worker].append(duration_s)

    def _avg(self, worker: int) -> Optional[float]:
        d = self.durations[worker]
        return sum(d) / len(d) if d else None

    def stragglers(self) -> List[int]:
        avgs = {w: self._avg(w) for w in self.durations if self._avg(w) is not None}
        if len(avgs) < 2:
            return []
        med = sorted(avgs.values())[len(avgs) // 2]
        return sorted(w for w, a in avgs.items() if a > self.threshold * med)


class HeartbeatRegistry:
    def __init__(self, timeout_s: float = 60.0, clock=time.monotonic):
        self.timeout_s = timeout_s
        self._clock = clock
        self._last: Dict[int, float] = {}

    def beat(self, worker: int):
        self._last[worker] = self._clock()

    def dead(self) -> List[int]:
        now = self._clock()
        return sorted(w for w, t in self._last.items() if now - t > self.timeout_s)

    def alive(self) -> List[int]:
        now = self._clock()
        return sorted(w for w, t in self._last.items() if now - t <= self.timeout_s)


@dataclasses.dataclass
class RestartPolicy:
    max_restarts: int = 5
    backoff_base_s: float = 5.0
    backoff_cap_s: float = 300.0
    restarts: int = 0

    def next_delay(self) -> Optional[float]:
        """None = give up; otherwise seconds to wait before restarting."""
        if self.restarts >= self.max_restarts:
            return None
        delay = min(self.backoff_base_s * (2 ** self.restarts), self.backoff_cap_s)
        self.restarts += 1
        return delay

    def reset(self):
        self.restarts = 0


def plan_elastic_mesh(n_alive_chips: int, model_parallel: int) -> Tuple[int, int]:
    """Largest (data, model) mesh with the fixed model-parallel degree.

    Keeping ``model`` fixed means weight shards stay valid; only the data
    axis shrinks, so resuming = restore checkpoint with new data-axis
    shardings (checkpoint/io.restore handles the re-slice).
    """
    if n_alive_chips < model_parallel:
        raise ValueError(
            f"cannot keep model_parallel={model_parallel} with {n_alive_chips} chips")
    data = n_alive_chips // model_parallel
    # batch divisibility prefers powers of two on the data axis
    while data & (data - 1):
        data -= 1
    return data, model_parallel


def should_restart_from(ckpt_dir: str) -> Optional[int]:
    """Restart protocol: resume from the newest committed checkpoint."""
    from repro.checkpoint.io import latest_step

    return latest_step(ckpt_dir)
