"""GPipe-style pipeline parallelism over a 'pipe' mesh axis (shard_map).

Optional third parallelism dimension for depth-dominated models: layers are
split into S stages along 'pipe'; microbatches stream through with
collective_permute between neighbours.  Bubble fraction = (S-1)/(M+S-1).

The assigned production meshes are (data, model) and (pod, data, model), so
the 40-cell dry-run does not use PP; this module is exercised by unit tests
on a small CPU mesh (deliverable: the parallelism feature exists and is
correct, and can be enabled by adding a 'pipe' axis to the mesh).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(mesh: Mesh, stage_fn, params_stacked, x_microbatches,
                   axis: str = "pipe"):
    """Run ``stage_fn(stage_params, x) -> x`` as an S-stage GPipe pipeline.

    params_stacked: pytree with leading dim S (one slice per stage, already
                    sharded over 'pipe').
    x_microbatches: (M, mb, ...) microbatches, replicated over 'pipe'.
    Returns (M, mb, ...) outputs (replicated).
    """
    S = mesh.shape[axis]
    M = x_microbatches.shape[0]
    steps = M + S - 1

    def per_stage(params, xs):
        # params: stage slice (leading dim 1 under shard_map); xs: (M, mb, ...)
        params = jax.tree.map(lambda a: a[0], params)
        stage = jax.lax.axis_index(axis)

        def body(carry, t):
            buf_in, outputs = carry
            # stage 0 injects microbatch t (if t < M); others use received buf
            mb_idx = jnp.clip(t, 0, M - 1)
            x0 = xs[mb_idx]
            x_in = jnp.where(stage == 0, x0, buf_in)
            y = stage_fn(params, x_in)
            # forward y to the next stage (ring permute; last stage's output
            # wraps to stage 0 where it is collected)
            y_next = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % S) for i in range(S)])
            # collect: stage 0 receives the finished microbatch (t - (S-1))
            out_idx = jnp.clip(t - (S - 1), 0, M - 1)
            valid = (t >= (S - 1))
            outputs = jax.lax.cond(
                valid,
                lambda o: o.at[out_idx].set(y_next),
                lambda o: o,
                outputs,
            )
            return (y_next, outputs), None

        outputs0 = jnp.zeros_like(xs)
        (_, outputs), _ = jax.lax.scan(
            body, (jnp.zeros_like(xs[0]), outputs0), jnp.arange(steps))
        # every stage holds a copy of `outputs`, only stage 0's is the real
        # collection; broadcast it
        outputs = jax.lax.ppermute(
            outputs, axis, [(0, i) for i in range(S)]) if S > 1 else outputs
        return outputs

    in_specs = (jax.tree.map(lambda _: P(axis), params_stacked), P())
    return shard_map(
        per_stage, mesh=mesh, in_specs=in_specs, out_specs=P(),
        check_rep=False,
    )(params_stacked, x_microbatches)


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_microbatches + n_stages - 1)
