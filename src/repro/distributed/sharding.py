"""Logical-axis sharding: rules mapping logical names -> mesh axes.

MaxText-style GSPMD approach: parameters/activations carry *logical* axis
names; a rule table maps each name to a mesh axis (or None = replicated).
``spec_for`` enforces divisibility — if a dim doesn't divide by the mesh
axis size it silently falls back to replication, which is what makes the
whole 10-arch zoo (40 heads, 6 heads, odd vocabs, batch=1 long-context)
shardable under one rule set.

A process-wide context (``use_mesh_rules``) lets model code call
``logical_constraint(x, axes)`` without threading mesh/rules through every
function; outside the context it is a no-op (CPU unit tests).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# -- default rule tables ------------------------------------------------------

# weights + activations, training (TP over 'model', DP/FSDP over 'data'(+pod))
TRAIN_RULES = {
    # weight axes
    "vocab": "model",
    "embed": None,            # -> "data" when cfg.fsdp (ZeRO-3 style)
    "embed_table": None,      # embedding/unembed d_model dim: never fsdp
    "mlp": "model",
    "experts": "model",
    "q_dim": "model",         # fused heads*head_dim projections
    "kv_dim": "model",
    "q_lora": None,
    "kv_lora": None,
    "ssm_inner": "model",
    "ssm_state": None,
    "layers": None,
    "conv": None,
    # activation axes
    "batch": ("pod", "data"),
    "seq": "model",           # sequence parallelism on the residual stream
    "heads": "model",
    "kv_seq": "model",
    "expert_cap": ("pod", "data"),
}

# serving: weights TP'd over 'model'; MoE experts spread over 'data' too
SERVE_RULES = dict(TRAIN_RULES)
SERVE_RULES.update({
    "experts": ("pod", "data"),
    "batch": ("pod", "data"),
    "seq": "model",
    "kv_seq": "model",
})


def rules_for(cfg, mode: str) -> dict:
    rules = dict(TRAIN_RULES if mode == "train" else SERVE_RULES)
    if getattr(cfg, "fsdp", False) and mode == "train":
        rules["embed"] = ("pod", "data")
    if not getattr(cfg, "seq_shard_activations", True):
        rules["seq"] = None
    overrides = getattr(cfg, "sharding_overrides", None)
    if overrides:
        rules.update(dict(overrides))
    return rules


# -- spec construction with divisibility fallback -----------------------------

def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        out = 1
        for a in axis:
            out *= _axis_size(mesh, a)
        return out
    return mesh.shape[axis] if axis in mesh.shape else 1


def _present(mesh: Mesh, axis):
    """Filter rule entries down to axes that exist in this mesh."""
    if axis is None:
        return None
    if isinstance(axis, tuple):
        kept = tuple(a for a in axis if a in mesh.shape)
        if not kept:
            return None
        return kept if len(kept) > 1 else kept[0]
    return axis if axis in mesh.shape else None


def spec_for(axes: Sequence[Optional[str]], shape: Sequence[int], mesh: Mesh,
             rules: dict) -> P:
    """Logical axes tuple + concrete shape -> PartitionSpec (divisibility-safe)."""
    used = set()
    parts = []
    for dim, name in zip(shape, axes):
        axis = _present(mesh, rules.get(name)) if name else None
        if axis is not None:
            flat = axis if isinstance(axis, tuple) else (axis,)
            if any(a in used for a in flat) or dim % _axis_size(mesh, axis) != 0:
                axis = None
            else:
                used.update(flat)
        parts.append(axis)
    return P(*parts)


def is_axes_leaf(x) -> bool:
    """A logical-axes tuple: plain tuple of axis names / None (NamedTuples
    like optimizer states are pytrees, not leaves)."""
    return (isinstance(x, tuple) and not hasattr(x, "_fields")
            and all(isinstance(e, (str, type(None))) for e in x))


def tree_shardings(specs_tree, shapes_tree, mesh: Mesh, rules: dict):
    """Map a specs tree (+ matching shapes tree) to NamedShardings."""
    def one(axes, shaped):
        return NamedSharding(mesh, spec_for(axes, shaped.shape, mesh, rules))

    return jax.tree.map(one, specs_tree, shapes_tree, is_leaf=is_axes_leaf)


# -- ambient mesh context ------------------------------------------------------

_ctx = threading.local()


@contextlib.contextmanager
def use_mesh_rules(mesh: Mesh, rules: dict):
    # NamedSharding carries its mesh, so no ambient jax mesh is required —
    # the context only records (mesh, rules) for logical_constraint.
    prev = getattr(_ctx, "state", None)
    _ctx.state = (mesh, rules)
    try:
        yield
    finally:
        _ctx.state = prev


def current_mesh_rules():
    return getattr(_ctx, "state", None)


def logical_constraint(x, axes):
    """with_sharding_constraint by logical axes; no-op outside a mesh context."""
    state = current_mesh_rules()
    if state is None:
        return x
    mesh, rules = state
    spec = spec_for(axes, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
