"""Pallas TPU kernels for the compute hot-spots.

  afpm_matmul  — segmented (split-float) approximate matmul on the MXU;
                 the TPU-native image of the paper's mantissa segmentation
  afpm_bitwise — bit-level AFPM datapath on the VPU (paper-faithful)
  ssd_scan     — Mamba2 SSD chunked scan (mamba2/zamba2 architectures)

Each kernel has a pure-jnp oracle in ``ref.py`` and a jit'd public wrapper
in ``ops.py`` (TPU -> Pallas, CPU -> XLA reference; tests run the kernels
in interpret mode).
"""
from . import ops, ref

__all__ = ["ops", "ref"]
