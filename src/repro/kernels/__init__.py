"""Kernel substrate: Pallas TPU kernels + portable backend dispatch.

  afpm_matmul  — segmented (split-float) approximate matmul on the MXU;
                 the TPU-native image of the paper's mantissa segmentation
  afpm_bitwise — bit-level AFPM datapath on the VPU (paper-faithful)
  ssd_scan     — Mamba2 SSD chunked scan (mamba2/zamba2 architectures)

Layering:

  compat.py    — JAX-version shim (CompilerParams / BlockSpec drift);
                 the only place allowed to touch ``pltpu.*CompilerParams``
  dispatch.py  — backend resolution (auto | pallas | interpret | xla) and
                 per-kernel block-size lookups (measured autotuner table
                 first, static (backend, shape bucket) fallback); the
                 audited entry points
  autotune.py  — measure-and-cache block-size autotuner: versioned
                 ``TUNE_<device>.json`` artifacts, explicit activation,
                 swept out-of-band via ``python -m benchmarks.autotune``
  ref.py       — pure-jnp oracles defining each kernel's exact semantics
  ops.py       — jit'd public wrappers the model zoo calls

Tests validate the kernel bodies in ``interpret`` mode on CPU and pin
them against ``ref.py``; ``NumericsConfig.backend`` selects the backend
end-to-end.
"""
from . import autotune, compat, dispatch, ops, ref

__all__ = ["autotune", "compat", "dispatch", "ops", "ref"]
