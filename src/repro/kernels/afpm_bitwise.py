"""Pallas TPU kernel: bit-level AFPM elementwise multiply (VPU datapath).

The paper-faithful datapath (segments, conditional execution, compensation,
3n-bit accumulator — see ``repro.core.afpm``) is pure uint32 bit
manipulation, which maps onto the TPU VPU.  This kernel tiles the operands
through VMEM and runs that datapath per block; it is the building block
for CiM-style elementwise workloads (image blending/masking) and for
emulated-numerics studies at tensor granularity.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.afpm import AFPMConfig, afpm_mult_f32

from . import compat

DEFAULT_BLOCK = (256, 256)


def _kernel(x_ref, y_ref, o_ref, *, cfg: AFPMConfig):
    o_ref[...] = afpm_mult_f32(x_ref[...], y_ref[...], cfg)


def afpm_bitwise_pallas(
    x: jax.Array,
    y: jax.Array,
    cfg: AFPMConfig = AFPMConfig(),
    *,
    block=DEFAULT_BLOCK,
    interpret: bool = False,
) -> jax.Array:
    """Elementwise AFPM multiply of two equal-shape arrays (any rank)."""
    if x.shape != y.shape:
        raise ValueError(f"shape mismatch {x.shape} vs {y.shape}")
    shape = x.shape
    flat = 1
    for s in shape:
        flat *= s
    bm, bn = block
    # reshape to 2-D tile space (pad to block multiple)
    ncols = bn
    nrows = (flat + ncols - 1) // ncols
    pad_rows = (-nrows) % bm
    x2 = jnp.resize(jnp.ravel(x), (nrows * ncols,)).reshape(nrows, ncols)
    y2 = jnp.resize(jnp.ravel(y), (nrows * ncols,)).reshape(nrows, ncols)
    if pad_rows:
        x2 = jnp.pad(x2, ((0, pad_rows), (0, 0)))
        y2 = jnp.pad(y2, ((0, pad_rows), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_kernel, cfg=cfg),
        grid=(x2.shape[0] // bm,),
        in_specs=[
            compat.block_spec((bm, ncols), lambda i: (i, 0)),
            compat.block_spec((bm, ncols), lambda i: (i, 0)),
        ],
        out_specs=compat.block_spec((bm, ncols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, jnp.float32),
        interpret=interpret,
    )(x2, y2)
    return out.reshape(-1)[:flat].reshape(shape)
