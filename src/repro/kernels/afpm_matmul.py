"""Pallas TPU kernel: segmented approximate matmul (the paper's AFPM on the MXU).

TPU adaptation of mantissa segmentation (DESIGN.md §2): each fp32 operand
tile is split in-VMEM into a high bf16 segment (hidden bit + top 7 mantissa
bits — the "A"/"C" segment) and a low bf16 segment (the "B"/"D" segment).
The mantissa partial products map onto MXU passes:

    AC   = hi(x) @ hi(w)      always executed (dominant term)
    AD   = lo(x) @ hi(w)      pass >= 2
    BC   = hi(x) @ lo(w)      pass >= 3
    BD   = lo(x) @ lo(w)      always omitted  (paper Eq. 6)

``passes`` is the accuracy knob (1 = ACL-like, 3 = AC-n-n-like); the exact
baseline is the fp32 dot (6 equivalent passes).  Accumulation is exact
fp32 in a VMEM scratch accumulator, matching the CiM macro's exact adder
tree.

2-D operands use a (M/bm, N/bn, K/bk) grid with k innermost; batched
(3-D+) operands flatten their leading axes into one grid batch dimension
— (G, M/bm, N/bn, K/bk) — so every batch element tiles the MXU natively
instead of being reshape-flattened into a tall matmul.  The fp32->bf16
split happens per tile in VMEM, so HBM traffic is the fp32 operands read
once — arithmetic intensity is identical to a plain matmul while the MXU
work is 1-3 bf16 passes instead of 6 (fp32 emulation) per tile.

Block sizes default to the substrate's tuning tables via
``kernels/dispatch.py``; version-portable Pallas construction goes
through ``kernels/compat.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import compat

DEFAULT_BM = 256
DEFAULT_BN = 256
DEFAULT_BK = 512


def _split(t):
    hi = t.astype(jnp.bfloat16)
    lo = (t - hi.astype(jnp.float32)).astype(jnp.bfloat16)
    return hi, lo


def _accumulate(x, w, acc_ref, *, passes: int):
    x = x.astype(jnp.float32)  # (bm, bk)
    w = w.astype(jnp.float32)  # (bk, bn)
    xh, xl = _split(x)
    wh, wl = _split(w)

    dot = functools.partial(jnp.dot, preferred_element_type=jnp.float32)
    acc = dot(xh, wh)                   # AC
    if passes >= 2:
        acc = acc + dot(xl, wh)         # AD (x low bits recovered)
    if passes >= 3:
        acc = acc + dot(xh, wl)         # BC (w low bits recovered)
    acc_ref[...] += acc


def _kernel2d(x_ref, w_ref, o_ref, acc_ref, *, passes: int, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    _accumulate(x_ref[...], w_ref[...], acc_ref, passes=passes)

    @pl.when(pl.program_id(2) == nk - 1)
    def _done():
        o_ref[...] = acc_ref[...]


def _kernel_batched(x_ref, w_ref, o_ref, acc_ref, *, passes: int, nk: int):
    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    _accumulate(x_ref[0], w_ref[...], acc_ref, passes=passes)

    @pl.when(pl.program_id(3) == nk - 1)
    def _done():
        o_ref[0] = acc_ref[...]


def _pad2(t, p0, p1):
    return jnp.pad(t, ((0, p0), (0, p1))) if p0 or p1 else t


def afpm_matmul_pallas(
    x: jax.Array,
    w: jax.Array,
    passes: int = 3,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    interpret: bool = False,
) -> jax.Array:
    """Segmented matmul ``x (..., K) @ w (K, N) -> (..., N) fp32``.

    ``x`` may carry any number of leading batch dims; they become a native
    grid axis (the weight tile is shared across it).
    """
    if x.ndim < 2 or w.ndim != 2:
        raise ValueError(f"need x (..., M, K) @ w (K, N); got {x.shape} @ {w.shape}")
    *lead, M, K = x.shape
    K2, N = w.shape
    if K != K2:
        raise ValueError(f"contraction mismatch {x.shape} @ {w.shape}")
    bm = min(bm, M)
    bn = min(bn, N)
    bk = min(bk, K)
    pm, pn, pk = (-M) % bm, (-N) % bn, (-K) % bk
    w = _pad2(w, pk, pn)
    Np = w.shape[1]

    if not lead:
        x = _pad2(x, pm, pk)
        Mp, Kp = x.shape
        nk = Kp // bk
        out = pl.pallas_call(
            functools.partial(_kernel2d, passes=passes, nk=nk),
            grid=(Mp // bm, Np // bn, nk),
            in_specs=[
                compat.block_spec((bm, bk), lambda i, j, k: (i, k)),
                compat.block_spec((bk, bn), lambda i, j, k: (k, j)),
            ],
            out_specs=compat.block_spec((bm, bn), lambda i, j, k: (i, j)),
            out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
            scratch_shapes=[compat.vmem((bm, bn), jnp.float32)],
            interpret=interpret,
            compiler_params=compat.tpu_compiler_params(
                dimension_semantics=("parallel", "parallel", "arbitrary"),
            ),
        )(x, w)
        return out[:M, :N] if pm or pn else out

    G = 1
    for s in lead:
        G *= s
    x = x.reshape(G, M, K)
    if pm or pk:
        x = jnp.pad(x, ((0, 0), (0, pm), (0, pk)))
    _, Mp, Kp = x.shape
    nk = Kp // bk
    out = pl.pallas_call(
        functools.partial(_kernel_batched, passes=passes, nk=nk),
        grid=(G, Mp // bm, Np // bn, nk),
        in_specs=[
            compat.block_spec((1, bm, bk), lambda g, i, j, k: (g, i, k)),
            compat.block_spec((bk, bn), lambda g, i, j, k: (k, j)),
        ],
        out_specs=compat.block_spec((1, bm, bn), lambda g, i, j, k: (g, i, j)),
        out_shape=jax.ShapeDtypeStruct((G, Mp, Np), jnp.float32),
        scratch_shapes=[compat.vmem((bm, bn), jnp.float32)],
        interpret=interpret,
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
    )(x, w)
    if pm or pn:
        out = out[:, :M, :N]
    return out.reshape(*lead, M, N)
