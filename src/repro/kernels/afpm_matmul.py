"""Pallas TPU kernel: segmented approximate matmul (the paper's AFPM on the MXU).

TPU adaptation of mantissa segmentation (DESIGN.md §2): each fp32 operand
tile is split in-VMEM into a high bf16 segment (hidden bit + top 7 mantissa
bits — the "A"/"C" segment) and a low bf16 segment (the "B"/"D" segment).
The mantissa partial products map onto MXU passes:

    AC   = hi(x) @ hi(w)      always executed (dominant term)
    AD   = lo(x) @ hi(w)      pass >= 2
    BC   = hi(x) @ lo(w)      pass >= 3
    BD   = lo(x) @ lo(w)      always omitted  (paper Eq. 6)

``passes`` is the accuracy knob (1 = ACL-like, 3 = AC-n-n-like); the exact
baseline is the fp32 dot (6 equivalent passes).  Accumulation is exact
fp32 in a VMEM scratch accumulator, matching the CiM macro's exact adder
tree.

Grid is (M/bm, N/bn, K/bk) with k innermost; the fp32->bf16 split happens
per (bm, bk)/(bk, bn) tile in VMEM, so HBM traffic is the fp32 operands
read once — arithmetic intensity is identical to a plain matmul while the
MXU work is 1-3 bf16 passes instead of 6 (fp32 emulation) per tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BM = 256
DEFAULT_BN = 256
DEFAULT_BK = 512


def _split(t):
    hi = t.astype(jnp.bfloat16)
    lo = (t - hi.astype(jnp.float32)).astype(jnp.bfloat16)
    return hi, lo


def _kernel(x_ref, w_ref, o_ref, acc_ref, *, passes: int, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)  # (bm, bk)
    w = w_ref[...].astype(jnp.float32)  # (bk, bn)
    xh, xl = _split(x)
    wh, wl = _split(w)

    dot = functools.partial(jnp.dot, preferred_element_type=jnp.float32)
    acc = dot(xh, wh)                   # AC
    if passes >= 2:
        acc = acc + dot(xl, wh)         # AD (x low bits recovered)
    if passes >= 3:
        acc = acc + dot(xh, wl)         # BC (w low bits recovered)
    acc_ref[...] += acc

    @pl.when(pl.program_id(2) == nk - 1)
    def _done():
        o_ref[...] = acc_ref[...]


def afpm_matmul_pallas(
    x: jax.Array,
    w: jax.Array,
    passes: int = 3,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    interpret: bool = False,
) -> jax.Array:
    """2-D segmented matmul ``x (M,K) @ w (K,N) -> (M,N) fp32``."""
    if x.ndim != 2 or w.ndim != 2:
        raise ValueError(f"afpm_matmul_pallas is 2-D; got {x.shape} @ {w.shape}")
    M, K = x.shape
    K2, N = w.shape
    if K != K2:
        raise ValueError(f"contraction mismatch {x.shape} @ {w.shape}")
    bm = min(bm, M)
    bn = min(bn, N)
    bk = min(bk, K)
    pm, pn, pk = (-M) % bm, (-N) % bn, (-K) % bk
    if pm or pk:
        x = jnp.pad(x, ((0, pm), (0, pk)))
    if pk or pn:
        w = jnp.pad(w, ((0, pk), (0, pn)))
    Mp, Kp = x.shape
    Np = w.shape[1]
    nk = Kp // bk

    out = pl.pallas_call(
        functools.partial(_kernel, passes=passes, nk=nk),
        grid=(Mp // bm, Np // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
    )(x, w)
    if pm or pn:
        out = out[:M, :N]
    return out
