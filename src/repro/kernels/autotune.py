"""Measure-and-cache kernel autotuner (replaces guessed tuning tables).

The static ``(backend, shape bucket)`` tables in ``dispatch.py`` guess a
block size once per bucket; serving throughput is decided by tile
choices, and the right tile is a *measured* property of the device
(cf. OpenACMv2's treatment of hardware parameters as measured, not
assumed, quantities).  This module is the measured replacement:

- :func:`sweep` times a small candidate grid of block sizes per
  ``(kernel, backend, shape bucket)`` through an injected ``measure_fn``
  (the ``benchmarks.harness.measure`` contract: a callable returning a
  median-µs float) and records the winner per key;
- the winners persist as a versioned JSON artifact
  (``kernels/TUNE_<device_kind>.json``, schema :data:`SCHEMA`) written
  atomically, so an interrupted sweep never leaves a corrupt table;
- :func:`activate` installs a table process-wide; ``dispatch``'s
  ``matmul_block_sizes`` / ``bitwise_block`` / ``scan_chunk`` consult it
  through :func:`lookup` and fall back to the static tables when no
  entry (or no table) exists.  A table tuned on a different
  ``device_kind`` never applies — lookups ignore it entirely.

Tuning is NEVER implicit: nothing in the jitted hot path measures
anything.  The sweep runs out-of-band via ``python -m
benchmarks.autotune`` (or programmatically), and activation is an
explicit opt-in — the :data:`ENV_VAR` environment variable, the
``Session(tune=...)`` knob, or a direct :func:`activate` call.  With no
artifact activated, dispatch behavior is bit-identical to the static
tables.

``tools/check_bench.py --tune-fresh ...`` validates and diffs tuning
artifacts so the perf CI can see tile-choice regressions (policy:
``docs/benchmarks.md``).
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
from typing import Callable, Mapping, Optional, Sequence

#: Versioned schema tag written into every tuning artifact;
#: loaders refuse tables whose tag does not match.
SCHEMA = "repro-tune/1"

#: Environment variable naming a tuning artifact to activate lazily on
#: first lookup (explicit opt-in without touching code).
ENV_VAR = "REPRO_TUNE_FILE"

#: The tunable kernels and the shape buckets they are keyed on
#: (buckets are ``dispatch.shape_bucket``'s).
KERNELS = ("matmul", "bitwise", "ssd")
BUCKETS = ("small", "medium", "large")


class TuneError(Exception):
    """Structured autotuner failure: bad artifact, bad key, bad grid."""


def device_kind() -> str:
    """The current host's accelerator kind, sanitized for filenames
    (``TPU v4`` -> ``tpu_v4``, CPU hosts -> ``cpu``)."""
    import jax

    devices = jax.devices()
    kind = devices[0].device_kind if devices else "none"
    return _sanitize(kind)


def _sanitize(kind: str) -> str:
    return "_".join("".join(ch if ch.isalnum() else " " for ch in
                            kind.lower()).split()) or "none"


def artifact_name(device: Optional[str] = None) -> str:
    """Default artifact filename for a device kind: ``TUNE_<device>.json``."""
    return f"TUNE_{device or device_kind()}.json"


def entry_key(kernel: str, backend: str, bucket: str) -> str:
    """The table key ``kernel/backend/bucket`` (validated)."""
    if kernel not in KERNELS:
        raise TuneError(f"unknown kernel {kernel!r}; expected one of {KERNELS}")
    if backend not in ("pallas", "interpret", "xla"):
        raise TuneError(f"unknown backend {backend!r}; expected "
                        f"pallas/interpret/xla")
    if bucket not in BUCKETS:
        raise TuneError(f"unknown bucket {bucket!r}; expected one of {BUCKETS}")
    return f"{kernel}/{backend}/{bucket}"


# -- candidate grids ---------------------------------------------------------
#
# Small grids bracketing the static defaults: the sweep stays cheap (a
# handful of timed candidates per key) while covering the choices that
# actually move throughput.  ``matmul`` blocks are (bm, bn, bk),
# ``bitwise`` blocks (rows, cols), ``ssd`` a scalar chunk length.

MATMUL_CANDIDATES = {
    "pallas": {
        "small": [(128, 128, 128), (128, 128, 256), (256, 256, 128)],
        "medium": [(128, 128, 256), (256, 256, 256), (256, 256, 512)],
        "large": [(256, 256, 256), (256, 256, 512), (512, 512, 512)],
    },
    "interpret": {
        "small": [(16, 16, 16), (32, 32, 32), (64, 64, 64)],
        "medium": [(32, 32, 32), (64, 64, 64), (128, 128, 128)],
        "large": [(64, 64, 64), (128, 128, 128), (256, 256, 256)],
    },
}

BITWISE_CANDIDATES = {
    "pallas": {
        "small": [(128, 256), (256, 256), (256, 512)],
        "medium": [(256, 256), (256, 512), (512, 256)],
        "large": [(256, 256), (512, 256), (512, 512)],
    },
    "interpret": {
        "small": [(16, 64), (32, 64), (64, 64)],
        "medium": [(32, 128), (64, 128), (128, 128)],
        "large": [(64, 256), (128, 256), (256, 256)],
    },
}

SSD_CANDIDATES = {
    "pallas": {
        "small": [64, 128, 256],
        "medium": [64, 128, 256],
        "large": [128, 256, 512],
    },
    "interpret": {
        "small": [16, 32, 64],
        "medium": [32, 64, 128],
        "large": [64, 128, 256],
    },
    # the xla reference path is chunked too — its chunk is a real CPU
    # tunable (the one the old dispatch hardcoded to 128)
    "xla": {
        "small": [32, 64, 128],
        "medium": [64, 128, 256],
        "large": [128, 256, 512],
    },
}

_GRIDS = {"matmul": MATMUL_CANDIDATES, "bitwise": BITWISE_CANDIDATES,
          "ssd": SSD_CANDIDATES}


def tunable(kernel: str, backend: str) -> bool:
    """Whether (kernel, backend) has a block-size knob at all (the xla
    matmul/bitwise references take no blocks)."""
    return kernel in _GRIDS and backend in _GRIDS[kernel]


def candidates(kernel: str, backend: str, bucket: str,
               max_extent: Optional[int] = None) -> list:
    """The candidate blocks for one table key, optionally dropping
    candidates whose every block dimension exceeds ``max_extent`` (a
    block larger than the measured problem would be silently clipped by
    the kernels, duplicating a smaller candidate's measurement)."""
    entry_key(kernel, backend, bucket)  # validate names
    if not tunable(kernel, backend):
        raise TuneError(f"kernel {kernel!r} has no tunable block on the "
                        f"{backend!r} backend")
    grid = list(_GRIDS[kernel][backend][bucket])
    if max_extent is not None:
        def fits(block):
            dims = block if isinstance(block, (tuple, list)) else (block,)
            return all(d <= max_extent for d in dims)
        kept = [b for b in grid if fits(b)]
        grid = kept or grid[:1]  # never an empty grid
    return grid


# -- the table ---------------------------------------------------------------

@dataclasses.dataclass
class TuningTable:
    """One device's measured block-size winners.

    ``entries`` maps :func:`entry_key` strings to
    ``{"block": [...]|int, "median_us": float, "candidates": {...}}`` —
    the winner plus every candidate's measured median, so an artifact
    diff shows *why* a tile was chosen, not just that it changed.
    """

    device: str
    entries: dict = dataclasses.field(default_factory=dict)
    meta: dict = dataclasses.field(default_factory=dict)

    def lookup(self, kernel: str, backend: str, bucket: str):
        """The tuned block for a key, or None (tuple-ized for dispatch)."""
        e = self.entries.get(f"{kernel}/{backend}/{bucket}")
        if e is None:
            return None
        block = e["block"]
        return tuple(block) if isinstance(block, list) else block

    def put(self, kernel: str, backend: str, bucket: str, block,
            median_us: float, measured: Optional[Mapping] = None) -> None:
        self.entries[entry_key(kernel, backend, bucket)] = {
            "block": list(block) if isinstance(block, (tuple, list)) else block,
            "median_us": float(median_us),
            "candidates": {_block_label(b): float(us)
                           for b, us in (measured or {}).items()},
        }

    def to_dict(self) -> dict:
        return {"schema": SCHEMA, "device": self.device,
                "meta": self.meta, "entries": self.entries}

    @classmethod
    def from_dict(cls, data: Mapping, source: str = "<dict>") -> "TuningTable":
        if not isinstance(data, Mapping):
            raise TuneError(f"{source}: tuning artifact is not a JSON object")
        schema = data.get("schema")
        if schema != SCHEMA:
            raise TuneError(f"{source}: schema {schema!r} does not match "
                            f"{SCHEMA!r}; regenerate with "
                            f"python -m benchmarks.autotune")
        device = data.get("device")
        if not isinstance(device, str) or not device:
            raise TuneError(f"{source}: malformed artifact: missing 'device'")
        entries = data.get("entries")
        if not isinstance(entries, Mapping):
            raise TuneError(f"{source}: malformed artifact: missing 'entries'")
        for key, e in entries.items():
            parts = key.split("/")
            if len(parts) != 3:
                raise TuneError(f"{source}: malformed entry key {key!r} "
                                f"(expected kernel/backend/bucket)")
            entry_key(*parts)
            if not isinstance(e, Mapping) or "block" not in e \
                    or "median_us" not in e:
                raise TuneError(f"{source}: malformed entry {key!r}: expected "
                                f"{{block, median_us, candidates}}")
            block = e["block"]
            if isinstance(block, list):
                if not block or not all(isinstance(d, int) and d > 0
                                        for d in block):
                    raise TuneError(f"{source}: entry {key!r}: bad block "
                                    f"{block!r}")
            elif not (isinstance(block, int) and block > 0):
                raise TuneError(f"{source}: entry {key!r}: bad block "
                                f"{block!r}")
        meta = data.get("meta")
        return cls(device=device, entries=dict(entries),
                   meta=dict(meta) if isinstance(meta, Mapping) else {})

    def save(self, path: str) -> None:
        """Atomic write (temp file + ``os.replace``): an interrupted
        sweep can never leave a half-written artifact behind."""
        path = os.fspath(path)
        tmp = f"{path}.tmp.{os.getpid()}"
        try:
            with open(tmp, "w") as f:
                json.dump(self.to_dict(), f, indent=1, sort_keys=True)
                f.write("\n")
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)


def _block_label(block) -> str:
    if isinstance(block, (tuple, list)):
        return "x".join(str(d) for d in block)
    return str(block)


def load(path: str) -> TuningTable:
    """Load + validate a tuning artifact (one-line :class:`TuneError`)."""
    try:
        with open(path) as f:
            data = json.load(f)
    except OSError as e:
        raise TuneError(f"cannot read tuning artifact {path!r}: "
                        f"{e.strerror or e}") from e
    except json.JSONDecodeError as e:
        raise TuneError(f"unreadable tuning artifact {path!r}: {e}") from e
    return TuningTable.from_dict(data, source=path)


# -- process-wide activation (what dispatch consults) ------------------------

_active: Optional[TuningTable] = None
_source: Optional[str] = None
_env_checked = False


def activate(spec=None) -> Optional[TuningTable]:
    """Install a tuning table process-wide.

    ``spec`` is a :class:`TuningTable`, a path to an artifact, or None
    (= activate :data:`ENV_VAR` if set, otherwise keep the current
    state).  Returns the active table (or None).  Activation is global
    because dispatch's lookups are module-level — exactly like the
    static tables they replace.
    """
    global _active, _source, _env_checked
    _env_checked = True
    if spec is None:
        path = os.environ.get(ENV_VAR)
        if not path:
            return _active
        spec = path
    if isinstance(spec, TuningTable):
        _active, _source = spec, "<in-memory>"
    else:
        path = os.fspath(spec)
        _active, _source = load(path), path
    return _active


def deactivate() -> None:
    """Drop the active table: dispatch falls back to the static tables."""
    global _active, _source, _env_checked
    _active, _source, _env_checked = None, None, False


def active_table() -> Optional[TuningTable]:
    return _active


def active_source() -> Optional[str]:
    """Where the active table came from (path or ``<in-memory>``)."""
    return _source


@functools.lru_cache(maxsize=1)
def _host_device() -> str:
    return device_kind()


def lookup(kernel: str, backend: str, bucket: str):
    """The tuned block for a key, or None to fall back to the static
    tables.  Pure cache read — never measures, never compiles — so it is
    safe on (and designed for) the jitted hot path's trace time.  A
    table tuned for a different device kind never applies."""
    global _env_checked
    if _active is None:
        if _env_checked or not os.environ.get(ENV_VAR):
            return None
        activate(os.environ[ENV_VAR])
    table = _active
    if table is None or table.device != _host_device():
        return None
    return table.lookup(kernel, backend, bucket)


# -- the sweep core ----------------------------------------------------------

def sweep(measure_fn: Callable, *, kernels: Sequence[str] = KERNELS,
          backends: Sequence[str] = ("interpret", "xla"),
          buckets: Sequence[str] = BUCKETS,
          sizes: Optional[Mapping[str, int]] = None,
          device: Optional[str] = None, meta: Optional[dict] = None,
          verbose: bool = False) -> TuningTable:
    """Measure every candidate and cache the winners as a TuningTable.

    ``measure_fn(kernel, backend, bucket, block, size) -> median_us``
    owns problem construction and timing (``benchmarks.autotune`` backs
    it with ``benchmarks.harness.measure``; tests inject a fake).
    ``sizes`` maps bucket -> representative max extent (used both to
    size the measured problem and to clip oversized candidates).
    Untunable (kernel, backend) pairs are skipped, so one call sweeps
    whatever the host can actually run.
    """
    sizes = dict(sizes or {})
    table = TuningTable(device=device or device_kind(), meta=dict(meta or {}))
    for kernel in kernels:
        for backend in backends:
            if not tunable(kernel, backend):
                continue
            for bucket in buckets:
                size = sizes.get(bucket)
                grid = candidates(kernel, backend, bucket, max_extent=size)
                measured = {}
                for block in grid:
                    measured[tuple(block) if isinstance(block, list)
                             else block] = float(
                        measure_fn(kernel, backend, bucket, block, size))
                winner = min(measured, key=measured.get)
                table.put(kernel, backend, bucket, winner, measured[winner],
                          measured)
                if verbose:
                    print(f"[autotune] {entry_key(kernel, backend, bucket)}"
                          f": {_block_label(winner)} "
                          f"({measured[winner]:.1f} us over "
                          f"{len(measured)} candidates)")
    return table
