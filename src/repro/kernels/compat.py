"""JAX-version compatibility shim for the Pallas TPU kernels.

The Pallas API has drifted across JAX releases in ways that break kernel
construction (not just execution):

* ``pltpu.CompilerParams`` is the current spelling of the TPU compiler
  parameter struct; older releases (including the pinned 0.4.x line) call
  it ``pltpu.TPUCompilerParams``, and very old ones take a raw
  ``mosaic=...`` dict.
* ``pl.BlockSpec`` swapped its positional argument order from
  ``(index_map, block_shape)`` to ``(block_shape, index_map)``.

Every kernel in this package goes through this module instead of touching
``pltpu.*CompilerParams`` / positional ``pl.BlockSpec`` directly, so a JAX
upgrade is a one-file change.  ``kernels/dispatch.py`` builds on top of
this for backend selection; nothing outside ``repro.kernels`` should need
to import this module.
"""
from __future__ import annotations

import inspect

from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# -- CompilerParams ---------------------------------------------------------

_COMPILER_PARAMS_CLS = getattr(
    pltpu, "CompilerParams", getattr(pltpu, "TPUCompilerParams", None)
)


def tpu_compiler_params(*, dimension_semantics=None, **kwargs):
    """Build the TPU compiler-params object for ``pl.pallas_call``.

    Accepts the modern keyword surface (``dimension_semantics`` plus any
    extra fields the resolved class supports) and returns whatever this
    JAX version expects for ``compiler_params=``.
    """
    if dimension_semantics is not None:
        kwargs["dimension_semantics"] = tuple(dimension_semantics)
    if _COMPILER_PARAMS_CLS is None:  # pre-dataclass JAX: raw mosaic dict
        return {"mosaic": kwargs}
    return _COMPILER_PARAMS_CLS(**kwargs)


# -- BlockSpec argument order -----------------------------------------------

def _blockspec_block_shape_first() -> bool:
    try:
        params = [
            p for p in inspect.signature(pl.BlockSpec.__init__).parameters
            if p not in ("self",)
        ]
        return params[0] == "block_shape"
    except (TypeError, ValueError, IndexError):  # builtins / exotic sigs
        return True


_BLOCK_SHAPE_FIRST = _blockspec_block_shape_first()


def block_spec(block_shape, index_map=None, **kwargs):
    """``pl.BlockSpec`` with the (block_shape, index_map) order regardless
    of which order the installed JAX uses positionally."""
    if _BLOCK_SHAPE_FIRST:
        return pl.BlockSpec(block_shape, index_map, **kwargs)
    return pl.BlockSpec(index_map, block_shape, **kwargs)


# -- VMEM scratch -----------------------------------------------------------

def vmem(shape, dtype):
    """VMEM scratch allocation for ``scratch_shapes=``."""
    return pltpu.VMEM(tuple(shape), dtype)
