"""Backend dispatch for the kernel substrate.

One audited entry point per kernel (``matmul`` / ``multiply`` / ``ssd``),
each taking a ``backend`` knob:

  ``auto``       pallas on TPU, xla everywhere else (the production default)
  ``pallas``     native Pallas lowering (requires a TPU backend)
  ``interpret``  the Pallas kernel body executed in interpreter mode —
                 runs on CPU/GPU, used by tests to validate the kernels
  ``xla``        the pure-jnp reference implementation (``ref.py``)

Block/tile sizes are no longer hardcoded in the kernels: every lookup
consults the measured-and-cached autotuner table first
(:mod:`repro.kernels.autotune`, keyed on ``(kernel, backend, shape
bucket, device_kind)`` and activated explicitly — never tuned implicitly
on a hot path) and falls back to the static per-bucket tables below, so
the interpreter path uses small tiles (fast to simulate) while the TPU
path uses MXU/VMEM-sized tiles.  Callers can still override explicitly.
With no tuned artifact activated, behavior is bit-identical to the
static tables.

``repro.core.numerics.NumericsConfig.backend`` feeds straight into this
module; the jit'd public wrappers live in ``ops.py``.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.afpm import AFPMConfig
from repro.core.numerics import BACKENDS

from . import autotune, ref
from .afpm_bitwise import afpm_bitwise_pallas
from .afpm_matmul import afpm_matmul_pallas
from .ssd_scan import ssd_scan_pallas


def resolve_backend(backend: str = "auto", *, force: str | None = None,
                    interpret: bool = False) -> str:
    """Resolve a backend request to one of ``pallas | interpret | xla``.

    ``force``/``interpret`` are the legacy knobs of the pre-substrate
    ``ops`` API (``force="pallas"|"xla"``, ``interpret=True``); they are
    honored only when ``backend`` is left at ``auto``.
    """
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; expected one of {BACKENDS}")
    if backend == "auto" and force is not None:
        if force not in ("pallas", "xla"):
            raise ValueError(f"unknown force={force!r}; expected 'pallas' or 'xla'")
        backend = force
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "xla"
    # the interpret downgrade applies wherever pallas was selected —
    # including via auto — matching the legacy interpret=True semantics
    if backend == "pallas" and interpret:
        backend = "interpret"
    if backend == "pallas" and jax.default_backend() != "tpu":
        raise ValueError(
            "backend='pallas' requires a TPU host; use 'interpret' to run "
            "the kernel body on CPU/GPU, or 'xla' for the reference")
    return backend


# -- block-size tuning tables -----------------------------------------------

def shape_bucket(*dims: int) -> str:
    """Bucket a shape by its largest extent: small / medium / large."""
    m = max(dims) if dims else 0
    if m <= 256:
        return "small"
    if m <= 1024:
        return "medium"
    return "large"


# (bm, bn, bk) for the segmented matmul.  TPU tiles are MXU-sized and grow
# the contraction block with the problem; interpreter tiles stay small so a
# CPU test sweep simulates few grid steps over little data.
MATMUL_BLOCKS = {
    ("pallas", "small"): (128, 128, 128),
    ("pallas", "medium"): (256, 256, 256),
    ("pallas", "large"): (256, 256, 512),
    ("interpret", "small"): (32, 32, 32),
    ("interpret", "medium"): (64, 64, 64),
    ("interpret", "large"): (128, 128, 128),
}

# (rows, cols) flat tile for the elementwise bit-level kernel.
BITWISE_BLOCKS = {
    ("pallas", "small"): (256, 256),
    ("pallas", "medium"): (256, 256),
    ("pallas", "large"): (512, 256),
    ("interpret", "small"): (32, 64),
    ("interpret", "medium"): (64, 128),
    ("interpret", "large"): (128, 256),
}

# SSD scan chunk length (the sequential grid step).  The xla reference
# is chunked too — its chunk follows the same tuning policy instead of
# the formerly hardcoded 128.
SCAN_CHUNKS = {
    ("pallas", "small"): 128,
    ("pallas", "medium"): 128,
    ("pallas", "large"): 256,
    ("interpret", "small"): 32,
    ("interpret", "medium"): 64,
    ("interpret", "large"): 128,
    ("xla", "small"): 128,
    ("xla", "medium"): 128,
    ("xla", "large"): 256,
}


def matmul_block_sizes(backend: str, M: int, K: int, N: int):
    bucket = shape_bucket(M, K, N)
    tuned = autotune.lookup("matmul", backend, bucket)
    return tuned if tuned is not None else MATMUL_BLOCKS[(backend, bucket)]


def bitwise_block(backend: str, nelems: int):
    # bucket by the side of the square an nelems-flat operand tiles into,
    # ceiling-rounded: 65536 elems -> extent 256 -> "small" (the old
    # int(nelems ** 0.5) + 1 pushed exact-boundary sizes a bucket up)
    side = math.isqrt(max(nelems, 1))
    if side * side < nelems:
        side += 1
    bucket = shape_bucket(side)
    tuned = autotune.lookup("bitwise", backend, bucket)
    return tuned if tuned is not None else BITWISE_BLOCKS[(backend, bucket)]


def scan_chunk(backend: str, L: int) -> int:
    bucket = shape_bucket(L)
    tuned = autotune.lookup("ssd", backend, bucket)
    return tuned if tuned is not None else SCAN_CHUNKS[(backend, bucket)]


# -- audited kernel entry points --------------------------------------------

def matmul(x, w, passes: int = 3, *, backend: str = "auto",
           block_sizes=None) -> jax.Array:
    """Segmented approximate matmul ``x (..., K) @ w (K, N)``.

    Batched (3-D+) ``x`` runs natively in the Pallas grid (no
    reshape-flattening of the MXU work); the xla backend is the
    ``ref.afpm_matmul_ref`` oracle.  Validation and 1-D promotion happen
    here, before the backend branch, so every backend accepts the same
    inputs.
    """
    backend = resolve_backend(backend)
    if x.ndim < 1 or w.ndim != 2:
        raise ValueError(f"need x (..., K) @ w (K, N); got {x.shape} @ {w.shape}")
    if x.shape[-1] != w.shape[0]:
        raise ValueError(f"contraction mismatch {x.shape} @ {w.shape}")
    vec = x.ndim == 1
    if vec:
        x = x[None, :]
    if backend == "xla":
        out = ref.afpm_matmul_ref(x, w, passes)
    else:
        if block_sizes is None:
            block_sizes = matmul_block_sizes(
                backend, x.shape[-2], x.shape[-1], w.shape[-1])
        bm, bn, bk = block_sizes
        out = afpm_matmul_pallas(x, w, passes, bm=bm, bn=bn, bk=bk,
                                 interpret=backend == "interpret")
    return out[0] if vec else out


def multiply(x, y, cfg: AFPMConfig = AFPMConfig(), *, backend: str = "auto",
             block=None) -> jax.Array:
    """Elementwise bit-level AFPM multiply under ``cfg``.

    Operands are broadcast first so every backend accepts the same inputs
    (the Pallas kernel itself requires equal shapes)."""
    x, y = jnp.broadcast_arrays(x, y)
    backend = resolve_backend(backend)
    if backend == "xla":
        return ref.afpm_bitwise_ref(x, y, cfg)
    if block is None:
        block = bitwise_block(backend, x.size)
    return afpm_bitwise_pallas(x, y, cfg, block=block,
                               interpret=backend == "interpret")


def ssd(x, dt, A, B, C, *, chunk: int | None = None,
        backend: str = "auto") -> jax.Array:
    """Mamba2 SSD chunked scan ``(L,H,P),(L,H),(H,),(L,N),(L,N) -> (L,H,P)``.

    ``chunk=None`` takes the tuned chunk for the resolved backend — every
    backend, the xla reference included, goes through the same
    ``scan_chunk`` lookup (tuned table first, static fallback); any
    sequence length is accepted — non-multiples of the chunk are padded
    with dt=0 steps (exact: zero decay increment and zero input weight)
    and sliced back.
    """
    backend = resolve_backend(backend)
    L = x.shape[0]
    if chunk is None:
        chunk = scan_chunk(backend, L)
    Q = min(chunk, L)
    pad = (-L) % Q
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, pad), (0, 0)))
        B = jnp.pad(B, ((0, pad), (0, 0)))
        C = jnp.pad(C, ((0, pad), (0, 0)))
    if backend == "xla":
        out = ref.ssd_scan_chunked_ref(x, dt, A, B, C, chunk=Q)
    else:
        out = ssd_scan_pallas(x, dt, A, B, C, chunk=Q,
                              interpret=backend == "interpret")
    return out[:L] if pad else out
