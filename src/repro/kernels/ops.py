"""Jit'd public wrappers for the Pallas kernels.

Thin jit shells over the substrate's audited entry points in
``dispatch.py``: backend selection (``auto | pallas | interpret | xla``)
and block-size tuning live there; this module only pins the jit/static
argument surface the model zoo and benchmarks call.

The legacy ``force=``/``interpret=`` knobs from the pre-substrate API are
still accepted (``force="xla"`` == ``backend="xla"``, ``force="pallas",
interpret=True`` == ``backend="interpret"``) so existing call sites and
tests keep working; new code should pass ``backend=`` — typically straight
from ``NumericsConfig.backend``.
"""
from __future__ import annotations

import functools

import jax

from repro.core.afpm import AFPMConfig

from . import dispatch


@functools.partial(jax.jit,
                   static_argnames=("passes", "backend", "force", "interpret"))
def afpm_matmul(x, w, passes: int = 3, *, backend: str = "auto",
                force: str | None = None, interpret: bool = False):
    """Segmented approximate matmul; batch dims on ``x`` run natively in
    the Pallas grid (no reshape-flattening)."""
    be = dispatch.resolve_backend(backend, force=force, interpret=interpret)
    return dispatch.matmul(x, w, passes, backend=be)


@functools.partial(jax.jit,
                   static_argnames=("cfg", "backend", "force", "interpret"))
def afpm_multiply(x, y, cfg: AFPMConfig = AFPMConfig(), *, backend: str = "auto",
                  force: str | None = None, interpret: bool = False):
    """Elementwise bit-level AFPM multiply."""
    be = dispatch.resolve_backend(backend, force=force, interpret=interpret)
    return dispatch.multiply(x, y, cfg, backend=be)


@functools.partial(jax.jit,
                   static_argnames=("chunk", "backend", "force", "interpret"))
def ssd_scan(x, dt, A, B, C, *, chunk: int | None = None, backend: str = "auto",
             force: str | None = None, interpret: bool = False):
    """Chunked Mamba2 SSD scan: (L,H,P),(L,H),(H,),(L,N),(L,N) -> (L,H,P).

    ``chunk=None`` takes the substrate's tuned chunk for the resolved
    backend; arbitrary sequence lengths are handled (dispatch pads with
    exact dt=0 steps).  The xla backend uses the chunked jnp
    implementation (same FLOP structure as the kernel) so dry-run cost
    analysis reflects the real algorithm.
    """
    be = dispatch.resolve_backend(backend, force=force, interpret=interpret)
    return dispatch.ssd(x, dt, A, B, C, chunk=chunk, backend=be)
