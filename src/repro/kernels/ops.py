"""Jit'd public wrappers for the Pallas kernels, with backend dispatch.

On TPU the Pallas kernels run natively; on CPU the wrappers route to the
mathematically-identical XLA reference (``ref.py``) so that large-model
paths stay fast, while tests exercise the kernels in ``interpret=True``
mode to validate the kernel bodies themselves.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.afpm import AFPMConfig

from . import ref
from .afpm_bitwise import afpm_bitwise_pallas
from .afpm_matmul import afpm_matmul_pallas
from .ssd_scan import ssd_scan_pallas


def _use_pallas(force: str | None) -> bool:
    if force == "pallas":
        return True
    if force == "xla":
        return False
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("passes", "force", "interpret"))
def afpm_matmul(x, w, passes: int = 3, *, force: str | None = None, interpret: bool = False):
    """Segmented approximate matmul; batch dims on ``x`` are flattened."""
    if not _use_pallas(force):
        return ref.afpm_matmul_ref(x, w, passes)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    out = afpm_matmul_pallas(x2, w, passes, interpret=interpret)
    return out.reshape(*lead, w.shape[-1])


@functools.partial(jax.jit, static_argnames=("cfg", "force", "interpret"))
def afpm_multiply(x, y, cfg: AFPMConfig = AFPMConfig(), *, force: str | None = None,
                  interpret: bool = False):
    """Elementwise bit-level AFPM multiply."""
    if not _use_pallas(force):
        return ref.afpm_bitwise_ref(x, y, cfg)
    return afpm_bitwise_pallas(x, y, cfg, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "force", "interpret"))
def ssd_scan(x, dt, A, B, C, *, chunk: int = 128, force: str | None = None,
             interpret: bool = False):
    """Chunked Mamba2 SSD scan: (L,H,P),(L,H),(H,),(L,N),(L,N) -> (L,H,P).

    CPU/XLA path uses the chunked jnp implementation (same FLOP structure
    as the kernel) so dry-run cost analysis reflects the real algorithm.
    """
    L = x.shape[0]
    Q = min(chunk, L)
    pad = (-L) % Q
    if pad:
        # dt=0 padding is exact: zero decay increment and zero input weight
        x = jnp.pad(x, ((0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, pad), (0, 0)))
        B = jnp.pad(B, ((0, pad), (0, 0)))
        C = jnp.pad(C, ((0, pad), (0, 0)))
    if not _use_pallas(force):
        out = ref.ssd_scan_chunked_ref(x, dt, A, B, C, chunk=Q)
    else:
        out = ssd_scan_pallas(x, dt, A, B, C, chunk=Q, interpret=interpret)
    return out[:L] if pad else out
