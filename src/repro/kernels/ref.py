"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` function defines the exact semantics its kernel must match
(tests sweep shapes/dtypes and assert_allclose kernel-vs-ref).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.afpm import AFPMConfig, afpm_mult_f32


def split_hi_lo_ref(x: jax.Array):
    """fp32 -> (hi, lo) bf16 segments; hi = RNE bf16, lo = bf16(x - hi)."""
    x = jnp.asarray(x, jnp.float32)
    hi = x.astype(jnp.bfloat16)
    lo = (x - hi.astype(jnp.float32)).astype(jnp.bfloat16)
    return hi, lo


def afpm_matmul_ref(x: jax.Array, w: jax.Array, passes: int = 3) -> jax.Array:
    """Segmented (split-float) approximate matmul oracle.

    passes=3: AC + AD + BC (BD omitted — the paper's Eq. 6 on the MXU)
    passes=2: AC + AD (weight low bits dropped)
    passes=1: AC only (ACL-like)
    """
    xh, xl = split_hi_lo_ref(x)
    wh, wl = split_hi_lo_ref(w)
    dot = lambda a, b: jax.lax.dot_general(
        a, b, (((a.ndim - 1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    out = dot(xh, wh)
    if passes >= 2:
        out = out + dot(xl, wh)
    if passes >= 3:
        out = out + dot(xh, wl)
    return out


def afpm_bitwise_ref(x: jax.Array, y: jax.Array, cfg: AFPMConfig) -> jax.Array:
    """Elementwise bit-level AFPM oracle — the core datapath itself."""
    return afpm_mult_f32(x, y, cfg)


def ssd_scan_ref(x, dt, A, B, C, chunk: int = 64):
    """Mamba2 SSD (state-space dual) chunked scan oracle.

    Shapes (single head group for the oracle):
      x:  (L, H, P)   inputs per head
      dt: (L, H)      positive step sizes
      A:  (H,)        negative state decay per head
      B:  (L, N)      input->state projection (shared across heads, "G" groups=1)
      C:  (L, N)      state->output projection
    Returns y: (L, H, P).

    Reference semantics: per head h, state S (N, P):
      S_t = exp(A_h * dt_t) * S_{t-1} + dt_t * B_t^T (x_t scaled)
      y_t = C_t S_t
    computed with a plain sequential scan (the kernel blocks it by chunks).
    """
    L, H, P = x.shape
    N = B.shape[-1]

    def head(xh, dth, Ah):
        # xh: (L, P), dth: (L,)
        decay = jnp.exp(Ah * dth)  # (L,)

        def step(S, t):
            xt, dt_t, dec, Bt, Ct = t
            S = dec * S + dt_t * (Bt[:, None] * xt[None, :])  # (N, P)
            y = Ct @ S  # (P,)
            return S, y

        S0 = jnp.zeros((N, P), jnp.float32)
        _, ys = jax.lax.scan(step, S0, (xh, dth, decay, B, C))
        return ys  # (L, P)

    y = jax.vmap(head, in_axes=(1, 1, 0), out_axes=1)(
        x.astype(jnp.float32), dt.astype(jnp.float32), A.astype(jnp.float32)
    )
    return y


def chunk_decay(dt, A, chunk: int):
    """Per-chunk log cumulative decay ``l[t] = A_h * cumsum(dt)[t]`` (the
    cumsum restarting at every chunk boundary).

    Hoisted out of both SSD execution paths on purpose: computed *inside*
    a fused kernel/scan body, ``A * cumsum(dt)`` is subject to
    fusion-context-dependent FP contraction (the compiler may emit
    ``fma(A, cs_t, -A*cs_s)`` for ``l_t - l_s`` in one lowering and two
    rounded multiplies in another), which made interpret-vs-xla agreement
    shape-dependent at small chunks.  Computing the decay once, behind a
    materialization boundary, pins its bits so both paths consume
    identical values.

    dt: (L, H), A: (H,) -> l: (L, H); L must be a multiple of ``chunk``.
    """
    L, H = dt.shape
    assert L % chunk == 0, (L, chunk)
    dtc = dt.astype(jnp.float32).reshape(L // chunk, chunk, H)
    l = A.astype(jnp.float32)[None, None, :] * jnp.cumsum(dtc, axis=1)
    return l.reshape(L, H)


def ssd_scan_chunked_ref(x, dt, A, B, C, chunk: int = 128):
    """Chunked SSD in pure jnp — the same math/FLOP structure as the Pallas
    kernel (used as the CPU/XLA execution path so dry-run cost analysis
    reflects the chunked algorithm, and as a second oracle in tests).

    Bit-exact with the interpret-mode Pallas kernel: both consume the
    same hoisted :func:`chunk_decay` and do the same per-chunk dots.
    """
    L, H, P = x.shape
    N = B.shape[-1]
    Q = min(chunk, L)
    assert L % Q == 0, (L, Q)
    nc = L // Q
    x = x.astype(jnp.float32)
    dt = dt.astype(jnp.float32)
    lfull = chunk_decay(dt, A, Q)
    Bc = B.astype(jnp.float32).reshape(nc, Q, N)
    Cc = C.astype(jnp.float32).reshape(nc, Q, N)
    t_idx = jnp.arange(Q)[:, None]
    s_idx = jnp.arange(Q)[None, :]

    def head(xh, dth, lh):
        xc = xh.reshape(nc, Q, P)
        dtc = dth.reshape(nc, Q)
        lc = lh.reshape(nc, Q)

        def chunk_body(S, inp):
            xq, dq, l, Bq, Cq = inp
            CB = Cq @ Bq.T
            # clamp: only t>=s is used, where l_t - l_s <= 0; the clamp keeps
            # the masked upper triangle finite (inf would NaN the where-grad)
            ratio = jnp.exp(jnp.minimum(l[:, None] - l[None, :], 0.0))
            M = jnp.where(t_idx >= s_idx, CB * ratio * dq[None, :], 0.0)
            y = M @ xq + (Cq * jnp.exp(l)[:, None]) @ S
            w = dq * jnp.exp(l[-1] - l)
            S_new = jnp.exp(l[-1]) * S + (Bq * w[:, None]).T @ xq
            return S_new, y

        S0 = jnp.zeros((N, P), jnp.float32)
        _, ys = jax.lax.scan(chunk_body, S0, (xc, dtc, lc, Bc, Cc))
        return ys.reshape(L, P)

    return jax.vmap(head, in_axes=(1, 1, 1), out_axes=1)(x, dt, lfull)
