"""Pallas TPU kernel: Mamba2 SSD chunked scan (state-space duality).

The SSD insight: a chunked selective-state-space scan decomposes into
MXU-friendly matmuls (intra-chunk quadratic part + low-rank state carry),
with the recurrence surviving only at chunk granularity.  The chunk
recurrence is carried in a VMEM scratch state (N, P) that persists across
grid steps — the grid's chunk axis is sequential ("arbitrary"), the head
axis parallel.

Used by the mamba2-130m and zamba2-7b architectures; it is the compute
hot-spot that makes `long_500k` sub-quadratic.

All intra-chunk math in fp32 on (Q, .) tiles:
  l_t    = A_h * cumsum(dt)[t]                 (log cumulative decay)
  y[t]   = sum_{s<=t} (C_t . B_s) dt_s e^{l_t - l_s} x_s   (intra, matmuls)
         + (C_t e^{l_t}) @ S_prev                          (state carry)
  S_new  = e^{l_Q} S_prev + sum_s dt_s e^{l_Q - l_s} B_s x_s^T

The log decay ``l`` is precomputed outside the kernel (``ref.chunk_decay``)
and streamed in per chunk: computed in-kernel it is exposed to
fusion-context-dependent FP contraction, which broke bit-exact agreement
with the chunked jnp path at small chunk sizes (see chunk_decay's docstring).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import compat
from .ref import chunk_decay

DEFAULT_CHUNK = 128


def _kernel(x_ref, dt_ref, l_ref, b_ref, c_ref, y_ref, s_ref, *, nc: int):
    cid = pl.program_id(1)

    @pl.when(cid == 0)
    def _reset():
        s_ref[...] = jnp.zeros_like(s_ref)

    x = x_ref[0].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)      # (Q,)
    l = l_ref[0].astype(jnp.float32)        # (Q,) log cumulative decay
    B = b_ref[...].astype(jnp.float32)      # (Q, N)
    C = c_ref[...].astype(jnp.float32)      # (Q, N)
    Q = x.shape[0]

    l_col = l[:, None]                      # (Q, 1)

    # intra-chunk quadratic term: M[t,s] = (C_t.B_s) dt_s e^{l_t-l_s} [t>=s]
    CB = jnp.dot(C, B.T, preferred_element_type=jnp.float32)     # (Q, Q)
    # clamped: only t>=s used (l_t-l_s <= 0); keeps masked region finite
    ratio = jnp.exp(jnp.minimum(l_col - l[None, :], 0.0))        # e^{l_t-l_s}
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    M = jnp.where(t_idx >= s_idx, CB * ratio * dt[None, :], 0.0)
    y = jnp.dot(M, x, preferred_element_type=jnp.float32)        # (Q, P)

    # inter-chunk carry: y += (C ∘ e^{l_t}) @ S_prev
    S_prev = s_ref[...]                                          # (N, P)
    y = y + jnp.dot(C * jnp.exp(l_col), S_prev, preferred_element_type=jnp.float32)

    # state update: S = e^{l_Q} S_prev + (B ∘ dt e^{l_Q - l_s})^T @ x
    lQ = l[-1]
    w = dt * jnp.exp(lQ - l)                                     # (Q,)
    s_ref[...] = jnp.exp(lQ) * S_prev + jnp.dot(
        (B * w[:, None]).T, x, preferred_element_type=jnp.float32
    )

    y_ref[0] = y


def ssd_scan_pallas(
    x: jax.Array,   # (L, H, P)
    dt: jax.Array,  # (L, H)
    A: jax.Array,   # (H,)
    B: jax.Array,   # (L, N)
    C: jax.Array,   # (L, N)
    *,
    chunk: int = DEFAULT_CHUNK,
    interpret: bool = False,
) -> jax.Array:
    L, H, P = x.shape
    N = B.shape[-1]
    Q = min(chunk, L)
    if L % Q:
        raise ValueError(f"seq len {L} not divisible by chunk {Q}")
    nc = L // Q

    # head-major layout for the grid; decay hoisted (see module docstring)
    xh = jnp.moveaxis(x, 1, 0)      # (H, L, P)
    dth = jnp.moveaxis(dt, 1, 0)    # (H, L)
    lh = jnp.moveaxis(chunk_decay(dt, A, Q), 1, 0)  # (H, L)

    out = pl.pallas_call(
        functools.partial(_kernel, nc=nc),
        grid=(H, nc),
        in_specs=[
            compat.block_spec((1, Q, P), lambda h, c: (h, c, 0)),
            compat.block_spec((1, Q), lambda h, c: (h, c)),
            compat.block_spec((1, Q), lambda h, c: (h, c)),
            compat.block_spec((Q, N), lambda h, c: (c, 0)),
            compat.block_spec((Q, N), lambda h, c: (c, 0)),
        ],
        out_specs=compat.block_spec((1, Q, P), lambda h, c: (h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((H, L, P), jnp.float32),
        scratch_shapes=[compat.vmem((N, P), jnp.float32)],
        interpret=interpret,
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
    )(xh, dth, lh, B, C)
    return jnp.moveaxis(out, 0, 1)  # (L, H, P)
