"""Launch: production meshes, input specs, step functions, dry-run, train/serve drivers."""
