import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the production mesh, abstract params/state
(ShapeDtypeStruct only — nothing is allocated), the real step function
(launch/steps.py), and runs ``jax.jit(...).lower().compile()``; it then
records ``memory_analysis()``, ``cost_analysis()``, loop-aware collective
bytes parsed from the compiled SPMD module, and the three roofline terms,
as a JSON artifact under benchmarks/artifacts/dryrun/.

Usage:
  python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--jobs 4]   # sweep every cell
"""
import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from functools import partial  # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import list_archs  # noqa: E402
from repro.distributed.sharding import (rules_for, tree_shardings,  # noqa: E402
                                        use_mesh_rules)
from repro.launch import hlo_analysis, specs, steps  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.optim import adamw  # noqa: E402

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "benchmarks", "artifacts", "dryrun")


def _mem_dict(mem):
    return {
        "argument_bytes": mem.argument_size_in_bytes,
        "output_bytes": mem.output_size_in_bytes,
        "temp_bytes": mem.temp_size_in_bytes,
        "alias_bytes": mem.alias_size_in_bytes,
        "peak_estimate_bytes": (mem.argument_size_in_bytes
                                + mem.temp_size_in_bytes
                                + mem.output_size_in_bytes
                                - mem.alias_size_in_bytes),
    }


def lower_cell(arch: str, shape_name: str, multi_pod: bool):
    """Build + lower + compile one cell; returns the result record.

    Thin wrapper: assembles a full-size :class:`repro.session.Session` and
    delegates to :func:`lower_session_cell` (``Session.dryrun`` is the
    same entry point with a policy/backend override attached)."""
    from repro.session import Session

    return lower_session_cell(Session(arch, reduced=False), shape_name,
                              multi_pod)


def lower_session_cell(session, shape_name: str, multi_pod: bool):
    """Lower + compile one (session x shape x mesh) cell — the engine
    behind ``Session.dryrun`` and the dryrun CLI.  The session carries the
    arch, numerics policy and backend; the shape and mesh select the
    workload cell."""
    arch = session.arch_id
    cfg = specs.cell_config(session.config, shape_name)
    ok, reason = specs.shape_applicable(cfg, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": reason}
    sh = specs.SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    mode = "train" if sh["kind"] == "train" else "serve"
    rules = rules_for(cfg, mode)
    t0 = time.time()

    with use_mesh_rules(mesh, rules):
        if sh["kind"] == "train":
            params_abs, pspecs = specs.abstract_params(
                cfg, dtype=jnp.dtype(cfg.param_dtype))
            opt_cfg, opt_init, opt_apply, opt_specs_fn = steps.make_optimizer(cfg)
            opt_abs = jax.eval_shape(partial(opt_init, cfg=opt_cfg), params_abs)
            param_sh = tree_shardings(pspecs, params_abs, mesh, rules)
            opt_sh = tree_shardings(opt_specs_fn(pspecs), opt_abs, mesh, rules)
            batch_abs = specs.batch_specs(cfg, shape_name)
            batch_sh = tree_shardings(
                specs.batch_axes_tree(batch_abs), batch_abs, mesh, rules)
            fn = steps.make_train_step(cfg, opt_cfg, opt_apply)
            jitted = jax.jit(fn, in_shardings=(param_sh, opt_sh, batch_sh),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_abs, opt_abs, batch_abs)
        elif sh["kind"] == "prefill":
            params_abs, pspecs = specs.abstract_params(cfg, dtype=jnp.bfloat16)
            param_sh = tree_shardings(pspecs, params_abs, mesh, rules)
            batch_abs = specs.batch_specs(cfg, shape_name)
            batch_sh = tree_shardings(
                specs.batch_axes_tree(batch_abs), batch_abs, mesh, rules)
            S_dec = cfg.decoder_len if cfg.frontend == "audio_stub" else sh["seq"]
            fn = steps.make_prefill_step(cfg, max_len=S_dec)
            jitted = jax.jit(fn, in_shardings=(param_sh, batch_sh))
            lowered = jitted.lower(params_abs, batch_abs)
        else:  # decode
            params_abs, pspecs = specs.abstract_params(cfg, dtype=jnp.bfloat16)
            param_sh = tree_shardings(pspecs, params_abs, mesh, rules)
            B, S = sh["batch"], sh["seq"]
            max_len = min(S, 4096) if cfg.frontend == "audio_stub" else S
            if cfg.frontend == "audio_stub":
                cfg = dataclasses.replace(cfg, enc_len=S)
            state_abs = specs.abstract_state(cfg, B, max_len)
            st_axes = specs.state_axes_tree(state_abs)
            state_sh = tree_shardings(st_axes, state_abs, mesh, rules)
            token_abs = specs.SDS((B, 1), jnp.int32)
            token_sh = NamedSharding(
                mesh, P(("pod", "data") if multi_pod else "data", None)
                if B % (mesh.shape.get("data", 1)) == 0 else P())
            pos_abs = specs.SDS((), jnp.int32)
            fn = steps.make_decode_step(cfg)
            jitted = jax.jit(
                fn, in_shardings=(param_sh, state_sh, token_sh,
                                  NamedSharding(mesh, P())),
                donate_argnums=(1,))
            lowered = jitted.lower(params_abs, state_abs, token_abs, pos_abs)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    hlo_text = compiled.as_text()
    cost = hlo_analysis.loop_aware_cost(hlo_text)
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # older jaxlib: one dict per device
        ca = ca[0] if ca else {}
    cost["xla_flops"] = ca.get("flops", 0.0)
    coll = hlo_analysis.collective_bytes(hlo_text)
    mflops = specs.model_flops(cfg, shape_name)
    # numerics-aware compute term: segmented multipliers skip MXU passes,
    # and a per-layer policy scales by its site-weighted pass count
    from repro.core.policy import is_policy

    if is_policy(cfg.numerics):
        from repro.models import transformer

        scale = hlo_analysis.policy_compute_scale(
            cfg.numerics, transformer.layer_paths(cfg),
            counts=transformer.layer_path_counts(cfg))
    elif getattr(cfg.numerics, "mode", "exact") == "segmented":
        scale = cfg.numerics.seg_passes / hlo_analysis.EXACT_MXU_PASSES
    else:
        scale = 1.0
    terms = hlo_analysis.roofline_terms(cost, coll, n_chips,
                                        model_flops=mflops,
                                        compute_scale=scale)
    return {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": _mem_dict(mem),
        "roofline": terms,
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
    }


def run_cell(arch, shape_name, multi_pod, out_dir=ARTIFACT_DIR):
    os.makedirs(out_dir, exist_ok=True)
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    out_path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_tag}.json")
    try:
        rec = lower_cell(arch, shape_name, multi_pod)
    except Exception as e:  # failures ARE the signal the dry-run exists for
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    status = rec["status"]
    extra = ""
    if status == "ok":
        r = rec["roofline"]
        extra = (f" dominant={r['dominant']}"
                 f" frac={r.get('roofline_fraction', 0):.3f}"
                 f" mem/chip={rec['memory']['peak_estimate_bytes']/2**30:.2f}GiB"
                 f" compile={rec['compile_s']:.0f}s")
    print(f"[dryrun] {arch} {shape_name} {mesh_tag}: {status}{extra}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(specs.SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()

    if args.all:
        cells = [(a, s, mp) for a in list_archs() for s in specs.SHAPES
                 for mp in (False, True)]
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        meshes = (False, True) if args.both_meshes else (args.multi_pod,)
        cells = [(args.arch, args.shape, mp) for mp in meshes]

    failures = 0
    for arch, shape_name, mp in cells:
        rec = run_cell(arch, shape_name, mp)
        if rec["status"] not in ("ok",) and not rec["status"].startswith("skipped"):
            failures += 1
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
