"""Elastic restart orchestration: tie together heartbeat, mesh planning,
checkpoint re-sharding and the restart policy into one recovery routine.

Reproduces nothing from the paper directly — it is the availability
layer the ROADMAP's production-scale serving/training goal needs: when a
worker dies mid-run, the coordinator re-plans the (data, model) mesh
over the survivors, re-shards the latest checkpoint
(``repro.checkpoint.io``) onto it, and resumes, so a long
approximate-numerics training or serving job keeps its accumulated
state.  Exercised by ``tests/test_elastic.py``.

On a real pod this runs in the coordinator; everything except the actual
process relaunch is exercised by unit tests here (the relaunch is a
callback so tests can fake it).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

from repro.checkpoint import io as ckpt_io
from repro.distributed.fault import (HeartbeatRegistry, RestartPolicy,
                                     plan_elastic_mesh)


@dataclasses.dataclass
class RecoveryPlan:
    resume_step: int
    data_parallel: int
    model_parallel: int
    lost_workers: list
    restart_delay_s: float


class ElasticCoordinator:
    """Decides when/how to restart a damaged job."""

    def __init__(self, ckpt_dir: str, chips_per_worker: int,
                 model_parallel: int, heartbeat_timeout_s: float = 60.0,
                 policy: Optional[RestartPolicy] = None,
                 clock=time.monotonic):
        self.ckpt_dir = ckpt_dir
        self.chips_per_worker = chips_per_worker
        self.model_parallel = model_parallel
        self.heartbeats = HeartbeatRegistry(heartbeat_timeout_s, clock=clock)
        self.policy = policy or RestartPolicy()
        self.n_workers_seen = 0

    def beat(self, worker: int):
        self.heartbeats.beat(worker)
        self.n_workers_seen = max(self.n_workers_seen, worker + 1)

    def check(self) -> Optional[RecoveryPlan]:
        """None = healthy; otherwise a recovery plan (or raises when the
        restart budget is exhausted)."""
        dead = self.heartbeats.dead()
        if not dead:
            return None
        delay = self.policy.next_delay()
        if delay is None:
            raise RuntimeError(
                f"restart budget exhausted with dead workers {dead}")
        alive = len(self.heartbeats.alive())
        data, model = plan_elastic_mesh(alive * self.chips_per_worker,
                                        self.model_parallel)
        step = ckpt_io.latest_step(self.ckpt_dir) or 0
        return RecoveryPlan(resume_step=step, data_parallel=data,
                            model_parallel=model, lost_workers=dead,
                            restart_delay_s=delay)

    def recover(self, plan: RecoveryPlan, relaunch: Callable[[RecoveryPlan], None]):
        """Execute a plan (sleep is the caller's business in tests)."""
        relaunch(plan)
        # healthy again: reset the backoff for the next incident
        self.policy.reset()
