"""Post-compilation HLO analysis: collective bytes (loop-aware) + roofline terms.

``collective_bytes`` parses ``compiled.as_text()`` (the SPMD-partitioned
module, so shapes are PER-DEVICE) and sums the output bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute,
multiplying ops inside ``while`` bodies by the loop's
``known_trip_count`` (XLA annotates scan-derived loops with it) — without
this, a 61-layer scanned model would under-count its collectives 61x.

Enables the dry-run/roofline story (``launch.dryrun``,
``benchmarks/roofline.py``): predicted communication terms for the model
zoo under different meshes and numerics configs without owning a pod —
the system-level analogue of the paper's analytical PPA model
(``repro.core.ppa``), applied to collectives instead of multiplier
datapaths.  Exercised by ``tests/test_hlo_analysis.py``.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"= ((?:\([^)]*\)|\S+)) (all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_CALL_RE = re.compile(r"(?:to_apply|condition|body|branch_computations|calls)="
                      r"\{?(%?[\w.\-]+(?:, *%?[\w.\-]+)*)\}?")
_WHILE_RE = re.compile(r" while\(.*?body=(%?[\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    total_bytes: float
    by_kind: dict


def _split_computations(hlo: str):
    """name -> list of lines, for each computation block in the module.

    Header detection is token-based (lines ending in '{' containing '->')
    because parameter lists may contain arbitrarily nested tuple types that
    defeat paren-matching regexes.
    """
    comps = {}
    cur_name, cur_lines = None, []
    for line in hlo.splitlines():
        ls = line.rstrip()
        if ls.endswith("{") and "->" in ls and not ls.startswith(" "):
            toks = ls.split()
            if toks[0] == "ENTRY":
                cur_name = toks[1]
                comps["__entry__"] = cur_lines = []
                comps[cur_name] = cur_lines
            else:
                cur_name = toks[0]
                comps[cur_name] = cur_lines = []
        elif cur_name is not None:
            cur_lines.append(line)
    return comps


def collective_bytes(hlo: str) -> CollectiveStats:
    comps = _split_computations(hlo)

    # direct collective bytes + child calls per computation
    direct = {}
    calls = defaultdict(list)  # name -> [(child, multiplier)]
    for name, lines in comps.items():
        if name == "__entry__":
            continue
        d = defaultdict(float)
        for line in lines:
            cm = _COLL_RE.search(line)
            if cm:
                d[cm.group(2)] += _shape_bytes(cm.group(1))
            wm = _WHILE_RE.search(line)
            if wm:
                tm = _TRIP_RE.search(line)
                trip = int(tm.group(1)) if tm else 1
                calls[name].append((wm.group(1), trip))
                cond = re.search(r"condition=(%?[\w.\-]+)", line)
                if cond:
                    calls[name].append((cond.group(1), trip))
            else:
                for cm2 in _CALL_RE.finditer(line):
                    if "while(" in line:
                        continue
                    for child in re.split(r", *", cm2.group(1)):
                        calls[name].append((child, 1))
        direct[name] = dict(d)

    memo = {}

    def total(name, depth=0):
        if name in memo or depth > 64:
            return memo.get(name, defaultdict(float))
        out = defaultdict(float)
        for k, v in direct.get(name, {}).items():
            out[k] += v
        for child, mult in calls.get(name, []):
            child_tot = total(child, depth + 1)
            for k, v in child_tot.items():
                out[k] += v * mult
        memo[name] = out
        return out

    entry_name = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY "):
            m = re.match(r"^ENTRY (%?[\w.\-]+)", line)
            if m:
                entry_name = m.group(1)
            break
    if entry_name is None or entry_name not in direct:
        # fall back: sum every computation once (upper bound-ish)
        agg = defaultdict(float)
        for name in direct:
            for k, v in total(name).items():
                agg[k] += v
        return CollectiveStats(sum(agg.values()), dict(agg))
    agg = total(entry_name)
    return CollectiveStats(sum(agg.values()), dict(agg))


# ---------------------------------------------------------------------------
# loop-aware FLOPs and HBM bytes (XLA's aggregate cost_analysis does NOT
# multiply while-loop bodies by their trip count, so a 61-layer scanned
# model under-counts ~61x; this walker does the multiplication).
# ---------------------------------------------------------------------------

_DEF_RE = re.compile(r"^\s*(?:ROOT )?(%?[\w.\-]+) = ((?:\([^=]*?\)|\S+)) (\w[\w\-]*)\(([^)]*)\)")
_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_BATCH_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "copy-start",
    "copy-done",
}


def _parse_ops(lines):
    """[(var, shape_str, op, [operand names], raw line)] for a computation."""
    out = []
    for line in lines:
        m = _DEF_RE.match(line)
        if not m:
            continue
        var, shape, op, args = m.groups()
        operands = re.findall(r"%[\w.\-]+", args)
        out.append((var, shape, op, operands, line))
    return out


def _first_shape_dims(shape_str):
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return None, []
    dtype, dims = m.groups()
    return dtype, [int(d) for d in dims.split(",") if d]


def loop_aware_cost(hlo: str) -> dict:
    """{'flops': f, 'bytes': b} per device, with while-trip multipliers."""
    comps = _split_computations(hlo)
    parsed = {n: _parse_ops(ls) for n, ls in comps.items() if n != "__entry__"}

    flops_direct, bytes_direct, outb_direct, fused_direct, calls = (
        {}, {}, {}, {}, defaultdict(list))
    while_bodies = set()
    for name, lines in comps.items():
        for line in lines:
            wm = _WHILE_RE.search(line)
            if wm:
                while_bodies.add(wm.group(1))
    for name, ops in parsed.items():
        symtab = {v: s for v, s, _, _, _ in ops}
        # root operands = the loop carry (or computation result)
        root_ops = set()
        for var, shape, op, operands, line in ops:
            if line.lstrip().startswith("ROOT"):
                root_ops.update(operands)
        in_loop = name in while_bodies
        fl = 0.0
        by = 0.0
        ob = 0.0
        fb = 0.0
        for var, shape, op, operands, line in ops:
            if op == "dot":
                _, out_dims = _first_shape_dims(shape)
                cdim_m = _DIMS_RE.search(line)
                lhs_shape = symtab.get(operands[0]) if operands else None
                csize = 1
                if cdim_m and lhs_shape:
                    _, lhs_dims = _first_shape_dims(lhs_shape)
                    for d in cdim_m.group(1).split(","):
                        if d and int(d) < len(lhs_dims):
                            csize *= lhs_dims[int(d)]
                n_out = 1
                for d in out_dims:
                    n_out *= d
                fl += 2.0 * n_out * csize
            elif op in ("convolution",):
                # rough: 2 * out_elems * (kernel elems per output)
                _, out_dims = _first_shape_dims(shape)
                n_out = 1
                for d in out_dims:
                    n_out *= d
                rhs_shape = symtab.get(operands[1]) if len(operands) > 1 else None
                k_elems = 1
                if rhs_shape:
                    _, rd = _first_shape_dims(rhs_shape)
                    for d in rd[:-1]:
                        k_elems *= d
                fl += 2.0 * n_out * k_elems
            if op not in _SKIP_BYTES_OPS:
                out_b = _shape_bytes(shape)
                by += out_b
                ob += out_b
                for o in operands:
                    if o in symtab:
                        by += _shape_bytes(symtab[o])
                # kernel-aware ("fused") model: inside loop bodies only the
                # carry (root operands), per-iteration weight/xs reads
                # (dynamic-slice) and collectives touch HBM; everything else
                # is assumed VMEM-resident in a tuned TPU lowering (our
                # Pallas flash/afpm kernels implement exactly that).
                if in_loop:
                    if var in root_ops or op.startswith(_COLLECTIVES):
                        fb += 2.0 * out_b
                    elif op == "dynamic-slice":
                        fb += out_b
                else:
                    fb += 2.0 * out_b
        flops_direct[name] = fl
        bytes_direct[name] = by
        outb_direct[name] = ob
        fused_direct[name] = fb

    # call graph from RAW lines (tuple-shaped ops like `while` defeat the
    # op-definition regex, so edges must not depend on it):
    # while/call/conditional children contribute flops AND bytes (x trip
    # count); fusion-like children (to_apply/calls) contribute flops only —
    # their internals never touch HBM, the call-site operands/output already
    # counted the fusion's memory traffic.
    for name, lines in comps.items():
        if name == "__entry__":
            continue
        for line in lines:
            wm = _WHILE_RE.search(line)
            if wm:
                tm = _TRIP_RE.search(line)
                trip = int(tm.group(1)) if tm else 1
                calls[name].append((wm.group(1), trip, True))
                cond = re.search(r"condition=(%?[\w.\-]+)", line)
                if cond:
                    calls[name].append((cond.group(1), trip, True))
                continue
            if " call(" in line or " conditional(" in line:
                for cm in _CALL_RE.finditer(line):
                    for child in re.split(r", *", cm.group(1)):
                        calls[name].append((child, 1, True))
                continue
            for cm in _CALL_RE.finditer(line):
                for child in re.split(r", *", cm.group(1)):
                    calls[name].append((child, 1, False))

    memo = {}

    def total(name, depth=0):
        if name in memo or depth > 64:
            return memo.get(name, (0.0, 0.0, 0.0, 0.0))
        fl = flops_direct.get(name, 0.0)
        by = bytes_direct.get(name, 0.0)
        ob = outb_direct.get(name, 0.0)
        fb = fused_direct.get(name, 0.0)
        for child, mult, with_bytes in calls.get(name, []):
            cf, cb, co, cfb = total(child, depth + 1)
            fl += cf * mult
            if with_bytes:
                by += cb * mult
                ob += co * mult
                fb += cfb * mult
        memo[name] = (fl, by, ob, fb)
        return memo[name]

    entry_name = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY "):
            m = re.match(r"^ENTRY (%?[\w.\-]+)", line)
            if m:
                entry_name = m.group(1)
            break
    if entry_name is None or entry_name not in flops_direct:
        fl = sum(total(n)[0] for n in flops_direct)
        by = sum(total(n)[1] for n in flops_direct)
        ob = sum(total(n)[2] for n in flops_direct)
        fb = sum(total(n)[3] for n in flops_direct)
    else:
        fl, by, ob, fb = total(entry_name)
    # bytes        — XLA convention (operands + outputs per op): pessimistic,
    #                every consumer re-reads from HBM (no fusion locality)
    # bytes_stream — write + single-read model (2x output bytes per op)
    # bytes_fused  — kernel-aware: inside scan bodies only carries, weight
    #                reads and collectives touch HBM (what the TPU target
    #                with our Pallas flash/afpm kernels actually streams)
    return {"flops": fl, "bytes": by, "bytes_stream": 2.0 * ob,
            "bytes_fused": fb}


# ---------------------------------------------------------------------------
# roofline terms (TPU v5e constants per the assignment)
# ---------------------------------------------------------------------------

PEAK_FLOPS_BF16 = 197e12   # per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

# MXU passes of the exact split-float product (paper Eq. 6: full 6-term
# hi/lo expansion at HIGHEST precision); segmented seg_passes=k keeps k of
# them, so a site's modeled compute-time scales by k/6 versus exact.
EXACT_MXU_PASSES = 6


def policy_compute_scale(policy, layer_paths, counts=None) -> float:
    """Modeled MXU-pass scale of a policy versus the all-exact baseline.

    Per site: exact -> 1.0; ``segmented`` -> ``seg_passes / 6`` (term
    skipping drops whole MXU passes — the paper's latency lever on the
    systolic datapath); ``emulated`` -> 1.0 (the bit-level emulation models
    accuracy, not a faster datapath).  Returns the unweighted mean over
    ``layer_paths`` (optionally weighted by ``counts`` multiplicity) — the
    factor ``roofline_terms(compute_scale=...)`` applies to t_compute.
    """
    counts = counts or {}
    num = den = 0.0
    for p in layer_paths:
        cfg = policy.lookup(p)
        k = counts.get(p, 1)
        scale = (cfg.seg_passes / EXACT_MXU_PASSES
                 if cfg.mode == "segmented" else 1.0)
        num += scale * k
        den += k
    return num / max(den, 1.0)


def policy_ppa_summary(policy, layer_paths, counts=None) -> dict:
    """Modeled area/power/latency of serving under a per-layer policy.

    Rolls the resolved policy up through the Table II PPA model
    (``repro.core.sweep.policy_ppa``: one multiplier instance per call-site
    path, expert multiplicity carried by the path list) and attaches the
    MXU-pass compute scale — what ``serve --policy`` reports and what the
    roofline's compute term is scaled by.
    """
    from repro.core import sweep  # deferred: core must not need launch

    out = dict(sweep.policy_ppa(policy, layer_paths, counts))
    out["compute_scale"] = policy_compute_scale(policy, layer_paths, counts)
    out["area_reduction"] = 1.0 - out["area_um2"] / max(
        out["baseline_area_um2"], 1e-30)
    out["power_reduction"] = 1.0 - out["power_w"] / max(
        out["baseline_power_w"], 1e-30)
    return out


def roofline_terms(cost: dict, coll: CollectiveStats, n_chips: int,
                   model_flops: float | None = None,
                   compute_scale: float = 1.0) -> dict:
    """``cost`` comes from loop_aware_cost (per-device, trip-count-correct).

    The memory term uses the kernel-aware ``bytes_fused`` model (carries +
    weight reads + collectives stream HBM; intra-body intermediates live in
    VMEM — that is what the TPU target with the Pallas kernels does); the
    stream and XLA-convention byte counts are recorded alongside.
    ``compute_scale`` folds a numerics policy into the compute term
    (:func:`policy_compute_scale`): segmented multipliers skip MXU passes,
    so the modeled t_compute shrinks while memory/collective terms do not.
    """
    hlo_flops = float(cost.get("flops", 0.0))
    hlo_bytes_xla = float(cost.get("bytes", cost.get("bytes accessed", 0.0)))
    hlo_bytes_stream = float(cost.get("bytes_stream", hlo_bytes_xla))
    hlo_bytes = float(cost.get("bytes_fused", hlo_bytes_stream))
    t_compute = hlo_flops * compute_scale / PEAK_FLOPS_BF16
    t_memory = hlo_bytes / HBM_BW
    t_collective = coll.total_bytes / ICI_BW
    dominant = max(
        (("compute", t_compute), ("memory", t_memory), ("collective", t_collective)),
        key=lambda kv: kv[1])[0]
    out = {
        "hlo_flops_per_chip": hlo_flops,
        "numerics_compute_scale": compute_scale,
        "hlo_bytes_per_chip": hlo_bytes,
        "hlo_bytes_stream_per_chip": hlo_bytes_stream,
        "hlo_bytes_xla_convention_per_chip": hlo_bytes_xla,
        "collective_bytes_per_chip": coll.total_bytes,
        "collective_by_kind": coll.by_kind,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "dominant": dominant,
        "n_chips": n_chips,
    }
    if model_flops is not None:
        out["model_flops_total"] = model_flops
        out["model_flops_per_chip"] = model_flops / n_chips
        out["useful_flops_ratio"] = (model_flops / n_chips) / max(hlo_flops, 1.0)
        bound = max(t_compute, t_memory, t_collective)
        ideal = (model_flops / n_chips) / PEAK_FLOPS_BF16
        out["roofline_fraction"] = ideal / max(bound, 1e-12)
    return out
