"""Production meshes: 16x16 single pod (256 chips), 2x16x16 multi-pod (512).

``make_production_mesh`` is a function (not a module-level constant) so that
importing this module never touches jax device state — the dry-run decides
how many host devices exist before any mesh is built.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before any "
            "jax import (launch/dryrun.py does this)")
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")) -> Mesh:
    """Small mesh over however many host devices exist (unit tests)."""
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(devices)}")
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)
