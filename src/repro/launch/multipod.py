"""Cross-pod training utilities: hierarchical gradient reduction + optional
int8 compression on the DCN hop.

Enables the ROADMAP's multi-pod scale-out: training the model zoo beyond
one pod under the paper's numerics config, with the slow inter-pod hop
compressed the same way the paper compresses arithmetic — trade a little
fidelity (int8 + error feedback) for a large resource saving.  Exercised
by ``tests/test_multipod.py``.

At 2+ pods the gradient reduction is hierarchical:
  1. reduce-scatter within each pod over 'data' (fast ICI),
  2. all-reduce the scattered shards across pods over 'pod' (slow DCN) —
     optionally int8-compressed with error feedback,
  3. all-gather within the pod.
With GSPMD the intra-pod parts come out of the sharding rules for free;
this module provides the explicit shard_map variant used when compression
is on (quantization must happen between the two reduction levels, which a
sharding annotation cannot express).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.optim.compression import compress_with_feedback


def hierarchical_grad_reduce(mesh: Mesh, grads, errors=None, compress=False):
    """Reduce gradients over ('pod','data') with optional int8 DCN hop.

    grads: pytree of per-replica gradient arrays (replicated layout under
    shard_map; i.e. this runs where each (pod,data) shard holds its local
    gradient contribution).  Returns (reduced grads, new error feedback).
    """
    axes = [a for a in ("pod", "data") if a in mesh.shape]
    if not compress or "pod" not in mesh.shape:
        # plain path: a single pmean over both axes inside shard_map
        def body(*flat):
            return tuple(jax.lax.pmean(g, tuple(axes)) for g in flat)

        flat, treedef = jax.tree.flatten(grads)
        out = shard_map(body, mesh=mesh,
                        in_specs=tuple(P() for _ in flat),
                        out_specs=tuple(P() for _ in flat),
                        check_rep=False)(*flat)
        return jax.tree.unflatten(treedef, out), errors

    def body(*flat):
        n = len(flat) // 2
        gs, errs = flat[:n], flat[n:]
        out_g, out_e = [], []
        for g, e in zip(gs, errs):
            # 1. intra-pod mean over 'data' (fast ICI)
            g = jax.lax.pmean(g, "data")
            # 2. compress, cross-pod mean over 'pod' (slow DCN), with EF
            gq, new_e = compress_with_feedback(g, e)
            g = jax.lax.pmean(gq, "pod")
            out_g.append(g)
            out_e.append(new_e)
        return tuple(out_g) + tuple(out_e)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(errors)
    out = shard_map(body, mesh=mesh,
                    in_specs=tuple(P() for _ in flat_g + flat_e),
                    out_specs=tuple(P() for _ in flat_g + flat_e),
                    check_rep=False)(*flat_g, *flat_e)
    n = len(flat_g)
    return (jax.tree.unflatten(treedef, out[:n]),
            jax.tree.unflatten(treedef, out[n:]))
