"""Continuous-batching serving scheduler (vLLM-style slot management).

Host-side orchestration for the decode loop: a fixed pool of B slots, a
FIFO request queue, prefill-on-admit, per-slot position tracking, and
eviction on completion — the piece that turns `decode_step` into a real
serving system.  Device work stays in the jitted prefill/decode steps;
this module owns only the (cheap) host bookkeeping, so it is exactly the
code a TPU pod frontend would run.

Batching policy: admit as many queued requests as there are free slots at
each step boundary; prefill admits one request at a time into its slot
(cache writes at the slot's row), decode advances all active slots
together.  Per-slot sampling is greedy (the numerics knob is the
experiment here, not samplers).

Enables the paper's configurability claim under real serving load: the
numerics config — including a per-layer ``NumericsPolicy``
(``repro.core.policy``) — is fixed at compile time while requests stream
through continuously, which is exactly the deployment shape of a CiM
accelerator whose multiplier configuration is set per model, not per
request.  Exercised by ``tests/test_scheduler.py``.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                  # (prompt_len,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # filled by the scheduler
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class SlotState:
    request: Optional[Request] = None
    pos: int = 0                        # next write position in the cache

    @property
    def free(self) -> bool:
        return self.request is None


class ContinuousBatcher:
    """Schedules requests through (prefill_fn, decode_fn) over B slots.

    prefill_fn(tokens (1, L)) -> (logits (1,1,V), state-for-one-row)
    decode_fn(token (B,1), state, pos (B,)) is approximated here with the
    uniform-pos decode step (the framework's decode uses a scalar pos), so
    slots are grouped by position cohort; mixed-position batching is
    handled by stepping each cohort — documented simplification, the
    bookkeeping below is cohort-aware.
    """

    def __init__(self, n_slots: int, prefill_fn: Callable, decode_fn: Callable,
                 max_len: int):
        self.slots = [SlotState() for _ in range(n_slots)]
        self.queue: deque[Request] = deque()
        self.prefill_fn = prefill_fn
        self.decode_fn = decode_fn
        self.max_len = max_len
        self.states: Dict[int, object] = {}   # slot -> per-row serving state
        self.completed: List[Request] = []

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for i, slot in enumerate(self.slots):
            if slot.free and self.queue:
                req = self.queue.popleft()
                logits, state = self.prefill_fn(req.prompt[None, :])
                tok = int(np.argmax(np.asarray(logits)[0, -1]))
                req.generated.append(tok)
                slot.request = req
                slot.pos = len(req.prompt)
                self.states[i] = state

    def _retire(self, i: int):
        slot = self.slots[i]
        slot.request.done = True
        self.completed.append(slot.request)
        slot.request = None
        self.states.pop(i, None)

    def step(self):
        """One scheduler tick: admit, decode every active slot, retire."""
        self._admit()
        for i, slot in enumerate(self.slots):
            if slot.free:
                continue
            req = slot.request
            last = req.generated[-1]
            if (len(req.generated) >= req.max_new_tokens
                    or (req.eos_id is not None and last == req.eos_id)
                    or slot.pos + 1 >= self.max_len):
                self._retire(i)
                continue
            tok = jnp.asarray([[last]], jnp.int32)
            logits, self.states[i] = self.decode_fn(tok, self.states[i],
                                                    jnp.int32(slot.pos))
            req.generated.append(int(np.argmax(np.asarray(logits)[0, -1])))
            slot.pos += 1

    def run_to_completion(self, max_ticks: int = 10_000):
        ticks = 0
        while (self.queue or any(not s.free for s in self.slots)) \
                and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.completed, ticks

    @property
    def utilization(self) -> float:
        busy = sum(0 if s.free else 1 for s in self.slots)
        return busy / len(self.slots)
