"""Serving driver: batched greedy decoding over the continuous-batching
engine (:mod:`repro.serving`).

Demonstrates the paper's accuracy-configurable serving: the same weights
served under exact / segmented-3 / segmented-1 (ACL-like) numerics.
``serve()`` routes every prompt through :class:`repro.serving.Engine` —
one accuracy tier, ``batch`` KV slots, requests retired per-step — and
returns exactly the tokens a plain ``Session.generate`` would produce
(continuous batching is bit-transparent; asserted in
``tests/test_session.py`` and ``tests/test_serving_numerics.py``).  For
multi-tier SLAs (premium/standard/bulk in ONE engine) use
``python -m repro.session serve-loop`` or ``examples/serve_lm.py``.

``--policy policy.json`` serves under a per-layer
:class:`~repro.core.policy.NumericsPolicy` (e.g. one emitted by
``Session.auto_configure``; schema in ``docs/numerics_policy.md``) and
prints the modeled area / power / compute-latency of the resolved policy
(Table II roll-up over every call site via ``Session.ppa_report``).

A malformed or missing ``--policy`` file exits with a one-line error and
a non-zero status (no traceback).
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from repro.session import Session, SessionError, print_ppa_report


def serve(arch: str = "qwen3-4b", batch: int = 4, prompt_len: int = 32,
          gen_len: int = 16, numerics: str = "exact", seed: int = 0,
          params=None, cfg=None, policy=None):
    """Serve ``arch`` (or a ready config + params) through the
    continuous-batching engine and return the greedy continuations as a
    ``(batch, gen_len)`` int array — token-for-token what
    ``Session.generate`` yields for the same seed.  ``numerics`` is a
    preset name; ``policy`` (a NumericsPolicy or a JSON path) overrides
    it."""
    from repro.serving import TierSpec

    sess = Session(cfg if cfg is not None else arch,
                   policy=policy if policy is not None else numerics,
                   seed=seed, params=params)
    label = "policy" if policy is not None else numerics
    if policy is not None:
        print_ppa_report(sess.ppa_report(), tag="serve")
    eng = sess.serving_engine((TierSpec("serve", policy=sess.numerics),),
                              slots=batch, max_len=prompt_len + gen_len)
    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, sess.config.vocab, (batch, prompt_len))
    t0 = time.perf_counter()
    reqs = [eng.submit(p, tier="serve", max_new_tokens=gen_len)
            for p in prompts]
    eng.run()
    dt = time.perf_counter() - t0
    print(f"[serve] {arch} numerics={label}: {batch}x{gen_len} tokens "
          f"in {dt:.2f}s ({batch * gen_len / dt:.1f} tok/s, "
          f"continuous batching)")
    return np.stack([r.result() for r in reqs])


def main(argv=None) -> int:
    from repro.serving import ServingError

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--numerics", default="exact",
                    choices=["exact", "segmented3", "segmented2", "segmented1"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--policy", default=None, metavar="POLICY_JSON",
                    help="serve under a per-layer NumericsPolicy (JSON file; "
                         "overrides --numerics)")
    args = ap.parse_args(argv)
    try:
        serve(args.arch, batch=args.batch, gen_len=args.gen_len,
              numerics=args.numerics, policy=args.policy)
    except (SessionError, ServingError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    return 0


if __name__ == "__main__":
    sys.exit(main())
