"""Serving driver: a thin CLI over :class:`repro.session.Session`.

Demonstrates the paper's accuracy-configurable serving: the same weights
served under exact / segmented-3 / segmented-1 (ACL-like) numerics, with
per-request greedy decoding.  ``--policy policy.json`` serves under a
per-layer :class:`~repro.core.policy.NumericsPolicy` (e.g. one emitted by
``Session.auto_configure`` / ``repro.core.sweep.auto_configure``; schema
in ``docs/numerics_policy.md``) instead of a single global setting, and
prints the modeled area / power / compute-latency of the resolved policy
(Table II roll-up over every call site — per-expert MoE paths included —
plus the MXU-pass roofline scale, via ``Session.ppa_report``).

A malformed or missing ``--policy`` file exits with a one-line error and
a non-zero status (no traceback).
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

from repro.session import Session, SessionError, print_ppa_report


def serve(arch: str = "qwen3-4b", batch: int = 4, prompt_len: int = 32,
          gen_len: int = 16, numerics: str = "exact", seed: int = 0,
          params=None, cfg=None, policy=None):
    """Serve ``arch`` (or a ready config + params) and return the greedy
    continuations as an int array.  ``numerics`` is a preset name;
    ``policy`` (a NumericsPolicy or a JSON path) overrides it."""
    sess = Session(cfg if cfg is not None else arch,
                   policy=policy if policy is not None else numerics,
                   seed=seed, params=params)
    label = "policy" if policy is not None else numerics
    if policy is not None:
        print_ppa_report(sess.ppa_report(), tag="serve")
    res = sess.generate(batch=batch, prompt_len=prompt_len, gen_len=gen_len)
    print(f"[serve] {arch} numerics={label}: {batch}x{gen_len} tokens "
          f"in {res.seconds:.2f}s ({res.tokens_per_s:.1f} tok/s)")
    return np.asarray(res.tokens)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--numerics", default="exact",
                    choices=["exact", "segmented3", "segmented2", "segmented1"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--policy", default=None, metavar="POLICY_JSON",
                    help="serve under a per-layer NumericsPolicy (JSON file; "
                         "overrides --numerics)")
    args = ap.parse_args(argv)
    try:
        serve(args.arch, batch=args.batch, gen_len=args.gen_len,
              numerics=args.numerics, policy=args.policy)
    except SessionError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    return 0


if __name__ == "__main__":
    sys.exit(main())
