"""Serving driver: batched prefill + decode loop with the numerics knob.

Demonstrates the paper's accuracy-configurable serving: the same weights
served under exact / segmented-3 / segmented-1 (ACL-like) numerics, with
per-request greedy decoding.  ``--policy policy.json`` serves under a
per-layer :class:`~repro.core.policy.NumericsPolicy` (e.g. one emitted by
``repro.core.sweep.auto_configure``; schema in ``docs/numerics_policy.md``)
instead of a single global setting, and prints the modeled area / power /
compute-latency of the resolved policy (Table II roll-up over every call
site — per-expert MoE paths included — plus the MXU-pass roofline scale
from ``repro.launch.hlo_analysis.policy_ppa_summary``).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.numerics import NumericsConfig
from repro.core.policy import NumericsPolicy
from repro.launch import hlo_analysis
from repro.models import transformer
from repro.models.layers import unzip


def serve(arch: str = "qwen3-4b", batch: int = 4, prompt_len: int = 32,
          gen_len: int = 16, numerics: str = "exact", seed: int = 0,
          params=None, cfg=None, policy=None):
    if cfg is None:
        cfg = get_arch(arch).reduced()
    if policy is not None:
        # per-layer policy: a NumericsPolicy, or a path to its JSON file
        if not isinstance(policy, NumericsPolicy):
            with open(policy) as f:
                policy = NumericsPolicy.from_json(f.read())
        cfg = dataclasses.replace(cfg, numerics=policy)
        numerics = "policy"
        # modeled PPA + latency of the resolved policy over every call site
        # (per-expert MoE paths included), via the Table II roll-up and the
        # MXU-pass roofline term
        paths = transformer.layer_paths(cfg)
        ppa = hlo_analysis.policy_ppa_summary(
            policy, paths, counts=transformer.layer_path_counts(cfg))
        print(f"[serve] policy over {ppa['n_sites']} call sites: "
              f"area {ppa['area_um2']:,.0f} um^2 "
              f"(-{ppa['area_reduction']:.1%} vs exact), "
              f"power {ppa['power_w']:.3f} W "
              f"(-{ppa['power_reduction']:.1%}), "
              f"modeled compute latency x{ppa['compute_scale']:.2f}")
    elif numerics != "exact":
        passes = {"segmented3": 3, "segmented2": 2, "segmented1": 1}[numerics]
        cfg = dataclasses.replace(cfg, numerics=NumericsConfig(
            mode="segmented", seg_passes=passes, backend="xla"))
    if params is None:
        pp = transformer.init(cfg, jax.random.PRNGKey(seed))
        params, _ = unzip(pp)

    rng = np.random.default_rng(seed)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (batch, prompt_len)), jnp.int32)
    max_len = prompt_len + gen_len

    prefill = jax.jit(lambda p, b: transformer.prefill(p, cfg, b, max_len=max_len))
    decode = jax.jit(
        lambda p, tok, st, pos: transformer.decode_step(p, cfg, {"token": tok}, st, pos))

    t0 = time.perf_counter()
    logits, state = prefill(params, {"tokens": prompts})
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [tok]
    for i in range(gen_len - 1):
        logits, state = decode(params, tok, state, jnp.int32(prompt_len + i))
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    gen = jnp.concatenate(out, axis=1)
    tps = batch * gen_len / dt
    print(f"[serve] {arch} numerics={numerics}: {batch}x{gen_len} tokens "
          f"in {dt:.2f}s ({tps:.1f} tok/s)")
    return np.asarray(gen)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--numerics", default="exact",
                    choices=["exact", "segmented3", "segmented2", "segmented1"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--policy", default=None, metavar="POLICY_JSON",
                    help="serve under a per-layer NumericsPolicy (JSON file; "
                         "overrides --numerics)")
    args = ap.parse_args()
    serve(args.arch, batch=args.batch, gen_len=args.gen_len,
          numerics=args.numerics, policy=args.policy)


if __name__ == "__main__":
    main()
