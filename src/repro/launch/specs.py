"""Input specs: ShapeDtypeStruct stand-ins for every (arch x shape) cell.

No device memory is ever allocated here — abstract params come from
``jax.eval_shape`` over the real initializers, so the dry-run exercises
exactly the structures the real launcher would build.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer
from repro.models.layers import unzip

SDS = jax.ShapeDtypeStruct

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


def shape_applicable(cfg: ArchConfig, shape_name: str):
    """(ok, reason) — long_500k only for sub-quadratic archs (assignment)."""
    if shape_name == "long_500k" and not cfg.subquadratic:
        return False, "skipped(full-attention)"
    return True, ""


def abstract_params(cfg: ArchConfig, dtype=None):
    """(abstract params tree, logical-axes specs tree) without allocation."""
    pp = jax.eval_shape(partial(transformer.init, cfg), jax.random.PRNGKey(0))
    params, specs = unzip(pp)
    if dtype is not None:
        params = jax.tree.map(
            lambda s: SDS(s.shape, dtype) if jnp.issubdtype(s.dtype, jnp.floating)
            else s, params)
    return params, specs


def abstract_state(cfg: ArchConfig, batch: int, max_len: int):
    return jax.eval_shape(
        partial(transformer.init_state, cfg, batch, max_len,
                dtype=jnp.dtype(cfg.dtype)))


def _whisper_cfg(cfg, seq):
    return dataclasses.replace(cfg, enc_len=seq)


def batch_specs(cfg: ArchConfig, shape_name: str):
    """Abstract batch for a train/prefill cell (tokens or stub embeds)."""
    sh = SHAPES[shape_name]
    B, S = sh["batch"], sh["seq"]
    if cfg.frontend == "audio_stub":
        out = {
            "enc_embeds": SDS((B, S, cfg.d_model), jnp.bfloat16),
            "tokens": SDS((B, cfg.decoder_len), jnp.int32),
        }
        if sh["kind"] == "train":
            out["targets"] = SDS((B, cfg.decoder_len), jnp.int32)
        return out
    if cfg.frontend == "vision_stub":
        out = {"embeds": SDS((B, S, cfg.d_model), jnp.bfloat16)}
        if cfg.mrope_sections:
            out["positions"] = SDS((B, S, 3), jnp.int32)
        if sh["kind"] == "train":
            out["targets"] = SDS((B, S), jnp.int32)
        return out
    out = {"tokens": SDS((B, S), jnp.int32)}
    if sh["kind"] == "train":
        out["targets"] = SDS((B, S), jnp.int32)
    return out


BATCH_AXES = {
    "tokens": ("batch", "seq"),
    "targets": ("batch", "seq"),
    "embeds": ("batch", "seq", None),
    "enc_embeds": ("batch", "seq", None),
    "positions": ("batch", "seq", None),
    "token": ("batch", None),
}

# serving-state leaves -> logical axes, keyed by (dict key, rank)
STATE_AXES = {
    ("k", 5): ("layers", "batch", "kv_seq", None, None),
    ("v", 5): ("layers", "batch", "kv_seq", None, None),
    ("ckv", 4): ("layers", "batch", "kv_seq", None),
    ("kpe", 4): ("layers", "batch", "kv_seq", None),
    ("conv", 4): ("layers", "batch", None, "ssm_inner"),
    ("state", 5): ("layers", "batch", "ssm_heads", None, None),
    ("enc_out", 3): ("batch", "seq", None),
}


def state_axes_tree(state_abs):
    """Map the abstract serving state to logical-axes tuples per leaf."""
    def visit(path, leaf):
        key = None
        for p in reversed(path):
            if hasattr(p, "key"):
                key = p.key
                break
        axes = STATE_AXES.get((key, leaf.ndim))
        if axes is None:
            return (None,) * leaf.ndim
        return axes

    return jax.tree_util.tree_map_with_path(visit, state_abs)


def batch_axes_tree(batch_abs):
    return {k: BATCH_AXES.get(k, (None,) * v.ndim)[:v.ndim] for k, v in batch_abs.items()}


def cell_config(cfg: ArchConfig, shape_name: str) -> ArchConfig:
    """Shape-dependent config tweaks (whisper encoder length; decode uses
    inference numerics by default)."""
    sh = SHAPES[shape_name]
    if cfg.frontend == "audio_stub":
        cfg = _whisper_cfg(cfg, sh["seq"])
    return cfg


def model_flops(cfg: ArchConfig, shape_name: str) -> float:
    """MODEL_FLOPS for the roofline: 6*N_active*D (train) / 2*N_active*D
    (inference fwd) + causal attention quadratic terms."""
    sh = SHAPES[shape_name]
    B, S = sh["batch"], sh["seq"]
    n_active = cfg.active_param_count()
    hd = cfg.resolved_head_dim
    attn_layers = [s for s in cfg.layer_specs() if s.attn not in ("none",)]
    if cfg.frontend == "audio_stub":
        # decoder runs on decoder_len tokens; encoder on S
        dec_T = B * cfg.decoder_len
        enc_flops_tok = cfg.encoder_layers * (4 * cfg.d_model ** 2 + 3 * cfg.d_model * cfg.d_ff)
        if sh["kind"] == "train":
            base = 6 * n_active * dec_T + 6 * enc_flops_tok * B * S
        elif sh["kind"] == "prefill":
            base = 2 * n_active * dec_T + 2 * enc_flops_tok * B * S
        else:
            base = 2 * n_active * B + 4 * B * S * cfg.n_heads * hd * len(attn_layers)
        return float(base)

    if sh["kind"] == "train":
        base = 6 * n_active * B * S
        attn = sum(6 * B * (min(S, sp.window if sp.attn == "local" else S)) * S
                   * cfg.n_heads * hd for sp in attn_layers)
        return float(base + attn)
    if sh["kind"] == "prefill":
        base = 2 * n_active * B * S
        attn = sum(2 * B * (min(S, sp.window if sp.attn == "local" else S)) * S
                   * cfg.n_heads * hd for sp in attn_layers)
        return float(base + attn)
    # decode: one token against an S-deep cache
    base = 2 * n_active * B
    attn = sum(4 * B * min(S, sp.window if sp.attn == "local" else S)
               * cfg.n_heads * hd for sp in attn_layers)
    return float(base + attn)
