"""Step functions: train_step (grad-accum + AdamW), prefill_step, decode_step.

These are the exact functions the dry-run lowers and the real launcher
executes — one code path for both.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer
from repro.optim import adafactor, adamw


def make_optimizer(cfg: ArchConfig, **overrides):
    """(opt_cfg, init_fn, apply_fn, moment_specs_fn) for the arch's optimizer.

    moment_specs_fn maps the params' logical-axes tree to the optimizer
    state's logical-axes tree (used by the dry-run to shard opt state).
    """
    if cfg.optimizer == "adafactor":
        opt_cfg = adafactor.AdafactorConfig(**overrides)

        def specs_fn(pspecs):
            def one(axes):
                return adafactor.FactoredMoment(
                    row=tuple(axes[:-1]), col=tuple(axes[:-2]) + tuple(axes[-1:]),
                    full=tuple(axes))
            # NOTE: non-factored leaves use .full with the param axes; the
            # placeholder (0,)-shaped leaves fall back to replicated via the
            # divisibility rule, which is free.
            v = jax.tree.map(one, pspecs, is_leaf=lambda x: isinstance(x, tuple))
            return adafactor.AdafactorState(step=(), v=v)

        return opt_cfg, adafactor.init, adafactor.apply_updates, specs_fn

    opt_cfg = adamw.AdamWConfig(moment_dtype=cfg.moment_dtype, **overrides)

    def specs_fn(pspecs):
        return adamw.OptState(step=(), mu=pspecs, nu=pspecs)

    return opt_cfg, adamw.init, adamw.apply_updates, specs_fn


def make_train_step(cfg: ArchConfig, opt_cfg=None, opt_apply=None):
    accum = max(1, cfg.grad_accum)
    if opt_cfg is None or opt_apply is None:
        opt_cfg, _, opt_apply, _ = make_optimizer(cfg)

    def loss_for(p, mb):
        return transformer.loss_fn(p, cfg, mb)

    def train_step(params, opt_state, batch):
        if accum == 1:
            loss, grads = jax.value_and_grad(loss_for)(params, batch)
        else:
            micro = jax.tree.map(
                lambda a: a.reshape((accum, a.shape[0] // accum) + a.shape[1:]),
                batch)

            def acc_body(carry, mb):
                gsum, lsum = carry
                l, g = jax.value_and_grad(loss_for)(params, mb)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (gsum, lsum + l), None

            zeros = jax.tree.map(jnp.zeros_like, params)
            (grads, loss), _ = jax.lax.scan(acc_body, (zeros, 0.0), micro)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss / accum
        new_params, new_opt, metrics = opt_apply(params, grads, opt_state, opt_cfg)
        return new_params, new_opt, {"loss": loss, **metrics}

    return train_step


def make_prefill_step(cfg: ArchConfig, max_len: int):
    def prefill_step(params, batch):
        return transformer.prefill(params, cfg, batch, max_len=max_len)

    return prefill_step


def make_decode_step(cfg: ArchConfig):
    def decode_step(params, state, token, pos):
        return transformer.decode_step(params, cfg, {"token": token}, state, pos)

    return decode_step
