"""Training driver: data pipeline -> sharded train_step -> checkpoint/restart.

Runs at any scale: on this container it trains reduced configs on the CPU
device; on a pod the same code path runs under the production mesh (the
mesh/rules arguments are the only difference — see launch/dryrun.py for
the production shardings).

Fault tolerance: resumes from the newest committed checkpoint, saves every
``ckpt_every`` steps, records per-step wall time into the straggler
watchdog, and (optionally) compresses cross-pod gradients.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import io as ckpt_io
from repro.configs import get_arch
from repro.data.synthetic import DataConfig, lm_batch
from repro.distributed.fault import StepWatchdog
from repro.launch import steps as steps_mod
from repro.models import transformer
from repro.models.layers import unzip


def train(arch: str, steps: int = 50, seq_len: int = 128, batch: int = 8,
          ckpt_dir: str | None = None, ckpt_every: int = 20, lr: float = 3e-4,
          reduced: bool = True, log_every: int = 10, seed: int = 0):
    cfg = get_arch(arch)
    if reduced:
        cfg = cfg.reduced()
    pp = transformer.init(cfg, jax.random.PRNGKey(seed))
    params, _ = unzip(pp)
    opt_cfg, opt_init, opt_apply, _ = steps_mod.make_optimizer(
        cfg, lr=lr, total_steps=steps, warmup_steps=max(2, steps // 10))
    opt_state = opt_init(params, opt_cfg)

    start_step = 0
    if ckpt_dir:
        latest = ckpt_io.latest_step(ckpt_dir)
        if latest is not None:
            (params, opt_state), manifest = ckpt_io.restore(
                ckpt_dir, (params, opt_state))
            start_step = manifest["step"]
            print(f"[train] restored step {start_step} from {ckpt_dir}")

    train_step = jax.jit(steps_mod.make_train_step(cfg, opt_cfg, opt_apply),
                         donate_argnums=(0, 1))
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=seq_len, global_batch=batch,
                      seed=seed)
    watchdog = StepWatchdog()
    losses = []
    for step in range(start_step, steps):
        hb = lm_batch(dcfg, step)
        b = {k: jnp.asarray(v) for k, v in hb.items()}
        t0 = time.perf_counter()
        params, opt_state, metrics = train_step(params, opt_state, b)
        jax.block_until_ready(metrics["loss"])
        watchdog.record(jax.process_index(), time.perf_counter() - t0)
        losses.append(float(metrics["loss"]))
        if step % log_every == 0 or step == steps - 1:
            print(f"[train] {arch} step {step:5d} loss {losses[-1]:.4f} "
                  f"lr {float(metrics['lr']):.2e} gnorm {float(metrics['grad_norm']):.2f}")
        if ckpt_dir and ((step + 1) % ckpt_every == 0 or step == steps - 1):
            ckpt_io.save(ckpt_dir, step + 1, (params, opt_state),
                         extra={"loss": losses[-1]})
    return params, opt_state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--full-config", action="store_true")
    args = ap.parse_args()
    train(args.arch, steps=args.steps, seq_len=args.seq_len, batch=args.batch,
          ckpt_dir=args.ckpt_dir, reduced=not args.full_config)


if __name__ == "__main__":
    main()
