"""Model zoo: transformer stacks (dense/MoE/MLA/SSM/hybrid/enc-dec/VLM) + ResNet."""
from . import attention, layers, moe, ssm, transformer
