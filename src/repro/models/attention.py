"""Attention: GQA / local(sliding-window) / MLA, chunked-flash for long prefill.

Numerics: q/k/v/o projections route through ``nmatmul`` (the paper's
configurable multiplier); the score/PV einsums stay in bf16/fp32 — the CiM
deployment model puts the approximate multipliers in the stationary-weight
arrays, while attention's activation-activation products run on the
(exact) digital datapath.  Configuration is ambient (``repro.numerics``):
the caller establishes the block's ``attn``/``cross`` scope and each
projection resolves under its own ``layer_scope`` segment
(``wq``/``wk``/``wv``/``wo``, MLA: ``wq_a``/``wq_b``/``wkv_a``/``wo``).

Memory: training/prefill attention is blockwise (online softmax over KV
chunks inside a scan over Q chunks), so the score matrix never
materializes at more than (q_chunk x kv_chunk).  Decode attends a single
query against the full cache; the cache sequence axis may be sharded over
the 'model' mesh axis (flash-decode: GSPMD turns the softmax reductions
into cross-shard collectives).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.numerics import layer_scope, nmatmul
from repro.distributed.sharding import logical_constraint

from .layers import PP, apply_rope, dense_init, rmsnorm, rmsnorm_init, softcap

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# standard GQA attention (global or sliding-window)
# ---------------------------------------------------------------------------

def gqa_init(key, cfg):
    d, H, KH, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": dense_init(k1, d, H * hd, ("embed", "q_dim")),
        "wk": dense_init(k2, d, KH * hd, ("embed", "kv_dim")),
        "wv": dense_init(k3, d, KH * hd, ("embed", "kv_dim")),
        "wo": dense_init(k4, H * hd, d, ("q_dim", "embed")),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd)
        p["k_norm"] = rmsnorm_init(hd)
    return p


def _repeat_kv(k, n_rep):
    if n_rep == 1:
        return k
    B, S, KH, D = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (B, S, KH, n_rep, D)).reshape(
        B, S, KH * n_rep, D
    )


def _row_pos(pos, rank):
    """Normalize a decode position to broadcast against a (..., S) score.

    ``pos`` is a scalar during lockstep decoding and a per-row ``(B,)``
    vector under continuous batching (every request sits at its own
    absolute position).  Returns an array shaped to broadcast over the
    leading batch axis of a rank-``rank`` score tensor whose last axis is
    the cache sequence."""
    pos = jnp.asarray(pos)
    if pos.ndim:
        return pos.reshape((-1,) + (1,) * (rank - 1))
    return pos


def _scatter_row(buf, new, pos):
    """Write ``new`` (B, 1, ...) into ``buf`` (B, S, ...) at per-row
    sequence position ``pos`` (B,) — the vector-position analogue of
    ``dynamic_update_slice_in_dim`` (same written bits, per-row starts)."""
    sel = jnp.arange(buf.shape[1])[None, :] == pos[:, None]
    sel = sel.reshape(sel.shape + (1,) * (buf.ndim - 2))
    return jnp.where(sel, new.astype(buf.dtype), buf)


def _cache_update(buf, new, pos):
    """Update a (B, S, ...) cache at decode position ``pos`` (scalar:
    lockstep batch; (B,) vector: continuous batching)."""
    if jnp.ndim(pos):
        return _scatter_row(buf, new, pos)
    return jax.lax.dynamic_update_slice_in_dim(
        buf, new.astype(buf.dtype), pos, axis=1)


def _mask_for(qp, kp, kvalid, causal, window):
    mask = kvalid[None, None, None, :]
    if causal:
        mask = mask & (qp[None, None, :, None] >= kp[None, None, None, :])
    if window is not None:
        mask = mask & (qp[None, None, :, None] - kp[None, None, None, :] < window)
    return mask


def blockwise_attention(q, k, v, *, causal=True, window=None, attn_cap=None,
                        q_chunk=1024, kv_chunk=1024, q_offset=0):
    """Keyword-friendly wrapper around the custom-VJP implementation.

    A traced ``q_offset`` (serving's chunked prefill jits the chunk start)
    cannot ride in ``nondiff_argnums``, so it routes directly to the
    forward impl — same bits (the custom-VJP wrapper computes its forward
    with the identical call); only training memory behaviour differs, and
    the serving path never differentiates."""
    if isinstance(q_offset, jax.Array):
        out, _ = _blockwise_fwd_impl(q, k, v, causal, window, attn_cap,
                                     q_chunk, kv_chunk, q_offset)
        return out
    return _blockwise_attention_cv(q, k, v, causal, window, attn_cap,
                                   q_chunk, kv_chunk, q_offset)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _blockwise_attention_cv(q, k, v, causal=True, window=None, attn_cap=None,
                            q_chunk=1024, kv_chunk=1024, q_offset=0):
    """Flash-style online-softmax blockwise attention with a custom VJP.

    q: (B, Sq, H, D); k/v: (B, Sk, H, D) (kv already head-repeated).
    The custom VJP is what keeps training memory flat: the forward saves
    only (q, k, v, out, lse) and the backward re-streams the score blocks
    (a plain jax.grad through the online-softmax scans would checkpoint
    every chunk of the inner loop).
    Returns (B, Sq, H, D) in fp32.
    """
    out, _ = _blockwise_fwd_impl(q, k, v, causal, window, attn_cap,
                                 q_chunk, kv_chunk, q_offset)
    return out


def _chunks(x, n, c):
    B = x.shape[0]
    return x.reshape(B, n, c, *x.shape[2:])


def _blockwise_fwd_impl(q, k, v, causal, window, attn_cap, q_chunk, kv_chunk,
                        q_offset):
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    scale = D ** -0.5
    qc = min(q_chunk, Sq)
    kc = min(kv_chunk, Sk)
    nq, nk = -(-Sq // qc), -(-Sk // kc)
    pad_q, pad_k = nq * qc - Sq, nk * kc - Sk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    qs = _chunks(q, nq, qc).astype(jnp.bfloat16)
    ks = _chunks(k, nk, kc).astype(jnp.bfloat16)
    vs = _chunks(v, nk, kc).astype(jnp.bfloat16)
    q_pos = q_offset + jnp.arange(nq * qc).reshape(nq, qc)
    k_pos = jnp.arange(nk * kc).reshape(nk, kc)
    k_valid = k_pos < Sk

    def q_body(_, qi):
        qb, qp = qi

        def kv_body(carry, ki):
            m, l, o = carry
            kb, vb, kp, kvalid = ki
            s = jnp.einsum("bqhd,bkhd->bhqk", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            if attn_cap is not None:
                s = softcap(s, attn_cap)
            s = jnp.where(_mask_for(qp, kp, kvalid, causal, window), s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + p.sum(axis=-1)
            pv = jnp.einsum("bhqk,bkhd->bqhd", p.astype(jnp.bfloat16), vb,
                            preferred_element_type=jnp.float32)
            o_new = o * alpha.transpose(0, 2, 1)[..., None] + pv
            return (m_new, l_new, o_new), None

        m0 = jnp.full((B, H, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, qc), jnp.float32)
        o0 = jnp.zeros((B, qc, H, D), jnp.float32)
        (m, l, o), _ = jax.lax.scan(
            kv_body, (m0, l0, o0),
            (ks.transpose(1, 0, 2, 3, 4), vs.transpose(1, 0, 2, 3, 4),
             k_pos, k_valid))
        l = jnp.maximum(l, 1e-30)
        o = o / l.transpose(0, 2, 1)[..., None]
        lse = m + jnp.log(l)          # (B, H, qc)
        return None, (o, lse)

    _, (out, lse) = jax.lax.scan(q_body, None, (qs.transpose(1, 0, 2, 3, 4), q_pos))
    out = out.transpose(1, 0, 2, 3, 4).reshape(B, nq * qc, H, D)[:, :Sq]
    lse = lse.transpose(1, 0, 3, 2).reshape(B, nq * qc, H)[:, :Sq]  # (B,Sq,H)
    return out, lse


def _blockwise_fwd(q, k, v, causal, window, attn_cap, q_chunk, kv_chunk, q_offset):
    out, lse = _blockwise_fwd_impl(q, k, v, causal, window, attn_cap,
                                   q_chunk, kv_chunk, q_offset)
    return out, (q, k, v, out, lse)


def _blockwise_bwd(causal, window, attn_cap, q_chunk, kv_chunk, q_offset,
                   res, dout):
    q, k, v, out, lse = res
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    scale = D ** -0.5
    qc = min(q_chunk, Sq)
    kc = min(kv_chunk, Sk)
    nq, nk = -(-Sq // qc), -(-Sk // kc)
    pad_q, pad_k = nq * qc - Sq, nk * kc - Sk
    pq = lambda x: jnp.pad(x, ((0, 0), (0, pad_q)) + ((0, 0),) * (x.ndim - 2))
    pk = lambda x: jnp.pad(x, ((0, 0), (0, pad_k)) + ((0, 0),) * (x.ndim - 2))
    if pad_q:
        q, out, dout, lse = pq(q), pq(out), pq(dout), pq(lse)
    if pad_k:
        k, v = pk(k), pk(v)
    # delta = rowsum(dout * out) per (B, Sq, H)
    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)
    qs = _chunks(q, nq, qc).astype(jnp.bfloat16)
    ks = _chunks(k, nk, kc).astype(jnp.bfloat16)
    vs = _chunks(v, nk, kc).astype(jnp.bfloat16)
    dos = _chunks(dout.astype(jnp.float32), nq, qc)
    lses = _chunks(lse, nq, qc)
    deltas = _chunks(delta, nq, qc)
    q_pos = q_offset + jnp.arange(nq * qc).reshape(nq, qc)
    k_pos = jnp.arange(nk * kc).reshape(nk, kc)
    k_valid = k_pos < Sk

    def q_body(carry, qi):
        dk_acc, dv_acc = carry  # (nk, B, kc, H, D) fp32
        qb, dob, lseb, delb, qp = qi

        def kv_body(dq_acc, ki):
            kb, vb, kp, kvalid, dk_j, dv_j = ki
            s = jnp.einsum("bqhd,bkhd->bhqk", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            if attn_cap is not None:
                t = jnp.tanh(s / attn_cap)
                s_capped = t * attn_cap
            else:
                s_capped = s
            mask = _mask_for(qp, kp, kvalid, causal, window)
            s_capped = jnp.where(mask, s_capped, NEG_INF)
            p = jnp.exp(s_capped - lseb.transpose(0, 2, 1)[..., None])  # (B,H,q,k)
            dv_j = dv_j + jnp.einsum("bhqk,bqhd->bkhd", p.astype(jnp.bfloat16),
                                     dob.astype(jnp.bfloat16),
                                     preferred_element_type=jnp.float32)
            dp = jnp.einsum("bqhd,bkhd->bhqk", dob.astype(jnp.bfloat16), vb,
                            preferred_element_type=jnp.float32)
            ds = p * (dp - delb.transpose(0, 2, 1)[..., None])
            if attn_cap is not None:
                ds = ds * (1.0 - t * t)  # softcap chain rule
            ds = jnp.where(mask, ds, 0.0) * scale
            dsb = ds.astype(jnp.bfloat16)
            dq_acc = dq_acc + jnp.einsum("bhqk,bkhd->bqhd", dsb, kb,
                                         preferred_element_type=jnp.float32)
            dk_j = dk_j + jnp.einsum("bhqk,bqhd->bkhd", dsb, qb,
                                     preferred_element_type=jnp.float32)
            return dq_acc, (dk_j, dv_j)

        dq0 = jnp.zeros((B, qc, H, D), jnp.float32)
        dq, (dk_new, dv_new) = jax.lax.scan(
            kv_body, dq0,
            (ks.transpose(1, 0, 2, 3, 4), vs.transpose(1, 0, 2, 3, 4),
             k_pos, k_valid, dk_acc, dv_acc))
        return (dk_new, dv_new), dq

    dk0 = jnp.zeros((nk, B, kc, H, D), jnp.float32)
    dv0 = jnp.zeros((nk, B, kc, H, D), jnp.float32)
    (dk, dv), dq = jax.lax.scan(
        q_body, (dk0, dv0),
        (qs.transpose(1, 0, 2, 3, 4), dos.transpose(1, 0, 2, 3, 4),
         lses.transpose(1, 0, 2, 3), deltas.transpose(1, 0, 2, 3), q_pos))
    dq = dq.transpose(1, 0, 2, 3, 4).reshape(B, nq * qc, H, D)[:, :Sq]
    dk = dk.transpose(1, 0, 2, 3, 4).reshape(B, nk * kc, H, D)[:, :Sk]
    dv = dv.transpose(1, 0, 2, 3, 4).reshape(B, nk * kc, H, D)[:, :Sk]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_blockwise_attention_cv.defvjp(_blockwise_fwd, _blockwise_bwd)


def gqa_apply(params, x, cfg, spec, positions,
              cache=None, q_offset=0, causal=True, use_rope=True):
    """Returns (out, new_cache).  cache = dict(k, v) with (B, S_max, KH, D)."""
    B, S, d = x.shape
    H, KH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    with layer_scope("wq"):
        q = nmatmul(x, params["wq"]).reshape(B, S, H, hd)
    with layer_scope("wk"):
        k = nmatmul(x, params["wk"]).reshape(B, S, KH, hd)
    with layer_scope("wv"):
        v = nmatmul(x, params["wv"]).reshape(B, S, KH, hd)
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(params["k_norm"], k, cfg.norm_eps)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    # TP region: heads sharded, sequence gathered (megatron pattern); the
    # residual stream re-shards to 'seq' at the block boundary
    q = logical_constraint(q, ("batch", None, "heads", None))
    k = logical_constraint(k, ("batch", None, "heads", None))
    v = logical_constraint(v, ("batch", None, "heads", None))
    window = spec.window if spec.attn == "local" else None

    if cache is None:
        kr = _repeat_kv(k, H // KH)
        vr = _repeat_kv(v, H // KH)
        out = blockwise_attention(
            q, kr, vr, causal=causal, window=window,
            attn_cap=cfg.attn_softcap, q_offset=q_offset,
        )
        out = logical_constraint(out, ("batch", None, "heads", None))
        new_cache = {
            "k": logical_constraint(k, ("batch", "kv_seq", None, None)),
            "v": logical_constraint(v, ("batch", "kv_seq", None, None)),
        }
    else:
        # decode (S == 1) or chunked prefill (S > 1, scalar q_offset):
        # update cache at q_offset (scalar, or (B,) vector under
        # continuous batching), attend full cache
        k_cache = _cache_update(cache["k"], k, q_offset)
        v_cache = _cache_update(cache["v"], v, q_offset)
        k_cache = logical_constraint(k_cache, ("batch", "kv_seq", None, None))
        v_cache = logical_constraint(v_cache, ("batch", "kv_seq", None, None))
        if S > 1:
            # chunked prefill: blockwise online softmax over the updated
            # cache — the same kernel the no-cache prefill path runs.
            # Cache rows from earlier chunks hold the bits a full prefill
            # would cast (bf16 store-then-read == one direct rounding)
            # and rows past the frontier mask to exact zero contributions,
            # so the chunk's outputs match the solo prefill bit-for-bit.
            out = blockwise_attention(
                q, _repeat_kv(k_cache, H // KH), _repeat_kv(v_cache, H // KH),
                causal=causal, window=window, attn_cap=cfg.attn_softcap,
                q_offset=q_offset,
            )
            out = logical_constraint(out, ("batch", None, "heads", None))
        else:
            out = decode_attention(
                q, k_cache, v_cache, q_offset, window=window,
                attn_cap=cfg.attn_softcap
            )
        new_cache = {"k": k_cache, "v": v_cache}

    out = out.astype(x.dtype).reshape(B, S, H * hd)
    with layer_scope("wo"):
        return nmatmul(out, params["wo"]).astype(x.dtype), new_cache


def decode_attention(q, k_cache, v_cache, pos, *, window=None, attn_cap=None):
    """Single-step attention against the full cache (seq may be mesh-sharded).

    ``pos`` is the absolute decode position — a scalar for a lockstep
    batch, or a ``(B,)`` vector when every row sits at its own position
    (continuous batching).

    GQA-aware: the query is grouped as (B, KH, G, D) and contracted against
    the UNexpanded cache — materializing head-repeated K/V (broadcast) makes
    GSPMD lose the cache's seq sharding and all-gather the full fp32 cache
    per layer (measured: 1 GiB x 2 x n_layers per decode step on
    qwen2-vl-72b before this formulation).
    """
    B, S1, H, D = q.shape  # S1 == 1
    KH = k_cache.shape[2]
    G = H // KH
    qr = q.reshape(B, KH, G, D)
    s = jnp.einsum("bkgd,bskd->bkgs", qr.astype(jnp.bfloat16),
                   k_cache.astype(jnp.bfloat16),
                   preferred_element_type=jnp.float32) * (D ** -0.5)
    if attn_cap is not None:
        s = softcap(s, attn_cap)
    k_pos = jnp.arange(k_cache.shape[1])
    pr = _row_pos(pos, 4)
    mask = k_pos[None, None, None, :] <= pr
    if window is not None:
        mask = mask & (pr - k_pos[None, None, None, :] < window)
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(jnp.bfloat16),
                   v_cache.astype(jnp.bfloat16),
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, H, D)


# ---------------------------------------------------------------------------
# MLA (deepseek-v3 multi-head latent attention)
# ---------------------------------------------------------------------------

def mla_init(key, cfg):
    d, H = cfg.d_model, cfg.n_heads
    m = cfg.mla
    ks = jax.random.split(key, 7)
    qd = m.nope_head_dim + m.rope_head_dim
    return {
        "wq_a": dense_init(ks[0], d, m.q_lora_rank, ("embed", "q_lora")),
        "q_a_norm": rmsnorm_init(m.q_lora_rank),
        "wq_b": dense_init(ks[1], m.q_lora_rank, H * qd, ("q_lora", "q_dim")),
        "wkv_a": dense_init(ks[2], d, m.kv_lora_rank + m.rope_head_dim, ("embed", "kv_lora")),
        "kv_a_norm": rmsnorm_init(m.kv_lora_rank),
        "wk_b": dense_init(ks[3], m.kv_lora_rank, H * m.nope_head_dim, ("kv_lora", "q_dim")),
        "wv_b": dense_init(ks[4], m.kv_lora_rank, H * m.v_head_dim, ("kv_lora", "q_dim")),
        "wo": dense_init(ks[5], H * m.v_head_dim, d, ("q_dim", "embed")),
    }


def mla_apply(params, x, cfg, spec, positions, cache=None, q_offset=0):
    """MLA with latent KV cache (the 93%-smaller cache of deepseek-v3).

    cache = dict(ckv (B,S,r), kpe (B,S,dr)).
    """
    B, S, d = x.shape
    H, m = cfg.n_heads, cfg.mla
    dn, dr, dv, r = m.nope_head_dim, m.rope_head_dim, m.v_head_dim, m.kv_lora_rank

    with layer_scope("wq_a"):
        q = nmatmul(x, params["wq_a"])
    q = rmsnorm(params["q_a_norm"], q.astype(x.dtype), cfg.norm_eps)
    with layer_scope("wq_b"):
        q = nmatmul(q, params["wq_b"]).reshape(B, S, H, dn + dr)
    q_nope, q_pe = q[..., :dn], q[..., dn:]
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)

    with layer_scope("wkv_a"):
        kv = nmatmul(x, params["wkv_a"])
    ckv, k_pe = kv[..., :r], kv[..., r:]
    ckv = rmsnorm(params["kv_a_norm"], ckv.astype(x.dtype), cfg.norm_eps)
    k_pe = apply_rope(k_pe.reshape(B, S, 1, dr), positions, cfg.rope_theta)

    wk_b = params["wk_b"].reshape(r, H, dn)
    wv_b = params["wv_b"].reshape(r, H, dv)

    if cache is None:
        # training/prefill: expand the latent into per-head k/v, blockwise attn
        q_nope = logical_constraint(q_nope, ("batch", None, "heads", None))
        k_nope = jnp.einsum("bsr,rhd->bshd", ckv, wk_b.astype(x.dtype))
        v = jnp.einsum("bsr,rhd->bshd", ckv, wv_b.astype(x.dtype))
        k_nope = logical_constraint(k_nope, ("batch", None, "heads", None))
        v = logical_constraint(v, ("batch", None, "heads", None))
        k = jnp.concatenate([k_nope, jnp.broadcast_to(k_pe, (B, S, H, dr))], axis=-1)
        qf = jnp.concatenate([q_nope, q_pe], axis=-1)
        # pad v head_dim up to k's for the shared kernel, then slice back
        out = blockwise_attention(qf, k, jnp.pad(v, ((0, 0),) * 3 + ((0, dn + dr - dv),)),
                                  causal=True, q_offset=q_offset)
        out = out[..., :dv]
        new_cache = {
            "ckv": logical_constraint(ckv, ("batch", "kv_seq", None)),
            "kpe": logical_constraint(k_pe.reshape(B, S, dr),
                                      ("batch", "kv_seq", None)),
        }
    elif S > 1:
        # chunked prefill: EXPANDED form over the updated latent cache.
        # The absorbed decode form below is mathematically equal but
        # bitwise different (different contraction order); re-expanding
        # the cached latent into per-head K/V reproduces the no-cache
        # prefill bits exactly, which is what keeps chunked serving
        # bit-identical to solo generation.
        ckv_c = _cache_update(cache["ckv"], ckv, q_offset)
        kpe_c = _cache_update(cache["kpe"], k_pe.reshape(B, S, dr), q_offset)
        ckv_c = logical_constraint(ckv_c, ("batch", "kv_seq", None))
        kpe_c = logical_constraint(kpe_c, ("batch", "kv_seq", None))
        Lc = ckv_c.shape[1]
        ckv_x = ckv_c.astype(x.dtype)
        q_nope = logical_constraint(q_nope, ("batch", None, "heads", None))
        k_nope = jnp.einsum("bsr,rhd->bshd", ckv_x, wk_b.astype(x.dtype))
        v = jnp.einsum("bsr,rhd->bshd", ckv_x, wv_b.astype(x.dtype))
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kpe_c.astype(x.dtype)[:, :, None, :],
                                      (B, Lc, H, dr))], axis=-1)
        qf = jnp.concatenate([q_nope, q_pe], axis=-1)
        out = blockwise_attention(qf, k,
                                  jnp.pad(v, ((0, 0),) * 3 + ((0, dn + dr - dv),)),
                                  causal=True, q_offset=q_offset)
        out = out[..., :dv]
        new_cache = {"ckv": ckv_c, "kpe": kpe_c}
    else:
        # decode: absorbed form — project q into the latent space and attend
        # the latent cache directly (never materialize per-head K/V).
        ckv_c = _cache_update(cache["ckv"], ckv, q_offset)
        kpe_c = _cache_update(cache["kpe"], k_pe.reshape(B, S, dr), q_offset)
        ckv_c = logical_constraint(ckv_c, ("batch", "kv_seq", None))
        kpe_c = logical_constraint(kpe_c, ("batch", "kv_seq", None))
        q_eff = jnp.einsum("bshd,rhd->bshr", q_nope, wk_b.astype(x.dtype))  # (B,1,H,r)
        s = jnp.einsum("bhr,bkr->bhk", q_eff[:, 0].astype(jnp.bfloat16),
                       ckv_c.astype(jnp.bfloat16), preferred_element_type=jnp.float32)
        s = s + jnp.einsum("bhd,bkd->bhk", q_pe[:, 0].astype(jnp.bfloat16),
                           kpe_c.astype(jnp.bfloat16), preferred_element_type=jnp.float32)
        s = s * ((dn + dr) ** -0.5)
        mask = jnp.arange(ckv_c.shape[1])[None, None, :] <= _row_pos(q_offset, 3)
        s = jnp.where(mask, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o_lat = jnp.einsum("bhk,bkr->bhr", p.astype(jnp.bfloat16),
                           ckv_c.astype(jnp.bfloat16), preferred_element_type=jnp.float32)
        out = jnp.einsum("bhr,rhd->bhd", o_lat.astype(x.dtype), wv_b.astype(x.dtype))
        out = out.reshape(B, 1, H, dv)
        new_cache = {"ckv": ckv_c, "kpe": kpe_c}

    out = out.astype(x.dtype).reshape(B, S, H * dv)
    with layer_scope("wo"):
        return nmatmul(out, params["wo"]).astype(x.dtype), new_cache


# ---------------------------------------------------------------------------
# cross-attention (whisper decoder)
# ---------------------------------------------------------------------------

def cross_attn_init(key, cfg):
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, d, H * hd, ("embed", "q_dim")),
        "wk": dense_init(k2, d, H * hd, ("embed", "q_dim")),
        "wv": dense_init(k3, d, H * hd, ("embed", "q_dim")),
        "wo": dense_init(k4, H * hd, d, ("q_dim", "embed")),
    }


def cross_attn_apply(params, x, enc_out, cfg):
    B, S, d = x.shape
    Se = enc_out.shape[1]
    H, hd = cfg.n_heads, cfg.resolved_head_dim
    with layer_scope("wq"):
        q = nmatmul(x, params["wq"]).reshape(B, S, H, hd)
    with layer_scope("wk"):
        k = nmatmul(enc_out, params["wk"]).reshape(B, Se, H, hd)
    with layer_scope("wv"):
        v = nmatmul(enc_out, params["wv"]).reshape(B, Se, H, hd)
    out = blockwise_attention(q, k, v, causal=False)
    out = out.astype(x.dtype).reshape(B, S, H * hd)
    with layer_scope("wo"):
        return nmatmul(out, params["wo"]).astype(x.dtype)
