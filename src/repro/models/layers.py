"""Shared building blocks: params-with-logical-axes, norms, embeddings, RoPE, MLPs.

Parameters are created as ``PP(value, axes)`` leaves — ``axes`` is a tuple
of *logical* axis names (one per array dim) that
``repro.distributed.sharding`` later maps onto mesh axes.  ``unzip``
separates a PP-tree into (params, specs); all model ``apply`` functions
take the plain params tree.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.numerics import (Numerics, layer_scope, maybe_numerics_scope,
                            nmatmul)


class PP:
    """A parameter leaf: array value + logical axis names.

    Registered as a pytree node with ``axes`` as static aux data, so PP
    trees flow through ``jax.vmap`` / ``jax.eval_shape`` (abstract init for
    the dry-run) while ``unzip`` can still split values from specs.
    """

    __slots__ = ("value", "axes")

    def __init__(self, value, axes):
        self.value = value
        self.axes = tuple(axes)

    def __repr__(self):
        shape = getattr(self.value, "shape", None)
        return f"PP{tuple(shape) if shape is not None else '?'}:{self.axes}"


jax.tree_util.register_pytree_node(
    PP, lambda p: ((p.value,), p.axes), lambda axes, ch: PP(ch[0], axes)
)


def _is_pp(x):
    return isinstance(x, PP)


def unzip(tree):
    """PP-tree -> (params tree of arrays, specs tree of logical-axes tuples)."""
    params = jax.tree.map(lambda p: p.value, tree, is_leaf=_is_pp)
    specs = jax.tree.map(lambda p: p.axes, tree, is_leaf=_is_pp)
    return params, specs


def normal(key, shape, scale, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) * jnp.asarray(scale, dtype)


def dense_init(key, d_in, d_out, axes, dtype=jnp.float32, scale=None):
    scale = scale if scale is not None else d_in ** -0.5
    return PP(normal(key, (d_in, d_out), scale, dtype), axes)


def stack_init(init_fn: Callable, key, repeats: int):
    """vmap an init over a leading 'layers' axis; prepends 'layers' to specs."""
    keys = jax.random.split(key, repeats)
    tree = jax.vmap(init_fn)(keys)
    return jax.tree.map(
        lambda p: PP(p.value, ("layers",) + p.axes), tree, is_leaf=_is_pp
    )


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d, name="scale"):
    return {name: PP(jnp.zeros((d,), jnp.float32), ("embed",))}


def rmsnorm(params, x, eps=1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(dt)


# ---------------------------------------------------------------------------
# embeddings / unembedding
# ---------------------------------------------------------------------------

def embed_init(key, vocab, d, scale=1.0):
    # vocab-sharded ONLY ('embed_table' never joins the fsdp rule): a 2D-
    # sharded table makes GSPMD all-gather it around the token gather.
    return PP(normal(key, (vocab, d), scale), ("vocab", "embed_table"))


def embed_lookup(table, tokens):
    return jnp.take(table, tokens, axis=0)


def unembed(x, table, ncfg: Numerics | None = None, transpose=True,
            name: str = "lm_head"):
    """Unembedding matmul under the ambient numerics scope.

    Resolves under the ``lm_head`` layer path (override via ``name``), so
    the site participates in per-layer policies and the sensitivity tap
    like every other projection; ``ncfg`` optionally establishes the scope
    for this call.
    """
    w = table.T if transpose else table
    with maybe_numerics_scope(ncfg), layer_scope(name):
        return nmatmul(x, w)


# ---------------------------------------------------------------------------
# RoPE (standard + M-RoPE sections)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim, theta=10000.0):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta=10000.0, sections=None):
    """x: (..., S, H, D); positions: (..., S) or (..., S, 3) for M-RoPE."""
    D = x.shape[-1]
    half = D // 2
    freqs = rope_freqs(D, theta)  # (half,)
    if sections is None:
        ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,half)
    else:
        # M-RoPE: frequency bands split into (t, h, w) sections, each using
        # its own position stream (qwen2-vl §2; text positions are identical
        # across sections, so this reduces to standard RoPE for pure text)
        st, sh, sw = sections
        assert st + sh + sw == half, (sections, half)
        sec = jnp.concatenate([
            jnp.zeros((st,), jnp.int32),
            jnp.ones((sh,), jnp.int32),
            jnp.full((sw,), 2, jnp.int32),
        ])  # (half,) -> which position stream drives each band
        pos = jnp.take_along_axis(
            positions.astype(jnp.float32),  # (..., S, 3)
            jnp.broadcast_to(sec, positions.shape[:-1] + (half,)).astype(jnp.int32),
            axis=-1,
        )  # (..., S, half)
        ang = pos[..., :, None, :] * freqs  # (..., S, 1, half)
    cos = jnp.cos(ang).astype(x.dtype)
    sin = jnp.sin(ang).astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# ---------------------------------------------------------------------------
# gated MLP
# ---------------------------------------------------------------------------

def mlp_init(key, d, ff):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": dense_init(k1, d, ff, ("embed", "mlp")),
        "wg": dense_init(k2, d, ff, ("embed", "mlp")),
        "wo": dense_init(k3, ff, d, ("mlp", "embed")),
    }


def mlp_apply(params, x, ncfg: Numerics | None = None):
    """Gated MLP under the ambient numerics scope (relative call-site
    paths ``wi``/``wg``/``wo``); ``ncfg`` optionally establishes the scope
    for this call (a config, or a policy resolved from here down)."""
    from repro.distributed.sharding import logical_constraint

    hidden_axes = ("batch",) + (None,) * (x.ndim - 2) + ("mlp",)
    with maybe_numerics_scope(ncfg):
        with layer_scope("wi"):
            h = nmatmul(x, params["wi"])
        with layer_scope("wg"):
            g = nmatmul(x, params["wg"])
        h = logical_constraint(h, hidden_axes)
        g = logical_constraint(g, hidden_axes)
        h = h * jax.nn.silu(g)
        with layer_scope("wo"):
            return nmatmul(h.astype(x.dtype), params["wo"])


def softcap(x, cap):
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap
