"""Mixture-of-experts with sort-based capacity dispatch (EP-shardable).

Dispatch strategy: (token, expert) assignments are sorted by expert id and
scattered into a dense ``(E, C, D)`` buffer (capacity C per expert,
overflow dropped — standard capacity-factor routing).  The buffer's expert
axis carries the ``experts`` logical axis, so under the training rules it
shards over 'model' (classic EP) and under serving rules over 'data'
(cluster-wide EP for the 671B-class models); GSPMD materializes the
all-to-alls from the sharding change at the scatter/gather boundaries.

Supports top-k routing, shared (always-on) experts (deepseek-v3), and
routes every expert matmul through the paper's numerics config — including
the routed experts: each expert's three projections resolve under the
relative ``expert{k}.{wi,wg,wo}`` paths (full paths
``blocks.{i}.mlp.expert{k}.wi`` etc.), so a per-layer policy can give
different experts different multipliers.  When every expert resolves to an
``exact`` config (the pre-policy behaviour, and any plain exact
NumericsConfig), the fused all-expert einsum datapath is kept bit-for-bit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.numerics import (Numerics, ambient_view, layer_scope,
                            maybe_numerics_scope, nmatmul, numerics_scope,
                            operand_tap_active, resolve)
from repro.distributed.sharding import (current_mesh_rules, logical_constraint,
                                        spec_for)

from .layers import PP, dense_init, mlp_apply, mlp_init, normal


def moe_init(key, cfg):
    d, ff = cfg.d_model, cfg.d_ff
    e = cfg.moe
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    scale = d ** -0.5
    p = {
        "router": dense_init(k1, d, e.n_experts, ("embed", None)),
        "wi": PP(normal(k2, (e.n_experts, d, ff), scale), ("experts", "embed", "mlp")),
        "wg": PP(normal(k3, (e.n_experts, d, ff), scale), ("experts", "embed", "mlp")),
        "wo": PP(normal(k4, (e.n_experts, ff, d), ff ** -0.5), ("experts", "mlp", "embed")),
    }
    if e.n_shared:
        p["shared"] = mlp_init(k5, d, ff * e.n_shared)
    return p


def routed_expert_configs(ncfg: Numerics | None, n_experts: int) -> dict:
    """Resolved config per (projection, expert) under ``expert{k}.{name}``.

    ``ncfg`` is the block's ``mlp``-scoped policy view (or a plain config,
    which resolves identically for every expert); pass
    ``repro.numerics.ambient_view()`` to resolve from the ambient scope.
    Returns ``{name: (cfg_expert0, ..., cfg_expertE-1)}`` for wi/wg/wo.
    """
    return {name: tuple(resolve(ncfg, f"expert{k}.{name}")
                        for k in range(n_experts))
            for name in ("wi", "wg", "wo")}


def _all_exact(cfgs: dict) -> bool:
    return all(c.mode == "exact" for tup in cfgs.values() for c in tup)


def _experts_matmul(buf, w, name, out_dtype):
    """Per-expert numerics matmul: ``buf (B, E, C, D) @ w (E, D, F)``.

    Each expert's slab goes through :func:`nmatmul` under its own
    ``layer_scope`` segment (``expert{k}.{name}``), so distinct experts can
    run distinct multipliers in one forward.  Used only when some expert
    resolves non-exact (or the calibration tap is recording) — the
    all-exact fast path keeps the fused einsum.
    """
    B, E, C, D = buf.shape
    outs = []
    for k in range(E):
        with layer_scope(f"expert{k}.{name}"):
            ye = nmatmul(buf[:, k].reshape(B * C, D), w[k])
        outs.append(ye.reshape(B, C, -1).astype(out_dtype))
    return jnp.stack(outs, axis=1)


def moe_apply(params, x, cfg, ncfg: Numerics | None = None):
    """x: (B, S, D) -> (B, S, D).

    Numerics come from the ambient scope (the caller establishes this
    block's ``mlp`` prefix); the shared (always-on) expert resolves under
    the relative ``shared.*`` paths and the routed experts under
    ``expert{k}.{wi,wg,wo}``.  ``ncfg`` optionally establishes the scope
    for this call.  The router always runs exact fp32 (routing is control
    logic).  When every expert resolves to an exact config the routed slab
    multiply keeps the fused all-expert einsum in ``x.dtype`` — bit-for-bit
    the pre-policy datapath; any non-exact expert switches the layer to
    per-expert :func:`nmatmul` calls.

    Two implementations:
    * **shard_map EP** (used whenever a mesh context with a 'model' axis
      dividing E is active): textbook expert parallelism — local routing/
      sort/dispatch, one all_to_all over the expert axis, local expert
      matmuls, all_to_all back, local combine.  Per-chip dispatch traffic
      is exactly K x activation bytes; nothing is ever replicated.
      (§Perf pair 2: GSPMD's batched big-D gathers replicated the
      dispatch slab — ~200s collective term on deepseek-v3 train;
      this path removes it.)
    * **GSPMD group-local** fallback (no mesh / indivisible E): each batch
      row sorts its own S*K assignments; only int32 slot indices are
      scattered, big-D movement is gathers.
    """
    with maybe_numerics_scope(ncfg):
        state = current_mesh_rules()
        if state is not None:
            mesh, rules = state
            w_spec = spec_for(("experts", None, None), params["wi"].shape,
                              mesh, rules)
            if w_spec[0] is not None:  # experts axis actually sharded
                return _moe_apply_shardmap(params, x, cfg, mesh, rules)
        return _moe_apply_gspmd(params, x, cfg)


def _moe_apply_shardmap(params, x, cfg, mesh, rules):
    e = cfg.moe
    E, K = e.n_experts, e.top_k
    B, S, D = x.shape

    # per-expert numerics: the shard_map body traces ONCE for all EP shards,
    # so expert-heterogeneous configs cannot branch per shard — uniform
    # non-exact configs run per-local-expert nmatmul inside the body;
    # heterogeneous policies fall back to the group-local GSPMD path (which
    # slices experts at trace time and lets GSPMD partition the result).
    cfgs = routed_expert_configs(ambient_view(), E)
    if any(len(set(tup)) > 1 for tup in cfgs.values()):
        return _moe_apply_gspmd(params, x, cfg)
    ucfg = {name: tup[0] for name, tup in cfgs.items()}
    exact_experts = _all_exact(cfgs)

    x_spec = spec_for(("batch", "seq", None), x.shape, mesh, rules)
    w_spec = spec_for(("experts", None, None), params["wi"].shape, mesh, rules)
    r_spec = spec_for((None, None), params["router"].shape, mesh, rules)
    ex_axis = w_spec[0]  # mesh axis (or tuple) carrying the expert dim
    ex_axes = ex_axis if isinstance(ex_axis, tuple) else (ex_axis,)
    nm = 1
    for a in ex_axes:
        nm *= mesh.shape[a]
    # local token count per shard (static): derive from the specs
    def _shards(spec, dim_axis):
        ax = spec[dim_axis] if dim_axis < len(spec) else None
        if ax is None:
            return 1
        return int(
            __import__("numpy").prod([mesh.shape[a] for a in
                                      (ax if isinstance(ax, tuple) else (ax,))]))

    b_loc = B // _shards(x_spec, 0)
    s_loc = S // _shards(x_spec, 1)
    T_loc = b_loc * s_loc
    A = T_loc * K
    C = max(4, -(-int(T_loc * K / E * e.capacity_factor) // 4) * 4)

    def body(xl, router, wi, wg, wo):
        # xl: (b_loc, s_loc, D); wi/wg/wo: (E/nm, D, F)
        xt = xl.reshape(T_loc, D)
        logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                            router.astype(jnp.float32))
        probs = jax.nn.softmax(logits, axis=-1)
        gate, eidx = jax.lax.top_k(probs, K)
        gate = (gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)).astype(xl.dtype)

        ea = eidx.reshape(A)
        ta = jnp.arange(A, dtype=jnp.int32) // K
        order = jnp.argsort(ea)
        es, ts = ea[order], ta[order]
        counts = jnp.bincount(es, length=E)
        starts = jnp.concatenate(
            [jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
        pos = jnp.arange(A, dtype=jnp.int32) - starts[es].astype(jnp.int32)
        keep = pos < C
        pos_c = jnp.where(keep, pos, 0)
        vals = jnp.where(keep[:, None], xt[ts], 0)
        buf = jnp.zeros((E, C, D), xl.dtype).at[es, pos_c].add(vals, mode="drop")

        # EP exchange: (E, C, D) -> (E/nm, C*nm, D); local expert compute
        buf = jax.lax.all_to_all(buf, ex_axes, split_axis=0, concat_axis=1,
                                 tiled=True)
        if exact_experts:
            h = jnp.einsum("ecd,edf->ecf", buf, wi.astype(xl.dtype))
            g = jnp.einsum("ecd,edf->ecf", buf, wg.astype(xl.dtype))
            h = h * jax.nn.silu(g)
            out = jnp.einsum("ecf,efd->ecd", h, wo.astype(xl.dtype))
        else:
            # uniform resolved config: a nested numerics_scope locally
            # overrides the outer policy (the body cannot branch per shard)
            def local(b, w_, c_):
                with numerics_scope(c_):
                    return jnp.stack(
                        [nmatmul(b[i], w_[i]) for i in range(b.shape[0])]
                    ).astype(xl.dtype)

            h = local(buf, wi, ucfg["wi"])
            g = local(buf, wg, ucfg["wg"])
            h = h * jax.nn.silu(g)
            out = local(h, wo, ucfg["wo"])
        out = jax.lax.all_to_all(out, ex_axes, split_axis=1, concat_axis=0,
                                 tiled=True)                    # (E, C, D)

        flat = out.reshape(E * C, D)
        slot = es * C + pos_c
        picked = jnp.take(flat, jnp.where(keep, slot, 0), axis=0)
        gs = gate.reshape(A)[order]
        picked = picked * (gs * keep.astype(xl.dtype))[:, None]
        y = jnp.zeros((T_loc, D), xl.dtype).at[ts].add(picked, mode="drop")
        return y.reshape(b_loc, s_loc, D)

    y = shard_map(
        body, mesh=mesh,
        in_specs=(x_spec, r_spec, w_spec, w_spec, w_spec),
        out_specs=x_spec,
        check_rep=False,
    )(x, params["router"], params["wi"], params["wg"], params["wo"])

    if "shared" in params:
        with layer_scope("shared"):
            y = y + mlp_apply(params["shared"], x.reshape(-1, D)).astype(
                x.dtype).reshape(B, S, D)
    return y


def _moe_apply_gspmd(params, x, cfg):
    B, S, D = x.shape
    e = cfg.moe
    E, K = e.n_experts, e.top_k
    A = S * K                                        # assignments per group
    C = max(4, -(-int(S * K / E * e.capacity_factor) // 4) * 4)

    # routing (always fp32 exact — routing decisions are control logic)
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, K)             # (B, S, K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    def route_group(eg):
        # eg: (S, K) -> int32 routing plan only (all small arrays — the big-D
        # data movement below is pure gathers, which GSPMD partitions cleanly;
        # scattering (S*K, D) values directly makes GSPMD replicate the slab)
        ea = eg.reshape(A)
        ta = jnp.arange(A, dtype=jnp.int32) // K
        order = jnp.argsort(ea)                      # local, stable
        es, ts = ea[order], ta[order]
        counts = jnp.bincount(es, length=E)
        starts = jnp.concatenate(
            [jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
        pos = jnp.arange(A, dtype=jnp.int32) - starts[es].astype(jnp.int32)
        keep = pos < C
        slot = es * C + jnp.where(keep, pos, 0)
        # src[e*C+c] = 1 + token feeding that slot (0 = empty slot)
        src = jnp.zeros((E * C,), jnp.int32).at[slot].max(
            jnp.where(keep, ts + 1, 0), mode="drop")
        # inverse: slot of each assignment (A = S*K), -1 when dropped
        inv_sorted = jnp.where(keep, slot, -1)
        inv = jnp.zeros((A,), jnp.int32).at[order].set(inv_sorted, mode="drop")
        return src, inv.reshape(S, K)

    src, inv = jax.vmap(route_group)(eidx)               # (B, E*C), (B, S, K)

    def gather_group(xg, srcg):
        vals = jnp.take(xg, jnp.maximum(srcg - 1, 0), axis=0)
        return jnp.where((srcg > 0)[:, None], vals, 0).reshape(E, C, D)

    buf = jax.vmap(gather_group)(x, src)                 # (B, E, C, D)
    buf = logical_constraint(buf, ("batch", "experts", None, None))

    # expert MLPs (weights EP-sharded over 'experts'; groups stay on 'data').
    # All-exact experts keep the fused einsum (bit-for-bit the pre-policy
    # datapath); any non-exact expert — or an active calibration tap, which
    # needs per-expert operand records — switches to per-expert nmatmul.
    cfgs = routed_expert_configs(ambient_view(), E)
    if _all_exact(cfgs) and not operand_tap_active():
        h = jnp.einsum("becd,edf->becf", buf, params["wi"].astype(x.dtype))
        g = jnp.einsum("becd,edf->becf", buf, params["wg"].astype(x.dtype))
        h = h * jax.nn.silu(g)
        out_buf = jnp.einsum("becf,efd->becd", h, params["wo"].astype(x.dtype))
    else:
        h = _experts_matmul(buf, params["wi"], "wi", x.dtype)
        g = _experts_matmul(buf, params["wg"], "wg", x.dtype)
        h = h * jax.nn.silu(g)
        out_buf = _experts_matmul(h, params["wo"], "wo", x.dtype)
    out_buf = logical_constraint(out_buf, ("batch", "experts", None, None))

    def combine_group(ob, invg, gg):
        # (E, C, D) slab -> per-token gather of its K slots, gate-weighted sum
        flat = ob.reshape(E * C, D)
        picked = jnp.take(flat, jnp.maximum(invg.reshape(-1), 0), axis=0)
        picked = jnp.where((invg.reshape(-1) >= 0)[:, None], picked, 0)
        picked = picked.reshape(S, K, D) * gg[..., None].astype(ob.dtype)
        return picked.sum(axis=1)

    y = jax.vmap(combine_group)(out_buf, inv, gate)      # (B, S, D)

    if "shared" in params:
        with layer_scope("shared"):
            y = y + mlp_apply(params["shared"], x.reshape(-1, D)).astype(
                x.dtype).reshape(B, S, D)
    return y


def aux_load_balance_loss(logits, eidx, n_experts):
    """Switch-style load-balancing auxiliary loss (framework feature)."""
    probs = jax.nn.softmax(logits, axis=-1)
    me = probs.mean(axis=0)
    one_hot = jax.nn.one_hot(eidx[..., 0], n_experts)
    fe = one_hot.mean(axis=0)
    return n_experts * jnp.sum(me * fe)
