"""ResNet-18 (CIFAR variant) — the paper's Table IV workload.

Convolutions route through the numerics config: ``exact`` mode uses the
native convolution; approximate modes lower each conv to im2col + the
numerics-aware matmul (``emulated``: every scalar product goes through the
bit-level multiplier — the paper's §IV-C methodology: train exactly, infer
approximately; ``segmented``: the split-float TPU analogue).  BatchNorm
statistics are part of a separate ``state`` tree (train mode updates them;
inference uses the running stats, fused into scale/shift so no multipliers
are spent on normalization).

``ResNetConfig.numerics`` may be a per-layer :class:`NumericsPolicy`
(``repro.core.policy``); layer paths are ``stem``,
``s{stage}b{block}.{conv1,conv2,proj}`` and ``fc`` (see
:func:`layer_paths`), which is what ``repro.core.sweep.auto_configure``
assigns per-layer designs against.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.numerics import (Numerics, NumericsConfig, current_numerics,
                            layer_scope, maybe_numerics_scope, nmatmul,
                            numerics_scope, operand_tap_active, resolve_here)

from .layers import PP, normal


@dataclasses.dataclass(frozen=True)
class ResNetConfig:
    num_classes: int = 10
    widths: tuple = (64, 128, 256, 512)
    blocks: tuple = (2, 2, 2, 2)
    numerics: Numerics = NumericsConfig(mode="exact", compute_dtype="float32")


def layer_paths(cfg: ResNetConfig) -> list:
    """All policy paths of this network, execution order (for auto-config)."""
    paths = ["stem"]
    cin = cfg.widths[0]
    for si, (w, n) in enumerate(zip(cfg.widths, cfg.blocks)):
        for bi in range(n):
            stride = 2 if (si > 0 and bi == 0) else 1
            paths += [f"s{si}b{bi}.conv1", f"s{si}b{bi}.conv2"]
            if stride != 1 or cin != w:
                paths.append(f"s{si}b{bi}.proj")
            cin = w
    paths.append("fc")
    return paths


def conv_init(key, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    return PP(normal(key, (kh, kw, cin, cout), (2.0 / fan_in) ** 0.5),
              (None, None, None, "mlp"))


def bn_init(c):
    return {
        "scale": PP(jnp.ones((c,), jnp.float32), (None,)),
        "bias": PP(jnp.zeros((c,), jnp.float32), (None,)),
    }


def bn_state_init(c):
    return {"mean": jnp.zeros((c,), jnp.float32), "var": jnp.ones((c,), jnp.float32)}


def conv2d(x, w, stride=1, numerics: Numerics | None = None):
    """NHWC conv; approximate numerics use im2col + the numerics matmul.

    The config resolves from the ambient scope at the current layer path
    (``numerics`` optionally establishes the scope for this call); with no
    ambient scope at all the native lowering runs unconditionally.  Exact
    convs run the native lowering too — except while a sensitivity
    calibration tap is installed (``repro.numerics.operand_tap_active``),
    when they route through im2col + ``nmatmul`` so the instrumented pass
    records this site's operand distribution under its full path.
    """
    with maybe_numerics_scope(numerics):
        resolved = (resolve_here() if current_numerics() is not None
                    else None)
        if resolved is None or (resolved.mode == "exact"
                                and not operand_tap_active()):
            return jax.lax.conv_general_dilated(
                x, w, (stride, stride), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
            )
        kh, kw, cin, cout = w.shape
        B, H, W, _ = x.shape
        Ho, Wo = -(-H // stride), -(-W // stride)
        # im2col with XLA-compatible SAME padding (asymmetric under stride)
        th = max((Ho - 1) * stride + kh - H, 0)
        tw = max((Wo - 1) * stride + kw - W, 0)
        ph_lo, pw_lo = th // 2, tw // 2
        xp = jnp.pad(x, ((0, 0), (ph_lo, th - ph_lo), (pw_lo, tw - pw_lo),
                         (0, 0)))
        patches = []
        for i in range(kh):
            for j in range(kw):
                patches.append(
                    xp[:, i:i + (Ho - 1) * stride + 1:stride,
                       j:j + (Wo - 1) * stride + 1:stride, :])
        cols = jnp.concatenate(patches, axis=-1).reshape(B * Ho * Wo,
                                                         kh * kw * cin)
        wmat = w.reshape(kh * kw * cin, cout)
        # one audited entry point for emulated AND segmented approximate
        # convs; nmatmul re-resolves at the ambient path so the calibration
        # tap records this site under its full path
        out = nmatmul(cols, wmat)
        return out.reshape(B, Ho, Wo, cout)


def batchnorm(params, state, x, train: bool, momentum=0.9, eps=1e-5):
    if train:
        mean = x.mean(axis=(0, 1, 2))
        var = x.var(axis=(0, 1, 2))
        new_state = {
            "mean": momentum * state["mean"] + (1 - momentum) * mean,
            "var": momentum * state["var"] + (1 - momentum) * var,
        }
    else:
        mean, var = state["mean"], state["var"]
        new_state = state
    inv = jax.lax.rsqrt(var + eps) * params["scale"]
    return (x - mean) * inv + params["bias"], new_state


def _basic_block_init(key, cin, cout, stride):
    ks = jax.random.split(key, 3)
    p = {
        "conv1": conv_init(ks[0], 3, 3, cin, cout), "bn1": bn_init(cout),
        "conv2": conv_init(ks[1], 3, 3, cout, cout), "bn2": bn_init(cout),
    }
    s = {"bn1": bn_state_init(cout), "bn2": bn_state_init(cout)}
    if stride != 1 or cin != cout:
        p["proj"] = conv_init(ks[2], 1, 1, cin, cout)
        p["bn_proj"] = bn_init(cout)
        s["bn_proj"] = bn_state_init(cout)
    return p, s


def init(cfg: ResNetConfig, key):
    ks = jax.random.split(key, 2 + sum(cfg.blocks))
    params = {"stem": conv_init(ks[0], 3, 3, 3, cfg.widths[0]),
              "bn_stem": bn_init(cfg.widths[0])}
    state = {"bn_stem": bn_state_init(cfg.widths[0])}
    ki = 1
    cin = cfg.widths[0]
    for si, (w, n) in enumerate(zip(cfg.widths, cfg.blocks)):
        for bi in range(n):
            stride = 2 if (si > 0 and bi == 0) else 1
            p, s = _basic_block_init(ks[ki], cin, w, stride)
            ki += 1
            params[f"s{si}b{bi}"] = p
            state[f"s{si}b{bi}"] = s
            cin = w
    params["fc"] = PP(normal(ks[-1], (cfg.widths[-1], cfg.num_classes),
                             cfg.widths[-1] ** -0.5), (None, None))
    params["fc_b"] = PP(jnp.zeros((cfg.num_classes,), jnp.float32), (None,))
    return params, state


def _block_apply(p, s, x, stride, cfg, train):
    with layer_scope("conv1"):
        c1 = conv2d(x, p["conv1"], stride)
    h, s1 = batchnorm(p["bn1"], s["bn1"], c1, train)
    h = jax.nn.relu(h)
    with layer_scope("conv2"):
        c2 = conv2d(h, p["conv2"], 1)
    h, s2 = batchnorm(p["bn2"], s["bn2"], c2, train)
    if "proj" in p:
        with layer_scope("proj"):
            cp = conv2d(x, p["proj"], stride)
        x, s3 = batchnorm(p["bn_proj"], s["bn_proj"], cp, train)
        new_s = {"bn1": s1, "bn2": s2, "bn_proj": s3}
    else:
        new_s = {"bn1": s1, "bn2": s2}
    return jax.nn.relu(h + x), new_s


def apply(params, state, x, cfg: ResNetConfig, train: bool = False):
    """x: (B, 32, 32, 3) -> logits (B, classes); returns (logits, new_state).

    Establishes the numerics scope from ``cfg.numerics``; every conv/fc
    resolves ambiently under its layer path (see :func:`layer_paths`)."""
    with numerics_scope(cfg.numerics):
        new_state = {}
        with layer_scope("stem"):
            cs = conv2d(x, params["stem"], 1)
        h, new_state["bn_stem"] = batchnorm(
            params["bn_stem"], state["bn_stem"], cs, train)
        h = jax.nn.relu(h)
        for si, (w, n) in enumerate(zip(cfg.widths, cfg.blocks)):
            for bi in range(n):
                stride = 2 if (si > 0 and bi == 0) else 1
                with layer_scope(f"s{si}b{bi}"):
                    h, s = _block_apply(params[f"s{si}b{bi}"],
                                        state[f"s{si}b{bi}"], h, stride,
                                        cfg, train)
                new_state[f"s{si}b{bi}"] = s
        h = h.mean(axis=(1, 2))
        # final classifier also goes through the configured multiplier
        with layer_scope("fc"):
            logits = nmatmul(h, params["fc"])
        return logits + params["fc_b"], new_state


def loss_fn(params, state, batch, cfg: ResNetConfig):
    logits, new_state = apply(params, state, batch["images"], cfg, train=True)
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return (lse - gold).mean(), new_state
