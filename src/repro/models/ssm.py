"""Mamba2 (SSD) block: projections + causal conv + chunked selective scan.

The scan itself is the Pallas kernel (``repro.kernels.ssd_scan``); this
module provides the block around it (in/out projections through the
paper's numerics config, gating, depthwise causal conv) plus the O(1)
single-token decode path that makes `long_500k` run at constant cost.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.numerics import (Numerics, layer_scope, maybe_numerics_scope,
                            nmatmul, resolve_here)
from repro.distributed.sharding import logical_constraint
from repro.kernels import ops

from .layers import PP, dense_init, normal, rmsnorm, rmsnorm_init


def ssm_dims(cfg):
    s = cfg.ssm
    d_inner = s.expansion * cfg.d_model
    n_heads = d_inner // s.head_dim
    return d_inner, n_heads


def ssm_init(key, cfg):
    s = cfg.ssm
    d = cfg.d_model
    d_inner, H = ssm_dims(cfg)
    N = s.state_size
    ks = jax.random.split(key, 6)
    # fused in_proj: [z, x, B, C, dt]
    proj_out = 2 * d_inner + 2 * N + H
    return {
        "in_proj": dense_init(ks[0], d, proj_out, ("embed", "ssm_inner")),
        "conv_w": PP(normal(ks[1], (s.conv_width, d_inner), (s.conv_width) ** -0.5),
                     ("conv", "ssm_inner")),
        "conv_b": PP(jnp.zeros((d_inner,), jnp.float32), ("ssm_inner",)),
        "A_log": PP(jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)), (None,)),
        "dt_bias": PP(jnp.zeros((H,), jnp.float32), (None,)),
        "norm": rmsnorm_init(d_inner)["scale"],
        "out_proj": dense_init(ks[2], d_inner, d, ("ssm_inner", "embed")),
    }


def _split_proj(proj, cfg):
    s = cfg.ssm
    d_inner, H = ssm_dims(cfg)
    N = s.state_size
    z = proj[..., :d_inner]
    xs = proj[..., d_inner:2 * d_inner]
    B = proj[..., 2 * d_inner:2 * d_inner + N]
    C = proj[..., 2 * d_inner + N:2 * d_inner + 2 * N]
    dt = proj[..., 2 * d_inner + 2 * N:]
    return z, xs, B, C, dt


def _causal_conv(xs, w, b, state=None):
    """Depthwise causal conv, width W.  xs: (B, S, D), w: (W, D).

    state: (B, W-1, D) trailing context for decode; returns (out, new_state).
    """
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros(xs.shape[:1] + (W - 1,) + xs.shape[2:], xs.dtype)
        full = jnp.concatenate([pad, xs], axis=1)
    else:
        full = jnp.concatenate([state.astype(xs.dtype), xs], axis=1)
    out = sum(full[:, i:i + xs.shape[1]] * w[i].astype(xs.dtype) for i in range(W))
    out = out + b.astype(xs.dtype)
    new_state = full[:, -(W - 1):]
    return jax.nn.silu(out), new_state


def ssm_apply(params, x, cfg, ncfg: Numerics | None = None, cache=None,
              want_state=False):
    """x: (B, S, D).  cache = dict(conv (B,W-1,Din), state (B,H,N,P)).

    want_state=True (prefill): additionally returns the final SSM/conv state,
    computed in closed form (one weighted einsum over the sequence).

    Numerics come from the ambient scope (the caller establishes this
    block's ``ssm`` prefix); relative call-site paths are
    ``in_proj``/``out_proj`` (projection matmuls) and ``scan`` (backend
    selection only — the selective scan is not a multiplier datapath, but
    its kernel backend is still per-layer).  ``ncfg`` optionally
    establishes the scope for this call.
    """
    with maybe_numerics_scope(ncfg):
        return _ssm_apply(params, x, cfg, cache=cache, want_state=want_state)


def _ssm_apply(params, x, cfg, cache=None, want_state=False):
    s = cfg.ssm
    B_, S, D = x.shape
    d_inner, H = ssm_dims(cfg)
    N, P = s.state_size, s.head_dim

    with layer_scope("in_proj"):
        proj = nmatmul(x, params["in_proj"]).astype(x.dtype)
    proj = logical_constraint(proj, ("batch", None, "ssm_inner"))
    z, xs, Bm, Cm, dt = _split_proj(proj, cfg)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"].astype(jnp.float32))  # (H,)

    if cache is None:
        xs_raw = xs
        xs, conv_tail = _causal_conv(xs, params["conv_w"], params["conv_b"])
        xh = xs.reshape(B_, S, H, P)
        scan_backend = resolve_here("scan").backend
        y = jax.vmap(
            lambda xb, db, Bb, Cb: ops.ssd_scan(xb, db, A, Bb, Cb, chunk=s.chunk,
                                                backend=scan_backend)
        )(xh, dt, Bm.astype(jnp.float32), Cm.astype(jnp.float32))
        new_cache = None
        if want_state:
            # closed-form final state:
            # S[h] = sum_l dt[l,h] e^{A_h (cum[L,h]-cum[l,h])} B[l] x[l,h]^T
            cum = jnp.cumsum(dt, axis=1)                         # (B,S,H)
            w = dt * jnp.exp(A[None, None, :] * (cum[:, -1:, :] - cum))
            S_fin = jnp.einsum("bsh,bsn,bshp->bhnp", w,
                               Bm.astype(jnp.float32), xh.astype(jnp.float32))
            new_cache = {
                "conv": xs_raw[:, -(s.conv_width - 1):].astype(x.dtype),
                "state": S_fin,
            }
    else:
        # decode: single token, O(1) state update
        xs, conv_state = _causal_conv(xs, params["conv_w"], params["conv_b"],
                                      state=cache["conv"])
        xh = xs.reshape(B_, 1, H, P).astype(jnp.float32)
        dt1 = dt[:, 0]                      # (B, H)
        decay = jnp.exp(A[None, :] * dt1)   # (B, H)
        Bv = Bm[:, 0].astype(jnp.float32)   # (B, N)
        Cv = Cm[:, 0].astype(jnp.float32)   # (B, N)
        S_prev = cache["state"]             # (B, H, N, P)
        inp = dt1[..., None, None] * Bv[:, None, :, None] * xh[:, 0][:, :, None, :]
        S_new = decay[..., None, None] * S_prev + inp
        y = jnp.einsum("bn,bhnp->bhp", Cv, S_new)[:, None]  # (B,1,H,P)
        y = y.reshape(B_, 1, H, P)
        new_cache = {"conv": conv_state.astype(cache["conv"].dtype), "state": S_new}

    y = y.reshape(B_, S, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm({"scale": params["norm"]}, y, cfg.norm_eps)
    with layer_scope("out_proj"):
        return nmatmul(y, params["out_proj"]).astype(x.dtype), new_cache


def ssm_cache_init(cfg, batch, dtype=jnp.float32):
    s = cfg.ssm
    d_inner, H = ssm_dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.conv_width - 1, d_inner), dtype),
        "state": jnp.zeros((batch, H, s.state_size, s.head_dim), jnp.float32),
    }
