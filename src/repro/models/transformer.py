"""Model assembly: LM decoder stacks (all 10 archs) + whisper-style enc-dec.

Depth is organized as ``segments``: ``(repeats, pattern)`` pairs scanned
with params stacked on a leading 'layers' axis (compile time flat in
depth), with configurable remat.  ``shared=True`` pattern entries reuse a
single weight set across repeats (zamba2) while still carrying
per-application caches.

Three execution modes:
  train   — no caches collected (memory-clean loss path)
  prefill — no input caches; every block *returns* its cache (SSM blocks
            compute their final state in closed form)
  decode  — single-token step against the caches

Public API:
  init(cfg, key)                          -> PP tree (use layers.unzip)
  loss_fn(params, cfg, batch)             -> scalar CE (chunked over seq)
  prefill(params, cfg, batch, max_len)    -> (last_logits, state)
  decode_step(params, cfg, batch, state, pos) -> (logits, state)
  init_state(cfg, batch, max_len)         -> serving state (abstract-init-able)
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.numerics import (current_numerics, expert_paths,
                            force_unroll_active, is_policy, layer_scope,
                            maybe_numerics_scope, nmatmul, numerics_scope,
                            resolve)
from repro.distributed.sharding import logical_constraint

from . import attention as attn
from . import moe as moe_mod
from . import ssm as ssm_mod
from .layers import (dense_init, embed_init, embed_lookup, mlp_apply,
                     mlp_init, rmsnorm, rmsnorm_init, softcap, stack_init)


# ---------------------------------------------------------------------------
# single block
# ---------------------------------------------------------------------------

def block_init(key, cfg, spec):
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    if spec.kind == "ssm":
        return {"ln1": rmsnorm_init(d), "ssm": ssm_mod.ssm_init(ks[0], cfg)}
    p = {"ln1": rmsnorm_init(d), "ln2": rmsnorm_init(d)}
    if spec.attn == "mla":
        p["attn"] = attn.mla_init(ks[0], cfg)
    elif spec.attn != "none":
        p["attn"] = attn.gqa_init(ks[0], cfg)
    if cfg.encoder_layers:
        p["cross"] = attn.cross_attn_init(ks[2], cfg)
        p["ln_cross"] = rmsnorm_init(d)
    if spec.kind == "moe":
        p["mlp"] = moe_mod.moe_init(ks[1], cfg)
    else:
        p["mlp"] = mlp_init(ks[1], d, cfg.dense_ff)
    return p


def block_apply(params, x, cfg, spec, positions, ncfg=None, mode="train",
                cache=None, q_offset=0, causal=True, enc=None):
    """Returns (x, new_cache_or_None).

    Numerics come from the ambient scope: the caller establishes this
    block's ``blocks.{i}`` prefix (``stack_apply``) and submodules resolve
    under the relative ``attn`` / ``cross`` / ``mlp`` / ``ssm`` segments
    (see ``repro.core.policy`` for the full path table).  ``ncfg``
    optionally establishes the scope for this call (a config, or a policy
    resolved from this block down).
    """
    with maybe_numerics_scope(ncfg):
        return _block_apply(params, x, cfg, spec, positions, mode,
                            cache=cache, q_offset=q_offset, causal=causal,
                            enc=enc)


def _block_apply(params, x, cfg, spec, positions, mode, cache=None,
                 q_offset=0, causal=True, enc=None):
    if spec.kind == "ssm":
        h = rmsnorm(params["ln1"], x, cfg.norm_eps)
        with layer_scope("ssm"):
            h, new_cache = ssm_mod.ssm_apply(
                params["ssm"], h, cfg, cache=cache,
                want_state=(mode == "prefill"),
            )
        x = logical_constraint(x + h, ("batch", "seq", None))
        return x, new_cache

    new_cache = None
    if "attn" in params:
        h = rmsnorm(params["ln1"], x, cfg.norm_eps)
        with layer_scope("attn"):
            if spec.attn == "mla":
                h, new_cache = attn.mla_apply(params["attn"], h, cfg, spec,
                                              positions, cache=cache,
                                              q_offset=q_offset)
            else:
                h, new_cache = attn.gqa_apply(params["attn"], h, cfg, spec,
                                              positions, cache=cache,
                                              q_offset=q_offset, causal=causal)
        x = logical_constraint(x + h, ("batch", "seq", None))
        if mode == "train":
            new_cache = None
    if "cross" in params and enc is not None:
        h = rmsnorm(params["ln_cross"], x, cfg.norm_eps)
        with layer_scope("cross"):
            x = x + attn.cross_attn_apply(params["cross"], h, enc, cfg)
    h = rmsnorm(params["ln2"], x, cfg.norm_eps)
    with layer_scope("mlp"):
        if spec.kind == "moe":
            h = moe_mod.moe_apply(params["mlp"], h, cfg)
        else:
            h = mlp_apply(params["mlp"], h).astype(x.dtype)
    x = logical_constraint(x + h, ("batch", "seq", None))
    return x, new_cache


def block_numerics_sites(cfg, spec):
    """Relative resolution paths inside one block (every nmatmul call site
    plus the SSM scan's backend lookup) — the probe set the scan-vs-unroll
    decision in :func:`stack_apply` checks a policy against."""
    if spec.kind == "ssm":
        return ("ssm.in_proj", "ssm.out_proj", "ssm.scan")
    sites = []
    if spec.attn == "mla":
        sites += ["attn.wq_a", "attn.wq_b", "attn.wkv_a", "attn.wo"]
    elif spec.attn != "none":
        sites += ["attn.wq", "attn.wk", "attn.wv", "attn.wo"]
    if cfg.encoder_layers:
        sites += ["cross.wq", "cross.wk", "cross.wv", "cross.wo"]
    if spec.kind == "moe":
        # every routed expert resolves its three projections individually
        # (expert multiplicity: one multiplier array instance per expert)
        sites += list(expert_paths(cfg.moe.n_experts, prefix="mlp"))
        if cfg.moe.n_shared:
            sites += ["mlp.shared.wi", "mlp.shared.wg", "mlp.shared.wo"]
    else:
        sites += ["mlp.wi", "mlp.wg", "mlp.wo"]
    return tuple(sites)


def layer_paths(cfg) -> list:
    """All policy paths of the decoder stack (+ encoder + lm_head), in
    execution order — the transformer analogue of
    ``repro.models.resnet.layer_paths``, what ``sweep.auto_configure`` and
    the PPA roll-up (``sweep.policy_area`` / ``policy_ppa``) enumerate.
    MoE blocks contribute one path per routed expert projection, so expert
    multiplicity is carried by the path list itself; the scanned encoder's
    unindexed ``encoder.blocks.*`` sites each stand for
    ``cfg.encoder_layers`` physical layers — pass
    :func:`layer_path_counts` as ``counts=`` to the roll-ups to weight
    them."""
    paths = []
    idx = 0
    for repeats, pattern in cfg.segments:
        for _ in range(repeats):
            for spec in pattern:
                paths += [f"blocks.{idx}.{s}"
                          for s in block_numerics_sites(cfg, spec)]
                idx += 1
    if cfg.encoder_layers:
        enc_cfg = dataclasses.replace(cfg, encoder_layers=0)
        paths += [f"encoder.blocks.{s}"
                  for s in block_numerics_sites(enc_cfg, _enc_spec(cfg))]
    paths.append("lm_head")
    return paths


def layer_path_counts(cfg) -> dict:
    """Instance multiplicity for paths standing for >1 physical layer.

    The whisper-style encoder scans its layers with a single trace, so one
    unindexed ``encoder.blocks.{site}`` path covers ``cfg.encoder_layers``
    multiplier-array instances; every other path (decoder blocks, per-
    expert MoE projections, ``lm_head``) is already enumerated one-to-one
    by :func:`layer_paths`.  Feed this to ``sweep.policy_area`` /
    ``policy_ppa`` and ``hlo_analysis.policy_compute_scale`` as
    ``counts=``."""
    if not cfg.encoder_layers:
        return {}
    enc_cfg = dataclasses.replace(cfg, encoder_layers=0)
    return {f"encoder.blocks.{s}": cfg.encoder_layers
            for s in block_numerics_sites(enc_cfg, _enc_spec(cfg))}


def _segment_scannable(ncfg, cfg, pattern, offset, repeats):
    """True if all repeats of a segment resolve to identical numerics.

    ``jax.lax.scan`` traces its body once, so per-repeat configs can only
    differ if the segment is unrolled; this probe decides which.  Plain
    configs and single-repeat segments are trivially scannable.
    """
    if not is_policy(ncfg):
        return True
    if getattr(ncfg, "force_unroll", False):
        # sensitivity calibration: every repeat must execute eagerly so the
        # operand tap records concrete arrays (see repro.core.sensitivity)
        return False
    if repeats == 1:
        return True
    P = len(pattern)
    for pi, spec in enumerate(pattern):
        for site in block_numerics_sites(cfg, spec):
            resolved = {resolve(ncfg, f"blocks.{offset + r * P + pi}.{site}")
                        for r in range(repeats)}
            if len(resolved) > 1:
                return False
    return True


# ---------------------------------------------------------------------------
# serving-state (cache) construction
# ---------------------------------------------------------------------------

def _block_cache(cfg, spec, batch, max_len, dtype):
    if spec.kind == "ssm":
        return ssm_mod.ssm_cache_init(cfg, batch, dtype)
    if spec.attn == "none":
        return None
    if spec.attn == "mla":
        m = cfg.mla
        return {
            "ckv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
            "kpe": jnp.zeros((batch, max_len, m.rope_head_dim), dtype),
        }
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype),
    }


def init_state(cfg, batch, max_len, dtype=jnp.bfloat16):
    """Serving state: per-block caches (stacked over repeats) + enc_out slot."""
    layers = []
    for repeats, pattern in cfg.segments:
        seg = {}
        for pi, spec in enumerate(pattern):
            c = _block_cache(cfg, spec, batch, max_len, dtype)
            if c is not None:
                seg[pi] = jax.tree.map(
                    lambda a: jnp.broadcast_to(a[None], (repeats,) + a.shape), c
                )
        layers.append(seg)
    state = {"layers": layers}
    if cfg.encoder_layers:
        state["enc_out"] = jnp.zeros((batch, cfg.enc_len, cfg.d_model), dtype)
    return state


def _merge_block_cache(spec, empty, run):
    """Write prefill-produced cache (length S) into the max_len buffer."""
    if spec.kind == "ssm":
        return jax.tree.map(lambda e, r: r.astype(e.dtype), empty, run)

    def write(buf, new, taxis):
        return jax.lax.dynamic_update_slice_in_dim(
            buf, new.astype(buf.dtype), 0, axis=taxis
        )

    out = {}
    for k in empty:
        taxis = empty[k].ndim - (3 if k in ("k", "v") else 2)
        out[k] = write(empty[k], run[k], taxis)
    return out


# ---------------------------------------------------------------------------
# decoder stack
# ---------------------------------------------------------------------------

def _remat(fn, cfg):
    if cfg.remat == "none":
        return fn
    policy = (jax.checkpoint_policies.nothing_saveable if cfg.remat == "full"
              else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn, policy=policy)


def stack_params_init(cfg, key):
    params = {}
    nseg = sum(len(p) for _, p in cfg.segments)
    keys = jax.random.split(key, nseg + 1)
    ki = 0
    for si, (repeats, pattern) in enumerate(cfg.segments):
        for pi, spec in enumerate(pattern):
            k = keys[ki]; ki += 1
            if spec.shared:
                params[f"seg{si}_p{pi}"] = block_init(k, cfg, spec)
            else:
                params[f"seg{si}_p{pi}"] = stack_init(
                    partial(block_init, cfg=cfg, spec=spec), k, repeats
                )
    return params


def stack_apply(params, x, cfg, ncfg=None, positions=None, mode="train",
                caches=None, q_offset=0, causal=True, enc=None):
    """Run all segments.  Returns (x, new_caches list-of-dicts or None).

    Numerics come from the ambient scope (a NumericsConfig — one global
    setting — or a NumericsPolicy): block ``(r, pi)`` of segment ``si``
    resolves under the ``blocks.{global_layer_index}`` layer scope.
    Scanned segments whose repeats resolve to different configs are
    transparently unrolled (each repeat traces its own numerics); segments
    uniform under the policy keep the compile-time-flat scan.  ``ncfg``
    optionally establishes the scope for this call.
    """
    with maybe_numerics_scope(ncfg):
        return _stack_apply(params, x, cfg, positions, mode, caches=caches,
                            q_offset=q_offset, causal=causal, enc=enc)


def _stack_apply(params, x, cfg, positions, mode, caches=None,
                 q_offset=0, causal=True, enc=None):
    ncfg = current_numerics()
    collect = mode != "train"
    new_caches = []
    layer_offset = 0
    for si, (repeats, pattern) in enumerate(cfg.segments):
        P = len(pattern)
        seg_caches = caches[si] if caches is not None else {}
        stacked = {pi: params[f"seg{si}_p{pi}"]
                   for pi, spec in enumerate(pattern) if not spec.shared}
        shared = {pi: params[f"seg{si}_p{pi}"]
                  for pi, spec in enumerate(pattern) if spec.shared}

        def seg_body_at(base, x, layer_params, layer_caches,
                        _pattern=pattern, _shared=shared):
            out_caches = {}
            for pi, spec in enumerate(_pattern):
                p = _shared[pi] if spec.shared else layer_params[pi]
                c = layer_caches.get(pi)
                with layer_scope(f"blocks.{base + pi}"):
                    x, nc = _block_apply(p, x, cfg, spec, positions, mode,
                                         cache=c, q_offset=q_offset,
                                         causal=causal, enc=enc)
                if nc is not None and collect:
                    out_caches[pi] = nc
            return x, out_caches

        take_r = lambda tree, r: jax.tree.map(lambda a: a[r], tree)
        if _segment_scannable(ncfg, cfg, pattern, layer_offset, repeats):
            # uniform numerics across repeats: scan (paths resolve with the
            # segment's first global index — valid exactly because uniform)
            def seg_body(x, xs, _base=layer_offset):
                layer_params, layer_caches = xs
                return seg_body_at(_base, x, layer_params, layer_caches)

            body = _remat(seg_body, cfg)
            if repeats == 1:
                x, outc = body(x, ({pi: take_r(v, 0) for pi, v in stacked.items()},
                                   {pi: take_r(v, 0) for pi, v in seg_caches.items()}))
                outc = {pi: jax.tree.map(lambda a: a[None], v)
                        for pi, v in outc.items()}
            else:
                x, outc = jax.lax.scan(body, x, (stacked, seg_caches))
        else:
            # heterogeneous policy: unroll so each repeat traces its own
            # numerics; caches re-stack to the scanned layout (leading
            # repeats axis) so prefill/decode consumers see one format.
            # A force_unroll (calibration) policy additionally skips remat —
            # jax.checkpoint traces its body, which would hide operands from
            # the sensitivity tap.
            wrap = ((lambda f: f) if force_unroll_active()
                    else (lambda f: _remat(f, cfg)))
            per_repeat = []
            for r in range(repeats):
                def one_repeat(x, xs, _base=layer_offset + r * P):
                    return seg_body_at(_base, x, xs[0], xs[1])

                x, oc = wrap(one_repeat)(
                    x, ({pi: take_r(v, r) for pi, v in stacked.items()},
                        {pi: take_r(v, r) for pi, v in seg_caches.items()}))
                per_repeat.append(oc)
            outc = {pi: jax.tree.map(lambda *a: jnp.stack(a),
                                     *[oc[pi] for oc in per_repeat])
                    for pi in (per_repeat[0] if per_repeat else {})}
        new_caches.append(outc if collect else {})
        layer_offset += repeats * P
    return x, (new_caches if collect else None)


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------

def init(cfg, key):
    k_emb, k_stack, k_head, k_enc = jax.random.split(key, 4)
    params = {
        "embed": embed_init(k_emb, cfg.vocab, cfg.d_model),
        "final_norm": rmsnorm_init(cfg.d_model),
        **stack_params_init(cfg, k_stack),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(k_head, cfg.d_model, cfg.vocab,
                                       ("embed_table", "vocab"))
    if cfg.encoder_layers:
        params["encoder"] = encoder_init(cfg, k_enc)
    return params


def _positions_for(cfg, batch, B, S, offset=0):
    if "positions" in batch:
        return batch["positions"]
    off = jnp.asarray(offset, jnp.int32)
    if off.ndim:
        # per-row decode offsets (continuous batching): each request sits
        # at its own absolute position
        off = off[:, None]
    pos = jnp.arange(S, dtype=jnp.int32)[None, :] + off
    pos = jnp.broadcast_to(pos, (B, S))
    if cfg.mrope_sections is not None:
        pos = jnp.broadcast_to(pos[..., None], (B, S, 3))
    return pos


def _embed_inputs(params, cfg, batch):
    if "embeds" in batch:
        x = batch["embeds"].astype(jnp.dtype(cfg.dtype))
    else:
        x = embed_lookup(params["embed"], batch["tokens"]).astype(jnp.dtype(cfg.dtype))
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return logical_constraint(x, ("batch", "seq", None))


def backbone(params, cfg, batch, mode, caches=None, q_offset=0, enc=None):
    """Embeds -> (encoder) -> decoder stack -> final norm.

    Establishes the numerics scope from ``cfg.numerics`` — everything
    below resolves ambiently (``repro.numerics``)."""
    with numerics_scope(cfg.numerics):
        x = _embed_inputs(params, cfg, batch)
        B, S = x.shape[:2]
        positions = _positions_for(cfg, batch, B, S, offset=q_offset)
        if cfg.encoder_layers and enc is None:
            enc = encoder_apply(params["encoder"], cfg, batch)
        x, new_caches = _stack_apply(params, x, cfg, positions, mode,
                                     caches=caches, q_offset=q_offset, enc=enc)
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        return x, new_caches, enc


def logits_fn(params, cfg, hidden):
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    if is_policy(cfg.numerics):
        # the unembedding participates in per-layer policies as ``lm_head``
        # (a policy default of exact/bf16 reproduces the legacy head)
        with numerics_scope(cfg.numerics), layer_scope("lm_head"):
            logits = nmatmul(hidden, w)
    else:
        logits = jax.lax.dot_general(
            hidden.astype(jnp.bfloat16), w.astype(jnp.bfloat16),
            (((hidden.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
    if cfg.tie_embeddings:
        # the tied table has unit-variance rows (embed_init scale=1.0), so
        # match the untied head's d**-0.5 init: logits start at unit scale
        # instead of sqrt(d_model) (which stalls early training)
        logits = logits * (cfg.d_model ** -0.5)
    logits = logical_constraint(logits, ("batch", "seq", "vocab"))
    return softcap(logits, cfg.logit_softcap)


def loss_fn(params, cfg, batch, batch_chunks: int | None = None):
    """Causal-LM cross-entropy, chunked over the BATCH dim.

    Chunking over batch (not sequence) preserves the activations' sharding
    under GSPMD — a (B,S,·)->(B,nc,c,·) sequence reshape would break the
    'seq' sharding and replicate fp32 logits on every chip.  Each chunk is
    rematerialized so the backward pass recomputes its logits instead of
    checkpointing (B_c, S, V).
    """
    hidden, _, _ = backbone(params, cfg, batch, mode="train")
    targets = batch["targets"]
    B, S = targets.shape
    if batch_chunks is None:
        batch_chunks = cfg.loss_batch_chunks
    nb = batch_chunks if B % batch_chunks == 0 else 1
    hid = hidden.reshape(nb, B // nb, S, hidden.shape[-1])
    tgt = targets.reshape(nb, B // nb, S)

    def chunk_loss(carry, xs):
        h, t = xs
        lg = logits_fn(params, cfg, h)
        lse = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, jnp.maximum(t, 0)[..., None], axis=-1)[..., 0]
        valid = (t >= 0).astype(jnp.float32)
        nll = (lse - gold) * valid
        loss, count = carry
        return (loss + nll.sum(), count + valid.sum()), None

    body = jax.checkpoint(chunk_loss, policy=jax.checkpoint_policies.nothing_saveable)
    (tot, cnt), _ = jax.lax.scan(body, (0.0, 0.0), (hid, tgt))
    return tot / jnp.maximum(cnt, 1.0)


def prefill(params, cfg, batch, max_len=None):
    """Process the prompt; returns (last-token logits, serving state)."""
    ref = batch["tokens"] if "tokens" in batch else batch["embeds"]
    B, S = ref.shape[0], ref.shape[1]
    max_len = max_len or S
    hidden, run_caches, enc = backbone(params, cfg, batch, mode="prefill")
    state = init_state(cfg, B, max_len, dtype=jnp.dtype(cfg.dtype))
    merged = []
    for (repeats, pattern), empty_seg, run_seg in zip(cfg.segments,
                                                      state["layers"], run_caches):
        seg = {}
        for pi in empty_seg:
            seg[pi] = _merge_block_cache(pattern[pi], empty_seg[pi], run_seg[pi])
        merged.append(seg)
    state["layers"] = merged
    if enc is not None:
        state["enc_out"] = enc.astype(jnp.dtype(cfg.dtype))
    return logits_fn(params, cfg, hidden[:, -1:]), state


def decode_step(params, cfg, batch, state, pos):
    """One decode step: batch['token'] (B,1) int32; pos = absolute position.

    ``pos`` is a scalar when the whole batch decodes in lockstep
    (``Session.generate``) or a ``(B,)`` int32 vector when each row sits at
    its own position (the continuous-batching engine of
    ``repro.serving``); rope, cache writes and attention masks all follow
    the per-row positions."""
    enc = state.get("enc_out")
    hidden, new_layers, _ = backbone(
        params, cfg, {"tokens": batch["token"]},
        mode="decode", caches=state["layers"], q_offset=pos, enc=enc,
    )
    new_state = dict(state)
    new_state["layers"] = new_layers
    return logits_fn(params, cfg, hidden), new_state


# ---------------------------------------------------------------------------
# whisper-style encoder
# ---------------------------------------------------------------------------

def _enc_spec(cfg):
    return dataclasses.replace(cfg.segments[0][1][0], kind="dense", attn="global")


def encoder_init(cfg, key):
    spec = _enc_spec(cfg)
    enc_cfg = dataclasses.replace(cfg, encoder_layers=0)  # no cross in encoder
    ks = jax.random.split(key, 2)
    return {
        "blocks": stack_init(partial(block_init, cfg=enc_cfg, spec=spec),
                             ks[0], cfg.encoder_layers),
        "norm": rmsnorm_init(cfg.d_model),
    }


def encoder_apply(params, cfg, batch, ncfg=None):
    with maybe_numerics_scope(ncfg):
        x = batch["enc_embeds"].astype(jnp.dtype(cfg.dtype))
        x = logical_constraint(x, ("batch", "seq", None))
        B, S = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                     (B, S))
        spec = _enc_spec(cfg)

        def body(x, layer_params):
            # encoder layers scan with one trace, so rules cannot
            # distinguish them: all resolve under the unindexed
            # ``encoder.blocks`` prefix
            with layer_scope("encoder.blocks"):
                x, _ = _block_apply(layer_params, x, cfg, spec, positions,
                                    mode="train", causal=False)
            return x, {}

        if force_unroll_active():
            # sensitivity calibration: the scan traces its body once, so
            # the operand tap would never see concrete encoder operands —
            # run each layer eagerly instead (no remat either: checkpoint
            # also traces).  Paths stay the unindexed ``encoder.blocks.*``
            # (matching policy resolution), so the tap records one sample
            # per site with ``calls == cfg.encoder_layers``.
            for r in range(cfg.encoder_layers):
                x, _ = body(x, jax.tree.map(lambda a: a[r], params["blocks"]))
        else:
            x, _ = jax.lax.scan(_remat(body, cfg), x, params["blocks"])
        return rmsnorm(params["norm"], x, cfg.norm_eps)
