"""Public numerics API — context-scoped accuracy configuration.

The paper's pitch is compiler-integrated accuracy configuration: the
multiplier precision of a *region* of the program is ambient state, not
an argument to every matmul.  This module is the one public surface for
that:

>>> from repro.numerics import NumericsConfig, numerics_scope, layer_scope, nmatmul
>>> seg1 = NumericsConfig(mode="segmented", seg_passes=1, backend="xla")
>>> with numerics_scope(seg1):
...     y = nmatmul(x, w)                 # runs under the ambient config

Per-layer policies resolve against the full path of the nested
``layer_scope`` stack:

>>> pol = NumericsPolicy((("blocks.*.mlp.*", seg1),))
>>> with numerics_scope(pol), layer_scope("blocks.3"), layer_scope("mlp"):
...     with layer_scope("wi"):
...         h = nmatmul(x, w)             # resolves blocks.3.mlp.wi -> seg1

Scopes are trace-time constructs — safe under ``jax.jit``, ``lax.scan``
and ``vmap``, but NOT part of a jit cache key: a function jitted under
one scope and re-invoked under another replays the first trace's
numerics (jit per scope, or close the jitted function over the config —
see ``repro.core.scope``).  The model zoo establishes scopes internally
from ``cfg.numerics``; end users normally go through
:class:`repro.session.Session` and never touch a matmul.

The legacy explicit form ``nmatmul(x, w, cfg, path=...)`` keeps working
for one release behind a DeprecationWarning.
"""
from __future__ import annotations

from repro.core.numerics import (BACKENDS, EXACT, NumericsConfig,
                                 apply_elementwise, nmatmul,
                                 operand_tap_active, segmented_matmul_xla,
                                 set_operand_tap)
from repro.core.numerics import _DEPRECATED_SITES as _DEPRECATED_SITES
from repro.core.policy import (Numerics, NumericsPolicy, PolicyRule,
                               ScopedPolicy, expert_paths, is_policy, resolve,
                               scoped)
from repro.core.scope import (ambient_view, current_numerics, current_path,
                              force_unroll_active, layer_scope,
                              maybe_numerics_scope, numerics_scope,
                              resolve_here)

__all__ = [
    "BACKENDS",
    "EXACT",
    "Numerics",
    "NumericsConfig",
    "NumericsPolicy",
    "PolicyRule",
    "ScopedPolicy",
    "ambient_view",
    "apply_elementwise",
    "current_numerics",
    "current_path",
    "expert_paths",
    "force_unroll_active",
    "is_policy",
    "layer_scope",
    "maybe_numerics_scope",
    "nmatmul",
    "numerics_scope",
    "operand_tap_active",
    "reset_deprecation_registry",
    "resolve",
    "resolve_here",
    "scoped",
    "segmented_matmul_xla",
    "set_operand_tap",
]


def reset_deprecation_registry() -> None:
    """Forget which call sites already emitted the nmatmul deprecation
    warning (each site warns once per process; tests use this)."""
    _DEPRECATED_SITES.clear()
