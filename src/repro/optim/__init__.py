"""Optimizers: AdamW (dtype-configurable moments), schedules, clipping,
int8 gradient compression with error feedback."""
from . import adamw, compression
from .adamw import AdamWConfig, OptState
