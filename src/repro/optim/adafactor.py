"""Adafactor (Shazeer & Stern 2018) — the memory-light optimizer for the
400B/670B-class configs: second moments factored into row/col statistics
(~0 bytes/param for matrices) and no first moment by default, so a 671B
model trains in ~1 extra byte/param of optimizer state instead of Adam's 8.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .adamw import clip_by_global_norm, schedule_lr


@dataclasses.dataclass(frozen=True)
class AdafactorConfig:
    lr: float = 1e-3
    beta2: float = 0.999
    eps: float = 1e-30
    clip_threshold: float = 1.0      # update RMS clipping (Adafactor d)
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    schedule: str = "cosine"
    warmup_steps: int = 100
    total_steps: int = 10000
    min_dim_factored: int = 128      # factor only dims >= this

    # mirror AdamWConfig's interface bits used by steps/dryrun
    moment_dtype: str = "float32"


class FactoredMoment(NamedTuple):
    row: jnp.ndarray    # mean of g^2 over the last axis
    col: jnp.ndarray    # mean of g^2 over the second-to-last axis
    full: jnp.ndarray   # used when not factored (shape of param or (0,))


class AdafactorState(NamedTuple):
    step: jnp.ndarray
    v: dict  # pytree of FactoredMoment


def _factored(p, cfg) -> bool:
    return (p.ndim >= 2 and p.shape[-1] >= cfg.min_dim_factored
            and p.shape[-2] >= cfg.min_dim_factored)


def init(params, cfg: AdafactorConfig) -> AdafactorState:
    def one(p):
        if _factored(p, cfg):
            return FactoredMoment(
                row=jnp.zeros(p.shape[:-1], jnp.float32),
                col=jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                full=jnp.zeros((0,), jnp.float32),
            )
        return FactoredMoment(
            row=jnp.zeros((0,), jnp.float32),
            col=jnp.zeros((0,), jnp.float32),
            full=jnp.zeros(p.shape, jnp.float32),
        )

    return AdafactorState(
        step=jnp.zeros((), jnp.int32),
        v=jax.tree.map(one, params),
    )


def apply_updates(params, grads, state: AdafactorState, cfg: AdafactorConfig):
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = schedule_lr(cfg, step)
    t = step.astype(jnp.float32)
    # increasing-decay beta2 hat (original paper eq. 37-ish)
    beta2t = 1.0 - t ** -0.8
    beta2t = jnp.minimum(beta2t, cfg.beta2)

    def upd(p, g, v: FactoredMoment):
        g32 = g.astype(jnp.float32)
        g2 = g32 * g32 + cfg.eps
        if _factored(p, cfg):
            row = beta2t * v.row + (1 - beta2t) * g2.mean(axis=-1)
            col = beta2t * v.col + (1 - beta2t) * g2.mean(axis=-2)
            # rhat = row/col outer product normalized by row mean
            denom = jnp.sqrt(
                (row / jnp.maximum(row.mean(axis=-1, keepdims=True), cfg.eps))[..., None]
                * col[..., None, :])
            u = g32 / jnp.maximum(denom, cfg.eps)
            new_v = FactoredMoment(row=row, col=col, full=v.full)
        else:
            full = beta2t * v.full + (1 - beta2t) * g2
            u = g32 / jnp.sqrt(jnp.maximum(full, cfg.eps))
            new_v = FactoredMoment(row=v.row, col=v.col, full=full)
        # update clipping: rms(u) <= clip_threshold
        rms = jnp.sqrt(jnp.mean(u * u) + cfg.eps)
        u = u / jnp.maximum(1.0, rms / cfg.clip_threshold)
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (u + cfg.weight_decay * p32)
        return p_new.astype(p.dtype), new_v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_v = jax.tree.leaves(state.v, is_leaf=lambda x: isinstance(x, FactoredMoment))
    out = [upd(p, g, v) for p, g, v in zip(flat_p, flat_g, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[1] for o in out])
    return new_p, AdafactorState(step, new_v), {"grad_norm": gnorm, "lr": lr}
