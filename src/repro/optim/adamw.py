"""AdamW with dtype-configurable moments + gradient clipping + schedules.

Written against plain pytrees (no optax dependency in this container).
``moment_dtype='bfloat16'`` halves optimizer-state HBM for the 400B/670B
configs (stochastic-rounding-free bf16 moments are standard at this scale;
the update math still runs in fp32).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    moment_dtype: str = "float32"
    schedule: str = "cosine"      # constant | cosine | linear_warmup_cosine
    warmup_steps: int = 100
    total_steps: int = 10000


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: dict
    nu: dict


def init(params, cfg: AdamWConfig) -> OptState:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def schedule_lr(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    lr = jnp.asarray(cfg.lr, jnp.float32)
    if cfg.schedule == "constant":
        return lr
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "linear_warmup_cosine" or cfg.schedule == "cosine":
        prog = jnp.clip((step - cfg.warmup_steps) /
                        jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return lr * warm * (0.1 + 0.9 * cos)
    raise ValueError(cfg.schedule)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def apply_updates(params, grads, state: OptState, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = schedule_lr(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    t = step.astype(jnp.float32)
    bc1 = 1 - b1 ** t
    bc2 = 1 - b2 ** t
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (delta + cfg.weight_decay * p32)
        return p_new.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, OptState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}
