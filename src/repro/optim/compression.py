"""Gradient compression with error feedback (DCN-bandwidth reducer).

At 2+ pods the cross-pod all-reduce rides the (slow) data-center network.
int8 block-quantized gradient exchange with error feedback cuts those
bytes 4x at negligible quality cost; the residual (quantization error) is
carried to the next step, which preserves convergence (EF-SGD result).

Used by wrapping the cross-pod reduction:
    g_q, new_err = compress_with_feedback(g, err)
    g_sync = psum(g_q) / npods          # 1 byte/elem on the wire
apply the optimizer with g_sync.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to_block(x):
    n = x.size
    pad = (-n) % BLOCK
    flat = jnp.concatenate([x.reshape(-1), jnp.zeros((pad,), x.dtype)])
    return flat.reshape(-1, BLOCK), n


def quantize_int8(x):
    """Blockwise symmetric int8: returns (q int8, scale f32 per block)."""
    blocks, n = _pad_to_block(x.astype(jnp.float32))
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-12)), -127, 127)
    return q.astype(jnp.int8), scale, n


def dequantize_int8(q, scale, n, shape):
    x = q.astype(jnp.float32) * scale
    return x.reshape(-1)[:n].reshape(shape)


def compress_with_feedback(grad, err):
    """Returns (dequantized-compressed grad, new error residual).

    The returned grad is exactly what the receiving side reconstructs, so
    applying it locally keeps replicas bit-identical; err accumulates what
    compression lost this step.
    """
    g = grad.astype(jnp.float32) + err
    q, scale, n = quantize_int8(g)
    g_hat = dequantize_int8(q, scale, n, grad.shape)
    return g_hat.astype(grad.dtype), (g - g_hat).astype(jnp.float32)


def tree_compress_with_feedback(grads, errs):
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(errs)
    out = [compress_with_feedback(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(treedef, [o[0] for o in out]),
            jax.tree.unflatten(treedef, [o[1] for o in out]))


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
