"""Serving layer: continuous batching over Session with accuracy-tiered SLAs.

>>> from repro.session import Session
>>> from repro.serving import Engine, DEFAULT_TIERS
>>> eng = Engine.from_session(Session("qwen3-4b"), slots=4, max_len=64)
>>> r = eng.submit(prompt, tier="premium", max_new_tokens=16)
>>> eng.run()
>>> r.result()          # bit-identical to a solo Session.generate

Design: ``docs/serving.md``.  Scheduling/queueing in
:mod:`repro.serving.scheduler`, the paged KV cache in
:mod:`repro.serving.kvcache`, the batching loop in
:mod:`repro.serving.engine`.
"""
from repro.serving.engine import (Engine, Event, ModelRunner, TierStats,
                                  TransformerRunner)
from repro.serving.kvcache import (PageAllocator, ServingError, SlotAllocator,
                                   gather_state, paged_layout,
                                   paged_pool_init, pages_for, scatter_chunk,
                                   scatter_token, write_state, zero_pages)
from repro.serving.scheduler import (DEFAULT_TIERS, FakeClock, MonotonicClock,
                                     Request, Scheduler, TierSpec)

__all__ = [
    "DEFAULT_TIERS",
    "Engine",
    "Event",
    "FakeClock",
    "ModelRunner",
    "MonotonicClock",
    "PageAllocator",
    "Request",
    "Scheduler",
    "ServingError",
    "SlotAllocator",
    "TierSpec",
    "TierStats",
    "TransformerRunner",
    "gather_state",
    "paged_layout",
    "paged_pool_init",
    "pages_for",
    "scatter_chunk",
    "scatter_token",
    "write_state",
    "zero_pages",
]
