"""Continuous-batching serving engine with accuracy-tiered SLAs.

One engine serves many concurrent requests over ONE set of resident
weights.  Each accuracy tier (``premium`` exact, ``bulk`` segmented, …)
owns a **lane**: a KV-slot pool (:mod:`repro.serving.kvcache`) plus one
resident compiled ``decode_step`` closed over that tier's
:class:`~repro.core.policy.NumericsPolicy` — the policy is established by
``numerics_scope`` inside ``transformer.backbone``, so routing a request
to a tier is just routing it to a lane.  Per engine step:

1. **admit** — free slots pull queued requests in scheduler order; each
   admitted prompt is prefilled (batch 1) and scattered into its slot,
   producing the request's first token;
2. **decode** — every lane with active requests runs ONE resident
   ``decode_step`` over its whole pool with a per-row position vector
   (new requests join mid-decode, rows past retirement are ignored);
3. **retire** — requests reaching ``max_new_tokens`` free their slot the
   same step, so the next admission reuses it.

Continuous batching never changes a request's numerics: every token is
bit-identical to a solo ``Session.generate`` of the same prompt under the
same policy (the decode path is row-parallel and the per-row position
vector reproduces the solo masks/rope/cache writes exactly — asserted on
the real model in ``tests/test_serving_numerics.py``).

Streaming: ``submit(..., on_token=cb)`` fires ``cb(request, token,
done)`` as tokens land; ``step()`` also returns the step's
:class:`Event` list for poll-style consumers.

The engine is model-agnostic behind the :class:`ModelRunner` duck type,
so the scheduler/batching logic is testable with a pure-Python stub and
no compilation (``tests/serving_sim.py``).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Mapping, Optional, Sequence

import numpy as np

from repro.serving.kvcache import ServingError, SlotAllocator, pool_init, \
    write_slot
from repro.serving.scheduler import (DEFAULT_TIERS, FakeClock, MonotonicClock,
                                     Request, Scheduler, TierSpec)

__all__ = ["Engine", "Event", "ModelRunner", "TransformerRunner",
           "TierStats"]


class ModelRunner:
    """What a lane needs from a model (duck-typed; this class is the
    documentation).  ``n_slots``/``max_len`` size the lane's pool;
    ``prefill(prompt)`` returns ``(first_token, state)`` for a 1-D int32
    prompt; ``write_slot(slot, state)`` installs that state into the
    resident pool; ``decode(tokens, pos)`` advances the WHOLE pool one
    step from per-slot last tokens and absolute positions (both
    ``(n_slots,)`` int32) and returns the per-slot next tokens."""

    n_slots: int
    max_len: int

    def prefill(self, prompt: np.ndarray):
        raise NotImplementedError

    def write_slot(self, slot: int, state) -> None:
        raise NotImplementedError

    def decode(self, tokens: np.ndarray, pos: np.ndarray) -> np.ndarray:
        raise NotImplementedError


class TransformerRunner(ModelRunner):
    """The real lane runner: resident pool + one jitted decode per tier.

    The decode closure is compiled ONCE per lane for the fixed pool shape
    ``(n_slots, max_len)`` and stays resident across the engine's
    lifetime; prefill is jitted per observed prompt length (prompts are
    not padded — padding would change the prefill numerics vs a solo
    run).  The per-length prefill cache is LRU-bounded
    (``prefill_cache_size``, default 32 lengths): under ragged
    production traffic every distinct prompt length would otherwise pin
    a compiled executable forever.  Greedy argmax happens outside the
    jit, mirroring ``Session.generate`` so the token stream is
    bit-comparable.
    """

    #: Default LRU bound on per-prompt-length jitted prefills.
    PREFILL_CACHE_SIZE = 32

    def __init__(self, cfg, params, n_slots: int, max_len: int, *,
                 prefill_cache_size: Optional[int] = None):
        import jax

        from repro.models import transformer

        if cfg.encoder_layers:
            raise ServingError(
                f"{cfg.arch_id}: encoder-decoder archs are not servable by "
                f"the token-only engine (requests carry no encoder inputs)")
        if prefill_cache_size is None:
            prefill_cache_size = self.PREFILL_CACHE_SIZE
        if prefill_cache_size < 1:
            raise ServingError(
                f"prefill_cache_size must be >= 1, got {prefill_cache_size}")
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.pool = pool_init(cfg, n_slots, max_len)
        self._decode = jax.jit(
            lambda p, tok, st, pos: transformer.decode_step(
                p, cfg, {"token": tok}, st, pos))
        # prompt_len -> jitted prefill, LRU order (least recent first)
        self._prefill = collections.OrderedDict()
        self._prefill_cache_size = prefill_cache_size

    def prefill(self, prompt: np.ndarray):
        import jax
        import jax.numpy as jnp

        from repro.models import transformer

        L = int(np.asarray(prompt).shape[-1])
        fn = self._prefill.get(L)
        if fn is None:
            fn = jax.jit(
                lambda p, b: transformer.prefill(p, self.cfg, b,
                                                 max_len=self.max_len))
            self._prefill[L] = fn
            while len(self._prefill) > self._prefill_cache_size:
                self._prefill.popitem(last=False)
        else:
            self._prefill.move_to_end(L)
        logits, state = fn(
            self.params, {"tokens": jnp.asarray(prompt, jnp.int32)[None]})
        token = int(jnp.argmax(logits[:, -1:], axis=-1)[0, 0])
        return token, state

    def write_slot(self, slot: int, state) -> None:
        self.pool = write_slot(self.pool, slot, state)

    def decode(self, tokens: np.ndarray, pos: np.ndarray) -> np.ndarray:
        import jax.numpy as jnp

        logits, self.pool = self._decode(
            self.params, jnp.asarray(tokens, jnp.int32)[:, None], self.pool,
            jnp.asarray(pos, jnp.int32))
        return np.asarray(jnp.argmax(logits[:, -1:], axis=-1), np.int32)[:, 0]


@dataclasses.dataclass(frozen=True)
class Event:
    """One streaming event: ``admit`` (slot granted), ``token`` (one
    generated token, the prefill token included) or ``finish``."""

    kind: str
    request_id: str
    tier: str
    step: int
    time: float
    token: Optional[int] = None


@dataclasses.dataclass
class TierStats:
    n_finished: int = 0
    n_tokens: int = 0
    n_decode_steps: int = 0
    occupancy_sum: int = 0      # active requests summed over decode steps

    @property
    def mean_occupancy(self) -> float:
        return (self.occupancy_sum / self.n_decode_steps
                if self.n_decode_steps else 0.0)


@dataclasses.dataclass
class _Lane:
    spec: TierSpec
    runner: ModelRunner
    alloc: SlotAllocator
    active: dict            # slot -> Request
    stats: TierStats


class Engine:
    """The continuous-batching serving engine (see module docstring)."""

    def __init__(self, runners: Mapping[str, ModelRunner],
                 tiers: Optional[Sequence[TierSpec]] = None,
                 *, clock=None, aging: Optional[float] = None):
        tiers = tuple(tiers) if tiers is not None else tuple(
            TierSpec(name, priority=i)
            for i, name in enumerate(runners))
        by_name = {t.name: t for t in tiers}
        if set(by_name) != set(runners):
            raise ServingError(
                f"tier specs {sorted(by_name)} do not match runners "
                f"{sorted(runners)}")
        self.clock = clock if clock is not None else MonotonicClock()
        self.scheduler = Scheduler(tuple(by_name), aging=aging)
        self._lanes = {
            name: _Lane(spec=by_name[name], runner=runner,
                        alloc=SlotAllocator(runner.n_slots), active={},
                        stats=TierStats())
            for name, runner in runners.items()
        }
        self._step = 0
        self._n_submitted = 0
        self._inflight: dict = {}  # request_id -> Request (queued or active)

    # -- construction -------------------------------------------------------

    @classmethod
    def from_session(cls, session, tiers: Sequence[TierSpec] = DEFAULT_TIERS,
                     *, slots: int = 4, max_len: int = 64, clock=None,
                     aging: Optional[float] = None,
                     prefill_cache: Optional[int] = None) -> "Engine":
        """Build real lanes over a :class:`repro.session.Session`: one
        :class:`TransformerRunner` per tier, every tier's config sharing
        the session's resident params (tier policies go through the same
        coercion as ``Session(policy=...)``).  ``prefill_cache`` bounds
        each lane's per-prompt-length jit cache (default
        :data:`TransformerRunner.PREFILL_CACHE_SIZE`)."""
        runners = {}
        for spec in tiers:
            tier_sess = session.replace(policy=spec.policy)
            runners[spec.name] = TransformerRunner(
                tier_sess.config, session.params, slots, max_len,
                prefill_cache_size=prefill_cache)
        return cls(runners, tiers, clock=clock, aging=aging)

    # -- submission ---------------------------------------------------------

    @property
    def tiers(self) -> tuple:
        return tuple(self._lanes)

    def lane_stats(self) -> dict:
        return {name: lane.stats for name, lane in self._lanes.items()}

    def submit(self, prompt, tier: Optional[str] = None,
               max_new_tokens: int = 16, *, request_id: Optional[str] = None,
               priority: Optional[int] = None, on_token=None,
               eos_id: Optional[int] = None) -> Request:
        """Queue one request; returns the live :class:`Request` handle
        (its ``tokens``/``done`` fields update as the engine steps).

        ``eos_id`` retires the request as soon as it emits that token
        (the EOS is landed as the final token); its KV slot frees the
        same step, so a waiting request can join the next admit pass.
        Early stopping never perturbs co-batched rows — tokens stay
        bit-identical to solo :meth:`repro.session.Session.generate`
        with the same ``eos_id``.
        """
        if tier is None:
            tier = next(iter(self._lanes))
        lane = self._lanes.get(tier)
        if lane is None:
            raise ServingError(f"unknown tier {tier!r}; engine serves "
                               f"{sorted(self._lanes)}")
        rid = request_id or f"r{self._n_submitted}"
        if rid in self._inflight:
            raise ServingError(
                f"request id {rid!r} is already in flight (tier "
                f"{self._inflight[rid].tier!r}); ids must be unique until "
                f"the request finishes")
        req = Request(
            id=rid,
            prompt=prompt,
            max_new_tokens=max_new_tokens,
            tier=tier,
            priority=(priority if priority is not None
                      else lane.spec.priority),
            on_token=on_token,
            eos_id=eos_id,
        )
        self._n_submitted += 1
        need = req.prompt.shape[0] + req.max_new_tokens - 1
        if need > lane.runner.max_len:
            raise ServingError(
                f"request {req.id!r} needs {need} cache positions "
                f"(prompt {req.prompt.shape[0]} + {req.max_new_tokens} new) "
                f"but tier {tier!r} pools max_len={lane.runner.max_len}")
        self._inflight[rid] = req
        return self.scheduler.submit(req, self.clock.now())

    # -- the serving loop ---------------------------------------------------

    def _emit(self, events, req, kind, token=None):
        now = self.clock.now()
        events.append(Event(kind=kind, request_id=req.id, tier=req.tier,
                            step=self._step, time=now, token=token))
        if kind == "token" and req.on_token is not None:
            req.on_token(req, token, req.complete)

    def _land_token(self, events, lane, req, token: int):
        req.tokens.append(int(token))
        lane.stats.n_tokens += 1
        self._emit(events, req, "token", token=int(token))
        # retire on the max-token cap OR the request's EOS stop token
        if req.complete:
            req.finish_time = self.clock.now()
            req.finish_step = self._step
            lane.alloc.free(req.slot)
            del lane.active[req.slot]
            self._inflight.pop(req.id, None)
            lane.stats.n_finished += 1
            self._emit(events, req, "finish")

    def step(self) -> list:
        """One engine step: admit -> decode every lane -> retire.
        Returns the step's events (admissions, tokens, finishes)."""
        self._step += 1
        events = []
        now = self.clock.now()
        for name, lane in self._lanes.items():
            # admit while there is room — new requests join mid-decode
            while (lane.alloc.n_free
                   and self.scheduler.pending(name)):
                req = self.scheduler.pop_next(name, now)
                req.slot = lane.alloc.alloc(req.id)
                req.admit_time = now
                req.admit_step = self._step
                token, state = lane.runner.prefill(req.prompt)
                lane.runner.write_slot(req.slot, state)
                req.pos = req.prompt.shape[0]
                lane.active[req.slot] = req
                self._emit(events, req, "admit")
                self._land_token(events, lane, req, token)
        for name, lane in self._lanes.items():
            if not lane.active:
                continue
            n = lane.runner.n_slots
            tokens = np.zeros(n, np.int32)
            pos = np.zeros(n, np.int32)
            for slot, req in lane.active.items():
                tokens[slot] = req.tokens[-1]
                pos[slot] = req.pos
            nxt = lane.runner.decode(tokens, pos)
            lane.stats.n_decode_steps += 1
            lane.stats.occupancy_sum += len(lane.active)
            # iterate a snapshot: retirement mutates lane.active
            for slot, req in sorted(lane.active.items()):
                req.pos += 1
                self._land_token(events, lane, req, nxt[slot])
        return events

    @property
    def idle(self) -> bool:
        return (self.scheduler.pending() == 0
                and all(not l.active for l in self._lanes.values()))

    def run(self, max_steps: int = 100_000) -> dict:
        """Step until every queued request has finished; returns
        ``lane_stats()``.  ``max_steps`` bounds the drain (a structured
        :class:`ServingError` instead of a hang)."""
        steps = 0
        while not self.idle:
            if steps >= max_steps:
                raise ServingError(
                    f"engine did not drain within {max_steps} steps "
                    f"({self.scheduler.pending()} queued, "
                    f"{sum(len(l.active) for l in self._lanes.values())} "
                    f"active)")
            self.step()
            steps += 1
        return self.lane_stats()
