"""Continuous-batching serving engine with accuracy-tiered SLAs.

One engine serves many concurrent requests over ONE set of resident
weights.  Each accuracy tier (``premium`` exact, ``bulk`` segmented, …)
owns a **lane**: a paged KV pool (:mod:`repro.serving.kvcache`) plus one
resident compiled ``decode_step`` closed over that tier's
:class:`~repro.core.policy.NumericsPolicy` — the policy is established by
``numerics_scope`` inside ``transformer.backbone``, so routing a request
to a tier is just routing it to a lane.  Per engine step:

1. **admit** — a request is admitted when a decode row AND its full
   worst-case page reservation (``prompt + max_new - 1`` positions) are
   both available; admission is head-of-line in scheduler order, so a
   large request is never starved by smaller queue-jumpers.
2. **prefill** — every admitted-but-unprefilled prompt advances ONE
   ``prefill_chunk``-sized chunk (its last chunk lands the first token),
   so a long prompt's prefill interleaves with the lane's decode steps
   instead of stalling them;
3. **decode** — every lane with active requests runs ONE resident
   ``decode_step`` over its whole pool: gather through the per-row page
   tables, step, scatter the new cache rows back (inactive rows scatter
   into the null page);
4. **retire** — requests reaching ``max_new_tokens``/EOS free their row
   and pages the same step; freed pages are re-zeroed before reuse.

Continuous batching never changes a request's numerics: every token is
bit-identical to a solo ``Session.generate`` of the same prompt under the
same policy.  Paging only relocates cache rows (the gathered view holds
the identical bits), and a chunked prefill reproduces the solo prefill's
activations chunk-by-chunk (store-then-read bf16 equals the solo path's
single rounding; positions past the frontier mask to exact-zero softmax
weight) — asserted on the real model in
``tests/test_serving_numerics.py`` and under randomized memory pressure
in ``tests/test_serving_paging.py``.

Streaming: ``submit(..., on_token=cb)`` fires ``cb(request, token,
done)`` as tokens land; ``step()`` also returns the step's
:class:`Event` list for poll-style consumers.

The engine is model-agnostic behind the :class:`ModelRunner` duck type,
so the scheduler/batching/paging logic is testable with a pure-Python
stub and no compilation (``tests/serving_sim.py``).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Mapping, Optional, Sequence

import numpy as np

from repro.serving.kvcache import (PageAllocator, ServingError, SlotAllocator,
                                   pages_for)
from repro.serving.scheduler import (DEFAULT_TIERS, MonotonicClock, Request,
                                     Scheduler, TierSpec)

__all__ = ["Engine", "Event", "ModelRunner", "TransformerRunner",
           "TierStats"]


class ModelRunner:
    """What a lane needs from a model (duck-typed; this class is the
    documentation).

    Sizing: ``n_slots`` decode rows (the batch axis of the resident
    decode), ``max_len`` the per-request position cap, ``page_size``
    tokens per KV page, ``n_pages`` physical pages in the lane's pool
    (page id ``n_pages`` is the null page), ``prefill_chunk`` tokens per
    prefill chunk, and ``chunked`` False when the arch's recurrent state
    forces whole-prompt prefill (:meth:`prefill_full`).

    All page tables are int32 vectors of physical page ids, null-filled
    (``n_pages``) past the request's allocation; ``tables`` in
    :meth:`decode` stacks one per row, ``(n_slots, max_pages)``.
    """

    n_slots: int
    max_len: int
    page_size: int
    n_pages: int
    prefill_chunk: int
    chunked: bool = True

    @property
    def max_pages(self) -> int:
        """Longest page table a single request can need."""
        return pages_for(self.max_len, self.page_size)

    def pages_for(self, n_positions: int) -> int:
        return pages_for(n_positions, self.page_size)

    def prefill_chunk_step(self, prompt, start: int, end: int, table_row):
        """Prefill prompt positions ``[start, end)`` into the pages of
        ``table_row``; returns the first generated token when ``end``
        completes the prompt, else None."""
        raise NotImplementedError

    def prefill_full(self, slot: int, prompt, table_row):
        """Whole-prompt fallback (archs with non-paged recurrent state):
        prefill the full prompt, install it into ``table_row``'s pages +
        per-slot row ``slot``, return the first token."""
        raise NotImplementedError

    def decode(self, tokens, pos, tables):
        """Advance the WHOLE pool one step from per-row last tokens and
        absolute positions (``(n_slots,)`` int32) through per-row page
        tables; returns the per-row next tokens."""
        raise NotImplementedError

    def zero_pages(self, pages) -> None:
        """Re-zero freed physical pages before they can be reused."""
        raise NotImplementedError


class TransformerRunner(ModelRunner):
    """The real lane runner: resident paged pool + one jitted decode per
    tier.

    The decode closure (gather pages -> ``decode_step`` -> scatter the
    new rows back) is compiled ONCE per lane for the fixed pool shape and
    stays resident; prefill compiles per CHUNK shape, not per prompt
    length — ragged production traffic shares ``ceil(max_len /
    prefill_chunk)``-ish chunk shapes instead of pinning one executable
    per observed length.  The chunk-shape cache is still LRU-bounded
    (``prefill_cache_size``) and each entry owns a private ``jax.jit``
    wrapper, so eviction actually releases the compiled executable.
    Greedy argmax happens outside the jit, mirroring ``Session.generate``
    so the token stream is bit-comparable.

    Archs with SSM/conv blocks keep a per-slot recurrent state that
    cannot be re-entered chunk-by-chunk without changing scan numerics,
    so they fall back to whole-prompt prefill (``chunked`` False; the
    compiled-prefill cache is then keyed per prompt length as before).
    """

    #: Default LRU bound on jitted prefill shapes (chunk shapes, plus
    #: whole-prompt lengths for non-chunkable archs).
    PREFILL_CACHE_SIZE = 32
    #: Default tokens per KV page.
    PAGE_SIZE = 16
    #: Default tokens prefilled per engine step per request.
    PREFILL_CHUNK = 32

    def __init__(self, cfg, params, n_slots: int, max_len: int, *,
                 page_size: Optional[int] = None,
                 pages: Optional[int] = None,
                 prefill_chunk: Optional[int] = None,
                 prefill_cache_size: Optional[int] = None):
        import jax

        from repro.models import transformer
        from repro.serving import kvcache

        if cfg.encoder_layers:
            raise ServingError(
                f"{cfg.arch_id}: encoder-decoder archs are not servable by "
                f"the token-only engine (requests carry no encoder inputs)")
        if prefill_cache_size is None:
            prefill_cache_size = self.PREFILL_CACHE_SIZE
        if prefill_cache_size < 1:
            raise ServingError(
                f"prefill_cache_size must be >= 1, got {prefill_cache_size}")
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.page_size = int(page_size or self.PAGE_SIZE)
        self.prefill_chunk = int(prefill_chunk or self.PREFILL_CHUNK)
        if self.page_size < 1:
            raise ServingError(
                f"page_size must be >= 1, got {self.page_size}")
        if self.prefill_chunk < 1:
            raise ServingError(
                f"prefill_chunk must be >= 1, got {self.prefill_chunk}")
        # default pool: capacity parity with the old whole-max_len slots
        self.n_pages = int(pages if pages is not None
                           else n_slots * self.max_pages)
        self._layout = kvcache.paged_layout(cfg)
        self.pool = kvcache.paged_pool_init(cfg, n_slots, self.n_pages,
                                            self.page_size)
        # chunked prefill re-enters decode_step per chunk, which only the
        # sequence-axis (paged) caches support; any per-slot recurrent
        # leaf forces the whole-prompt fallback
        self.chunked = all(pi in self._layout[si]
                           for si, seg in enumerate(self.pool["layers"])
                           for pi in seg)
        ps = self.page_size

        def _decode(p, tok, pool, tables, pos):
            dense = kvcache.gather_state(pool, self._layout, tables)
            logits, new = transformer.decode_step(p, cfg, {"token": tok},
                                                  dense, pos)
            pool = kvcache.scatter_token(pool, self._layout, new, tables,
                                         pos, ps)
            return logits, pool

        self._decode = jax.jit(_decode)
        # compile-shape key -> private jitted fn, LRU order (LRU first);
        # keys: ("chunk", chunk_len) / ("full", prompt_len)
        self._prefill = collections.OrderedDict()
        self._prefill_cache_size = prefill_cache_size

    # -- compiled-shape LRU --------------------------------------------------

    def _jitted(self, key, make):
        fn = self._prefill.get(key)
        if fn is None:
            fn = make()
            self._prefill[key] = fn
            while len(self._prefill) > self._prefill_cache_size:
                self._prefill.popitem(last=False)
        else:
            self._prefill.move_to_end(key)
        return fn

    # -- ModelRunner protocol ------------------------------------------------

    def prefill_chunk_step(self, prompt, start: int, end: int, table_row):
        import jax
        import jax.numpy as jnp

        from repro.models import transformer
        from repro.serving import kvcache

        prompt = np.asarray(prompt, np.int32)
        c = int(end) - int(start)
        ps = self.page_size

        def make():
            def _chunk(p, tok, pool, trow, off):
                dense = kvcache.gather_state(pool, self._layout, trow[None])
                logits, new = transformer.decode_step(
                    p, self.cfg, {"token": tok}, dense, off)
                pool = kvcache.scatter_chunk(pool, self._layout, new, trow,
                                             off, c, ps)
                return logits, pool

            return jax.jit(_chunk)

        fn = self._jitted(("chunk", c), make)
        logits, self.pool = fn(
            self.params, jnp.asarray(prompt[start:end])[None], self.pool,
            jnp.asarray(table_row, jnp.int32), jnp.asarray(start, jnp.int32))
        if int(end) == prompt.shape[0]:
            return int(jnp.argmax(logits[:, -1:], axis=-1)[0, 0])
        return None

    def prefill_full(self, slot: int, prompt, table_row):
        import jax
        import jax.numpy as jnp

        from repro.models import transformer
        from repro.serving import kvcache

        prompt = np.asarray(prompt, np.int32)
        L = int(prompt.shape[0])
        # buffer exactly the pages the prompt occupies: write_state
        # scatters every buffered position, so the buffer must not
        # overrun the live page-table entries
        ml = self.pages_for(L) * self.page_size
        ps = self.page_size

        def make():
            def _full(p, tokens, pool, trow, sl):
                logits, state = transformer.prefill(
                    p, self.cfg, {"tokens": tokens}, max_len=ml)
                pool = kvcache.write_state(pool, self._layout, state, sl,
                                           trow, ps)
                return logits, pool

            return jax.jit(_full)

        fn = self._jitted(("full", L), make)
        logits, self.pool = fn(
            self.params, jnp.asarray(prompt)[None], self.pool,
            jnp.asarray(table_row, jnp.int32), jnp.asarray(slot, jnp.int32))
        return int(jnp.argmax(logits[:, -1:], axis=-1)[0, 0])

    def decode(self, tokens, pos, tables):
        import jax.numpy as jnp

        logits, self.pool = self._decode(
            self.params, jnp.asarray(tokens, jnp.int32)[:, None], self.pool,
            jnp.asarray(tables, jnp.int32), jnp.asarray(pos, jnp.int32))
        return np.asarray(jnp.argmax(logits[:, -1:], axis=-1), np.int32)[:, 0]

    def zero_pages(self, pages) -> None:
        from repro.serving import kvcache

        if len(pages) == 0:
            return
        self.pool = kvcache.zero_pages(self.pool, self._layout,
                                       np.asarray(pages, np.int32))


@dataclasses.dataclass(frozen=True)
class Event:
    """One streaming event: ``admit`` (row + page reservation granted),
    ``token`` (one generated token, the prefill token included) or
    ``finish``."""

    kind: str
    request_id: str
    tier: str
    step: int
    time: float
    token: Optional[int] = None


@dataclasses.dataclass
class TierStats:
    n_finished: int = 0
    n_tokens: int = 0
    n_decode_steps: int = 0
    occupancy_sum: int = 0      # active requests summed over decode steps
    n_prefill_chunks: int = 0   # prefill calls (chunks, or whole prompts)
    pages_reserved_sum: int = 0  # reserved pages summed over retired requests
    # steps that ran prefill chunks WHILE this lane also decoded — the
    # interleave chunked prefill exists to provide
    n_interleave_steps: int = 0
    # steps where active decoders stalled with no decode batch (must stay
    # 0: chunked prefill never preempts a lane's decode)
    n_decode_stall_steps: int = 0

    @property
    def mean_occupancy(self) -> float:
        return (self.occupancy_sum / self.n_decode_steps
                if self.n_decode_steps else 0.0)

    @property
    def pages_per_request(self) -> float:
        """Mean KV pages reserved per retired request — the paged pool's
        footprint metric (a whole-``max_len`` slot design pins
        ``max_pages`` for every request)."""
        return (self.pages_reserved_sum / self.n_finished
                if self.n_finished else 0.0)


@dataclasses.dataclass
class _Lane:
    spec: TierSpec
    runner: ModelRunner
    alloc: SlotAllocator        # decode rows (cheap, no KV storage)
    pages: PageAllocator        # KV pages (the real capacity)
    active: dict                # slot -> Request (decoding)
    prefilling: dict            # slot -> Request (admitted, prompt pending)
    stats: TierStats


class Engine:
    """The continuous-batching serving engine (see module docstring)."""

    def __init__(self, runners: Mapping[str, ModelRunner],
                 tiers: Optional[Sequence[TierSpec]] = None,
                 *, clock=None, aging: Optional[float] = None):
        tiers = tuple(tiers) if tiers is not None else tuple(
            TierSpec(name, priority=i)
            for i, name in enumerate(runners))
        by_name = {t.name: t for t in tiers}
        if set(by_name) != set(runners):
            raise ServingError(
                f"tier specs {sorted(by_name)} do not match runners "
                f"{sorted(runners)}")
        self.clock = clock if clock is not None else MonotonicClock()
        self.scheduler = Scheduler(tuple(by_name), aging=aging)
        self._lanes = {
            name: _Lane(spec=by_name[name], runner=runner,
                        alloc=SlotAllocator(runner.n_slots),
                        pages=PageAllocator(runner.n_pages),
                        active={}, prefilling={}, stats=TierStats())
            for name, runner in runners.items()
        }
        self._step = 0
        self._n_submitted = 0
        self._inflight: dict = {}  # request_id -> Request (queued or active)

    # -- construction -------------------------------------------------------

    @classmethod
    def from_session(cls, session, tiers: Sequence[TierSpec] = DEFAULT_TIERS,
                     *, slots: int = 4, max_len: int = 64,
                     page_size: Optional[int] = None,
                     pages: Optional[int] = None,
                     prefill_chunk: Optional[int] = None, clock=None,
                     aging: Optional[float] = None,
                     prefill_cache: Optional[int] = None) -> "Engine":
        """Build real lanes over a :class:`repro.session.Session`: one
        :class:`TransformerRunner` per tier, every tier's config sharing
        the session's resident params (tier policies go through the same
        coercion as ``Session(policy=...)``).

        ``page_size`` (default :data:`TransformerRunner.PAGE_SIZE`) sets
        the KV page granularity and ``pages`` the per-tier physical pool
        (default: ``slots * ceil(max_len / page_size)``, capacity parity
        with whole-``max_len`` slots); ``prefill_chunk`` (default
        :data:`TransformerRunner.PREFILL_CHUNK`) bounds the prompt tokens
        prefilled per engine step; ``prefill_cache`` bounds each lane's
        compiled-prefill-shape cache (LRU, default
        :data:`TransformerRunner.PREFILL_CACHE_SIZE`)."""
        runners = {}
        for spec in tiers:
            tier_sess = session.replace(policy=spec.policy)
            runners[spec.name] = TransformerRunner(
                tier_sess.config, session.params, slots, max_len,
                page_size=page_size, pages=pages,
                prefill_chunk=prefill_chunk,
                prefill_cache_size=prefill_cache)
        return cls(runners, tiers, clock=clock, aging=aging)

    # -- submission ---------------------------------------------------------

    @property
    def tiers(self) -> tuple:
        return tuple(self._lanes)

    def lane_stats(self) -> dict:
        return {name: lane.stats for name, lane in self._lanes.items()}

    def submit(self, prompt, tier: Optional[str] = None,
               max_new_tokens: int = 16, *, request_id: Optional[str] = None,
               priority: Optional[int] = None, on_token=None,
               eos_id: Optional[int] = None) -> Request:
        """Queue one request; returns the live :class:`Request` handle
        (its ``tokens``/``done`` fields update as the engine steps).

        ``eos_id`` retires the request as soon as it emits that token
        (the EOS is landed as the final token); its row and KV pages free
        the same step, so a waiting request can join the next admit pass.
        Early stopping never perturbs co-batched rows — tokens stay
        bit-identical to solo :meth:`repro.session.Session.generate`
        with the same ``eos_id``.
        """
        if tier is None:
            tier = next(iter(self._lanes))
        lane = self._lanes.get(tier)
        if lane is None:
            raise ServingError(f"unknown tier {tier!r}; engine serves "
                               f"{sorted(self._lanes)}")
        rid = request_id or f"r{self._n_submitted}"
        if rid in self._inflight:
            raise ServingError(
                f"request id {rid!r} is already in flight (tier "
                f"{self._inflight[rid].tier!r}); ids must be unique until "
                f"the request finishes")
        req = Request(
            id=rid,
            prompt=prompt,
            max_new_tokens=max_new_tokens,
            tier=tier,
            priority=(priority if priority is not None
                      else lane.spec.priority),
            on_token=on_token,
            eos_id=eos_id,
        )
        self._n_submitted += 1
        need = req.prompt.shape[0] + req.max_new_tokens - 1
        if need > lane.runner.max_len:
            raise ServingError(
                f"request {req.id!r} needs {need} cache positions "
                f"(prompt {req.prompt.shape[0]} + {req.max_new_tokens} new) "
                f"but tier {tier!r} pools max_len={lane.runner.max_len}")
        if lane.runner.pages_for(need) > lane.runner.n_pages:
            raise ServingError(
                f"request {req.id!r} needs {lane.runner.pages_for(need)} KV "
                f"pages ({need} positions / page_size "
                f"{lane.runner.page_size}) but tier {tier!r} pools "
                f"{lane.runner.n_pages} pages")
        self._inflight[rid] = req
        return self.scheduler.submit(req, self.clock.now())

    # -- the serving loop ---------------------------------------------------

    def _emit(self, events, req, kind, token=None):
        now = self.clock.now()
        events.append(Event(kind=kind, request_id=req.id, tier=req.tier,
                            step=self._step, time=now, token=token))
        if kind == "token" and req.on_token is not None:
            req.on_token(req, token, req.complete)

    def _land_token(self, events, lane, req, token: int):
        req.tokens.append(int(token))
        lane.stats.n_tokens += 1
        self._emit(events, req, "token", token=int(token))
        # retire on the max-token cap OR the request's EOS stop token
        if req.complete:
            req.finish_time = self.clock.now()
            req.finish_step = self._step
            lane.alloc.free(req.slot)
            del lane.active[req.slot]
            freed = lane.pages.release(req.id)
            lane.runner.zero_pages(freed)
            req.pages = []
            lane.stats.pages_reserved_sum += req.n_reserved_pages
            self._inflight.pop(req.id, None)
            lane.stats.n_finished += 1
            self._emit(events, req, "finish")

    def _grow_pages(self, lane, req, n_positions: int):
        """Take physical pages (lazily, within the admission reservation)
        until ``req``'s table covers ``n_positions`` positions."""
        while len(req.pages) * lane.runner.page_size < n_positions:
            req.pages.append(lane.pages.take_page(req.id))

    def _table_row(self, runner, req):
        row = np.full(runner.max_pages, runner.n_pages, np.int32)
        row[:len(req.pages)] = req.pages
        return row

    def _prefill_one(self, events, lane, req):
        """Advance one request's prefill by one chunk (or the whole
        prompt on non-chunkable archs); lands the first token when the
        prompt completes."""
        runner = lane.runner
        L = req.prompt.shape[0]
        if runner.chunked:
            end = min(req.prefill_pos + runner.prefill_chunk, L)
            self._grow_pages(lane, req, end)
            token = runner.prefill_chunk_step(
                req.prompt, req.prefill_pos, end,
                self._table_row(runner, req))
            req.prefill_pos = end
        else:
            # whole-prompt fallback: the runner buffers pages_for(L)
            # full pages, so cover them all
            self._grow_pages(lane, req, runner.pages_for(L)
                             * runner.page_size)
            token = runner.prefill_full(req.slot, req.prompt,
                                        self._table_row(runner, req))
            req.prefill_pos = L
        lane.stats.n_prefill_chunks += 1
        if token is None:
            return
        del lane.prefilling[req.slot]
        req.pos = L
        lane.active[req.slot] = req
        self._land_token(events, lane, req, token)

    def step(self) -> list:
        """One engine step: admit -> advance prefills one chunk -> decode
        every lane -> retire.  Returns the step's events."""
        self._step += 1
        events = []
        now = self.clock.now()
        ran_chunks = {}
        # decoders live BEFORE this step's prefill work: the interleave /
        # stall accounting is about what chunked prefill does to them
        had_active = {name: bool(lane.active)
                      for name, lane in self._lanes.items()}
        for name, lane in self._lanes.items():
            # admit while a row AND the head request's full page
            # reservation fit — head-of-line, so a big request is never
            # starved by smaller queue-jumpers behind it
            while lane.alloc.n_free and self.scheduler.pending(name):
                head = self.scheduler.peek_next(name, now)
                need = head.prompt.shape[0] + head.max_new_tokens - 1
                n_need = lane.runner.pages_for(need)
                if not lane.pages.can_reserve(n_need):
                    break
                req = self.scheduler.pop_next(name, now)
                lane.pages.reserve(req.id, n_need)
                req.n_reserved_pages = n_need
                req.slot = lane.alloc.alloc(req.id)
                req.admit_time = now
                req.admit_step = self._step
                lane.prefilling[req.slot] = req
                self._emit(events, req, "admit")
            # one prefill chunk per pending prompt, in admission order
            ran_chunks[name] = len(lane.prefilling)
            for req in [lane.prefilling[s] for s in list(lane.prefilling)]:
                self._prefill_one(events, lane, req)
        for name, lane in self._lanes.items():
            if not lane.active:
                # a lane whose decoders got no decode batch this step has
                # stalled — structurally impossible here (prefill chunks
                # never preempt decode), and gated at 0 in the bench
                if had_active[name]:
                    lane.stats.n_decode_stall_steps += 1
                continue
            if ran_chunks[name] and had_active[name]:
                lane.stats.n_interleave_steps += 1
            runner = lane.runner
            n = runner.n_slots
            tokens = np.zeros(n, np.int32)
            pos = np.zeros(n, np.int32)
            tables = np.full((n, runner.max_pages), runner.n_pages, np.int32)
            for slot, req in lane.active.items():
                # this step writes cache position req.pos — make sure a
                # physical page covers it (always within the reservation)
                self._grow_pages(lane, req, req.pos + 1)
                tokens[slot] = req.tokens[-1]
                pos[slot] = req.pos
                tables[slot, :len(req.pages)] = req.pages
            nxt = runner.decode(tokens, pos, tables)
            lane.stats.n_decode_steps += 1
            lane.stats.occupancy_sum += len(lane.active)
            # iterate a snapshot: retirement mutates lane.active
            for slot, req in sorted(lane.active.items()):
                req.pos += 1
                self._land_token(events, lane, req, nxt[slot])
        return events

    @property
    def idle(self) -> bool:
        return (self.scheduler.pending() == 0
                and all(not l.active and not l.prefilling
                        for l in self._lanes.values()))

    def run(self, max_steps: int = 100_000) -> dict:
        """Step until every queued request has finished; returns
        ``lane_stats()``.  ``max_steps`` bounds the drain (a structured
        :class:`ServingError` instead of a hang)."""
        steps = 0
        while not self.idle:
            if steps >= max_steps:
                raise ServingError(
                    f"engine did not drain within {max_steps} steps "
                    f"({self.scheduler.pending()} queued, "
                    f"{sum(len(l.active) + len(l.prefilling) for l in self._lanes.values())} "
                    f"active)")
            self.step()
            steps += 1
        return self.lane_stats()
