"""Pooled KV cache: a slot allocator over a fixed decode pool.

The continuous-batching engine keeps ONE resident serving state per
accuracy tier — the pool — whose batch axis is a fixed set of ``slots``.
A request occupies a slot from admission to retirement; the allocator
(:class:`SlotAllocator`) is plain host-side bookkeeping, so exhaustion is
a structured :class:`ServingError` raised at admission time, never an XLA
shape error mid-step.

The pool pytree is exactly :func:`repro.models.transformer.init_state`
with ``batch = n_slots``, which is what makes it directly consumable by
``transformer.decode_step``: no gather is needed on the decode path —
the whole pool decodes in one resident compiled step and inactive slots
are simply ignored by the engine.  Scatter/gather happens only at the
slot boundary:

- :func:`write_slot` copies a freshly prefilled single-request state
  (batch 1, same ``max_len``) into one slot, overwriting the slot's full
  buffers so nothing leaks from a previous occupant;
- :func:`read_slot` is the inverse view (used by tests and golden
  fixtures to check the round-trip against a dense reference).

Layer-cache leaves are stacked ``(repeats, batch, ...)`` (see
``transformer.init_state``), so their slot axis is 1; the encoder-output
slot (``enc_out``) carries batch at axis 0.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


class ServingError(RuntimeError):
    """A serving-layer error with a one-line message (queue/slot/engine
    misuse) — the serving analogue of ``repro.session.SessionError``."""


@dataclasses.dataclass
class SlotAllocator:
    """Fixed-size slot pool; allocation order is lowest-free-slot-first
    (deterministic, and keeps the active prefix of the pool dense-ish)."""

    n_slots: int

    def __post_init__(self):
        if self.n_slots < 1:
            raise ServingError(
                f"slot pool needs at least 1 slot, got {self.n_slots}")
        self._owner: dict[int, str] = {}

    @property
    def n_free(self) -> int:
        return self.n_slots - len(self._owner)

    @property
    def owners(self) -> dict[int, str]:
        """slot -> request id for every occupied slot (a copy)."""
        return dict(self._owner)

    def alloc(self, request_id: str) -> int:
        """Claim the lowest free slot for ``request_id``; raises
        :class:`ServingError` when the pool is exhausted."""
        for slot in range(self.n_slots):
            if slot not in self._owner:
                self._owner[slot] = request_id
                return slot
        raise ServingError(
            f"KV pool exhausted: all {self.n_slots} slots in use "
            f"(admitting {request_id!r}); retire a request or grow the pool")

    def free(self, slot: int) -> None:
        if slot not in self._owner:
            raise ServingError(f"slot {slot} is not allocated")
        del self._owner[slot]

    def owner(self, slot: int) -> Optional[str]:
        return self._owner.get(slot)


# ---------------------------------------------------------------------------
# pool pytree scatter/gather (transformer serving state)
# ---------------------------------------------------------------------------

def pool_init(cfg, n_slots: int, max_len: int, dtype=None):
    """The resident decode pool: ``transformer.init_state`` with the slot
    set as the batch axis."""
    import jax.numpy as jnp

    from repro.models import transformer

    return transformer.init_state(cfg, n_slots, max_len,
                                  dtype=jnp.dtype(dtype or cfg.dtype))


def _leaf_write(pool_leaf, req_leaf, slot: int, axis: int):
    import jax.numpy as jnp

    src = jnp.take(req_leaf, 0, axis=axis).astype(pool_leaf.dtype)
    return pool_leaf.at[(slice(None),) * axis + (slot,)].set(src)


def write_slot(pool, slot: int, state):
    """Copy a single-request serving state (batch 1, same ``max_len``)
    into ``slot`` of the pool.  The FULL slot buffer is overwritten — a
    prefilled state's tail past the prompt is zeros, so a reused slot
    carries no bits from its previous occupant."""
    import jax

    out = dict(pool)
    out["layers"] = [
        {pi: jax.tree.map(lambda p, r: _leaf_write(p, r, slot, 1),
                          pool_seg[pi], state_seg[pi])
         for pi in pool_seg}
        for pool_seg, state_seg in zip(pool["layers"], state["layers"])
    ]
    if "enc_out" in pool:
        out["enc_out"] = _leaf_write(pool["enc_out"], state["enc_out"],
                                     slot, 0)
    return out


def read_slot(pool, slot: int):
    """The batch-1 serving-state view of one slot (gather; the inverse of
    :func:`write_slot`)."""
    import jax

    out = dict(pool)
    out["layers"] = [
        {pi: jax.tree.map(lambda p: p[:, slot:slot + 1], seg[pi])
         for pi in seg}
        for seg in pool["layers"]
    ]
    if "enc_out" in pool:
        out["enc_out"] = pool["enc_out"][slot:slot + 1]
    return out
