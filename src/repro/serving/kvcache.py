"""Paged KV cache: fixed-size page pool + page-table scatter/gather.

The continuous-batching engine keeps ONE resident serving state per
accuracy tier — the pool.  Attention-cache leaves are stored as a pool of
fixed-size **pages** of ``page_size`` token positions each, and every
request holds a *page table* (a vector of physical page ids) instead of a
whole-``max_len`` contiguous slot: a 30-token request in a 4096-max_len
tier reserves ``ceil(30/page_size)`` pages, not 4096 rows.

Layout
------
Paged leaves are shaped ``(repeats, n_pages + 1, page_size, ...)`` — the
page id replaces the batch axis of ``transformer.init_state`` and the
sequence axis shrinks to one page.  Physical page ``n_pages`` is the
**null page**: page-table entries past a request's allocation point at
it, and decode scatters for inactive pool rows land in it, so garbage can
never reach a live page.  SSM/conv states carry no sequence axis and stay
per-slot (``(repeats, n_slots, ...)``); :func:`paged_layout` records
which phases page.

Host-side accounting is split over two cheap resources:

- :class:`SlotAllocator` — decode *rows* (the batch axis of the resident
  ``decode_step``); rows are cheap, they carry no KV storage anymore.
- :class:`PageAllocator` — KV *pages*, the real capacity.  A request's
  FULL worst-case need (``prompt + max_new - 1`` positions) is reserved
  at admission; physical pages are taken lazily as the write frontier
  advances.  Reserving up front keeps admission the only failure point —
  a request mid-decode can never hit pool exhaustion.

Device-side, the decode/prefill jits move data across the page boundary:

- :func:`gather_state` assembles the dense ``(rows, max_len)`` view the
  unmodified ``transformer.decode_step`` consumes (``leaf[:, tables]``
  is one XLA gather per leaf);
- :func:`scatter_token` / :func:`scatter_chunk` write the step's freshly
  produced cache rows back through the page tables;
- :func:`write_state` installs a whole prefilled batch-1 state into a
  request's pages (the fallback for archs whose SSM state cannot chunk);
- :func:`zero_pages` re-zeroes freed pages so a reused page carries no
  bits from its previous occupant.

Bit-transparency: paging only *relocates* cache rows; gather returns the
identical values a contiguous buffer would hold, so the decode math — and
therefore the token stream — is bit-identical to solo generation
(asserted in ``tests/test_serving_numerics.py``; the differential stub
rig in ``tests/test_serving_paging.py`` proves the indirection itself).
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import List, Optional


class ServingError(RuntimeError):
    """A serving-layer error with a one-line message (queue/slot/engine
    misuse) — the serving analogue of ``repro.session.SessionError``."""


@dataclasses.dataclass
class SlotAllocator:
    """Fixed-size slot pool; allocation order is lowest-free-slot-first
    (deterministic, and keeps the active prefix of the pool dense-ish)."""

    n_slots: int

    def __post_init__(self):
        if self.n_slots < 1:
            raise ServingError(
                f"slot pool needs at least 1 slot, got {self.n_slots}")
        self._owner: dict[int, str] = {}

    @property
    def n_free(self) -> int:
        return self.n_slots - len(self._owner)

    @property
    def owners(self) -> dict[int, str]:
        """slot -> request id for every occupied slot (a copy)."""
        return dict(self._owner)

    def alloc(self, request_id: str) -> int:
        """Claim the lowest free slot for ``request_id``; raises
        :class:`ServingError` when the pool is exhausted."""
        for slot in range(self.n_slots):
            if slot not in self._owner:
                self._owner[slot] = request_id
                return slot
        raise ServingError(
            f"KV pool exhausted: all {self.n_slots} slots in use "
            f"(admitting {request_id!r}); retire a request or grow the pool")

    def free(self, slot: int) -> None:
        if slot not in self._owner:
            raise ServingError(f"slot {slot} is not allocated")
        del self._owner[slot]

    def owner(self, slot: int) -> Optional[str]:
        return self._owner.get(slot)


@dataclasses.dataclass
class PageAllocator:
    """Reservation-based page accounting (host-side, deterministic).

    ``reserve(rid, n)`` claims *capacity* for a request's full worst-case
    need at admission; ``take_page(rid)`` turns one unit of that
    reservation into a physical page id as the request's write frontier
    reaches it.  Because ``sum(held) <= sum(reserved) <= n_pages`` is an
    invariant, a ``take_page`` within a live reservation can never fail —
    exhaustion is an admission-time decision only.

    Pages are handed out lowest-id-first and returned to a sorted free
    list, so allocation is deterministic under identical schedules (the
    golden/differential tests rely on this).
    """

    n_pages: int

    def __post_init__(self):
        if self.n_pages < 1:
            raise ServingError(
                f"page pool needs at least 1 page, got {self.n_pages}")
        self._free: List[int] = list(range(self.n_pages))
        self._reserved: dict[str, int] = {}   # rid -> reserved page count
        self._held: dict[str, List[int]] = {}  # rid -> physical pages taken

    @property
    def n_free_pages(self) -> int:
        """Physically unallocated pages (>= ``n_unreserved``)."""
        return len(self._free)

    @property
    def n_unreserved(self) -> int:
        """Pages not promised to any live request — what admission has
        left to hand out."""
        return self.n_pages - sum(self._reserved.values())

    @property
    def owners(self) -> dict[int, str]:
        """page -> request id for every physically held page (a copy)."""
        return {p: rid for rid, pages in self._held.items() for p in pages}

    def can_reserve(self, n: int) -> bool:
        return 1 <= n <= self.n_unreserved

    def reserve(self, request_id: str, n: int) -> None:
        if n < 1:
            raise ServingError(
                f"request {request_id!r}: page reservation must be >= 1, "
                f"got {n}")
        if request_id in self._reserved:
            raise ServingError(
                f"request {request_id!r} already holds a page reservation")
        if n > self.n_unreserved:
            raise ServingError(
                f"page pool exhausted: {request_id!r} needs {n} pages but "
                f"only {self.n_unreserved} of {self.n_pages} are unreserved")
        self._reserved[request_id] = n
        self._held[request_id] = []

    def take_page(self, request_id: str) -> int:
        held = self._held.get(request_id)
        if held is None:
            raise ServingError(
                f"request {request_id!r} has no page reservation")
        if len(held) >= self._reserved[request_id]:
            raise ServingError(
                f"request {request_id!r} exceeded its reservation of "
                f"{self._reserved[request_id]} pages")
        if not self._free:  # unreachable while the invariant holds
            raise ServingError("page pool invariant violated: reservation "
                               "honored but no physical page is free")
        page = self._free.pop(0)
        held.append(page)
        return page

    def release(self, request_id: str) -> List[int]:
        """Drop the request's reservation; returns the physical pages it
        held (callers must re-zero them before reuse, see
        :func:`zero_pages`)."""
        if request_id not in self._reserved:
            raise ServingError(
                f"request {request_id!r} has no page reservation")
        pages = self._held.pop(request_id)
        del self._reserved[request_id]
        for p in pages:
            bisect.insort(self._free, p)
        return pages


# ---------------------------------------------------------------------------
# pool pytree scatter/gather (paged transformer serving state)
# ---------------------------------------------------------------------------

def pages_for(n_positions: int, page_size: int) -> int:
    """Pages needed to hold ``n_positions`` cache rows."""
    return -(-int(n_positions) // int(page_size))


def paged_layout(cfg):
    """Which cache phases page: per segment, the frozenset of pattern
    indices whose cache carries a sequence axis (every attention kind).
    SSM/conv states are recurrent — no sequence axis — and stay
    per-slot."""
    return tuple(
        frozenset(pi for pi, spec in enumerate(pattern)
                  if spec.kind != "ssm" and spec.attn != "none")
        for _, pattern in cfg.segments)


def paged_pool_init(cfg, n_slots: int, n_pages: int, page_size: int,
                    dtype=None):
    """The resident paged pool for ``cfg``: attention-cache leaves become
    ``(repeats, n_pages + 1, page_size, ...)`` (index ``n_pages`` is the
    null page), sequence-free leaves (SSM conv/state) stay per-slot
    ``(repeats, n_slots, ...)``."""
    import jax.numpy as jnp

    from repro.models import transformer

    if cfg.encoder_layers:
        raise ServingError(
            f"{cfg.arch_id}: encoder-decoder archs are not servable by the "
            f"token-only paged pool (requests carry no encoder inputs)")
    if page_size < 1:
        raise ServingError(f"page_size must be >= 1, got {page_size}")
    if n_pages < 1:
        raise ServingError(f"page pool needs at least 1 page, got {n_pages}")
    dt = jnp.dtype(dtype or cfg.dtype)
    layout = paged_layout(cfg)
    # templates: one init_state per storage granularity, picked per phase
    paged_tpl = transformer.init_state(cfg, n_pages + 1, page_size, dtype=dt)
    slot_tpl = transformer.init_state(cfg, n_slots, 1, dtype=dt)
    return {"layers": [
        {pi: (pseg[pi] if pi in layout[si] else sseg[pi]) for pi in pseg}
        for si, (pseg, sseg) in enumerate(zip(paged_tpl["layers"],
                                              slot_tpl["layers"]))
    ]}


def _map_pairs(pool, layout, dense, paged_fn, slot_fn):
    """Map ``paged_fn(pool_leaf, dense_leaf)`` over paged phases and
    ``slot_fn`` over per-slot phases, leaf-wise."""
    import jax

    return {"layers": [
        {pi: jax.tree.map(paged_fn if pi in layout[si] else slot_fn,
                          pseg[pi], dseg[pi])
         for pi in pseg}
        for si, (pseg, dseg) in enumerate(zip(pool["layers"],
                                              dense["layers"]))
    ]}


def gather_state(pool, layout, tables):
    """Assemble the dense decode view: for page tables ``(rows,
    max_pages)`` int32 the paged leaves become ``(repeats, rows,
    max_pages * page_size, ...)`` — exactly the contiguous state
    ``transformer.decode_step`` consumes.  Table entries pointing at the
    null page contribute zeros (causally masked away by the decode
    math).  Per-slot leaves pass through untouched (their batch axis IS
    the row set)."""
    import jax

    def g(leaf):
        x = leaf[:, tables]  # (repeats, rows, max_pages, page_size, ...)
        s = x.shape
        return x.reshape(s[0], s[1], s[2] * s[3], *s[4:])

    return {"layers": [
        {pi: (jax.tree.map(g, seg[pi]) if pi in layout[si] else seg[pi])
         for pi in seg}
        for si, seg in enumerate(pool["layers"])
    ]}


def scatter_token(pool, layout, dense, tables, pos, page_size: int):
    """Write one decode step back: for every row, the cache row the step
    produced at ``pos[row]`` of the dense state lands in page
    ``tables[row, pos // page_size]`` at offset ``pos % page_size``.
    Inactive rows carry null page tables, so their (garbage) row lands in
    the null page.  Per-slot leaves are replaced wholesale by the new
    dense leaves (``decode_step`` already advanced them in place)."""
    import jax.numpy as jnp

    pidx = jnp.take_along_axis(tables, (pos // page_size)[:, None],
                               axis=1)[:, 0]
    off = pos % page_size

    def upd(pl, dl):
        idx = pos.reshape((1, -1, 1) + (1,) * (dl.ndim - 3))
        val = jnp.take_along_axis(dl, idx, axis=2)[:, :, 0]
        return pl.at[:, pidx, off].set(val.astype(pl.dtype))

    return _map_pairs(pool, layout, dense, upd, lambda pl, dl: dl)


def scatter_chunk(pool, layout, dense, table_row, start, length: int,
                  page_size: int):
    """Write one prefill chunk back (batch-1 path): dense positions
    ``[start, start + length)`` land through ``table_row`` (one page
    table, ``(max_pages,)`` int32).  ``length`` is static per compiled
    chunk shape; ``start`` may be traced.  Only valid for fully paged
    layouts (chunked prefill is disabled for SSM hybrids)."""
    import jax.lax
    import jax.numpy as jnp

    pvec = start + jnp.arange(length)
    pidx = table_row[pvec // page_size]
    off = pvec % page_size

    def upd(pl, dl):
        val = jax.lax.dynamic_slice_in_dim(dl, start, length, axis=2)[:, 0]
        return pl.at[:, pidx, off].set(val.astype(pl.dtype))

    def slot_leaf(pl, dl):  # unreachable under chunked layouts
        return pl

    return _map_pairs(pool, layout, dense, upd, slot_leaf)


def write_state(pool, layout, state, slot, table_row, page_size: int):
    """Install a whole prefilled batch-1 serving state: paged leaves
    scatter every buffered position ``[0, L_buf)`` through ``table_row``;
    per-slot leaves (SSM conv/state) write row ``slot``.  This is the
    whole-prompt fallback for archs whose recurrent state cannot be
    chunk-prefilled; ``L_buf`` must not exceed the positions covered by
    ``table_row``'s live entries."""
    import jax.numpy as jnp

    def upd(pl, dl):
        n_buf = dl.shape[2]
        pvec = jnp.arange(n_buf)
        return pl.at[:, table_row[pvec // page_size],
                     pvec % page_size].set(dl[:, 0].astype(pl.dtype))

    def srow(pl, dl):
        return pl.at[:, slot].set(dl[:, 0].astype(pl.dtype))

    return _map_pairs(pool, layout, state, upd, srow)


def zero_pages(pool, layout, pages):
    """Re-zero freed pages so the next occupant starts from the same
    all-zeros state a fresh pool would give it — no bits leak across
    requests (the stale-bit property of ``tests/test_serving_paging.py``)."""
    import jax
    import jax.numpy as jnp

    idx = jnp.asarray(pages, jnp.int32)

    def z(leaf):
        return leaf.at[:, idx].set(jnp.zeros((), leaf.dtype))

    return {"layers": [
        {pi: (jax.tree.map(z, seg[pi]) if pi in layout[si] else seg[pi])
         for pi in seg}
        for si, seg in enumerate(pool["layers"])
    ]}
