"""Request queue + admission scheduler with accuracy-tiered SLAs.

The paper's accuracy knob becomes a *traffic* knob here: every request
carries a traffic class, every class maps to an accuracy **tier** (a
:class:`~repro.core.policy.NumericsPolicy` / preset served on the same
resident weights), and admission into a tier's KV-slot pool is ordered by
``(effective priority, arrival order)``:

- priority 0 admits first; ties break by arrival sequence (FIFO);
- **aging** guarantees starvation-freedom under a flood of high-priority
  arrivals: a request that has waited longer than ``aging`` clock units
  is treated as priority 0, so FIFO order among aged requests bounds
  every admitted request's wait by the pool's service rate.

Time comes from an injected clock so the engine is deterministic under
test: :class:`FakeClock` is advanced manually by the simulation rig
(``tests/serving_sim.py``); :class:`MonotonicClock` is the production
default.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.serving.kvcache import ServingError


# ---------------------------------------------------------------------------
# clocks
# ---------------------------------------------------------------------------

class MonotonicClock:
    """Production clock: ``time.monotonic`` seconds."""

    def now(self) -> float:
        return time.monotonic()


class FakeClock:
    """Deterministic manually-advanced clock for the scheduler test rig."""

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ServingError(f"FakeClock cannot go backwards (dt={dt})")
        self._t += dt
        return self._t


# ---------------------------------------------------------------------------
# tiers (traffic class -> accuracy policy)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TierSpec:
    """One accuracy tier: a named traffic class served under ``policy``
    (any ``repro.session`` policy spec — preset name, NumericsPolicy,
    NumericsConfig or policy-JSON path) at admission ``priority``
    (0 = admits first)."""

    name: str
    policy: object = "exact"
    priority: int = 0


#: The default SLA ladder: premium traffic decodes exact, standard under
#: the 3-pass segmented multiplier (AC-like), bulk under 1-pass
#: (ACL-like) — all three on the same resident weights.
DEFAULT_TIERS: tuple = (
    TierSpec("premium", "exact", priority=0),
    TierSpec("standard", "segmented3", priority=1),
    TierSpec("bulk", "segmented1", priority=2),
)


# ---------------------------------------------------------------------------
# requests
# ---------------------------------------------------------------------------

@dataclasses.dataclass(eq=False)
class Request:
    """One generation request plus its mutable serving progress.

    The spec half (id/prompt/max_new_tokens/tier/priority) is set at
    submission; the progress half (tokens/slot/…) is owned by the engine.
    ``tokens`` accumulates the greedy continuation — for a request served
    solo it is bit-identical to ``Session.generate`` of the same prompt
    under the tier's policy (asserted in ``tests/test_serving_numerics``).

    ``eq=False``: requests compare by identity.  The auto-generated
    ``__eq__`` would compare the ``np.ndarray`` prompt field, so two
    queued requests sharing an id made ``Scheduler.pop_next``'s
    ``q.remove(best)`` raise "truth value of an array is ambiguous"
    (duplicate in-flight ids are additionally rejected at
    ``Engine.submit``).
    """

    id: str
    prompt: np.ndarray          # (prompt_len,) int32
    max_new_tokens: int
    tier: str
    priority: int = 0
    on_token: Optional[Callable] = None  # on_token(request, token, done)
    eos_id: Optional[int] = None  # stop token: retire on emitting it
    # -- engine-owned progress ---------------------------------------------
    seq: int = -1               # global arrival sequence number
    arrival_time: float = 0.0
    admit_time: Optional[float] = None
    finish_time: Optional[float] = None
    admit_step: Optional[int] = None
    finish_step: Optional[int] = None
    slot: Optional[int] = None
    pos: int = 0                # next absolute decode position
    tokens: List[int] = dataclasses.field(default_factory=list)
    # -- paged-KV progress (engine-owned) ----------------------------------
    prefill_pos: int = 0        # prompt positions prefilled so far
    pages: List[int] = dataclasses.field(default_factory=list)
    n_reserved_pages: int = 0   # full worst-case reservation at admission

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size == 0:
            raise ServingError(f"request {self.id!r} has an empty prompt")
        if self.max_new_tokens < 1:
            raise ServingError(
                f"request {self.id!r}: max_new_tokens must be >= 1, got "
                f"{self.max_new_tokens}")

    @property
    def done(self) -> bool:
        return self.finish_time is not None

    @property
    def complete(self) -> bool:
        """True once the landed tokens satisfy the stop condition: the
        ``max_new_tokens`` cap, or the ``eos_id`` stop token (the EOS
        itself is the last landed token)."""
        if len(self.tokens) >= self.max_new_tokens:
            return True
        return (self.eos_id is not None and len(self.tokens) > 0
                and self.tokens[-1] == self.eos_id)

    def result(self) -> np.ndarray:
        """The generated continuation, (n,) int32 — ``max_new_tokens``
        long, or shorter when ``eos_id`` stopped it (EOS included)."""
        if not self.done:
            raise ServingError(f"request {self.id!r} is not finished "
                               f"({len(self.tokens)}/{self.max_new_tokens} "
                               f"tokens)")
        return np.asarray(self.tokens, np.int32)


# ---------------------------------------------------------------------------
# the scheduler
# ---------------------------------------------------------------------------

class Scheduler:
    """Per-tier admission queues ordered by (effective priority, arrival).

    ``aging`` (clock units; ``None`` disables) is the starvation bound:
    once ``now - arrival_time >= aging`` a request's effective priority
    becomes 0, so it can no longer be overtaken by fresh high-priority
    arrivals of the same tier.
    """

    def __init__(self, tiers: Sequence[str], aging: Optional[float] = None):
        if not tiers:
            raise ServingError("scheduler needs at least one tier")
        self._queues: dict[str, list[Request]] = {t: [] for t in tiers}
        self.aging = aging
        self._seq = 0

    @property
    def tiers(self) -> tuple:
        return tuple(self._queues)

    def submit(self, req: Request, now: float) -> Request:
        if req.tier not in self._queues:
            raise ServingError(
                f"unknown tier {req.tier!r} for request {req.id!r}; "
                f"expected one of {sorted(self._queues)}")
        req.seq = self._seq
        self._seq += 1
        req.arrival_time = now
        self._queues[req.tier].append(req)
        return req

    def pending(self, tier: Optional[str] = None) -> int:
        if tier is not None:
            return len(self._queues[tier])
        return sum(len(q) for q in self._queues.values())

    def effective_priority(self, req: Request, now: float) -> int:
        if self.aging is not None and now - req.arrival_time >= self.aging:
            return 0
        return req.priority

    def peek_next(self, tier: str, now: float) -> Optional[Request]:
        """The request :meth:`pop_next` would return, without removing it.
        Admission peeks first so a head request whose page reservation
        does not fit yet BLOCKS the queue (head-of-line) instead of being
        popped-and-requeued, which would forfeit its FIFO position."""
        q = self._queues[tier]
        if not q:
            return None
        return min(q, key=lambda r: (self.effective_priority(r, now), r.seq))

    def pop_next(self, tier: str, now: float) -> Optional[Request]:
        """The next request to admit for ``tier`` (or None): lowest
        effective priority first, FIFO (arrival seq) within a priority."""
        best = self.peek_next(tier, now)
        if best is not None:
            self._queues[tier].remove(best)
        return best
