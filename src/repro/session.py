"""Unified Session facade — one spec for every entry point.

``serve``, ``dryrun``, the benchmark drivers and the auto-configuration
sweep all need the same tuple: an architecture, a numerics policy, a
kernel backend, and (for compiled dry-runs) a mesh.  Before this module
each entry point re-assembled that tuple with its own ad-hoc signature;
:class:`Session` owns it once:

>>> from repro.session import Session
>>> s = Session("qwen3-4b", policy="segmented1")
>>> out = s.generate(batch=2, prompt_len=16, gen_len=8)   # serve loop
>>> s.ppa_report()["area_reduction"]                      # Table II roll-up
>>> res = s.auto_configure(budget=1e-2)                   # proxy sweep
>>> s.save_policy("policy.json")

``policy`` accepts a :class:`~repro.core.policy.NumericsPolicy`, a plain
:class:`~repro.core.numerics.NumericsConfig`, a preset name (``exact`` /
``segmented1|2|3``), or a path to a policy JSON file (the ``serve
--policy`` wire format); malformed files raise :class:`SessionError` with
a one-line message instead of a traceback.

The module doubles as the unified CLI (the sweep CLI of the repo):

    python -m repro.session generate       --arch qwen3-4b --policy p.json
    python -m repro.session auto-configure --arch qwen3-4b --budget 1e-2 --out p.json
    python -m repro.session ppa            --arch qwen3-4b --policy p.json
    python -m repro.session dryrun         --arch qwen3-4b --shape train_4k

``repro.launch.serve``, ``repro.launch.dryrun`` and
``benchmarks/table4_resnet.py`` are thin wrappers over Session.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from typing import Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.numerics import NumericsConfig
from repro.core.policy import Numerics, NumericsPolicy, is_policy
from repro.configs.base import ArchConfig

__all__ = ["GenerateResult", "Session", "SessionError", "build_parser",
           "load_policy", "print_ppa_report"]


class SessionError(RuntimeError):
    """A session-level configuration error with a one-line message."""


# the fast split-float ladder — the default auto-configure candidate set
# (CPU-cheap calibration; pass candidates="emulated" for the bit-level
# Pareto-frontier designs of repro.core.sweep.pareto_candidates)
SEGMENTED_CANDIDATES: Tuple[Tuple[str, NumericsConfig], ...] = (
    ("segmented-1", NumericsConfig(mode="segmented", seg_passes=1, backend="xla")),
    ("segmented-2", NumericsConfig(mode="segmented", seg_passes=2, backend="xla")),
    ("segmented-3", NumericsConfig(mode="segmented", seg_passes=3, backend="xla")),
)

# "exact" keeps the arch's own numerics (exact by default); segmented
# presets are the same ladder the auto-configurer sweeps
_PRESETS = {"exact": None,
            **{name.replace("-", ""): cfg
               for name, cfg in SEGMENTED_CANDIDATES}}


def load_policy(path: str) -> NumericsPolicy:
    """Load a NumericsPolicy from a JSON file with one-line errors."""
    try:
        with open(path) as f:
            text = f.read()
    except OSError as e:
        raise SessionError(
            f"cannot read policy file {path!r}: {e.strerror or e}") from e
    try:
        return NumericsPolicy.from_json(text)
    except (json.JSONDecodeError, ValueError, KeyError, TypeError) as e:
        raise SessionError(f"invalid policy JSON in {path!r}: {e}") from e


def _coerce_numerics(policy) -> Optional[Numerics]:
    """policy arg -> Numerics override (None = keep the arch's own)."""
    if policy is None or isinstance(policy, (NumericsConfig, NumericsPolicy)):
        return policy
    if is_policy(policy):  # ScopedPolicy view: prefixed, not servable as-is
        raise SessionError(
            "a ScopedPolicy view cannot configure a session — pass the root "
            "NumericsPolicy (views are created per layer during resolution)")
    if isinstance(policy, str):
        if policy in _PRESETS:
            return _PRESETS[policy]
        return load_policy(policy)
    raise SessionError(
        f"unsupported policy spec {policy!r}: expected a NumericsConfig, "
        f"NumericsPolicy, preset name ({'/'.join(_PRESETS)}) or a JSON path")


def _with_backend(numerics: Numerics, backend: str) -> Numerics:
    """Force the kernel backend on every config a Numerics can resolve to."""
    if isinstance(numerics, NumericsConfig):
        return dataclasses.replace(numerics, backend=backend)
    d = numerics.to_dict()
    d["default"]["backend"] = backend
    for r in d["rules"]:
        r["config"]["backend"] = backend
    return NumericsPolicy.from_dict(d)


@dataclasses.dataclass(frozen=True)
class GenerateResult:
    tokens: np.ndarray        # (batch, gen_len) int32 greedy continuations
    seconds: float
    tokens_per_s: float
    # per-row emitted-token counts (EOS included).  Rows that hit the EOS
    # stop token have their remaining columns pinned to eos_id; without
    # eos_id every row is full-length.
    gen_lengths: Optional[np.ndarray] = None


class Session:
    """(arch, policy, backend, mesh) + params — the one public spec.

    ``arch`` is an arch-id string from ``repro.configs`` (reduced to the
    CPU-sized config unless ``reduced=False``), a ready
    :class:`~repro.configs.base.ArchConfig`, or a
    :class:`~repro.models.resnet.ResNetConfig` (see :meth:`from_resnet`).
    ``mesh`` is carried for the dry-run path (``multi`` selects the
    2x16x16 multi-pod mesh; anything else the single-pod 16x16).
    """

    def __init__(self, arch, policy=None, backend: Optional[str] = None,
                 mesh: Optional[str] = None, *, seed: int = 0,
                 reduced: bool = True, params=None, state=None, tune=None):
        from repro.models import resnet as resnet_mod

        if isinstance(arch, str):
            from repro.configs import get_arch

            try:
                base = get_arch(arch)
            except ValueError as e:
                raise SessionError(str(e)) from e
            self.arch_id = arch
            self._base_cfg = base.reduced() if reduced else base
            self._family = "lm"
        elif isinstance(arch, ArchConfig):
            self.arch_id = arch.arch_id
            self._base_cfg = arch
            self._family = "lm"
        elif isinstance(arch, resnet_mod.ResNetConfig):
            self.arch_id = "resnet18"
            self._base_cfg = arch
            self._family = "resnet"
        else:
            raise SessionError(
                f"unsupported arch spec {arch!r}: expected an arch id, "
                f"ArchConfig or ResNetConfig")
        self.backend = backend
        self.mesh = mesh
        self.seed = seed
        self._numerics_override = _coerce_numerics(policy)
        self._params = params
        self._state = state  # resnet batchnorm state
        self._jit_cache = {}  # (config, max_len) -> (prefill, decode)
        # measured kernel-tuning artifact (path or TuningTable); activation
        # is process-wide — the dispatch lookups it feeds are module-level,
        # exactly like the static tables they replace
        self._tune = tune
        if tune is not None:
            from repro.kernels import autotune

            try:
                autotune.activate(tune)
            except autotune.TuneError as e:
                raise SessionError(str(e)) from e

    # -- configuration ------------------------------------------------------

    @property
    def numerics(self) -> Numerics:
        """The effective Numerics (override > arch default > backend)."""
        num = (self._numerics_override
               if self._numerics_override is not None
               else self._base_cfg.numerics)
        if self.backend is not None:
            num = _with_backend(num, self.backend)
        return num

    @property
    def config(self):
        """The arch config with this session's numerics applied."""
        return dataclasses.replace(self._base_cfg, numerics=self.numerics)

    @property
    def is_policy(self) -> bool:
        return is_policy(self.numerics)

    def replace(self, **kw) -> "Session":
        """A new Session with fields replaced (policy/backend/mesh/seed/
        params/state/tune); params/state are shared unless overridden."""
        args = dict(policy=self._numerics_override, backend=self.backend,
                    mesh=self.mesh, seed=self.seed, params=self._params,
                    state=self._state, tune=self._tune)
        unknown = set(kw) - set(args)
        if unknown:
            raise SessionError(
                f"unknown Session.replace field(s) {sorted(unknown)}; "
                f"expected a subset of {sorted(args)}")
        args.update(kw)
        return Session(self._base_cfg, args["policy"], args["backend"],
                       args["mesh"], seed=args["seed"],
                       params=args["params"], state=args["state"],
                       tune=args["tune"])

    # -- parameters ---------------------------------------------------------

    @property
    def params(self):
        """Model parameters (lazily initialized for the LM zoo)."""
        if self._params is None:
            if self._family != "lm":
                raise SessionError(
                    "resnet sessions need trained params: use "
                    "Session.from_resnet(cfg, params, state)")
            import jax

            from repro.models import transformer
            from repro.models.layers import unzip

            pp = transformer.init(self.config, jax.random.PRNGKey(self.seed))
            self._params, _ = unzip(pp)
        return self._params

    @classmethod
    def from_resnet(cls, cfg, params, state, policy=None,
                    backend: Optional[str] = None, seed: int = 0) -> "Session":
        """Session over a trained ResNet: ``cfg`` is a ResNetConfig,
        ``params``/``state`` the trained trees (``repro.models.resnet``)."""
        return cls(cfg, policy, backend, seed=seed, params=params,
                   state=state)

    @classmethod
    def from_pretrained(cls, family: str, path, policy=None,
                        backend: Optional[str] = None,
                        mesh: Optional[str] = None, *, cfg=None,
                        reduced: bool = True, unknown: str = "error",
                        cast: bool = True, seed: int = 0,
                        tune=None) -> "Session":
        """A Session over real pretrained weights (``repro.compat``).

        ``family`` names a registered checkpoint converter (``qwen3-4b``,
        ``whisper-tiny``, ``resnet18``); ``path`` is a safetensors file,
        a sharded ``*.safetensors.index.json`` (or a directory holding
        either), or a torch pickle.  The architecture comes from ``cfg``
        when given, else the checkpoint's ``repro.config`` metadata, else
        the registered arch (``reduced`` picking the CPU-sized variant).
        ``unknown``/``cast`` are forwarded to
        :func:`repro.compat.load_pretrained`; interop failures surface as
        one-line :class:`repro.compat.CompatError`\\ s.
        """
        from repro import compat

        loaded = compat.load_pretrained(family, path, cfg=cfg,
                                        reduced=reduced, unknown=unknown,
                                        cast=cast)
        if loaded.kind == "resnet":
            return cls(loaded.cfg, policy, backend, seed=seed,
                       params=loaded.params, state=loaded.state, tune=tune)
        return cls(loaded.cfg, policy, backend, mesh, seed=seed,
                   params=loaded.params, tune=tune)

    def export(self, path) -> None:
        """Write this session's params (+ ResNet bn state) as a single
        safetensors checkpoint in the family's foreign naming scheme —
        the exact inverse of :meth:`from_pretrained`, so an
        export/reload round trip is bit-exact."""
        from repro import compat

        foreign, meta = compat.export_pretrained(
            self.arch_id, self._base_cfg, self.params, self._state)
        compat.write_safetensors(path, foreign, meta)

    # -- layer enumeration / PPA -------------------------------------------

    def layer_paths(self) -> list:
        if self._family == "resnet":
            from repro.models import resnet

            return resnet.layer_paths(self._base_cfg)
        from repro.models import transformer

        return transformer.layer_paths(self.config)

    def layer_path_counts(self) -> Mapping[str, int]:
        if self._family == "resnet":
            return {}
        from repro.models import transformer

        return transformer.layer_path_counts(self.config)

    def ppa_report(self) -> dict:
        """Modeled PPA of this session's numerics over every call site:
        the Table II area/power roll-up plus the MXU-pass roofline scale
        (``repro.launch.hlo_analysis.policy_ppa_summary``)."""
        from repro.launch import hlo_analysis

        num = self.numerics
        policy = (num if isinstance(num, NumericsPolicy)
                  else NumericsPolicy((), default=num))
        return hlo_analysis.policy_ppa_summary(
            policy, self.layer_paths(), counts=self.layer_path_counts())

    def save_policy(self, path: str) -> None:
        num = self.numerics
        policy = (num if isinstance(num, NumericsPolicy)
                  else NumericsPolicy((), default=num))
        with open(path, "w") as f:
            f.write(policy.to_json())

    # -- forward / generation ----------------------------------------------

    def apply(self, images):
        """ResNet inference under the session numerics -> logits."""
        if self._family != "resnet":
            raise SessionError("apply(images) is the ResNet entry point; "
                               "use generate() for the LM zoo")
        from repro.models import resnet

        logits, _ = resnet.apply(self.params, self._state, images,
                                 self.config, train=False)
        return logits

    def generate(self, batch: int = 4, prompt_len: int = 32,
                 gen_len: int = 16, prompts=None,
                 eos_id: Optional[int] = None) -> GenerateResult:
        """Batched prefill + greedy decode loop (the serve driver).

        ``prompts`` (batch, prompt_len) int32 overrides the seeded random
        prompts.  Returns the generated tokens plus wall-clock stats.

        ``eos_id`` enables stop-token handling: a per-row finished mask
        tracks rows that emitted the token, the loop exits early once
        every row has, and finished rows' remaining columns come back
        pinned to ``eos_id`` (``gen_lengths`` carries the true per-row
        counts, EOS included).  Stopping is bit-transparent: the tokens a
        row emits before its EOS are identical with and without
        ``eos_id``, because unfinished rows keep seeing exactly the same
        batched decode steps.
        """
        if self._family != "lm":
            raise SessionError("generate() is the LM entry point; use "
                               "apply(images) for ResNet sessions")
        import jax
        import jax.numpy as jnp

        from repro.models import transformer

        cfg = self.config
        params = self.params
        if prompts is None:
            rng = np.random.default_rng(self.seed)
            prompts = jnp.asarray(
                rng.integers(0, cfg.vocab, (batch, prompt_len)), jnp.int32)
        else:
            prompts = jnp.asarray(prompts, jnp.int32)
            batch, prompt_len = prompts.shape
        max_len = prompt_len + gen_len

        # jitted callables are cached per (config, max_len) so repeated
        # generate() calls on one Session reuse compiled code instead of
        # paying two fresh XLA compilations each time (jax.jit caches per
        # function object; the config is closed over, so a policy/backend
        # change via replace() naturally gets its own entry)
        key = (cfg, max_len)
        if key not in self._jit_cache:
            self._jit_cache[key] = (
                jax.jit(lambda p, b: transformer.prefill(p, cfg, b,
                                                         max_len=max_len)),
                jax.jit(lambda p, tok, st, pos: transformer.decode_step(
                    p, cfg, {"token": tok}, st, pos)),
            )
        prefill, decode = self._jit_cache[key]

        t0 = time.perf_counter()
        logits, state = prefill(params, {"tokens": prompts})
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out = [tok]
        # the EOS mask lives on the host (it gates the python loop); the
        # decode itself always advances the full batch, so a row's tokens
        # are unchanged by other rows finishing
        finished = (np.asarray(tok)[:, 0] == eos_id
                    if eos_id is not None else None)
        for i in range(gen_len - 1):
            if finished is not None and finished.all():
                break
            logits, state = decode(params, tok, state, jnp.int32(prompt_len + i))
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            out.append(tok)
            if finished is not None:
                finished = finished | (np.asarray(tok)[:, 0] == eos_id)
        jax.block_until_ready(tok)
        dt = time.perf_counter() - t0
        gen = np.asarray(jnp.concatenate(out, axis=1))
        if eos_id is None:
            return GenerateResult(tokens=gen, seconds=dt,
                                  tokens_per_s=batch * gen_len / dt,
                                  gen_lengths=np.full(batch, gen_len,
                                                      np.int64))
        emitted = gen.shape[1]
        lengths = np.full(batch, gen_len, np.int64)
        full = np.full((batch, gen_len), eos_id, np.int32)
        full[:, :emitted] = gen
        for b in range(batch):
            hits = np.nonzero(gen[b] == eos_id)[0]
            if hits.size:
                lengths[b] = hits[0] + 1
                full[b, hits[0] + 1:] = eos_id
        return GenerateResult(tokens=full, seconds=dt,
                              tokens_per_s=int(lengths.sum()) / dt,
                              gen_lengths=lengths)

    # -- serving (continuous batching) -------------------------------------

    def serving_engine(self, tiers=None, *, slots: int = 4,
                       max_len: int = 64, page_size=None, pages=None,
                       prefill_chunk=None, clock=None, aging=None,
                       prefill_cache=None):
        """A continuous-batching :class:`repro.serving.Engine` over this
        session's resident weights: one paged KV pool + one resident
        compiled decode per accuracy tier, requests joining mid-decode
        (design: ``docs/serving.md``).

        ``tiers`` is a sequence of :class:`repro.serving.TierSpec`
        (default: the premium/standard/bulk SLA ladder); each tier's
        ``policy`` goes through the same coercion as ``Session(policy=...)``.
        ``page_size`` sets the KV page granularity (default 16 tokens) and
        ``pages`` the physical pool per tier (default ``slots *
        ceil(max_len / page_size)``); a request reserves only the pages
        its own ``prompt + max_new - 1`` positions need.
        ``prefill_chunk`` (default 32) bounds the prompt tokens prefilled
        per engine step, so long prompts interleave with in-flight
        decodes; ``prefill_cache`` bounds each lane's compiled
        prefill-shape cache (LRU; default 32 shapes).  Continuous
        batching never changes a request's numerics — every request's
        tokens are bit-identical to a solo :meth:`generate` of the same
        prompt under that tier's policy.
        """
        if self._family != "lm":
            raise SessionError("serving_engine() is the LM entry point; "
                               "ResNet sessions have no decode loop")
        from repro.serving import DEFAULT_TIERS, Engine

        tiers = DEFAULT_TIERS if tiers is None else tuple(tiers)
        return Engine.from_session(self, tiers, slots=slots, max_len=max_len,
                                   page_size=page_size, pages=pages,
                                   prefill_chunk=prefill_chunk,
                                   clock=clock, aging=aging,
                                   prefill_cache=prefill_cache)

    # -- auto-configuration (the sweep) ------------------------------------

    def auto_configure(self, budget: float, calib=None, candidates=None,
                       method: str = "proxy", default=None,
                       verbose: bool = False):
        """Budget-driven per-layer numerics selection over this session's
        network; adopts the emitted policy as the session numerics.

        ``calib`` is the calibration input — a token batch dict
        (``{"tokens": ...}``, plus ``"enc_embeds"`` for encoder-decoder
        archs) for the LM zoo (default: seeded random tokens, and seeded
        random encoder embeddings when the arch has an encoder), an image
        array for ResNet sessions.  ``candidates`` is a ``(name,
        NumericsConfig)`` list, ``"segmented"`` (default: the split-float
        ladder) or ``"emulated"`` (bit-level Pareto designs).

        ``method="proxy"`` (default) fits the gain-aware composed-error
        model from ONE instrumented pass (``repro.core.sensitivity``);
        scanned decoder segments and the whisper-style encoder unroll
        transparently during that pass, so every site —
        ``encoder.blocks.*`` included — is visible to the calibration
        tap.  Returns the :class:`repro.core.sweep.AutoConfigResult`.
        """
        import jax.numpy as jnp

        from repro.core import sweep
        from repro.core.metrics import mred

        if candidates is None or candidates == "segmented":
            cand: Optional[Sequence] = list(SEGMENTED_CANDIDATES)
        elif candidates == "emulated":
            cand = None  # sweep's default: emulated Pareto frontier
        else:
            cand = list(candidates)

        if self._family == "resnet":
            from repro.models import resnet

            if calib is None:
                raise SessionError(
                    "resnet auto_configure needs a calibration image batch "
                    "(calib=images)")
            images = jnp.asarray(calib)
            base_cfg = dataclasses.replace(
                self._base_cfg,
                numerics=NumericsConfig(mode="exact", compute_dtype="float32"))
            ref, _ = resnet.apply(self.params, self._state, images, base_cfg,
                                  train=False)
            ref = np.asarray(ref, np.float64)

            def eval_fn(policy):
                acfg = dataclasses.replace(base_cfg, numerics=policy)
                logits, _ = resnet.apply(self.params, self._state, images,
                                         acfg, train=False)
                return mred(np.asarray(logits), ref)

            default = default or NumericsConfig(mode="exact",
                                                compute_dtype="float32")
        else:
            from repro.models import transformer

            cfg = self.config
            if calib is None:
                rng = np.random.default_rng(self.seed)
                calib = {"tokens": jnp.asarray(
                    rng.integers(0, cfg.vocab, (2, 16)), jnp.int32)}
                if cfg.encoder_layers:
                    # encoder-decoder archs also need encoder inputs so the
                    # calibration pass reaches the encoder.blocks.* sites
                    # (cfg.enc_len itself only sizes serving caches, which
                    # the train-mode calibration forward never allocates)
                    enc_len = min(cfg.enc_len, 16)
                    calib["enc_embeds"] = jnp.asarray(rng.standard_normal(
                        (2, enc_len, cfg.d_model)), jnp.float32)
            # the default must match the network's own exact numerics (bf16
            # for the LM zoo) so the baseline itself reads as zero error
            default = default or NumericsConfig(mode="exact")
            base_cfg = dataclasses.replace(cfg, numerics=default)
            params = self.params
            hidden, _, _ = transformer.backbone(params, base_cfg, calib,
                                                mode="train")
            ref = np.asarray(transformer.logits_fn(params, base_cfg, hidden),
                             np.float64)

            def eval_fn(policy):
                pcfg = dataclasses.replace(cfg, numerics=policy)
                h, _, _ = transformer.backbone(params, pcfg, calib,
                                               mode="train")
                return mred(
                    np.asarray(transformer.logits_fn(params, pcfg, h)), ref)

        res = sweep.auto_configure(eval_fn, self.layer_paths(), budget,
                                   candidates=cand, default=default,
                                   method=method, verbose=verbose)
        self._numerics_override = res.policy
        return res

    # -- compiled dry-run ---------------------------------------------------

    def dryrun(self, shape: str, multi_pod: Optional[bool] = None) -> dict:
        """Lower + compile one (arch x shape x mesh) cell and return the
        roofline/memory record (``repro.launch.dryrun``).  Requires the
        512-fake-device environment the dryrun CLI sets up — use
        ``python -m repro.launch.dryrun`` (or ``python -m repro.session
        dryrun``) from a fresh process.
        """
        if self._family != "lm":
            raise SessionError("dryrun() is the LM entry point; ResNet "
                               "sessions have no launch shapes")
        from repro.launch import specs

        if shape not in specs.SHAPES:
            raise SessionError(f"unknown dryrun shape {shape!r}; expected "
                               f"one of {sorted(specs.SHAPES)}")
        from repro.launch import dryrun as dryrun_mod

        if multi_pod is None:
            multi_pod = self.mesh == "multi"
        try:
            return dryrun_mod.lower_session_cell(self, shape, multi_pod)
        except RuntimeError as e:
            if "device" not in str(e):
                raise
            # mesh construction needs the fake-device env the dryrun CLI
            # sets before jax loads; in-process callers must preset it
            raise SessionError(
                f"{e} (python -m repro.session imports jax before the "
                f"dryrun module can set it — run with XLA_FLAGS="
                f"--xla_force_host_platform_device_count=512, or use "
                f"python -m repro.launch.dryrun)") from e


# ---------------------------------------------------------------------------
# the unified CLI (generate / auto-configure / ppa / dryrun)
# ---------------------------------------------------------------------------

def _add_common(ap):
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--policy", default=None,
                    help="NumericsPolicy JSON file, or a preset "
                         "(exact/segmented1/segmented2/segmented3)")
    ap.add_argument("--backend", default=None,
                    choices=["auto", "pallas", "interpret", "xla"])
    ap.add_argument("--tune", default=None, metavar="TUNE_JSON",
                    help="measured kernel-tuning artifact to activate "
                         "(kernels/TUNE_<device>.json; generate with "
                         "python -m benchmarks.autotune). Default: the "
                         "REPRO_TUNE_FILE env var if set, else the "
                         "static tuning tables")
    ap.add_argument("--weights", default=None, metavar="CKPT",
                    help="pretrained checkpoint loaded through the compat "
                         "converter registered for --arch (safetensors "
                         "file, sharded *.safetensors.index.json or its "
                         "directory, or a torch pickle; see docs/compat.md)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--full-size", action="store_true",
                    help="use the full arch config (default: reduced)")


def parse_tiers(spec: str):
    """``name:policy,name:policy`` -> TierSpec tuple (priority = listed
    order; policy is a preset name or a policy-JSON path).  The wire
    format of ``python -m repro.session serve-loop --tiers``."""
    from repro.serving import TierSpec

    tiers = []
    for i, part in enumerate(p for p in spec.split(",") if p.strip()):
        name, _, pol = part.partition(":")
        if not name.strip() or not pol.strip():
            raise SessionError(f"bad tier spec {part.strip()!r}: expected "
                               f"name:policy (e.g. premium:exact)")
        if any(t.name == name.strip() for t in tiers):
            raise SessionError(f"duplicate tier {name.strip()!r} in --tiers")
        tiers.append(TierSpec(name.strip(), pol.strip(), priority=i))
    if not tiers:
        raise SessionError(f"empty tier spec {spec!r}: expected "
                           f"name:policy[,name:policy...]")
    return tuple(tiers)


def print_ppa_report(ppa: dict, tag: str = "session") -> None:
    """One-line human summary of a ``Session.ppa_report`` dict (shared by
    the session and serve CLIs so the two never drift)."""
    print(f"[{tag}] policy over {ppa['n_sites']} call sites: "
          f"area {ppa['area_um2']:,.0f} um^2 "
          f"(-{ppa['area_reduction']:.1%} vs exact), "
          f"power {ppa['power_w']:.3f} W "
          f"(-{ppa['power_reduction']:.1%}), "
          f"modeled compute latency x{ppa['compute_scale']:.2f}")


def build_parser() -> argparse.ArgumentParser:
    """The unified-CLI argument parser (also what ``tools/gen_cli_docs.py``
    introspects to generate ``docs/cli.md`` — keep help strings current)."""
    ap = argparse.ArgumentParser(
        prog="repro.session",
        description="Unified Session CLI: generate / auto-configure / "
                    "ppa / dryrun over one (arch, policy, backend) spec")
    sub = ap.add_subparsers(dest="cmd", required=True)

    g = sub.add_parser("generate", help="batched prefill + greedy decode")
    _add_common(g)
    g.add_argument("--batch", type=int, default=4)
    g.add_argument("--prompt-len", type=int, default=32)
    g.add_argument("--gen-len", type=int, default=16)
    g.add_argument("--eos-id", type=int, default=None,
                   help="stop token: rows retire when they emit it "
                        "(bit-transparent early exit; default: none)")

    sl = sub.add_parser(
        "serve-loop",
        help="continuous-batching serving demo: a synthetic mixed-tier "
             "workload decodes on one resident weight set (per-tier "
             "accuracy policies; see docs/serving.md)")
    _add_common(sl)
    sl.add_argument("--tiers", default="premium:exact,bulk:segmented1",
                    help="comma list of name:policy tiers, priority in "
                         "listed order (policy: preset name or policy-JSON "
                         "path; overrides --policy per lane)")
    sl.add_argument("--requests", type=int, default=8,
                    help="synthetic workload size (round-robin over tiers)")
    sl.add_argument("--slots", type=int, default=4,
                    help="KV-pool slots per tier")
    sl.add_argument("--max-len", type=int, default=64,
                    help="per-request KV position cap")
    sl.add_argument("--page-size", type=int, default=None,
                    help="tokens per paged-KV page (default 16); requests "
                         "reserve only the pages their own length needs")
    sl.add_argument("--pages", type=int, default=None,
                    help="physical KV pages per tier (default: "
                         "slots * ceil(max_len / page_size))")
    sl.add_argument("--prefill-chunk", type=int, default=None,
                    help="prompt tokens prefilled per engine step "
                         "(default 32); long prompts interleave with "
                         "in-flight decodes")
    sl.add_argument("--prompt-len", type=int, default=16)
    sl.add_argument("--gen-len", type=int, default=16)
    sl.add_argument("--aging", type=float, default=None,
                    help="scheduler aging bound in seconds (starvation "
                         "freedom; default: off)")

    a = sub.add_parser("auto-configure",
                       help="budget-driven per-layer numerics sweep "
                            "(proxy: ONE gain-aware calibration pass)")
    _add_common(a)
    a.add_argument("--budget", type=float, required=True)
    a.add_argument("--method", choices=["proxy", "greedy"], default="proxy")
    a.add_argument("--candidates", choices=["segmented", "emulated"],
                   default="segmented")
    a.add_argument("--out", default=None, help="write the policy JSON here")

    p = sub.add_parser("ppa", help="Table II PPA roll-up of the policy")
    _add_common(p)

    d = sub.add_parser(
        "dryrun",
        help="lower+compile one cell (fresh process with XLA_FLAGS="
             "--xla_force_host_platform_device_count=512, or use "
             "python -m repro.launch.dryrun which sets it itself)")
    _add_common(d)
    d.add_argument("--shape", required=True)
    d.add_argument("--multi-pod", action="store_true")
    d.add_argument("--reduced", action="store_true",
                   help="lower the reduced CPU-sized config instead of the "
                        "full arch (dryrun defaults to full-size so records "
                        "match python -m repro.launch.dryrun)")
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    # dryrun lowers the full-size arch by default — its records must be
    # comparable with the launch.dryrun CLI; every other subcommand works
    # on the reduced config unless --full-size
    reduced = args.reduced if args.cmd == "dryrun" else not args.full_size
    try:
        if getattr(args, "weights", None):
            from repro.compat import CompatError

            try:
                sess = Session.from_pretrained(
                    args.arch, args.weights, policy=args.policy,
                    backend=args.backend, seed=args.seed, reduced=reduced,
                    tune=args.tune)
            except CompatError as e:
                raise SessionError(str(e)) from e
        else:
            sess = Session(args.arch, policy=args.policy,
                           backend=args.backend, seed=args.seed,
                           reduced=reduced, tune=args.tune)
        if args.cmd == "generate":
            if sess.is_policy:
                print_ppa_report(sess.ppa_report())
            res = sess.generate(batch=args.batch, prompt_len=args.prompt_len,
                                gen_len=args.gen_len, eos_id=args.eos_id)
            n_tok = (int(res.gen_lengths.sum()) if res.gen_lengths is not None
                     else res.tokens.size)
            print(f"[session] {args.arch}: {res.tokens.shape[0]}x"
                  f"{res.tokens.shape[1]} tokens ({n_tok} emitted) in "
                  f"{res.seconds:.2f}s ({res.tokens_per_s:.1f} tok/s)")
        elif args.cmd == "serve-loop":
            from repro.serving import ServingError

            tiers = parse_tiers(args.tiers)
            try:
                eng = sess.serving_engine(tiers, slots=args.slots,
                                          max_len=args.max_len,
                                          page_size=args.page_size,
                                          pages=args.pages,
                                          prefill_chunk=args.prefill_chunk,
                                          aging=args.aging)
                rng = np.random.default_rng(args.seed)
                for i in range(args.requests):
                    spec = tiers[i % len(tiers)]
                    plen = int(rng.integers(max(2, args.prompt_len // 2),
                                            args.prompt_len + 1))
                    eng.submit(rng.integers(0, sess.config.vocab, plen),
                               tier=spec.name,
                               max_new_tokens=args.gen_len)
                t0 = time.perf_counter()
                stats = eng.run()
                dt = time.perf_counter() - t0
            except ServingError as e:
                raise SessionError(str(e)) from e
            total = sum(s.n_tokens for s in stats.values())
            print(f"[serve-loop] {args.arch}: {args.requests} requests, "
                  f"{total} tokens in {dt:.2f}s ({total / dt:.1f} tok/s "
                  f"aggregate)")
            for spec in tiers:
                s = stats[spec.name]
                print(f"[serve-loop]   {spec.name} ({spec.policy}): "
                      f"{s.n_finished} finished, {s.n_tokens} tokens, "
                      f"{s.n_decode_steps} decode steps, mean batch "
                      f"{s.mean_occupancy:.2f}")
                print_ppa_report(sess.replace(policy=spec.policy).ppa_report(),
                                 tag=f"tier:{spec.name}")
        elif args.cmd == "auto-configure":
            res = sess.auto_configure(args.budget, method=args.method,
                                      candidates=args.candidates, verbose=True)
            print(f"[session] {res.method} error={res.error:.3e} "
                  f"(budget {args.budget:g})  area {res.area_um2:,.0f} um^2 "
                  f"(-{res.area_reduction:.1%} vs exact)  "
                  f"[{res.n_evals} calibration evals]")
            if args.out:
                sess.save_policy(args.out)
                print(f"[session] policy written to {args.out}")
        elif args.cmd == "ppa":
            print_ppa_report(sess.ppa_report())
        elif args.cmd == "dryrun":
            rec = sess.dryrun(args.shape, multi_pod=args.multi_pod)
            print(json.dumps(rec, indent=1))
            return 0 if rec.get("status", "error").startswith(
                ("ok", "skipped")) else 1
    except SessionError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    return 0


if __name__ == "__main__":
    sys.exit(main())
