"""Shared fixtures for the test suite.

The ``slow`` marker (declared in pyproject.toml, deselected by default via
``addopts``) keeps the default run — the tier-1 command — under ~2
minutes; ``pytest -m ""`` runs everything.
"""
import zlib

import numpy as np
import pytest


@pytest.fixture
def rng(request):
    """Deterministic per-test Generator: seeded from the test's node id, so
    every test (and every parametrization) gets an independent, stable
    stream without hand-picked seed constants."""
    return np.random.default_rng(zlib.crc32(request.node.nodeid.encode()))


@pytest.fixture
def seeded_rng():
    """One fixed stream for tests that want cross-test reproducibility."""
    return np.random.default_rng(0)
