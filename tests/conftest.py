"""Shared fixtures for the test suite.

The ``slow`` marker (declared in pyproject.toml, deselected by default via
``addopts``) keeps the default run — the tier-1 command — under ~2
minutes; ``pytest -m ""`` runs everything.
"""
import zlib

import numpy as np
import pytest


@pytest.fixture
def rng(request):
    """Deterministic per-test Generator: seeded from the test's node id, so
    every test (and every parametrization) gets an independent, stable
    stream without hand-picked seed constants."""
    return np.random.default_rng(zlib.crc32(request.node.nodeid.encode()))


@pytest.fixture
def seeded_rng():
    """One fixed stream for tests that want cross-test reproducibility."""
    return np.random.default_rng(0)


@pytest.fixture
def small_moe():
    """Factory for a tiny MoE layer: ``small_moe(E=2, ...)`` returns
    ``(cfg, params, x)`` on the deepseek-v3 family config with reduced
    dims.  Defaults are the smallest useful setup (2 experts, tiny dims);
    shared by test_moe.py, test_moe_shardmap.py and test_sensitivity.py so
    the default suite stays under its ~2 min budget."""
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.configs import get_arch
    from repro.models import moe as moe_mod
    from repro.models.layers import unzip

    def make(E=2, K=1, T=8, D=8, FF=16, cf=8.0, n_shared=0, seed=0, B=2):
        cfg_arch = get_arch("deepseek-v3-671b").reduced()
        cfg = dataclasses.replace(
            cfg_arch, d_model=D, d_ff=FF,
            moe=dataclasses.replace(cfg_arch.moe, n_experts=E, top_k=K,
                                    capacity_factor=cf, n_shared=n_shared))
        pp = moe_mod.moe_init(jax.random.PRNGKey(seed), cfg)
        params, _ = unzip(pp)
        x = jax.random.normal(jax.random.PRNGKey(seed + 1), (B, T // B, D),
                              jnp.float32)
        return cfg, params, x

    return make
