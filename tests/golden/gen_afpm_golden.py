"""Golden-vector generator for the AFPM datapath.

A pure-Python, integer-only reimplementation of the paper's AC-n-n / ACL-n
multiplier (repro/core/afpm.py §III-B), deliberately sharing NO code with
the JAX datapath: plain ints, one scalar at a time.  The JAX implementation
is pinned bit-for-bit against the vectors this script emits.

Run from the repo root to regenerate ``tests/golden/afpm_golden.json``:

    python tests/golden/gen_afpm_golden.py

Inputs are uint32 bit patterns: IEEE-754 specials (zeros, infs, nans,
subnormals, extreme normals) plus a fixed-PRNG sweep of the full pattern
space, so the exception paths are exercised, not just the happy path.
NaN results are stored as the canonical quiet-NaN pattern 0x7FC00000; the
consuming test treats any-NaN-vs-any-NaN as equal (payloads are
unspecified), everything else must match exactly.
"""
from __future__ import annotations

import json
import os
import random

F32 = {"man_bits": 23, "exp_bits": 8}
BF16 = {"man_bits": 7, "exp_bits": 8}
FORMATS = {"fp32": F32, "bf16": BF16}

_CANON_NAN = 0x7FC00000
_INF = 0x7F800000


def _fmt_params(fmt):
    bias = (1 << (fmt["exp_bits"] - 1)) - 1
    max_exp_field = (1 << fmt["exp_bits"]) - 1
    return fmt["man_bits"], bias, max_exp_field


def decode(bits: int, fmt) -> tuple[int, int, int]:
    """uint32 carrier -> (sign, biased exp field, fmt-width mantissa field)."""
    man_bits, bias, max_exp_field = _fmt_params(fmt)
    man32 = bits & ((1 << 23) - 1)
    exp32 = (bits >> 23) & 0xFF
    sign = bits >> 31
    if man_bits == 23 and fmt["exp_bits"] == 8:
        return sign, exp32, man32
    man = man32 >> (23 - man_bits)
    e_unb = exp32 - 127
    exp = min(max(e_unb + bias, 0), max_exp_field)
    if exp == 0 or exp == max_exp_field:  # flushed subnormal / saturated
        man = 0
    if exp32 == 255:  # preserve inf/nan class from the fp32 carrier
        exp = max_exp_field
        if man32 != 0:
            man = 1
    return sign, exp, man


def ac_cross(mx: int, my: int, n: int, M: int) -> int:
    """Approximate cross term Mx*My in units of 2^-3n (paper Eqs. 5-6,
    with conditional execution and shift compensation enabled)."""
    lo_shift = max(M - 2 * n, 0)
    A = mx >> (M - n)
    B = (mx >> lo_shift) & ((1 << n) - 1)
    C = my >> (M - n)
    D = (my >> lo_shift) & ((1 << n) - 1)

    force_ad = C == 0 and A != 0 and D != 0
    force_bc = A == 0 and C != 0 and B != 0
    exec_ad = (D >> 2) != 0 or force_ad
    exec_bc = (B >> 2) != 0 or force_bc
    comp_ad = (A << 1) if (A != 0 and D != 0) else 0
    comp_bc = (C << 1) if (C != 0 and B != 0) else 0
    ad_term = (A * D) if exec_ad else comp_ad
    bc_term = (B * C) if exec_bc else comp_bc
    return ((A * C) << n) + ad_term + bc_term  # BD always omitted


def afpm_mult_bits(xb: int, yb: int, n: int, mode: str, fmt) -> int:
    """The full datapath on uint32 carriers; returns the uint32 result."""
    man_bits, bias, max_exp_field = _fmt_params(fmt)
    M = man_bits
    sx, ex, mx = decode(xb, fmt)
    sy, ey, my = decode(yb, fmt)
    s = sx ^ sy

    if mode == "ac":
        T = min(3 * n, M)
        U = 1 << T
        cross = ac_cross(mx, my, n, M)
        cross_t = cross >> (3 * n - T) if 3 * n > T else cross << (T - 3 * n)
        acc = U + (mx >> (M - T)) + (my >> (M - T)) + cross_t
    else:  # acl
        T = n
        U = 1 << T
        A = mx >> (M - n)
        C = my >> (M - n)
        acc = U + A + C + (A & C)

    ge2 = acc >= (U << 1)
    acc_n = acc >> 1 if ge2 else acc
    man_res = (acc_n - U) << (M - T)  # zero-padded to the format width
    e_unb = (ex - bias) + (ey - bias) + (1 if ge2 else 0)

    e_min = 1 - bias
    e_max = max_exp_field - 1 - bias
    if e_unb > e_max:  # overflow -> signed inf
        res = (s << 31) | _INF
    elif e_unb < e_min:  # underflow -> signed zero
        res = s << 31
    else:
        res = (s << 31) | ((e_unb + 127) << 23) | (man_res << (23 - M))

    # special operands on the fp32 carrier (same precedence as the datapath:
    # zero-flush, then inf, then nan)
    exp32_x, man32_x = (xb >> 23) & 0xFF, xb & 0x7FFFFF
    exp32_y, man32_y = (yb >> 23) & 0xFF, yb & 0x7FFFFF
    x_fin = exp32_x != 255
    y_fin = exp32_y != 255
    x_inf = exp32_x == 255 and man32_x == 0
    y_inf = exp32_y == 255 and man32_y == 0
    x_nan = exp32_x == 255 and man32_x != 0
    y_nan = exp32_y == 255 and man32_y != 0
    x_zero = ex == 0  # true zero or flushed subnormal (in fmt terms)
    y_zero = ey == 0
    if (x_zero or y_zero) and x_fin and y_fin:
        res = s << 31
    if x_inf or y_inf:
        res = (s << 31) | _INF
    if x_nan or y_nan or ((x_inf or y_inf) and (x_zero or y_zero)):
        res = _CANON_NAN
    return res


def _input_bits(rnd: random.Random, count: int) -> list[int]:
    specials = [
        0x00000000, 0x80000000,              # +-0
        0x7F800000, 0xFF800000,              # +-inf
        0x7FC00000, 0xFFC00001, 0x7F800001,  # nans (quiet + signalling)
        0x00000001, 0x807FFFFF,              # subnormals
        0x00800000, 0x80800000,              # smallest normals
        0x7F7FFFFF, 0xFF7FFFFF,              # largest finite
        0x3F800000, 0xBF800000,              # +-1
        0x3FFFFFFF, 0x34000000, 0x4E800000,  # assorted magnitudes
    ]
    out = list(specials)
    while len(out) < count:
        out.append(rnd.getrandbits(32))
    return out[:count]


CONFIGS = [
    {"label": "AC5-5/fp32", "n": 5, "mode": "ac", "fmt": "fp32"},
    {"label": "ACL4/fp32", "n": 4, "mode": "acl", "fmt": "fp32"},
    {"label": "AC3-3/bf16", "n": 3, "mode": "ac", "fmt": "bf16"},
    {"label": "ACL4/bf16", "n": 4, "mode": "acl", "fmt": "bf16"},
]

N_VECTORS = 256


def generate() -> dict:
    rnd = random.Random(20260730)
    cases = []
    for cfg in CONFIGS:
        xs = _input_bits(rnd, N_VECTORS)
        ys = _input_bits(rnd, N_VECTORS)
        rnd.shuffle(ys)
        outs = [
            afpm_mult_bits(x, y, cfg["n"], cfg["mode"], FORMATS[cfg["fmt"]])
            for x, y in zip(xs, ys)
        ]
        cases.append({**cfg, "x_bits": xs, "y_bits": ys, "out_bits": outs})
    return {"generator": os.path.basename(__file__), "seed": 20260730,
            "cases": cases}


if __name__ == "__main__":
    path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "afpm_golden.json")
    with open(path, "w") as f:
        json.dump(generate(), f)
        f.write("\n")
    print(f"wrote {path}")
