"""Golden checkpoint fixtures for the compat subsystem (docs/compat.md).

Writes miniature "HF-format" pretrained checkpoints for the three
converter families under ``tests/golden/compat/`` — real foreign naming
schemes at the reduced-config sizes — plus, per family, a numpy
``*_reference.npz`` holding the EXPECTED native state dict.

Independence is the point, twice over:

* the safetensors bytes are produced by :func:`_write_safetensors`
  below — a from-scratch writer sharing no code with
  ``repro.compat.safetensors_io`` — so the test's read path is a
  cross-implementation check of the container format (qwen3 is written
  *sharded* with a ``model.safetensors.index.json`` to cover the shard
  path);
* the reference native arrays are computed right here with explicit
  numpy transposes/stacks (``w.T``, ``np.transpose(w, (2, 3, 1, 0))``,
  ``w - 1``), sharing no code with the mapping DSL — the consuming test
  (``tests/test_compat.py``) asserts ``Session.from_pretrained`` output
  equals them with ``np.testing.assert_array_equal``, bit-exact.

Run from the repo root to regenerate (fixture sizes are a few hundred
KB total):

    PYTHONPATH=src python tests/golden/gen_compat_golden.py
"""
from __future__ import annotations

import json
import os

import numpy as np

HERE = os.path.dirname(os.path.abspath(__file__))
OUT = os.path.join(HERE, "compat")

# reduced-config dimensions (mirror ArchConfig.reduced(): d_model=64,
# heads=4, head_dim=16, d_ff=128, vocab=256, <=2 repeats/encoder layers)
D, HEADS, KV, HD, FF, VOCAB = 64, 4, 4, 16, 128, 256
N_LAYERS = 2          # decoder layers (both LM families)
N_ENC = 2             # whisper encoder layers
# tiny ResNet (widths/blocks deliberately not the full CIFAR config —
# the checkpoint's repro.config metadata must carry it)
R_WIDTHS, R_BLOCKS, R_CLASSES = (4, 8), (1, 1), 10


# ---------------------------------------------------------------------------
# an INDEPENDENT minimal safetensors writer (no repro.compat imports)
# ---------------------------------------------------------------------------

def _write_safetensors(path, sd, metadata):
    header = {"__metadata__": {k: str(v) for k, v in metadata.items()}}
    body = b""
    for name, arr in sd.items():
        arr = np.ascontiguousarray(arr, dtype=np.float32)
        header[name] = {"dtype": "F32", "shape": list(arr.shape),
                        "data_offsets": [len(body), len(body) + arr.nbytes]}
        body += arr.tobytes()
    blob = json.dumps(header).encode()
    with open(path, "wb") as f:
        f.write(len(blob).to_bytes(8, "little"))
        f.write(blob)
        f.write(body)


def _write_sharded(dirname, shards, metadata, basename="model"):
    """shards: list of state dicts -> N shard files + HF index."""
    n = len(shards)
    weight_map, total = {}, 0
    for gi, sd in enumerate(shards):
        fname = f"{basename}-{gi + 1:05d}-of-{n:05d}.safetensors"
        _write_safetensors(os.path.join(dirname, fname), sd, metadata)
        for k, arr in sd.items():
            weight_map[k] = fname
            total += np.asarray(arr, np.float32).nbytes
    with open(os.path.join(dirname, f"{basename}.safetensors.index.json"),
              "w") as f:
        json.dump({"metadata": {"total_size": total},
                   "weight_map": weight_map}, f, indent=1, sort_keys=True)


# ---------------------------------------------------------------------------
# family builders: (foreign state dict, expected native state dict)
# ---------------------------------------------------------------------------

def _r(rng, *shape):
    return rng.standard_normal(shape, dtype=np.float32)


def build_qwen3(rng):
    foreign, ref = {}, {}
    foreign["model.embed_tokens.weight"] = _r(rng, VOCAB, D)
    ref["embed"] = foreign["model.embed_tokens.weight"]
    per = {k: [] for k in ["ln1", "ln2", "wq", "wk", "wv", "wo",
                           "qn", "kn", "wi", "wg", "wom"]}
    for i in range(N_LAYERS):
        p = f"model.layers.{i}."
        ln1 = _r(rng, D); ln2 = _r(rng, D)
        wq = _r(rng, HEADS * HD, D); wk = _r(rng, KV * HD, D)
        wv = _r(rng, KV * HD, D); wo = _r(rng, D, HEADS * HD)
        qn = _r(rng, HD); kn = _r(rng, HD)
        wg = _r(rng, FF, D); wi = _r(rng, FF, D); wom = _r(rng, D, FF)
        foreign.update({
            p + "input_layernorm.weight": ln1,
            p + "post_attention_layernorm.weight": ln2,
            p + "self_attn.q_proj.weight": wq,
            p + "self_attn.k_proj.weight": wk,
            p + "self_attn.v_proj.weight": wv,
            p + "self_attn.o_proj.weight": wo,
            p + "self_attn.q_norm.weight": qn,
            p + "self_attn.k_norm.weight": kn,
            p + "mlp.gate_proj.weight": wg,
            p + "mlp.up_proj.weight": wi,
            p + "mlp.down_proj.weight": wom,
        })
        # expected native slices: torch Linear (out,in) -> ours (in,out);
        # HF rmsnorm weight w -> our scale = w - 1
        per["ln1"].append(ln1 - 1); per["ln2"].append(ln2 - 1)
        per["wq"].append(wq.T); per["wk"].append(wk.T)
        per["wv"].append(wv.T); per["wo"].append(wo.T)
        per["qn"].append(qn - 1); per["kn"].append(kn - 1)
        per["wi"].append(wi.T); per["wg"].append(wg.T)
        per["wom"].append(wom.T)
    dst = "seg0_p0."
    ref[dst + "ln1.scale"] = np.stack(per["ln1"])
    ref[dst + "ln2.scale"] = np.stack(per["ln2"])
    ref[dst + "attn.wq"] = np.stack(per["wq"])
    ref[dst + "attn.wk"] = np.stack(per["wk"])
    ref[dst + "attn.wv"] = np.stack(per["wv"])
    ref[dst + "attn.wo"] = np.stack(per["wo"])
    ref[dst + "attn.q_norm.scale"] = np.stack(per["qn"])
    ref[dst + "attn.k_norm.scale"] = np.stack(per["kn"])
    ref[dst + "mlp.wi"] = np.stack(per["wi"])
    ref[dst + "mlp.wg"] = np.stack(per["wg"])
    ref[dst + "mlp.wo"] = np.stack(per["wom"])
    foreign["model.norm.weight"] = _r(rng, D)
    ref["final_norm.scale"] = foreign["model.norm.weight"] - 1
    # tie_embeddings=True: no lm_head in the checkpoint, none natively
    return foreign, ref


def _whisper_block(rng, foreign, ref_acc, prefix, cross):
    ln1 = _r(rng, D); ln2 = _r(rng, D)
    foreign[prefix + "self_attn_layer_norm.weight"] = ln1
    foreign[prefix + "final_layer_norm.weight"] = ln2
    ref_acc.setdefault("ln1.scale", []).append(ln1 - 1)
    ref_acc.setdefault("ln2.scale", []).append(ln2 - 1)
    for src, dst in [("self_attn.q_proj.weight", "attn.wq"),
                     ("self_attn.k_proj.weight", "attn.wk"),
                     ("self_attn.v_proj.weight", "attn.wv"),
                     ("self_attn.out_proj.weight", "attn.wo")]:
        w = _r(rng, D, D)
        foreign[prefix + src] = w
        ref_acc.setdefault(dst, []).append(w.T)
    if cross:
        for src, dst in [("encoder_attn.q_proj.weight", "cross.wq"),
                         ("encoder_attn.k_proj.weight", "cross.wk"),
                         ("encoder_attn.v_proj.weight", "cross.wv"),
                         ("encoder_attn.out_proj.weight", "cross.wo")]:
            w = _r(rng, D, D)
            foreign[prefix + src] = w
            ref_acc.setdefault(dst, []).append(w.T)
        lnc = _r(rng, D)
        foreign[prefix + "encoder_attn_layer_norm.weight"] = lnc
        ref_acc.setdefault("ln_cross.scale", []).append(lnc - 1)
    fc1 = _r(rng, FF, D); fcg = _r(rng, FF, D); fc2 = _r(rng, D, FF)
    foreign[prefix + "fc1.weight"] = fc1
    foreign[prefix + "fc_gate.weight"] = fcg   # gated-MLP extension key
    foreign[prefix + "fc2.weight"] = fc2
    ref_acc.setdefault("mlp.wi", []).append(fc1.T)
    ref_acc.setdefault("mlp.wg", []).append(fcg.T)
    ref_acc.setdefault("mlp.wo", []).append(fc2.T)


def build_whisper(rng):
    foreign, ref = {}, {}
    foreign["model.decoder.embed_tokens.weight"] = _r(rng, VOCAB, D)
    ref["embed"] = foreign["model.decoder.embed_tokens.weight"]
    dec = {}
    for i in range(N_LAYERS):
        _whisper_block(rng, foreign, dec, f"model.decoder.layers.{i}.",
                       cross=True)
    for k, slices in dec.items():
        ref["seg0_p0." + k] = np.stack(slices)
    foreign["model.decoder.layer_norm.weight"] = _r(rng, D)
    ref["final_norm.scale"] = foreign["model.decoder.layer_norm.weight"] - 1
    proj = _r(rng, VOCAB, D)
    foreign["proj_out.weight"] = proj
    ref["unembed"] = proj.T
    enc = {}
    for i in range(N_ENC):
        _whisper_block(rng, foreign, enc, f"model.encoder.layers.{i}.",
                       cross=False)
    for k, slices in enc.items():
        ref["encoder.blocks." + k] = np.stack(slices)
    foreign["model.encoder.layer_norm.weight"] = _r(rng, D)
    ref["encoder.norm.scale"] = foreign["model.encoder.layer_norm.weight"] - 1
    return foreign, ref


def _resnet_bn(rng, foreign, ref, src, dst, c):
    w, b = _r(rng, c), _r(rng, c)
    mean, var = _r(rng, c), np.abs(_r(rng, c)) + 0.5
    foreign[src + "weight"] = w
    foreign[src + "bias"] = b
    foreign[src + "running_mean"] = mean
    foreign[src + "running_var"] = var
    ref[dst + "scale"] = w
    ref[dst + "bias"] = b
    ref[dst + "mean"] = mean
    ref[dst + "var"] = var


def build_resnet(rng):
    foreign, ref = {}, {}

    def conv(src, dst, cin, cout, k):
        w = _r(rng, cout, cin, k, k)                      # torch OIHW
        foreign[src] = w
        ref[dst] = np.transpose(w, (2, 3, 1, 0))          # ours HWIO

    conv("conv1.weight", "stem", 3, R_WIDTHS[0], 3)
    _resnet_bn(rng, foreign, ref, "bn1.", "bn_stem.", R_WIDTHS[0])
    cin = R_WIDTHS[0]
    for si, (w, n) in enumerate(zip(R_WIDTHS, R_BLOCKS)):
        for bi in range(n):
            src, dst = f"layer{si + 1}.{bi}.", f"s{si}b{bi}."
            stride = 2 if (si > 0 and bi == 0) else 1
            conv(src + "conv1.weight", dst + "conv1", cin, w, 3)
            conv(src + "conv2.weight", dst + "conv2", w, w, 3)
            _resnet_bn(rng, foreign, ref, src + "bn1.", dst + "bn1.", w)
            _resnet_bn(rng, foreign, ref, src + "bn2.", dst + "bn2.", w)
            if stride != 1 or cin != w:
                conv(src + "downsample.0.weight", dst + "proj", cin, w, 1)
                _resnet_bn(rng, foreign, ref, src + "downsample.1.",
                           dst + "bn_proj.", w)
            cin = w
    fc = _r(rng, R_CLASSES, R_WIDTHS[-1])
    foreign["fc.weight"] = fc
    ref["fc"] = fc.T
    foreign["fc.bias"] = _r(rng, R_CLASSES)
    ref["fc_b"] = foreign["fc.bias"]
    return foreign, ref


# ---------------------------------------------------------------------------

def main():
    rng = np.random.default_rng(20260807)

    qwen_dir = os.path.join(OUT, "qwen3-4b")
    whisper_dir = os.path.join(OUT, "whisper-tiny")
    resnet_dir = os.path.join(OUT, "resnet18")
    for d in (qwen_dir, whisper_dir, resnet_dir):
        os.makedirs(d, exist_ok=True)

    foreign, ref = build_qwen3(rng)
    meta = {"format": "repro-compat/1", "repro.family": "qwen3-4b",
            "repro.config": json.dumps({"arch_id": "qwen3-4b",
                                        "reduced": True})}
    # split mid-layer across two shards to exercise the index path
    names = list(foreign)
    half = len(names) // 2
    _write_sharded(qwen_dir,
                   [{k: foreign[k] for k in names[:half]},
                    {k: foreign[k] for k in names[half:]}], meta)
    np.savez(os.path.join(OUT, "qwen3-4b_reference.npz"), **ref)
    print(f"qwen3-4b: {len(foreign)} foreign tensors, sharded x2")

    foreign, ref = build_whisper(rng)
    meta = {"format": "repro-compat/1", "repro.family": "whisper-tiny",
            "repro.config": json.dumps({"arch_id": "whisper-tiny",
                                        "reduced": True})}
    _write_safetensors(os.path.join(whisper_dir, "model.safetensors"),
                       foreign, meta)
    np.savez(os.path.join(OUT, "whisper-tiny_reference.npz"), **ref)
    print(f"whisper-tiny: {len(foreign)} foreign tensors")

    foreign, ref = build_resnet(rng)
    meta = {"format": "repro-compat/1", "repro.family": "resnet18",
            "repro.config": json.dumps({"num_classes": R_CLASSES,
                                        "widths": list(R_WIDTHS),
                                        "blocks": list(R_BLOCKS)})}
    _write_safetensors(os.path.join(resnet_dir, "model.safetensors"),
                       foreign, meta)
    np.savez(os.path.join(OUT, "resnet18_reference.npz"), **ref)
    print(f"resnet18: {len(foreign)} foreign tensors")


if __name__ == "__main__":
    main()
