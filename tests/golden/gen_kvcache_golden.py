"""Golden-vector generator: paged KV scatter/gather vs a dense reference.

An independent numpy reference implementation of the page-boundary data
movement in ``repro.serving.kvcache`` — the page-table indirection is
done BY HAND (explicit per-position python loops over
``table[pos // page_size]``), deliberately sharing NO code with
``write_state`` / ``scatter_chunk`` / ``scatter_token`` /
``gather_state`` / ``zero_pages`` (which go through vectorized
``jnp.take_along_axis`` + ``.at[...].set``).

The synthetic pool mimics the paged transformer serving state
(:func:`repro.serving.paged_pool_init`): segment 0 carries paged
attention leaves ``(repeats, n_pages + 1, page_size, feat...)`` —
physical id ``n_pages`` is the null page — and segment 1 carries
per-slot SSM-like leaves ``(repeats, n_slots, ...)`` with no sequence
axis.  The script exercises:

- a whole-state install through a FRAGMENTED out-of-order page table
  whose last page is only partially filled;
- prefill-chunk scatters, including one that OVERWRITES already
  occupied pages end to end (last-write-wins, no blending);
- a decode-token scatter whose inactive row carries a null page table
  (its garbage row must land in the null page, never a live one);
- a page re-zero of freed pages;
- gathers back through fragmented tables that include null entries.

The fixture stores the reference pool/gather leaves verbatim (small
float32 arrays; JSON decimal repr round-trips them bit-exactly) plus
CRC32 pins of every leaf.  The consuming test
(``tests/test_kvcache.py``) rebuilds the same inputs, replays the
script through the REAL kvcache functions, and compares with
``assert_array_equal`` — bit-exact, no tolerance.

Run from the repo root to regenerate ``tests/golden/kvcache_golden.json``:

    python tests/golden/gen_kvcache_golden.py
"""
from __future__ import annotations

import json
import os
import zlib

import numpy as np

N_SLOTS = 2
PAGE_SIZE = 3
N_PAGES = 5                      # physical pages; id N_PAGES is the null page
MAX_PAGES = 2                    # page-table row width
DENSE_LEN = MAX_PAGES * PAGE_SIZE  # positions a full table row covers

#: leaf path -> full pool shape.  ``layers.{i}.{phase}.{name}``; segment 0
#: leaves are paged (page axis at 1, page_size axis at 2), segment 1
#: leaves are per-slot (slot axis at 1, no sequence axis) — the two
#: storage granularities of ``paged_pool_init``.
LEAVES = {
    "layers.0.0.k": (2, N_PAGES + 1, PAGE_SIZE, 4),
    "layers.0.0.v": (2, N_PAGES + 1, PAGE_SIZE, 4),
    "layers.1.0.conv": (1, N_SLOTS, 3, 2),
    "layers.1.0.state": (1, N_SLOTS, 2, 3, 2),
}
PAGED = ("layers.0.0.k", "layers.0.0.v")


def _dense_shapes(rows: int, length: int) -> dict:
    """Request-side (dense) leaf shapes for one op: paged leaves carry
    ``rows`` batch rows and a ``length``-position sequence axis; per-slot
    leaves just carry ``rows``."""
    out = {}
    for p, full in LEAVES.items():
        if p in PAGED:
            out[p] = [full[0], rows, length] + list(full[3:])
        else:
            out[p] = [full[0], rows] + list(full[2:])
    return out


#: The scripted op sequence.  Page tables are deliberately fragmented and
#: out of order; op 2 fully overwrites pages occupied by ops 0-1; op 3's
#: row 1 is inactive (all-null table) so its write must land in the null
#: page; op 4 re-zeroes two freed pages.
SCRIPT = [
    {"op": "write_state", "slot": 0, "table": [3, 1], "l_buf": 5,
     "seed": 10, "dense": _dense_shapes(1, 5)},
    {"op": "scatter_chunk", "table": [0, 4], "start": 2, "length": 3,
     "seed": 11, "dense": _dense_shapes(1, DENSE_LEN)},
    {"op": "scatter_chunk", "table": [3, 0], "start": 0, "length": 6,
     "seed": 12, "dense": _dense_shapes(1, DENSE_LEN)},
    {"op": "scatter_token", "tables": [[1, 2], [N_PAGES, N_PAGES]],
     "pos": [4, 0], "seed": 13, "dense": _dense_shapes(N_SLOTS, DENSE_LEN)},
    {"op": "zero_pages", "pages": [3, 1]},
]

#: Page tables to gather back through — fragmented, out of order, and
#: with null entries (which read whatever the null page holds; the real
#: engine's decode math masks those positions away).
GATHERS = [
    [[3, 1], [0, N_PAGES]],
    [[N_PAGES, N_PAGES], [4, 2]],
]


def leaf_values(path: str, shape, seed: int) -> np.ndarray:
    """Deterministic float32 content per (leaf path, seed) — the same
    recipe the consuming test uses, so generator and test agree on inputs
    without sharing code with the implementation under test."""
    rng = np.random.default_rng(zlib.crc32(path.encode()) + seed)
    return rng.standard_normal(shape).astype(np.float32)


def crc(a: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(a, np.float32).tobytes())


def apply_script(pool: dict) -> dict:
    """Replay ``SCRIPT`` over ``pool`` (leaf path -> array, mutated in
    place) with hand-done page-table indirection: every position is
    routed through ``table[pos // PAGE_SIZE]`` one at a time."""
    for op in SCRIPT:
        if op["op"] == "zero_pages":
            for p in PAGED:
                for page in op["pages"]:
                    pool[p][:, page] = 0.0
            continue
        dense = {p: leaf_values(p, tuple(s), op["seed"])
                 for p, s in op["dense"].items()}
        if op["op"] == "write_state":
            for p in PAGED:
                for pos in range(op["l_buf"]):
                    page = op["table"][pos // PAGE_SIZE]
                    pool[p][:, page, pos % PAGE_SIZE] = dense[p][:, 0, pos]
            for p in LEAVES:
                if p not in PAGED:
                    pool[p][:, op["slot"]] = dense[p][:, 0]
        elif op["op"] == "scatter_chunk":
            for p in PAGED:
                for pos in range(op["start"], op["start"] + op["length"]):
                    page = op["table"][pos // PAGE_SIZE]
                    pool[p][:, page, pos % PAGE_SIZE] = dense[p][:, 0, pos]
        elif op["op"] == "scatter_token":
            for p in PAGED:
                for row, pos in enumerate(op["pos"]):
                    page = op["tables"][row][pos // PAGE_SIZE]
                    pool[p][:, page, pos % PAGE_SIZE] = dense[p][:, row, pos]
            for p in LEAVES:
                if p not in PAGED:
                    pool[p] = dense[p].copy()   # decode replaces wholesale
    return pool


def gather_reference(pool: dict, tables) -> dict:
    """Dense view of ``tables`` rows, one position at a time by hand."""
    out = {}
    for p in PAGED:
        full = LEAVES[p]
        got = np.empty((full[0], len(tables), DENSE_LEN) + tuple(full[3:]),
                       np.float32)
        for row, trow in enumerate(tables):
            for pos in range(DENSE_LEN):
                got[:, row, pos] = pool[p][:, trow[pos // PAGE_SIZE],
                                           pos % PAGE_SIZE]
        out[p] = got
    return out


def main() -> dict:
    pool = {p: leaf_values(p, s, seed=0) for p, s in sorted(LEAVES.items())}
    pool = apply_script(pool)
    gathers = [gather_reference(pool, t) for t in GATHERS]
    return {
        "n_slots": N_SLOTS,
        "page_size": PAGE_SIZE,
        "n_pages": N_PAGES,
        "max_pages": MAX_PAGES,
        "leaves": {p: list(s) for p, s in sorted(LEAVES.items())},
        "paged": list(PAGED),
        "script": SCRIPT,
        "gathers": GATHERS,
        "pool": {p: pool[p].tolist() for p in sorted(pool)},
        "pool_crc": {p: crc(pool[p]) for p in sorted(pool)},
        "gather": [{p: g[p].tolist() for p in sorted(g)} for g in gathers],
        "gather_crc": [{p: crc(g[p]) for p in sorted(g)} for g in gathers],
    }


if __name__ == "__main__":
    out = os.path.join(os.path.dirname(__file__), "kvcache_golden.json")
    with open(out, "w") as f:
        json.dump(main(), f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {out}")
