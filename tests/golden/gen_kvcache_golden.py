"""Golden-vector generator: KV-pool scatter/gather vs a dense reference.

An independent reference implementation of the slot-boundary data
movement in ``repro.serving.kvcache`` — plain numpy slice assignment on
dense arrays, deliberately sharing NO code with ``write_slot`` /
``read_slot`` (which go through ``jnp.take`` + ``.at[...].set``).  The
synthetic pool mimics the transformer serving-state pytree: a ``layers``
list of per-phase leaf dicts with the slot axis at 1 (leaves are stacked
``(repeats, slot, max_len, ...)``) plus an ``enc_out`` leaf with the slot
axis at 0.

The fixture pins CRC32 checksums of every pool leaf after a scripted
sequence of slot writes (including an overwrite of an occupied slot — the
no-stale-bits property) and of every gathered leaf of each slot read.
The consuming test (``tests/test_kvcache.py``) rebuilds the same inputs,
replays the script through the real scatter/gather, and compares
checksums — bit-exact, no tolerance.

Run from the repo root to regenerate ``tests/golden/kvcache_golden.json``:

    python tests/golden/gen_kvcache_golden.py
"""
from __future__ import annotations

import json
import os
import zlib

import numpy as np

N_SLOTS = 3
MAX_LEN = 6

#: leaf path -> full pool shape.  ``layers.{i}.{phase}.{name}`` leaves
#: carry the slot axis at 1; ``enc_out`` at 0.  Shapes are deliberately
#: heterogeneous (attention-like 4-D, conv/ssm-like 3-D and 4-D ranks).
LEAVES = {
    "layers.0.0.k": (2, N_SLOTS, MAX_LEN, 4),
    "layers.0.0.v": (2, N_SLOTS, MAX_LEN, 4),
    "layers.1.0.conv": (1, N_SLOTS, 3, 2),
    "layers.1.0.state": (1, N_SLOTS, 2, 3, 2),
    "enc_out": (N_SLOTS, 4, 2),
}

#: (slot, state_seed) per write, in order.  Slot 1 is written twice: the
#: second write must fully overwrite the first occupant's bits.
SCRIPT = [(1, 10), (0, 11), (1, 12)]


def leaf_values(path: str, shape, seed: int) -> np.ndarray:
    """Deterministic float32 content per (leaf path, seed) — the same
    recipe the consuming test uses, so generator and test agree on inputs
    without sharing code with the implementation under test."""
    rng = np.random.default_rng(zlib.crc32(path.encode()) + seed)
    return rng.standard_normal(shape).astype(np.float32)


def request_shape(path: str, shape):
    """The batch-1 (single-request) version of a pool leaf shape."""
    axis = 0 if path == "enc_out" else 1
    return tuple(1 if i == axis else d for i, d in enumerate(shape))


def crc(a: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(a, np.float32).tobytes())


def main() -> dict:
    pool = {p: leaf_values(p, s, seed=0) for p, s in sorted(LEAVES.items())}
    for slot, sseed in SCRIPT:
        for p, s in sorted(LEAVES.items()):
            src = leaf_values(p, request_shape(p, s), seed=sseed)
            if p == "enc_out":
                pool[p][slot] = src[0]          # dense reference scatter
            else:
                pool[p][:, slot] = src[:, 0]
    reads = {}
    for slot in range(N_SLOTS):
        for p in sorted(LEAVES):
            got = (pool[p][slot:slot + 1] if p == "enc_out"
                   else pool[p][:, slot:slot + 1])   # dense reference gather
            reads[f"slot{slot}.{p}"] = crc(got)
    return {
        "n_slots": N_SLOTS,
        "max_len": MAX_LEN,
        "leaves": {p: list(s) for p, s in sorted(LEAVES.items())},
        "script": [list(op) for op in SCRIPT],
        "pool_crc": {p: crc(a) for p, a in pool.items()},
        "read_crc": reads,
    }


if __name__ == "__main__":
    out = os.path.join(os.path.dirname(__file__), "kvcache_golden.json")
    with open(out, "w") as f:
        json.dump(main(), f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {out}")
