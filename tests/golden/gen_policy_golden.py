"""Golden-vector generator: MoE per-expert path resolution + sensitivity.

An independent reference implementation (stdlib ``fnmatch`` + plain numpy,
deliberately sharing NO code with ``repro.core.policy`` /
``repro.core.sensitivity``) that pins two subsystems:

1. **Per-expert policy resolution** — ordered first-match glob rules over
   MoE expert paths (``blocks.{i}.mlp.expert{k}.{wi,wg,wo}``), resolved by
   a six-line reference resolver.  The consuming test
   (``tests/test_policy.py``) replays each case through ``NumericsPolicy``
   and compares the resolved config tags.
2. **Sensitivity coefficients** — fixed-PRNG operand matrices pushed
   through a numpy reimplementation of the split-float segmented product
   (bf16 round-to-nearest-even via the integer carry trick) to produce
   per-site ``out_rms``, propagation coefficients ``alpha``, per-design
   local MRED and local rms relative error, the random-tangent gain
   coefficients (the JVP probe, reimplemented as a plain numpy matmul of
   the same fixed-seed tangent), the downstream chain-gain products, the
   head's MRED tail factor, and the gain-aware composed prediction
   ``sum tail * alpha * G * local_rms``.  The consuming test
   (``tests/test_sensitivity.py``) rebuilds the model through the real
   operand tap and compares.

Run from the repo root to regenerate ``tests/golden/policy_golden.json``:

    python tests/golden/gen_policy_golden.py
"""
from __future__ import annotations

import fnmatch
import json
import os

import numpy as np

CONFIG_TAGS = {
    "exact": {"mode": "exact", "compute_dtype": "float32"},
    "seg1": {"mode": "segmented", "seg_passes": 1, "backend": "xla"},
    "seg2": {"mode": "segmented", "seg_passes": 2, "backend": "xla"},
    "seg3": {"mode": "segmented", "seg_passes": 3, "backend": "xla"},
    "ac44": {"mode": "emulated", "multiplier": "AC4-4", "seg_n": 4},
}


# ---------------------------------------------------------------------------
# part 1: per-expert path resolution (reference resolver: first match wins)
# ---------------------------------------------------------------------------

def resolve_tag(rules, default_tag, path):
    for pattern, tag in rules:
        if fnmatch.fnmatchcase(path, pattern):
            return tag
    return default_tag


def expert_site_paths(block, n_experts, names=("wi", "wg", "wo")):
    return [f"blocks.{block}.mlp.expert{k}.{n}"
            for k in range(n_experts) for n in names]


RESOLUTION_CASES = [
    {
        "label": "one-expert-approximate",
        "rules": [["blocks.*.mlp.expert0.*", "seg1"]],
        "default": "exact",
        "paths": expert_site_paths(0, 2) + expert_site_paths(7, 2),
    },
    {
        "label": "per-projection-split",
        "rules": [["blocks.*.mlp.expert*.wo", "seg3"],
                  ["blocks.*.mlp.expert*.w?", "seg1"]],
        "default": "exact",
        "paths": expert_site_paths(3, 3),
    },
    {
        "label": "block-specific-beats-broad",
        "rules": [["blocks.0.mlp.expert1.wi", "ac44"],
                  ["blocks.0.mlp.*", "seg2"],
                  ["blocks.*.mlp.expert*.*", "seg1"]],
        "default": "exact",
        "paths": expert_site_paths(0, 2) + expert_site_paths(1, 2)
        + ["blocks.0.mlp.shared.wi", "lm_head"],
    },
    {
        "label": "expert-range-set",
        "rules": [["blocks.*.mlp.expert[01].*", "seg3"],
                  ["blocks.*.mlp.expert[23].*", "seg1"]],
        "default": "exact",
        "paths": expert_site_paths(5, 4),
    },
]


def build_resolution_cases():
    out = []
    for case in RESOLUTION_CASES:
        expected = {p: resolve_tag(case["rules"], case["default"], p)
                    for p in case["paths"]}
        out.append({**case, "expected": expected})
    return out


# ---------------------------------------------------------------------------
# part 2: sensitivity fixtures (numpy split-float + rms/alpha/MRED)
# ---------------------------------------------------------------------------

def bf16_rne(x: np.ndarray) -> np.ndarray:
    """fp32 -> bf16 (stored as fp32): round-to-nearest-even by integer carry."""
    bits = np.asarray(x, np.float32).view(np.uint32)
    rounded = (bits + np.uint32(0x7FFF) + ((bits >> np.uint32(16))
                                           & np.uint32(1))) & np.uint32(0xFFFF0000)
    return rounded.view(np.float32)


def split_hi_lo(x):
    hi = bf16_rne(x)
    lo = bf16_rne(np.asarray(x, np.float32) - hi)
    return hi, lo


def segmented_matmul(x, w, passes):
    xh, xl = split_hi_lo(x)
    wh, wl = split_hi_lo(w)
    out = np.matmul(xh.astype(np.float32), wh.astype(np.float32),
                    dtype=np.float32)
    if passes >= 2:
        out = out + np.matmul(xl.astype(np.float32), wh.astype(np.float32),
                              dtype=np.float32)
    if passes >= 3:
        out = out + np.matmul(xh.astype(np.float32), wl.astype(np.float32),
                              dtype=np.float32)
    return out


def mred(approx, exact):
    approx = np.asarray(approx, np.float64).ravel()
    exact = np.asarray(exact, np.float64).ravel()
    mask = np.isfinite(exact) & np.isfinite(approx) & (exact != 0)
    return float(np.mean(np.abs(approx[mask] - exact[mask])
                         / np.abs(exact[mask])))


PROBE_SEED = 20260730  # must match repro.core.sensitivity.PROBE_SEED


def rms(a):
    a = np.asarray(a, np.float64)
    return float(np.sqrt(np.mean(a * a)))


def probe_gain_ref(x, w):
    """Reference gain: rms(v @ w)/rms(v) for the fixed-seed tangent the
    JVP probe uses (the map is linear, so the JVP of t -> t @ w IS v @ w)."""
    v = np.random.default_rng(PROBE_SEED).standard_normal(
        np.asarray(x).shape).astype(np.float32)
    return rms(np.matmul(v, np.asarray(w, np.float32), dtype=np.float32)) \
        / rms(v)


def build_sensitivity_fixture(seed=20260730):
    """A 3-site chain (the output of one site feeds the next) with fixed-
    PRNG operands; expected alpha / gains / tail / local errors / composed
    prediction for the gain-aware model."""
    rng = np.random.default_rng(seed)
    shapes = [(12, 8, 6), (12, 6, 10), (12, 10, 4)]
    names = ["s0", "s1", "s2"]
    h = rng.standard_normal((shapes[0][0], shapes[0][1])).astype(np.float32)
    sites = []
    head_exact = None
    for name, (m, k, n) in zip(names, shapes):
        w = (rng.standard_normal((k, n)) / np.sqrt(k)).astype(np.float32)
        exact = h.astype(np.float64) @ w.astype(np.float64)
        local = {f"seg{p}": mred(segmented_matmul(h, w, p), exact)
                 for p in (1, 2, 3)}
        local_rms = {
            f"seg{p}": rms(segmented_matmul(h, w, p).astype(np.float64)
                           - exact) / rms(exact)
            for p in (1, 2, 3)}
        sites.append({
            "path": name,
            "x": [[float(v) for v in row] for row in h],
            "w": [[float(v) for v in row] for row in w],
            "out_rms": rms(exact),
            "local_mred": local,
            "local_rms": local_rms,
            "site_gain": probe_gain_ref(h, w),
            "chained": name != "s0",  # each site consumes the previous output
        })
        h = exact.astype(np.float32)  # exact f32 chain, like the eager pass
        head_exact = exact
    net_rms = sites[-1]["out_rms"]
    for s in sites:
        s["alpha"] = s["out_rms"] / net_rms
    # downstream chain-gain products: G_i = prod of site_gain over the
    # chained successors (the whole suffix here — it is a pure chain)
    for i, s in enumerate(sites):
        g = 1.0
        for nxt in sites[i + 1:]:
            if not nxt["chained"]:
                break
            g *= nxt["site_gain"]
        s["downstream_gain"] = g
    # MRED tail factor at the head: sqrt(2/pi) * mean(1/|y|) * rms(y)
    y = head_exact.ravel()
    y = y[y != 0.0]
    tail = float(np.sqrt(2.0 / np.pi) * np.mean(1.0 / np.abs(y)) * rms(y))
    # gain-aware composed prediction for a mixed assignment
    assignment = {"s0": "seg1", "s1": "seg3", "s2": "seg2"}
    composed = sum(tail * s["alpha"] * s["downstream_gain"]
                   * s["local_rms"][assignment[s["path"]]] for s in sites)
    return {"seed": seed, "sites": sites, "assignment": assignment,
            "tail_factor": tail, "composed_prediction": composed}


def main():
    out = {
        "resolution_cases": build_resolution_cases(),
        "config_tags": CONFIG_TAGS,
        "sensitivity": build_sensitivity_fixture(),
    }
    path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "policy_golden.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    n_res = sum(len(c["expected"]) for c in out["resolution_cases"])
    print(f"wrote {path}: {n_res} resolution expectations, "
          f"{len(out['sensitivity']['sites'])} sensitivity sites")


if __name__ == "__main__":
    main()
