"""Deterministic simulation rig for the serving engine.

The engine's scheduling/batching/paging logic is model-agnostic behind
the ``ModelRunner`` duck type (``repro.serving.engine``), so it can be
driven here by :class:`StubRunner` — a pure-Python "language model" whose
next token is a hash of the FULL context (prompt plus every token
generated so far) — with zero jax compilation.

The stub is a real differential probe for the paged KV cache: its "KV
pages" store the context tokens themselves (as ``token + 1``, so 0 means
*empty cell*), and every decode step **reconstructs the context by
reading back through the page tables** before hashing it.  Any paging
bug — two live requests sharing a page, a wrong page-table entry, a
freed page reused without re-zeroing, a chunk landing at the wrong
offset — corrupts the reconstructed context and flips the emitted
tokens, so the bit-equality assertions in ``tests/test_serving_paging.py``
catch it.  The hash's key property still drives the invariance tests:
the token stream depends ONLY on the request's own prompt, never on
which row/pages it landed in or who shared the batch — exactly the
bit-exactness contract the real ``TransformerRunner`` honors in
``tests/test_serving_numerics.py``.

Time is a :class:`repro.serving.FakeClock` advanced by the script, so
aging/starvation behaviour is exact, not wall-clock-flaky.
"""
from __future__ import annotations

import zlib

import numpy as np

from repro.serving import Engine, FakeClock, TierSpec, pages_for


def stub_token(context, vocab: int = 97) -> int:
    """The stub LM: greedy next token given the FULL ``context`` (prompt
    plus generated-so-far) — a pure function of the context bits,
    slot/page/batch-agnostic."""
    h = zlib.crc32(np.asarray(context, np.int32).tobytes())
    return int((h ^ (h >> 7)) % vocab)


def stub_reference(prompt, n: int, vocab: int = 97) -> np.ndarray:
    """The solo-generate reference: ``n`` greedy tokens for ``prompt``
    (k=0 is the prefill token; each later token conditions on everything
    before it, mirroring autoregressive decode)."""
    ctx = list(np.asarray(prompt, np.int32))
    out = []
    for _ in range(n):
        t = stub_token(ctx, vocab)
        out.append(t)
        ctx.append(t)
    return np.asarray(out, np.int32)


class StubRunner:
    """A paged ``ModelRunner`` with no model: the page pool is a plain
    ``(n_pages, page_size)`` int array holding context tokens as
    ``token + 1`` (0 = empty cell), and every prefill chunk / decode step
    writes and then re-reads the context THROUGH the page tables.

    Hard invariants asserted inline (they make paging bugs loud even
    when the token comparison would happen to pass):

    - no write ever lands in the null page (``n_pages``);
    - a write only ever lands in an EMPTY cell — the engine must have
      re-zeroed freed pages before reuse, so stale bits from a previous
      occupant trip the assert;
    - the re-read context has no holes (every cell of the live prefix is
      populated).

    Records every prefill/decode call for white-box assertions.
    """

    def __init__(self, n_slots: int = 4, max_len: int = 64, *,
                 page_size: int = 4, pages=None, prefill_chunk: int = 32,
                 vocab: int = 97):
        self.n_slots = n_slots
        self.max_len = max_len
        self.page_size = page_size
        self.max_pages = pages_for(max_len, page_size)
        self.n_pages = int(pages if pages is not None
                           else n_slots * self.max_pages)
        self.prefill_chunk = prefill_chunk
        self.chunked = True
        self.vocab = vocab
        self.store = np.zeros((self.n_pages, page_size), np.int64)
        self.prefill_calls = []  # (prompt, start, end) per chunk
        self.decode_calls = []   # (tokens, pos) per decode batch
        self.decode_tables = []  # page tables per decode batch

    def pages_for(self, n_positions: int) -> int:
        return pages_for(n_positions, self.page_size)

    # -- paged context store -------------------------------------------------

    def _write(self, table_row, pos: int, token: int) -> None:
        page = int(table_row[pos // self.page_size])
        off = pos % self.page_size
        assert page != self.n_pages, \
            f"write at position {pos} routed to the null page"
        cell = self.store[page, off]
        assert cell == 0, (
            f"stale bits: page {page} offset {off} still holds token "
            f"{cell - 1} — freed pages must be re-zeroed before reuse")
        self.store[page, off] = token + 1

    def _read_context(self, table_row, n: int) -> np.ndarray:
        pages = np.asarray(table_row[:self.pages_for(n)], int)
        flat = self.store[pages].reshape(-1)[:n]
        assert (flat > 0).all(), \
            "context hole: empty cell inside the live prefix"
        return (flat - 1).astype(np.int64)

    # -- ModelRunner protocol ------------------------------------------------

    def prefill_chunk_step(self, prompt, start: int, end: int, table_row):
        prompt = np.asarray(prompt, np.int32)
        self.prefill_calls.append((prompt.copy(), int(start), int(end)))
        for i in range(int(start), int(end)):
            self._write(table_row, i, int(prompt[i]))
        if int(end) == prompt.shape[0]:
            ctx = self._read_context(table_row, int(end))
            np.testing.assert_array_equal(ctx, prompt)  # paging round-trip
            return stub_token(ctx, self.vocab)
        return None

    def prefill_full(self, slot: int, prompt, table_row):
        # the stub has no recurrent state; exercise the same paged writes
        return self.prefill_chunk_step(prompt, 0,
                                       np.asarray(prompt).shape[0], table_row)

    def decode(self, tokens, pos, tables):
        tokens = np.asarray(tokens, np.int32)
        pos = np.asarray(pos, np.int32)
        tables = np.asarray(tables, np.int32)
        self.decode_calls.append((tokens.copy(), pos.copy()))
        self.decode_tables.append(tables.copy())
        out = np.zeros(self.n_slots, np.int32)
        for slot in range(self.n_slots):
            if tables[slot, 0] == self.n_pages:  # inactive row: null table
                continue
            self._write(tables[slot], int(pos[slot]), int(tokens[slot]))
            ctx = self._read_context(tables[slot], int(pos[slot]) + 1)
            out[slot] = stub_token(ctx, self.vocab)
        return out

    def zero_pages(self, pages) -> None:
        for p in pages:
            self.store[int(p)] = 0


def make_stub_engine(tiers=(TierSpec("a"),), slots: int = 2,
                     max_len: int = 64, aging=None, *, page_size: int = 4,
                     pages=None, prefill_chunk: int = 32):
    """One stub lane per tier -> (engine, clock, {tier: StubRunner})."""
    clock = FakeClock()
    runners = {t.name: StubRunner(n_slots=slots, max_len=max_len,
                                  page_size=page_size, pages=pages,
                                  prefill_chunk=prefill_chunk)
               for t in tiers}
    eng = Engine(runners, tiers, clock=clock, aging=aging)
    return eng, clock, runners


def run_scripted(eng: Engine, clock: FakeClock, script,
                 dt: float = 1.0, max_steps: int = 10_000, on_step=None):
    """Drive the engine through a scripted arrival schedule.

    ``script`` is an iterable of per-step submission lists: at step i the
    clock advances by ``dt``, every kwargs dict in ``script[i]`` is
    submitted, then the engine steps once.  After the script runs out the
    engine drains (still advancing the clock).  ``on_step(eng)``, when
    given, runs after every step (invariant checkers).  Returns
    ``(requests, events)`` in submission/emission order.
    """
    reqs, events = [], []
    for submits in script:
        clock.advance(dt)
        for kw in submits:
            reqs.append(eng.submit(**kw))
        events.extend(eng.step())
        if on_step is not None:
            on_step(eng)
    steps = 0
    while not eng.idle:
        if steps >= max_steps:
            raise AssertionError(f"engine did not drain in {max_steps} steps")
        clock.advance(dt)
        events.extend(eng.step())
        if on_step is not None:
            on_step(eng)
        steps += 1
    return reqs, events
