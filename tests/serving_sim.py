"""Deterministic simulation rig for the serving engine.

The engine's scheduling/batching/slot logic is model-agnostic behind the
``ModelRunner`` duck type (``repro.serving.engine``), so it can be driven
here by :class:`StubRunner` — a pure-Python "language model" whose next
token is a hash of ``(prompt bytes, absolute position)`` — with zero jax
compilation.  That makes every engine behaviour (admission order,
mid-decode joins, retirement, slot reuse, starvation-freedom) assertable
in milliseconds, and the hash's key property drives the invariance tests:
the token stream depends ONLY on the request's own prompt and position,
never on which slot it landed in or who shared the batch — exactly the
bit-exactness contract the real ``TransformerRunner`` is proven to honor
in ``tests/test_serving_numerics.py``.

Time is a :class:`repro.serving.FakeClock` advanced by the script, so
aging/starvation behaviour is exact, not wall-clock-flaky.
"""
from __future__ import annotations

import zlib

import numpy as np

from repro.serving import Engine, FakeClock, TierSpec


def stub_token(prompt: np.ndarray, pos: int, vocab: int = 97) -> int:
    """The stub LM: next token after absolute position ``pos`` given
    ``prompt`` — a pure function of (prompt, pos), slot/batch-agnostic."""
    h = zlib.crc32(np.asarray(prompt, np.int32).tobytes())
    return int((h + 2654435761 * (pos + 1)) % vocab)


def stub_reference(prompt, n: int, vocab: int = 97) -> np.ndarray:
    """The solo-generate reference: ``n`` greedy tokens for ``prompt``.
    Token k conditions through absolute position ``len(prompt) - 1 + k``
    (k=0 is the prefill token), mirroring the engine's position
    bookkeeping."""
    prompt = np.asarray(prompt, np.int32)
    L = prompt.shape[0]
    return np.asarray([stub_token(prompt, L - 1 + k, vocab)
                       for k in range(n)], np.int32)


class StubRunner:
    """A ``ModelRunner`` with no model: per-slot state is just the
    request's prompt, and decode hashes (prompt, pos) per active slot.
    Records every prefill/decode call for white-box assertions."""

    def __init__(self, n_slots: int = 4, max_len: int = 64, vocab: int = 97):
        self.n_slots = n_slots
        self.max_len = max_len
        self.vocab = vocab
        self.slots = {}                 # slot -> prompt array
        self.prefill_calls = []         # list of prompt copies
        self.decode_calls = []          # list of (tokens, pos) copies

    def prefill(self, prompt):
        prompt = np.asarray(prompt, np.int32)
        self.prefill_calls.append(prompt.copy())
        return (stub_token(prompt, prompt.shape[0] - 1, self.vocab),
                {"prompt": prompt.copy()})

    def write_slot(self, slot: int, state) -> None:
        self.slots[slot] = state["prompt"]

    def decode(self, tokens, pos):
        tokens = np.asarray(tokens, np.int32)
        pos = np.asarray(pos, np.int32)
        self.decode_calls.append((tokens.copy(), pos.copy()))
        out = np.zeros(self.n_slots, np.int32)
        for slot, prompt in self.slots.items():
            out[slot] = stub_token(prompt, int(pos[slot]), self.vocab)
        return out


def make_stub_engine(tiers=(TierSpec("a"),), slots: int = 2,
                     max_len: int = 64, aging=None):
    """One stub lane per tier -> (engine, clock, {tier: StubRunner})."""
    clock = FakeClock()
    runners = {t.name: StubRunner(n_slots=slots, max_len=max_len)
               for t in tiers}
    eng = Engine(runners, tiers, clock=clock, aging=aging)
    return eng, clock, runners


def run_scripted(eng: Engine, clock: FakeClock, script,
                 dt: float = 1.0, max_steps: int = 10_000):
    """Drive the engine through a scripted arrival schedule.

    ``script`` is an iterable of per-step submission lists: at step i the
    clock advances by ``dt``, every kwargs dict in ``script[i]`` is
    submitted, then the engine steps once.  After the script runs out the
    engine drains (still advancing the clock).  Returns
    ``(requests, events)`` in submission/emission order.
    """
    reqs, events = [], []
    for submits in script:
        clock.advance(dt)
        for kw in submits:
            reqs.append(eng.submit(**kw))
        events.extend(eng.step())
    steps = 0
    while not eng.idle:
        if steps >= max_steps:
            raise AssertionError(f"engine did not drain in {max_steps} steps")
        clock.advance(dt)
        events.extend(eng.step())
        steps += 1
    return reqs, events
