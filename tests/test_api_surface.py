"""Public API surface: ``repro.numerics`` / ``repro.session`` exports and
signatures are pinned by ``tests/golden/api_surface.json`` — undeclared
drift fails here (and in CI via ``tools/check_api.py``).  Intentional
changes regenerate the snapshot:

    PYTHONPATH=src python tools/check_api.py --write
"""
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _check_api():
    sys.path.insert(0, os.path.join(REPO, "tools"))
    try:
        import check_api
    finally:
        sys.path.pop(0)
    return check_api


def test_api_surface_matches_golden():
    check_api = _check_api()
    with open(check_api.GOLDEN) as f:
        golden = json.load(f)
    current = check_api.snapshot()
    assert current == golden, (
        "public API drift in repro.numerics / repro.session — if "
        "intentional, run: PYTHONPATH=src python tools/check_api.py --write")


def test_api_surface_covers_the_scope_and_session_entry_points():
    """Guard against the snapshot rotting into an empty file: the names the
    redesign is built on must be present."""
    check_api = _check_api()
    current = check_api.snapshot()
    for name in ("numerics_scope", "layer_scope", "nmatmul",
                 "NumericsPolicy", "current_path"):
        assert name in current["repro.numerics"], name
    assert "Session" in current["repro.session"]
    methods = current["repro.session"]["Session"]["methods"]
    for m in ("generate", "dryrun", "auto_configure", "ppa_report"):
        assert m in methods, m
