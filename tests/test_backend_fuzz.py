"""Backend-equivalence fuzz: random shapes/dtypes/configs through
``afpm_matmul`` on ``interpret`` vs ``xla`` (and ``pallas`` when a TPU is
attached), asserting ulp-bounded agreement.

Parametrized over the dispatch tuning-table shape buckets
(``small``/``medium``/``large``), so every (backend, bucket) block-size
entry is exercised by at least one case — including multi-block grids,
where the accumulation order differs from the single-dot oracle and
agreement is ulp-bounded rather than bit-exact (compare
tests/test_kernels_dispatch.py, which pins the single-block case
bit-for-bit).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import dispatch

# draw ranges per bucket: the bucketed dim is the max extent, the other
# dims stay small so interpreter-mode grids remain cheap to simulate
BUCKET_RANGES = {"small": (9, 256), "medium": (257, 1024),
                 "large": (1025, 1536)}

# agreement bound: ulps of the LARGEST output magnitude — multi-block fp32
# accumulation reorders sums, so per-element wobble scales with the
# accumulated magnitude, not the (possibly cancelled-to-tiny) element value
ULP_BOUND = 64


def _backends():
    out = ["interpret", "xla"]
    if jax.default_backend() == "tpu":
        out.append("pallas")
    return out


def _assert_ulp_close(got, want, trials_id):
    got = np.asarray(got, np.float32)
    want = np.asarray(want, np.float32)
    assert got.shape == want.shape, trials_id
    assert np.isfinite(got).all() and np.isfinite(want).all(), trials_id
    scale = np.float32(max(np.max(np.abs(want)), np.finfo(np.float32).tiny))
    tol = ULP_BOUND * np.spacing(scale)
    worst = np.max(np.abs(got - want))
    assert worst <= tol, (trials_id, float(worst), float(tol))


@pytest.mark.parametrize("bucket", sorted(BUCKET_RANGES))
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_matmul_backends_agree_across_buckets(bucket, dtype, rng):
    lo, hi = BUCKET_RANGES[bucket]
    n_trials = 3 if bucket == "small" else 2
    for t in range(n_trials):
        # one axis lands in the bucket, the others stay small; the bucketed
        # axis rotates through M / K / N so contraction-heavy and
        # output-heavy grids are both covered
        big = int(rng.integers(lo, hi + 1))
        small_dims = [int(rng.integers(3, 48)) for _ in range(2)]
        dims = small_dims[:]
        dims.insert(t % 3, big)
        M, K, N = dims
        assert dispatch.shape_bucket(M, K, N) == bucket
        passes = int(rng.integers(1, 4))
        batched = bool(rng.integers(0, 2)) and bucket == "small"
        lead = (2,) if batched else ()
        x = jnp.asarray(rng.standard_normal(lead + (M, K)),
                        jnp.dtype(dtype)).astype(jnp.float32)
        w = jnp.asarray(rng.standard_normal((K, N)),
                        jnp.dtype(dtype)).astype(jnp.float32)
        outs = {b: dispatch.matmul(x, w, passes, backend=b)
                for b in _backends()}
        want = outs.pop("xla")
        for b, got in outs.items():
            _assert_ulp_close(got, want, (bucket, dtype, t, b, (M, K, N),
                                          passes))


@pytest.mark.parametrize("bucket", sorted(BUCKET_RANGES))
def test_bucketed_block_sizes_actually_selected(bucket, rng):
    """The fuzz shapes must hit the tuning-table row they claim to cover."""
    lo, hi = BUCKET_RANGES[bucket]
    m = int(rng.integers(lo, hi + 1))
    blocks = dispatch.matmul_block_sizes("interpret", m, 8, 8)
    assert blocks == dispatch.MATMUL_BLOCKS[("interpret", bucket)]


def test_elementwise_multiply_backends_agree_fuzz(rng):
    """Random shapes/configs through the bit-level elementwise kernel:
    interpret and xla must agree BIT-exactly (same scalar datapath)."""
    from repro.core.afpm import AFPMConfig

    for _ in range(4):
        shape = tuple(int(rng.integers(1, 40))
                      for _ in range(int(rng.integers(1, 4))))
        n = int(rng.integers(3, 8))
        mode = "acl" if rng.integers(0, 2) else "ac"
        cfg = AFPMConfig(n=n, mode=mode)
        x = jnp.asarray(rng.standard_normal(shape) * 4, jnp.float32)
        y = jnp.asarray(rng.standard_normal(shape) * 4, jnp.float32)
        got = dispatch.multiply(x, y, cfg, backend="interpret")
        want = dispatch.multiply(x, y, cfg, backend="xla")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
