"""Benchmark harness + perf-trajectory gate contract tests.

Three layers, matching docs/benchmarks.md:

- the timing core (``benchmarks.harness.measure``) — warmup excluded,
  every timed iteration synced, dispersion reported;
- the ``BenchReport`` artifact — versioned schema, JSON round-trip,
  duplicate-metric protection;
- the trajectory gate (``tools/check_bench.py``) — passes on self-diff,
  fails (exit 1) when a gated ratio leaves its band or disappears, and
  reports structured errors (exit 2) on missing/mismatched artifacts.

Guard-the-guard style (see tests/test_docs.py): the checker is exercised
against deliberately broken artifacts, and the committed baseline
(``benchmarks/BENCH_cpu_ci.json``) must itself stay loadable and gated.
"""
import copy
import importlib.util
import json
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO))

from benchmarks import harness  # noqa: E402

BASELINE = REPO / "benchmarks" / "BENCH_cpu_ci.json"


def _load_check_bench():
    spec = importlib.util.spec_from_file_location(
        "check_bench", REPO / "tools" / "check_bench.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ---------------------------------------------------------------- timing core

def test_measure_counts_warmup_and_timed_iterations():
    calls = []

    def fn():
        calls.append(1)
        return 1.0

    meas = harness.measure(fn, iters=4, warmup=2)
    assert len(calls) == 6  # 2 warmup + 4 timed
    assert meas.iters == 4 and meas.warmup == 2
    assert meas.median_us >= 0.0
    assert meas.min_us <= meas.median_us <= meas.max_us


def test_measure_rejects_zero_iters():
    with pytest.raises(ValueError):
        harness.measure(lambda: 1.0, iters=0)


def test_measure_handles_jax_arrays_and_pytrees():
    import jax.numpy as jnp

    meas = harness.measure(lambda: {"y": jnp.arange(8) * 2}, iters=2, warmup=1)
    assert meas.median_us > 0.0


def test_measurement_dispersion_fields():
    meas = harness.measure(lambda: 0, iters=5, warmup=0)
    stats = meas.stats()
    for key in ("median_us", "iqr_us", "min_us", "max_us", "iters", "warmup"):
        assert key in stats
    assert meas.rel_iqr >= 0.0


# ---------------------------------------------------------------- BenchReport

def test_report_round_trips_through_json(tmp_path):
    rep = harness.BenchReport(fast=True)
    rep.add("m_ratio", 1.5, "ratio", derived={"dims": "2x2"})
    rep.record("m_time", lambda: 1.0, iters=2, warmup=0)
    path = tmp_path / "BENCH_test.json"
    rep.write(path)
    data = json.loads(path.read_text())
    assert data["schema"] == harness.SCHEMA
    assert data["meta"]["fast"] is True
    assert data["meta"]["jax"]  # environment stamped
    assert data["metrics"]["m_ratio"]["value"] == 1.5
    assert data["metrics"]["m_ratio"]["derived"] == {"dims": "2x2"}
    assert data["metrics"]["m_time"]["unit"] == "us"
    assert data["metrics"]["m_time"]["meta"]["iters"] == 2


def test_report_write_is_atomic(tmp_path, monkeypatch):
    """An interrupted write must never leave a truncated BENCH_*.json
    (check_bench would exit 2 on the next CI run): the artifact lands
    via temp file + os.replace, and a crash mid-serialization leaves the
    previous artifact intact."""
    path = tmp_path / "BENCH_test.json"
    rep = harness.BenchReport(fast=True)
    rep.add("m", 1.0, "ratio")
    rep.write(path)

    class Boom(RuntimeError):
        pass

    bad = harness.BenchReport(fast=True)
    bad.add("m", 2.0, "ratio")
    monkeypatch.setattr(bad, "to_dict",
                        lambda: (_ for _ in ()).throw(Boom("mid-write")))
    with pytest.raises(Boom):
        bad.write(path)
    # prior artifact untouched, no temp debris
    assert json.loads(path.read_text())["metrics"]["m"]["value"] == 1.0
    assert [p.name for p in tmp_path.iterdir()] == ["BENCH_test.json"]


def test_report_meta_records_active_tuning(tmp_path):
    from repro.kernels import autotune

    assert harness.BenchReport().meta["tune"] is None
    table = autotune.TuningTable(device=autotune.device_kind())
    table.put("ssd", "xla", "small", 64, 1.0)
    path = tmp_path / "TUNE_t.json"
    table.save(str(path))
    try:
        harness.activate_tuning(str(path))
        assert harness.BenchReport().meta["tune"] == str(path)
    finally:
        autotune.deactivate()


def test_report_rejects_duplicate_metric():
    rep = harness.BenchReport()
    rep.add("m", 1.0, "ratio")
    with pytest.raises(ValueError):
        rep.add("m", 2.0, "ratio")


def test_report_csv_rows_match_metrics():
    rep = harness.BenchReport()
    rep.add("a", 1.0, "ratio", derived={"k": 1})
    rep.add("b", 2.0, "us")
    rows = list(rep.csv_rows())
    assert [r[0] for r in rows] == ["a", "b"]
    assert rows[0][2] == "ratio" and "k=1" in rows[0][3]


def test_gated_units_cover_the_trajectory_policy():
    # the unit-level gating table is the contract docs/benchmarks.md
    # documents — a silent edit here must be a conscious policy change
    assert set(harness.GATED_UNITS) == {"ratio", "dB", "um2", "W", "percent"}
    assert "us" not in harness.GATED_UNITS  # wall-clock never gates CI


# ------------------------------------------------------------------ the gate

def _mini_report(**overrides):
    rep = {
        "schema": harness.SCHEMA,
        "meta": {"fast": True},
        "metrics": {
            "k_ratio": {"value": 2.0, "unit": "ratio", "derived": {}, "meta": {}},
            "k_time": {"value": 100.0, "unit": "us", "derived": {}, "meta": {}},
        },
    }
    rep.update(overrides)
    return rep


def test_check_bench_passes_on_identical_reports(tmp_path):
    cb = _load_check_bench()
    violations, _ = cb.compare(_mini_report(), _mini_report())
    assert violations == []


def test_check_bench_fails_when_ratio_leaves_band(tmp_path):
    cb = _load_check_bench()
    fresh = copy.deepcopy(_mini_report())
    fresh["metrics"]["k_ratio"]["value"] = 2.0 * 1.6  # +60% > ±50% band
    violations, _ = cb.compare(_mini_report(), fresh)
    assert len(violations) == 1 and "k_ratio" in violations[0]
    # ... while the same drift on wall-clock stays informational
    fresh2 = copy.deepcopy(_mini_report())
    fresh2["metrics"]["k_time"]["value"] = 100.0 * 10
    violations2, infos2 = cb.compare(_mini_report(), fresh2)
    assert violations2 == []
    assert any("k_time" in line for line in infos2)


def test_check_bench_flags_missing_gated_metric():
    cb = _load_check_bench()
    fresh = copy.deepcopy(_mini_report())
    del fresh["metrics"]["k_ratio"]
    violations, _ = cb.compare(_mini_report(), fresh)
    assert len(violations) == 1 and "missing" in violations[0]
    # missing informational metric is not a violation
    fresh2 = copy.deepcopy(_mini_report())
    del fresh2["metrics"]["k_time"]
    assert cb.compare(_mini_report(), fresh2)[0] == []


def test_check_bench_flags_unit_change():
    cb = _load_check_bench()
    fresh = copy.deepcopy(_mini_report())
    fresh["metrics"]["k_ratio"]["unit"] = "us"
    violations, _ = cb.compare(_mini_report(), fresh)
    assert len(violations) == 1 and "unit changed" in violations[0]


def test_check_bench_tolerance_scale_loosens_bands():
    cb = _load_check_bench()
    fresh = copy.deepcopy(_mini_report())
    fresh["metrics"]["k_ratio"]["value"] = 2.0 * 1.6
    assert cb.compare(_mini_report(), fresh)[0]
    assert cb.compare(_mini_report(), fresh, tolerance_scale=2.0)[0] == []


def test_check_bench_structured_errors(tmp_path):
    cb = _load_check_bench()
    with pytest.raises(cb.BenchError, match="no such"):
        cb.load_report(tmp_path / "nope.json")
    bad_schema = tmp_path / "schema.json"
    bad_schema.write_text(json.dumps(_mini_report(schema="repro-bench/99")))
    with pytest.raises(cb.BenchError, match="schema"):
        cb.load_report(bad_schema)
    malformed = tmp_path / "malformed.json"
    malformed.write_text(json.dumps(
        {"schema": harness.SCHEMA, "meta": {},
         "metrics": {"m": {"value": 1.0}}}))
    with pytest.raises(cb.BenchError, match="malformed metric"):
        cb.load_report(malformed)
    garbage = tmp_path / "garbage.json"
    garbage.write_text("{not json")
    with pytest.raises(cb.BenchError, match="unreadable"):
        cb.load_report(garbage)


def test_check_bench_cli_exit_codes(tmp_path):
    # the CI contract: 0 pass / 1 violation / 2 structured error
    ok = tmp_path / "ok.json"
    ok.write_text(json.dumps(_mini_report()))
    drifted = tmp_path / "drift.json"
    rep = copy.deepcopy(_mini_report())
    rep["metrics"]["k_ratio"]["value"] = 99.0
    drifted.write_text(json.dumps(rep))

    def run(fresh, baseline):
        return subprocess.run(
            [sys.executable, str(REPO / "tools" / "check_bench.py"),
             str(fresh), "--baseline", str(baseline)],
            capture_output=True, text=True)

    assert run(ok, ok).returncode == 0
    proc = run(drifted, ok)
    assert proc.returncode == 1 and "FAIL" in proc.stderr
    proc = run(tmp_path / "missing.json", ok)
    assert proc.returncode == 2 and "ERROR" in proc.stderr


# ------------------------------------------------- the tuning-artifact gate

def _mini_tune(device="cpu", **entries):
    from repro.kernels import autotune

    t = autotune.TuningTable(device=device)
    for key, block in (entries or {"ssd__xla__small": 64,
                                   "ssd__xla__medium": 128}).items():
        kernel, backend, bucket = key.split("__")
        t.put(kernel, backend, bucket, block, 1.0)
    return t


def test_check_bench_tune_passes_on_self_and_notes_block_changes():
    cb = _load_check_bench()
    violations, _ = cb.compare_tune(_mini_tune(), _mini_tune())
    assert violations == []
    # a different measured winner is informational, not a failure
    fresh = _mini_tune(ssd__xla__small=32, ssd__xla__medium=128)
    violations, infos = cb.compare_tune(_mini_tune(), fresh)
    assert violations == []
    assert any("ssd/xla/small" in line and "->" in line for line in infos)


def test_check_bench_tune_gates_coverage_not_choices():
    cb = _load_check_bench()
    fresh = _mini_tune(ssd__xla__small=64)  # medium entry dropped
    violations, _ = cb.compare_tune(_mini_tune(), fresh)
    assert len(violations) == 1 and "ssd/xla/medium" in violations[0]
    # device mismatch is a note (blocks aren't comparable), not a failure
    violations, infos = cb.compare_tune(_mini_tune(),
                                        _mini_tune(device="tpu_v4"))
    assert violations == []
    assert any("device kind differs" in line for line in infos)


def test_check_bench_tune_cli_exit_codes(tmp_path):
    ok = tmp_path / "TUNE_ok.json"
    _mini_tune().save(str(ok))
    sparse = tmp_path / "TUNE_sparse.json"
    _mini_tune(ssd__xla__small=64).save(str(sparse))
    corrupt = tmp_path / "TUNE_bad.json"
    corrupt.write_text('{"schema": "repro-tune/1"')  # truncated

    def run(fresh, baseline):
        return subprocess.run(
            [sys.executable, str(REPO / "tools" / "check_bench.py"),
             "--tune-fresh", str(fresh), "--tune-baseline", str(baseline)],
            capture_output=True, text=True)

    assert run(ok, ok).returncode == 0
    proc = run(sparse, ok)
    assert proc.returncode == 1 and "missing from fresh sweep" in proc.stderr
    assert run(corrupt, ok).returncode == 2
    assert run(ok, tmp_path / "absent.json").returncode == 2
    # no positional and no --tune-fresh: structured usage error
    bare = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_bench.py")],
        capture_output=True, text=True)
    assert bare.returncode == 2 and "nothing to check" in bare.stderr


def test_committed_tuning_artifact_is_valid_and_covers_ci_keys():
    cb = _load_check_bench()
    table = cb.load_tune(REPO / "kernels" / "TUNE_cpu_ci.json")
    assert table.device == "cpu"          # CI runners are cpu device_kind
    assert table.meta.get("fast") is True  # CI sweeps fast-vs-fast
    # the keys the CI bench run actually exercises must be tuned
    for key in ("ssd/xla/medium", "matmul/interpret/small",
                "bitwise/interpret/small"):
        assert key in table.entries, key


# -------------------------------------------------- committed baseline + CI

def test_committed_baseline_is_schema_valid():
    cb = _load_check_bench()
    data = cb.load_report(BASELINE)  # raises BenchError if invalid
    assert data["meta"]["fast"] is True  # CI diffs fast-vs-fast
    # the headline gate metrics of each suite must be present
    for name in ("kern_seg_matmul_p3_vs_exact", "table2_ac44_area_saving",
                 "table3_AC5-5_psnr_blend"):
        assert name in data["metrics"], name
    gated = [n for n, m in data["metrics"].items()
             if cb.tolerance_for(n, m["unit"]) is not None]
    assert len(gated) >= 10
    # the autotuner's headline gate metric rides the same trajectory
    assert "autotuned_vs_static" in data["metrics"]
    assert cb.tolerance_for("autotuned_vs_static", "ratio") is not None


def test_ci_bench_job_runs_the_gate():
    ci = (REPO / ".github" / "workflows" / "ci.yml").read_text()
    assert "python -m benchmarks.run --fast --skip-resnet" in ci
    assert "tools/check_bench.py --baseline benchmarks/BENCH_cpu_ci.json" in ci
    # the bench run measures under the committed tuning artifact, and the
    # artifact itself is regenerated + gated in the same job
    assert "--tune kernels/TUNE_cpu_ci.json" in ci
    assert "python -m benchmarks.autotune --fast" in ci
    assert ("tools/check_bench.py --tune-baseline kernels/TUNE_cpu_ci.json"
            in ci)
