"""Pretrained-checkpoint interop (``repro.compat``; docs/compat.md).

Three layers of assurance:

- **unit**: the state-dict walkers and the mapping DSL invert exactly
  (transpose/permute/reshape/shift/stack), and every failure mode is a
  one-line ``CompatError`` (missing key, shape/dtype mismatch, unknown
  keys under strict mode);
- **container**: the dependency-free safetensors reader round-trips the
  writer, loads the *sharded* index layout, and rejects malformed bytes
  with the file named — against fixture files written by an INDEPENDENT
  writer (``tests/golden/gen_compat_golden.py``);
- **golden**: `Session.from_pretrained` on the committed miniature
  HF-format checkpoints reproduces the hand-computed numpy reference
  for all three families bit-exactly (``assert_array_equal``), the PR 1
  tied-embedding ``d**-0.5`` scale survives import, and
  export -> reload round-trips.

Real-download validation is opt-in: point ``REPRO_REAL_CHECKPOINT_QWEN3``
at a local full-size checkpoint (slow marker).
"""
import dataclasses
import os

import numpy as np
import pytest

from repro import compat
from repro.compat import (CompatError, MapRule, Mapping, flatten_tree,
                          unflatten_tree)
from repro.compat.safetensors_io import (read_safetensors, write_safetensors,
                                         write_sharded_checkpoint)

GOLDEN = os.path.join(os.path.dirname(__file__), "golden", "compat")

FAMILIES = ["qwen3-4b", "whisper-tiny", "resnet18"]


def load_reference(family):
    return dict(np.load(os.path.join(GOLDEN, f"{family}_reference.npz")))


def session_for(family, path=None, **kw):
    from repro.session import Session

    return Session.from_pretrained(
        family, path or os.path.join(GOLDEN, family), **kw)


def session_state_dict(sess):
    flat = flatten_tree(sess.params)
    if sess._state is not None:
        flat.update(flatten_tree(sess._state))
    return flat


# ---------------------------------------------------------------------------
# state-dict model
# ---------------------------------------------------------------------------

class TestStateDict:
    def tree(self):
        from repro.models.layers import PP

        return {"a": {"b": np.arange(6, dtype=np.float32).reshape(2, 3)},
                "pp": PP(np.ones((4,), np.float32), (None,)),
                "list": [np.zeros((2,), np.int32),
                         np.ones((2,), np.int32)]}

    def test_flatten_paths_and_values(self):
        flat = flatten_tree(self.tree())
        assert sorted(flat) == ["a.b", "list.0", "list.1", "pp"]
        assert flat["a.b"].shape == (2, 3)
        assert flat["pp"].shape == (4,)  # PP unwrapped to its value

    def test_unflatten_round_trip(self):
        tree = self.tree()
        flat = flatten_tree(tree)
        rebuilt = unflatten_tree(tree, flat)
        for k, v in flatten_tree(rebuilt).items():
            np.testing.assert_array_equal(v, flat[k])

    def test_missing_key_one_liner(self):
        tree = self.tree()
        flat = flatten_tree(tree)
        del flat["a.b"]
        with pytest.raises(CompatError, match="missing key 'a.b'"):
            unflatten_tree(tree, flat)

    def test_shape_mismatch_names_path(self):
        tree = self.tree()
        flat = flatten_tree(tree)
        flat["a.b"] = flat["a.b"].T
        with pytest.raises(CompatError, match=r"a\.b: shape \(3, 2\)"):
            unflatten_tree(tree, flat)

    def test_dtype_mismatch_strict_and_cast(self):
        tree = self.tree()
        flat = flatten_tree(tree)
        flat["pp"] = flat["pp"].astype(np.float64)
        with pytest.raises(CompatError, match="pp: dtype float64"):
            unflatten_tree(tree, flat)
        rebuilt = unflatten_tree(tree, flat, cast=True)
        assert rebuilt["pp"].dtype == np.float32


class TestMappingDSL:
    def test_transpose_inverts(self, rng):
        rule = MapRule("w", "n", transpose=True)
        w = rng.standard_normal((3, 5))
        np.testing.assert_array_equal(rule.adapt(w), w.T)
        np.testing.assert_array_equal(rule.unadapt(rule.adapt(w)), w)

    def test_permute_inverts(self, rng):
        rule = MapRule("w", "n", permute=(2, 3, 1, 0))  # OIHW -> HWIO
        w = rng.standard_normal((4, 3, 2, 2))
        assert rule.adapt(w).shape == (2, 2, 3, 4)
        np.testing.assert_array_equal(rule.unadapt(rule.adapt(w)), w)

    def test_reshape_needs_src_shape_to_invert(self, rng):
        w = rng.standard_normal((6, 4))
        rule = MapRule("w", "n", transpose=True, reshape=(4, 2, 3))
        assert rule.adapt(w).shape == (4, 2, 3)
        with pytest.raises(CompatError, match="src_shape"):
            rule.unadapt(rule.adapt(w))
        rule = dataclasses.replace(rule, src_shape=(6, 4))
        np.testing.assert_array_equal(rule.unadapt(rule.adapt(w)), w)

    def test_shift_inverts(self, rng):
        rule = MapRule("w", "n", shift=-1.0)
        w = rng.standard_normal((7,)).astype(np.float32)
        # import applies the same f32 op the golden reference uses (w - 1)
        np.testing.assert_array_equal(rule.adapt(w), w - 1)
        np.testing.assert_allclose(rule.unadapt(rule.adapt(w)), w,
                                   rtol=1e-6, atol=1e-7)
        # dyadic values round-trip bit-exactly (norm weights near 1.0 do)
        exact = np.asarray([0.5, -2.25, 3.0, 1.125], np.float32)
        np.testing.assert_array_equal(rule.unadapt(rule.adapt(exact)), exact)

    def test_stack_gathers_strided_layers(self, rng):
        # period-2 pattern: position 1 of 3 repeats -> layers 1, 3, 5
        rule = MapRule("l.{i}.w", "seg0_p1.w", transpose=True,
                       stack=3, start=1, stride=2)
        assert rule.src_keys() == ["l.1.w", "l.3.w", "l.5.w"]
        foreign = {f"l.{i}.w": rng.standard_normal((2, 4)) for i in range(6)}
        native = Mapping([rule]).to_native(foreign, unknown="ignore")
        assert native["seg0_p1.w"].shape == (3, 4, 2)
        np.testing.assert_array_equal(native["seg0_p1.w"][1],
                                      foreign["l.3.w"].T)
        back = Mapping([rule]).to_foreign(native)
        for k in rule.src_keys():
            np.testing.assert_array_equal(back[k], foreign[k])

    def test_stack_requires_placeholder(self):
        with pytest.raises(CompatError, match="placeholder"):
            MapRule("l.w", "n", stack=2)

    def test_duplicate_native_keys_rejected(self):
        with pytest.raises(CompatError, match="duplicate native"):
            Mapping([MapRule("a", "n"), MapRule("b", "n")])

    def test_missing_source_key(self):
        with pytest.raises(CompatError, match="missing 'a' for native "
                                              "key 'n'"):
            Mapping([MapRule("a", "n")]).to_native({})

    def test_unknown_strict_vs_ignore(self, rng):
        m = Mapping([MapRule("a", "n")])
        foreign = {"a": rng.standard_normal((2,)),
                   "rotary.inv_freq": rng.standard_normal((2,))}
        with pytest.raises(CompatError, match="unmapped key.*inv_freq"):
            m.to_native(foreign)
        native = m.to_native(foreign, unknown="ignore")
        assert list(native) == ["n"]
        with pytest.raises(CompatError, match="unknown="):
            m.to_native(foreign, unknown="maybe")


# ---------------------------------------------------------------------------
# safetensors container
# ---------------------------------------------------------------------------

class TestSafetensors:
    def test_write_read_round_trip(self, tmp_path, rng):
        sd = {"a": rng.standard_normal((3, 4)).astype(np.float32),
              "b": rng.integers(0, 100, (5,)).astype(np.int64),
              "c": rng.standard_normal((2,)).astype(np.float16)}
        try:
            import ml_dtypes
            sd["d"] = rng.standard_normal((4,)).astype(ml_dtypes.bfloat16)
        except ImportError:
            pass
        path = tmp_path / "t.safetensors"
        write_safetensors(path, sd, {"who": "test"})
        back, meta = read_safetensors(path)
        assert meta == {"who": "test"}
        assert sorted(back) == sorted(sd)
        for k in sd:
            assert back[k].dtype == sd[k].dtype
            np.testing.assert_array_equal(back[k], sd[k])

    def test_sharded_round_trip(self, tmp_path, rng):
        sd = {f"t{i}": rng.standard_normal((8, 8)).astype(np.float32)
              for i in range(5)}
        index = write_sharded_checkpoint(tmp_path, sd, {"m": "1"},
                                         max_shard_bytes=600)
        shards = [p for p in os.listdir(tmp_path)
                  if p.endswith(".safetensors")]
        assert len(shards) > 1  # the budget forces real sharding
        for loc in (index, tmp_path):  # index file and directory both load
            back, meta = compat.load_checkpoint(loc)
            assert meta == {"m": "1"}
            for k in sd:
                np.testing.assert_array_equal(back[k], sd[k])

    def test_truncated_file(self, tmp_path):
        p = tmp_path / "t.safetensors"
        p.write_bytes(b"\x01\x02")
        with pytest.raises(CompatError, match="truncated"):
            read_safetensors(p)

    def test_header_overrun(self, tmp_path):
        p = tmp_path / "t.safetensors"
        p.write_bytes((1 << 40).to_bytes(8, "little") + b"{}")
        with pytest.raises(CompatError, match="overruns"):
            read_safetensors(p)

    def test_bad_offsets(self, tmp_path, rng):
        p = tmp_path / "t.safetensors"
        write_safetensors(p, {"a": np.zeros((4,), np.float32)})
        raw = bytearray(p.read_bytes())
        # shrink the data section so the declared offsets dangle
        p.write_bytes(bytes(raw[:-8]))
        with pytest.raises(CompatError, match="'a' offsets"):
            read_safetensors(p)

    def test_empty_dir(self, tmp_path):
        with pytest.raises(CompatError, match="expected one"):
            compat.load_checkpoint(tmp_path)

    def test_torch_reader_guarded(self, tmp_path):
        torch = pytest.importorskip("torch")
        sd = {"w": torch.arange(6, dtype=torch.float32).reshape(2, 3)}
        p = tmp_path / "w.pt"
        torch.save(sd, p)
        back = compat.read_torch_checkpoint(p)
        np.testing.assert_array_equal(
            back["w"], np.arange(6, dtype=np.float32).reshape(2, 3))


# ---------------------------------------------------------------------------
# golden fixtures: all three families load bit-exact
# ---------------------------------------------------------------------------

class TestGoldenFixtures:
    @pytest.mark.parametrize("family", FAMILIES)
    def test_bit_exact_vs_numpy_reference(self, family):
        ref = load_reference(family)
        flat = session_state_dict(session_for(family))
        assert sorted(flat) == sorted(ref)
        for k in ref:
            np.testing.assert_array_equal(flat[k], ref[k], err_msg=k)

    @pytest.mark.parametrize("family", FAMILIES)
    def test_export_reload_round_trip(self, family, tmp_path):
        sess = session_for(family)
        out = tmp_path / "export.safetensors"
        sess.export(out)
        flat2 = session_state_dict(session_for(family, str(out)))
        for k, v in session_state_dict(sess).items():
            np.testing.assert_array_equal(flat2[k], v, err_msg=k)

    def test_qwen_shard_index_was_exercised(self):
        # regression guard: the qwen fixture must STAY sharded, or the
        # index code path loses its only hermetic coverage
        files = os.listdir(os.path.join(GOLDEN, "qwen3-4b"))
        assert sum(f.endswith(".safetensors") for f in files) == 2
        assert any(f.endswith(".safetensors.index.json") for f in files)

    def test_loaded_tree_matches_init_template(self):
        # a loaded tree is structurally identical to a fresh init: same
        # leaf paths, shapes and dtypes (what downstream jit paths assume)
        from repro.session import Session

        loaded = session_for("qwen3-4b")
        fresh = Session("qwen3-4b")
        a, b = flatten_tree(loaded.params), flatten_tree(fresh.params)
        assert sorted(a) == sorted(b)
        for k in a:
            assert a[k].shape == b[k].shape, k
            assert a[k].dtype == b[k].dtype, k


class TestConverterEdgeCases:
    def test_tied_embedding_scale_survives_import(self, rng):
        # PR 1 fix: the tied head applies d**-0.5 at runtime — importing
        # must keep the raw table untransformed ("embed" only, no
        # "unembed") so logits remain scaled-tied-matmul exactly
        import jax
        import jax.numpy as jnp

        from repro.models import transformer

        sess = session_for("qwen3-4b")
        cfg = sess.config
        assert cfg.tie_embeddings
        assert "unembed" not in sess.params
        hidden = jnp.asarray(rng.standard_normal((1, 2, cfg.d_model)),
                             jnp.float32)
        got = transformer.logits_fn(sess.params, cfg, hidden)
        want = jax.lax.dot_general(
            hidden.astype(jnp.bfloat16),
            sess.params["embed"].T.astype(jnp.bfloat16),
            (((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * (cfg.d_model ** -0.5)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_gqa_shape_mismatch_names_site(self):
        # a checkpoint whose kv projections don't match the config's GQA
        # head layout must fail with the native path named, not load
        # garbage — here: config expects half the fixture's kv heads
        import dataclasses as dc

        from repro.configs import get_arch

        cfg = get_arch("qwen3-4b").reduced()
        bad = dc.replace(cfg, n_kv_heads=cfg.n_kv_heads // 2)
        with pytest.raises(CompatError,
                           match=r"seg0_p0\.attn\.wk: shape"):
            compat.load_pretrained("qwen3-4b",
                                   os.path.join(GOLDEN, "qwen3-4b"),
                                   cfg=bad)

    def test_whisper_encoder_decoder_prefix_split(self):
        # model.encoder.* and model.decoder.* land in disjoint native
        # subtrees: encoder.blocks.* stacks encoder_layers (2), the
        # decoder seg stacks decoder repeats — verify against the raw
        # foreign shards, not the reference (an independent angle)
        sess = session_for("whisper-tiny")
        foreign, _ = compat.load_checkpoint(
            os.path.join(GOLDEN, "whisper-tiny"))
        enc = sess.params["encoder"]["blocks"]["attn"]["wq"]
        dec = sess.params["seg0_p0"]["attn"]["wq"]
        assert enc.shape[0] == 2 and dec.shape[0] == 2
        np.testing.assert_array_equal(
            enc[1], foreign["model.encoder.layers.1.self_attn.q_proj"
                            ".weight"].T)
        np.testing.assert_array_equal(
            dec[0], foreign["model.decoder.layers.0.self_attn.q_proj"
                            ".weight"].T)
        # cross-attention only exists on the decoder side
        assert "cross" in sess.params["seg0_p0"]
        assert "cross" not in sess.params["encoder"]["blocks"]

    def test_unknown_keys_strict_vs_ignore_through_loader(self, tmp_path):
        foreign, meta = compat.load_checkpoint(
            os.path.join(GOLDEN, "resnet18"))
        foreign["bn1.num_batches_tracked"] = np.zeros((), np.float32)
        p = tmp_path / "extra.safetensors"
        write_safetensors(p, foreign, meta)
        with pytest.raises(CompatError, match="unmapped"):
            compat.load_pretrained("resnet18", p)
        loaded = compat.load_pretrained("resnet18", p, unknown="ignore")
        ref = load_reference("resnet18")
        np.testing.assert_array_equal(
            flatten_tree(loaded.params)["fc"], ref["fc"])

    def test_unregistered_family(self):
        with pytest.raises(CompatError, match="no checkpoint converter"):
            compat.load_pretrained("alexnet", "nowhere")

    def test_metadata_family_mismatch(self):
        with pytest.raises(CompatError, match="family"):
            compat.load_pretrained("qwen3-4b",
                                   os.path.join(GOLDEN, "whisper-tiny"))


# ---------------------------------------------------------------------------
# checkpoint/io.py round trip + codec error (satellites)
# ---------------------------------------------------------------------------

class TestCheckpointIO:
    def test_params_safetensors_round_trip_bitexact(self, tmp_path):
        from repro.checkpoint import io as ckpt_io
        from repro.session import Session

        sess = Session("qwen3-4b")
        path = tmp_path / "params.safetensors"
        ckpt_io.save_safetensors(path, sess.params, {"step": "7"})
        tree, meta = ckpt_io.load_safetensors(path, sess.params)
        assert meta == {"step": "7"}
        want = flatten_tree(sess.params)
        for k, v in flatten_tree(tree).items():
            np.testing.assert_array_equal(v, want[k], err_msg=k)

    def test_missing_codec_error_message(self, monkeypatch, tmp_path):
        # a zstd-compressed shard restored in a zlib-only environment must
        # say exactly what to install, not die in zlib.decompress
        from repro.checkpoint import io as ckpt_io

        blob = b"\x28\xb5\x2f\xfd" + b"rest-of-zstd-frame"
        monkeypatch.setattr(ckpt_io, "zstandard", None)
        with pytest.raises(ModuleNotFoundError,
                           match="pip install zstandard"):
            ckpt_io._decompress(blob)
        # and the zlib path still round-trips in that environment
        assert ckpt_io._decompress(ckpt_io._compress(b"payload")) == b"payload"


# ---------------------------------------------------------------------------
# opt-in real-download validation (slow; needs a local checkpoint)
# ---------------------------------------------------------------------------

REAL_QWEN = os.environ.get("REPRO_REAL_CHECKPOINT_QWEN3")


@pytest.mark.slow
@pytest.mark.skipif(not REAL_QWEN,
                    reason="set REPRO_REAL_CHECKPOINT_QWEN3=/path/to/ckpt "
                           "(safetensors dir) to validate a real download")
def test_real_qwen3_checkpoint_loads_full_size():
    loaded = compat.load_pretrained("qwen3-4b", REAL_QWEN, reduced=False,
                                    unknown="ignore")
    flat = flatten_tree(loaded.params)
    assert flat["embed"].shape == (151936, 2560)
    assert flat["seg0_p0.attn.wq"].shape == (36, 2560, 32 * 128)
