"""Properties of the mantissa-segmentation AFPM (paper §III-B)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import metrics
from repro.core.afpm import AFPMConfig, afpm_matmul_emulated, afpm_mult_f32


def _mult(x, y, **kw):
    return np.asarray(afpm_mult_f32(jnp.float32(x), jnp.float32(y), AFPMConfig(**kw)))


# ---- paper-claim validation: MRED/NMED bands of Table IV -------------------

PAPER_MRED = {  # (config kwargs, paper MRED, tolerance factor)
    "AC4-4": (dict(n=4), 1.38e-3),
    "AC5-5": (dict(n=5), 3.36e-4),
    "AC6-6": (dict(n=6), 8.29e-5),
    "ACL5": (dict(n=5, mode="acl"), 4.16e-2),
}


@pytest.mark.parametrize("label", sorted(PAPER_MRED))
def test_mred_matches_paper_table4(label):
    kw, paper = PAPER_MRED[label]
    rng = np.random.default_rng(0)
    x = rng.uniform(-4, 4, 100_000).astype(np.float32)
    y = rng.uniform(-4, 4, 100_000).astype(np.float32)
    approx = np.asarray(afpm_mult_f32(x, y, AFPMConfig(**kw)))
    exact = x.astype(np.float64) * y.astype(np.float64)
    got = metrics.mred(approx, exact)
    assert paper / 1.5 < got < paper * 1.5, (label, got, paper)


def test_error_decreases_with_n():
    rng = np.random.default_rng(1)
    x = rng.standard_normal(50_000).astype(np.float32)
    y = rng.standard_normal(50_000).astype(np.float32)
    exact = x.astype(np.float64) * y.astype(np.float64)
    mreds = [
        metrics.mred(np.asarray(afpm_mult_f32(x, y, AFPMConfig(n=n))), exact)
        for n in (3, 4, 5, 6, 7)
    ]
    assert all(a > b for a, b in zip(mreds, mreds[1:])), mreds


def test_special_values():
    assert np.isnan(_mult(np.nan, 1.0, n=5))
    assert np.isinf(_mult(np.inf, 2.0, n=5))
    assert _mult(np.inf, 2.0, n=5) > 0
    assert _mult(-np.inf, 2.0, n=5) < 0
    assert np.isnan(_mult(np.inf, 0.0, n=5))
    assert _mult(1e30, 1e30, n=5) == np.inf    # overflow -> inf
    assert _mult(1e-30, 1e-30, n=5) == 0.0     # underflow -> 0 (paper rule)


def test_acl_mode_properties():
    rng = np.random.default_rng(2)
    x = rng.uniform(0.1, 4, 20_000).astype(np.float32)
    y = rng.uniform(0.1, 4, 20_000).astype(np.float32)
    r = np.asarray(afpm_mult_f32(x, y, AFPMConfig(n=5, mode="acl")))
    exact = x.astype(np.float64) * y.astype(np.float64)
    assert metrics.mred(r, exact) < 0.08
    # sign/exponent path still exact: result within 2x of truth always
    ratio = r / exact
    assert ratio.min() > 0.5 and ratio.max() < 2.0


def test_ablation_knobs_change_error():
    rng = np.random.default_rng(3)
    x = rng.uniform(-2, 2, 50_000).astype(np.float32)
    y = rng.uniform(-2, 2, 50_000).astype(np.float32)
    exact = x.astype(np.float64) * y.astype(np.float64)
    full = metrics.mred(np.asarray(afpm_mult_f32(x, y, AFPMConfig(n=5))), exact)
    no_comp = metrics.mred(
        np.asarray(afpm_mult_f32(x, y, AFPMConfig(n=5, compensation=False))), exact
    )
    with_bd = metrics.mred(
        np.asarray(afpm_mult_f32(x, y, AFPMConfig(n=5, skip_bd=False))), exact
    )
    assert with_bd <= full          # adding BD back only helps accuracy
    assert no_comp >= full * 0.9    # compensation shouldn't hurt


def test_narrow_format_storage():
    rng = np.random.default_rng(4)
    x = rng.uniform(-2, 2, 10_000).astype(np.float32)
    y = rng.uniform(-2, 2, 10_000).astype(np.float32)
    exact = x.astype(np.float64) * y.astype(np.float64)
    for fmt, n in (("fp16", 5), ("afp24", 6)):
        r = np.asarray(afpm_mult_f32(x, y, AFPMConfig(n=n, fmt=fmt)))
        assert metrics.mred(r, exact) < 0.02, fmt


def test_invalid_configs():
    with pytest.raises(ValueError):
        afpm_mult_f32(jnp.float32(1), jnp.float32(1), AFPMConfig(n=5, mode="bogus"))
    with pytest.raises(ValueError):
        afpm_mult_f32(jnp.float32(1), jnp.float32(1), AFPMConfig(n=12))  # 2n > 23


# ---- emulated matmul --------------------------------------------------------

def test_emulated_matmul_matches_elementwise_sum():
    rng = np.random.default_rng(5)
    x = rng.standard_normal((3, 17, 33)).astype(np.float32)
    w = rng.standard_normal((33, 9)).astype(np.float32)
    cfg = AFPMConfig(n=5)
    got = np.asarray(afpm_matmul_emulated(x, w, cfg, k_chunk=16))
    prods = np.asarray(afpm_mult_f32(x[..., :, None], w[None, None], cfg))
    want = prods.sum(axis=-2)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_emulated_matmul_close_to_exact():
    rng = np.random.default_rng(6)
    x = rng.standard_normal((8, 64)).astype(np.float32)
    w = rng.standard_normal((64, 8)).astype(np.float32)
    got = np.asarray(afpm_matmul_emulated(x, w, AFPMConfig(n=6)))
    want = x @ w
    np.testing.assert_allclose(got, want, rtol=5e-3, atol=5e-3)


def test_ste_gradient_is_exact_product_rule():
    import jax

    from repro.core.afpm import afpm_mult_ste

    cfg = AFPMConfig(n=5)
    f = lambda x, y: jnp.sum(afpm_mult_ste(x, y, cfg))
    x = jnp.asarray(np.random.default_rng(7).standard_normal(32), jnp.float32)
    y = jnp.asarray(np.random.default_rng(8).standard_normal(32), jnp.float32)
    gx, gy = jax.grad(f, argnums=(0, 1))(x, y)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(y), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(gy), np.asarray(x), rtol=1e-6)
