"""Baseline multipliers: error magnitudes and rankings vs the paper's tables."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines, metrics

RNG = np.random.default_rng(0)
X = RNG.uniform(-4, 4, 100_000).astype(np.float32)
Y = RNG.uniform(-4, 4, 100_000).astype(np.float32)
EXACT = X.astype(np.float64) * Y.astype(np.float64)

# (callable, paper MRED, tolerance factor) — re-implementations from cited
# descriptions, so a looser band than our own designs (see DESIGN.md §7)
CASES = {
    "MMBS5": (lambda: baselines.mmbs_mult_f32(X, Y, baselines.MMBSConfig(5)), 2.92e-3, 2.0),
    "MMBS6": (lambda: baselines.mmbs_mult_f32(X, Y, baselines.MMBSConfig(6)), 1.14e-3, 2.0),
    "MMBS7": (lambda: baselines.mmbs_mult_f32(X, Y, baselines.MMBSConfig(7)), 5.04e-4, 2.0),
    "CSS12": (lambda: baselines.css_mult_f32(X, Y, baselines.CSSConfig(12)), 1.45e-3, 2.0),
    "CSS14": (lambda: baselines.css_mult_f32(X, Y, baselines.CSSConfig(14)), 7.08e-4, 2.0),
    "CSS16": (lambda: baselines.css_mult_f32(X, Y, baselines.CSSConfig(16)), 3.48e-4, 2.0),
    "CSS18": (lambda: baselines.css_mult_f32(X, Y, baselines.CSSConfig(18)), 1.73e-4, 2.0),
    "NC": (lambda: baselines.log_mult_f32(X, Y, baselines.LogConfig("nc")), 4.37e-2, 1.5),
    "LPC": (lambda: baselines.log_mult_f32(X, Y, baselines.LogConfig("lpc")), 2.81e-2, 1.5),
    "HPC": (lambda: baselines.log_mult_f32(X, Y, baselines.LogConfig("hpc")), 7.06e-3, 2.0),
}


@pytest.mark.parametrize("name", sorted(CASES))
def test_mred_within_band(name):
    fn, paper, tol = CASES[name]
    got = metrics.mred(np.asarray(fn()), EXACT)
    assert paper / tol < got < paper * tol, (name, got, paper)


def test_rankings_match_paper():
    mred = {name: metrics.mred(np.asarray(fn()), EXACT) for name, (fn, _, _) in CASES.items()}
    # within-family orderings from Table IV
    assert mred["MMBS5"] > mred["MMBS6"] > mred["MMBS7"]
    assert mred["CSS12"] > mred["CSS14"] > mred["CSS16"] > mred["CSS18"]
    assert mred["NC"] > mred["LPC"] > mred["HPC"]


def test_paper_cross_family_claims():
    """§IV-A: AC4-4 improves MRED vs MMBS5; AC5-5 beats CSS16; AC4-4 beats HPC."""
    from repro.core.afpm import AFPMConfig, afpm_mult_f32

    ac44 = metrics.mred(np.asarray(afpm_mult_f32(X, Y, AFPMConfig(n=4))), EXACT)
    ac55 = metrics.mred(np.asarray(afpm_mult_f32(X, Y, AFPMConfig(n=5))), EXACT)
    acl5 = metrics.mred(np.asarray(afpm_mult_f32(X, Y, AFPMConfig(n=5, mode="acl"))), EXACT)
    mmbs5 = metrics.mred(np.asarray(CASES["MMBS5"][0]()), EXACT)
    css16 = metrics.mred(np.asarray(CASES["CSS16"][0]()), EXACT)
    hpc = metrics.mred(np.asarray(CASES["HPC"][0]()), EXACT)
    nc = metrics.mred(np.asarray(CASES["NC"][0]()), EXACT)
    assert ac44 < mmbs5           # Table IV: 1.38e-3 < 2.92e-3
    assert ac55 < css16           # Table IV: 3.36e-4 < 3.48e-4
    assert ac44 < hpc             # §IV-A: AC4-4 improves MRED 80.4% vs HPC
    assert abs(acl5 - nc) / nc < 0.35  # ACL5 ~ NC accuracy at lower cost


def test_sign_and_specials_all_baselines():
    cases = [
        ("mmbs", lambda a, b: baselines.mmbs_mult_f32(a, b, baselines.MMBSConfig(6))),
        ("css", lambda a, b: baselines.css_mult_f32(a, b, baselines.CSSConfig(16))),
        ("log", lambda a, b: baselines.log_mult_f32(a, b, baselines.LogConfig("hpc"))),
    ]
    for name, fn in cases:
        assert float(fn(jnp.float32(2.0), jnp.float32(0.0))) == 0.0, name
        assert float(fn(jnp.float32(-2.0), jnp.float32(3.0))) < 0, name
        assert np.isinf(float(fn(jnp.float32(np.inf), jnp.float32(2.0)))), name
        assert np.isnan(float(fn(jnp.float32(np.nan), jnp.float32(2.0)))), name
        # powers of two (zero mantissas) stay within the half-ULP comp band
        got = float(fn(jnp.float32(8.0), jnp.float32(0.25)))
        assert abs(got - 2.0) / 2.0 < 0.02, (name, got)


def test_registry_exposes_all():
    from repro.core import registry

    avail = registry.available()
    for name in ["exact", "ac5-5", "acl5", "mmbs5", "css16", "nc", "lpc", "hpc"]:
        assert name in avail, name
    f = registry.get_multiplier("AC5-5")
    assert float(f(jnp.float32(2.0), jnp.float32(4.0))) == 8.0
    with pytest.raises(ValueError):
        registry.get_multiplier("does-not-exist")
