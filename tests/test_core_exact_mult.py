"""The exact multiplier must be bit-identical to host IEEE754 arithmetic."""
import numpy as np

from repro.core import exact_mult
from repro.core.formats import FP16, FP32, np_f32_to_bits


def test_bit_exact_bulk_random():
    rng = np.random.default_rng(7)
    # broad dynamic range incl. overflow/underflow/subnormal products
    x = (rng.standard_normal(100_000) * 10.0 ** rng.integers(-38, 38, 100_000)).astype(np.float32)
    y = (rng.standard_normal(100_000) * 10.0 ** rng.integers(-38, 38, 100_000)).astype(np.float32)
    got = exact_mult.np_exact_mult_f32(x, y)
    want = x * y
    np.testing.assert_array_equal(got.view(np.uint32), want.view(np.uint32))


def test_specials():
    cases = [
        (np.float32(0.0), np.float32(-3.5)),
        (np.float32(-0.0), np.float32(3.5)),
        (np.float32(np.inf), np.float32(2.0)),
        (np.float32(-np.inf), np.float32(-2.0)),
        (np.float32(np.inf), np.float32(0.0)),  # nan
        (np.float32(np.nan), np.float32(1.0)),
        (np.float32(1e-44), np.float32(0.5)),   # subnormal input
        (np.float32(3.4e38), np.float32(10.0)), # overflow
    ]
    for x, y in cases:
        got = exact_mult.np_exact_mult_f32(x, y)
        want = x * y
        if np.isnan(want):
            assert np.isnan(got)
        else:
            assert got.view(np.uint32) == want.view(np.uint32), (x, y, got, want)


def test_generic_format_fp16_bit_exact():
    rng = np.random.default_rng(3)
    x = (rng.standard_normal(50_000) * 10.0 ** rng.integers(-6, 5, 50_000)).astype(np.float16)
    y = (rng.standard_normal(50_000) * 10.0 ** rng.integers(-6, 5, 50_000)).astype(np.float16)
    xb = x.view(np.uint16).astype(np.int64)
    yb = y.view(np.uint16).astype(np.int64)
    got = exact_mult.np_exact_mult_bits(xb, yb, FP16)
    want = (x * y).view(np.uint16).astype(np.int64)
    # nan payloads may differ; compare values
    gotf = got.astype(np.uint16).view(np.float16)
    wantf = want.astype(np.uint16).view(np.float16)
    nan = np.isnan(wantf)
    np.testing.assert_array_equal(got[~nan], want[~nan])
    assert np.isnan(gotf[nan]).all()


def test_device_exact_is_native():
    rng = np.random.default_rng(4)
    x = rng.standard_normal(256).astype(np.float32)
    y = rng.standard_normal(256).astype(np.float32)
    got = np.asarray(exact_mult.exact_mult_f32(x, y))
    np.testing.assert_array_equal(got, x * y)
