import numpy as np
import pytest

from repro.core import formats


def test_format_properties():
    assert formats.FP32.bias == 127
    assert formats.FP32.sig_bits == 24
    assert formats.FP16.bias == 15
    assert formats.BF16.max_exp_field == 255
    assert formats.FP8_E4M3.total_bits == 8
    np.testing.assert_allclose(formats.FP32.max_finite, np.finfo(np.float32).max)
    np.testing.assert_allclose(formats.FP16.max_finite, 65504.0)


def test_get_format_unknown():
    with pytest.raises(ValueError):
        formats.get_format("fp13")


def test_np_encode_from_value_fp16_matches_numpy():
    rng = np.random.default_rng(0)
    x = rng.standard_normal(5000).astype(np.float64) * 10.0 ** rng.integers(-6, 5, 5000)
    enc = formats.np_encode_from_value(x, formats.FP16)
    want = x.astype(np.float16).view(np.uint16).astype(np.int64)
    np.testing.assert_array_equal(enc, want)


def test_jnp_bit_roundtrip():
    rng = np.random.default_rng(1)
    x = rng.standard_normal(1000).astype(np.float32)
    back = np.asarray(formats.jnp_bits_to_f32(formats.jnp_f32_to_bits(x)))
    np.testing.assert_array_equal(back, x)


def test_jnp_quantize_bf16_matches_cast():
    rng = np.random.default_rng(2)
    x = rng.standard_normal(4096).astype(np.float32) * 100
    q = np.asarray(formats.quantize(x, "bf16"))
    import jax.numpy as jnp

    want = np.asarray(jnp.asarray(x).astype(jnp.bfloat16).astype(jnp.float32))
    np.testing.assert_array_equal(q, want)


def test_truncate_mantissa():
    x = np.float32(1.0 + 0.5 + 0.25 + 2**-20)
    t = float(np.asarray(formats.truncate_mantissa(x, 2)))
    assert t == 1.75
    assert float(np.asarray(formats.truncate_mantissa(x, 23))) == float(x)


def test_quantize_flushes_subnormals_and_keeps_inf():
    tiny = np.float32(1e-41)  # subnormal in fp16's range mapping
    q = float(np.asarray(formats.quantize(tiny, "fp16")))
    assert q == 0.0
    assert np.isinf(np.asarray(formats.quantize(np.float32(np.inf), "fp16")))
    assert np.isnan(np.asarray(formats.quantize(np.float32(np.nan), "fp16")))
