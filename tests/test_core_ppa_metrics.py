import numpy as np
import pytest

from repro.core import metrics, ppa


def test_metrics_basic():
    exact = np.array([1.0, 2.0, -4.0, 0.0])
    approx = np.array([1.1, 2.0, -4.0, 0.5])
    assert metrics.mred(approx, exact) == pytest.approx(0.1 / 3)
    assert metrics.nmed(approx, exact) == pytest.approx((0.1 + 0.5) / 4 / 4.0)
    assert metrics.psnr(exact, exact) == float("inf")
    assert metrics.psnr(np.zeros(4), np.ones(4), peak=1.0) == pytest.approx(0.0)
    assert metrics.max_red(approx, exact) == pytest.approx(0.1)


def test_topk():
    logits = np.array([[0.1, 0.5, 0.4], [0.9, 0.05, 0.05]])
    labels = np.array([1, 2])
    assert metrics.top_k_accuracy(logits, labels, k=1) == pytest.approx(0.5)
    assert metrics.top_k_accuracy(logits, labels, k=3) == pytest.approx(1.0)


def test_ppa_anchors_exact():
    e = ppa.estimate("exact", name="Exact")
    a5 = ppa.estimate("ac", name="AC5-5", n=5)
    assert e.logic_area_um2 == pytest.approx(6268.0)
    assert e.power_w == pytest.approx(2.32e-3)
    assert a5.logic_area_um2 == pytest.approx(2156.0)
    assert a5.power_w == pytest.approx(7.72e-4)


def test_ppa_predictions_within_band():
    """Cost model must predict every published row within 25% (it is
    calibrated on only 2 of the 15 rows)."""
    for name, (kind, kw) in ppa.TABLE2_SPECS.items():
        est = ppa.estimate(kind, name=name, **kw)
        area, power = ppa.PAPER_TABLE2_64x32[name]
        assert abs(est.logic_area_um2 - area) / area < 0.25, (name, est.logic_area_um2, area)
        assert abs(est.power_w - power) / power < 0.25, (name, est.power_w, power)


def test_ppa_headline_claims():
    """Abstract: 'up to 69% logic area reduction and 72% power savings'
    for the AC designs; ACL5 hits 78%/82% (§IV-A)."""
    e = ppa.estimate("exact")
    acl5 = ppa.estimate("acl", n=5)
    ac44 = ppa.estimate("ac", n=4)
    area_red_acl5 = 1 - acl5.logic_area_um2 / e.logic_area_um2
    pow_red_acl5 = 1 - acl5.power_w / e.power_w
    assert area_red_acl5 > 0.72
    assert pow_red_acl5 > 0.72
    # AC4-4 achieves the paper's headline ~69%/72% band
    assert 1 - ac44.logic_area_um2 / e.logic_area_um2 > 0.60
    assert 1 - ac44.power_w / e.power_w > 0.65


def test_ppa_monotonic_in_n():
    areas = [ppa.estimate("ac", n=n).logic_area_um2 for n in (3, 4, 5, 6, 7)]
    assert all(a < b for a, b in zip(areas, areas[1:]))


def test_bd_omission_claim():
    """Paper: omitting BD saves ~6.8% area / ~12.6% power. Cost model should
    land in the same regime (a few to ~15 percent)."""
    darea, dpow = ppa.bd_omission_savings(5)
    assert 0.03 < darea < 0.18
    assert 0.05 < dpow < 0.20


def test_delay_is_sram_dominated():
    for sram, delay in ppa.SRAM_DELAY_NS.items():
        assert ppa.estimate("ac", n=5, sram=sram).delay_ns == delay
