"""Docs stay honest: intra-repo links resolve and fenced Python examples
compile (same checks as the CI docs job, run locally by tier-1)."""
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def test_docs_tree_exists():
    for f in ("docs/architecture.md", "docs/paper_map.md",
              "docs/numerics_policy.md"):
        assert (REPO / f).is_file(), f


def test_check_docs_passes():
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_docs.py")],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_check_docs_catches_broken_link(tmp_path):
    # the checker must actually fail on a broken link (guards the guard)
    import importlib.util

    spec = importlib.util.spec_from_file_location(
        "check_docs", REPO / "tools" / "check_docs.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    bad = tmp_path / "bad.md"
    bad.write_text("see [missing](does_not_exist.md)")
    assert mod.check_links(bad)
    fence = tmp_path / "fence.md"
    fence.write_text("```python\ndef broken(:\n```\n")
    assert mod.check_fences(fence)
