"""Docs stay honest: intra-repo links + anchor fragments resolve, fenced
Python examples compile, and the generated CLI reference is in sync
(same checks as the CI docs job, run locally by tier-1)."""
import importlib.util
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def _load(name):
    spec = importlib.util.spec_from_file_location(
        name, REPO / "tools" / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_docs_tree_exists():
    for f in ("docs/architecture.md", "docs/paper_map.md",
              "docs/numerics_policy.md", "docs/sensitivity.md",
              "docs/cli.md"):
        assert (REPO / f).is_file(), f


def test_check_docs_passes():
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_docs.py")],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_check_docs_catches_broken_link(tmp_path):
    # the checker must actually fail on a broken link (guards the guard)
    mod = _load("check_docs")
    bad = tmp_path / "bad.md"
    bad.write_text("see [missing](does_not_exist.md)")
    assert mod.check_links(bad)
    fence = tmp_path / "fence.md"
    fence.write_text("```python\ndef broken(:\n```\n")
    assert mod.check_fences(fence)


def test_check_docs_catches_broken_anchor(tmp_path):
    """A renamed heading must no longer break links silently: the checker
    validates `file.md#fragment` and in-page `#fragment` links against
    GitHub-style heading slugs."""
    mod = _load("check_docs")
    target = tmp_path / "target.md"
    target.write_text("# Top Title\n\n## A `code` — section!\n\n## Dup\n\n## Dup\n")
    md = tmp_path / "doc.md"
    md.write_text(
        "[ok](target.md#top-title) [ok2](target.md#a-code--section)\n"
        "[dup2](target.md#dup-1) [inpage](#local-heading)\n\n"
        "## Local Heading\n")
    assert mod.check_links(md) == []
    bad = tmp_path / "bad.md"
    bad.write_text("[stale](target.md#renamed-heading) [inpage](#nope)\n")
    problems = mod.check_links(bad)
    assert len(problems) == 2 and all("broken anchor" in p for p in problems)
    # fragments on non-markdown targets are not anchor-checked
    (tmp_path / "x.py").write_text("pass\n")
    ok = tmp_path / "ok.md"
    ok.write_text("[src](x.py#L3)\n")
    assert mod.check_links(ok) == []


def test_cli_reference_in_sync():
    """docs/cli.md must match what tools/gen_cli_docs.py renders from the
    live `python -m repro.session` parser (the CI docs job enforces the
    same via tools/check_docs.py)."""
    mod = _load("gen_cli_docs")
    assert mod.render() == (REPO / "docs" / "cli.md").read_text(), \
        "regenerate with: PYTHONPATH=src python tools/gen_cli_docs.py"


def test_check_docs_catches_cli_drift(tmp_path, monkeypatch):
    # guard the guard: a drifted cli.md must fail check_cli_sync
    mod = _load("check_docs")
    assert mod.check_cli_sync() == []
    gen = _load("gen_cli_docs")
    stale = tmp_path / "cli.md"
    stale.write_text("# stale\n")
    monkeypatch.setattr(gen, "OUT", stale)
    monkeypatch.setitem(sys.modules, "gen_cli_docs", gen)
    assert mod.check_cli_sync()
