"""Elastic recovery orchestration end-to-end (faked clock + relaunch)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import io as ckpt_io
from repro.distributed.fault import RestartPolicy
from repro.launch.elastic import ElasticCoordinator


def test_healthy_no_plan(tmp_path):
    t = [0.0]
    c = ElasticCoordinator(str(tmp_path), chips_per_worker=4,
                           model_parallel=16, heartbeat_timeout_s=10,
                           clock=lambda: t[0])
    for w in range(128):
        c.beat(w)
    assert c.check() is None


def test_recovery_plan_after_worker_loss(tmp_path):
    ckpt_io.save(str(tmp_path), 42, {"w": jnp.zeros(4)})
    t = [0.0]
    c = ElasticCoordinator(str(tmp_path), chips_per_worker=4,
                           model_parallel=16, heartbeat_timeout_s=10,
                           clock=lambda: t[0])
    for w in range(128):       # 128 workers x 4 chips = 512
        c.beat(w)
    t[0] = 8.0
    for w in range(120):       # 8 workers never beat again
        c.beat(w)
    t[0] = 12.0                # workers 120-127 exceeded the 10s timeout
    plan = c.check()
    assert plan is not None
    assert plan.resume_step == 42
    assert plan.lost_workers == list(range(120, 128))
    # 120*4 = 480 chips -> data 16 (pow2 floor of 30), model kept at 16
    assert (plan.data_parallel, plan.model_parallel) == (16, 16)

    launched = []
    c.recover(plan, launched.append)
    assert launched[0] is plan
    assert c.policy.restarts == 0  # reset after successful recovery


def test_restart_budget_exhausts(tmp_path):
    t = [100.0]
    c = ElasticCoordinator(str(tmp_path), 4, 16, heartbeat_timeout_s=1,
                           policy=RestartPolicy(max_restarts=2),
                           clock=lambda: t[0])
    for w in range(64):
        c.beat(w)
    t[0] = 200.0  # everyone times out except... keep a quorum alive
    for w in range(32):
        c.beat(w)
    assert c.check() is not None
    assert c.check() is not None
    with pytest.raises(RuntimeError):
        c.check()
