"""The loop-aware HLO walker is what the roofline stands on — test it on
real compiled modules with known ground truth."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_analysis as H


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_flops_single_dot():
    M, K, N = 64, 128, 32
    txt = _compile_text(
        lambda a, b: a @ b,
        jax.ShapeDtypeStruct((M, K), jnp.float32),
        jax.ShapeDtypeStruct((K, N), jnp.float32))
    cost = H.loop_aware_cost(txt)
    assert cost["flops"] == pytest.approx(2 * M * K * N, rel=0.01)


def test_flops_scan_multiplies_by_trip_count():
    M, K, T = 32, 32, 7

    def f(x, ws):
        def body(c, w):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, ws)
        return out

    txt = _compile_text(
        f, jax.ShapeDtypeStruct((M, K), jnp.float32),
        jax.ShapeDtypeStruct((T, K, K), jnp.float32))
    cost = H.loop_aware_cost(txt)
    assert cost["flops"] == pytest.approx(T * 2 * M * K * K, rel=0.05)


def test_bytes_fused_counts_carry_not_intermediates():
    M, K, T = 64, 64, 5

    def f(x, ws):
        def body(c, w):
            h = jnp.tanh(c @ w)         # intermediates should NOT count
            h2 = h * 2.0 + 1.0
            return h2, None
        out, _ = jax.lax.scan(body, x, ws)
        return out

    txt = _compile_text(
        f, jax.ShapeDtypeStruct((M, K), jnp.float32),
        jax.ShapeDtypeStruct((T, K, K), jnp.float32))
    cost = H.loop_aware_cost(txt)
    carry_bytes = M * K * 4
    weight_bytes = K * K * 4
    # fused model: per iteration ~ 2x carry + 1x weight slice (+ small misc)
    expect = T * (2 * carry_bytes + weight_bytes)
    assert cost["bytes_fused"] == pytest.approx(expect, rel=1.0)
    assert cost["bytes_fused"] < cost["bytes_stream"] <= cost["bytes"]


def test_shape_bytes_parser():
    assert H._shape_bytes("f32[16,128]{1,0}") == 16 * 128 * 4
    assert H._shape_bytes("bf16[4,8]") == 4 * 8 * 2
    assert H._shape_bytes("(f32[4], s32[2])") == 16 + 8
    assert H._shape_bytes("pred[]") == 1  # scalar = empty dims -> 1 elem


def test_collective_bytes_with_loops():
    if len(jax.devices()) < 2:
        pytest.skip("needs >=2 devices")
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    mesh = Mesh(np.array(jax.devices()[:2]).reshape(2), ("m",))
    T, M, K = 3, 16, 64

    def f(x, ws):
        def body(c, w):
            y = c @ w
            return jax.lax.with_sharding_constraint(
                y, NamedSharding(mesh, P(None, None))), None
        out, _ = jax.lax.scan(body, x, ws)
        return out

    xs = jax.ShapeDtypeStruct((M, K), jnp.float32)
    ws = jax.ShapeDtypeStruct((T, K, K), jnp.float32)
    lowered = jax.jit(f, in_shardings=(
        NamedSharding(mesh, P(None, "m")),
        NamedSharding(mesh, P(None, "m", None)))).lower(xs, ws)
    txt = lowered.compile().as_text()
    stats = H.collective_bytes(txt)
    if stats.total_bytes == 0:
        pytest.skip("XLA elided collectives on this backend")
    # per-iteration all-reduce of (M,K) f32, T iterations
    assert stats.total_bytes == pytest.approx(T * M * K * 4, rel=0.5)


def test_roofline_terms_shape():
    cost = {"flops": 197e12, "bytes_fused": 819e9, "bytes": 1e12,
            "bytes_stream": 9e11}
    coll = H.CollectiveStats(50e9, {"all-gather": 50e9})
    t = H.roofline_terms(cost, coll, 256, model_flops=197e12 * 256)
    assert t["t_compute_s"] == pytest.approx(1.0)
    assert t["t_memory_s"] == pytest.approx(1.0)
    assert t["t_collective_s"] == pytest.approx(1.0)
    assert t["useful_flops_ratio"] == pytest.approx(1.0)
    assert t["roofline_fraction"] == pytest.approx(1.0)


def test_roofline_compute_scale_shrinks_only_compute():
    cost = {"flops": 197e12, "bytes_fused": 819e9, "bytes": 1e12,
            "bytes_stream": 9e11}
    coll = H.CollectiveStats(50e9, {"all-gather": 50e9})
    t = H.roofline_terms(cost, coll, 256, compute_scale=0.5)
    assert t["t_compute_s"] == pytest.approx(0.5)
    assert t["t_memory_s"] == pytest.approx(1.0)
    assert t["t_collective_s"] == pytest.approx(1.0)
    assert t["numerics_compute_scale"] == 0.5


def test_policy_compute_scale_and_ppa_summary():
    from repro.core.numerics import NumericsConfig
    from repro.core.policy import NumericsPolicy, PolicyRule

    seg1 = NumericsConfig(mode="segmented", seg_passes=1, backend="xla")
    pol = NumericsPolicy((PolicyRule("mlp.*", seg1),),
                         default=NumericsConfig(mode="exact"))
    paths = ["attn.wq", "mlp.wi", "mlp.wo"]
    # 1 exact site (scale 1) + 2 single-pass sites (scale 1/6)
    want = (1.0 + 2 * (1 / 6)) / 3
    assert H.policy_compute_scale(pol, paths) == pytest.approx(want)
    # counts= multiplicity weighting (one path standing for 4 experts)
    w4 = (1.0 + 4 * (1 / 6)) / 5
    assert H.policy_compute_scale(pol, ["attn.wq", "mlp.wi"],
                                  counts={"mlp.wi": 4}) == pytest.approx(w4)
    summary = H.policy_ppa_summary(pol, paths)
    assert summary["n_sites"] == 3
    assert 0 < summary["area_um2"] < summary["baseline_area_um2"]
    assert 0 < summary["power_w"] < summary["baseline_power_w"]
    assert summary["compute_scale"] == pytest.approx(want)
    assert 0 < summary["area_reduction"] < 1
