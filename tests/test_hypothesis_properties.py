"""Hypothesis property tests for the multiplier datapaths and formats.

Collected here (from test_core_afpm / test_core_exact_mult /
test_core_formats / test_system) behind a single ``pytest.importorskip``
so a bare environment — no ``hypothesis`` installed — still collects the
whole suite with zero errors while the deterministic tests in those
modules keep running.  Install the test extras (``pip install -e .[test]``
or ``requirements-test.txt``) to run these.
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import exact_mult, formats
from repro.core.afpm import AFPMConfig, afpm_mult_f32
from repro.core.registry import get_multiplier

finite = st.floats(width=32, allow_nan=False, allow_infinity=False,
                   allow_subnormal=False)
f32_full = st.floats(width=32, allow_nan=False, allow_infinity=True,
                     allow_subnormal=True)
mults = st.sampled_from(["AC4-4", "AC5-5", "AC6-6", "ACL5", "MMBS6", "CSS16",
                         "NC", "HPC"])


def _mult(x, y, **kw):
    return np.asarray(afpm_mult_f32(jnp.float32(x), jnp.float32(y), AFPMConfig(**kw)))


# ---- AFPM algebraic properties (from test_core_afpm) -----------------------

@given(finite, finite)
@settings(max_examples=300, deadline=None)
def test_sign_symmetry(x, y):
    # sign path is exact XOR logic, so |.| and sign factor commute
    r = _mult(x, y, n=5)
    r_neg = _mult(-x, y, n=5)
    np.testing.assert_array_equal(r_neg, -r)


@given(finite, finite)
@settings(max_examples=300, deadline=None)
def test_commutative(x, y):
    # A/C and B/D play symmetric roles (incl. the special-case forcing rules)
    np.testing.assert_array_equal(_mult(x, y, n=5), _mult(y, x, n=5))


@given(finite)
@settings(max_examples=200, deadline=None)
def test_mult_by_zero_and_one_powers(x):
    assert _mult(x, 0.0, n=5) == 0.0
    # powers of two have zero mantissa -> product equals the operand with its
    # mantissa truncated to 3n bits (paper Fig. 3: inputs keep upper 3n bits)
    from repro.core.formats import truncate_mantissa

    for p in (1.0, 2.0, 0.5, 4.0):
        r = float(_mult(x, p, n=5))
        want = float(np.float32(np.asarray(truncate_mantissa(np.float32(x), 15))) * np.float32(p))
        if np.isfinite(want) and abs(want) >= float(np.float32(2.0 ** -126)):
            assert r == want, (x, p, r, want)


@given(finite, finite)
@settings(max_examples=300, deadline=None)
def test_relative_error_bound(x, y):
    # AC-n-n truncates at most ~2^-(2n-? ) of each mantissa; conservative
    # bound: relative error < 2^-(n-1) for all normal operands/results.
    r = float(_mult(x, y, n=5))
    want = float(np.float32(x) * np.float32(y))
    if want == 0.0 or not np.isfinite(want) or abs(want) < 2.0 ** -100:
        return
    assert abs(r - want) / abs(want) < 2.0 ** -4, (x, y, r, want)


# ---- exact multiplier bit-exactness (from test_core_exact_mult) ------------

@given(f32_full, f32_full)
@settings(max_examples=500, deadline=None)
def test_bit_exact_vs_host_fp32(x, y):
    x, y = np.float32(x), np.float32(y)
    got = exact_mult.np_exact_mult_f32(x, y)
    want = x * y
    if np.isnan(want):
        assert np.isnan(got), (x, y, got, want)  # nan payloads may differ
    else:
        assert got.view(np.uint32) == want.view(np.uint32), (x, y, got, want)


# ---- format encode/decode roundtrips (from test_core_formats) --------------

@given(st.floats(width=32, allow_nan=False, allow_infinity=False))
@settings(max_examples=300, deadline=None)
def test_np_roundtrip_fp32(x):
    x = np.float32(x)
    bits = formats.np_f32_to_bits(x)
    sign, exp, man = formats.np_decode(bits, formats.FP32)
    back = formats.np_encode(sign, exp, man, formats.FP32)
    assert back == bits
    val = formats.np_decode_to_value(bits, formats.FP32)
    assert val == np.float64(x)


@given(st.floats(width=32, allow_nan=False))
@settings(max_examples=300, deadline=None)
def test_np_encode_from_value_matches_cast(x):
    # float64 -> fp32 RNE must agree with numpy's cast
    enc = formats.np_encode_from_value(np.float64(x), formats.FP32)
    want = formats.np_f32_to_bits(np.float32(x))
    assert enc == want, (x, hex(int(enc)), hex(int(want)))


# ---- system invariants over the registry (from test_system) ----------------

@given(mults, finite, finite)
@settings(max_examples=200, deadline=None)
def test_every_multiplier_sign_correct(name, x, y):
    """Invariant: all registry multipliers have an EXACT sign/zero path."""
    r = float(get_multiplier(name)(jnp.float32(x), jnp.float32(y)))
    want = np.float32(x) * np.float32(y)
    if want == 0 or not np.isfinite(want) or abs(want) < 2.0 ** -100:
        return
    assert np.sign(r) == np.sign(want) or r == 0.0, (name, x, y, r)


@given(mults, finite, finite)
@settings(max_examples=200, deadline=None)
def test_every_multiplier_bounded_error(name, x, y):
    """Invariant: relative error never exceeds the Mitchell bound (~12.5%)
    for normal operands/results — the worst design in the registry."""
    r = float(get_multiplier(name)(jnp.float32(x), jnp.float32(y)))
    want = float(np.float32(x) * np.float32(y))
    if want == 0 or not np.isfinite(want) or abs(want) < 2.0 ** -60:
        return
    assert abs(r - want) / abs(want) < 0.13, (name, x, y, r, want)


# ---- design-ladder monotonicity (every SWEEPABLE design) -------------------
#
# Within each family, MRED must be non-increasing in the width knob the
# paper sweeps (segment width n for AC/ACL, booth span k for MMBS, mantissa
# m for CSS) — and for the log family in compensation strength (NC -> LPC
# -> HPC).  The union of the ladders is asserted to cover the whole
# SWEEPABLE table, so a new design cannot silently dodge the property.

LADDERS = {
    "ac": ["AC3-3", "AC4-4", "AC5-5", "AC6-6", "AC7-7"],
    "acl": ["ACL4", "ACL5", "ACL6"],
    "mmbs": ["MMBS5", "MMBS6", "MMBS7"],
    "css": ["CSS12", "CSS14", "CSS16", "CSS18"],
    "log": ["NC", "LPC", "HPC"],
}


def test_ladders_cover_every_sweepable_design():
    from repro.core.sweep import SWEEPABLE

    assert set(SWEEPABLE) == {n for fam in LADDERS.values() for n in fam}


@given(st.sampled_from(sorted(LADDERS)), st.integers(0, 2 ** 16))
@settings(max_examples=15, deadline=None)
def test_mred_monotone_non_increasing_in_width(family, seed):
    from repro.core.metrics import mred

    rng = np.random.default_rng(seed)
    x = rng.uniform(-4, 4, 2000).astype(np.float32)
    y = rng.uniform(-4, 4, 2000).astype(np.float32)
    exact = x.astype(np.float64) * y.astype(np.float64)
    errs = [mred(np.asarray(get_multiplier(n)(jnp.asarray(x), jnp.asarray(y))),
                 exact) for n in LADDERS[family]]
    for wide, narrow in zip(errs[1:], errs[:-1]):
        # widening a segment keeps strictly more mantissa product bits;
        # tiny relative slack absorbs sample noise at the 2e3-operand size
        assert wide <= narrow * 1.001 + 1e-12, (family, errs)


# ---- composed-error prediction brackets measured error ---------------------
#
# The gain-aware sensitivity model (rms local error vs the calibration
# default, JVP-probe gains composed along dataflow chains, MRED tail
# factor at the head — repro.core.sensitivity) must bracket the measured
# network MRED within stated factors on random 2-4 layer linear stacks
# and on a 2-block transformer stack.  The bracket is asymmetric: the
# linear (no-cancellation) composition deliberately over-predicts
# (independent per-site errors partially cancel), while MRED's
# small-denominator tail can inflate the measured side.  The flat
# (pre-gain) model needed 24x/64x here; the gain-aware model pins 6x/32x
# — observed extremes over 700+ linear stacks and 52 transformer seeds
# are 1.8x/14.6x, so the stated factors carry >= 2x headroom.

BRACKET_OVER = 6.0     # measured <= pred * BRACKET_OVER      (was 24x flat)
BRACKET_UNDER = 32.0   # pred <= (measured + baseline) * BRACKET_UNDER (was 64x)


@given(st.integers(2, 4), st.integers(1, 3), st.integers(0, 2 ** 16))
@settings(max_examples=15, deadline=None)
def test_composed_error_prediction_brackets_measured(depth, passes, seed):
    from repro.core import sensitivity
    from repro.core.metrics import mred
    from repro.core.numerics import NumericsConfig, nmatmul
    from repro.core.policy import NumericsPolicy

    exact_f32 = NumericsConfig(mode="exact", compute_dtype="float32")
    rng = np.random.default_rng(seed)
    dims = [int(rng.integers(8, 33)) for _ in range(depth + 1)]
    ws = [jnp.asarray(rng.standard_normal((dims[i], dims[i + 1]))
                      / np.sqrt(dims[i]), jnp.float32) for i in range(depth)]
    x = jnp.asarray(rng.standard_normal((16, dims[0])), jnp.float32)

    def fwd(pol):
        h = x
        for i, w in enumerate(ws):
            h = nmatmul(h, w, pol, path=f"layer.{i}").astype(jnp.float32)
        return h

    def eval_fn(pol):
        fwd(pol)
        return 0.0

    model = sensitivity.calibrate(eval_fn, default=exact_f32)
    # a pure chain: every site after the first consumes its predecessor's
    # output, so the probe gains compose downstream
    for i in range(1, depth):
        assert model.sites[f"layer.{i}"].chained
    seg = NumericsConfig(mode="segmented", seg_passes=passes, backend="xla")
    assignment = {f"layer.{i}": seg for i in range(depth)}
    pred = model.predict(assignment)
    pol = NumericsPolicy.from_assignments(assignment, default=exact_f32)
    ref = np.asarray(fwd(NumericsPolicy((), default=exact_f32)), np.float64)
    measured = mred(np.asarray(fwd(pol), np.float64), ref)
    assert pred > 0 and measured > 0
    assert measured <= pred * BRACKET_OVER, (depth, passes, pred, measured)
    assert pred <= measured * BRACKET_UNDER, (depth, passes, pred, measured)


@pytest.mark.slow
@given(st.integers(0, 2 ** 16))
@settings(max_examples=5, deadline=None)
def test_composed_error_brackets_measured_on_2block_transformer(seed):
    """The acceptance bracket on a real 2-block transformer stack (the
    setup where the flat model under-predicted ~2x and needed the 24x
    over-bracket): the gain-aware prediction stays within 6x/32x of the
    measured logits MRED.  The UNDER side compares against ``measured +
    baseline`` — the baseline term is the unrolled-calibration-vs-scanned
    numeric wobble the model carries additively by construction, and the
    scanned-vs-scanned measurement genuinely does not contain it."""
    import dataclasses

    import jax

    from repro.configs import get_arch
    from repro.core import sensitivity
    from repro.core.metrics import mred
    from repro.core.numerics import NumericsConfig
    from repro.core.policy import NumericsPolicy
    from repro.models import transformer
    from repro.models.layers import unzip

    cfg = get_arch("qwen3-4b").reduced()
    cfg = dataclasses.replace(cfg, segments=((2, cfg.segments[0][1]),))
    pp = transformer.init(cfg, jax.random.PRNGKey(seed))
    params, _ = unzip(pp)
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)),
                                   jnp.int32)}
    default = NumericsConfig(mode="exact", compute_dtype="float32")
    base_cfg = dataclasses.replace(cfg, numerics=default)
    h, _, _ = transformer.backbone(params, base_cfg, batch, mode="train")
    ref = np.asarray(transformer.logits_fn(params, base_cfg, h), np.float64)

    def eval_fn(policy):
        pcfg = dataclasses.replace(cfg, numerics=policy)
        h, _, _ = transformer.backbone(params, pcfg, batch, mode="train")
        return mred(np.asarray(transformer.logits_fn(params, pcfg, h)), ref)

    model = sensitivity.calibrate(eval_fn, default=default)
    seg1 = NumericsConfig(mode="segmented", seg_passes=1, backend="xla")
    paths = [p for p in transformer.layer_paths(cfg)
             if not p.endswith(".scan")]
    assignment = {p: seg1 for p in paths}
    pred = model.predict(assignment)
    measured = eval_fn(NumericsPolicy.from_assignments(assignment,
                                                       default=default))
    assert pred > 0 and measured > 0
    assert measured <= pred * BRACKET_OVER, (pred, measured)
    assert pred <= (measured + model.baseline_error) * BRACKET_UNDER, (
        pred, measured, model.baseline_error)


@given(st.integers(1, 3), st.integers(2, 6), st.integers(2, 6))
@settings(max_examples=30, deadline=None)
def test_segmented_matmul_linearity(passes, m, n):
    """Invariant: segmented matmul is (near-)linear in its inputs — term
    dropping must commute with addition for gradient correctness."""
    from repro.core.numerics import segmented_matmul_xla

    rng = np.random.default_rng(m * 7 + n)
    x1 = jnp.asarray(rng.standard_normal((m, 8)), jnp.float32)
    x2 = jnp.asarray(rng.standard_normal((m, 8)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((8, n)), jnp.float32)
    both = np.asarray(segmented_matmul_xla(x1 + x2, w, passes))
    sep = np.asarray(segmented_matmul_xla(x1, w, passes)) + \
        np.asarray(segmented_matmul_xla(x2, w, passes))
    # not bit-equal (hi/lo split is nonlinear at bf16 boundaries) but tight
    np.testing.assert_allclose(both, sep, rtol=0.05, atol=0.05)
