"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.afpm import AFPMConfig
from repro.kernels import ops, ref
from repro.kernels.afpm_bitwise import afpm_bitwise_pallas
from repro.kernels.afpm_matmul import afpm_matmul_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas


# ---------------------------------------------------------------------------
# afpm_matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(8, 16, 8), (128, 256, 128), (100, 130, 50), (256, 512, 384)])
@pytest.mark.parametrize("passes", [1, 2, 3])
def test_afpm_matmul_matches_ref(shape, passes):
    M, K, N = shape
    rng = np.random.default_rng(hash((M, K, N, passes)) % 2**31)
    x = rng.standard_normal((M, K)).astype(np.float32)
    w = rng.standard_normal((K, N)).astype(np.float32)
    got = afpm_matmul_pallas(jnp.asarray(x), jnp.asarray(w), passes,
                             bm=64, bn=64, bk=64, interpret=True)
    want = ref.afpm_matmul_ref(jnp.asarray(x), jnp.asarray(w), passes)
    # blocked accumulation reorders fp32 adds vs the single-dot oracle
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_afpm_matmul_dtypes(dtype):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((64, 96)), dtype)
    w = jnp.asarray(rng.standard_normal((96, 64)), dtype)
    got = afpm_matmul_pallas(x, w, 3, bm=32, bn=32, bk=32, interpret=True)
    want = ref.afpm_matmul_ref(x.astype(jnp.float32), w.astype(jnp.float32), 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_afpm_matmul_accuracy_ladder():
    """More passes -> closer to the exact fp32 product (the accuracy knob)."""
    rng = np.random.default_rng(1)
    x = rng.standard_normal((128, 256)).astype(np.float32)
    w = rng.standard_normal((256, 64)).astype(np.float32)
    exact = x.astype(np.float64) @ w.astype(np.float64)
    errs = []
    for p in (1, 2, 3):
        got = np.asarray(afpm_matmul_pallas(jnp.asarray(x), jnp.asarray(w), p,
                                            bm=64, bn=64, bk=64, interpret=True))
        errs.append(np.abs(got - exact).mean())
    assert errs[0] > errs[1] > errs[2], errs
    # 3-pass split-float keeps ~16 significand bits per operand
    rel = np.abs(errs[2]) / np.abs(exact).mean()
    assert rel < 5e-4


def test_afpm_matmul_ops_wrapper_batch_dims():
    rng = np.random.default_rng(2)
    x = rng.standard_normal((2, 5, 48)).astype(np.float32)
    w = rng.standard_normal((48, 32)).astype(np.float32)
    got = ops.afpm_matmul(jnp.asarray(x), jnp.asarray(w), 3, force="xla")
    want = ref.afpm_matmul_ref(jnp.asarray(x), jnp.asarray(w), 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_afpm_matmul_rejects_bad_shapes():
    # batched (3-D+) x is legal since the substrate (tested in
    # test_kernels_dispatch); bad contraction or rank still raises
    with pytest.raises(ValueError):
        afpm_matmul_pallas(jnp.zeros((4, 8)), jnp.zeros((9, 4)))
    with pytest.raises(ValueError):
        afpm_matmul_pallas(jnp.zeros((8,)), jnp.zeros((8, 4)))
    with pytest.raises(ValueError):
        afpm_matmul_pallas(jnp.zeros((4, 8)), jnp.zeros((8,)))


# ---------------------------------------------------------------------------
# afpm_bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(64,), (33, 77), (4, 130, 19)])
@pytest.mark.parametrize("cfg", [AFPMConfig(n=4), AFPMConfig(n=5), AFPMConfig(n=6),
                                 AFPMConfig(n=5, mode="acl")])
def test_afpm_bitwise_matches_ref(shape, cfg):
    rng = np.random.default_rng(hash((shape, cfg.n, cfg.mode)) % 2**31)
    x = rng.standard_normal(shape).astype(np.float32) * 4
    y = rng.standard_normal(shape).astype(np.float32) * 4
    got = afpm_bitwise_pallas(jnp.asarray(x), jnp.asarray(y), cfg,
                              block=(32, 64), interpret=True)
    want = ref.afpm_bitwise_ref(jnp.asarray(x), jnp.asarray(y), cfg)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_afpm_bitwise_ops_wrapper():
    rng = np.random.default_rng(3)
    x = rng.standard_normal((100,)).astype(np.float32)
    y = rng.standard_normal((100,)).astype(np.float32)
    got = ops.afpm_multiply(jnp.asarray(x), jnp.asarray(y), AFPMConfig(n=5), force="xla")
    want = ref.afpm_bitwise_ref(jnp.asarray(x), jnp.asarray(y), AFPMConfig(n=5))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# ssd_scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dims", [
    # (L, H, P, N, chunk)
    (64, 2, 16, 8, 16),
    (128, 1, 32, 16, 32),
    (96, 3, 8, 4, 32),
])
def test_ssd_scan_matches_ref(dims):
    L, H, P, N, chunk = dims
    rng = np.random.default_rng(hash(dims) % 2**31)
    x = rng.standard_normal((L, H, P)).astype(np.float32)
    dt = rng.uniform(0.01, 0.2, (L, H)).astype(np.float32)
    A = -rng.uniform(0.5, 2.0, (H,)).astype(np.float32)
    B = rng.standard_normal((L, N)).astype(np.float32)
    C = rng.standard_normal((L, N)).astype(np.float32)
    got = ssd_scan_pallas(*map(jnp.asarray, (x, dt, A, B, C)), chunk=chunk, interpret=True)
    want = ref.ssd_scan_ref(*map(jnp.asarray, (x, dt, A, B, C)))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_ssd_scan_chunk_invariance():
    """Chunk size is a tiling choice — results must not depend on it."""
    rng = np.random.default_rng(4)
    L, H, P, N = 128, 2, 8, 4
    x = rng.standard_normal((L, H, P)).astype(np.float32)
    dt = rng.uniform(0.01, 0.2, (L, H)).astype(np.float32)
    A = -rng.uniform(0.5, 2.0, (H,)).astype(np.float32)
    B = rng.standard_normal((L, N)).astype(np.float32)
    C = rng.standard_normal((L, N)).astype(np.float32)
    outs = [
        np.asarray(ssd_scan_pallas(*map(jnp.asarray, (x, dt, A, B, C)), chunk=c, interpret=True))
        for c in (16, 32, 64, 128)
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=2e-4, atol=2e-4)


def test_ssd_scan_state_decay_property():
    """With strongly negative A the state forgets: doubling early input
    must not change late outputs materially."""
    rng = np.random.default_rng(5)
    L, H, P, N = 64, 1, 4, 4
    x = rng.standard_normal((L, H, P)).astype(np.float32)
    dt = np.full((L, H), 0.5, np.float32)
    A = np.array([-8.0], np.float32)
    B = rng.standard_normal((L, N)).astype(np.float32)
    C = rng.standard_normal((L, N)).astype(np.float32)
    y1 = np.asarray(ref.ssd_scan_ref(*map(jnp.asarray, (x, dt, A, B, C))))
    x2 = x.copy()
    x2[:4] *= 2
    y2 = np.asarray(ref.ssd_scan_ref(*map(jnp.asarray, (x2, dt, A, B, C))))
    np.testing.assert_allclose(y1[-8:], y2[-8:], rtol=1e-3, atol=1e-3)
    assert not np.allclose(y1[:4], y2[:4])
