"""The measure-and-cache kernel autotuner (repro.kernels.autotune).

Guarantees pinned here:

1. artifact contract — the ``repro-tune/1`` schema round-trips, saves
   atomically, and every malformed shape (bad schema tag, bad keys, bad
   blocks, truncated JSON) is a one-line :class:`TuneError`, never a
   KeyError deep in dispatch;
2. sweep core — the injected ``measure_fn`` drives winner selection
   (argmin of median µs), candidate grids are validated and clipped to
   the measured problem, and untunable (kernel, backend) pairs are
   skipped rather than crashed on;
3. activation/dispatch wiring — with a table active, the dispatch
   lookups resolve from it; with none (or one tuned for a different
   device kind), behavior is bit-identical to the static tables.
   Activation is explicit only: a Session knob, the REPRO_TUNE_FILE env
   var, or activate() — never implicit measurement on a hot path.
"""
import json
import os

import pytest

from repro.kernels import autotune, dispatch
from repro.kernels.autotune import TuneError, TuningTable


@pytest.fixture(autouse=True)
def _clean_activation(monkeypatch):
    """Every test starts and ends with no active table and no env var."""
    monkeypatch.delenv(autotune.ENV_VAR, raising=False)
    autotune.deactivate()
    yield
    autotune.deactivate()


def make_table(device=None, **entries):
    t = TuningTable(device=device or autotune.device_kind())
    for key, block in entries.items():
        kernel, backend, bucket = key.split("__")
        t.put(kernel, backend, bucket, block, 1.0)
    return t


# ---------------------------------------------------------------------------
# artifact contract
# ---------------------------------------------------------------------------

def test_schema_round_trip(tmp_path):
    t = make_table(matmul__interpret__small=(16, 16, 16),
                   bitwise__interpret__medium=(64, 128),
                   ssd__xla__large=256)
    t.meta["fast"] = True
    path = tmp_path / "TUNE_test.json"
    t.save(str(path))
    loaded = autotune.load(str(path))
    assert loaded.device == t.device
    assert loaded.meta == {"fast": True}
    assert loaded.lookup("matmul", "interpret", "small") == (16, 16, 16)
    assert loaded.lookup("bitwise", "interpret", "medium") == (64, 128)
    assert loaded.lookup("ssd", "xla", "large") == 256
    assert loaded.lookup("ssd", "xla", "small") is None
    data = json.loads(path.read_text())
    assert data["schema"] == autotune.SCHEMA


def test_save_is_atomic_and_leaves_no_temp(tmp_path):
    path = tmp_path / "TUNE_a.json"
    make_table(ssd__xla__small=64).save(str(path))
    # overwrite with different content: reader must never see a mix
    make_table(ssd__xla__small=128).save(str(path))
    assert autotune.load(str(path)).lookup("ssd", "xla", "small") == 128
    assert [p.name for p in tmp_path.iterdir()] == ["TUNE_a.json"]


def test_load_rejects_malformed_artifacts(tmp_path):
    cases = {
        "missing.json": None,  # no file at all
        "not_json.json": "{oops",
        "bad_schema.json": json.dumps({"schema": "repro-tune/999",
                                       "device": "cpu", "entries": {}}),
        "no_device.json": json.dumps({"schema": autotune.SCHEMA,
                                      "entries": {}}),
        "bad_key.json": json.dumps({
            "schema": autotune.SCHEMA, "device": "cpu",
            "entries": {"matmul/small": {"block": 1, "median_us": 1.0}}}),
        "bad_kernel.json": json.dumps({
            "schema": autotune.SCHEMA, "device": "cpu",
            "entries": {"conv/xla/small": {"block": 1, "median_us": 1.0}}}),
        "bad_block.json": json.dumps({
            "schema": autotune.SCHEMA, "device": "cpu",
            "entries": {"ssd/xla/small": {"block": -8, "median_us": 1.0}}}),
        "no_median.json": json.dumps({
            "schema": autotune.SCHEMA, "device": "cpu",
            "entries": {"ssd/xla/small": {"block": 64}}}),
    }
    for name, content in cases.items():
        p = tmp_path / name
        if content is not None:
            p.write_text(content)
        with pytest.raises(TuneError):
            autotune.load(str(p))


def test_entry_key_validates_names():
    assert autotune.entry_key("ssd", "xla", "large") == "ssd/xla/large"
    with pytest.raises(TuneError):
        autotune.entry_key("conv", "xla", "large")
    with pytest.raises(TuneError):
        autotune.entry_key("ssd", "cuda", "large")
    with pytest.raises(TuneError):
        autotune.entry_key("ssd", "xla", "huge")


def test_candidates_clip_to_problem_but_never_empty():
    full = autotune.candidates("matmul", "interpret", "large")
    assert all(isinstance(b, tuple) and len(b) == 3 for b in full)
    clipped = autotune.candidates("matmul", "interpret", "large",
                                  max_extent=64)
    assert clipped == [(64, 64, 64)]
    # every candidate oversized -> keep the smallest instead of an empty grid
    tiny = autotune.candidates("matmul", "interpret", "large", max_extent=8)
    assert tiny == [full[0]]
    # the xla matmul reference takes no blocks: not tunable
    assert not autotune.tunable("matmul", "xla")
    assert autotune.tunable("ssd", "xla")
    with pytest.raises(TuneError):
        autotune.candidates("matmul", "xla", "small")


# ---------------------------------------------------------------------------
# sweep core (fake measure_fn — no kernels, no timing)
# ---------------------------------------------------------------------------

def test_sweep_picks_the_measured_argmin():
    # fastest candidate by construction: the one whose first dim is 64
    def fake_measure(kernel, backend, bucket, block, size):
        dims = block if isinstance(block, tuple) else (block,)
        return 1.0 if dims[0] == 64 else 100.0

    table = autotune.sweep(fake_measure, kernels=("ssd",),
                           backends=("interpret", "xla"),
                           buckets=("small", "medium"), device="testdev")
    assert table.device == "testdev"
    assert table.lookup("ssd", "interpret", "small") == 64
    assert table.lookup("ssd", "xla", "medium") == 64
    # every candidate's measurement is recorded alongside the winner
    entry = table.entries["ssd/xla/medium"]
    assert entry["median_us"] == 1.0
    assert set(entry["candidates"]) == {"64", "128", "256"}


def test_sweep_skips_untunable_pairs_and_clips_by_size():
    seen = []

    def fake_measure(kernel, backend, bucket, block, size):
        seen.append((kernel, backend, bucket, block))
        return 1.0

    table = autotune.sweep(fake_measure, kernels=("matmul", "ssd"),
                           backends=("xla",), buckets=("small",),
                           sizes={"small": 32}, device="testdev")
    # matmul/xla has no block knob: skipped entirely, no entry, no calls
    assert all(k != "matmul" for k, *_ in seen)
    assert "matmul/xla/small" not in table.entries
    # ssd candidates above the 32-extent problem were clipped
    assert all(b <= 32 for *_, b in seen)
    assert table.lookup("ssd", "xla", "small") == 32


# ---------------------------------------------------------------------------
# activation + dispatch wiring
# ---------------------------------------------------------------------------

def test_dispatch_resolves_from_active_table():
    t = make_table(matmul__interpret__small=(16, 16, 16),
                   bitwise__interpret__small=(48, 48),
                   ssd__xla__small=48)
    autotune.activate(t)
    assert autotune.active_source() == "<in-memory>"
    assert dispatch.matmul_block_sizes("interpret", 64, 64, 64) == (16, 16, 16)
    assert dispatch.bitwise_block("interpret", 1024) == (48, 48)
    assert dispatch.scan_chunk("xla", 96) == 48
    # keys the table does not cover fall back to the static tables
    assert dispatch.matmul_block_sizes("interpret", 512, 512, 512) \
        == dispatch.MATMUL_BLOCKS[("interpret", "medium")]


def test_deactivate_restores_static_tables_bit_identically():
    static = (dispatch.matmul_block_sizes("interpret", 64, 64, 64),
              dispatch.bitwise_block("interpret", 1024),
              dispatch.scan_chunk("xla", 96))
    autotune.activate(make_table(matmul__interpret__small=(16, 16, 16),
                                 bitwise__interpret__small=(48, 48),
                                 ssd__xla__small=48))
    autotune.deactivate()
    assert (dispatch.matmul_block_sizes("interpret", 64, 64, 64),
            dispatch.bitwise_block("interpret", 1024),
            dispatch.scan_chunk("xla", 96)) == static
    assert static == (dispatch.MATMUL_BLOCKS[("interpret", "small")],
                      dispatch.BITWISE_BLOCKS[("interpret", "small")],
                      dispatch.SCAN_CHUNKS[("xla", "small")])


def test_table_for_other_device_kind_never_applies():
    t = make_table(device="tpu_v4", ssd__xla__small=999)
    autotune.activate(t)
    assert autotune.active_table() is t  # active, but gated off by device
    assert dispatch.scan_chunk("xla", 96) \
        == dispatch.SCAN_CHUNKS[("xla", "small")]


def test_env_var_activates_lazily_on_first_lookup(tmp_path, monkeypatch):
    path = tmp_path / "TUNE_env.json"
    make_table(ssd__xla__small=48).save(str(path))
    monkeypatch.setenv(autotune.ENV_VAR, str(path))
    autotune.deactivate()  # forget the env var was already checked
    assert dispatch.scan_chunk("xla", 96) == 48
    assert autotune.active_source() == str(path)


def test_activate_path_errors_are_structured(tmp_path):
    with pytest.raises(TuneError, match="cannot read"):
        autotune.activate(str(tmp_path / "nope.json"))
    bad = tmp_path / "bad.json"
    bad.write_text("{truncated")
    with pytest.raises(TuneError, match="unreadable"):
        autotune.activate(str(bad))
    # a failed activation must not leave a half-installed table behind
    assert autotune.active_table() is None


def test_session_tune_knob_activates_and_rejects_bad_artifacts(tmp_path):
    from repro.session import Session, SessionError

    path = tmp_path / "TUNE_sess.json"
    make_table(ssd__xla__small=48).save(str(path))
    Session("qwen3-4b", tune=str(path))
    assert autotune.active_source() == str(path)
    assert dispatch.scan_chunk("xla", 96) == 48
    autotune.deactivate()
    with pytest.raises(SessionError):
        Session("qwen3-4b", tune=str(tmp_path / "missing.json"))
