"""The kernel substrate: backend dispatch, tuning tables, batched grids,
and golden bit-level vectors.

Three layers of guarantees:

1. dispatch plumbing — backend resolution (auto/legacy-force mapping),
   tuning-table lookups, and the NumericsConfig/registry entry points all
   route to the right implementation;
2. backend equivalence — the Pallas kernel bodies (interpret mode) are
   BIT-IDENTICAL to the XLA references across batched and odd shapes for
   the matmul (single contraction block, so the fp32 accumulation order
   matches the oracle's single dot) and the elementwise kernel, and
   ulp-tight for the SSD scan;
3. golden vectors — the bit-level AFPM datapath is pinned against a
   pure-Python integer reference (tests/golden/, regenerate with
   gen_afpm_golden.py).
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.afpm import AFPMConfig, afpm_mult_f32
from repro.core.numerics import NumericsConfig, nmatmul
from repro.core.registry import get_elementwise, get_multiplier
from repro.kernels import dispatch, ops, ref

GOLDEN = os.path.join(os.path.dirname(__file__), "golden", "afpm_golden.json")


# ---------------------------------------------------------------------------
# dispatch plumbing
# ---------------------------------------------------------------------------

def test_resolve_backend_auto_and_explicit():
    native = "pallas" if jax.default_backend() == "tpu" else "xla"
    assert dispatch.resolve_backend("auto") == native
    assert dispatch.resolve_backend("xla") == "xla"
    assert dispatch.resolve_backend("interpret") == "interpret"
    if jax.default_backend() == "tpu":
        assert dispatch.resolve_backend("pallas") == "pallas"
    else:
        # fail fast at the dispatch boundary, not deep in Mosaic lowering
        with pytest.raises(ValueError, match="requires a TPU"):
            dispatch.resolve_backend("pallas")


def test_resolve_backend_legacy_force_mapping():
    # the pre-substrate ops API: force= and interpret= keep working
    assert dispatch.resolve_backend("auto", force="xla") == "xla"
    assert dispatch.resolve_backend("auto", force="pallas", interpret=True) == "interpret"
    if jax.default_backend() == "tpu":
        assert dispatch.resolve_backend("auto", force="pallas") == "pallas"
    else:
        with pytest.raises(ValueError, match="requires a TPU"):
            dispatch.resolve_backend("auto", force="pallas")
    # an explicit backend wins over the legacy knob
    assert dispatch.resolve_backend("xla", force="pallas") == "xla"
    # bare interpret=True downgrades wherever pallas was selected — including
    # via auto (legacy: on CPU auto resolves to xla and interpret is ignored)
    native = "pallas" if jax.default_backend() == "tpu" else "xla"
    want = "interpret" if native == "pallas" else "xla"
    assert dispatch.resolve_backend("auto", interpret=True) == want
    assert dispatch.resolve_backend("pallas", interpret=True) == "interpret"


def test_resolve_backend_rejects_unknown():
    with pytest.raises(ValueError):
        dispatch.resolve_backend("tpu")
    with pytest.raises(ValueError):
        dispatch.resolve_backend("auto", force="interpret")


def test_tuning_tables_cover_all_buckets():
    for backend in ("pallas", "interpret"):
        for bucket in ("small", "medium", "large"):
            assert (backend, bucket) in dispatch.MATMUL_BLOCKS
            assert (backend, bucket) in dispatch.BITWISE_BLOCKS
            assert (backend, bucket) in dispatch.SCAN_CHUNKS
    assert dispatch.shape_bucket(128, 64) == "small"
    assert dispatch.shape_bucket(512, 64) == "medium"
    assert dispatch.shape_bucket(4096) == "large"
    # interpreter tiles are smaller than TPU tiles in every bucket
    for bucket in ("small", "medium", "large"):
        assert (dispatch.MATMUL_BLOCKS[("interpret", bucket)]
                < dispatch.MATMUL_BLOCKS[("pallas", bucket)])


def test_bitwise_block_bucket_boundaries_exact():
    """Regression: int(nelems ** 0.5) + 1 pushed exact-square boundary
    sizes one bucket up (65536 elems -> side 257 -> "medium"); the
    ceiling-isqrt bucketing keeps 256**2 in "small" and only crosses on
    65537."""
    assert dispatch.bitwise_block("interpret", 256 * 256) \
        == dispatch.BITWISE_BLOCKS[("interpret", "small")]
    assert dispatch.bitwise_block("interpret", 256 * 256 + 1) \
        == dispatch.BITWISE_BLOCKS[("interpret", "medium")]
    # the medium/large boundary follows the same rule (1024**2 elems)
    assert dispatch.bitwise_block("interpret", 1024 * 1024) \
        == dispatch.BITWISE_BLOCKS[("interpret", "medium")]
    assert dispatch.bitwise_block("interpret", 1024 * 1024 + 1) \
        == dispatch.BITWISE_BLOCKS[("interpret", "large")]
    # degenerate sizes bucket small instead of crashing isqrt
    assert dispatch.bitwise_block("interpret", 0) \
        == dispatch.BITWISE_BLOCKS[("interpret", "small")]
    assert dispatch.bitwise_block("interpret", 1) \
        == dispatch.BITWISE_BLOCKS[("interpret", "small")]


def test_ssd_xla_default_chunk_comes_from_table(rng):
    """The xla reference's chunk=None is tuned like every other backend
    (the legacy path hardcoded 128 regardless of L) and stays exact."""
    assert dispatch.scan_chunk("xla", 96) \
        == dispatch.SCAN_CHUNKS[("xla", "small")]
    assert dispatch.scan_chunk("xla", 2048) \
        == dispatch.SCAN_CHUNKS[("xla", "large")]
    L, H, P, N = 72, 2, 8, 4
    x = jnp.asarray(rng.standard_normal((L, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (L, H)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (H,)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((L, N)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((L, N)), jnp.float32)
    got = dispatch.ssd(x, dt, A, B, C, backend="xla")
    want = ref.ssd_scan_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_numerics_config_backend_validation():
    with pytest.raises(ValueError):
        NumericsConfig(mode="segmented", backend="cuda")
    assert NumericsConfig(backend="interpret").backend == "interpret"


def test_nmatmul_segmented_routes_through_dispatch(rng):
    x = jnp.asarray(rng.standard_normal((4, 24, 40)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((40, 16)), jnp.float32)
    via_xla = nmatmul(x, w, NumericsConfig(mode="segmented", backend="xla"))
    via_interp = nmatmul(x, w, NumericsConfig(mode="segmented", backend="interpret"))
    want = ref.afpm_matmul_ref(x, w, 3)
    np.testing.assert_array_equal(np.asarray(via_xla), np.asarray(want))
    np.testing.assert_allclose(np.asarray(via_interp), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_registry_elementwise_backends_agree(rng):
    x = jnp.asarray(rng.standard_normal(777) * 4, jnp.float32)
    y = jnp.asarray(rng.standard_normal(777) * 4, jnp.float32)
    plain = get_multiplier("AC5-5")(x, y)
    for backend in ("xla", "interpret"):
        via = get_elementwise("AC5-5", backend=backend)(x, y)
        np.testing.assert_array_equal(np.asarray(via), np.asarray(plain))
    # non-AFPM designs fall back to the registered function
    assert get_elementwise("CSS16") is get_multiplier("CSS16")


# ---------------------------------------------------------------------------
# backend equivalence: pallas-interpret vs xla-ref, bit-identical
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [
    # (lead..., M, K, N): batched and odd/prime extents
    (37, 43, 29),
    (5, 37, 43, 29),
    (2, 3, 17, 33, 9),
])
@pytest.mark.parametrize("passes", [1, 3])
def test_matmul_interpret_bitwise_equals_xla(shape, passes, rng):
    *lead_mk, N = shape
    x = jnp.asarray(rng.standard_normal(tuple(lead_mk)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((lead_mk[-1], N)), jnp.float32)
    # one contraction block => identical fp32 accumulation order to the oracle
    blocks = (lead_mk[-2], N, lead_mk[-1])
    got = dispatch.matmul(x, w, passes, backend="interpret", block_sizes=blocks)
    want = dispatch.matmul(x, w, passes, backend="xla")
    assert got.shape == tuple(lead_mk[:-1]) + (N,)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_multiply_broadcasts_on_every_backend(rng):
    """Broadcastable operands must behave identically across backends (the
    Pallas kernel itself requires equal shapes; dispatch broadcasts)."""
    cfg = AFPMConfig(n=5)
    x = jnp.asarray(rng.standard_normal((8, 5)) * 4, jnp.float32)
    y = jnp.float32(1.5)
    outs = [dispatch.multiply(x, y, cfg, backend=b) for b in ("xla", "interpret")]
    for out in outs:
        assert out.shape == (8, 5)
    np.testing.assert_array_equal(np.asarray(outs[0]), np.asarray(outs[1]))


def test_ssd_default_chunk_comes_from_tuning_table(rng):
    """ops.ssd_scan with chunk=None consults the substrate's table (the old
    hardcoded 128 would skip it) and still matches the oracle."""
    L, H, P, N = 96, 2, 8, 4
    x = jnp.asarray(rng.standard_normal((L, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (L, H)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (H,)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((L, N)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((L, N)), jnp.float32)
    assert dispatch.scan_chunk("interpret", L) == 32  # not the legacy 128
    got = ops.ssd_scan(x, dt, A, B, C, backend="interpret")
    want = ref.ssd_scan_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("shape", [(61,), (33, 77), (3, 65, 19)])
def test_bitwise_interpret_bitwise_equals_xla(shape, rng):
    cfg = AFPMConfig(n=5)
    x = jnp.asarray(rng.standard_normal(shape) * 4, jnp.float32)
    y = jnp.asarray(rng.standard_normal(shape) * 4, jnp.float32)
    got = dispatch.multiply(x, y, cfg, backend="interpret")
    want = dispatch.multiply(x, y, cfg, backend="xla")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("dims", [(64, 2, 16, 8, 16), (96, 3, 8, 4, 32),
                                  (128, 4, 32, 16, 32), (256, 4, 16, 8, 64)])
def test_ssd_interpret_bitwise_equals_xla(dims, rng):
    """Bit-exact, not merely ulp-close: both paths consume the hoisted
    ref.chunk_decay, so no fusion-context FP contraction can diverge them.
    chunk=16 is the regression shape — computed in-kernel, A*cumsum(dt) was
    contracted differently there and drifted by hundreds of ulp."""
    L, H, P, N, chunk = dims
    x = jnp.asarray(rng.standard_normal((L, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (L, H)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (H,)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((L, N)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((L, N)), jnp.float32)
    got = dispatch.ssd(x, dt, A, B, C, chunk=chunk, backend="interpret")
    want = dispatch.ssd(x, dt, A, B, C, chunk=chunk, backend="xla")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_ssd_interpret_equals_xla_under_jit(rng):
    """The bit-exactness guarantee must survive jit (the production entry
    point ops.ssd_scan is jitted): the hoisted decay sits behind a
    materialization boundary in both compiled programs."""
    L, H, P, N, chunk = 64, 2, 16, 8, 16
    x = jnp.asarray(rng.standard_normal((L, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (L, H)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (H,)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((L, N)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((L, N)), jnp.float32)
    got = ops.ssd_scan(x, dt, A, B, C, chunk=chunk, backend="interpret")
    want = ops.ssd_scan(x, dt, A, B, C, chunk=chunk, backend="xla")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_ops_batched_matmul_native_grid(rng):
    """The jit'd wrapper keeps leading batch dims through the native grid
    (not reshape-flattening) and matches the oracle on every element."""
    x = jnp.asarray(rng.standard_normal((3, 2, 48, 45)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((45, 29)), jnp.float32)
    got = ops.afpm_matmul(x, w, 3, backend="interpret")
    want = ref.afpm_matmul_ref(x, w, 3)
    assert got.shape == (3, 2, 48, 29)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_matmul_shape_validation_is_backend_uniform():
    for backend in ("xla", "interpret"):
        with pytest.raises(ValueError):
            dispatch.matmul(jnp.zeros((4, 8)), jnp.zeros((9, 4)), backend=backend)
        with pytest.raises(ValueError):
            dispatch.matmul(jnp.zeros((4, 8)), jnp.zeros((8,)), backend=backend)


def test_matmul_vector_lhs_on_every_backend(rng):
    """1-D x is promoted to (1, K) uniformly — the legacy ops wrapper
    accepted vectors, and auto must not crash only on one backend."""
    v = jnp.asarray(rng.standard_normal(24), jnp.float32)
    w = jnp.asarray(rng.standard_normal((24, 12)), jnp.float32)
    outs = [dispatch.matmul(v, w, 3, backend=b) for b in ("xla", "interpret")]
    for out in outs:
        assert out.shape == (12,)
    # GEMV lowers to a different XLA reduction strategy than the kernel's
    # (1, K) dot -> ulp-level wobble, not bit-exact like the 2-D cases
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(outs[1]),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("backend", ["xla", "interpret"])
def test_ssd_entry_point_pads_arbitrary_lengths(backend, rng):
    """dispatch.ssd itself must accept L not divisible by the (possibly
    auto-selected) chunk — padding is exact dt=0 steps."""
    L, H, P, N = 100, 2, 8, 4
    x = jnp.asarray(rng.standard_normal((L, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (L, H)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (H,)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((L, N)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((L, N)), jnp.float32)
    got = dispatch.ssd(x, dt, A, B, C, backend=backend)  # chunk auto-selected
    assert got.shape == (L, H, P)
    want = ref.ssd_scan_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# golden vectors: JAX datapath vs pure-Python bit-level reference
# ---------------------------------------------------------------------------

def _is_nan_bits(bits):
    return (((bits >> 23) & 0xFF) == 255) & ((bits & 0x7FFFFF) != 0)


def _golden_cases():
    with open(GOLDEN) as f:
        return json.load(f)["cases"]


@pytest.mark.parametrize("case", _golden_cases(), ids=lambda c: c["label"])
def test_afpm_golden_vectors(case):
    cfg = AFPMConfig(n=case["n"], mode=case["mode"], fmt=case["fmt"])
    x = jax.lax.bitcast_convert_type(
        jnp.asarray(case["x_bits"], jnp.uint32), jnp.float32)
    y = jax.lax.bitcast_convert_type(
        jnp.asarray(case["y_bits"], jnp.uint32), jnp.float32)
    got = np.asarray(
        jax.lax.bitcast_convert_type(afpm_mult_f32(x, y, cfg), jnp.uint32))
    want = np.asarray(case["out_bits"], np.uint32)
    # NaN payloads are unspecified; everything else is bit-exact
    ok = (got == want) | (_is_nan_bits(got) & _is_nan_bits(want))
    bad = np.where(~ok)[0]
    assert bad.size == 0, [
        (int(i), hex(case["x_bits"][i]), hex(case["y_bits"][i]),
         hex(int(got[i])), hex(int(want[i]))) for i in bad[:10]
    ]


def test_golden_file_covers_required_configs():
    labels = {c["label"] for c in _golden_cases()}
    assert {"AC5-5/fp32", "ACL4/fp32", "AC3-3/bf16", "ACL4/bf16"} <= labels
    for case in _golden_cases():
        assert len(case["x_bits"]) == len(case["y_bits"]) == len(case["out_bits"])
        assert len(case["x_bits"]) >= 256
