"""KV-pool unit tests: slot allocator + scatter/gather golden fixtures.

The allocator contract is host-side and structural (exhaustion is a
:class:`ServingError` at admission time, never an XLA shape error
mid-step).  The data-movement contract is bit-exact: ``write_slot`` /
``read_slot`` are replayed over the synthetic pool pinned by
``tests/golden/gen_kvcache_golden.py`` (an independent dense-numpy
reference) and checked by CRC, then round-tripped through a REAL
prefilled transformer state to prove the synthetic shapes did not cheat.
"""
import json
import os
import zlib

import numpy as np
import pytest

from repro.serving import ServingError, SlotAllocator, pool_init, read_slot, \
    write_slot

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")


# ---------------------------------------------------------------------------
# slot allocator
# ---------------------------------------------------------------------------

def test_alloc_is_lowest_free_slot_first():
    a = SlotAllocator(3)
    assert [a.alloc("r0"), a.alloc("r1"), a.alloc("r2")] == [0, 1, 2]
    a.free(1)
    assert a.alloc("r3") == 1            # reuses the hole, not slot 3
    assert a.owners == {0: "r0", 1: "r3", 2: "r2"}
    assert a.owner(1) == "r3" and a.owner(5) is None


def test_exhaustion_is_a_structured_serving_error():
    a = SlotAllocator(2)
    a.alloc("r0")
    a.alloc("r1")
    with pytest.raises(ServingError, match="exhausted"):
        a.alloc("r2")
    assert a.n_free == 0                 # failed alloc did not corrupt state
    a.free(0)
    assert a.alloc("r2") == 0            # recoverable after a retirement


def test_allocator_misuse_raises():
    with pytest.raises(ServingError, match="at least 1"):
        SlotAllocator(0)
    a = SlotAllocator(1)
    with pytest.raises(ServingError, match="not allocated"):
        a.free(0)


# ---------------------------------------------------------------------------
# scatter/gather vs the dense reference (golden fixture)
# ---------------------------------------------------------------------------

def _golden():
    with open(os.path.join(GOLDEN_DIR, "kvcache_golden.json")) as f:
        return json.load(f)


def _leaf_values(path, shape, seed):
    # same input recipe as the generator (content keyed by path + seed)
    rng = np.random.default_rng(zlib.crc32(path.encode()) + seed)
    return rng.standard_normal(shape).astype(np.float32)


def _crc(a):
    return zlib.crc32(np.ascontiguousarray(np.asarray(a), np.float32)
                      .tobytes())


def _build_pool(leaves, seed):
    """Assemble the synthetic pool pytree (the transformer serving-state
    shape: layers list of per-phase leaf dicts, slot axis 1; enc_out slot
    axis 0) from ``layers.{i}.{phase}.{name}`` leaf paths."""
    import jax.numpy as jnp

    layers = {}
    pool = {}
    for path, shape in leaves.items():
        if path == "enc_out":
            pool["enc_out"] = jnp.asarray(_leaf_values(path, shape, seed))
            continue
        _, i, phase, name = path.split(".")
        layers.setdefault(int(i), {}).setdefault(int(phase), {})[name] = \
            jnp.asarray(_leaf_values(path, shape, seed))
    pool["layers"] = [layers[i] for i in sorted(layers)]
    return pool


def _flatten(pool):
    out = {}
    for i, seg in enumerate(pool["layers"]):
        for phase, leaves in seg.items():
            for name, leaf in leaves.items():
                out[f"layers.{i}.{phase}.{name}"] = leaf
    if "enc_out" in pool:
        out["enc_out"] = pool["enc_out"]
    return out


def test_write_slot_matches_dense_reference():
    g = _golden()
    leaves = {p: tuple(s) for p, s in g["leaves"].items()}
    pool = _build_pool(leaves, seed=0)
    for slot, sseed in g["script"]:
        req_shapes = {
            p: tuple(1 if i == (0 if p == "enc_out" else 1) else d
                     for i, d in enumerate(s))
            for p, s in leaves.items()}
        state = _build_pool(req_shapes, seed=sseed)
        pool = write_slot(pool, slot, state)
    got = {p: _crc(a) for p, a in _flatten(pool).items()}
    assert got == g["pool_crc"]


def test_read_slot_matches_dense_reference():
    g = _golden()
    leaves = {p: tuple(s) for p, s in g["leaves"].items()}
    pool = _build_pool(leaves, seed=0)
    for slot, sseed in g["script"]:
        req_shapes = {
            p: tuple(1 if i == (0 if p == "enc_out" else 1) else d
                     for i, d in enumerate(s))
            for p, s in leaves.items()}
        pool = write_slot(pool, slot, _build_pool(req_shapes, seed=sseed))
    got = {}
    for slot in range(g["n_slots"]):
        for p, leaf in _flatten(read_slot(pool, slot)).items():
            got[f"slot{slot}.{p}"] = _crc(leaf)
    assert got == g["read_crc"]


# ---------------------------------------------------------------------------
# round-trip through a REAL prefilled transformer state
# ---------------------------------------------------------------------------

def test_real_state_round_trip_is_bit_exact(rng):
    import jax

    from repro.configs import get_arch
    from repro.models import transformer
    from repro.models.layers import unzip

    cfg = get_arch("qwen3-4b").reduced()
    params, _ = unzip(transformer.init(cfg, jax.random.PRNGKey(0)))
    max_len = 16

    def prefilled(L, seed_off):
        toks = rng.integers(0, cfg.vocab, (1, L))
        _, state = transformer.prefill(
            params, cfg, {"tokens": np.asarray(toks, np.int32)},
            max_len=max_len)
        return state

    pool = pool_init(cfg, 3, max_len)
    s_a, s_b = prefilled(5, 0), prefilled(7, 1)
    pool = write_slot(pool, 2, s_a)
    pool = write_slot(pool, 0, s_b)

    def leaves(state):
        return jax.tree.leaves(state["layers"])

    for got, want in zip(leaves(read_slot(pool, 2)), leaves(s_a)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    for got, want in zip(leaves(read_slot(pool, 0)), leaves(s_b)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # overwrite slot 2: the new occupant's state comes back exactly — no
    # stale bits from s_a survive anywhere in the slot
    s_c = prefilled(3, 2)
    pool = write_slot(pool, 2, s_c)
    for got, want in zip(leaves(read_slot(pool, 2)), leaves(s_c)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
