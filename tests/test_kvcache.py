"""KV-cache unit tests: slot allocator + paged scatter/gather goldens.

The allocator contract is host-side and structural (exhaustion is a
:class:`ServingError` at admission time, never an XLA shape error
mid-step; the page allocator's reservation contract is covered in
``tests/test_serving_paging.py``).  The data-movement contract is
bit-exact: ``write_state`` / ``scatter_chunk`` / ``scatter_token`` /
``zero_pages`` / ``gather_state`` are replayed over the synthetic paged
pool pinned by ``tests/golden/gen_kvcache_golden.py`` — an independent
dense-numpy reference whose page-table indirection is done by hand, one
position at a time — and compared leaf-for-leaf with
``assert_array_equal`` plus CRC pins, then round-tripped through a REAL
prefilled transformer state to prove the synthetic shapes did not cheat.
"""
import json
import os
import zlib

import numpy as np
import pytest

from repro.serving import ServingError, SlotAllocator, gather_state, \
    paged_layout, paged_pool_init, scatter_chunk, scatter_token, \
    write_state, zero_pages

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")


# ---------------------------------------------------------------------------
# slot (decode-row) allocator
# ---------------------------------------------------------------------------

def test_alloc_is_lowest_free_slot_first():
    a = SlotAllocator(3)
    assert [a.alloc("r0"), a.alloc("r1"), a.alloc("r2")] == [0, 1, 2]
    a.free(1)
    assert a.alloc("r3") == 1            # reuses the hole, not slot 3
    assert a.owners == {0: "r0", 1: "r3", 2: "r2"}
    assert a.owner(1) == "r3" and a.owner(5) is None


def test_exhaustion_is_a_structured_serving_error():
    a = SlotAllocator(2)
    a.alloc("r0")
    a.alloc("r1")
    with pytest.raises(ServingError, match="exhausted"):
        a.alloc("r2")
    assert a.n_free == 0                 # failed alloc did not corrupt state
    a.free(0)
    assert a.alloc("r2") == 0            # recoverable after a retirement


def test_allocator_misuse_raises():
    with pytest.raises(ServingError, match="at least 1"):
        SlotAllocator(0)
    a = SlotAllocator(1)
    with pytest.raises(ServingError, match="not allocated"):
        a.free(0)


# ---------------------------------------------------------------------------
# paged scatter/gather vs the hand-indirected dense reference (golden)
# ---------------------------------------------------------------------------

def _golden():
    with open(os.path.join(GOLDEN_DIR, "kvcache_golden.json")) as f:
        return json.load(f)


def _leaf_values(path, shape, seed):
    # same input recipe as the generator (content keyed by path + seed)
    rng = np.random.default_rng(zlib.crc32(path.encode()) + seed)
    return rng.standard_normal(shape).astype(np.float32)


def _crc(a):
    return zlib.crc32(np.ascontiguousarray(np.asarray(a), np.float32)
                      .tobytes())


def _as_tree(flat):
    """Assemble the pool/state pytree (layers list of per-phase leaf
    dicts) from ``layers.{i}.{phase}.{name}`` leaf paths."""
    layers = {}
    for path, arr in flat.items():
        _, i, phase, name = path.split(".")
        layers.setdefault(int(i), {}).setdefault(int(phase), {})[name] = arr
    return {"layers": [layers[i] for i in sorted(layers)]}


def _flatten(tree):
    out = {}
    for i, seg in enumerate(tree["layers"]):
        for phase, leaves in seg.items():
            for name, leaf in leaves.items():
                out[f"layers.{i}.{phase}.{name}"] = leaf
    return out


def _layout(g):
    """The paged-phase layout the golden leaves imply: phase ``pi`` of
    segment ``si`` pages iff some ``paged`` leaf path lives there."""
    n_seg = 1 + max(int(p.split(".")[1]) for p in g["leaves"])
    paged = [set() for _ in range(n_seg)]
    for p in g["paged"]:
        _, i, phase, _ = p.split(".")
        paged[int(i)].add(int(phase))
    return tuple(frozenset(s) for s in paged)


def _replay(g):
    """Drive the scripted ops through the real kvcache functions."""
    import jax.numpy as jnp

    ps = g["page_size"]
    layout = _layout(g)
    pool = _as_tree({p: jnp.asarray(_leaf_values(p, tuple(s), 0))
                     for p, s in g["leaves"].items()})
    for op in g["script"]:
        if op["op"] == "zero_pages":
            pool = zero_pages(pool, layout, op["pages"])
            continue
        dense = _as_tree({p: jnp.asarray(_leaf_values(p, tuple(s),
                                                      op["seed"]))
                          for p, s in op["dense"].items()})
        if op["op"] == "write_state":
            pool = write_state(pool, layout, dense, op["slot"],
                               jnp.asarray(op["table"], jnp.int32), ps)
        elif op["op"] == "scatter_chunk":
            pool = scatter_chunk(pool, layout, dense,
                                 jnp.asarray(op["table"], jnp.int32),
                                 op["start"], op["length"], ps)
        elif op["op"] == "scatter_token":
            pool = scatter_token(pool, layout, dense,
                                 jnp.asarray(op["tables"], jnp.int32),
                                 jnp.asarray(op["pos"], jnp.int32), ps)
        else:  # a regenerated fixture must not outrun this replayer
            raise AssertionError(f"unknown golden op {op['op']!r}")
    return pool


def test_paged_script_matches_dense_reference():
    g = _golden()
    flat = _flatten(_replay(g))
    assert set(flat) == set(g["pool"])
    for p, want in g["pool"].items():
        np.testing.assert_array_equal(
            np.asarray(flat[p]), np.asarray(want, np.float32), err_msg=p)
    assert {p: _crc(a) for p, a in flat.items()} == g["pool_crc"]


def test_gather_state_matches_dense_reference():
    import jax.numpy as jnp

    g = _golden()
    pool = _replay(g)
    layout = _layout(g)
    pool_flat = _flatten(pool)
    for tables, want, want_crc in zip(g["gathers"], g["gather"],
                                      g["gather_crc"]):
        got = _flatten(gather_state(pool, layout,
                                    jnp.asarray(tables, jnp.int32)))
        for p in g["paged"]:
            np.testing.assert_array_equal(
                np.asarray(got[p]), np.asarray(want[p], np.float32),
                err_msg=f"{tables}: {p}")
            assert _crc(got[p]) == want_crc[p]
        for p in g["leaves"]:            # per-slot leaves pass through
            if p not in g["paged"]:
                np.testing.assert_array_equal(np.asarray(got[p]),
                                              np.asarray(pool_flat[p]))


def test_paged_layout_pages_attention_but_not_ssm():
    from repro.configs import get_arch

    qwen = get_arch("qwen3-4b").reduced()
    assert all(len(paged) == len(pattern) for paged, (_, pattern)
               in zip(paged_layout(qwen), qwen.segments))
    hybrid = get_arch("zamba2-7b").reduced()
    layout = paged_layout(hybrid)
    n_paged = sum(len(s) for s in layout)
    n_total = sum(len(pattern) for _, pattern in hybrid.segments)
    assert 0 < n_paged < n_total         # attention pages, SSM stays per-slot


# ---------------------------------------------------------------------------
# round-trip through a REAL prefilled transformer state
# ---------------------------------------------------------------------------

def test_real_state_round_trip_is_bit_exact(rng):
    import jax
    import jax.numpy as jnp

    from repro.configs import get_arch
    from repro.models import transformer
    from repro.models.layers import unzip

    cfg = get_arch("qwen3-4b").reduced()
    params, _ = unzip(transformer.init(cfg, jax.random.PRNGKey(0)))
    page_size, n_pages, null = 4, 6, 6
    layout = paged_layout(cfg)
    pool = paged_pool_init(cfg, 2, n_pages, page_size)

    def prefilled(L):
        toks = rng.integers(0, cfg.vocab, (1, L))
        _, state = transformer.prefill(
            params, cfg, {"tokens": np.asarray(toks, np.int32)}, max_len=8)
        return state

    def rows_match(dense, row, state):
        # the gathered row's buffered prefix vs the original state, leaf
        # by leaf (everything in qwen3 is attention, hence paged)
        for got, want in zip(jax.tree.leaves(_flatten(dense)),
                             jax.tree.leaves(_flatten(state))):
            np.testing.assert_array_equal(
                np.asarray(got[:, row:row + 1, :want.shape[2]]),
                np.asarray(want.astype(got.dtype)))

    # two states installed through FRAGMENTED out-of-order page tables
    s_a, s_b = prefilled(5), prefilled(7)
    t_a = jnp.asarray([5, 2, null, null], jnp.int32)
    t_b = jnp.asarray([3, 0, null, null], jnp.int32)
    pool = write_state(pool, layout, s_a, 0, t_a, page_size)
    pool = write_state(pool, layout, s_b, 1, t_b, page_size)
    dense = gather_state(pool, layout, jnp.stack([t_a, t_b]))
    rows_match(dense, 0, s_a)
    rows_match(dense, 1, s_b)

    # overwrite the OCCUPIED pages 5 and 2 (reversed order): the new
    # occupant's bits come back exactly, the other request is untouched
    s_c = prefilled(6)
    t_c = jnp.asarray([2, 5, null, null], jnp.int32)
    pool = write_state(pool, layout, s_c, 0, t_c, page_size)
    dense = gather_state(pool, layout, jnp.stack([t_c, t_b]))
    rows_match(dense, 0, s_c)
    rows_match(dense, 1, s_b)
