"""Per-arch smoke tests: reduced configs, one forward/train step on CPU,
shape checks, no NaNs, and prefill/decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, list_archs
from repro.models import transformer
from repro.models.layers import unzip

ARCHS = list_archs()


def _batch_for(cfg, B=2, S=32, key=0):
    rng = np.random.default_rng(key)
    batch = {}
    if cfg.frontend in ("audio_stub",):
        batch["enc_embeds"] = jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model)), jnp.float32)
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (B, cfg.decoder_len)), jnp.int32)
        batch["targets"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (B, cfg.decoder_len)), jnp.int32)
    elif cfg.frontend == "vision_stub":
        batch["embeds"] = jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model)), jnp.float32)
        batch["targets"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
        if cfg.mrope_sections:
            pos = np.broadcast_to(np.arange(S)[None, :, None], (B, S, 3)).copy()
            batch["positions"] = jnp.asarray(pos, jnp.int32)
    else:
        toks = rng.integers(0, cfg.vocab, (B, S + 1))
        batch["tokens"] = jnp.asarray(toks[:, :-1], jnp.int32)
        batch["targets"] = jnp.asarray(toks[:, 1:], jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_forward_smoke(arch):
    cfg = get_arch(arch).reduced()
    pp = transformer.init(cfg, jax.random.PRNGKey(0))
    params, specs = unzip(pp)
    # specs tree mirrors params tree
    assert jax.tree.structure(params) == jax.tree.structure(
        specs, is_leaf=lambda x: isinstance(x, tuple))
    batch = _batch_for(cfg)
    loss, grads = jax.value_and_grad(
        lambda p: transformer.loss_fn(p, cfg, batch))(params)
    assert np.isfinite(float(loss)), (arch, loss)
    assert float(loss) > 0.5  # ~log(vocab) at init
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    """Decode step at position S must match the full forward's next-token
    logits (cache correctness across GQA/MLA/local/SSM/shared blocks)."""
    cfg = get_arch(arch).reduced()
    if cfg.frontend == "vision_stub":
        pytest.skip("vlm prefill uses embeds; decode path covered via dense archs")
    pp = transformer.init(cfg, jax.random.PRNGKey(1))
    params, _ = unzip(pp)
    rng = np.random.default_rng(2)
    B, S = 2, 32
    if cfg.encoder_layers:
        enc = jnp.asarray(rng.standard_normal((B, 48, cfg.d_model)), jnp.float32)
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S + 1)), jnp.int32)
        batch_full = {"enc_embeds": enc, "tokens": toks}
        batch_pre = {"enc_embeds": enc, "tokens": toks[:, :S]}
        cfg = __import__("dataclasses").replace(cfg, enc_len=48)
    else:
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S + 1)), jnp.int32)
        batch_full = {"tokens": toks}
        batch_pre = {"tokens": toks[:, :S]}

    # ground truth: full forward over S+1 tokens, logits at the last position
    hidden, _, _ = transformer.backbone(params, cfg, batch_full, mode="train")
    want = np.asarray(transformer.logits_fn(params, cfg, hidden[:, -1:]))

    # prefill on S tokens, then one decode step with token S
    last_logits, state = transformer.prefill(params, cfg, batch_pre, max_len=S + 8)
    got_logits, state = transformer.decode_step(
        params, cfg, {"token": toks[:, S:S + 1]}, state, pos=jnp.int32(S))
    got = np.asarray(got_logits)

    np.testing.assert_allclose(got, want, rtol=0.05, atol=0.05)
    # and prefill's own last-token logits match the S-token forward
    hidden_s, _, _ = transformer.backbone(params, cfg, batch_pre, mode="train")
    want_s = np.asarray(transformer.logits_fn(params, cfg, hidden_s[:, -1:]))
    np.testing.assert_allclose(np.asarray(last_logits), want_s, rtol=0.05, atol=0.05)


@pytest.mark.parametrize("arch", ["qwen3-4b", "gemma2-9b"])
def test_local_vs_global_window_effect(arch):
    """Sanity: a tiny local window changes logits vs global attention."""
    import dataclasses

    from repro.configs.base import LayerSpec

    cfg = get_arch(arch).reduced()
    specs_local = tuple(
        (r, tuple(dataclasses.replace(s, attn="local", window=4) for s in p))
        for r, p in cfg.segments)
    cfg_local = dataclasses.replace(cfg, segments=specs_local)
    pp = transformer.init(cfg, jax.random.PRNGKey(3))
    params, _ = unzip(pp)
    batch = _batch_for(cfg, S=64)
    h1, _, _ = transformer.backbone(params, cfg, batch, mode="train")
    h2, _, _ = transformer.backbone(params, cfg_local, batch, mode="train")
    assert not np.allclose(np.asarray(h1), np.asarray(h2), atol=1e-3)


def test_param_counts_match_assignment():
    """Full configs land on the advertised model scale."""
    expect = {
        "deepseek-v3-671b": (600e9, 720e9),
        "llama4-maverick-400b-a17b": (380e9, 420e9),
        "qwen2-vl-72b": (65e9, 80e9),
        "gemma2-9b": (8e9, 11e9),
        "gemma3-12b": (10e9, 13.5e9),
        "minitron-8b": (7.5e9, 11e9),
        "qwen3-4b": (3.4e9, 4.6e9),
        "zamba2-7b": (6e9, 9e9),
        "mamba2-130m": (0.1e9, 0.2e9),
        "whisper-tiny": (0.02e9, 0.09e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_arch(arch).param_count()
        assert lo <= n <= hi, (arch, n)


def test_numerics_knob_changes_lm_output():
    """The paper's knob: segmented numerics perturbs logits measurably but
    slightly (segmented 3-pass ~ AC-n-n accuracy)."""
    import dataclasses

    from repro.core.numerics import NumericsConfig

    cfg = get_arch("qwen3-4b").reduced()
    pp = transformer.init(cfg, jax.random.PRNGKey(4))
    params, _ = unzip(pp)
    batch = _batch_for(cfg)
    h_exact, _, _ = transformer.backbone(params, cfg, batch, mode="train")
    cfg_seg = dataclasses.replace(
        cfg, numerics=NumericsConfig(mode="segmented", seg_passes=3, backend="xla"))
    h_seg, _, _ = transformer.backbone(params, cfg_seg, batch, mode="train")
    d = np.abs(np.asarray(h_exact) - np.asarray(h_seg))
    rel = d.mean() / (np.abs(np.asarray(h_exact)).mean() + 1e-9)
    assert 0 < rel < 5e-3, rel
