"""MoE dispatch correctness: vs dense reference, capacity, shared experts."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.numerics import NumericsConfig
from repro.models import moe as moe_mod
from repro.models.layers import unzip

NCFG = NumericsConfig(mode="exact", compute_dtype="float32")


def _setup(E=4, K=2, T=24, D=16, FF=32, cf=8.0, n_shared=0, seed=0):
    cfg_arch = get_arch("deepseek-v3-671b").reduced()
    cfg = dataclasses.replace(
        cfg_arch, d_model=D, d_ff=FF,
        moe=dataclasses.replace(cfg_arch.moe, n_experts=E, top_k=K,
                                capacity_factor=cf, n_shared=n_shared))
    pp = moe_mod.moe_init(jax.random.PRNGKey(seed), cfg)
    params, _ = unzip(pp)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, T // 2, D), jnp.float32)
    return cfg, params, x


def _dense_reference(params, x, cfg):
    """Route every token to its top-k experts WITHOUT capacity limits."""
    B, S, D = x.shape
    xt = np.asarray(x, np.float64).reshape(-1, D)
    router = np.asarray(params["router"], np.float64)
    logits = xt @ router
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs = probs / probs.sum(-1, keepdims=True)
    K = cfg.moe.top_k
    wi = np.asarray(params["wi"], np.float64)
    wg = np.asarray(params["wg"], np.float64)
    wo = np.asarray(params["wo"], np.float64)
    out = np.zeros_like(xt)
    for t in range(xt.shape[0]):
        top = np.argsort(probs[t])[::-1][:K]
        gates = probs[t][top]
        gates = gates / gates.sum()
        for e, g in zip(top, gates):
            h = xt[t] @ wi[e]
            gg = xt[t] @ wg[e]
            act = h * (gg / (1 + np.exp(-gg)))
            out[t] += g * (act @ wo[e])
    return out.reshape(B, S, D)


def test_moe_matches_dense_reference():
    cfg, params, x = _setup()
    got = np.asarray(moe_mod.moe_apply(params, x, cfg, NCFG))
    want = _dense_reference(params, x, cfg)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_moe_top1():
    cfg, params, x = _setup(K=1)
    got = np.asarray(moe_mod.moe_apply(params, x, cfg, NCFG))
    want = _dense_reference(params, x, cfg)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_capacity_drops_reduce_output_mass():
    """With a tiny capacity factor some tokens are dropped (their MoE output
    is zero) — output L2 must shrink vs generous capacity, never grow."""
    cfg_hi, params, x = _setup(cf=8.0, T=64)
    cfg_lo = dataclasses.replace(
        cfg_hi, moe=dataclasses.replace(cfg_hi.moe, capacity_factor=0.25))
    hi = np.asarray(moe_mod.moe_apply(params, x, cfg_hi, NCFG))
    lo = np.asarray(moe_mod.moe_apply(params, x, cfg_lo, NCFG))
    assert np.linalg.norm(lo) < np.linalg.norm(hi)
    assert not np.allclose(lo, hi)


def test_shared_expert_always_on():
    cfg, params, x = _setup(n_shared=1)
    got = np.asarray(moe_mod.moe_apply(params, x, cfg, NCFG))
    # zeroing the router keeps the shared-expert contribution
    params0 = dict(params)
    params0["router"] = jnp.zeros_like(params["router"])
    got0 = np.asarray(moe_mod.moe_apply(params0, x, cfg, NCFG))
    from repro.models.layers import mlp_apply

    shared = np.asarray(mlp_apply(params["shared"], x.reshape(-1, x.shape[-1]), NCFG))
    assert np.abs(shared).sum() > 0
    # both outputs contain the shared path; routed parts differ
    assert not np.allclose(got, got0)


def test_gates_renormalized():
    """top-k gates sum to 1 after renormalization: scaling router logits by a
    constant shift leaves the output invariant."""
    cfg, params, x = _setup()
    got1 = np.asarray(moe_mod.moe_apply(params, x, cfg, NCFG))
    params2 = dict(params)
    params2["router"] = params["router"] + 3.0  # softmax shift-invariant anyway
    got2 = np.asarray(moe_mod.moe_apply(params2, x, cfg, NCFG))
    np.testing.assert_allclose(got1, got2, rtol=1e-5, atol=1e-5)


def test_aux_loss_positive_and_uniform_minimum():
    T, E = 512, 8
    rng = np.random.default_rng(0)
    logits_uniform = jnp.zeros((T, E))
    eidx = jnp.asarray(rng.integers(0, E, (T, 1)))
    l_u = float(moe_mod.aux_load_balance_loss(logits_uniform, eidx, E))
    logits_peaked = jnp.asarray(np.eye(E)[rng.integers(0, 2, T)] * 10.0)
    eidx_peaked = jnp.argmax(logits_peaked, -1, keepdims=True)
    l_p = float(moe_mod.aux_load_balance_loss(logits_peaked, eidx_peaked, E))
    assert l_p > l_u * 0.9
