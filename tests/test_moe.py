"""MoE dispatch correctness: vs dense reference, capacity, shared experts,
and per-expert numerics paths (``expert{k}.{wi,wg,wo}``)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.numerics import NumericsConfig
from repro.core.policy import NumericsPolicy, PolicyRule, expert_paths
from repro.models import moe as moe_mod

NCFG = NumericsConfig(mode="exact", compute_dtype="float32")
SEG1 = NumericsConfig(mode="segmented", seg_passes=1, backend="xla")
SEG3 = NumericsConfig(mode="segmented", seg_passes=3, backend="xla")


def _dense_reference(params, x, cfg):
    """Route every token to its top-k experts WITHOUT capacity limits."""
    B, S, D = x.shape
    xt = np.asarray(x, np.float64).reshape(-1, D)
    router = np.asarray(params["router"], np.float64)
    logits = xt @ router
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs = probs / probs.sum(-1, keepdims=True)
    K = cfg.moe.top_k
    wi = np.asarray(params["wi"], np.float64)
    wg = np.asarray(params["wg"], np.float64)
    wo = np.asarray(params["wo"], np.float64)
    out = np.zeros_like(xt)
    for t in range(xt.shape[0]):
        top = np.argsort(probs[t])[::-1][:K]
        gates = probs[t][top]
        gates = gates / gates.sum()
        for e, g in zip(top, gates):
            h = xt[t] @ wi[e]
            gg = xt[t] @ wg[e]
            act = h * (gg / (1 + np.exp(-gg)))
            out[t] += g * (act @ wo[e])
    return out.reshape(B, S, D)


def test_moe_matches_dense_reference(small_moe):
    cfg, params, x = small_moe(E=4, K=2, T=24, D=16, FF=32)
    got = np.asarray(moe_mod.moe_apply(params, x, cfg, NCFG))
    want = _dense_reference(params, x, cfg)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_moe_top1(small_moe):
    cfg, params, x = small_moe(E=4, K=1, T=24, D=16, FF=32)
    got = np.asarray(moe_mod.moe_apply(params, x, cfg, NCFG))
    want = _dense_reference(params, x, cfg)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_capacity_drops_reduce_output_mass(small_moe):
    """With a tiny capacity factor some tokens are dropped (their MoE output
    is zero) — output L2 must shrink vs generous capacity, never grow."""
    cfg_hi, params, x = small_moe(E=4, K=2, T=64, D=16, FF=32, cf=8.0)
    cfg_lo = dataclasses.replace(
        cfg_hi, moe=dataclasses.replace(cfg_hi.moe, capacity_factor=0.25))
    hi = np.asarray(moe_mod.moe_apply(params, x, cfg_hi, NCFG))
    lo = np.asarray(moe_mod.moe_apply(params, x, cfg_lo, NCFG))
    assert np.linalg.norm(lo) < np.linalg.norm(hi)
    assert not np.allclose(lo, hi)


def test_shared_expert_always_on(small_moe):
    cfg, params, x = small_moe(E=4, K=2, T=24, D=16, FF=32, n_shared=1)
    got = np.asarray(moe_mod.moe_apply(params, x, cfg, NCFG))
    # zeroing the router keeps the shared-expert contribution
    params0 = dict(params)
    params0["router"] = jnp.zeros_like(params["router"])
    got0 = np.asarray(moe_mod.moe_apply(params0, x, cfg, NCFG))
    from repro.models.layers import mlp_apply

    shared = np.asarray(mlp_apply(params["shared"], x.reshape(-1, x.shape[-1]), NCFG))
    assert np.abs(shared).sum() > 0
    # both outputs contain the shared path; routed parts differ
    assert not np.allclose(got, got0)


def test_gates_renormalized(small_moe):
    """top-k gates sum to 1 after renormalization: scaling router logits by a
    constant shift leaves the output invariant."""
    cfg, params, x = small_moe(E=4, K=2, T=24, D=16, FF=32)
    got1 = np.asarray(moe_mod.moe_apply(params, x, cfg, NCFG))
    params2 = dict(params)
    params2["router"] = params["router"] + 3.0  # softmax shift-invariant anyway
    got2 = np.asarray(moe_mod.moe_apply(params2, x, cfg, NCFG))
    np.testing.assert_allclose(got1, got2, rtol=1e-5, atol=1e-5)


def test_aux_loss_positive_and_uniform_minimum():
    T, E = 512, 8
    rng = np.random.default_rng(0)
    logits_uniform = jnp.zeros((T, E))
    eidx = jnp.asarray(rng.integers(0, E, (T, 1)))
    l_u = float(moe_mod.aux_load_balance_loss(logits_uniform, eidx, E))
    logits_peaked = jnp.asarray(np.eye(E)[rng.integers(0, 2, T)] * 10.0)
    eidx_peaked = jnp.argmax(logits_peaked, -1, keepdims=True)
    l_p = float(moe_mod.aux_load_balance_loss(logits_peaked, eidx_peaked, E))
    assert l_p > l_u * 0.9


# ---------------------------------------------------------------------------
# per-expert numerics paths
# ---------------------------------------------------------------------------

class _SpyPolicy(NumericsPolicy):
    """Records every resolved (path, config)."""

    def __post_init__(self):
        super().__post_init__()
        object.__setattr__(self, "seen", [])

    def lookup(self, path):
        cfg = super().lookup(path)
        self.seen.append((path, cfg))
        return cfg


def test_expert_paths_enumeration():
    assert expert_paths(2) == ("expert0.wi", "expert0.wg", "expert0.wo",
                               "expert1.wi", "expert1.wg", "expert1.wo")
    assert expert_paths(1, prefix="blocks.3.mlp") == (
        "blocks.3.mlp.expert0.wi", "blocks.3.mlp.expert0.wg",
        "blocks.3.mlp.expert0.wo")


def test_routed_expert_configs_resolution():
    pol = NumericsPolicy((PolicyRule("expert0.*", SEG1),), default=NCFG)
    cfgs = moe_mod.routed_expert_configs(pol, 2)
    assert cfgs["wi"] == (SEG1, NCFG) and cfgs["wo"] == (SEG1, NCFG)
    # plain configs resolve identically for every expert
    cfgs_plain = moe_mod.routed_expert_configs(SEG1, 3)
    assert cfgs_plain["wg"] == (SEG1, SEG1, SEG1)


def test_per_expert_policy_resolves_distinct_configs(small_moe):
    """Acceptance: a mixed MoE forward resolves >= 2 distinct
    NumericsConfigs across experts, and the output differs from all-exact."""
    cfg, params, x = small_moe(E=2, K=1, T=16, D=16, FF=32)
    pol = _SpyPolicy((PolicyRule("expert0.*", SEG1),
                      PolicyRule("expert1.*", NCFG)), default=NCFG)
    got = np.asarray(moe_mod.moe_apply(params, x, cfg, pol))
    used = {c for p, c in pol.seen if p.startswith("expert")}
    assert SEG1 in used and NCFG in used, used
    exact = np.asarray(moe_mod.moe_apply(params, x, cfg, NCFG))
    assert np.isfinite(got).all()
    assert not np.allclose(got, exact)
    # expert1 tokens are untouched (exact config == the fused-einsum math
    # up to dot-strategy ulps); expert0 tokens carry the segmented error
    assert np.abs(got - exact).max() > 1e-4


def test_all_exact_expert_policy_bit_identical_to_plain(small_moe):
    """Acceptance: a policy mapping every expert to ``exact`` keeps the
    fused einsum datapath — bit-for-bit the plain-config output."""
    cfg, params, x = small_moe(E=2, K=1, T=16, D=16, FF=32, n_shared=1)
    pol = NumericsPolicy((PolicyRule("expert*", NCFG),
                          PolicyRule("shared.*", NCFG)), default=NCFG)
    got = np.asarray(moe_mod.moe_apply(params, x, cfg, pol))
    want = np.asarray(moe_mod.moe_apply(params, x, cfg, NCFG))
    np.testing.assert_array_equal(got, want)


def test_uniform_segmented_policy_matches_plain_segmented(small_moe):
    """A policy resolving every expert to SEG3 == the plain SEG3 config
    (both take the per-expert nmatmul path with identical configs)."""
    cfg, params, x = small_moe(E=2, K=1, T=16, D=16, FF=32)
    pol = NumericsPolicy((), default=SEG3)
    got = np.asarray(moe_mod.moe_apply(params, x, cfg, pol))
    want = np.asarray(moe_mod.moe_apply(params, x, cfg, SEG3))
    np.testing.assert_array_equal(got, want)


def test_per_expert_segmented_still_tracks_dense_reference(small_moe):
    """Segmented-3 experts stay close to the float64 dense reference —
    the approximate path must not silently break routing/combination."""
    cfg, params, x = small_moe(E=4, K=2, T=24, D=16, FF=32)
    got = np.asarray(moe_mod.moe_apply(params, x, cfg, SEG3))
    want = _dense_reference(params, x, cfg)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)
