"""shard_map EP MoE vs the GSPMD reference (needs a multi-device host).

Run with XLA_FLAGS=--xla_force_host_platform_device_count=8 to exercise;
on a single-device host the mesh can't be built and the tests skip.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.numerics import NumericsConfig
from repro.core.policy import NumericsPolicy, PolicyRule
from repro.distributed.sharding import rules_for, use_mesh_rules
from repro.models import moe as moe_mod

NCFG = NumericsConfig(mode="exact", compute_dtype="float32")
SEG3 = NumericsConfig(mode="segmented", seg_passes=3, backend="xla")


def _setup(small_moe):
    if len(jax.devices()) < 8:
        pytest.skip("needs 8 host devices (XLA_FLAGS device count)")
    from repro.launch.mesh import make_test_mesh

    cfg, params, x = small_moe(E=8, K=2, T=64, D=16, FF=32, cf=8.0, B=4,
                               seed=0)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model),
                          jnp.float32)
    return cfg, params, x, make_test_mesh((2, 4), ("data", "model"))


def test_shardmap_matches_gspmd_forward(small_moe):
    cfg, params, x, mesh = _setup(small_moe)
    ref = np.asarray(moe_mod.moe_apply(params, x, cfg, NCFG))
    with use_mesh_rules(mesh, rules_for(cfg, "train")):
        got = np.asarray(jax.jit(
            lambda p, xx: moe_mod.moe_apply(p, xx, cfg, NCFG))(params, x))
    np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4)


def test_shardmap_gradients_finite_and_match(small_moe):
    cfg, params, x, mesh = _setup(small_moe)

    def loss(p, xx):
        return jnp.sum(moe_mod.moe_apply(p, xx, cfg, NCFG) ** 2)

    g_ref = jax.grad(loss)(params, x)
    with use_mesh_rules(mesh, rules_for(cfg, "train")):
        g = jax.jit(jax.grad(loss))(params, x)
    for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(g_ref)):
        assert np.isfinite(np.asarray(a)).all()
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=5e-3)


def test_shardmap_uniform_segmented_matches_gspmd(small_moe):
    """Expert-uniform non-exact configs run per-local-expert nmatmul inside
    the shard_map body and must agree with the (unsharded) GSPMD path."""
    cfg, params, x, mesh = _setup(small_moe)
    ref = np.asarray(moe_mod.moe_apply(params, x, cfg, SEG3))
    with use_mesh_rules(mesh, rules_for(cfg, "train")):
        got = np.asarray(jax.jit(
            lambda p, xx: moe_mod.moe_apply(p, xx, cfg, SEG3))(params, x))
    np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4)


def test_shardmap_heterogeneous_policy_falls_back_to_gspmd(small_moe):
    """Expert-heterogeneous numerics cannot trace once across EP shards;
    the shard_map entry must fall back to the GSPMD path and still match
    the unsharded result."""
    cfg, params, x, mesh = _setup(small_moe)
    pol = NumericsPolicy((PolicyRule("expert0.*", SEG3),), default=NCFG)
    ref = np.asarray(moe_mod.moe_apply(params, x, cfg, pol))
    with use_mesh_rules(mesh, rules_for(cfg, "train")):
        got = np.asarray(jax.jit(
            lambda p, xx: moe_mod.moe_apply(p, xx, cfg, pol))(params, x))
    np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4)
