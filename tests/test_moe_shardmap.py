"""shard_map EP MoE vs the GSPMD reference (needs a multi-device host).

Run with XLA_FLAGS=--xla_force_host_platform_device_count=8 to exercise;
on a single-device host the mesh can't be built and the tests skip.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.numerics import NumericsConfig
from repro.distributed.sharding import rules_for, use_mesh_rules
from repro.models import moe as moe_mod
from repro.models.layers import unzip

NCFG = NumericsConfig(mode="exact", compute_dtype="float32")


def _setup():
    if len(jax.devices()) < 8:
        pytest.skip("needs 8 host devices (XLA_FLAGS device count)")
    from repro.launch.mesh import make_test_mesh

    cfg0 = get_arch("deepseek-v3-671b").reduced()
    cfg = dataclasses.replace(
        cfg0, moe=dataclasses.replace(cfg0.moe, n_experts=8, top_k=2,
                                      capacity_factor=8.0))
    pp = moe_mod.moe_init(jax.random.PRNGKey(0), cfg)
    params, _ = unzip(pp)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model), jnp.float32)
    return cfg, params, x, make_test_mesh((2, 4), ("data", "model"))


def test_shardmap_matches_gspmd_forward():
    cfg, params, x, mesh = _setup()
    ref = np.asarray(moe_mod.moe_apply(params, x, cfg, NCFG))
    with use_mesh_rules(mesh, rules_for(cfg, "train")):
        got = np.asarray(jax.jit(
            lambda p, xx: moe_mod.moe_apply(p, xx, cfg, NCFG))(params, x))
    np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4)


def test_shardmap_gradients_finite_and_match():
    cfg, params, x, mesh = _setup()

    def loss(p, xx):
        return jnp.sum(moe_mod.moe_apply(p, xx, cfg, NCFG) ** 2)

    g_ref = jax.grad(loss)(params, x)
    with use_mesh_rules(mesh, rules_for(cfg, "train")):
        g = jax.jit(jax.grad(loss))(params, x)
    for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(g_ref)):
        assert np.isfinite(np.asarray(a)).all()
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=5e-3)
