"""Hierarchical cross-pod gradient reduction (+int8 DCN compression)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.multipod import hierarchical_grad_reduce
from repro.optim.compression import init_error_feedback


@pytest.fixture
def mesh():
    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 host devices")
    from repro.launch.mesh import make_test_mesh

    return make_test_mesh((2, 1), ("pod", "data"))


def test_plain_reduce_is_mean(mesh):
    g = {"w": jnp.ones((4,)) * jnp.arange(1, 5)}
    out, _ = hierarchical_grad_reduce(mesh, g, compress=False)
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.asarray(g["w"]), rtol=1e-6)


def test_compressed_reduce_close_and_has_feedback(mesh):
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.standard_normal(512), jnp.float32) * 0.01}
    errs = init_error_feedback(g)
    out, new_errs = hierarchical_grad_reduce(mesh, g, errs, compress=True)
    # int8 blockwise: relative error bounded by ~1/127 per block max
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]),
                               atol=float(jnp.abs(g["w"]).max()) / 100)
    # error feedback captured the residual
    resid = np.asarray(g["w"] - out["w"])
    np.testing.assert_allclose(np.asarray(new_errs["w"]), resid, atol=1e-6)
