"""The numerics dispatch layer (compiler integration) + segmented matmul."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.numerics import (EXACT, NumericsConfig, nmatmul,
                                 segmented_matmul_xla)


RNG = np.random.default_rng(0)
X = jnp.asarray(RNG.standard_normal((16, 96)), jnp.float32)
W = jnp.asarray(RNG.standard_normal((96, 24)), jnp.float32)
REF = np.asarray(X, np.float64) @ np.asarray(W, np.float64)


def test_exact_mode():
    got = np.asarray(nmatmul(X, W, NumericsConfig(mode="exact",
                                                  compute_dtype="float32")))
    np.testing.assert_allclose(got, REF, rtol=1e-5, atol=1e-5)


def test_exact_bf16_compute_dtype():
    got = np.asarray(nmatmul(X, W, EXACT))  # bf16 compute, fp32 accum
    rel = np.abs(got - REF).mean() / np.abs(REF).mean()
    assert 1e-5 < rel < 2e-2  # bf16-level error


@pytest.mark.parametrize("passes,bound", [(1, 0.03), (2, 0.004), (3, 0.002)])
def test_segmented_accuracy_ladder(passes, bound):
    got = np.asarray(segmented_matmul_xla(X, W, passes))
    rel = np.abs(got - REF).mean() / np.abs(REF).mean()
    assert rel < bound, (passes, rel)
    if passes > 1:
        worse = np.asarray(segmented_matmul_xla(X, W, passes - 1))
        assert np.abs(got - REF).mean() < np.abs(worse - REF).mean()


def test_segmented_equals_paper_term_structure():
    """3-pass = AC + AD + BC with BD omitted: reconstruct by hand."""
    xh = X.astype(jnp.bfloat16).astype(jnp.float32)
    xl = (X - xh).astype(jnp.bfloat16).astype(jnp.float32)
    wh = W.astype(jnp.bfloat16).astype(jnp.float32)
    wl = (W - wh).astype(jnp.bfloat16).astype(jnp.float32)
    manual = xh @ wh + xl @ wh + xh @ wl
    got = np.asarray(segmented_matmul_xla(X, W, 3))
    np.testing.assert_allclose(got, np.asarray(manual), rtol=2e-3, atol=2e-3)


def test_emulated_mode_matches_registry():
    cfg = NumericsConfig(mode="emulated", multiplier="AC5-5", seg_n=5)
    got = np.asarray(nmatmul(X, W, cfg))
    rel = np.abs(got - REF).mean() / np.abs(REF).mean()
    assert rel < 3e-3
    # generic registry multiplier path (CSS16)
    cfg2 = NumericsConfig(mode="emulated", multiplier="CSS16")
    got2 = np.asarray(nmatmul(X, W, cfg2))
    rel2 = np.abs(got2 - REF).mean() / np.abs(REF).mean()
    assert rel2 < 5e-3
    assert not np.allclose(got, got2)


def test_segmented_pallas_wrapper_roundtrip():
    from repro.kernels import ops

    got = np.asarray(ops.afpm_matmul(X, W, 3, force="xla"))
    want = np.asarray(segmented_matmul_xla(X, W, 3))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_unknown_mode_raises():
    with pytest.raises(ValueError):
        nmatmul(X, W, NumericsConfig(mode="nope"))
