"""Per-layer numerics policies: rules/globs/precedence, JSON round-trip,
mixed-policy model forwards (scan + unroll paths), and the budget-driven
auto-configurer."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import sweep
from repro.core.metrics import mred
from repro.core.numerics import EXACT, NumericsConfig, nmatmul
from repro.core.policy import (NumericsPolicy, PolicyRule, is_policy, resolve,
                               scoped)
from repro.models import resnet, transformer
from repro.models.layers import unzip

SEG1 = NumericsConfig(mode="segmented", seg_passes=1, backend="xla")
SEG3 = NumericsConfig(mode="segmented", seg_passes=3, backend="xla")
EXACT_F32 = NumericsConfig(mode="exact", compute_dtype="float32")


# ---------------------------------------------------------------------------
# rule matching / precedence / scoping
# ---------------------------------------------------------------------------

def test_glob_matching_and_default():
    pol = NumericsPolicy((PolicyRule("blocks.*.attn.*", SEG1),), default=EXACT_F32)
    assert pol.lookup("blocks.3.attn.wq") == SEG1
    assert pol.lookup("blocks.11.attn.wo") == SEG1
    assert pol.lookup("blocks.3.mlp.wi") == EXACT_F32      # default
    assert pol.lookup("lm_head") == EXACT_F32


def test_first_matching_rule_wins():
    pol = NumericsPolicy((
        PolicyRule("blocks.0.attn.wq", SEG3),   # specific first
        PolicyRule("blocks.*", SEG1),           # broad later
    ))
    assert pol.lookup("blocks.0.attn.wq") == SEG3
    assert pol.lookup("blocks.0.attn.wk") == SEG1
    # reversed order: the broad rule shadows the specific one
    rev = NumericsPolicy((PolicyRule("blocks.*", SEG1),
                          PolicyRule("blocks.0.attn.wq", SEG3)))
    assert rev.lookup("blocks.0.attn.wq") == SEG1


def test_star_crosses_dots():
    pol = NumericsPolicy((PolicyRule("blocks.*.wo", SEG1),))
    assert pol.lookup("blocks.7.attn.wo") == SEG1


def test_rules_accept_bare_pairs():
    pol = NumericsPolicy((("mlp.*", SEG1),))
    assert pol.rules[0] == PolicyRule("mlp.*", SEG1)


def test_scoping_prefixes_lookups():
    pol = NumericsPolicy((PolicyRule("blocks.2.mlp.wi", SEG1),), default=EXACT_F32)
    view = pol.scope("blocks.2").scope("mlp")
    assert view.lookup("wi") == SEG1
    assert view.lookup("wo") == EXACT_F32
    assert is_policy(view) and is_policy(pol) and not is_policy(SEG1)


def test_resolve_and_scoped_helpers_passthrough():
    # plain configs flow through untouched (pre-policy call sites unchanged)
    assert resolve(SEG1, "anything") == SEG1
    assert resolve(None) == EXACT
    assert scoped(SEG1, "blocks.0") is SEG1
    pol = NumericsPolicy((PolicyRule("a.b", SEG1),))
    assert resolve(scoped(pol, "a"), "b") == SEG1


def test_nmatmul_resolves_policy_per_path():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((8, 32)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((32, 8)), jnp.float32)
    pol = NumericsPolicy((PolicyRule("approx", SEG1),), default=EXACT_F32)
    exact = np.asarray(nmatmul(x, w, pol, path="other"))
    approx = np.asarray(nmatmul(x, w, pol, path="approx"))
    np.testing.assert_allclose(exact, np.asarray(x) @ np.asarray(w), rtol=1e-5)
    assert not np.allclose(exact, approx)
    np.testing.assert_array_equal(approx, np.asarray(nmatmul(x, w, SEG1)))


# ---------------------------------------------------------------------------
# JSON serialization
# ---------------------------------------------------------------------------

def test_policy_json_round_trip():
    pol = NumericsPolicy((
        PolicyRule("blocks.*.attn.*", NumericsConfig(mode="exact")),
        PolicyRule("blocks.*.mlp.*", SEG1),
        PolicyRule("fc", NumericsConfig(mode="emulated", multiplier="AC4-4",
                                        seg_n=4)),
    ), default=EXACT_F32)
    text = pol.to_json()
    assert NumericsPolicy.from_json(text) == pol
    # the wire format is plain JSON with the documented shape
    d = json.loads(text)
    assert set(d) == {"default", "rules"}
    assert d["rules"][1]["pattern"] == "blocks.*.mlp.*"
    assert d["rules"][1]["config"]["seg_passes"] == 1


def test_policy_json_partial_configs_take_defaults():
    pol = NumericsPolicy.from_json(
        '{"rules": [{"pattern": "x", "config": {"mode": "segmented"}}]}')
    assert pol.lookup("x") == NumericsConfig(mode="segmented")
    assert pol.default == EXACT


def test_policy_json_rejects_unknown_fields():
    with pytest.raises(ValueError, match="unknown NumericsConfig fields"):
        NumericsPolicy.from_json(
            '{"rules": [{"pattern": "x", "config": {"use_pallas": true}}]}')
    with pytest.raises(ValueError, match="unknown backend"):
        NumericsPolicy.from_json('{"default": {"backend": "cuda"}}')


# ---------------------------------------------------------------------------
# transformer forwards under policies
# ---------------------------------------------------------------------------

class _SpyPolicy(NumericsPolicy):
    """Records every resolved (path, config) — proves distinct numerics
    actually run inside one forward pass."""

    def __post_init__(self):
        super().__post_init__()
        object.__setattr__(self, "seen", [])

    def lookup(self, path):
        cfg = super().lookup(path)
        self.seen.append((path, cfg))
        return cfg


def _lm_setup(arch="qwen3-4b", B=2, S=16, seed=0):
    cfg = get_arch(arch).reduced()
    pp = transformer.init(cfg, jax.random.PRNGKey(seed))
    params, _ = unzip(pp)
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab, (B, S + 1))
    batch = {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
             "targets": jnp.asarray(toks[:, 1:], jnp.int32)}
    return cfg, params, batch


def test_uniform_policy_matches_global_config():
    """A policy resolving every site to one config == that global config."""
    cfg, params, batch = _lm_setup()
    h_global, _, _ = transformer.backbone(
        params, dataclasses.replace(cfg, numerics=SEG1), batch, mode="train")
    pol = NumericsPolicy((PolicyRule("blocks.*", SEG1),
                          PolicyRule("lm_head", SEG1)), default=SEG1)
    h_policy, _, _ = transformer.backbone(
        params, dataclasses.replace(cfg, numerics=pol), batch, mode="train")
    np.testing.assert_array_equal(np.asarray(h_global), np.asarray(h_policy))


def test_mixed_policy_runs_two_numerics_in_one_forward():
    """Acceptance: >= 2 distinct configs demonstrably run in ONE pass."""
    cfg, params, batch = _lm_setup()
    pol = _SpyPolicy((PolicyRule("blocks.*.attn.*", EXACT_F32),
                      PolicyRule("blocks.*.mlp.*", SEG1)), default=EXACT_F32)
    cfg_p = dataclasses.replace(cfg, numerics=pol)
    h_mixed, _, _ = transformer.backbone(params, cfg_p, batch, mode="train")
    used = {c for _, c in pol.seen}
    assert SEG1 in used and EXACT_F32 in used, used
    attn_sites = {p for p, c in pol.seen if ".attn." in p}
    assert all(c == EXACT_F32 for p, c in pol.seen if ".attn." in p)
    assert all(c == SEG1 for p, c in pol.seen if ".mlp." in p)
    assert attn_sites, "no attention sites resolved"
    # and the mixture is numerically distinct from either endpoint
    h_ex, _, _ = transformer.backbone(
        params, dataclasses.replace(cfg, numerics=EXACT_F32), batch, mode="train")
    h_sg, _, _ = transformer.backbone(
        params, dataclasses.replace(cfg, numerics=SEG1), batch, mode="train")
    assert not np.allclose(np.asarray(h_mixed), np.asarray(h_ex))
    assert not np.allclose(np.asarray(h_mixed), np.asarray(h_sg))


def test_segment_scannable_probe():
    cfg, _, _ = _lm_setup()
    (repeats, pattern), = cfg.segments
    assert repeats >= 2, "needs a scanned segment"
    role = NumericsPolicy((PolicyRule("blocks.*.mlp.*", SEG1),))
    assert transformer._segment_scannable(role, cfg, pattern, 0, repeats)
    hetero = NumericsPolicy((PolicyRule("blocks.0.*", SEG1),))
    assert not transformer._segment_scannable(hetero, cfg, pattern, 0, repeats)
    # per-index rules that resolve identically stay scannable
    same = NumericsPolicy((PolicyRule("blocks.0.*", SEG1),
                           PolicyRule("blocks.1.*", SEG1)), default=SEG1)
    assert transformer._segment_scannable(same, cfg, pattern, 0, repeats)


def test_heterogeneous_segment_unrolls_and_matches_manual_reference():
    """blocks.0 on segmented-1, blocks.1 exact — the scanned segment must
    unroll, and equal running the two blocks by hand with those configs."""
    cfg, params, batch = _lm_setup()
    (repeats, pattern), = cfg.segments
    spec = pattern[0]
    pol = NumericsPolicy((PolicyRule("blocks.0.*", SEG1),), default=EXACT_F32)

    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((2, 8, cfg.d_model)), jnp.float32)
    positions = jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32)[None], (2, 8))
    out_policy, _ = transformer.stack_apply(params, x, cfg, pol, positions,
                                            mode="train")
    # manual: apply each repeat's params with its resolved plain config
    ref = x
    for r, ncfg in enumerate([SEG1] + [EXACT_F32] * (repeats - 1)):
        layer = jax.tree.map(lambda a: a[r], params["seg0_p0"])
        ref, _ = transformer.block_apply(layer, ref, cfg, spec, positions,
                                         ncfg, mode="train")
    np.testing.assert_allclose(np.asarray(out_policy), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


def test_policy_prefill_decode_consistency():
    """Decode under a heterogeneous policy matches prefill's next-token
    logits (the unrolled cache layout matches the scanned one)."""
    cfg, params, batch = _lm_setup(S=12)
    pol = NumericsPolicy((PolicyRule("blocks.0.*", SEG1),), default=EXACT_F32)
    cfg_p = dataclasses.replace(cfg, numerics=pol)
    toks = batch["tokens"]
    logits_full, _, _ = transformer.backbone(params, cfg_p, {"tokens": toks},
                                             mode="train")
    logits_full = transformer.logits_fn(params, cfg_p, logits_full)
    lg_prefill, state = transformer.prefill(params, cfg_p,
                                            {"tokens": toks[:, :-1]},
                                            max_len=toks.shape[1] + 1)
    lg_decode, _ = transformer.decode_step(params, cfg_p,
                                           {"token": toks[:, -1:]},
                                           state, toks.shape[1] - 1)
    np.testing.assert_allclose(np.asarray(lg_decode[:, 0]),
                               np.asarray(logits_full[:, -1]),
                               rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# resnet + auto-configuration
# ---------------------------------------------------------------------------

def _tiny_resnet(seed=0):
    cfg = resnet.ResNetConfig(widths=(8, 16), blocks=(1, 1))
    pp, state = resnet.init(cfg, jax.random.PRNGKey(seed))
    params, _ = unzip(pp)
    rng = np.random.default_rng(seed)
    images = jnp.asarray(rng.standard_normal((4, 8, 8, 3)), jnp.float32)
    return cfg, params, state, images


def test_resnet_layer_paths_cover_all_convs():
    cfg = resnet.ResNetConfig(widths=(8, 16), blocks=(1, 1))
    assert resnet.layer_paths(cfg) == [
        "stem", "s0b0.conv1", "s0b0.conv2",
        "s1b0.conv1", "s1b0.conv2", "s1b0.proj", "fc"]


def test_resnet_mixed_policy_forward():
    cfg, params, state, images = _tiny_resnet()
    ref, _ = resnet.apply(params, state, images, cfg, train=False)
    pol = NumericsPolicy((PolicyRule("s1b0.*", SEG1),), default=EXACT_F32)
    got, _ = resnet.apply(params, state, images,
                          dataclasses.replace(cfg, numerics=pol), train=False)
    assert np.isfinite(np.asarray(got)).all()
    assert not np.allclose(np.asarray(ref), np.asarray(got))


def test_auto_configure_meets_budget_below_exact_area():
    """Acceptance: the emitted policy meets the MRED budget at lower
    modeled area than the all-exact baseline, and round-trips via JSON.
    Pinned to the measured-error greedy method (the proxy's composed-model
    semantics are covered by tests/test_sensitivity.py)."""
    cfg, params, state, images = _tiny_resnet()
    ref, _ = resnet.apply(params, state, images, cfg, train=False)
    ref = np.asarray(ref, np.float64)

    def eval_fn(policy):
        acfg = dataclasses.replace(cfg, numerics=policy)
        logits, _ = resnet.apply(params, state, images, acfg, train=False)
        return mred(np.asarray(logits), ref)

    budget = 5e-3
    res = sweep.auto_configure(eval_fn, resnet.layer_paths(cfg), budget,
                               candidates=[("segmented-1", SEG1),
                                           ("segmented-3", SEG3)],
                               method="greedy")
    assert res.method == "greedy" and res.predicted_error is None
    assert res.error <= budget
    assert res.area_um2 < res.baseline_area_um2
    assert res.assignments  # at least one layer went approximate
    # the reported error is reproducible from the serialized policy
    reloaded = NumericsPolicy.from_json(res.policy.to_json())
    assert reloaded == res.policy
    assert eval_fn(reloaded) == pytest.approx(res.error)


def test_auto_configure_area_model_orders_designs():
    # ACL-like (1 pass) < AC-like (3 passes) < exact, as in paper Table II
    a1 = sweep.config_ppa(SEG1).logic_area_um2
    a3 = sweep.config_ppa(SEG3).logic_area_um2
    ax = sweep.config_ppa(EXACT_F32).logic_area_um2
    assert a1 < a3 < ax
    # emulated designs use their Table II spec
    ac55 = sweep.config_ppa(NumericsConfig(mode="emulated", multiplier="AC5-5"))
    assert ac55.logic_area_um2 == pytest.approx(2156.0, rel=1e-6)


def test_pareto_candidates_are_on_frontier():
    cands = sweep.pareto_candidates(n_samples=10_000)
    names = {n for n, _ in cands}
    pareto = {p.name for p in sweep.sweep(n_samples=10_000) if p.pareto}
    assert names == pareto
    for _, c in cands:
        assert c.mode == "emulated"


# ---------------------------------------------------------------------------
# golden vectors: per-expert path resolution pinned against the independent
# reference resolver (tests/golden/gen_policy_golden.py)
# ---------------------------------------------------------------------------

def _policy_golden():
    import json
    import os

    path = os.path.join(os.path.dirname(__file__), "golden",
                        "policy_golden.json")
    with open(path) as f:
        return json.load(f)


@pytest.mark.parametrize("case", _policy_golden()["resolution_cases"],
                         ids=lambda c: c["label"])
def test_expert_path_resolution_golden(case):
    tags = _policy_golden()["config_tags"]
    cfg_of = {tag: NumericsConfig(**d) for tag, d in tags.items()}
    tag_of = {v: k for k, v in cfg_of.items()}
    pol = NumericsPolicy(
        tuple(PolicyRule(pat, cfg_of[tag]) for pat, tag in case["rules"]),
        default=cfg_of[case["default"]])
    for path, want_tag in case["expected"].items():
        got = pol.lookup(path)
        assert tag_of[got] == want_tag, (path, tag_of[got], want_tag)


def test_resolution_golden_covers_expert_multiplicity():
    """The golden file must exercise >= 2 experts and >= 2 distinct
    non-default tags across its cases (guards fixture rot)."""
    data = _policy_golden()
    experts = set()
    tags = set()
    for case in data["resolution_cases"]:
        for path, tag in case["expected"].items():
            if ".expert" in path:
                experts.add(path.split(".expert")[1].split(".")[0])
            tags.add(tag)
    assert len(experts) >= 2 and len(tags - {"exact"}) >= 2
