"""ResNet-18: conv/bn correctness, im2col-emulated conv vs exact, training."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.numerics import NumericsConfig
from repro.models import resnet
from repro.models.layers import unzip


def _tiny_cfg(mult="AC6-6"):
    return resnet.ResNetConfig(widths=(8, 16, 24, 32))


def test_forward_shapes_and_finite():
    cfg = _tiny_cfg()
    pp, state = resnet.init(cfg, jax.random.PRNGKey(0))
    params, _ = unzip(pp)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((4, 32, 32, 3)),
                    jnp.float32)
    logits, new_state = resnet.apply(params, state, x, cfg, train=True)
    assert logits.shape == (4, 10)
    assert np.isfinite(np.asarray(logits)).all()
    # bn state updated in train mode
    assert not np.allclose(np.asarray(new_state["bn_stem"]["mean"]),
                           np.asarray(state["bn_stem"]["mean"]))


def test_im2col_conv_matches_exact_conv():
    """The emulated-numerics conv path (im2col + AC6-6, near-exact) must
    agree with lax.conv to within the multiplier's error."""
    cfg = _tiny_cfg()
    pp, state = resnet.init(cfg, jax.random.PRNGKey(1))
    params, _ = unzip(pp)
    x = jnp.asarray(np.random.default_rng(1).standard_normal((2, 32, 32, 3)),
                    jnp.float32)
    w = params["stem"]
    exact = resnet.conv2d(x, w, 1, None)
    ncfg = NumericsConfig(mode="emulated", multiplier="AC6-6", seg_n=6)
    approx = resnet.conv2d(x, w, 1, ncfg)
    rel = np.abs(np.asarray(approx - exact)).mean() / np.abs(np.asarray(exact)).mean()
    assert rel < 2e-3, rel
    # strided conv too
    w2 = params["s1b0"]["conv1"]
    h = jax.nn.relu(exact)
    e2 = resnet.conv2d(h, w2, 2, None)
    a2 = resnet.conv2d(h, w2, 2, ncfg)
    rel2 = np.abs(np.asarray(a2 - e2)).mean() / (np.abs(np.asarray(e2)).mean() + 1e-9)
    assert rel2 < 2e-3, rel2
    assert e2.shape == a2.shape


def test_resnet_trains_on_synthetic_cifar():
    from benchmarks.table4_resnet import train_resnet

    cfg, params, state = train_resnet(steps=40, batch=32, width_mult=0.25)
    from repro.core.metrics import top_k_accuracy
    from repro.data.synthetic import DataConfig, cifar_like

    b = cifar_like(DataConfig(global_batch=64, seed=5), 999)
    logits, _ = resnet.apply(params, state, jnp.asarray(b["images"]), cfg,
                             train=False)
    acc = top_k_accuracy(logits, jnp.asarray(b["labels"]), 1)
    assert float(acc) > 0.25, acc  # well above 10% chance after 40 steps


def test_numerics_knob_perturbs_resnet_slightly():
    cfg = _tiny_cfg()
    pp, state = resnet.init(cfg, jax.random.PRNGKey(2))
    params, _ = unzip(pp)
    x = jnp.asarray(np.random.default_rng(2).standard_normal((2, 32, 32, 3)),
                    jnp.float32)
    exact, _ = resnet.apply(params, state, x, cfg, train=False)
    acfg = dataclasses.replace(
        cfg, numerics=NumericsConfig(mode="emulated", multiplier="AC5-5", seg_n=5))
    approx, _ = resnet.apply(params, state, x, acfg, train=False)
    d = np.abs(np.asarray(exact - approx))
    assert 0 < d.mean() < 0.1 * np.abs(np.asarray(exact)).mean() + 0.05
