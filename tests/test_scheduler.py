"""Continuous-batching scheduler: slot management, cohorts, completion."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.launch.scheduler import ContinuousBatcher, Request
from repro.models import transformer
from repro.models.layers import unzip


def _make_fns(cfg, max_len):
    params, _ = unzip(transformer.init(cfg, jax.random.PRNGKey(0)))
    prefill = jax.jit(lambda p, b: transformer.prefill(p, cfg, b, max_len=max_len))
    decode = jax.jit(lambda p, tok, st, pos: transformer.decode_step(
        p, cfg, {"token": tok}, st, pos))
    return (lambda toks: prefill(params, {"tokens": jnp.asarray(toks, jnp.int32)}),
            lambda tok, st, pos: decode(params, tok, st, pos))


def test_scheduler_completes_all_requests():
    cfg = get_arch("qwen3-4b").reduced()
    max_len = 64
    prefill_fn, decode_fn = _make_fns(cfg, max_len)
    b = ContinuousBatcher(n_slots=2, prefill_fn=prefill_fn,
                          decode_fn=decode_fn, max_len=max_len)
    rng = np.random.default_rng(0)
    for uid in range(5):  # more requests than slots -> queuing happens
        b.submit(Request(uid=uid, prompt=rng.integers(0, cfg.vocab, 12),
                         max_new_tokens=4))
    done, ticks = b.run_to_completion()
    assert len(done) == 5
    assert all(len(r.generated) == 4 for r in done)
    assert ticks < 40
    assert b.utilization == 0.0  # drained


def test_scheduler_matches_unbatched_decode():
    """Tokens produced via the scheduler == tokens from a manual loop."""
    cfg = get_arch("qwen3-4b").reduced()
    max_len = 48
    prefill_fn, decode_fn = _make_fns(cfg, max_len)
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab, 10)

    b = ContinuousBatcher(1, prefill_fn, decode_fn, max_len)
    b.submit(Request(uid=0, prompt=prompt, max_new_tokens=5))
    done, _ = b.run_to_completion()
    got = done[0].generated

    # manual greedy loop
    logits, state = prefill_fn(prompt[None, :])
    want = [int(np.argmax(np.asarray(logits)[0, -1]))]
    pos = len(prompt)
    for _ in range(4):
        lg, state = decode_fn(jnp.asarray([[want[-1]]], jnp.int32), state,
                              jnp.int32(pos))
        want.append(int(np.argmax(np.asarray(lg)[0, -1])))
        pos += 1
    assert got == want


def test_scheduler_eos_early_stop():
    cfg = get_arch("qwen3-4b").reduced()
    prefill_fn, decode_fn = _make_fns(cfg, 48)
    b = ContinuousBatcher(1, prefill_fn, decode_fn, 48)
    # find what the model greedily emits first, then use it as "eos"
    prompt = np.arange(8) % cfg.vocab
    logits, _ = prefill_fn(prompt[None, :])
    first = int(np.argmax(np.asarray(logits)[0, -1]))
    b.submit(Request(uid=0, prompt=prompt, max_new_tokens=10, eos_id=first))
    done, _ = b.run_to_completion()
    assert done[0].generated[0] == first
    assert len(done[0].generated) == 1  # stopped at eos immediately
