"""Context-scoped numerics API: scope/path resolution, equivalence with the
deprecated kwarg form under jit/scan/vmap, the once-per-site deprecation
warning, and the model-zoo full-path regression (every call site resolves
a non-empty full path)."""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import numerics as rn
from repro.configs import get_arch
from repro.core import sensitivity
from repro.models import resnet, transformer
from repro.models.layers import unzip

SEG1 = rn.NumericsConfig(mode="segmented", seg_passes=1, backend="xla")
SEG3 = rn.NumericsConfig(mode="segmented", seg_passes=3, backend="xla")
EXACT_F32 = rn.NumericsConfig(mode="exact", compute_dtype="float32")


def _xw(rng, m=8, k=32, n=8):
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    return x, w


def _kwarg_nmatmul(x, w, cfg, path):
    """The deprecated explicit form, with its warning silenced."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return rn.nmatmul(x, w, cfg, path=path)


# ---------------------------------------------------------------------------
# scope stack semantics
# ---------------------------------------------------------------------------

def test_current_path_and_numerics_nesting():
    assert rn.current_numerics() is None and rn.current_path() == ""
    with rn.numerics_scope(SEG1):
        assert rn.current_numerics() == SEG1
        with rn.numerics_scope(SEG3):       # innermost wins
            assert rn.current_numerics() == SEG3
        assert rn.current_numerics() == SEG1
        with rn.layer_scope("blocks.3"), rn.layer_scope("mlp"):
            assert rn.current_path() == "blocks.3.mlp"
            assert rn.current_path("wi") == "blocks.3.mlp.wi"
    assert rn.current_numerics() is None and rn.current_path() == ""


def test_scopes_unwind_on_exception():
    with pytest.raises(RuntimeError):
        with rn.numerics_scope(SEG1), rn.layer_scope("a"):
            raise RuntimeError("boom")
    assert rn.current_numerics() is None and rn.current_path() == ""


def test_resolve_here_and_ambient_view():
    pol = rn.NumericsPolicy(((("blocks.*.mlp.*"), SEG1),), default=EXACT_F32)
    assert rn.resolve_here() == rn.EXACT          # no ambient scope
    assert rn.ambient_view() is None
    with rn.numerics_scope(pol), rn.layer_scope("blocks.0"), \
            rn.layer_scope("mlp"):
        assert rn.resolve_here("wi") == SEG1
        assert rn.resolve_here() == EXACT_F32     # no-leaf path: default
        view = rn.ambient_view()
        assert view.lookup("wi") == SEG1          # relative lookups work
        assert view.full_path("wi") == "blocks.0.mlp.wi"


def test_scope_resolution_matches_kwarg_api_bitwise(rng):
    x, w = _xw(rng)
    pol = rn.NumericsPolicy((("blocks.*.mlp.*", SEG1),), default=EXACT_F32)
    ref = _kwarg_nmatmul(x, w, pol, "blocks.3.mlp.wi")
    with rn.numerics_scope(pol), rn.layer_scope("blocks.3"), \
            rn.layer_scope("mlp"), rn.layer_scope("wi"):
        got = rn.nmatmul(x, w)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))
    # scoped-policy shim view == nested layer_scope
    ref2 = _kwarg_nmatmul(x, w, pol.scope("blocks.3").scope("mlp"), "wo")
    with rn.numerics_scope(pol), rn.layer_scope("blocks.3.mlp.wo"):
        got2 = rn.nmatmul(x, w)
    np.testing.assert_array_equal(np.asarray(ref2), np.asarray(got2))


# ---------------------------------------------------------------------------
# transform safety: jit / scan / vmap resolve at trace time
# ---------------------------------------------------------------------------

def test_scope_inside_jit_matches_kwarg_api(rng):
    x, w = _xw(rng)
    pol = rn.NumericsPolicy((("approx.*", SEG1),), default=EXACT_F32)

    def scoped_fn(a, b):
        with rn.numerics_scope(pol), rn.layer_scope("approx"), \
                rn.layer_scope("wi"):
            return rn.nmatmul(a, b)

    def kwarg_fn(a, b):
        return _kwarg_nmatmul(a, b, pol, "approx.wi")

    got = jax.jit(scoped_fn)(x, w)
    ref = jax.jit(kwarg_fn)(x, w)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    # and the approximate path really ran (distinct from exact)
    assert not np.allclose(np.asarray(got), np.asarray(x) @ np.asarray(w))


def test_scope_inside_scan_matches_kwarg_api(rng):
    x, _ = _xw(rng, m=4, k=16, n=16)
    ws = jnp.asarray(rng.standard_normal((3, 16, 16)) * 0.3, jnp.float32)
    pol = rn.NumericsPolicy((("stack.*", SEG1),), default=EXACT_F32)

    def scoped_scan(x0):
        def body(h, wk):
            with rn.numerics_scope(pol), rn.layer_scope("stack"), \
                    rn.layer_scope("w"):
                return rn.nmatmul(h, wk), None
        out, _ = jax.lax.scan(body, x0, ws)
        return out

    def kwarg_scan(x0):
        def body(h, wk):
            return _kwarg_nmatmul(h, wk, pol, "stack.w"), None
        out, _ = jax.lax.scan(body, x0, ws)
        return out

    np.testing.assert_array_equal(np.asarray(jax.jit(scoped_scan)(x)),
                                  np.asarray(jax.jit(kwarg_scan)(x)))


def test_scope_inside_vmap_matches_kwarg_api(rng):
    xs = jnp.asarray(rng.standard_normal((5, 8, 16)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)
    pol = rn.NumericsPolicy((("v.*", SEG1),))

    def scoped_fn(a):
        with rn.numerics_scope(pol), rn.layer_scope("v.w"):
            return rn.nmatmul(a, w)

    got = jax.vmap(scoped_fn)(xs)
    ref = jax.vmap(lambda a: _kwarg_nmatmul(a, w, pol, "v.w"))(xs)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def _lm_setup(arch="qwen3-4b", B=2, S=16, seed=0):
    cfg = get_arch(arch).reduced()
    pp = transformer.init(cfg, jax.random.PRNGKey(seed))
    params, _ = unzip(pp)
    rng = np.random.default_rng(seed)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    return cfg, params, {"tokens": toks}


def test_scanned_transformer_under_policy_scope_in_jit():
    """Scanned transformer blocks under a uniform policy inside jax.jit are
    bit-identical to the same blocks under the equivalent plain config —
    the scope machinery resolves at trace time and leaves no residue in
    the compiled computation."""
    cfg, params, batch = _lm_setup()
    pol = rn.NumericsPolicy((("blocks.*", SEG1),), default=SEG1)
    cfg_pol = dataclasses.replace(cfg, numerics=pol)
    cfg_cfg = dataclasses.replace(cfg, numerics=SEG1)

    run = lambda c: jax.jit(
        lambda p, b: transformer.backbone(p, c, b, mode="train")[0])(
            params, batch)
    np.testing.assert_array_equal(np.asarray(run(cfg_pol)),
                                  np.asarray(run(cfg_cfg)))


# ---------------------------------------------------------------------------
# deprecation shim
# ---------------------------------------------------------------------------

def test_deprecated_kwarg_form_warns_once_per_site(rng):
    x, w = _xw(rng)
    rn.reset_deprecation_registry()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        for _ in range(3):  # same call site three times -> one warning
            a = rn.nmatmul(x, w, SEG1, path="p")
        b = rn.nmatmul(x, w, SEG1, path="p")  # different site -> warns again
    deps = [r for r in rec if issubclass(r.category, DeprecationWarning)]
    assert len(deps) == 2, [str(r.message) for r in rec]
    assert "numerics_scope" in str(deps[0].message)
    # and the shim still computes the same thing as the scoped form
    with rn.numerics_scope(SEG1):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(rn.nmatmul(x, w)))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_path_only_shim_call_resolves_ambient_scope(rng):
    """A half-migrated site that dropped cfg but kept path= must not
    silently fall back to EXACT under an active scope — the path acts as
    an inline layer_scope leaf."""
    x, w = _xw(rng)
    pol = rn.NumericsPolicy((("blocks.0.mlp.wi", SEG1),), default=EXACT_F32)
    with rn.numerics_scope(pol), rn.layer_scope("blocks.0"), \
            rn.layer_scope("mlp"):
        got = _kwarg_nmatmul(x, w, None, "wi")
    with rn.numerics_scope(SEG1):
        want = rn.nmatmul(x, w)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # outside any scope the legacy behaviour holds: EXACT
    bare = _kwarg_nmatmul(x, w, None, "wi")
    np.testing.assert_array_equal(
        np.asarray(bare), np.asarray(rn.nmatmul(x, w)))


def test_scoped_form_does_not_warn(rng):
    x, w = _xw(rng)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        with rn.numerics_scope(SEG1):
            rn.nmatmul(x, w)
    assert not [r for r in rec if issubclass(r.category, DeprecationWarning)]


# ---------------------------------------------------------------------------
# regression: every model call site resolves a non-empty full path
# ---------------------------------------------------------------------------

def _recorded_paths(run_fn, cfg_numerics_replace):
    """Run one instrumented calibration pass; return the recorded paths."""
    with sensitivity.record_operands() as store:
        run_fn(sensitivity.calibration_policy(
            rn.NumericsConfig(mode="exact", compute_dtype="float32")
            if cfg_numerics_replace == "f32" else rn.EXACT))
    return store


@pytest.mark.parametrize("arch", ["qwen3-4b", "mamba2-130m", "whisper-tiny"])
def test_every_lm_call_site_resolves_nonempty_full_path(arch):
    """The satellite regression for the old ``nmatmul(x, w, ncfg)``-with-
    no-path bug: one instrumented pass over each model family must record
    every enumerated layer path, and never an empty or relative one.
    (``ssm.scan`` is a backend lookup, not a matmul site, and is excluded
    by construction.)  The scanned whisper encoder unrolls under the
    calibration policy, so its ``encoder.blocks.*`` sites record too —
    one sample per site, hit once per encoder layer."""
    cfg = get_arch(arch).reduced()
    pp = transformer.init(cfg, jax.random.PRNGKey(0))
    params, _ = unzip(pp)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (1, 8)),
                                   jnp.int32)}
    if cfg.encoder_layers:
        batch["enc_embeds"] = jnp.asarray(
            rng.standard_normal((1, 16, cfg.d_model)), jnp.float32)
        cfg = dataclasses.replace(cfg, enc_len=16)

    def run(policy):
        pcfg = dataclasses.replace(cfg, numerics=policy)
        h, _, _ = transformer.backbone(params, pcfg, batch, mode="train")
        transformer.logits_fn(params, pcfg, h)

    store = _recorded_paths(run, "bf16")
    assert "" not in store
    expected = {p for p in transformer.layer_paths(cfg)
                if not p.endswith(".scan")}
    assert set(store) == expected, (
        sorted(expected - set(store)), sorted(set(store) - expected))
    for p in expected:
        if p.startswith("encoder.blocks."):
            # unindexed path: every encoder layer hits the same site
            assert store[p].calls == cfg.encoder_layers, (p, store[p].calls)


def test_every_resnet_call_site_resolves_nonempty_full_path():
    cfg = resnet.ResNetConfig(widths=(8, 16), blocks=(1, 1))
    pp, state = resnet.init(cfg, jax.random.PRNGKey(0))
    params, _ = unzip(pp)
    images = jnp.asarray(np.random.default_rng(0).standard_normal((2, 8, 8, 3)),
                         jnp.float32)

    def run(policy):
        pcfg = dataclasses.replace(cfg, numerics=policy)
        resnet.apply(params, state, images, pcfg, train=False)

    store = _recorded_paths(run, "f32")
    assert "" not in store
    assert set(store) == set(resnet.layer_paths(cfg))


def test_tap_records_absolute_path_under_scoped_policy_ambient(rng):
    """A ScopedPolicy ambient (the incremental-migration sugar, e.g.
    block_apply(ncfg=policy.scope("blocks.0"))) carries a prefix: the
    operand tap must record the ABSOLUTE path, matching the deprecated
    kwarg branch's cfg.full_path(path) behaviour."""
    x, w = _xw(rng)
    pol = sensitivity.calibration_policy(EXACT_F32)
    with sensitivity.record_operands() as store:
        with rn.numerics_scope(pol.scope("blocks.0").scope("mlp")), \
                rn.layer_scope("wi"):
            rn.nmatmul(x, w)
    assert set(store) == {"blocks.0.mlp.wi"}
    # and resolution under the view still applies the prefixed rules
    pol2 = rn.NumericsPolicy((("blocks.0.mlp.wi", SEG1),), default=EXACT_F32)
    with rn.numerics_scope(pol2.scope("blocks.0")), rn.layer_scope("mlp.wi"):
        got = rn.nmatmul(x, w)
    with rn.numerics_scope(SEG1):
        want = rn.nmatmul(x, w)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_unembed_records_lm_head_path(rng):
    """models/layers.py:unembed previously called nmatmul with no path and
    was invisible to policies and the tap; it must resolve ``lm_head``."""
    from repro.models.layers import unembed

    table = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((4, 16)), jnp.float32)
    pol = sensitivity.calibration_policy(EXACT_F32)
    with sensitivity.record_operands() as store:
        unembed(x, table, pol)
    assert set(store) == {"lm_head"}
    # and a policy rule targeting lm_head actually applies
    pol2 = rn.NumericsPolicy((("lm_head", SEG1),), default=EXACT_F32)
    got = unembed(x, table, pol2)
    with rn.numerics_scope(SEG1):
        want = rn.nmatmul(x, table.T)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
