"""Composed-error sensitivity model + proxy auto-configuration.

Pins the tentpole contract: ONE instrumented calibration pass (eval-callback
call count == 1) yields a policy whose measured error stays within budget
and whose modeled area is within 10% of the greedy (measured-error)
baseline on the ResNet-18 calibration setup; plus the wall-clock budget the
CI leg enforces for the LM-zoo path (proxy auto-configure on qwen3-4b in
under 60 s on a CPU runner — the greedy method need not meet any budget).
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sensitivity, sweep
from repro.core.metrics import mred
from repro.core.numerics import NumericsConfig, nmatmul
from repro.core.policy import NumericsPolicy, PolicyRule
from repro.models import resnet, transformer
from repro.models.layers import unzip

EXACT_F32 = NumericsConfig(mode="exact", compute_dtype="float32")
SEG1 = NumericsConfig(mode="segmented", seg_passes=1, backend="xla")
SEG2 = NumericsConfig(mode="segmented", seg_passes=2, backend="xla")
SEG3 = NumericsConfig(mode="segmented", seg_passes=3, backend="xla")
CANDIDATES = [("segmented-1", SEG1), ("segmented-2", SEG2),
              ("segmented-3", SEG3)]


# ---------------------------------------------------------------------------
# the operand tap + calibration pass
# ---------------------------------------------------------------------------

def test_record_operands_captures_paths_and_samples(rng):
    x = jnp.asarray(rng.standard_normal((200, 12)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((12, 7)), jnp.float32)
    pol = sensitivity.calibration_policy(EXACT_F32)
    with sensitivity.record_operands(max_rows=16) as store:
        nmatmul(x, w, pol, path="a")
        nmatmul(x, w, pol.scope("deep"), path="b")
        nmatmul(x, w, pol, path="a")  # revisit: keeps first sample
    assert set(store) == {"a", "deep.b"}
    rec = store["a"]
    assert rec.x.shape == (16, 12) and rec.w.shape == (12, 7)
    assert rec.calls == 2 and store["deep.b"].calls == 1
    assert rec.out_rms > 0
    # tap is uninstalled on exit
    from repro.core.numerics import operand_tap_active

    assert not operand_tap_active()


def test_tap_skips_traced_operands(rng):
    x = jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)
    pol = sensitivity.calibration_policy(EXACT_F32)
    with sensitivity.record_operands() as store:
        jax.jit(lambda a, b: nmatmul(a, b, pol, path="jitted"))(x, w)
        nmatmul(x, w, pol, path="eager")
    assert set(store) == {"eager"}


def test_propagation_coefficients_head_is_unity(rng):
    """The last-executed site (the network head) has alpha == 1; louder
    upstream sites get proportionally larger coefficients."""
    xs = [jnp.asarray(rng.standard_normal((32, 8)), jnp.float32)
          for _ in range(3)]
    w = jnp.asarray(rng.standard_normal((8, 8)) * 0.35, jnp.float32)
    pol = sensitivity.calibration_policy(EXACT_F32)
    with sensitivity.record_operands() as store:
        nmatmul(xs[0] * 10.0, w, pol, path="loud")
        nmatmul(xs[1], w, pol, path="mid")
        nmatmul(xs[2], w, pol, path="head")
    alpha = sensitivity.propagation_coefficients(store)
    assert alpha["head"] == pytest.approx(1.0)
    assert alpha["loud"] > alpha["mid"]


def test_local_error_orders_the_segmented_ladder(rng):
    """Fewer kept MXU passes -> strictly larger local error on a generic
    operand sample (the model's per-site ladder must be monotone)."""
    x = jnp.asarray(rng.standard_normal((48, 24)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((24, 16)), jnp.float32)
    pol = sensitivity.calibration_policy(EXACT_F32)
    with sensitivity.record_operands() as store:
        nmatmul(x, w, pol, path="site")
    model = sensitivity.SensitivityModel.from_store(store)
    e1 = model.local_error("site", SEG1)
    e2 = model.local_error("site", SEG2)
    e3 = model.local_error("site", SEG3)
    ex = model.local_error("site", EXACT_F32)
    assert e1 > e2 > e3 > ex
    assert ex == pytest.approx(0.0, abs=1e-6)
    # contributions and predictions compose linearly over sites
    assert model.predict({"site": SEG1}) == pytest.approx(
        model.baseline_error + model.alpha["site"] * e1)


# ---------------------------------------------------------------------------
# proxy auto-configuration: the acceptance contract
# ---------------------------------------------------------------------------

def _resnet18_calibration(seed=0):
    """ResNet-18 topology (2-2-2-2 basic blocks) at calibration width."""
    cfg = resnet.ResNetConfig(widths=(8, 16, 32, 64), blocks=(2, 2, 2, 2))
    pp, state = resnet.init(cfg, jax.random.PRNGKey(seed))
    params, _ = unzip(pp)
    rng = np.random.default_rng(seed)
    images = jnp.asarray(rng.standard_normal((4, 16, 16, 3)), jnp.float32)
    return cfg, params, state, images


def _resnet_eval_fn(cfg, params, state, images):
    ref, _ = resnet.apply(params, state, images, cfg, train=False)
    ref = np.asarray(ref, np.float64)
    calls = [0]

    def eval_fn(policy):
        calls[0] += 1
        acfg = dataclasses.replace(cfg, numerics=policy)
        logits, _ = resnet.apply(params, state, images, acfg, train=False)
        return mred(np.asarray(logits), ref)

    return eval_fn, calls


def test_proxy_calibration_records_every_resnet_site():
    cfg, params, state, images = _resnet18_calibration()
    eval_fn, calls = _resnet_eval_fn(cfg, params, state, images)
    model = sensitivity.calibrate(eval_fn, default=EXACT_F32)
    assert calls[0] == 1
    assert set(model.sites) == set(resnet.layer_paths(cfg))
    assert model.alpha["fc"] == pytest.approx(1.0)  # fc executes last
    assert all(a > 0 for a in model.alpha.values())


def test_proxy_auto_configure_one_pass_within_budget_near_greedy():
    """Acceptance: proxy spends exactly one eval, its policy's MEASURED
    error meets the budget, and its modeled area is within 10% of the
    greedy baseline's."""
    cfg, params, state, images = _resnet18_calibration()
    paths = resnet.layer_paths(cfg)
    budget = 5e-3

    eval_fn, calls = _resnet_eval_fn(cfg, params, state, images)
    res = sweep.auto_configure(eval_fn, paths, budget, candidates=CANDIDATES,
                               method="proxy")
    assert res.method == "proxy"
    assert calls[0] == 1 and res.n_evals == 1
    assert res.predicted_error == res.error <= budget
    measured = eval_fn(res.policy)
    assert measured <= budget, (measured, res.error)

    eval_fn_g, calls_g = _resnet_eval_fn(cfg, params, state, images)
    greedy = sweep.auto_configure(eval_fn_g, paths, budget,
                                  candidates=CANDIDATES, method="greedy")
    assert greedy.error <= budget
    assert calls_g[0] > len(paths)  # the cost the proxy removes
    # modeled area within 10% of the greedy baseline
    assert abs(res.area_um2 - greedy.area_um2) <= 0.10 * greedy.area_um2, (
        res.area_um2, greedy.area_um2, res.assignments, greedy.assignments)
    # both beat the all-exact baseline
    assert res.area_um2 < res.baseline_area_um2


def test_proxy_unrecorded_paths_stay_default(rng):
    """Paths never executed on the calibration batch keep the default
    config rather than receiving a blind assignment."""
    x = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((16, 16)) * 0.25, jnp.float32)

    def eval_fn(policy):
        h = nmatmul(x, w, policy, path="used")
        return 0.0

    res = sweep.auto_configure(eval_fn, ["used", "ghost"], 1.0,
                               candidates=CANDIDATES, method="proxy")
    assigned = dict(res.assignments)
    assert "used" in assigned and "ghost" not in assigned
    assert res.policy.lookup("ghost").mode == "exact"


def test_auto_configure_rejects_unknown_method():
    with pytest.raises(ValueError, match="unknown method"):
        sweep.auto_configure(lambda p: 0.0, ["a"], 1e-3, method="magic")


def test_proxy_raises_when_calibration_records_nothing(rng):
    """A jit-wrapped eval_fn hides every operand from the tap; the proxy
    must fail loudly instead of returning an empty zero-savings policy."""
    x = jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)

    def eval_fn(policy):
        jax.jit(lambda a, b: nmatmul(a, b, policy, path="site"))(x, w)
        return 0.0

    with pytest.raises(ValueError, match="EAGERLY"):
        sweep.auto_configure(eval_fn, ["site"], 1e-3, candidates=CANDIDATES,
                             method="proxy")


# ---------------------------------------------------------------------------
# golden fixtures: coefficients pinned against the independent numpy
# reference (tests/golden/gen_policy_golden.py)
# ---------------------------------------------------------------------------

def _sensitivity_golden():
    import json
    import os

    path = os.path.join(os.path.dirname(__file__), "golden",
                        "policy_golden.json")
    with open(path) as f:
        return json.load(f)["sensitivity"]


def test_sensitivity_coefficients_match_golden():
    """alpha / out_rms / per-design local MRED / composed prediction all
    match the independent numpy split-float reference bit-near (the only
    wobble is f32 matmul accumulation order)."""
    gold = _sensitivity_golden()
    pol = sensitivity.calibration_policy(EXACT_F32)
    with sensitivity.record_operands() as store:
        for site in gold["sites"]:
            nmatmul(jnp.asarray(np.asarray(site["x"], np.float32)),
                    jnp.asarray(np.asarray(site["w"], np.float32)),
                    pol, path=site["path"])
    model = sensitivity.SensitivityModel.from_store(store)
    seg = {f"seg{p}": NumericsConfig(mode="segmented", seg_passes=p,
                                     backend="xla") for p in (1, 2, 3)}
    for site in gold["sites"]:
        p = site["path"]
        assert model.sites[p].out_rms == pytest.approx(site["out_rms"],
                                                       rel=1e-6)
        assert model.alpha[p] == pytest.approx(site["alpha"], rel=1e-6)
        for tag, want in site["local_mred"].items():
            got = model.local_error(p, seg[tag])
            assert got == pytest.approx(want, rel=1e-3), (p, tag, got, want)
    composed = model.predict(
        {p: seg[tag] for p, tag in gold["assignment"].items()})
    assert composed == pytest.approx(gold["composed_prediction"], rel=1e-3)


# ---------------------------------------------------------------------------
# MoE per-expert sensitivity + LM-zoo scaling (the CI wall-clock leg)
# ---------------------------------------------------------------------------

def test_calibration_records_per_expert_moe_sites(small_moe):
    from repro.models import moe as moe_mod

    cfg, params, x = small_moe(E=2, K=2, T=16, D=16, FF=32)

    def eval_fn(policy):
        moe_mod.moe_apply(params, x, cfg, policy)
        return 0.0

    model = sensitivity.calibrate(eval_fn, default=EXACT_F32)
    for k in range(2):
        for name in ("wi", "wg", "wo"):
            assert f"expert{k}.{name}" in model.sites, sorted(model.sites)


def test_transformer_layer_paths_enumerate_expert_multiplicity():
    from repro.configs import get_arch

    cfg = get_arch("deepseek-v3-671b").reduced()
    paths = transformer.layer_paths(cfg)
    assert paths[-1] == "lm_head"
    moe_paths = [p for p in paths if ".mlp.expert" in p]
    # every MoE block contributes n_experts * 3 routed-projection paths
    n_moe_blocks = sum(r * sum(1 for s in pat if s.kind == "moe")
                       for r, pat in cfg.segments)
    assert len(moe_paths) == n_moe_blocks * cfg.moe.n_experts * 3
    # area roll-up counts each expert instance (policy_area over the list)
    pol = NumericsPolicy((), default=EXACT_F32)
    assert sweep.policy_area(pol, paths) == pytest.approx(
        sweep.config_ppa(EXACT_F32).logic_area_um2 * len(paths))
    # counts= multiplicity is equivalent to repeating the path
    assert sweep.policy_area(pol, ["lm_head"], counts={"lm_head": 5}) == (
        pytest.approx(5 * sweep.config_ppa(EXACT_F32).logic_area_um2))


def test_encoder_paths_carry_layer_multiplicity_via_counts():
    """The scanned whisper encoder resolves under unindexed paths, so the
    PPA roll-up must weight each by cfg.encoder_layers."""
    from repro.configs import get_arch

    cfg = get_arch("whisper-tiny").reduced()
    assert cfg.encoder_layers > 1
    paths = transformer.layer_paths(cfg)
    counts = transformer.layer_path_counts(cfg)
    enc_paths = [p for p in paths if p.startswith("encoder.blocks.")]
    assert enc_paths and set(counts) == set(enc_paths)
    assert all(v == cfg.encoder_layers for v in counts.values())
    pol = NumericsPolicy((), default=EXACT_F32)
    unit = sweep.config_ppa(EXACT_F32).logic_area_um2
    extra = (cfg.encoder_layers - 1) * len(enc_paths)
    assert sweep.policy_area(pol, paths, counts) == pytest.approx(
        unit * (len(paths) + extra))
    # decoder-only models need no counts
    assert transformer.layer_path_counts(
        get_arch("qwen3-4b").reduced()) == {}


@pytest.mark.slow
def test_proxy_auto_configure_qwen3_under_60s_wall_clock():
    """CI budget: proxy auto-configure on the qwen3-4b config — one
    calibration forward + modeled assignment — completes in under 60 s on
    the CPU runner.  (greedy re-evaluates the network per candidate and
    carries no such budget.)"""
    from repro.configs import get_arch

    cfg = get_arch("qwen3-4b").reduced()
    pp = transformer.init(cfg, jax.random.PRNGKey(0))
    params, _ = unzip(pp)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)),
                                   jnp.int32)}
    hidden, _, _ = transformer.backbone(params, cfg, batch, mode="train")
    ref = np.asarray(transformer.logits_fn(params, cfg, hidden), np.float64)
    calls = [0]

    def eval_fn(policy):
        calls[0] += 1
        pcfg = dataclasses.replace(cfg, numerics=policy)
        h, _, _ = transformer.backbone(params, pcfg, batch, mode="train")
        logits = transformer.logits_fn(params, pcfg, h)
        return mred(np.asarray(logits), ref)

    t0 = time.perf_counter()
    # the default must match the network's own exact numerics (bf16 for the
    # LM zoo) — an f32 default would make the baseline itself read as error
    res = sweep.auto_configure(eval_fn, transformer.layer_paths(cfg), 1e-2,
                               candidates=CANDIDATES, method="proxy",
                               default=NumericsConfig(mode="exact"))
    dt = time.perf_counter() - t0
    assert calls[0] == 1 and res.n_evals == 1
    assert dt < 60.0, f"proxy auto-configure took {dt:.1f}s (budget 60s)"
    assert res.error <= 1e-2
    assert res.assignments  # the LM actually got approximate layers
    # the composed prediction brackets the measured error of the emitted
    # policy within the stated first-order factor (see the bracketing
    # property in tests/test_hypothesis_properties.py)
    measured = eval_fn(res.policy)
    assert measured <= 4.0 * res.error, (measured, res.error)
