"""Composed-error sensitivity model + proxy auto-configuration.

Pins the tentpole contract: ONE instrumented calibration pass (eval-callback
call count == 1) yields a policy whose measured error stays within budget
and whose modeled area is within 10% of the greedy (measured-error)
baseline on the ResNet-18 calibration setup; plus the wall-clock budget the
CI leg enforces for the LM-zoo path (proxy auto-configure on qwen3-4b in
under 60 s on a CPU runner — the greedy method need not meet any budget).
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sensitivity, sweep
from repro.core.metrics import mred
from repro.core.numerics import NumericsConfig, nmatmul
from repro.core.policy import NumericsPolicy, PolicyRule
from repro.models import resnet, transformer
from repro.models.layers import unzip

EXACT_F32 = NumericsConfig(mode="exact", compute_dtype="float32")
SEG1 = NumericsConfig(mode="segmented", seg_passes=1, backend="xla")
SEG2 = NumericsConfig(mode="segmented", seg_passes=2, backend="xla")
SEG3 = NumericsConfig(mode="segmented", seg_passes=3, backend="xla")
CANDIDATES = [("segmented-1", SEG1), ("segmented-2", SEG2),
              ("segmented-3", SEG3)]


# ---------------------------------------------------------------------------
# the operand tap + calibration pass
# ---------------------------------------------------------------------------

def test_record_operands_captures_paths_and_samples(rng):
    x = jnp.asarray(rng.standard_normal((200, 12)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((12, 7)), jnp.float32)
    pol = sensitivity.calibration_policy(EXACT_F32)
    with sensitivity.record_operands(max_rows=16) as store:
        nmatmul(x, w, pol, path="a")
        nmatmul(x, w, pol.scope("deep"), path="b")
        nmatmul(x, w, pol, path="a")  # revisit: keeps first sample
    assert set(store) == {"a", "deep.b"}
    rec = store["a"]
    assert rec.x.shape == (16, 12) and rec.w.shape == (12, 7)
    assert rec.calls == 2 and store["deep.b"].calls == 1
    assert rec.out_rms > 0
    # tap is uninstalled on exit
    from repro.core.numerics import operand_tap_active

    assert not operand_tap_active()


def test_tap_skips_traced_operands(rng):
    x = jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)
    pol = sensitivity.calibration_policy(EXACT_F32)
    with sensitivity.record_operands() as store:
        jax.jit(lambda a, b: nmatmul(a, b, pol, path="jitted"))(x, w)
        nmatmul(x, w, pol, path="eager")
    assert set(store) == {"eager"}


def test_propagation_coefficients_head_is_unity(rng):
    """The last-executed site (the network head) has alpha == 1; louder
    upstream sites get proportionally larger coefficients."""
    xs = [jnp.asarray(rng.standard_normal((32, 8)), jnp.float32)
          for _ in range(3)]
    w = jnp.asarray(rng.standard_normal((8, 8)) * 0.35, jnp.float32)
    pol = sensitivity.calibration_policy(EXACT_F32)
    with sensitivity.record_operands() as store:
        nmatmul(xs[0] * 10.0, w, pol, path="loud")
        nmatmul(xs[1], w, pol, path="mid")
        nmatmul(xs[2], w, pol, path="head")
    alpha = sensitivity.propagation_coefficients(store)
    assert alpha["head"] == pytest.approx(1.0)
    assert alpha["loud"] > alpha["mid"]


def test_local_error_orders_the_segmented_ladder(rng):
    """Fewer kept MXU passes -> strictly larger local error on a generic
    operand sample (the model's per-site ladder must be monotone)."""
    x = jnp.asarray(rng.standard_normal((48, 24)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((24, 16)), jnp.float32)
    pol = sensitivity.calibration_policy(EXACT_F32)
    with sensitivity.record_operands() as store:
        nmatmul(x, w, pol, path="site")
    model = sensitivity.SensitivityModel.from_store(store)
    e1 = model.local_error("site", SEG1)
    e2 = model.local_error("site", SEG2)
    e3 = model.local_error("site", SEG3)
    ex = model.local_error("site", EXACT_F32)
    assert e1 > e2 > e3 > ex
    assert ex == pytest.approx(0.0, abs=1e-6)
    # the rms-flavoured ladder is monotone too
    r1 = model.local_rms_error("site", SEG1)
    assert r1 > model.local_rms_error("site", SEG2) > \
        model.local_rms_error("site", SEG3)
    # contributions and predictions compose linearly over sites, through
    # the gain-aware formula tail * alpha * G * local_rms_error
    assert model.contribution("site", SEG1) == pytest.approx(
        model.tail * model.alpha["site"] * model.gain["site"] * r1)
    assert model.predict({"site": SEG1}) == pytest.approx(
        model.baseline_error + model.contribution("site", SEG1))


# ---------------------------------------------------------------------------
# proxy auto-configuration: the acceptance contract
# ---------------------------------------------------------------------------

def _resnet18_calibration(seed=0):
    """ResNet-18 topology (2-2-2-2 basic blocks) at calibration width."""
    cfg = resnet.ResNetConfig(widths=(8, 16, 32, 64), blocks=(2, 2, 2, 2))
    pp, state = resnet.init(cfg, jax.random.PRNGKey(seed))
    params, _ = unzip(pp)
    rng = np.random.default_rng(seed)
    images = jnp.asarray(rng.standard_normal((4, 16, 16, 3)), jnp.float32)
    return cfg, params, state, images


def _resnet_eval_fn(cfg, params, state, images):
    ref, _ = resnet.apply(params, state, images, cfg, train=False)
    ref = np.asarray(ref, np.float64)
    calls = [0]

    def eval_fn(policy):
        calls[0] += 1
        acfg = dataclasses.replace(cfg, numerics=policy)
        logits, _ = resnet.apply(params, state, images, acfg, train=False)
        return mred(np.asarray(logits), ref)

    return eval_fn, calls


def test_proxy_calibration_records_every_resnet_site():
    cfg, params, state, images = _resnet18_calibration()
    eval_fn, calls = _resnet_eval_fn(cfg, params, state, images)
    model = sensitivity.calibrate(eval_fn, default=EXACT_F32)
    assert calls[0] == 1
    assert set(model.sites) == set(resnet.layer_paths(cfg))
    assert model.alpha["fc"] == pytest.approx(1.0)  # fc executes last
    assert all(a > 0 for a in model.alpha.values())


def test_proxy_auto_configure_one_pass_within_budget_near_greedy():
    """Acceptance: proxy spends exactly one eval, its policy's MEASURED
    error meets the budget, and its modeled area is within 10% of the
    greedy baseline's."""
    cfg, params, state, images = _resnet18_calibration()
    paths = resnet.layer_paths(cfg)
    budget = 5e-3

    eval_fn, calls = _resnet_eval_fn(cfg, params, state, images)
    res = sweep.auto_configure(eval_fn, paths, budget, candidates=CANDIDATES,
                               method="proxy")
    assert res.method == "proxy"
    assert calls[0] == 1 and res.n_evals == 1
    assert res.predicted_error == res.error <= budget
    measured = eval_fn(res.policy)
    assert measured <= budget, (measured, res.error)

    eval_fn_g, calls_g = _resnet_eval_fn(cfg, params, state, images)
    greedy = sweep.auto_configure(eval_fn_g, paths, budget,
                                  candidates=CANDIDATES, method="greedy")
    assert greedy.error <= budget
    assert calls_g[0] > len(paths)  # the cost the proxy removes
    # modeled area within 10% of the greedy baseline
    assert abs(res.area_um2 - greedy.area_um2) <= 0.10 * greedy.area_um2, (
        res.area_um2, greedy.area_um2, res.assignments, greedy.assignments)
    # both beat the all-exact baseline
    assert res.area_um2 < res.baseline_area_um2


def test_proxy_unrecorded_paths_stay_default(rng):
    """Paths never executed on the calibration batch keep the default
    config rather than receiving a blind assignment."""
    x = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((16, 16)) * 0.25, jnp.float32)

    def eval_fn(policy):
        h = nmatmul(x, w, policy, path="used")
        return 0.0

    res = sweep.auto_configure(eval_fn, ["used", "ghost"], 1.0,
                               candidates=CANDIDATES, method="proxy")
    assigned = dict(res.assignments)
    assert "used" in assigned and "ghost" not in assigned
    assert res.policy.lookup("ghost").mode == "exact"


def test_auto_configure_rejects_unknown_method():
    with pytest.raises(ValueError, match="unknown method"):
        sweep.auto_configure(lambda p: 0.0, ["a"], 1e-3, method="magic")


def test_proxy_raises_when_calibration_records_nothing(rng):
    """A jit-wrapped eval_fn hides every operand from the tap; the proxy
    must fail loudly instead of returning an empty zero-savings policy."""
    x = jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)

    def eval_fn(policy):
        jax.jit(lambda a, b: nmatmul(a, b, policy, path="site"))(x, w)
        return 0.0

    with pytest.raises(ValueError, match="EAGERLY"):
        sweep.auto_configure(eval_fn, ["site"], 1e-3, candidates=CANDIDATES,
                             method="proxy")


# ---------------------------------------------------------------------------
# gain coefficients: the JVP probe, its finite-difference fallback, and
# the downstream chain composition
# ---------------------------------------------------------------------------

def test_probe_gain_fd_fallback_matches_jvp(rng):
    """The site map is linear in x, so the finite-difference output
    perturbation and the JVP probe must agree to rounding."""
    x = np.asarray(rng.standard_normal((16, 12)), np.float32)
    w = np.asarray(rng.standard_normal((12, 20)) * 0.6, np.float32)
    g_jvp = sensitivity.probe_gain(x, w, method="jvp")
    g_fd = sensitivity.probe_gain(x, w, method="fd")
    assert g_jvp == pytest.approx(g_fd, rel=1e-4)
    with pytest.raises(ValueError, match="unknown probe method"):
        sensitivity.probe_gain(x, w, method="magic")


def test_site_gain_tracks_map_amplification(rng):
    """An amplifying weight matrix must show up in the recorded gain: the
    probe measures what the map does to a random (error-like) direction,
    scaling linearly with the weights."""
    x = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((16, 16)), jnp.float32)
    pol = sensitivity.calibration_policy(EXACT_F32)
    with sensitivity.record_operands() as store:
        nmatmul(x, w, pol, path="unit")
        nmatmul(x, 10.0 * w, pol, path="loud")
    assert store["loud"].gain == pytest.approx(10.0 * store["unit"].gain,
                                               rel=1e-5)
    assert store["unit"].in_rms == pytest.approx(
        float(np.sqrt(np.mean(np.square(np.asarray(x))))), rel=1e-6)


def test_downstream_gain_composes_along_chains_only(rng):
    """Gains multiply along observed input-equals-previous-output chains;
    a break in the chain (a site fed by something other than its
    predecessor's output) resets the product to the unit-gain residual
    assumption."""
    x = jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)
    w_amp = jnp.asarray(rng.standard_normal((8, 8)) * 2.0, jnp.float32)
    pol = sensitivity.calibration_policy(EXACT_F32)
    with sensitivity.record_operands() as store:
        h = nmatmul(x, w_amp, pol, path="a").astype(jnp.float32)
        h = nmatmul(h, w_amp, pol, path="b").astype(jnp.float32)
        nmatmul(x, w_amp, pol, path="c")  # fed by x, NOT by b's output
    assert store["b"].chained and not store["c"].chained
    G = sensitivity.downstream_gains(store)
    # a's error flows through b's map; the chain breaks at c
    assert G["a"] == pytest.approx(store["b"].gain, rel=1e-6)
    assert G["b"] == 1.0 and G["c"] == 1.0


def test_chain_detection_survives_column_subsampling(rng):
    """Chains must be detected at real network widths: the operand tap
    samples <= MAX_COLS weight columns, so the probe compares the next
    site's input in the PREVIOUS site's sampled column space — a
    width-128 chain (wider than the 64-column sample) still chains."""
    x = jnp.asarray(rng.standard_normal((16, 128)), jnp.float32)
    w1 = jnp.asarray(rng.standard_normal((128, 128)) / 12.0, jnp.float32)
    w2 = jnp.asarray(rng.standard_normal((128, 128)) / 12.0, jnp.float32)
    pol = sensitivity.calibration_policy(EXACT_F32)
    with sensitivity.record_operands() as store:
        h = nmatmul(x, w1, pol, path="a").astype(jnp.float32)
        nmatmul(h, w2, pol, path="b")
    assert store["a"].w.shape[1] == sensitivity.MAX_COLS  # really subsampled
    assert store["b"].chained
    G = sensitivity.downstream_gains(store)
    assert G["a"] == pytest.approx(store["b"].gain, rel=1e-6)
    # and a width change between sites (not a chain) stays unchained
    with sensitivity.record_operands() as store2:
        h = nmatmul(x, w1[:, :96], pol, path="a").astype(jnp.float32)
        nmatmul(h[:, :80], w2[:80], pol, path="b")
    assert not store2["b"].chained


def test_chain_detection_survives_bf16_default(rng):
    """The LM zoo calibrates under the exact-bf16 default, so the eager
    pass's actual outputs carry bf16 operand rounding (~4e-3/element)
    versus the tap's float64 reference product — the chain tolerance must
    swallow that, or gain composition silently degrades to the flat
    model exactly on the deep-stack path it exists to fix."""
    bf16 = NumericsConfig(mode="exact")  # compute_dtype defaults bfloat16
    x = jnp.asarray(rng.standard_normal((16, 32)), jnp.float32)
    w1 = jnp.asarray(rng.standard_normal((32, 32)) / 5.0, jnp.float32)
    w2 = jnp.asarray(rng.standard_normal((32, 24)) / 5.0, jnp.float32)
    pol = sensitivity.calibration_policy(bf16)
    with sensitivity.record_operands() as store:
        h = nmatmul(x, w1, pol, path="a").astype(jnp.float32)
        nmatmul(h, w2, pol, path="b")
    assert store["b"].chained
    # and genuinely unrelated inputs (O(1) per-element differences) must
    # still NOT chain under the loosened tolerance
    with sensitivity.record_operands() as store2:
        nmatmul(x, w1, pol, path="a")
        nmatmul(jnp.asarray(rng.standard_normal((16, 32)), jnp.float32),
                w1, pol, path="b")
    assert not store2["b"].chained


def test_contribution_weights_execution_multiplicity(rng):
    """A site hit N times during the pass (the unrolled scanned encoder:
    one unindexed path per N physical layers) injects its design error N
    times — contribution must scale by ``calls``, or encoder budgets read
    N-times too optimistic."""
    x = jnp.asarray(rng.standard_normal((16, 12)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((12, 8)), jnp.float32)
    pol = sensitivity.calibration_policy(EXACT_F32)
    with sensitivity.record_operands() as once:
        nmatmul(x, w, pol, path="site")
    with sensitivity.record_operands() as thrice:
        for _ in range(3):
            nmatmul(x, w, pol, path="site")
    m1 = sensitivity.SensitivityModel.from_store(once)
    m3 = sensitivity.SensitivityModel.from_store(thrice)
    assert m3.sites["site"].calls == 3
    assert m3.contribution("site", SEG1) == pytest.approx(
        3.0 * m1.contribution("site", SEG1))


def test_gain_aware_prediction_tracks_amplifying_chain(rng):
    """On a 2-layer chain whose second map amplifies ~4x (unnormalized
    weights), the flat alpha-only composition under-predicts the measured
    error by about that gain; the gain-aware prediction stays within a
    small factor.  This is the ROADMAP's 'proxy under-predicts on deep
    stacks' failure, reduced to its minimal case."""
    from repro.core.metrics import mred

    d = 16
    x = jnp.asarray(rng.standard_normal((24, d)), jnp.float32)
    w1 = jnp.asarray(rng.standard_normal((d, d)) / np.sqrt(d), jnp.float32)
    w2 = jnp.asarray(rng.standard_normal((d, d)), jnp.float32)  # gain ~sqrt(d)

    def fwd(pol):
        h = nmatmul(x, w1, pol, path="layer.0").astype(jnp.float32)
        return nmatmul(h, w2, pol, path="layer.1").astype(jnp.float32)

    model = sensitivity.calibrate(lambda p: (fwd(p), 0.0)[1],
                                  default=EXACT_F32)
    assert model.sites["layer.1"].chained
    assert model.gain["layer.0"] == pytest.approx(
        model.sites["layer.1"].gain, rel=1e-6)
    assert model.sites["layer.1"].gain > 2.0  # the chain genuinely amplifies
    assignment = {"layer.0": SEG1}  # error injected upstream only
    pred = model.predict(assignment)
    flat_pred = model.tail * model.alpha["layer.0"] * \
        model.local_rms_error("layer.0", SEG1)  # same model, gain ablated
    pol = NumericsPolicy.from_assignments(assignment, default=EXACT_F32)
    ref = np.asarray(fwd(NumericsPolicy((), default=EXACT_F32)), np.float64)
    measured = mred(np.asarray(fwd(pol), np.float64), ref)
    # gain-aware brackets the measurement; the ablation shows the gain
    # term is what closes the gap
    assert measured <= 6.0 * pred and pred <= 32.0 * measured, (
        pred, measured)
    assert pred / flat_pred == pytest.approx(model.gain["layer.0"], rel=1e-6)


# ---------------------------------------------------------------------------
# golden fixtures: coefficients pinned against the independent numpy
# reference (tests/golden/gen_policy_golden.py)
# ---------------------------------------------------------------------------

def _sensitivity_golden():
    import json
    import os

    path = os.path.join(os.path.dirname(__file__), "golden",
                        "policy_golden.json")
    with open(path) as f:
        return json.load(f)["sensitivity"]


def test_sensitivity_coefficients_match_golden():
    """alpha / out_rms / gains / chain flags / tail / per-design local
    errors / composed prediction all match the independent numpy
    split-float reference bit-near (the only wobble is f32 matmul
    accumulation order)."""
    gold = _sensitivity_golden()
    pol = sensitivity.calibration_policy(EXACT_F32)
    with sensitivity.record_operands() as store:
        for site in gold["sites"]:
            nmatmul(jnp.asarray(np.asarray(site["x"], np.float32)),
                    jnp.asarray(np.asarray(site["w"], np.float32)),
                    pol, path=site["path"])
    model = sensitivity.SensitivityModel.from_store(store)
    seg = {f"seg{p}": NumericsConfig(mode="segmented", seg_passes=p,
                                     backend="xla") for p in (1, 2, 3)}
    assert model.tail == pytest.approx(gold["tail_factor"], rel=1e-6)
    for site in gold["sites"]:
        p = site["path"]
        assert model.sites[p].out_rms == pytest.approx(site["out_rms"],
                                                       rel=1e-6)
        assert model.sites[p].chained == site["chained"]
        assert model.sites[p].gain == pytest.approx(site["site_gain"],
                                                    rel=1e-4)
        assert model.alpha[p] == pytest.approx(site["alpha"], rel=1e-6)
        assert model.gain[p] == pytest.approx(site["downstream_gain"],
                                              rel=1e-4)
        for tag, want in site["local_mred"].items():
            got = model.local_error(p, seg[tag])
            assert got == pytest.approx(want, rel=1e-3), (p, tag, got, want)
        for tag, want in site["local_rms"].items():
            got = model.local_rms_error(p, seg[tag])
            assert got == pytest.approx(want, rel=1e-3), (p, tag, got, want)
    composed = model.predict(
        {p: seg[tag] for p, tag in gold["assignment"].items()})
    assert composed == pytest.approx(gold["composed_prediction"], rel=1e-3)


# ---------------------------------------------------------------------------
# MoE per-expert sensitivity + LM-zoo scaling (the CI wall-clock leg)
# ---------------------------------------------------------------------------

def test_calibration_records_per_expert_moe_sites(small_moe):
    from repro.models import moe as moe_mod

    cfg, params, x = small_moe(E=2, K=2, T=16, D=16, FF=32)

    def eval_fn(policy):
        moe_mod.moe_apply(params, x, cfg, policy)
        return 0.0

    model = sensitivity.calibrate(eval_fn, default=EXACT_F32)
    for k in range(2):
        for name in ("wi", "wg", "wo"):
            assert f"expert{k}.{name}" in model.sites, sorted(model.sites)


def test_transformer_layer_paths_enumerate_expert_multiplicity():
    from repro.configs import get_arch

    cfg = get_arch("deepseek-v3-671b").reduced()
    paths = transformer.layer_paths(cfg)
    assert paths[-1] == "lm_head"
    moe_paths = [p for p in paths if ".mlp.expert" in p]
    # every MoE block contributes n_experts * 3 routed-projection paths
    n_moe_blocks = sum(r * sum(1 for s in pat if s.kind == "moe")
                       for r, pat in cfg.segments)
    assert len(moe_paths) == n_moe_blocks * cfg.moe.n_experts * 3
    # area roll-up counts each expert instance (policy_area over the list)
    pol = NumericsPolicy((), default=EXACT_F32)
    assert sweep.policy_area(pol, paths) == pytest.approx(
        sweep.config_ppa(EXACT_F32).logic_area_um2 * len(paths))
    # counts= multiplicity is equivalent to repeating the path
    assert sweep.policy_area(pol, ["lm_head"], counts={"lm_head": 5}) == (
        pytest.approx(5 * sweep.config_ppa(EXACT_F32).logic_area_um2))


def test_encoder_paths_carry_layer_multiplicity_via_counts():
    """The scanned whisper encoder resolves under unindexed paths, so the
    PPA roll-up must weight each by cfg.encoder_layers."""
    from repro.configs import get_arch

    cfg = get_arch("whisper-tiny").reduced()
    assert cfg.encoder_layers > 1
    paths = transformer.layer_paths(cfg)
    counts = transformer.layer_path_counts(cfg)
    enc_paths = [p for p in paths if p.startswith("encoder.blocks.")]
    assert enc_paths and set(counts) == set(enc_paths)
    assert all(v == cfg.encoder_layers for v in counts.values())
    pol = NumericsPolicy((), default=EXACT_F32)
    unit = sweep.config_ppa(EXACT_F32).logic_area_um2
    extra = (cfg.encoder_layers - 1) * len(enc_paths)
    assert sweep.policy_area(pol, paths, counts) == pytest.approx(
        unit * (len(paths) + extra))
    # decoder-only models need no counts
    assert transformer.layer_path_counts(
        get_arch("qwen3-4b").reduced()) == {}


def test_calibration_records_scanned_encoder_sites():
    """The scan blind spot, closed: the whisper-style encoder scans its
    layers with one trace, which used to hide every ``encoder.blocks.*``
    site from the eager calibration tap.  Under the calibration policy
    the encoder unrolls, so one instrumented pass records each encoder
    site with a non-empty ABSOLUTE path, hit once per encoder layer."""
    from repro.configs import get_arch

    cfg = get_arch("whisper-tiny").reduced()
    cfg = dataclasses.replace(cfg, enc_len=16)
    pp = transformer.init(cfg, jax.random.PRNGKey(0))
    params, _ = unzip(pp)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (1, 8)),
                                   jnp.int32),
             "enc_embeds": jnp.asarray(
                 rng.standard_normal((1, 16, cfg.d_model)), jnp.float32)}

    def eval_fn(policy):
        pcfg = dataclasses.replace(cfg, numerics=policy)
        h, _, _ = transformer.backbone(params, pcfg, batch, mode="train")
        transformer.logits_fn(params, pcfg, h)
        return 0.0

    model = sensitivity.calibrate(eval_fn, default=NumericsConfig(mode="exact"))
    enc_sites = {p for p in model.sites if p.startswith("encoder.blocks.")}
    expected = {p for p in transformer.layer_paths(cfg)
                if p.startswith("encoder.blocks.")}
    assert enc_sites == expected and expected, sorted(model.sites)
    for p in enc_sites:
        assert model.sites[p].calls == cfg.encoder_layers
        assert model.alpha[p] > 0
    # and the proxy can now assign encoder sites under a budget
    paths = transformer.layer_paths(cfg)
    res = sweep.auto_configure(eval_fn, paths, 1e6,
                               candidates=CANDIDATES, method="proxy",
                               default=NumericsConfig(mode="exact"))
    assert any(p.startswith("encoder.blocks.") for p, _ in res.assignments)
    # area accounting counts one multiplier instance per physical encoder
    # layer (calls multiplicity), matching the calls-weighted contribution
    exact_area = sweep.config_ppa(NumericsConfig(mode="exact")).logic_area_um2
    n_enc = sum(1 for p in paths if p.startswith("encoder.blocks."))
    assert res.baseline_area_um2 == pytest.approx(
        exact_area * (len(paths) + (cfg.encoder_layers - 1) * n_enc))


@pytest.mark.slow
def test_session_auto_configure_whisper_covers_encoder():
    """Session.auto_configure on an encoder-decoder arch builds its own
    calibration batch (tokens + enc_embeds) and emits a policy whose
    rules cover the ``encoder.blocks.*`` sites."""
    from repro.session import Session

    sess = Session("whisper-tiny")
    res = sess.auto_configure(budget=1e6, method="proxy")
    assert res.n_evals == 1
    assert any(p.startswith("encoder.blocks.") for p, _ in res.assignments), \
        res.assignments


@pytest.mark.slow
def test_proxy_auto_configure_qwen3_under_60s_wall_clock():
    """CI budget: proxy auto-configure on the qwen3-4b config — one
    calibration forward + modeled assignment — completes in under 60 s on
    the CPU runner.  (greedy re-evaluates the network per candidate and
    carries no such budget.)"""
    from repro.configs import get_arch

    cfg = get_arch("qwen3-4b").reduced()
    pp = transformer.init(cfg, jax.random.PRNGKey(0))
    params, _ = unzip(pp)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)),
                                   jnp.int32)}
    hidden, _, _ = transformer.backbone(params, cfg, batch, mode="train")
    ref = np.asarray(transformer.logits_fn(params, cfg, hidden), np.float64)
    calls = [0]

    def eval_fn(policy):
        calls[0] += 1
        pcfg = dataclasses.replace(cfg, numerics=policy)
        h, _, _ = transformer.backbone(params, pcfg, batch, mode="train")
        logits = transformer.logits_fn(params, pcfg, h)
        return mred(np.asarray(logits), ref)

    t0 = time.perf_counter()
    # the default must match the network's own exact numerics (bf16 for the
    # LM zoo) — an f32 default would make the baseline itself read as error
    res = sweep.auto_configure(eval_fn, transformer.layer_paths(cfg), 1e-2,
                               candidates=CANDIDATES, method="proxy",
                               default=NumericsConfig(mode="exact"))
    dt = time.perf_counter() - t0
    assert calls[0] == 1 and res.n_evals == 1
    assert dt < 60.0, f"proxy auto-configure took {dt:.1f}s (budget 60s)"
    assert res.error <= 1e-2
    assert res.assignments  # the LM actually got approximate layers
    # the composed prediction brackets the measured error of the emitted
    # policy within the stated first-order factor (see the bracketing
    # property in tests/test_hypothesis_properties.py)
    measured = eval_fn(res.policy)
    assert measured <= 4.0 * res.error, (measured, res.error)
