"""Deterministic serving-engine tests over the simulation rig.

Everything here runs on :class:`tests.serving_sim.StubRunner` — no jax
compilation — with scripted arrivals through a ``FakeClock``, so the
assertions are about the engine itself: admission order, mid-decode
joins, per-request retirement, KV slot reuse, starvation-freedom, the
event stream, and the submit-time validation contract.  Numerics (the
bit-equality of continuous batching to solo generation on the real
model) lives in ``tests/test_serving_numerics.py``.
"""
import numpy as np
import pytest

from repro.serving import (FakeClock, Request, Scheduler, ServingError,
                           TierSpec, TierStats)
from serving_sim import make_stub_engine, run_scripted, stub_reference


def _req(prompt, n=3, **kw):
    return dict(prompt=np.asarray(prompt, np.int32), max_new_tokens=n, **kw)


# ---------------------------------------------------------------------------
# scheduler + clock units
# ---------------------------------------------------------------------------

def test_fake_clock_is_manual_and_monotone():
    clk = FakeClock(start=5.0)
    assert clk.now() == 5.0
    assert clk.advance(2.5) == 7.5
    with pytest.raises(ServingError):
        clk.advance(-0.1)


def test_scheduler_orders_by_priority_then_arrival():
    sched = Scheduler(("a",))
    for i, prio in enumerate([2, 0, 1, 0]):
        sched.submit(Request(id=f"r{i}", prompt=[1], max_new_tokens=1,
                             tier="a", priority=prio), now=0.0)
    order = [sched.pop_next("a", now=0.0).id for _ in range(4)]
    assert order == ["r1", "r3", "r2", "r0"]  # prio asc, FIFO within prio
    assert sched.pop_next("a", now=0.0) is None


def test_scheduler_aging_promotes_to_priority_zero():
    sched = Scheduler(("a",), aging=10.0)
    old = sched.submit(Request(id="old", prompt=[1], max_new_tokens=1,
                               tier="a", priority=9), now=0.0)
    sched.submit(Request(id="new", prompt=[1], max_new_tokens=1,
                         tier="a", priority=0), now=9.0)
    # before the aging horizon the fresh priority-0 request wins ...
    assert sched.effective_priority(old, now=9.0) == 9
    assert sched.pop_next("a", now=9.0).id == "new"
    sched.submit(Request(id="new2", prompt=[1], max_new_tokens=1,
                         tier="a", priority=0), now=10.0)
    # ... at the horizon the old request is priority 0 and FIFO beats new2
    assert sched.effective_priority(old, now=10.0) == 0
    assert sched.pop_next("a", now=10.0).id == "old"


def test_scheduler_rejects_unknown_tier():
    sched = Scheduler(("a",))
    with pytest.raises(ServingError, match="unknown tier"):
        sched.submit(Request(id="r", prompt=[1], max_new_tokens=1,
                             tier="nope"), now=0.0)


# ---------------------------------------------------------------------------
# submit-time validation (structured errors, never an XLA shape error)
# ---------------------------------------------------------------------------

def test_submit_validation_contract():
    eng, _, _ = make_stub_engine(slots=1, max_len=8)
    with pytest.raises(ServingError, match="unknown tier"):
        eng.submit(np.array([1]), tier="nope")
    with pytest.raises(ServingError, match="empty prompt"):
        eng.submit(np.array([], np.int32))
    with pytest.raises(ServingError, match="max_new_tokens"):
        eng.submit(np.array([1]), max_new_tokens=0)
    with pytest.raises(ServingError, match="max_len=8"):
        eng.submit(np.arange(6), max_new_tokens=4)  # needs 9 > 8 positions
    # boundary: prompt_len + max_new - 1 == max_len is admissible
    eng.submit(np.arange(5), max_new_tokens=4)


def test_unfinished_result_raises():
    eng, _, _ = make_stub_engine()
    r = eng.submit(np.array([1, 2]), max_new_tokens=2)
    with pytest.raises(ServingError, match="not finished"):
        r.result()


def test_engine_rejects_mismatched_tier_specs():
    from repro.serving import Engine
    from serving_sim import StubRunner

    with pytest.raises(ServingError, match="do not match"):
        Engine({"a": StubRunner()}, (TierSpec("b"),))


# ---------------------------------------------------------------------------
# admission order
# ---------------------------------------------------------------------------

def test_admission_order_priority_then_fifo():
    eng, clock, _ = make_stub_engine(slots=1)
    # n=2 so each request occupies the slot for one decode step (an n=1
    # request retires inside the admit loop and the order would not show)
    r_lo = eng.submit(np.array([1]), max_new_tokens=2, priority=2)
    r_hi = eng.submit(np.array([2]), max_new_tokens=2, priority=0)
    r_hi2 = eng.submit(np.array([3]), max_new_tokens=2, priority=0)
    run_scripted(eng, clock, [])
    # priority admits first; FIFO within a priority; only then the laggard
    assert r_hi.admit_step < r_hi2.admit_step < r_lo.admit_step


def test_single_slot_serializes_requests():
    eng, clock, _ = make_stub_engine(slots=1)
    a = eng.submit(np.array([1, 2, 3]), max_new_tokens=3)
    b = eng.submit(np.array([4, 5]), max_new_tokens=2)
    run_scripted(eng, clock, [])
    assert a.done and b.done
    assert b.admit_step > a.finish_step  # b waited for the only slot


# ---------------------------------------------------------------------------
# continuous batching: mid-decode join, retirement, slot reuse
# ---------------------------------------------------------------------------

def test_mid_decode_join():
    eng, clock, runners = make_stub_engine(slots=2)
    long = eng.submit(np.array([1, 2, 3]), max_new_tokens=8)
    # late arrival two steps into long's decode
    reqs, _ = run_scripted(eng, clock, [[], [], [_req([7, 8], n=2)]])
    late = reqs[0]
    assert late.admit_step > long.admit_step      # joined mid-flight ...
    assert late.admit_step < long.finish_step     # ... while long was active
    assert late.finish_step < long.finish_step    # and retired first
    # the join really was batched: some decode call carried both positions
    runner = runners["a"]
    joint = [pos for _, pos in runner.decode_calls
             if (pos > 0).sum() == 2]
    assert joint, "expected at least one decode step with both slots active"
    np.testing.assert_array_equal(long.result(),
                                  stub_reference([1, 2, 3], 8))
    np.testing.assert_array_equal(late.result(), stub_reference([7, 8], 2))


def test_per_request_retirement_frees_slot_same_step():
    eng, clock, _ = make_stub_engine(slots=2)
    short = eng.submit(np.array([1]), max_new_tokens=1)   # prefill-only
    eng.step()
    assert short.done and short.finish_step == short.admit_step
    lane = eng._lanes["a"]
    assert lane.alloc.n_free == 2 and lane.active == {}


def test_kv_slot_reuse_after_retirement():
    eng, clock, runners = make_stub_engine(slots=1)
    a = eng.submit(np.array([1, 2]), max_new_tokens=2)
    b = eng.submit(np.array([9, 9, 9]), max_new_tokens=3)
    run_scripted(eng, clock, [])
    assert a.slot == b.slot == 0                  # the one slot, reused
    assert b.admit_step > a.finish_step
    # reuse did not leak a's state into b's stream
    np.testing.assert_array_equal(b.result(), stub_reference([9, 9, 9], 3))
    assert eng._lanes["a"].alloc.owners == {}     # drained clean


def test_eos_retires_early_with_truncated_result():
    prompt = np.array([3, 1, 4])
    ref = stub_reference(prompt, 8)
    eos = int(ref[2])                 # third token of the deterministic stream
    eng, clock, _ = make_stub_engine(slots=2)
    r = eng.submit(prompt, max_new_tokens=8, eos_id=eos)
    run_scripted(eng, clock, [])
    assert r.done and len(r.tokens) == 3          # stopped at the EOS
    np.testing.assert_array_equal(r.result(), ref[:3])
    assert r.result()[-1] == eos                  # EOS itself is landed


def test_eos_frees_slot_for_waiting_request():
    prompt = np.array([3, 1, 4])
    eos = int(stub_reference(prompt, 8)[1])
    eng, clock, _ = make_stub_engine(slots=1)
    a = eng.submit(prompt, max_new_tokens=8, eos_id=eos)
    b = eng.submit(np.array([9, 9]), max_new_tokens=2)
    run_scripted(eng, clock, [])
    # a stopped at step 2 of 8, so b admitted far earlier than a's cap
    assert len(a.tokens) == 2
    assert a.slot == b.slot == 0                  # slot recycled
    assert b.admit_step > a.finish_step
    np.testing.assert_array_equal(b.result(), stub_reference([9, 9], 2))


def test_eos_never_emitted_runs_to_cap():
    prompt = np.array([5, 6])
    ref = stub_reference(prompt, 4)
    eos = int(max(ref) + 1)                       # not in the stream
    eng, clock, _ = make_stub_engine(slots=1)
    r = eng.submit(prompt, max_new_tokens=4, eos_id=eos)
    run_scripted(eng, clock, [])
    np.testing.assert_array_equal(r.result(), ref)


def test_eos_on_token_callback_reports_done():
    prompt = np.array([2, 7, 1])
    ref = stub_reference(prompt, 8)
    eos = int(ref[1])
    seen = []
    eng, clock, _ = make_stub_engine(slots=1)
    eng.submit(prompt, max_new_tokens=8, eos_id=eos,
               on_token=lambda req, tok, done: seen.append((tok, done)))
    run_scripted(eng, clock, [])
    assert seen == [(int(ref[0]), False), (eos, True)]


# ---------------------------------------------------------------------------
# starvation-freedom under aging
# ---------------------------------------------------------------------------

def test_aging_bounds_low_priority_wait():
    eng, clock, _ = make_stub_engine(slots=1, aging=3.0)
    laggard = eng.submit(np.array([42]), max_new_tokens=1, priority=5)
    # continuous priority-0 flood: one fresh arrival per step, each
    # holding the slot for a decode step (n=2)
    flood = [[_req([i], n=2, priority=0)] for i in range(20)]
    run_scripted(eng, clock, flood, dt=1.0)
    assert laggard.done
    # aged to priority 0 at t=3, then FIFO order admits it ahead of the
    # flood's later arrivals -> bounded admission
    assert laggard.admit_step <= 6


def test_no_aging_starves_low_priority_under_flood():
    eng, clock, _ = make_stub_engine(slots=1, aging=None)
    laggard = eng.submit(np.array([42]), max_new_tokens=1, priority=5)
    flood = [[_req([i], n=2, priority=0)] for i in range(20)]
    for submits in flood:
        clock.advance(1.0)
        for kw in submits:
            eng.submit(**kw)
        eng.step()
    # while the flood lasts, the laggard never runs (the negative control
    # that test_aging_bounds_low_priority_wait is meaningful)
    assert laggard.admit_time is None


# ---------------------------------------------------------------------------
# events, stats, tiers
# ---------------------------------------------------------------------------

def test_event_stream_shape():
    eng, clock, _ = make_stub_engine(slots=1)
    r = eng.submit(np.array([3, 1]), max_new_tokens=3)
    _, events = run_scripted(eng, clock, [])
    mine = [e for e in events if e.request_id == r.id]
    assert [e.kind for e in mine] == ["admit", "token", "token", "token",
                                     "finish"]
    assert [e.token for e in mine if e.kind == "token"] == r.tokens
    assert all(e.tier == "a" for e in mine)
    steps = [e.step for e in mine]
    assert steps == sorted(steps)


def test_on_token_streaming_callback():
    eng, clock, _ = make_stub_engine(slots=1)
    seen = []
    r = eng.submit(np.array([5]), max_new_tokens=2,
                   on_token=lambda req, tok, done: seen.append((tok, done)))
    run_scripted(eng, clock, [])
    assert seen == [(r.tokens[0], False), (r.tokens[1], True)]


def test_tier_stats_accounting():
    eng, clock, _ = make_stub_engine(slots=2)
    eng.submit(np.array([1]), max_new_tokens=3)
    eng.submit(np.array([2]), max_new_tokens=3)
    stats = eng.run()
    s = stats["a"]
    assert isinstance(s, TierStats)
    assert s.n_finished == 2 and s.n_tokens == 6
    # both live the same 2 decode steps (prefill token is step-less)
    assert s.n_decode_steps == 2 and s.mean_occupancy == 2.0


def test_lanes_are_independent_per_tier():
    tiers = (TierSpec("fast", priority=0), TierSpec("slow", priority=1))
    eng, clock, runners = make_stub_engine(tiers=tiers, slots=1)
    a = eng.submit(np.array([1, 2]), tier="fast", max_new_tokens=3)
    b = eng.submit(np.array([3, 4]), tier="slow", max_new_tokens=3)
    run_scripted(eng, clock, [])
    # one slot per lane, but the lanes never queue behind each other
    assert a.admit_step == b.admit_step == 1
    np.testing.assert_array_equal(a.result(), stub_reference([1, 2], 3))
    np.testing.assert_array_equal(b.result(), stub_reference([3, 4], 3))
    # each lane served its request on its own row 0 of its own page pool
    assert a.slot == b.slot == 0
    assert len(runners["fast"].prefill_calls) == 1
    assert len(runners["slow"].prefill_calls) == 1


def test_run_raises_structured_error_on_bound():
    eng, clock, _ = make_stub_engine(slots=1)
    eng.submit(np.array([1]), max_new_tokens=5)
    with pytest.raises(ServingError, match="did not drain"):
        eng.run(max_steps=1)


# ---------------------------------------------------------------------------
# request identity: duplicate ids, ndarray-safe equality, cache bounds
# ---------------------------------------------------------------------------

def test_duplicate_inflight_id_rejected_then_reusable():
    eng, clock, _ = make_stub_engine(slots=2)
    eng.submit(np.array([1, 2]), max_new_tokens=2, request_id="job")
    # same id while the first is still in flight: structured rejection
    # at submit time, not a silent second request shadowing the first
    with pytest.raises(ServingError, match="already in flight"):
        eng.submit(np.array([3]), max_new_tokens=1, request_id="job")
    run_scripted(eng, clock, [])
    # once finished the id is free again (retries reuse ticket ids)
    r2 = eng.submit(np.array([3]), max_new_tokens=1, request_id="job")
    run_scripted(eng, clock, [])
    assert r2.done


def test_failed_submit_does_not_leak_the_id():
    eng, clock, _ = make_stub_engine(slots=1, max_len=8)
    with pytest.raises(ServingError, match="max_len"):
        eng.submit(np.arange(6), max_new_tokens=5, request_id="job")
    # the rejected submit must not have registered "job" as in flight
    r = eng.submit(np.array([1]), max_new_tokens=1, request_id="job")
    run_scripted(eng, clock, [])
    assert r.done


def test_request_equality_is_identity_not_ndarray_compare():
    """Regression: dataclass __eq__ compared ndarray prompts elementwise,
    so Scheduler.pop_next's queue removal raised 'truth value of an
    array is ambiguous' whenever two queued requests had identical
    field values.  Requests now compare by identity (eq=False)."""
    a = Request(id="r0", prompt=np.array([1, 2]), max_new_tokens=1, tier="a")
    b = Request(id="r0", prompt=np.array([1, 2]), max_new_tokens=1, tier="a")
    assert a != b and a == a
    sched = Scheduler(("a",))
    sched.submit(a, now=0.0)
    sched.submit(b, now=0.0)
    assert sched.pop_next("a", now=0.0) is a   # list.remove by identity
    assert sched.pop_next("a", now=0.0) is b
    assert sched.pop_next("a", now=0.0) is None


def test_engine_drains_identical_content_requests():
    # end-to-end shape of the same regression: two indistinguishable
    # payloads queued behind one slot must both retire
    eng, clock, _ = make_stub_engine(slots=1)
    a = eng.submit(np.array([7, 7]), max_new_tokens=2)
    b = eng.submit(np.array([7, 7]), max_new_tokens=2)
    run_scripted(eng, clock, [])
    assert a.done and b.done
    np.testing.assert_array_equal(a.result(), b.result())


def test_prefill_cache_is_lru_bounded():
    # the compiled-prefill cache is keyed per CHUNK shape (not per prompt
    # length) and each entry owns a private jit wrapper, so eviction
    # actually drops the executable
    from repro.serving.engine import TransformerRunner
    from repro.session import Session

    sess = Session("qwen3-4b")
    runner = TransformerRunner(sess.config, sess.params, 1, 16,
                               page_size=4, prefill_cache_size=2)
    row = np.arange(runner.max_pages, dtype=np.int32)  # pages 0..max_pages-1
    for c in (2, 3, 4):               # third distinct chunk shape evicts LRU
        runner.prefill_chunk_step(np.arange(1, c + 1, dtype=np.int32),
                                  0, c, row)
    assert list(runner._prefill) == [("chunk", 3), ("chunk", 4)]
    # hit refreshes the 3-chunk; a new 5-chunk then evicts the 4-chunk
    runner.prefill_chunk_step(np.arange(1, 4, dtype=np.int32), 0, 3, row)
    runner.prefill_chunk_step(np.arange(1, 6, dtype=np.int32), 0, 5, row)
    assert list(runner._prefill) == [("chunk", 3), ("chunk", 5)]
    # prompts sharing a chunk shape share the executable: a length-7
    # prompt chunked at 5 reuses ("chunk", 5) and adds only the tail
    runner.prefill_chunk_step(np.arange(1, 8, dtype=np.int32), 0, 5, row)
    runner.prefill_chunk_step(np.arange(1, 8, dtype=np.int32), 5, 7, row)
    assert list(runner._prefill) == [("chunk", 5), ("chunk", 2)]
    with pytest.raises(ServingError, match="prefill_cache_size"):
        TransformerRunner(sess.config, sess.params, 1, 16,
                          prefill_cache_size=0)
