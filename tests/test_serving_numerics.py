"""Serving numerics: continuous batching never changes a request's bits.

The engine's core contract — every request's token stream is
bit-identical to a solo ``Session.generate`` of the same prompt under the
same accuracy tier, no matter who shared the batch, which slot it landed
in, or when it arrived.  This holds because the decode path is
row-parallel, slot buffers are fully overwritten at admission (zero
tails), masked positions contribute exact zeros, and argmax runs outside
the jit in both paths; here it is asserted black-box:

- on the REAL tiny LM (reduced qwen3-4b): mixed exact/segmented tiers in
  one engine, staggered prompt/continuation lengths forcing mid-decode
  joins and per-row position vectors, each request checked against its
  solo generate (which even uses a different cache ``max_len``);
- property-based on the stub rig: hypothesis draws random arrival
  schedules, priorities, pool sizes and tier placements, and the token
  streams must always equal the schedule-independent solo reference.
"""
import numpy as np
import pytest

from repro.serving import TierSpec
from serving_sim import make_stub_engine, run_scripted, stub_reference

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:       # bare environment: deterministic tests still run
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# real tiny-LM: mixed tiers, bit-equal to solo generate
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def lm_session():
    from repro.session import Session

    return Session("qwen3-4b")  # reduced config, seeded params


TIERS = (TierSpec("premium", "exact", priority=0),
         TierSpec("bulk", "segmented1", priority=1))
POLICY = {t.name: t.policy for t in TIERS}


def _check_against_solo(session, reqs):
    for req in reqs:
        solo = session.replace(policy=POLICY[req.tier]).generate(
            prompts=req.prompt[None], gen_len=req.max_new_tokens)
        np.testing.assert_array_equal(
            req.result(), solo.tokens[0],
            err_msg=f"{req.id} ({req.tier}) diverged from solo generate")


def test_mixed_tiers_bit_equal_to_solo(lm_session, rng):
    eng = lm_session.serving_engine(TIERS, slots=2, max_len=16)
    vocab = lm_session.config.vocab
    # staggered lengths: lanes decode with genuinely different per-row
    # positions, and retirements force mid-decode joins on both lanes
    specs = [("premium", 5, 4), ("bulk", 6, 5), ("premium", 7, 3),
             ("bulk", 4, 6), ("premium", 3, 5)]
    reqs = [eng.submit(rng.integers(0, vocab, L), tier=tier, max_new_tokens=n)
            for tier, L, n in specs]
    eng.run()
    assert all(r.done for r in reqs)
    _check_against_solo(lm_session, reqs)


@pytest.mark.slow
def test_late_arrivals_bit_equal_to_solo(lm_session, rng):
    """Arrivals land mid-decode via a scripted clock; bits still match."""
    from repro.serving import FakeClock

    clock = FakeClock()
    eng = lm_session.serving_engine(TIERS, slots=2, max_len=16, clock=clock)
    vocab = lm_session.config.vocab
    script = [
        [dict(prompt=rng.integers(0, vocab, 6), tier="premium",
              max_new_tokens=6)],
        [],
        [dict(prompt=rng.integers(0, vocab, 4), tier="premium",
              max_new_tokens=4),
         dict(prompt=rng.integers(0, vocab, 5), tier="bulk",
              max_new_tokens=5)],
        [dict(prompt=rng.integers(0, vocab, 3), tier="bulk",
              max_new_tokens=7)],
    ]
    reqs, _ = run_scripted(eng, clock, script)
    _check_against_solo(lm_session, reqs)


def test_chunked_prefill_long_prompt_bit_equal_to_solo(lm_session, rng):
    """The acceptance scenario for paged serving: mixed exact/segmented
    tiers, a prompt LONGER than ``prefill_chunk`` (so it prefills in
    pieces across engine steps, interleaved with live decode), scripted
    late arrivals landing mid-flight, and a small page pool — every
    request still bit-equals its solo generate, and the stats prove the
    chunking actually happened (this is not the whole-prompt fallback)."""
    from repro.serving import FakeClock, pages_for

    clock = FakeClock()
    eng = lm_session.serving_engine(TIERS, slots=2, max_len=32,
                                    page_size=4, prefill_chunk=5,
                                    clock=clock)
    vocab = lm_session.config.vocab
    long_prompt = rng.integers(0, vocab, 13)   # 13 > prefill_chunk=5
    script = [
        [dict(prompt=rng.integers(0, vocab, 4), tier="premium",
              max_new_tokens=8)],
        [dict(prompt=long_prompt, tier="premium", max_new_tokens=4)],
        [],
        [dict(prompt=rng.integers(0, vocab, 6), tier="bulk",
              max_new_tokens=5),
         dict(prompt=rng.integers(0, vocab, 3), tier="bulk",
              max_new_tokens=6)],
    ]
    reqs, _ = run_scripted(eng, clock, script)
    assert all(r.done for r in reqs)
    _check_against_solo(lm_session, reqs)

    prem = eng.lane_stats()["premium"]
    assert prem.n_prefill_chunks >= 1 + 3      # short (1) + long (ceil 13/5)
    assert prem.n_interleave_steps >= 1        # chunks ran beside decode
    assert prem.n_decode_stall_steps == 0      # prefill never starved decode
    # paged reservations, not whole-max_len slots: the 4-token prompt
    # reserved pages for 4 + 8 - 1 = 11 positions, not 32
    assert reqs[0].n_reserved_pages == pages_for(4 + 8 - 1, 4)


def test_eos_bit_equal_to_solo_generate(lm_session, rng):
    """EOS early-stopping in the engine lands exactly the tokens a solo
    ``Session.generate`` with the same ``eos_id`` keeps (its pre-padding
    prefix), and never perturbs a co-batched row without an EOS."""
    vocab = lm_session.config.vocab
    prompt = rng.integers(0, vocab, 5)
    other = rng.integers(0, vocab, 4)
    sess = lm_session.replace(policy=POLICY["premium"])
    base = sess.generate(prompts=prompt[None], gen_len=8)
    eos = int(base.tokens[0, 2])      # stops the stream three tokens in
    solo = sess.generate(prompts=prompt[None], gen_len=8, eos_id=eos)
    n = int(solo.gen_lengths[0])
    assert n < 8                      # the stop really triggered

    eng = lm_session.serving_engine(TIERS, slots=2, max_len=16)
    r_eos = eng.submit(prompt, tier="premium", max_new_tokens=8, eos_id=eos)
    r_full = eng.submit(other, tier="premium", max_new_tokens=8)
    eng.run()
    np.testing.assert_array_equal(r_eos.result(), solo.tokens[0, :n])
    solo_full = sess.generate(prompts=other[None], gen_len=8)
    np.testing.assert_array_equal(r_full.result(), solo_full.tokens[0])


# ---------------------------------------------------------------------------
# property: arrival schedules never change tokens (stub rig)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    @st.composite
    def workloads(draw):
        slots = draw(st.integers(1, 3))
        n_req = draw(st.integers(1, 6))
        reqs = [dict(prompt=draw(st.lists(st.integers(0, 96), min_size=1,
                                          max_size=5)),
                     n=draw(st.integers(1, 4)),
                     priority=draw(st.integers(0, 2)),
                     tier=draw(st.sampled_from(["x", "y"])),
                     step=draw(st.integers(0, 6)))
                for _ in range(n_req)]
        return slots, reqs

    @given(workloads())
    @settings(max_examples=60, deadline=None)
    def test_arrival_schedule_invariance(workload):
        slots, reqs = workload
        tiers = (TierSpec("x", priority=0), TierSpec("y", priority=1))
        eng, clock, _ = make_stub_engine(tiers=tiers, slots=slots,
                                         max_len=64)
        script = [[dict(prompt=np.asarray(r["prompt"], np.int32),
                        tier=r["tier"], max_new_tokens=r["n"],
                        priority=r["priority"])
                   for r in reqs if r["step"] == step]
                  for step in range(max(r["step"] for r in reqs) + 1)]
        submitted, _ = run_scripted(eng, clock, script)
        assert len(submitted) == len(reqs)
        for req in submitted:
            np.testing.assert_array_equal(
                req.result(), stub_reference(req.prompt, req.max_new_tokens),
                err_msg="token stream depended on the arrival schedule")
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_arrival_schedule_invariance():
        pass
