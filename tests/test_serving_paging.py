"""Differential tests for the paged KV cache under memory pressure.

The :class:`tests.serving_sim.StubRunner` stores the context tokens
themselves in its pages and reconstructs every request's context through
the page tables before emitting a token, so these tests are *differential*:
a paging bug (shared page, stale bits, wrong indirection, chunk at the
wrong offset) corrupts the reconstructed context and flips tokens.

The hypothesis property sweeps random arrival schedules, prompt lengths,
``page_size``, ``prefill_chunk`` and pool sizes, asserting the three
paged-serving invariants:

(a) emitted tokens are bit-identical to solo generate (``stub_reference``);
(b) no physical page is ever referenced by two live requests;
(c) freed pages are re-zeroed before reuse (no stale-bit leaks) — the
    stub hard-asserts this on every write, and the drained pool must be
    all-zeros.
"""
import numpy as np
import pytest

from repro.serving import ServingError, TierSpec, pages_for

from serving_sim import make_stub_engine, run_scripted, stub_reference

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:       # bare environment: deterministic tests still run
    HAVE_HYPOTHESIS = False


MAX_LEN = 24


def _live_page_checker(max_steps_tables=None):
    """An ``on_step`` hook asserting invariant (b) after every step: the
    union of page tables across live (prefilling + active) requests has
    no duplicates, and the allocator's view agrees."""

    def check(eng):
        for lane in eng._lanes.values():
            held = []
            for req in list(lane.prefilling.values()) + list(lane.active.values()):
                held.extend(req.pages)
            assert len(held) == len(set(held)), \
                f"page referenced by two live requests: {sorted(held)}"
            assert sorted(held) == sorted(lane.pages.owners), \
                "allocator and request page tables disagree"
            assert all(0 <= p < lane.runner.n_pages for p in held)

    return check


if HAVE_HYPOTHESIS:
    @st.composite
    def paged_workloads(draw):
        page_size = draw(st.integers(1, 5))
        slots = draw(st.integers(1, 3))
        max_pages = pages_for(MAX_LEN, page_size)
        # at least one full-size request must fit; less than
        # slots*max_pages creates genuine page pressure (admission blocks
        # on pages, not rows)
        pages = draw(st.integers(max_pages, slots * max_pages))
        prefill_chunk = draw(st.integers(1, 8))
        n_req = draw(st.integers(1, 6))
        reqs = []
        for _ in range(n_req):
            prompt_len = draw(st.integers(1, 12))
            max_new = draw(st.integers(1, MAX_LEN + 1 - prompt_len))
            step = draw(st.integers(0, 6))
            reqs.append((step, prompt_len, max_new))
        return dict(page_size=page_size, slots=slots, pages=pages,
                    prefill_chunk=prefill_chunk, reqs=reqs)

    @settings(max_examples=60, deadline=None)
    @given(paged_workloads(), st.integers(0, 2 ** 31 - 1))
    def test_paged_serving_invariants(wl, seed):
        rng = np.random.default_rng(seed)
        eng, clock, runners = make_stub_engine(
            slots=wl["slots"], max_len=MAX_LEN, page_size=wl["page_size"],
            pages=wl["pages"], prefill_chunk=wl["prefill_chunk"])
        stub = runners["a"]
        prompts = [rng.integers(0, 97, L).astype(np.int32)
                   for _, L, _ in wl["reqs"]]
        n_steps = max(s for s, _, _ in wl["reqs"]) + 1
        script = [[] for _ in range(n_steps)]
        for (step, _, max_new), prompt in zip(wl["reqs"], prompts):
            script[step].append(dict(prompt=prompt, max_new_tokens=max_new))
        reqs, _ = run_scripted(eng, clock, script,
                               on_step=_live_page_checker())
        assert len(reqs) == len(prompts)
        # (a) bit-identical to solo generate under any schedule/pressure
        # (reqs come back in submission order, so reference each against
        # its OWN prompt)
        for req in reqs:
            np.testing.assert_array_equal(
                req.result(),
                stub_reference(req.prompt, req.max_new_tokens))
        # (c) every page was released and re-zeroed after the drain
        assert (stub.store == 0).all()
        assert stub.n_pages == eng._lanes["a"].pages.n_free_pages
        # reservations were sized to the true need, never whole-max_len
        s = eng._lanes["a"].stats
        want_pages = sum(
            pages_for(req.prompt.shape[0] + req.max_new_tokens - 1,
                      wl["page_size"]) for req in reqs)
        assert s.pages_reserved_sum == want_pages
        assert s.n_decode_stall_steps == 0
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_paged_serving_invariants():
        pass


# ---------------------------------------------------------------------------
# deterministic regressions
# ---------------------------------------------------------------------------

def test_two_short_requests_share_one_long_requests_capacity():
    # the admission over-reservation regression: the pool holds exactly
    # the pages ONE whole-max_len request would consume; under whole-slot
    # accounting the second short request would wait, under page
    # accounting both admit concurrently
    eng, clock, _ = make_stub_engine(slots=2, max_len=16, page_size=4,
                                     pages=4)
    a = eng.submit(np.arange(1, 4), max_new_tokens=4)    # need 6 -> 2 pages
    b = eng.submit(np.arange(4, 9), max_new_tokens=4)    # need 8 -> 2 pages
    run_scripted(eng, clock, [])
    assert a.admit_step == b.admit_step == 1
    assert a.n_reserved_pages == b.n_reserved_pages == 2
    np.testing.assert_array_equal(a.result(), stub_reference(np.arange(1, 4), 4))
    np.testing.assert_array_equal(b.result(), stub_reference(np.arange(4, 9), 4))


def test_page_pressure_serializes_when_pages_exhausted():
    # same pool, but a full-max_len request takes all 4 pages: the short
    # request has a free ROW yet must wait for pages
    eng, clock, _ = make_stub_engine(slots=2, max_len=16, page_size=4,
                                     pages=4)
    big = eng.submit(np.arange(1, 9), max_new_tokens=9)   # need 16 -> 4 pages
    small = eng.submit(np.arange(9, 12), max_new_tokens=2)  # 1 page
    run_scripted(eng, clock, [])
    assert big.admit_step == 1
    assert small.admit_step > big.finish_step or small.admit_step > 1
    assert small.admit_step == big.finish_step + 1
    np.testing.assert_array_equal(small.result(),
                                  stub_reference(np.arange(9, 12), 2))


def test_admission_is_head_of_line_on_pages():
    # a big head request whose pages don't fit yet BLOCKS later small
    # requests of the same priority (no starvation via queue-jumping)
    eng, clock, _ = make_stub_engine(slots=3, max_len=16, page_size=4,
                                     pages=4)
    hog = eng.submit(np.arange(1, 5), max_new_tokens=9, request_id="hog")
    big = eng.submit(np.arange(1, 9), max_new_tokens=9, request_id="big")
    small = eng.submit(np.arange(9, 12), max_new_tokens=2,
                       request_id="small")
    run_scripted(eng, clock, [])
    # hog holds 3 pages; big (4 pages) can't admit and blocks small
    # (1 page would fit!) until hog retires
    assert hog.admit_step == 1
    assert big.admit_step > 1 and small.admit_step >= big.admit_step
    np.testing.assert_array_equal(small.result(),
                                  stub_reference(np.arange(9, 12), 2))


def test_long_prompt_chunks_interleave_with_decode():
    # a long prompt (3 chunks) joins while a short request decodes: the
    # short request keeps landing one token per step through every chunk
    # step — chunked prefill never stalls in-flight decodes
    eng, clock, _ = make_stub_engine(slots=2, max_len=24, page_size=4,
                                     prefill_chunk=4)
    short = eng.submit(np.arange(1, 3), max_new_tokens=12,
                       request_id="short")
    script = [[], [dict(prompt=np.arange(1, 11), max_new_tokens=3,
                        request_id="long")]]
    reqs, events = run_scripted(eng, clock, script)
    long = reqs[0]            # the scripted (second) submission
    # 10-token prompt at chunk 4 -> chunks on steps 2,3,4; first token
    # lands with the last chunk
    first_tok_step = min(e.step for e in events
                        if e.kind == "token" and e.request_id == "long")
    assert long.admit_step == 2
    assert first_tok_step == long.admit_step + 2
    # short landed a decode token on EVERY step of the long prefill
    short_steps = sorted(e.step for e in events
                         if e.kind == "token" and e.request_id == "short")
    assert set(range(2, 5)) <= set(short_steps)
    np.testing.assert_array_equal(short.result(),
                                  stub_reference(np.arange(1, 3), 12))
    np.testing.assert_array_equal(long.result(),
                                  stub_reference(np.arange(1, 11), 3))
    stats = eng._lanes["a"].stats
    assert stats.n_prefill_chunks == 1 + 3     # short (1) + long (3)
    assert stats.n_interleave_steps == 3       # long's chunks ran alongside
    assert stats.n_decode_stall_steps == 0


def test_short_requests_reserve_small_4x_vs_whole_max_len():
    # the acceptance ratio: short requests in a long-max_len tier reserve
    # >= 4x less KV than the whole-max_len slot design would pin
    eng, clock, _ = make_stub_engine(slots=2, max_len=64, page_size=4)
    prompts = [np.arange(1, 4), np.arange(2, 6), np.arange(3, 5)]
    for p in prompts:
        eng.submit(p, max_new_tokens=5)
    run_scripted(eng, clock, [])
    s = eng._lanes["a"].stats
    assert s.n_finished == len(prompts)
    reserved_tokens = s.pages_per_request * 4
    assert reserved_tokens * 4 <= 64, \
        f"paged reservation {reserved_tokens} tokens/request is not >=4x " \
        f"smaller than max_len=64"


def test_submit_rejects_requests_larger_than_page_pool():
    eng, _, _ = make_stub_engine(slots=2, max_len=16, page_size=4, pages=2)
    with pytest.raises(ServingError, match="pages"):
        eng.submit(np.arange(1, 9), max_new_tokens=9)  # 4 pages > pool of 2


def test_freed_pages_are_rezeroed_and_reused():
    eng, clock, runners = make_stub_engine(slots=1, max_len=16, page_size=4,
                                           pages=2)
    a = eng.submit(np.arange(1, 6), max_new_tokens=3)   # 2 pages
    run_scripted(eng, clock, [])
    stub = runners["a"]
    assert (stub.store == 0).all()          # released AND re-zeroed
    # the next occupant reuses the same physical pages (lowest-id-first)
    b = eng.submit(np.arange(6, 11), max_new_tokens=3)
    run_scripted(eng, clock, [])
    assert b.done and (stub.store == 0).all()
    np.testing.assert_array_equal(b.result(),
                                  stub_reference(np.arange(6, 11), 3))


def test_decode_tables_route_inactive_rows_to_null_page():
    eng, clock, runners = make_stub_engine(slots=3, max_len=16, page_size=4)
    eng.submit(np.arange(1, 4), max_new_tokens=3)
    run_scripted(eng, clock, [])
    stub = runners["a"]
    for tables in stub.decode_tables:
        # rows 1/2 never held a request: all-null tables
        assert (tables[1:] == stub.n_pages).all()
        # the active row's table is null past its live pages
        live = tables[0] != stub.n_pages
        assert live.sum() >= 1 and not live[live.argmin():].any()


def test_page_allocator_reservation_accounting():
    from repro.serving import PageAllocator

    pa = PageAllocator(4)
    pa.reserve("a", 3)
    assert pa.n_unreserved == 1 and pa.n_free_pages == 4
    assert pa.can_reserve(1) and not pa.can_reserve(2)
    assert [pa.take_page("a") for _ in range(3)] == [0, 1, 2]
    with pytest.raises(ServingError, match="exceeded its reservation"):
        pa.take_page("a")
    with pytest.raises(ServingError, match="already holds"):
        pa.reserve("a", 1)
    with pytest.raises(ServingError, match="exhausted"):
        pa.reserve("b", 2)
    assert pa.owners == {0: "a", 1: "a", 2: "a"}
    assert pa.release("a") == [0, 1, 2]
    assert pa.n_unreserved == pa.n_free_pages == 4
    with pytest.raises(ServingError, match="no page reservation"):
        pa.release("a")
