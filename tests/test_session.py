"""Session facade: one (arch, policy, backend, mesh) spec behind serve /
dryrun / the sweep; policy loading with one-line errors; the serve CLI's
non-zero exit on malformed policy files."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.metrics import mred
from repro.core.numerics import NumericsConfig
from repro.core.policy import NumericsPolicy, PolicyRule
from repro.models import resnet, transformer
from repro.models.layers import unzip
from repro.session import GenerateResult, Session, SessionError, load_policy

SEG1 = NumericsConfig(mode="segmented", seg_passes=1, backend="xla")
SEG3 = NumericsConfig(mode="segmented", seg_passes=3, backend="xla")


# ---------------------------------------------------------------------------
# construction / policy coercion
# ---------------------------------------------------------------------------

def test_session_presets_and_config():
    s = Session("qwen3-4b", policy="segmented1")
    assert s.config.numerics == SEG1
    assert not s.is_policy
    # "exact" keeps the arch's own numerics
    assert Session("qwen3-4b", policy="exact").config.numerics == \
        Session("qwen3-4b").config.numerics
    # reduced by default; full-size on request
    assert Session("qwen3-4b").config.d_model < \
        Session("qwen3-4b", reduced=False).config.d_model


def test_session_accepts_ready_config_and_policy_object():
    from repro.configs import get_arch

    cfg = get_arch("qwen3-4b").reduced()
    pol = NumericsPolicy((PolicyRule("blocks.*.mlp.*", SEG1),))
    s = Session(cfg, policy=pol)
    assert s.is_policy and s.config.numerics == pol
    assert s.arch_id == cfg.arch_id


def test_session_backend_override_rewrites_all_configs():
    s = Session("qwen3-4b", policy="segmented1", backend="interpret")
    assert s.config.numerics.backend == "interpret"
    pol = NumericsPolicy((PolicyRule("a", SEG1),), default=SEG3)
    sp = Session("qwen3-4b", policy=pol, backend="interpret")
    num = sp.config.numerics
    assert num.default.backend == "interpret"
    assert all(r.config.backend == "interpret" for r in num.rules)


def test_session_policy_json_file_round_trip(tmp_path):
    pol = NumericsPolicy((PolicyRule("blocks.*", SEG1),), default=SEG3)
    p = tmp_path / "policy.json"
    p.write_text(pol.to_json())
    s = Session("qwen3-4b", policy=str(p))
    assert s.config.numerics == pol
    assert load_policy(str(p)) == pol


def test_session_policy_errors_are_one_line():
    with pytest.raises(SessionError, match="cannot read policy file"):
        Session("qwen3-4b", policy="/does/not/exist.json")
    with pytest.raises(SessionError, match="unknown arch"):
        Session("no-such-arch")
    with pytest.raises(SessionError, match="unsupported policy spec"):
        Session("qwen3-4b", policy=3.14)
    # a ScopedPolicy view is prefixed — rejected up front instead of
    # crashing later in ppa_report/save_policy/_with_backend
    pol = NumericsPolicy((PolicyRule("blocks.*", SEG1),))
    with pytest.raises(SessionError, match="ScopedPolicy"):
        Session("qwen3-4b", policy=pol.scope("blocks.0"))


def test_session_replace_rejects_unknown_fields():
    s = Session("qwen3-4b")
    with pytest.raises(SessionError, match="unknown Session.replace field"):
        s.replace(polcy=SEG1)  # typo must not silently no-op


def test_session_policy_malformed_json(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text("{this is not json")
    with pytest.raises(SessionError, match="invalid policy JSON"):
        Session("qwen3-4b", policy=str(bad))
    # valid JSON, invalid schema (unknown config field)
    bad.write_text(json.dumps(
        {"rules": [{"pattern": "x", "config": {"use_pallas": True}}]}))
    with pytest.raises(SessionError, match="invalid policy JSON"):
        Session("qwen3-4b", policy=str(bad))


# ---------------------------------------------------------------------------
# PPA report / layer enumeration
# ---------------------------------------------------------------------------

def test_session_ppa_report_matches_manual_rollup():
    from repro.core import sweep

    s = Session("qwen3-4b", policy="segmented1")
    rep = s.ppa_report()
    paths = transformer.layer_paths(s.config)
    assert rep["n_sites"] == len(paths)
    assert rep["area_um2"] == pytest.approx(
        sweep.policy_area(NumericsPolicy((), default=SEG1), paths))
    assert 0.0 < rep["area_reduction"] < 1.0
    assert rep["compute_scale"] < 1.0  # 1 of 6 MXU passes


def test_session_save_policy_round_trips(tmp_path):
    pol = NumericsPolicy((PolicyRule("blocks.*", SEG1),))
    s = Session("qwen3-4b", policy=pol)
    out = tmp_path / "out.json"
    s.save_policy(str(out))
    assert NumericsPolicy.from_json(out.read_text()) == pol


# ---------------------------------------------------------------------------
# generation (the serve loop)
# ---------------------------------------------------------------------------

def test_session_generate_deterministic_and_policy_equivalence():
    pol = NumericsPolicy((), default=SEG1)
    a = Session("qwen3-4b", policy="segmented1").generate(
        batch=1, prompt_len=4, gen_len=2)
    b = Session("qwen3-4b", policy=pol).generate(
        batch=1, prompt_len=4, gen_len=2)
    assert isinstance(a, GenerateResult)
    assert a.tokens.shape == (1, 2) and a.tokens.dtype == np.int32
    # a default-only policy == the same global config, token-for-token
    np.testing.assert_array_equal(a.tokens, b.tokens)
    assert a.tokens_per_s > 0


def test_session_family_guards():
    with pytest.raises(SessionError, match="generate"):
        Session("qwen3-4b").apply(np.zeros((1, 8, 8, 3), np.float32))
    cfg = resnet.ResNetConfig(widths=(8, 16), blocks=(1, 1))
    with pytest.raises(SessionError, match="from_resnet"):
        _ = Session(cfg).params
    with pytest.raises(SessionError, match="no launch shapes"):
        Session(cfg).dryrun("train_4k")
    with pytest.raises(SessionError, match="unknown dryrun shape"):
        Session("qwen3-4b").dryrun("train4k")


def test_session_generate_reuses_compiled_functions():
    """Repeated generate() on one Session must reuse the jitted prefill/
    decode (per-(config, max_len) cache) instead of recompiling."""
    s = Session("qwen3-4b", policy="segmented1")
    s.generate(batch=1, prompt_len=4, gen_len=2)
    assert len(s._jit_cache) == 1
    s.generate(batch=1, prompt_len=4, gen_len=2)
    assert len(s._jit_cache) == 1          # same key: no new closures
    s.generate(batch=1, prompt_len=4, gen_len=3)
    assert len(s._jit_cache) == 2          # new max_len: new entry


@pytest.fixture(scope="module")
def gen_session():
    """One compiled session shared by the generate(prompts=...) tests."""
    return Session("qwen3-4b", policy="segmented1")


def test_session_generate_explicit_prompts_win_over_shape_args(gen_session,
                                                               rng):
    """``prompts`` overrides batch/prompt_len (taken from the array), and
    a plain nested list is accepted."""
    P = rng.integers(0, gen_session.config.vocab, (3, 6))
    res = gen_session.generate(prompts=P, gen_len=2, batch=99, prompt_len=99)
    assert res.tokens.shape == (3, 2)
    res_list = gen_session.generate(prompts=P.tolist(), gen_len=2)
    np.testing.assert_array_equal(res.tokens, res_list.tokens)


def test_session_generate_left_padded_prompts_pinned(gen_session, rng):
    """Ragged-intent batches are served LEFT-PADDED by the caller, and the
    pad is an ordinary vocab token: no pad masking, so each row's tokens
    equal a solo run of the same literal padded row (rows are
    independent).  Pinned: callers who pad must pad the solo reference
    identically to reproduce batched results."""
    vocab = gen_session.config.vocab
    short = rng.integers(1, vocab, 3)
    long = rng.integers(1, vocab, 6)
    P = np.stack([np.concatenate([np.zeros(3, np.int64), short]), long])
    batched = gen_session.generate(prompts=P, gen_len=3)
    for row in range(2):
        solo = gen_session.generate(prompts=P[row:row + 1], gen_len=3)
        np.testing.assert_array_equal(batched.tokens[row], solo.tokens[0])


def test_session_generate_result_stats_contract(gen_session, rng):
    P = rng.integers(0, gen_session.config.vocab, (2, 4))
    res = gen_session.generate(prompts=P, gen_len=4)
    assert isinstance(res, GenerateResult)
    assert res.tokens.shape == (2, 4) and res.tokens.dtype == np.int32
    assert (0 <= res.tokens).all() and (res.tokens <
                                        gen_session.config.vocab).all()
    assert res.seconds > 0
    assert res.tokens_per_s == pytest.approx(2 * 4 / res.seconds)
    # without eos_id every row is full length
    np.testing.assert_array_equal(res.gen_lengths, [4, 4])


def test_session_generate_eos_bit_transparent(gen_session, rng):
    """EOS stopping never changes a row's pre-EOS tokens: rows that hit
    the stop token match the no-eos run up to (and including) the EOS and
    come back pinned to it after; rows that never hit it are identical
    end to end."""
    P = rng.integers(0, gen_session.config.vocab, (3, 6))
    base = gen_session.generate(prompts=P, gen_len=8)
    eos = int(base.tokens[0, 2])  # some token row 0 emits mid-stream
    res = gen_session.generate(prompts=P, gen_len=8, eos_id=eos)
    assert res.tokens.shape == base.tokens.shape  # padded, shape-stable
    stopped = 0
    for b in range(3):
        row = base.tokens[b]
        hits = np.nonzero(row == eos)[0]
        if hits.size:
            n = hits[0] + 1
            stopped += 1
            np.testing.assert_array_equal(res.tokens[b, :n], row[:n])
            assert (res.tokens[b, n:] == eos).all()
            assert res.gen_lengths[b] == n
        else:
            np.testing.assert_array_equal(res.tokens[b], row)
            assert res.gen_lengths[b] == 8
    assert stopped >= 1  # eos chosen from an emitted token: row 0 stops
    assert res.tokens_per_s == pytest.approx(
        int(res.gen_lengths.sum()) / res.seconds)


def test_session_generate_eos_all_rows_exit_early(gen_session, rng):
    """When every row has finished the decode loop stops instead of
    burning the remaining steps; output is still (batch, gen_len)."""
    P = rng.integers(0, gen_session.config.vocab, (1, 6))
    base = gen_session.generate(prompts=P, gen_len=8)
    eos = int(base.tokens[0, 0])  # very first emitted token
    res = gen_session.generate(prompts=P, gen_len=8, eos_id=eos)
    assert res.tokens.shape == (1, 8)
    assert res.gen_lengths[0] == 1
    assert (res.tokens[0] == eos).all()


# ---------------------------------------------------------------------------
# resnet sessions + auto-configuration (the sweep)
# ---------------------------------------------------------------------------

def _tiny_resnet(seed=0):
    cfg = resnet.ResNetConfig(widths=(8, 16), blocks=(1, 1))
    pp, state = resnet.init(cfg, jax.random.PRNGKey(seed))
    params, _ = unzip(pp)
    rng = np.random.default_rng(seed)
    images = jnp.asarray(rng.standard_normal((4, 8, 8, 3)), jnp.float32)
    return cfg, params, state, images


def test_session_resnet_apply_and_replace():
    cfg, params, state, images = _tiny_resnet()
    sess = Session.from_resnet(cfg, params, state)
    ref = np.asarray(sess.apply(images))
    approx = np.asarray(sess.replace(policy=SEG1).apply(images))
    assert np.isfinite(approx).all()
    assert not np.allclose(ref, approx)
    # replace() didn't mutate the original session
    np.testing.assert_array_equal(ref, np.asarray(sess.apply(images)))


def test_session_resnet_auto_configure_adopts_policy():
    cfg, params, state, images = _tiny_resnet()
    sess = Session.from_resnet(cfg, params, state)
    ref = np.asarray(sess.apply(images), np.float64)
    budget = 5e-3
    res = sess.auto_configure(budget, calib=images,
                              candidates=[("segmented-1", SEG1),
                                          ("segmented-3", SEG3)],
                              method="greedy")
    assert res.error <= budget
    assert res.area_um2 < res.baseline_area_um2
    # the session now serves under the emitted policy
    assert sess.config.numerics == res.policy
    measured = mred(np.asarray(sess.apply(images)), ref)
    assert measured <= budget
    with pytest.raises(SessionError, match="calibration image batch"):
        Session.from_resnet(cfg, params, state).auto_configure(budget)


# ---------------------------------------------------------------------------
# serving tiers (the serve-loop CLI's --tiers spec)
# ---------------------------------------------------------------------------

def test_parse_tiers_spec():
    from repro.session import parse_tiers

    tiers = parse_tiers("premium:exact,bulk:segmented1")
    assert [(t.name, t.policy, t.priority) for t in tiers] == \
        [("premium", "exact", 0), ("bulk", "segmented1", 1)]
    with pytest.raises(SessionError, match="tier spec"):
        parse_tiers("premium")          # missing :policy
    with pytest.raises(SessionError, match="tier spec"):
        parse_tiers("")
    with pytest.raises(SessionError, match="duplicate tier"):
        parse_tiers("a:exact,a:segmented1")


# ---------------------------------------------------------------------------
# serve CLI: thin wrapper + one-line errors, non-zero exit
# ---------------------------------------------------------------------------

def test_serve_cli_missing_policy_file_exits_nonzero(capsys):
    from repro.launch import serve

    rc = serve.main(["--policy", "/does/not/exist.json", "--batch", "1",
                     "--gen-len", "2"])
    assert rc != 0
    err = capsys.readouterr().err.strip()
    assert err.startswith("error:") and "\n" not in err
    assert "cannot read policy file" in err


def test_serve_cli_malformed_policy_file_exits_nonzero(tmp_path, capsys):
    from repro.launch import serve

    bad = tmp_path / "bad.json"
    bad.write_text("{definitely: not json")
    rc = serve.main(["--policy", str(bad), "--batch", "1", "--gen-len", "2"])
    assert rc != 0
    err = capsys.readouterr().err.strip()
    assert err.startswith("error:") and "\n" not in err
    assert "invalid policy JSON" in err


@pytest.mark.slow
def test_serve_function_routes_through_session():
    """serve() == Session.generate, token-for-token (same arch/seed/preset)."""
    from repro.launch.serve import serve

    toks = serve(batch=1, prompt_len=8, gen_len=3, numerics="segmented1")
    res = Session("qwen3-4b", policy="segmented1").generate(
        batch=1, prompt_len=8, gen_len=3)
    np.testing.assert_array_equal(toks, res.tokens)
