"""Substrate tests: data determinism, optimizer, compression, checkpoint,
fault-tolerance logic, sharding rules."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import io as ckpt_io
from repro.data import synthetic
from repro.distributed import fault, sharding
from repro.optim import adamw, compression


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_lm_batch_deterministic_and_shardable():
    cfg = synthetic.DataConfig(vocab=1000, seq_len=64, global_batch=8, seed=3)
    b1 = synthetic.lm_batch(cfg, step=5)
    b2 = synthetic.lm_batch(cfg, step=5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = synthetic.lm_batch(cfg, step=6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # shards are independent slices of the global batch
    s0 = synthetic.lm_batch(cfg, step=5, shard=0, nshards=2)
    s1 = synthetic.lm_batch(cfg, step=5, shard=1, nshards=2)
    assert s0["tokens"].shape[0] == 4
    assert not np.array_equal(s0["tokens"], s1["tokens"])
    # targets are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["targets"][:, :-1])


def test_lm_batch_is_learnable_markov():
    cfg = synthetic.DataConfig(vocab=97, seq_len=128, global_batch=4, seed=0)
    b = synthetic.lm_batch(cfg, 0)
    t = b["tokens"].astype(np.int64)
    pred = (31 * t[:, 1:] + 7 * t[:, :-1]) % cfg.vocab
    hits = (np.abs((b["targets"][:, 1:] - pred) % cfg.vocab) <= 16).mean()
    assert hits > 0.99  # residual noise is bounded by 16


def test_cifar_like_class_structure():
    cfg = synthetic.DataConfig(global_batch=64, seed=1)
    b = synthetic.cifar_like(cfg, 0)
    assert b["images"].shape == (64, 32, 32, 3)
    assert np.isfinite(b["images"]).all()
    assert 0 <= b["labels"].min() and b["labels"].max() < 10


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def _quad_params():
    return {"w": jnp.asarray([1.0, -2.0, 3.0]), "b": jnp.asarray([0.5])}


def test_adamw_descends_quadratic():
    cfg = adamw.AdamWConfig(lr=0.05, weight_decay=0.0, schedule="constant",
                            grad_clip=100.0)
    params = _quad_params()
    state = adamw.init(params, cfg)
    loss_fn = lambda p: jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)
    losses = []
    for _ in range(200):
        g = jax.grad(loss_fn)(params)
        params, state, m = adamw.apply_updates(params, g, state, cfg)
        losses.append(float(loss_fn(params)))
    assert losses[-1] < 1e-2 < losses[0]


def test_adamw_bf16_moments_close_to_fp32():
    k = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(k, (64,))}
    g = {"w": jax.random.normal(jax.random.PRNGKey(1), (64,)) * 0.1}
    out = {}
    for mdt in ("float32", "bfloat16"):
        cfg = adamw.AdamWConfig(moment_dtype=mdt, schedule="constant")
        state = adamw.init(params, cfg)
        p = params
        for _ in range(10):
            p, state, _ = adamw.apply_updates(p, g, state, cfg)
        out[mdt] = np.asarray(p["w"])
        assert state.mu["w"].dtype == jnp.dtype(mdt)
    np.testing.assert_allclose(out["bfloat16"], out["float32"], rtol=0.02, atol=2e-3)


def test_grad_clip_and_schedule():
    g = {"w": jnp.full((4,), 100.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert float(adamw.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(adamw.schedule_lr(cfg, jnp.asarray(s))) for s in (0, 5, 10, 100)]
    assert lrs[0] == 0.0 and lrs[1] == pytest.approx(5e-4)
    assert lrs[2] == pytest.approx(1e-3) and lrs[3] < 2e-4


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------

def test_int8_roundtrip_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 3
    q, scale, n = compression.quantize_int8(x)
    back = compression.dequantize_int8(q, scale, n, x.shape)
    err = np.abs(np.asarray(back - x))
    # blockwise symmetric int8: error < scale/2 per block
    assert err.max() < float(scale.max()) * 0.51


def test_error_feedback_preserves_sum():
    """Over many steps, sum of compressed grads ~ sum of true grads (EF)."""
    rng = np.random.default_rng(0)
    true_sum = np.zeros(256)
    sent_sum = np.zeros(256)
    err = jnp.zeros((256,), jnp.float32)
    for _ in range(50):
        g = jnp.asarray(rng.standard_normal(256) * 0.01, jnp.float32)
        sent, err = compression.compress_with_feedback(g, err)
        true_sum += np.asarray(g)
        sent_sum += np.asarray(sent)
    np.testing.assert_allclose(sent_sum, true_sum, atol=1e-3)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_prune(tmp_path):
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 4), jnp.bfloat16)},
            "s": jnp.asarray(7, jnp.int32)}
    d = str(tmp_path / "ck")
    for step in (1, 2, 3, 4, 5):
        ckpt_io.save(d, step, tree, extra={"loss": 1.0 / step}, keep=3)
    assert ckpt_io.all_steps(d) == [3, 4, 5]
    restored, manifest = ckpt_io.restore(d, tree)
    assert manifest["step"] == 5
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.arange(10))
    assert restored["b"]["c"].dtype == jnp.bfloat16
    assert int(restored["s"]) == 7


def test_checkpoint_ignores_uncommitted(tmp_path):
    tree = {"a": jnp.zeros(3)}
    d = str(tmp_path / "ck")
    ckpt_io.save(d, 1, tree)
    # simulate a crash mid-write: .tmp dir without manifest
    os.makedirs(os.path.join(d, "step_000000002.tmp"))
    assert ckpt_io.latest_step(d) == 1


def test_checkpoint_restore_missing(tmp_path):
    with pytest.raises(FileNotFoundError):
        ckpt_io.restore(str(tmp_path / "nope"), {"a": jnp.zeros(1)})


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_straggler_watchdog():
    w = fault.StepWatchdog(threshold=2.0)
    for step in range(8):
        for worker in range(8):
            w.record(worker, 1.0 if worker != 3 else 3.5)
    assert w.stragglers() == [3]


def test_heartbeats():
    t = [0.0]
    reg = fault.HeartbeatRegistry(timeout_s=10, clock=lambda: t[0])
    for wkr in range(4):
        reg.beat(wkr)
    t[0] = 5.0
    reg.beat(0)
    t[0] = 12.0
    assert reg.dead() == [1, 2, 3]
    assert reg.alive() == [0]


def test_restart_policy_backoff():
    p = fault.RestartPolicy(max_restarts=3, backoff_base_s=1.0)
    delays = [p.next_delay() for _ in range(4)]
    assert delays == [1.0, 2.0, 4.0, None]


def test_elastic_mesh_plan():
    assert fault.plan_elastic_mesh(512, 16) == (32, 16)
    assert fault.plan_elastic_mesh(480, 16) == (16, 16)   # 30 -> pow2 16
    assert fault.plan_elastic_mesh(256, 16) == (16, 16)
    with pytest.raises(ValueError):
        fault.plan_elastic_mesh(8, 16)


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

def test_spec_divisibility_fallback():
    # axis_types / AxisType only exist on newer JAX; the default is Auto
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = {"heads": "model", "batch": ("pod", "data"), "embed": None}
    # 40 heads % 1 == 0 trivially here; emulate a 16-wide axis via fake mesh
    import numpy as np_

    from jax.sharding import PartitionSpec as P
    spec = sharding.spec_for(("batch", "heads", "embed"), (8, 40, 64), mesh, rules)
    assert spec == P("data", "model", None)


def test_spec_divisibility_fallback_16():
    devs = jax.devices() * 1
    # build a virtual mesh shape via abstract Mesh from mesh_utils is not
    # possible on 1 CPU; instead validate the arithmetic helper directly
    class FakeMesh:
        shape = {"data": 16, "model": 16}

    rules = {"heads": "model", "vocab": "model", "batch": ("pod", "data")}
    from jax.sharding import PartitionSpec as P

    spec = sharding.spec_for(("batch", "heads", None), (256, 40, 64), FakeMesh, rules)
    assert spec == P("data", None, None)  # 40 % 16 != 0 -> replicated
    spec2 = sharding.spec_for(("vocab", None), (51865, 384), FakeMesh, rules)
    assert spec2 == P(None, None)          # whisper vocab not divisible
    spec3 = sharding.spec_for(("vocab", None), (256000, 384), FakeMesh, rules)
    assert spec3 == P("model", None)


def test_no_double_axis_use():
    class FakeMesh:
        shape = {"data": 4, "model": 4}

    rules = {"a": "model", "b": "model"}
    from jax.sharding import PartitionSpec as P

    spec = sharding.spec_for(("a", "b"), (8, 8), FakeMesh, rules)
    assert spec == P("model", None)  # second use of 'model' falls back
