"""Design-space exploration (the OpenACM compiler role)."""
import pytest

from repro.core import sweep


def test_sweep_has_pareto_points():
    pts = sweep.sweep(n_samples=20_000)
    assert len(pts) == len(sweep.SWEEPABLE)
    pareto = [p for p in pts if p.pareto]
    assert 3 <= len(pareto) <= len(pts)
    # AC designs should dominate the frontier at mid-accuracy (paper claim)
    names = {p.name for p in pareto}
    assert any(n.startswith("AC") for n in names), names


def test_recommend_meets_budget_and_is_cheapest():
    p = sweep.recommend(1e-3, n_samples=20_000)
    assert p.mred <= 1e-3
    all_ok = [q for q in sweep.sweep(n_samples=20_000) if q.mred <= 1e-3]
    assert p.area_um2 == min(q.area_um2 for q in all_ok)


def test_recommend_infeasible_raises():
    with pytest.raises(ValueError):
        sweep.recommend(1e-12, n_samples=5_000)


def test_exact_always_available_fallback():
    # a loose budget should select the cheapest approximate design (ACL/NC)
    p = sweep.recommend(0.1, n_samples=20_000)
    assert p.area_um2 < 2000
